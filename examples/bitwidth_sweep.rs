//! Figure-1 regeneration as a standalone example: MicroNet-V2 top-1
//! vs quantisation bit width, original vs DFQ. CSV lands in results/.
//!
//!     cargo run --release --example bitwidth_sweep

fn main() -> dfq::Result<()> {
    dfq::experiments::run("fig1")?;
    println!("series saved to results/fig1.csv");
    Ok(())
}
