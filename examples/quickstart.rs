//! Quickstart: the paper's promised "straightforward API call".
//!
//! Loads the pretrained (corrupted) MicroNet-V2, quantises it to INT8
//! with plain per-tensor quantisation and with DFQ, and compares top-1
//! on SynthShapes-10 — Table 1 / Table 2 in miniature.
//!
//!     cargo run --release --example quickstart

use dfq::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
use dfq::eval::{evaluate, Backend};
use dfq::graph::io::Dataset;
use dfq::graph::Model;
use dfq::nn::QuantCfg;
use dfq::quant::QScheme;
use dfq::runtime::{Manifest, Runtime};

fn main() -> dfq::Result<()> {
    let manifest = Manifest::load(dfq::artifacts_dir())?;
    let entry = manifest.arch("micronet_v2")?;
    let model = Model::load(manifest.path(&entry.model))?;
    let dataset =
        Dataset::load(manifest.dataset("classification", "test")?)?;
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());

    let run = |label: &str, cfg: &DfqConfig, bc, bits| -> dfq::Result<()> {
        let prep = quantize_data_free(&model, cfg)?;
        let q = prep.quantize(
            &QScheme::int8_asymmetric().with_bits(bits),
            bits,
            bc,
            None,
        )?;
        let exec = rt.load_model_exec(&manifest, "micronet_v2", 64, &q.model)?;
        let weights = exec.bind_weights(&q.model)?;
        let acc = evaluate(
            &q.model,
            &q.act_cfg,
            &dataset,
            &Backend::Pjrt { exec: &exec, weights: &weights },
            Some(512),
        )?;
        println!("{label:<28} top-1 = {:.2}%", 100.0 * acc);
        Ok(())
    };

    // FP32 reference
    let prep = quantize_data_free(&model, &DfqConfig::baseline())?;
    let exec = rt.load_model_exec(&manifest, "micronet_v2", 64, &prep.model)?;
    let weights = exec.bind_weights(&prep.model)?;
    let fp32 = evaluate(
        &prep.model,
        &QuantCfg::fp32(&prep.model),
        &dataset,
        &Backend::Pjrt { exec: &exec, weights: &weights },
        Some(512),
    )?;
    println!("{:<28} top-1 = {:.2}%", "FP32 original", 100.0 * fp32);

    run(
        "INT8 naive (per-tensor)",
        &DfqConfig::baseline(),
        BiasCorrMode::None,
        8,
    )?;
    run("INT8 DFQ", &DfqConfig::default(), BiasCorrMode::Analytic, 8)?;
    run("INT6 DFQ", &DfqConfig::default(), BiasCorrMode::Analytic, 6)?;
    Ok(())
}
