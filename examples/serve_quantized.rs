//! Serving example: INT8 DFQ models behind the dynamic batcher.
//!
//! Demonstrates the L3 coordinator the way a deployment would use it: a
//! router hosting an f32-oracle variant (reference engine) and a true
//! int8 variant (`serve::QuantExecutor` over `nn::qengine`) side by
//! side, then — when AOT artifacts are present — the PJRT-backed
//! MicroNet-V2 server under three offered loads.
//!
//!     cargo run --release --example serve_quantized

use std::time::Duration;

use dfq::dfq::{quantize_data_free, testutil, BiasCorrMode, DfqConfig};
use dfq::quant::QScheme;
use dfq::serve::{
    EngineExecutor, QuantExecutor, Router, ServeConfig, Server,
};

fn main() -> dfq::Result<()> {
    // -- engine-backed router: f32 oracle + int8, no artifacts needed --
    let model = testutil::two_layer_model(7, true);
    let prep = quantize_data_free(&model, &DfqConfig::default())?;
    let q = prep.quantize(
        &QScheme::int8_asymmetric(),
        8,
        BiasCorrMode::Analytic,
        None,
    )?;

    let cfg = ServeConfig {
        max_batch: 16,
        max_delay: Duration::from_millis(2),
        queue_depth: 256,
        ..ServeConfig::default()
    };
    let mut router = Router::new();
    let (m2, c2) = (q.model.clone(), q.act_cfg.clone());
    router.add(
        "fp32-oracle",
        Server::start(cfg, move || {
            Ok(Box::new(EngineExecutor { model: m2, cfg: c2, max_batch: 16 }))
        }),
    );
    let q2 = q.clone();
    router.add(
        "int8",
        Server::start(cfg, move || {
            Ok(Box::new(QuantExecutor::from_quantized(&q2, 16)?))
        }),
    );

    let x = testutil::random_input(&model, 1, 42);
    for variant in ["fp32-oracle", "int8"] {
        let y = router.client(variant)?.infer(x.clone())?;
        println!(
            "{variant:>12}: output {:?}, mean {:+.4}",
            y.shape(),
            y.mean()
        );
    }
    for (name, snap) in router.shutdown() {
        println!("{name:>12}: {}", snap.report());
    }

    // -- PJRT-backed load demo (skipped when artifacts are absent) -----
    for (label, requests, rate) in [
        ("light load   (50 req/s)", 128usize, 50.0),
        ("medium load (400 req/s)", 256, 400.0),
        ("heavy load (2000 req/s)", 512, 2000.0),
    ] {
        print!("{label}: ");
        match dfq::serve::demo::run_load(
            "micronet_v2",
            requests,
            rate,
            64,
            dfq::serve::demo::ServeBackend::from_env(),
        ) {
            Ok(()) => {}
            Err(e) => {
                println!("skipped ({e})");
                break;
            }
        }
    }
    Ok(())
}
