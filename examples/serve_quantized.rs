//! Serving example: INT8 DFQ MicroNet-V2 behind the dynamic batcher,
//! under three offered loads. Demonstrates the L3 coordinator the way a
//! deployment would use it: router + per-variant servers + metrics.
//!
//!     cargo run --release --example serve_quantized

fn main() -> dfq::Result<()> {
    for (label, requests, rate) in [
        ("light load   (50 req/s)", 128usize, 50.0),
        ("medium load (400 req/s)", 256, 400.0),
        ("heavy load (2000 req/s)", 512, 2000.0),
    ] {
        print!("{label}: ");
        dfq::serve::demo::run_load("micronet_v2", requests, rate, 64)?;
    }
    Ok(())
}
