//! Semantic-segmentation example (paper §5.2.1 / Table 3): quantise the
//! MicroDeepLab model data-free and compare mIoU, then show per-class
//! IoU detail for the DFQ model.
//!
//!     cargo run --release --example segmentation

use dfq::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
use dfq::eval::{evaluate, run_all, Backend, SEG_CLASSES};
use dfq::graph::io::Dataset;
use dfq::graph::Model;
use dfq::nn::QuantCfg;
use dfq::quant::QScheme;
use dfq::runtime::{Manifest, Runtime};

fn main() -> dfq::Result<()> {
    let manifest = Manifest::load(dfq::artifacts_dir())?;
    let entry = manifest.arch("microdeeplab")?;
    let model = Model::load(manifest.path(&entry.model))?;
    let ds = Dataset::load(manifest.dataset("segmentation", "test")?)?;
    let rt = Runtime::cpu()?;
    let n = 512usize.min(ds.len());

    // FP32
    let prep = quantize_data_free(&model, &DfqConfig::baseline())?;
    let exec = rt.load_model_exec(&manifest, "microdeeplab", 64, &prep.model)?;
    let w = exec.bind_weights(&prep.model)?;
    let fp = evaluate(
        &prep.model,
        &QuantCfg::fp32(&prep.model),
        &ds,
        &Backend::Pjrt { exec: &exec, weights: &w },
        Some(n),
    )?;
    println!("FP32 mIoU        = {:.2}%", 100.0 * fp);

    // naive INT8 vs DFQ INT8
    for (label, cfg, bc) in [
        ("naive INT8 mIoU", DfqConfig::baseline(), BiasCorrMode::None),
        ("DFQ INT8 mIoU  ", DfqConfig::default(), BiasCorrMode::Analytic),
    ] {
        let prep = quantize_data_free(&model, &cfg)?;
        let q =
            prep.quantize(&QScheme::int8_asymmetric(), 8, bc, None)?;
        let exec =
            rt.load_model_exec(&manifest, "microdeeplab", 64, &q.model)?;
        let w = exec.bind_weights(&q.model)?;
        let miou = evaluate(
            &q.model,
            &q.act_cfg,
            &ds,
            &Backend::Pjrt { exec: &exec, weights: &w },
            Some(n),
        )?;
        println!("{label} = {:.2}%", 100.0 * miou);
        if bc == BiasCorrMode::Analytic {
            // per-class IoU detail on the DFQ model
            let out = run_all(
                &q.model,
                &q.act_cfg,
                &ds,
                &Backend::Pjrt { exec: &exec, weights: &w },
                n,
            )?;
            let spatial = ds.label_shape[1] * ds.label_shape[2];
            println!("per-class IoU (DFQ INT8):");
            for c in 0..SEG_CLASSES {
                // compute IoU restricted to class c via the generic
                // routine on a 2-class relabelling
                let iou = per_class_iou(&out, &ds.labels[..n * spatial], c);
                println!("  class {c}: {:.2}%", 100.0 * iou);
            }
        }
    }
    Ok(())
}

fn per_class_iou(logits: &dfq::tensor::Tensor, labels: &[i32], cls: usize) -> f64 {
    let s = logits.shape();
    let (n, k, h, w) = (s[0], s[1], s[2], s[3]);
    let spatial = h * w;
    let mut inter = 0u64;
    let mut uni = 0u64;
    for i in 0..n {
        for p in 0..spatial {
            let mut best = 0usize;
            let mut bv = f32::NEG_INFINITY;
            for c in 0..k {
                let v = logits.data()[(i * k + c) * spatial + p];
                if v > bv {
                    bv = v;
                    best = c;
                }
            }
            let gt = labels[i * spatial + p] as usize == cls;
            let pd = best == cls;
            if gt && pd {
                inter += 1;
            }
            if gt || pd {
                uni += 1;
            }
        }
    }
    if uni == 0 { 1.0 } else { inter as f64 / uni as f64 }
}
