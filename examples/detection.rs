//! Object-detection example (paper §5.2.1 / Table 4): MicroSSD-lite
//! quantised data-free; reports mAP@0.5 and shows the decoded boxes of
//! the first few test images for FP32 vs DFQ-INT8.
//!
//!     cargo run --release --example detection

use dfq::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
use dfq::eval::{evaluate, metrics, run_all, Backend};
use dfq::graph::io::Dataset;
use dfq::graph::Model;
use dfq::nn::QuantCfg;
use dfq::quant::QScheme;
use dfq::runtime::{Manifest, Runtime};

fn main() -> dfq::Result<()> {
    let manifest = Manifest::load(dfq::artifacts_dir())?;
    let entry = manifest.arch("microssd")?;
    let model = Model::load(manifest.path(&entry.model))?;
    let ds = Dataset::load(manifest.dataset("detection", "test")?)?;
    let rt = Runtime::cpu()?;
    let n = 512usize.min(ds.len());

    let prep_fp = quantize_data_free(&model, &DfqConfig::baseline())?;
    let exec = rt.load_model_exec(&manifest, "microssd", 64, &prep_fp.model)?;
    let w = exec.bind_weights(&prep_fp.model)?;
    let fp_cfg = QuantCfg::fp32(&prep_fp.model);
    let fp = evaluate(
        &prep_fp.model,
        &fp_cfg,
        &ds,
        &Backend::Pjrt { exec: &exec, weights: &w },
        Some(n),
    )?;
    println!("FP32 mAP@0.5      = {:.2}%", 100.0 * fp);

    let prep = quantize_data_free(&model, &DfqConfig::default())?;
    let q = prep.quantize(
        &QScheme::int8_asymmetric(),
        8,
        BiasCorrMode::Analytic,
        None,
    )?;
    let exec_q = rt.load_model_exec(&manifest, "microssd", 64, &q.model)?;
    let wq = exec_q.bind_weights(&q.model)?;
    let dfq8 = evaluate(
        &q.model,
        &q.act_cfg,
        &ds,
        &Backend::Pjrt { exec: &exec_q, weights: &wq },
        Some(n),
    )?;
    println!("DFQ INT8 mAP@0.5  = {:.2}%", 100.0 * dfq8);

    // show decoded boxes for the first 3 images
    let out = run_all(
        &q.model,
        &q.act_cfg,
        &ds,
        &Backend::Pjrt { exec: &exec_q, weights: &wq },
        3,
    )?;
    let cell = (ds.x.shape()[2] / out.shape()[2]) as f32;
    let dets = metrics::decode_detections(&out, cell, 0.3);
    let gt = metrics::gt_boxes(ds.boxes.as_ref().unwrap());
    for img in 0..3 {
        println!("\nimage {img}: ground truth:");
        for (c, b) in &gt[img] {
            println!("  class {c} @ [{:.0},{:.0},{:.0},{:.0}]",
                     b[0], b[1], b[2], b[3]);
        }
        println!("image {img}: DFQ-INT8 detections:");
        for d in dets.iter().filter(|d| d.image == img) {
            println!(
                "  class {} score {:.2} @ [{:.0},{:.0},{:.0},{:.0}]",
                d.class, d.score, d.bbox[0], d.bbox[1], d.bbox[2], d.bbox[3]
            );
        }
    }
    Ok(())
}
