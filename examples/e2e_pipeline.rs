//! End-to-end driver (DESIGN.md "End-to-end validation"): exercises the
//! FULL stack on the real small workload —
//!
//!   1. load every pretrained (corrupted) model container,
//!   2. run the complete DFQ pipeline (fold → ReLU6 → CLE → absorb →
//!      INT8 quantise → analytic bias correction),
//!   3. evaluate FP32 vs naive-INT8 vs DFQ-INT8 on PJRT executables
//!      produced by the JAX/Pallas AOT path,
//!   4. serve the quantised classifier behind the dynamic batcher and
//!      report latency/throughput.
//!
//! Results are recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example e2e_pipeline

use dfq::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
use dfq::eval::{evaluate, Backend};
use dfq::graph::io::Dataset;
use dfq::graph::Model;
use dfq::nn::QuantCfg;
use dfq::quant::QScheme;
use dfq::runtime::{Manifest, Runtime};
use dfq::util::table::{pct, Table};

fn main() -> dfq::Result<()> {
    let manifest = Manifest::load(dfq::artifacts_dir())?;
    let rt = Runtime::cpu()?;
    let limit = std::env::var("DFQ_EVAL_LIMIT")
        .ok()
        .and_then(|s| s.parse().ok())
        .or(Some(512));

    let mut t = Table::new(
        "End-to-end: every architecture through the full stack",
        &["arch", "task", "params", "FP32", "naive INT8", "DFQ INT8"],
    );
    let archs: Vec<String> = manifest.archs.keys().cloned().collect();
    for arch in &archs {
        let entry = manifest.arch(arch)?.clone();
        let model = Model::load(manifest.path(&entry.model))?;
        let dataset = Dataset::load(manifest.dataset(&entry.task, "test")?)?;

        let fp = {
            let prep = quantize_data_free(&model, &DfqConfig::baseline())?;
            let exec = rt.load_model_exec(&manifest, arch, 64, &prep.model)?;
            let w = exec.bind_weights(&prep.model)?;
            evaluate(
                &prep.model,
                &QuantCfg::fp32(&prep.model),
                &dataset,
                &Backend::Pjrt { exec: &exec, weights: &w },
                limit,
            )?
        };
        let naive = {
            let prep = quantize_data_free(&model, &DfqConfig::baseline())?;
            let q = prep.quantize(
                &QScheme::int8_asymmetric(),
                8,
                BiasCorrMode::None,
                None,
            )?;
            let exec = rt.load_model_exec(&manifest, arch, 64, &q.model)?;
            let w = exec.bind_weights(&q.model)?;
            evaluate(
                &q.model,
                &q.act_cfg,
                &dataset,
                &Backend::Pjrt { exec: &exec, weights: &w },
                limit,
            )?
        };
        let dfq8 = {
            let prep = quantize_data_free(&model, &DfqConfig::default())?;
            let q = prep.quantize(
                &QScheme::int8_asymmetric(),
                8,
                BiasCorrMode::Analytic,
                None,
            )?;
            let exec = rt.load_model_exec(&manifest, arch, 64, &q.model)?;
            let w = exec.bind_weights(&q.model)?;
            evaluate(
                &q.model,
                &q.act_cfg,
                &dataset,
                &Backend::Pjrt { exec: &exec, weights: &w },
                limit,
            )?
        };
        t.row(&[
            arch.clone(),
            entry.task.clone(),
            model.param_count().to_string(),
            pct(fp),
            pct(naive),
            pct(dfq8),
        ]);
    }
    t.print();

    println!("\nserving the DFQ-INT8 classifier (dynamic batcher, PJRT):");
    dfq::serve::demo::run_load(
        "micronet_v2",
        256,
        400.0,
        64,
        dfq::serve::demo::ServeBackend::from_env(),
    )?;
    println!("\ne2e pipeline complete.");
    Ok(())
}
