//! Bench: cost of the DFQ pipeline itself — the paper's headline
//! usability claim is "a simple API call"; this measures what that call
//! costs per architecture, per pass (fold, CLE, absorb, quantise, BC).

use dfq::dfq::{
    absorb, bias_correct, bn_fold, equalize, quantize_data_free, relu6,
    BiasCorrMode, DfqConfig,
};
use dfq::graph::Model;
use dfq::quant::QScheme;
use dfq::runtime::Manifest;
use dfq::util::bench::{section, Bench};

fn main() {
    let man = match Manifest::load(dfq::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping pipeline bench (no artifacts): {e:#}");
            return;
        }
    };
    for arch in ["micronet_v2", "micronet_v1", "microresnet18"] {
        section(&format!("DFQ pass costs — {arch}"));
        let entry = man.arch(arch).unwrap();
        let model = Model::load(man.path(&entry.model)).unwrap();

        Bench::new("bn_fold")
            .run(|| {
                std::hint::black_box(bn_fold::fold(&model).unwrap());
            })
            .print();

        let folded = bn_fold::fold(&model).unwrap();
        Bench::new("replace_relu6 + CLE to convergence")
            .run(|| {
                let mut m = folded.clone();
                relu6::replace_relu6(&mut m);
                std::hint::black_box(
                    equalize::equalize(&mut m, 40, 1e-4).unwrap(),
                );
            })
            .print();

        let mut prepared = folded.clone();
        relu6::replace_relu6(&mut prepared);
        equalize::equalize(&mut prepared, 40, 1e-4).unwrap();
        Bench::new("bias absorption")
            .run(|| {
                let mut m = prepared.clone();
                std::hint::black_box(
                    absorb::absorb_high_biases(&mut m, 3.0).unwrap(),
                );
            })
            .print();

        Bench::new("weight quantisation (int8 asym)")
            .run(|| {
                let prep =
                    quantize_data_free(&model, &DfqConfig::default()).unwrap();
                std::hint::black_box(
                    prep.quantize(
                        &QScheme::int8_asymmetric(),
                        8,
                        BiasCorrMode::None,
                        None,
                    )
                    .unwrap(),
                );
            })
            .print();

        Bench::new("analytic bias correction")
            .run(|| {
                let prep =
                    quantize_data_free(&model, &DfqConfig::default()).unwrap();
                let mut q = prep
                    .quantize(
                        &QScheme::int8_asymmetric(),
                        8,
                        BiasCorrMode::None,
                        None,
                    )
                    .unwrap();
                std::hint::black_box(
                    bias_correct::analytic(&mut q.model, &prep.model).unwrap(),
                );
            })
            .print();

        Bench::new("full DFQ API call (prepare + quantise + BC)")
            .run(|| {
                let prep =
                    quantize_data_free(&model, &DfqConfig::default()).unwrap();
                std::hint::black_box(
                    prep.quantize(
                        &QScheme::int8_asymmetric(),
                        8,
                        BiasCorrMode::Analytic,
                        None,
                    )
                    .unwrap(),
                );
            })
            .print();
    }
}
