//! Bench: execution engines — PJRT executable vs pure-Rust reference,
//! plus the standalone Pallas fq-matmul kernel artifact and the
//! reference GEMM/conv primitives. This is the L3/L1 §Perf instrument.

use dfq::dfq::{bn_fold, quantize_data_free, BiasCorrMode, DfqConfig};
use dfq::graph::io::Dataset;
use dfq::graph::Model;
use dfq::nn::{self, QuantCfg};
use dfq::quant::QScheme;
use dfq::runtime::{ExecMeta, Manifest, Runtime};
use dfq::tensor::Tensor;
use dfq::util::bench::{section, Bench};
use dfq::util::rng::Rng;

fn main() {
    // The reference primitives need no artifacts: always bench them (the
    // int8 counterparts live in benches/qengine.rs, same JSON format).
    section("reference primitives");
    let mut rng = Rng::new(1);
    let a: Vec<f32> = rng.normal_vec(1024 * 64, 1.0);
    let b: Vec<f32> = rng.normal_vec(64 * 64, 1.0);
    Bench::new("gemm 1024x64x64 (reference)")
        .run(|| {
            std::hint::black_box(nn::conv::matmul(&a, &b, 1024, 64, 64));
        })
        .with_units(2.0 * 1024.0 * 64.0 * 64.0, "flop")
        .print()
        .print_json();
    let x = Tensor::new(&[8, 24, 16, 16], rng.normal_vec(8 * 24 * 256, 1.0));
    let w = Tensor::new(&[96, 24, 1, 1], rng.normal_vec(96 * 24, 0.3));
    Bench::new("pointwise conv 8x24x16x16 -> 96 (reference)")
        .run(|| {
            std::hint::black_box(nn::conv::conv2d(&x, &w, None, 1, 0, 1));
        })
        .print()
        .print_json();
    let wd = Tensor::new(&[24, 1, 3, 3], rng.normal_vec(24 * 9, 0.3));
    Bench::new("depthwise conv 8x24x16x16 (reference)")
        .run(|| {
            std::hint::black_box(nn::conv::conv2d(&x, &wd, None, 1, 1, 24));
        })
        .print()
        .print_json();

    let man = match Manifest::load(dfq::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping PJRT engine benches (no artifacts): {e:#}");
            return;
        }
    };
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping PJRT engine benches: {e:#}");
            return;
        }
    };

    section("pallas fq-matmul kernel (AOT, PJRT)");
    if let Some((hlo, m, k, n)) = man.kernel_bench.clone() {
        let exec = rt
            .load(
                &man.path(&hlo),
                ExecMeta {
                    batch: m,
                    input_shape: [0, 0, 0],
                    num_weights: 0,
                    num_sites: 0,
                    num_outputs: 1,
                },
            )
            .expect("kernel hlo");
        let xk = Tensor::new(&[m, k], rng.normal_vec(m * k, 1.0));
        let wk = Tensor::new(&[k, n], rng.normal_vec(k * n, 1.0));
        let bk = Tensor::new(&[n], rng.normal_vec(n, 1.0));
        let cfg = Tensor::new(
            &[8],
            vec![0.0, 6.0, 0.05, 128.0, 256.0, 0.0, 0.0, 0.0],
        );
        Bench::new(format!("fq_matmul {m}x{k}x{n} fused epilogue"))
            .run(|| {
                std::hint::black_box(
                    exec.run_raw(&[&xk, &wk, &bk, &cfg]).expect("kernel run"),
                );
            })
            .with_units(2.0 * (m * k * n) as f64, "flop")
            .print();
    }

    section("micronet_v2 end-to-end forward");
    let entry = man.arch("micronet_v2").unwrap();
    let model = Model::load(man.path(&entry.model)).unwrap();
    let prep = quantize_data_free(&model, &DfqConfig::default()).unwrap();
    let q = prep
        .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::Analytic, None)
        .unwrap();
    let ds =
        Dataset::load(man.dataset("classification", "test").unwrap()).unwrap();

    for batch in [1usize, 64] {
        let exec = rt
            .load_model_exec(&man, "micronet_v2", batch, &q.model)
            .unwrap();
        let weights = exec.bind_weights(&q.model).unwrap();
        let xb = ds.batch(0, batch);
        Bench::new(format!("pjrt int8 quant-sim forward b{batch}"))
            .run(|| {
                std::hint::black_box(
                    exec.run(&xb, &weights, &q.act_cfg).expect("pjrt run"),
                );
            })
            .with_units(batch as f64, "img")
            .print();
    }
    let folded = bn_fold::fold(&model).unwrap();
    let xb = ds.batch(0, 32);
    let cfg = QuantCfg::fp32(&folded);
    Bench::new("reference engine fp32 forward b32")
        .run(|| {
            std::hint::black_box(nn::forward(&folded, &xb, &cfg).unwrap());
        })
        .with_units(32.0, "img")
        .print();
}
