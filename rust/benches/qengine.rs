//! Bench: true-int8 execution vs the f32 reference engine — raw GEMM
//! (f32 vs u8×i8→i32, per dispatch kernel), whole conv layers (im2col +
//! GEMM + requant epilogue vs im2col + f32 GEMM) across MobileNet-ish
//! shapes, and the end-to-end planned executor vs the fake-quant engine
//! on a residual block model at batch 1/8/32.
//!
//! Prints the human report lines *and* the shared one-line JSON records
//! (see `BenchResult::json`, same format as `benches/engine.rs`), so the
//! driver can diff int8 vs f32 throughput mechanically. Every record is
//! also persisted to `BENCH_qengine.json` at the repo root (JSON lines),
//! together with derived `int8-vs-f32` throughput-ratio records at batch
//! 1/8/32 and the active dispatch kernel, so successive runs on the same
//! host are diffable without scraping stdout. `--quick` (the CI smoke
//! mode) forces single-iteration runs via `DFQ_BENCH_FAST`.

use dfq::dfq::{quantize_data_free, testutil, BiasCorrMode, DfqConfig};
use dfq::nn::conv;
use dfq::nn::qengine::{
    self, qgemm_into_kind, EpiSpec, QActTensor, QConv,
};
use dfq::nn::{self, SiteCfg};
use dfq::quant::{params_for_range, quantize_weights_retaining, QScheme};
use dfq::tensor::Tensor;
use dfq::util::bench::{section, Bench, BenchResult};
use dfq::util::rng::Rng;

fn rand_t(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
    Tensor::new(shape, rng.normal_vec(shape.iter().product(), std))
}

/// Quantised conv fixture: packed int8 layer + matching f32 operands.
struct Fixture {
    name: String,
    x_f32: Tensor,
    w_f32: Tensor,
    bias: Vec<f32>,
    xq: QActTensor,
    qc: QConv,
    stride: usize,
    pad: usize,
    groups: usize,
    flops: f64,
}

#[allow(clippy::too_many_arguments)]
fn fixture(
    rng: &mut Rng,
    name: &str,
    n: usize,
    c_in: usize,
    c_out: usize,
    hw: usize,
    k: usize,
    stride: usize,
    groups: usize,
) -> Fixture {
    let pad = k / 2;
    let mut w = rand_t(rng, &[c_out, c_in / groups, k, k], 0.3);
    let (_, codes) =
        quantize_weights_retaining(&mut w, &QScheme::int8_asymmetric())
            .unwrap();
    let bias: Vec<f32> = rng.normal_vec(c_out, 0.1);

    // ReLU-looking input: non-negative, on a zp=0 grid like a real
    // inter-layer feature map
    let mut x = rand_t(rng, &[n, c_in, hw, hw], 1.0);
    x.map_inplace(|v| v.max(0.0));
    let in_qp = params_for_range(0.0, x.max().max(0.1), 8, false);
    let xq = QActTensor::quantize(&x, &in_qp);
    let x_f32 = xq.dequantize();

    let y = conv::conv2d(&x_f32, &w, Some(&bias), stride, pad, groups);
    let p = params_for_range(0.0, y.max().max(0.1), 8, false);
    let row = SiteCfg {
        scale: p.scale,
        zero_point: p.zero_point,
        n_levels: p.n_levels,
        clip_hi: f32::INFINITY,
    };
    let qc = QConv::pack(
        &codes,
        &bias,
        stride,
        pad,
        groups,
        &in_qp,
        EpiSpec::Act(&row),
    )
    .unwrap();

    let oh = (hw + 2 * pad - k) / stride + 1;
    let flops =
        2.0 * (n * c_out * oh * oh * (c_in / groups) * k * k) as f64;
    Fixture {
        name: name.to_string(),
        x_f32,
        w_f32: w,
        bias,
        xq,
        qc,
        stride,
        pad,
        groups,
        flops,
    }
}

/// Print a result (report + JSON line) and keep its record.
fn emit(records: &mut Vec<String>, r: &BenchResult) {
    r.print().print_json();
    records.push(r.json());
}

fn main() {
    // `--quick` = CI smoke mode: one iteration per bench, records still
    // emitted in the shared JSON format
    if std::env::args().any(|a| a == "--quick") {
        std::env::set_var("DFQ_BENCH_FAST", "1");
    }
    let mut rng = Rng::new(7);
    let mut records: Vec<String> = Vec::new();

    // which microkernel this host dispatches to (DFQ_FORCE_SCALAR pins
    // it to the scalar reference) — first record so a bench file is
    // self-describing
    let kernel = qengine::active_kind();
    println!("dispatch kernel: {}", kernel.name());
    records.push(format!(
        "{{\"name\":\"dispatch kernel\",\"kind\":{:?}}}",
        kernel.name()
    ));

    section("raw GEMM — f32 vs u8×i8→i32 per dispatch kernel");
    for (m, k, n) in [(3136usize, 64usize, 64usize), (784, 128, 128)] {
        let flops = 2.0 * (m * k * n) as f64;
        let a: Vec<f32> = rng.normal_vec(m * k, 1.0);
        let b: Vec<f32> = rng.normal_vec(k * n, 1.0);
        let r = Bench::new(format!("f32 gemm {m}x{k}x{n}"))
            .run(|| {
                std::hint::black_box(conv::matmul(&a, &b, m, k, n));
            })
            .with_units(flops, "flop");
        emit(&mut records, &r);
        let aq: Vec<u8> =
            (0..m * k).map(|_| rng.below(256) as u8).collect();
        let bq: Vec<i8> =
            (0..k * n).map(|_| rng.below(256) as u8 as i8).collect();
        // every compiled-in kernel this host can run, scalar first: the
        // scalar row is the PR-5 k-unroll baseline the SIMD rows must
        // beat (bitwise-equal outputs — see tests/qengine_parity.rs)
        let mut c = vec![0i32; m * n];
        for kind in qengine::available_kinds() {
            let r = Bench::new(format!(
                "int8 gemm {m}x{k}x{n} [{}]",
                kind.name()
            ))
            .run(|| {
                qgemm_into_kind(kind, &aq, &bq, m, k, n, &mut c);
                std::hint::black_box(&c);
            })
            .with_units(flops, "flop");
            emit(&mut records, &r);
        }
    }

    section("deep-K GEMM — KC cache blocking (k >> KC=512)");
    // a reduction dimension far past the KC=512 slab size, the shape
    // the PR-7 K-blocking targets: the packed panel walks B in
    // KC-sized slabs that stay L1/L2-resident instead of streaming the
    // whole k extent per tile. One row per compiled-in kernel, scalar
    // first as the baseline; outputs are checked bitwise against the
    // scalar oracle right here (i32 wrapping adds are associative, so
    // blocking must not change a single lane).
    {
        let (m, k, n) = (64usize, 4096usize, 64usize);
        let flops = 2.0 * (m * k * n) as f64;
        let aq: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
        let bq: Vec<i8> =
            (0..k * n).map(|_| rng.below(256) as u8 as i8).collect();
        let mut oracle = vec![0i32; m * n];
        qgemm_into_kind(
            qengine::KernelKind::Scalar,
            &aq,
            &bq,
            m,
            k,
            n,
            &mut oracle,
        );
        let mut c = vec![0i32; m * n];
        for kind in qengine::available_kinds() {
            let r = Bench::new(format!(
                "int8 gemm deep-k {m}x{k}x{n} [{}]",
                kind.name()
            ))
            .run(|| {
                qgemm_into_kind(kind, &aq, &bq, m, k, n, &mut c);
                std::hint::black_box(&c);
            })
            .with_units(flops, "flop");
            emit(&mut records, &r);
            assert_eq!(
                c,
                oracle,
                "K-blocked {} kernel drifted from the scalar oracle",
                kind.name()
            );
        }
    }

    section("conv layers (MobileNet-ish) — fake-quant f32 vs fused int8");
    let fixtures = [
        fixture(&mut rng, "pointwise 32->64 @28", 1, 32, 64, 28, 1, 1, 1),
        fixture(&mut rng, "pointwise 64->128 @14", 1, 64, 128, 14, 1, 1, 1),
        fixture(&mut rng, "dense 3x3 32->64 @14", 1, 32, 64, 14, 3, 1, 1),
        fixture(&mut rng, "dense 3x3 s2 32->64 @28", 1, 32, 64, 28, 3, 2, 1),
        fixture(&mut rng, "depthwise 3x3 64 @28", 1, 64, 64, 28, 3, 1, 64),
    ];
    for f in &fixtures {
        let r = Bench::new(format!("f32  conv {}", f.name))
            .run(|| {
                std::hint::black_box(conv::conv2d(
                    &f.x_f32,
                    &f.w_f32,
                    Some(&f.bias),
                    f.stride,
                    f.pad,
                    f.groups,
                ));
            })
            .with_units(f.flops, "flop");
        emit(&mut records, &r);
        let r = Bench::new(format!("int8 conv {}", f.name))
            .run(|| {
                std::hint::black_box(f.qc.run_q(&f.xq).unwrap());
            })
            .with_units(f.flops, "flop");
        emit(&mut records, &r);
    }

    section("end-to-end model — fake-quant f32 engine vs int8 plan");
    // four model shapes: the residual block (dense + depthwise +
    // requantise-add + GAP + head), the inception-style block (max-pool
    // stem + avg-pool branch + requantise-concat), the deeplab-style
    // segmentation head (transposed-conv decoder + global-pool branch)
    // and the ssd-style detection head (rectangular + global pool
    // pyramid) — all planned with zero f32 fallback ops
    let models = [
        ("resblock", testutil::residual_block_model(77)),
        ("inception", testutil::inception_block_model(78)),
        ("deeplab", testutil::deeplab_head_model(79)),
        ("ssd", testutil::ssd_head_model(80)),
    ];
    for (name, m) in models {
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        let q = prep
            .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
            .unwrap();
        let qm = q.pack_int8().unwrap();
        println!("plan[{name}]: {}", qm.summary());
        assert_eq!(qm.fallback_ops(), 0, "{name} must stay fully integer");
        for batch in [1usize, 8, 32] {
            let x = testutil::random_input(&m, batch, 1234 + batch as u64);
            let imgs = batch as f64;
            let r_f32 = Bench::new(format!("f32  e2e {name} batch {batch}"))
                .run(|| {
                    std::hint::black_box(
                        nn::forward(&q.model, &x, &q.act_cfg).unwrap(),
                    );
                })
                .with_units(imgs, "img");
            emit(&mut records, &r_f32);
            let r_int = Bench::new(format!("int8 e2e {name} batch {batch}"))
                .run(|| {
                    std::hint::black_box(qm.run_all(&x).unwrap());
                })
                .with_units(imgs, "img");
            emit(&mut records, &r_int);
            let r = Bench::new(format!("int8 e2e {name} batch {batch} (serial)"))
                .run(|| {
                    std::hint::black_box(qm.run_batch(&x).unwrap());
                })
                .with_units(imgs, "img");
            emit(&mut records, &r);
            // the headline success metric: int8 speedup over the f32
            // engine (>1 means int8 is faster), one record per batch
            let ratio = r_f32.secs.mean / r_int.secs.mean;
            let line = format!(
                "{{\"name\":\"int8-vs-f32 e2e {name} batch {batch}\",\
                 \"kind\":{:?},\"ratio\":{ratio:e}}}",
                kernel.name()
            );
            println!("{line}");
            records.push(line);
        }
    }

    // persist every record for mechanical diffing across runs/hosts
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_qengine.json");
    let mut body = records.join("\n");
    body.push('\n');
    match std::fs::write(path, body) {
        Ok(()) => println!("\nwrote {} records to {path}", records.len()),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
