//! Bench: true-int8 execution vs the f32 reference engine — raw GEMM
//! (u8×i8→i32 vs f32) and whole conv layers (im2col + GEMM + requant
//! epilogue vs im2col + f32 GEMM) across MobileNet-ish shapes.
//!
//! Prints the human report lines *and* the shared one-line JSON records
//! (see `BenchResult::json`, same format as `benches/engine.rs`), so the
//! driver can diff int8 vs f32 throughput mechanically.

use dfq::nn::conv;
use dfq::nn::qengine::{self, QActTensor, QConv};
use dfq::nn::SiteCfg;
use dfq::quant::{params_for_range, quantize_weights_retaining, QScheme};
use dfq::tensor::Tensor;
use dfq::util::bench::{section, Bench};
use dfq::util::rng::Rng;

fn rand_t(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
    Tensor::new(shape, rng.normal_vec(shape.iter().product(), std))
}

/// Quantised conv fixture: packed int8 layer + matching f32 operands.
struct Fixture {
    name: String,
    x_f32: Tensor,
    w_f32: Tensor,
    bias: Vec<f32>,
    xq: QActTensor,
    qc: QConv,
    stride: usize,
    pad: usize,
    groups: usize,
    flops: f64,
}

#[allow(clippy::too_many_arguments)]
fn fixture(
    rng: &mut Rng,
    name: &str,
    n: usize,
    c_in: usize,
    c_out: usize,
    hw: usize,
    k: usize,
    stride: usize,
    groups: usize,
) -> Fixture {
    let pad = k / 2;
    let mut w = rand_t(rng, &[c_out, c_in / groups, k, k], 0.3);
    let (_, codes) =
        quantize_weights_retaining(&mut w, &QScheme::int8_asymmetric())
            .unwrap();
    let bias: Vec<f32> = rng.normal_vec(c_out, 0.1);

    // ReLU-looking input: non-negative, on a zp=0 grid like a real
    // inter-layer feature map
    let mut x = rand_t(rng, &[n, c_in, hw, hw], 1.0);
    x.map_inplace(|v| v.max(0.0));
    let in_qp = params_for_range(0.0, x.max().max(0.1), 8, false);
    let xq = QActTensor::quantize(&x, &in_qp);
    let x_f32 = xq.dequantize();

    let y = conv::conv2d(&x_f32, &w, Some(&bias), stride, pad, groups);
    let p = params_for_range(0.0, y.max().max(0.1), 8, false);
    let row = SiteCfg {
        scale: p.scale,
        zero_point: p.zero_point,
        n_levels: p.n_levels,
        clip_hi: f32::INFINITY,
    };
    let qc = QConv::pack(&codes, &bias, stride, pad, groups, &in_qp,
                         Some(&row))
        .unwrap();

    let oh = (hw + 2 * pad - k) / stride + 1;
    let flops =
        2.0 * (n * c_out * oh * oh * (c_in / groups) * k * k) as f64;
    Fixture {
        name: name.to_string(),
        x_f32,
        w_f32: w,
        bias,
        xq,
        qc,
        stride,
        pad,
        groups,
        flops,
    }
}

fn main() {
    let mut rng = Rng::new(7);

    section("raw GEMM — f32 vs u8×i8→i32");
    for (m, k, n) in [(3136usize, 64usize, 64usize), (784, 128, 128)] {
        let flops = 2.0 * (m * k * n) as f64;
        let a: Vec<f32> = rng.normal_vec(m * k, 1.0);
        let b: Vec<f32> = rng.normal_vec(k * n, 1.0);
        Bench::new(format!("f32 gemm {m}x{k}x{n}"))
            .run(|| {
                std::hint::black_box(conv::matmul(&a, &b, m, k, n));
            })
            .with_units(flops, "flop")
            .print()
            .print_json();
        let aq: Vec<u8> =
            (0..m * k).map(|_| rng.below(256) as u8).collect();
        let bq: Vec<i8> =
            (0..k * n).map(|_| rng.below(256) as u8 as i8).collect();
        Bench::new(format!("int8 gemm {m}x{k}x{n}"))
            .run(|| {
                std::hint::black_box(qengine::qgemm(&aq, &bq, m, k, n));
            })
            .with_units(flops, "flop")
            .print()
            .print_json();
    }

    section("conv layers (MobileNet-ish) — fake-quant f32 vs fused int8");
    let fixtures = [
        fixture(&mut rng, "pointwise 32->64 @28", 1, 32, 64, 28, 1, 1, 1),
        fixture(&mut rng, "pointwise 64->128 @14", 1, 64, 128, 14, 1, 1, 1),
        fixture(&mut rng, "dense 3x3 32->64 @14", 1, 32, 64, 14, 3, 1, 1),
        fixture(&mut rng, "dense 3x3 s2 32->64 @28", 1, 32, 64, 28, 3, 2, 1),
        fixture(&mut rng, "depthwise 3x3 64 @28", 1, 64, 64, 28, 3, 1, 64),
    ];
    for f in &fixtures {
        Bench::new(format!("f32  conv {}", f.name))
            .run(|| {
                std::hint::black_box(conv::conv2d(
                    &f.x_f32,
                    &f.w_f32,
                    Some(&f.bias),
                    f.stride,
                    f.pad,
                    f.groups,
                ));
            })
            .with_units(f.flops, "flop")
            .print()
            .print_json();
        Bench::new(format!("int8 conv {}", f.name))
            .run(|| {
                std::hint::black_box(f.qc.run_q(&f.xq).unwrap());
            })
            .with_units(f.flops, "flop")
            .print()
            .print_json();
    }
}
