//! Bench: regenerate every paper table/figure end-to-end and time it.
//!
//! One bench section per table (DESIGN.md §4). Accuracy rows are printed
//! by the drivers themselves; the timings cover the full pipeline
//! (load → DFQ passes → quantise → PJRT evaluation).
//!
//! `DFQ_EVAL_LIMIT` defaults to 256 here so `cargo bench` stays snappy;
//! unset it (or raise it) for full-test-set numbers.

use dfq::experiments;
use dfq::util::bench::{section, Bench};

fn main() {
    if std::env::var_os("DFQ_EVAL_LIMIT").is_none() {
        std::env::set_var("DFQ_EVAL_LIMIT", "256");
    }
    // accuracy tables are deterministic; one timed iteration each
    std::env::set_var("DFQ_BENCH_FAST", "1");

    let ids = [
        "1", "2", "3", "4", "5", "6", "7", "8", "fig1", "fig2", "fig3",
    ];
    for id in ids {
        section(&format!("experiment {id}"));
        let r = Bench::new(format!("regenerate {id}"))
            .run(|| {
                experiments::run(id).expect("experiment failed");
            });
        r.print();
    }
}
