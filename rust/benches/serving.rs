//! Bench: serving coordinator — throughput/latency under Poisson load,
//! batch-size ablation, and batching-window ablation. The L3 §Perf
//! instrument (the paper's deployment motivation: INT8 serving).

use std::time::Duration;

use dfq::dfq::bn_fold;
use dfq::graph::Model;
use dfq::nn::QuantCfg;
use dfq::runtime::Manifest;
use dfq::serve::{EngineExecutor, ServeConfig, Server};
use dfq::tensor::Tensor;
use dfq::util::bench::section;

fn main() {
    let man = match Manifest::load(dfq::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping serving bench (no artifacts): {e:#}");
            return;
        }
    };
    let fast = std::env::var("DFQ_BENCH_FAST").ok().as_deref() == Some("1");
    let requests = if fast { 32 } else { 512 };

    let backend = dfq::serve::demo::ServeBackend::from_env();
    section(&format!(
        "INT8 serving [{}] — offered load sweep",
        backend.as_str()
    ));
    for rate in [50.0, 200.0, 1000.0] {
        match dfq::serve::demo::run_load_quiet(
            "micronet_v2",
            requests,
            rate,
            64,
            backend,
        ) {
            Ok(s) => println!("rate {rate:>6.0} req/s -> {}", s.report()),
            Err(e) => eprintln!("rate {rate}: {e:#}"),
        }
    }

    section(&format!(
        "INT8 serving [{}] — max batch ablation",
        backend.as_str()
    ));
    for batch in [1usize, 64] {
        match dfq::serve::demo::run_load_quiet(
            "micronet_v2",
            requests,
            500.0,
            batch,
            backend,
        ) {
            Ok(s) => println!("batch {batch:>3} -> {}", s.report()),
            Err(e) => eprintln!("batch {batch}: {e:#}"),
        }
    }

    section("engine-backed server — batching window ablation");
    let entry = man.arch("micronet_v2").unwrap();
    let model = Model::load(man.path(&entry.model)).unwrap();
    let folded = bn_fold::fold(&model).unwrap();
    for delay_ms in [0u64, 2, 10] {
        let m2 = folded.clone();
        let server = Server::start(
            ServeConfig {
                max_batch: 32,
                max_delay: Duration::from_millis(delay_ms),
                queue_depth: 2048,
            },
            move || {
                let cfg = QuantCfg::fp32(&m2);
                Ok(Box::new(EngineExecutor {
                    model: m2,
                    cfg,
                    max_batch: 32,
                }))
            },
        );
        let client = server.client();
        let x = Tensor::full(&[1, 3, 32, 32], 0.5);
        let mut pending = Vec::new();
        for _ in 0..requests.min(128) {
            pending.push(client.submit(x.clone()).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let snap = server.shutdown();
        println!("window {delay_ms:>2} ms -> {}", snap.report());
    }
}
