//! Bench: serving coordinator — throughput/latency under Poisson load,
//! batch-size ablation, batching-window ablation, and the compiled-
//! artifact boot comparison (full DFQ recompile vs `.dfqm` load). The
//! L3 §Perf instrument (the paper's deployment motivation: INT8
//! serving). `--quick` runs only the manifest-free artifact sections
//! (the CI smoke step).

use std::time::Duration;

use dfq::dfq::{
    bn_fold, quantize_data_free, testutil, BiasCorrMode, DfqConfig,
};
use dfq::graph::Model;
use dfq::nn::qengine::{PlanOpts, QModel};
use dfq::nn::QuantCfg;
use dfq::quant::QScheme;
use dfq::runtime::Manifest;
use dfq::serve::{EngineExecutor, ServeConfig, Server};
use dfq::tensor::Tensor;
use dfq::util::bench::{section, Bench};

/// Boot-time instrument: what a serving host pays to become ready —
/// replaying the whole DFQ pipeline + planner versus decoding a
/// compiled `.dfqm` artifact. Manifest-free (testutil models), so it
/// runs everywhere including CI; emits the shared BenchResult JSON
/// records next to the human lines.
fn artifact_boot_bench() {
    section("compiled artifact — boot: full DFQ recompile vs .dfqm load");
    let model = testutil::residual_block_model(77);
    let quantize = || {
        let prep =
            quantize_data_free(&model, &DfqConfig::default()).unwrap();
        prep.quantize(
            &QScheme::int8_asymmetric(),
            8,
            BiasCorrMode::Analytic,
            None,
        )
        .unwrap()
    };
    let q = quantize();
    let dir = std::env::temp_dir()
        .join(format!("dfq-serving-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resblock.dfqm");
    let info = q.save_artifact(&path, PlanOpts { int8_only: true }).unwrap();
    println!("artifact: {}", info.summary());

    let recompile = Bench::new("boot/full-dfq-recompile").run(|| {
        let q = quantize();
        let qm = q.pack_int8_opts(PlanOpts { int8_only: true }).unwrap();
        std::hint::black_box(qm.num_ops());
    });
    recompile.print().print_json();
    let load = Bench::new("boot/artifact-load").run(|| {
        let qm = QModel::from_artifact(&path).unwrap();
        std::hint::black_box(qm.num_ops());
    });
    load.print().print_json();
    println!(
        "boot speedup (recompile mean / load mean): {:.1}x",
        recompile.secs.mean / load.secs.mean
    );

    // smoke: the reloaded plan must serve bit-for-bit what the
    // in-memory pipeline serves
    let x = testutil::random_input(&model, 1, 5);
    let want = q.pack_int8().unwrap().run(&x).unwrap();
    let got = QModel::from_artifact(&path).unwrap().run(&x).unwrap();
    assert_eq!(want.data(), got.data(), "artifact round-trip drifted");
    println!("compile -> write -> reload -> run bitwise check: OK");

    // registry smoke: two artifacts served from one process
    let q2 = {
        let m2 = testutil::two_layer_model(78, true);
        let prep = quantize_data_free(&m2, &DfqConfig::default()).unwrap();
        prep.quantize(
            &QScheme::int8_asymmetric(),
            8,
            BiasCorrMode::Analytic,
            None,
        )
        .unwrap()
    };
    q2.save_artifact(dir.join("twolayer.dfqm"), PlanOpts { int8_only: true })
        .unwrap();
    // this doubles as the CI smoke gate — a registry failure must fail
    // the bench run, not scroll past on stderr
    let snaps = dfq::serve::demo::run_registry_load(
        dir.to_str().unwrap(),
        64,
        500.0,
        16,
    )
    .unwrap_or_else(|e| panic!("registry load failed: {e:#}"));
    for (name, snap) in snaps {
        println!("registry[{name}] {}", snap.report());
    }
    std::fs::remove_dir_all(&dir).ok();
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    if quick {
        std::env::set_var("DFQ_BENCH_FAST", "1");
    }
    artifact_boot_bench();
    if quick {
        return;
    }
    let man = match Manifest::load(dfq::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping manifest-backed serving benches: {e:#}");
            return;
        }
    };
    let fast = std::env::var("DFQ_BENCH_FAST").ok().as_deref() == Some("1");
    let requests = if fast { 32 } else { 512 };

    let backend = dfq::serve::demo::ServeBackend::from_env();
    section(&format!(
        "INT8 serving [{}] — offered load sweep",
        backend.as_str()
    ));
    for rate in [50.0, 200.0, 1000.0] {
        match dfq::serve::demo::run_load_quiet(
            "micronet_v2",
            requests,
            rate,
            64,
            backend,
        ) {
            Ok(s) => println!("rate {rate:>6.0} req/s -> {}", s.report()),
            Err(e) => eprintln!("rate {rate}: {e:#}"),
        }
    }

    section(&format!(
        "INT8 serving [{}] — max batch ablation",
        backend.as_str()
    ));
    for batch in [1usize, 64] {
        match dfq::serve::demo::run_load_quiet(
            "micronet_v2",
            requests,
            500.0,
            batch,
            backend,
        ) {
            Ok(s) => println!("batch {batch:>3} -> {}", s.report()),
            Err(e) => eprintln!("batch {batch}: {e:#}"),
        }
    }

    section("engine-backed server — batching window ablation");
    let entry = man.arch("micronet_v2").unwrap();
    let model = Model::load(man.path(&entry.model)).unwrap();
    let folded = bn_fold::fold(&model).unwrap();
    for delay_ms in [0u64, 2, 10] {
        let m2 = folded.clone();
        let server = Server::start(
            ServeConfig {
                max_batch: 32,
                max_delay: Duration::from_millis(delay_ms),
                queue_depth: 2048,
            },
            move || {
                let cfg = QuantCfg::fp32(&m2);
                Ok(Box::new(EngineExecutor {
                    model: m2,
                    cfg,
                    max_batch: 32,
                }))
            },
        );
        let client = server.client();
        let x = Tensor::full(&[1, 3, 32, 32], 0.5);
        let mut pending = Vec::new();
        for _ in 0..requests.min(128) {
            pending.push(client.submit(x.clone()).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let snap = server.shutdown();
        println!("window {delay_ms:>2} ms -> {}", snap.report());
    }
}
