//! Bench: serving coordinator — throughput/latency under Poisson load,
//! batch-size ablation, batching-window ablation, the compiled-
//! artifact boot comparison (full DFQ recompile vs copy load vs
//! zero-copy mmap load, plus the evict/re-load cycle behind
//! `--max-resident`; records persisted to `BENCH_serving.json` at the
//! repo root), a registry hot-swap under load (zero dropped
//! requests), and an
//! autoscale run steering traffic between the f32 and int8 variants.
//! The L3 §Perf instrument (the paper's deployment motivation: INT8
//! serving). `--quick` runs only the manifest-free sections (the CI
//! smoke step).

use std::time::Duration;

use dfq::dfq::{
    bn_fold, quantize_data_free, testutil, BiasCorrMode, DfqConfig,
    QuantizedModel,
};
use dfq::graph::Model;
use dfq::nn::qengine::{PlanOpts, QModel};
use dfq::nn::QuantCfg;
use dfq::quant::QScheme;
use dfq::runtime::Manifest;
use dfq::serve::registry::VARIANT_INT8;
use dfq::serve::{
    AutoscalePolicy, EngineExecutor, Registry, ServeConfig, Server,
};
use dfq::tensor::Tensor;
use dfq::util::bench::{section, Bench};
use dfq::util::rng::Rng;

/// Boot-time instrument: what a serving host pays to become ready —
/// replaying the whole DFQ pipeline + planner, versus decoding a
/// compiled `.dfqm` artifact into owned buffers, versus mmap-viewing
/// it straight out of the page cache — plus the evict/re-load cycle a
/// `--max-resident` cap induces. Manifest-free (testutil models), so it
/// runs everywhere including CI; emits the shared BenchResult JSON
/// records next to the human lines.
fn artifact_boot_bench() -> Vec<String> {
    section(
        "compiled artifact — boot: full DFQ recompile vs copy load vs \
         mmap load",
    );
    let model = testutil::residual_block_model(77);
    let quantize = || {
        let prep =
            quantize_data_free(&model, &DfqConfig::default()).unwrap();
        prep.quantize(
            &QScheme::int8_asymmetric(),
            8,
            BiasCorrMode::Analytic,
            None,
        )
        .unwrap()
    };
    let q = quantize();
    let dir = std::env::temp_dir()
        .join(format!("dfq-serving-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resblock.dfqm");
    let info = q.save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() }).unwrap();
    println!("artifact: {}", info.summary());

    let recompile = Bench::new("boot/full-dfq-recompile").run(|| {
        let q = quantize();
        let qm = q.pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() }).unwrap();
        std::hint::black_box(qm.num_ops());
    });
    recompile.print().print_json();
    let load = Bench::new("boot/artifact-load").run(|| {
        let qm = QModel::from_artifact(&path).unwrap();
        std::hint::black_box(qm.num_ops());
    });
    load.print().print_json();
    let mload = Bench::new("boot/artifact-load-mmap").run(|| {
        let qm = QModel::from_artifact_mmap(&path).unwrap();
        std::hint::black_box(qm.num_ops());
    });
    mload.print().print_json();
    println!(
        "boot speedup vs recompile: copy {:.1}x, mmap {:.1}x \
         (mmap/copy {:.2}x)",
        recompile.secs.mean / load.secs.mean,
        recompile.secs.mean / mload.secs.mean,
        load.secs.mean / mload.secs.mean
    );

    // smoke: both load paths must serve bit-for-bit what the in-memory
    // pipeline serves
    let x = testutil::random_input(&model, 1, 5);
    let want = q.pack_int8().unwrap().run(&x).unwrap();
    let got = QModel::from_artifact(&path).unwrap().run(&x).unwrap();
    assert_eq!(want.data(), got.data(), "artifact round-trip drifted");
    let got = QModel::from_artifact_mmap(&path).unwrap().run(&x).unwrap();
    assert_eq!(want.data(), got.data(), "mmap load drifted from copy");
    println!("compile -> write -> reload -> run bitwise check: OK (both)");

    // registry lifecycle latency: what `--max-resident` eviction costs
    // when the victim comes back — drop the plan, re-load from the page
    // cache (mmap default), spin the servers back up
    let mut reg = Registry::new(ServeConfig {
        max_batch: 16,
        max_delay: Duration::from_millis(1),
        ..ServeConfig::default()
    });
    assert_eq!(reg.scan_dir(&dir).unwrap(), vec!["resblock"]);
    let cycle = Bench::new("boot/evict-reload").run(|| {
        reg.evict("resblock").unwrap();
        reg.reload("resblock").unwrap();
    });
    cycle.print().print_json();
    reg.shutdown();

    // registry smoke: two artifacts served from one process
    let q2 = {
        let m2 = testutil::two_layer_model(78, true);
        let prep = quantize_data_free(&m2, &DfqConfig::default()).unwrap();
        prep.quantize(
            &QScheme::int8_asymmetric(),
            8,
            BiasCorrMode::Analytic,
            None,
        )
        .unwrap()
    };
    q2.save_artifact(dir.join("twolayer.dfqm"), PlanOpts { int8_only: true, ..Default::default() })
        .unwrap();
    // this doubles as the CI smoke gate — a registry failure must fail
    // the bench run, not scroll past on stderr
    let snaps = dfq::serve::demo::run_registry_load(
        dir.to_str().unwrap(),
        dfq::serve::demo::RegistryLoadOpts {
            requests: 64,
            rate: 500.0,
            batch: 16,
            ..Default::default()
        },
    )
    .unwrap_or_else(|e| panic!("registry load failed: {e:#}"));
    for (name, snap) in snaps {
        println!("registry[{name}] {}", snap.report());
    }
    std::fs::remove_dir_all(&dir).ok();
    vec![recompile.json(), load.json(), mload.json(), cycle.json()]
}

fn quantize_resblock(seed: u64) -> QuantizedModel {
    let model = testutil::residual_block_model(seed);
    let prep = quantize_data_free(&model, &DfqConfig::default()).unwrap();
    prep.quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
        .unwrap()
}

/// Registry lifecycle instrument: hot-swap a `.dfqm` behind a live
/// client mid-way through a Poisson run and prove zero requests fail —
/// the pre-swap tail drains on the old server generation while new
/// arrivals hit the replacement. The output split (old-model outputs vs
/// new-model outputs) is the falsifiable part: both must be non-zero.
fn registry_hot_swap_bench() {
    section("registry — hot swap under Poisson load (zero dropped reqs)");
    let dir = std::env::temp_dir()
        .join(format!("dfq-serving-swap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("swap.dfqm");
    let qa = quantize_resblock(91);
    let qb = quantize_resblock(92); // same arch, different weights
    qa.save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() }).unwrap();

    let mut reg = Registry::new(ServeConfig {
        max_batch: 16,
        max_delay: Duration::from_millis(1),
        queue_depth: 4096,
        ..ServeConfig::default()
    });
    assert_eq!(reg.scan_dir(&dir).unwrap(), vec!["swap"]);
    let client = reg.live_client("swap", VARIANT_INT8).unwrap();

    let x = testutil::random_input(&qa.model, 1, 5);
    let want_a = qa.pack_int8().unwrap().run(&x).unwrap();
    let want_b = qb.pack_int8().unwrap().run(&x).unwrap();
    assert_ne!(want_a.data(), want_b.data(), "swap would be invisible");

    let requests = 200usize;
    let mut rng = Rng::new(4242);
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        if i == requests / 2 {
            // overwrite the artifact and swap it in under live load
            qb.save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() }).unwrap();
            reg.reload("swap").unwrap();
        }
        pending.push(client.submit(x.clone()).unwrap());
        let gap = rng.exp(2000.0);
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.05)));
        }
    }
    let (mut served_old, mut served_new, mut failed) = (0u64, 0u64, 0u64);
    for rx in pending {
        match rx.recv() {
            Ok(Ok(y)) if y.data() == want_a.data() => served_old += 1,
            Ok(Ok(y)) if y.data() == want_b.data() => served_new += 1,
            _ => failed += 1,
        }
    }
    assert_eq!(failed, 0, "hot swap dropped {failed} request(s)");
    assert!(
        served_old > 0 && served_new > 0,
        "expected both generations to serve (old {served_old}, new \
         {served_new})"
    );
    println!(
        "{{\"name\":\"serve/hot-swap\",\"requests\":{requests},\
         \"failed\":{failed},\"served_old\":{served_old},\
         \"served_new\":{served_new},\"swaps\":1}}"
    );
    for (model, variant, snap) in reg.shutdown() {
        println!("registry[{model}/{variant}] {}", snap.report());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Autoscale instrument: an in-memory registration hosts the f32 oracle
/// and the int8 plan; a mid-run burst builds queue depth on the oracle
/// and the policy sheds to int8. The JSON record shows the router
/// shifting traffic between the variants.
fn autoscale_bench() {
    section("autoscale — metrics-driven f32 <-> int8 steering");
    let q = quantize_resblock(93);
    let x = testutil::random_input(&q.model, 1, 7);
    let mut reg = Registry::new(ServeConfig {
        max_batch: 16,
        max_delay: Duration::from_millis(1),
        queue_depth: 4096,
        autoscale: Some(AutoscalePolicy {
            queue_shed: 2,
            queue_recover: 1,
            min_window: 4,
            min_dwell: 2,
            tick_every: 4,
            ..AutoscalePolicy::default()
        }),
        ..ServeConfig::default()
    });
    reg.register_quantized("resblock", q).unwrap();
    let client = reg.adaptive_client("resblock").unwrap();
    let failed =
        dfq::serve::demo::drive_adaptive(&client, &[x], 96, 400.0, 64, 4242)
            .unwrap();
    assert_eq!(failed, 0, "autoscale run dropped {failed} request(s)");
    let report = client.report();
    assert!(
        !report.transitions.is_empty(),
        "burst of 64 back-to-back requests never tripped the autoscaler"
    );
    assert!(
        report.routed_f32 > 0 && report.routed_int8 > 0,
        "traffic never shifted (f32 {}, int8 {})",
        report.routed_f32,
        report.routed_int8
    );
    println!("{}", report.summary_line());
    for t in &report.transitions {
        println!("  {}", t.describe());
    }
    println!("{}", report.json("serve/autoscale"));
    for (model, variant, snap) in reg.shutdown() {
        println!("registry[{model}/{variant}] {}", snap.report());
    }
}

/// Observability-overhead instrument: the same int8 plan run with the
/// trace ring + per-op profiling off vs on, over identical inputs. Two
/// falsifiable claims: the instrumented run stays bitwise-identical to
/// the plain one, and the on/off mean-latency ratio lands in the JSON
/// record so regressions diff mechanically. Manifest-free, so it runs
/// under `--quick` (the CI smoke step).
fn observability_overhead_bench() -> Vec<String> {
    section("observability — trace + per-op profile overhead");
    let q = quantize_resblock(94);
    let x = testutil::random_input(&q.model, 4, 9);
    let plain = PlanOpts { int8_only: true, ..Default::default() };
    let qm_off = q.pack_int8_opts(plain).unwrap();
    let qm_on = q
        .pack_int8_opts(PlanOpts { profile: true, ..plain })
        .unwrap();
    let was = dfq::obs::trace::enabled();
    dfq::obs::trace::set_enabled(false);
    let off = Bench::new("obs/trace-profile-off").run(|| {
        std::hint::black_box(qm_off.run(&x).unwrap());
    });
    off.print().print_json();
    dfq::obs::trace::set_enabled(true);
    let on = Bench::new("obs/trace-profile-on").run(|| {
        std::hint::black_box(qm_on.run(&x).unwrap());
    });
    on.print().print_json();
    // instrumentation must not change the math: bitwise-identical logits
    let a = qm_off.run(&x).unwrap();
    let b = qm_on.run(&x).unwrap();
    dfq::obs::trace::set_enabled(was);
    assert_eq!(a.data(), b.data(), "profiled run drifted from plain run");
    let prof = qm_on.profile().expect("profiling was on");
    assert!(prof.runs > 0 && prof.secs() > 0.0, "profile stayed empty");
    let ratio = on.secs.mean / off.secs.mean;
    println!("trace+profile on/off mean-latency ratio: {ratio:.3}x");
    let rec = format!(
        "{{\"name\":\"serve/obs-overhead\",\"off_mean_s\":{:.9},\
         \"on_mean_s\":{:.9},\"on_off_ratio\":{ratio:.4}}}",
        off.secs.mean, on.secs.mean,
    );
    println!("{rec}");
    vec![off.json(), on.json(), rec]
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    if quick {
        std::env::set_var("DFQ_BENCH_FAST", "1");
    }
    let mut records = artifact_boot_bench();
    records.extend(observability_overhead_bench());
    registry_hot_swap_bench();
    autoscale_bench();
    // persist the boot-comparison records (recompile / copy load / mmap
    // load / evict+reload) plus the observability-overhead records for
    // mechanical diffing across runs — same JSON-lines format as
    // BENCH_qengine.json; CI uploads it
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json");
    let mut body = records.join("\n");
    body.push('\n');
    match std::fs::write(path, body) {
        Ok(()) => println!("\nwrote {} records to {path}", records.len()),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    if quick {
        return;
    }
    let man = match Manifest::load(dfq::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping manifest-backed serving benches: {e:#}");
            return;
        }
    };
    let fast = std::env::var("DFQ_BENCH_FAST").ok().as_deref() == Some("1");
    let requests = if fast { 32 } else { 512 };

    let backend = dfq::serve::demo::ServeBackend::from_env();
    section(&format!(
        "INT8 serving [{}] — offered load sweep",
        backend.as_str()
    ));
    for rate in [50.0, 200.0, 1000.0] {
        match dfq::serve::demo::run_load_quiet(
            "micronet_v2",
            requests,
            rate,
            64,
            backend,
            4242,
            None,
        ) {
            Ok(s) => println!("rate {rate:>6.0} req/s -> {}", s.report()),
            Err(e) => eprintln!("rate {rate}: {e:#}"),
        }
    }

    section(&format!(
        "INT8 serving [{}] — max batch ablation",
        backend.as_str()
    ));
    for batch in [1usize, 64] {
        match dfq::serve::demo::run_load_quiet(
            "micronet_v2",
            requests,
            500.0,
            batch,
            backend,
            4242,
            None,
        ) {
            Ok(s) => println!("batch {batch:>3} -> {}", s.report()),
            Err(e) => eprintln!("batch {batch}: {e:#}"),
        }
    }

    section("engine-backed server — batching window ablation");
    let entry = man.arch("micronet_v2").unwrap();
    let model = Model::load(man.path(&entry.model)).unwrap();
    let folded = bn_fold::fold(&model).unwrap();
    for delay_ms in [0u64, 2, 10] {
        let m2 = folded.clone();
        let server = Server::start(
            ServeConfig {
                max_batch: 32,
                max_delay: Duration::from_millis(delay_ms),
                queue_depth: 2048,
                ..ServeConfig::default()
            },
            move || {
                let cfg = QuantCfg::fp32(&m2);
                Ok(Box::new(EngineExecutor {
                    model: m2,
                    cfg,
                    max_batch: 32,
                }))
            },
        );
        let client = server.client();
        let x = Tensor::full(&[1, 3, 32, 32], 0.5);
        let mut pending = Vec::new();
        for _ in 0..requests.min(128) {
            pending.push(client.submit(x.clone()).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let snap = server.shutdown();
        println!("window {delay_ms:>2} ms -> {}", snap.report());
    }
}
