//! Bench: serving coordinator — throughput/latency under Poisson load,
//! batch-size ablation, batching-window ablation, the compiled-
//! artifact boot comparison (full DFQ recompile vs copy load vs
//! zero-copy mmap load, plus the evict/re-load cycle behind
//! `--max-resident`; records persisted to `BENCH_serving.json` at the
//! repo root), a registry hot-swap under load (zero dropped
//! requests), an
//! autoscale run steering traffic between the f32 and int8 variants,
//! and the sharded-ingress instrument (lane scaling, admission-cap
//! shedding with per-SLO-class p99s, under-capacity zero-shed gate).
//! The L3 §Perf instrument (the paper's deployment motivation: INT8
//! serving). `--quick` runs only the manifest-free sections (the CI
//! smoke step).

use std::time::Duration;

use dfq::dfq::{
    bn_fold, quantize_data_free, testutil, BiasCorrMode, DfqConfig,
    QuantizedModel,
};
use dfq::graph::Model;
use dfq::nn::qengine::{PlanOpts, QModel};
use dfq::nn::QuantCfg;
use dfq::quant::QScheme;
use dfq::runtime::Manifest;
use dfq::serve::registry::VARIANT_INT8;
use dfq::serve::{
    AutoscalePolicy, BatchExecutor, EngineExecutor, Priority,
    QuantExecutor, Registry, ServeConfig, Server, SubmitError,
};
use dfq::tensor::Tensor;
use dfq::util::bench::{section, Bench};
use dfq::util::rng::Rng;

/// Boot-time instrument: what a serving host pays to become ready —
/// replaying the whole DFQ pipeline + planner, versus decoding a
/// compiled `.dfqm` artifact into owned buffers, versus mmap-viewing
/// it straight out of the page cache — plus the evict/re-load cycle a
/// `--max-resident` cap induces. Manifest-free (testutil models), so it
/// runs everywhere including CI; emits the shared BenchResult JSON
/// records next to the human lines.
fn artifact_boot_bench() -> Vec<String> {
    section(
        "compiled artifact — boot: full DFQ recompile vs copy load vs \
         mmap load",
    );
    let model = testutil::residual_block_model(77);
    let quantize = || {
        let prep =
            quantize_data_free(&model, &DfqConfig::default()).unwrap();
        prep.quantize(
            &QScheme::int8_asymmetric(),
            8,
            BiasCorrMode::Analytic,
            None,
        )
        .unwrap()
    };
    let q = quantize();
    let dir = std::env::temp_dir()
        .join(format!("dfq-serving-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resblock.dfqm");
    let info = q.save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() }).unwrap();
    println!("artifact: {}", info.summary());

    let recompile = Bench::new("boot/full-dfq-recompile").run(|| {
        let q = quantize();
        let qm = q.pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() }).unwrap();
        std::hint::black_box(qm.num_ops());
    });
    recompile.print().print_json();
    let load = Bench::new("boot/artifact-load").run(|| {
        let qm = QModel::from_artifact(&path).unwrap();
        std::hint::black_box(qm.num_ops());
    });
    load.print().print_json();
    let mload = Bench::new("boot/artifact-load-mmap").run(|| {
        let qm = QModel::from_artifact_mmap(&path).unwrap();
        std::hint::black_box(qm.num_ops());
    });
    mload.print().print_json();
    println!(
        "boot speedup vs recompile: copy {:.1}x, mmap {:.1}x \
         (mmap/copy {:.2}x)",
        recompile.secs.mean / load.secs.mean,
        recompile.secs.mean / mload.secs.mean,
        load.secs.mean / mload.secs.mean
    );

    // smoke: both load paths must serve bit-for-bit what the in-memory
    // pipeline serves
    let x = testutil::random_input(&model, 1, 5);
    let want = q.pack_int8().unwrap().run(&x).unwrap();
    let got = QModel::from_artifact(&path).unwrap().run(&x).unwrap();
    assert_eq!(want.data(), got.data(), "artifact round-trip drifted");
    let got = QModel::from_artifact_mmap(&path).unwrap().run(&x).unwrap();
    assert_eq!(want.data(), got.data(), "mmap load drifted from copy");
    println!("compile -> write -> reload -> run bitwise check: OK (both)");

    // registry lifecycle latency: what `--max-resident` eviction costs
    // when the victim comes back — drop the plan, re-load from the page
    // cache (mmap default), spin the servers back up
    let mut reg = Registry::new(ServeConfig {
        max_batch: 16,
        max_delay: Duration::from_millis(1),
        ..ServeConfig::default()
    });
    assert_eq!(reg.scan_dir(&dir).unwrap(), vec!["resblock"]);
    let cycle = Bench::new("boot/evict-reload").run(|| {
        reg.evict("resblock").unwrap();
        reg.reload("resblock").unwrap();
    });
    cycle.print().print_json();
    reg.shutdown();

    // registry smoke: two artifacts served from one process
    let q2 = {
        let m2 = testutil::two_layer_model(78, true);
        let prep = quantize_data_free(&m2, &DfqConfig::default()).unwrap();
        prep.quantize(
            &QScheme::int8_asymmetric(),
            8,
            BiasCorrMode::Analytic,
            None,
        )
        .unwrap()
    };
    q2.save_artifact(dir.join("twolayer.dfqm"), PlanOpts { int8_only: true, ..Default::default() })
        .unwrap();
    // this doubles as the CI smoke gate — a registry failure must fail
    // the bench run, not scroll past on stderr
    let snaps = dfq::serve::demo::run_registry_load(
        dir.to_str().unwrap(),
        dfq::serve::demo::RegistryLoadOpts {
            requests: 64,
            rate: 500.0,
            batch: 16,
            ..Default::default()
        },
    )
    .unwrap_or_else(|e| panic!("registry load failed: {e:#}"));
    for (name, snap) in snaps {
        println!("registry[{name}] {}", snap.report());
    }
    std::fs::remove_dir_all(&dir).ok();
    vec![recompile.json(), load.json(), mload.json(), cycle.json()]
}

fn quantize_resblock(seed: u64) -> QuantizedModel {
    let model = testutil::residual_block_model(seed);
    let prep = quantize_data_free(&model, &DfqConfig::default()).unwrap();
    prep.quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
        .unwrap()
}

/// Registry lifecycle instrument: hot-swap a `.dfqm` behind a live
/// client mid-way through a Poisson run and prove zero requests fail —
/// the pre-swap tail drains on the old server generation while new
/// arrivals hit the replacement. The output split (old-model outputs vs
/// new-model outputs) is the falsifiable part: both must be non-zero.
fn registry_hot_swap_bench() {
    section("registry — hot swap under Poisson load (zero dropped reqs)");
    let dir = std::env::temp_dir()
        .join(format!("dfq-serving-swap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("swap.dfqm");
    let qa = quantize_resblock(91);
    let qb = quantize_resblock(92); // same arch, different weights
    qa.save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() }).unwrap();

    let mut reg = Registry::new(ServeConfig {
        max_batch: 16,
        max_delay: Duration::from_millis(1),
        queue_depth: 4096,
        ..ServeConfig::default()
    });
    assert_eq!(reg.scan_dir(&dir).unwrap(), vec!["swap"]);
    let client = reg.live_client("swap", VARIANT_INT8).unwrap();

    let x = testutil::random_input(&qa.model, 1, 5);
    let want_a = qa.pack_int8().unwrap().run(&x).unwrap();
    let want_b = qb.pack_int8().unwrap().run(&x).unwrap();
    assert_ne!(want_a.data(), want_b.data(), "swap would be invisible");

    let requests = 200usize;
    let mut rng = Rng::new(4242);
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        if i == requests / 2 {
            // overwrite the artifact and swap it in under live load
            qb.save_artifact(&path, PlanOpts { int8_only: true, ..Default::default() }).unwrap();
            reg.reload("swap").unwrap();
        }
        pending.push(client.submit(x.clone()).unwrap());
        let gap = rng.exp(2000.0);
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.05)));
        }
    }
    let (mut served_old, mut served_new, mut failed) = (0u64, 0u64, 0u64);
    for rx in pending {
        match rx.recv() {
            Ok(Ok(y)) if y.data() == want_a.data() => served_old += 1,
            Ok(Ok(y)) if y.data() == want_b.data() => served_new += 1,
            _ => failed += 1,
        }
    }
    assert_eq!(failed, 0, "hot swap dropped {failed} request(s)");
    assert!(
        served_old > 0 && served_new > 0,
        "expected both generations to serve (old {served_old}, new \
         {served_new})"
    );
    println!(
        "{{\"name\":\"serve/hot-swap\",\"requests\":{requests},\
         \"failed\":{failed},\"served_old\":{served_old},\
         \"served_new\":{served_new},\"swaps\":1}}"
    );
    for (model, variant, snap) in reg.shutdown() {
        println!("registry[{model}/{variant}] {}", snap.report());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Autoscale instrument: an in-memory registration hosts the f32 oracle
/// and the int8 plan; a mid-run burst builds queue depth on the oracle
/// and the policy sheds to int8. The JSON record shows the router
/// shifting traffic between the variants.
fn autoscale_bench() {
    section("autoscale — metrics-driven f32 <-> int8 steering");
    let q = quantize_resblock(93);
    let x = testutil::random_input(&q.model, 1, 7);
    let mut reg = Registry::new(ServeConfig {
        max_batch: 16,
        max_delay: Duration::from_millis(1),
        queue_depth: 4096,
        autoscale: Some(AutoscalePolicy {
            queue_shed: 2,
            queue_recover: 1,
            min_window: 4,
            min_dwell: 2,
            tick_every: 4,
            ..AutoscalePolicy::default()
        }),
        ..ServeConfig::default()
    });
    reg.register_quantized("resblock", q).unwrap();
    let client = reg.adaptive_client("resblock").unwrap();
    let failed =
        dfq::serve::demo::drive_adaptive(&client, &[x], 96, 400.0, 64, 4242)
            .unwrap();
    assert_eq!(failed, 0, "autoscale run dropped {failed} request(s)");
    let report = client.report();
    assert!(
        !report.transitions.is_empty(),
        "burst of 64 back-to-back requests never tripped the autoscaler"
    );
    assert!(
        report.routed_f32 > 0 && report.routed_int8 > 0,
        "traffic never shifted (f32 {}, int8 {})",
        report.routed_f32,
        report.routed_int8
    );
    println!("{}", report.summary_line());
    for t in &report.transitions {
        println!("  {}", t.describe());
    }
    println!("{}", report.json("serve/autoscale"));
    for (model, variant, snap) in reg.shutdown() {
        println!("registry[{model}/{variant}] {}", snap.report());
    }
}

/// Observability-overhead instrument: the same int8 plan run with the
/// trace ring + per-op profiling off vs on, over identical inputs. Two
/// falsifiable claims: the instrumented run stays bitwise-identical to
/// the plain one, and the on/off mean-latency ratio lands in the JSON
/// record so regressions diff mechanically. Manifest-free, so it runs
/// under `--quick` (the CI smoke step).
fn observability_overhead_bench() -> Vec<String> {
    section("observability — trace + per-op profile overhead");
    let q = quantize_resblock(94);
    let x = testutil::random_input(&q.model, 4, 9);
    let plain = PlanOpts { int8_only: true, ..Default::default() };
    let qm_off = q.pack_int8_opts(plain).unwrap();
    let qm_on = q
        .pack_int8_opts(PlanOpts { profile: true, ..plain })
        .unwrap();
    let was = dfq::obs::trace::enabled();
    dfq::obs::trace::set_enabled(false);
    let off = Bench::new("obs/trace-profile-off").run(|| {
        std::hint::black_box(qm_off.run(&x).unwrap());
    });
    off.print().print_json();
    dfq::obs::trace::set_enabled(true);
    let on = Bench::new("obs/trace-profile-on").run(|| {
        std::hint::black_box(qm_on.run(&x).unwrap());
    });
    on.print().print_json();
    // instrumentation must not change the math: bitwise-identical logits
    let a = qm_off.run(&x).unwrap();
    let b = qm_on.run(&x).unwrap();
    dfq::obs::trace::set_enabled(was);
    assert_eq!(a.data(), b.data(), "profiled run drifted from plain run");
    let prof = qm_on.profile().expect("profiling was on");
    assert!(prof.runs > 0 && prof.secs() > 0.0, "profile stayed empty");
    let ratio = on.secs.mean / off.secs.mean;
    println!("trace+profile on/off mean-latency ratio: {ratio:.3}x");
    let rec = format!(
        "{{\"name\":\"serve/obs-overhead\",\"off_mean_s\":{:.9},\
         \"on_mean_s\":{:.9},\"on_off_ratio\":{ratio:.4}}}",
        off.secs.mean, on.secs.mean,
    );
    println!("{rec}");
    vec![off.json(), on.json(), rec]
}

/// Ingress instrument — the three falsifiable claims of the sharded
/// router: (1) lane scaling: the same int8 model behind 1 vs 4 worker
/// lanes at saturation (max_batch 1 forces per-request work, so lanes
/// are the only parallelism axis); (2) bounded admission: ~2x
/// over-capacity offered load must trip the cap with the *typed* shed
/// error, stay memory-bounded, surface the shed counter in the
/// Prometheus exposition, and keep interactive-class p99 at or below
/// batch-class p99 under the 70/30 SLO mix; (3) a wave-paced run that
/// never exceeds half the cap must shed exactly nothing. Manifest-free,
/// so it runs under `--quick`; the CI gate parses the emitted record
/// for `shed_rate` / `p99_interactive` / `under_capacity_shed_rate`.
fn ingress_bench() -> Vec<String> {
    section("ingress — lane scaling, admission control, SLO classes");
    let fast = std::env::var("DFQ_BENCH_FAST").ok().as_deref() == Some("1");
    let q = std::sync::Arc::new(quantize_resblock(95));
    let x = testutil::random_input(&q.model, 1, 3);
    let mk = |lanes: usize, cap: usize, max_batch: usize| {
        let q = std::sync::Arc::clone(&q);
        Server::start_sharded(
            ServeConfig {
                max_batch,
                max_delay: Duration::from_millis(1),
                queue_depth: 8192,
                lanes_per_model: lanes,
                admission_cap: cap,
                ..ServeConfig::default()
            },
            move || {
                Ok(Box::new(QuantExecutor::from_quantized(&q, max_batch)?)
                    as Box<dyn BatchExecutor>)
            },
        )
    };

    // (1) lane scaling at saturation: submit everything up front, time
    // the drain. Warm-up requests spin up every lane's executor first so
    // the measured window is pure service time.
    let requests = if fast { 96 } else { 512 };
    let mut rps = [0.0f64; 2];
    for (slot, lanes) in [(0usize, 1usize), (1, 4)] {
        let server = mk(lanes, 0, 1);
        let client = server.client();
        let warm: Vec<_> = (0..lanes * 4)
            .map(|_| client.submit(x.clone()).unwrap())
            .collect();
        for rx in warm {
            rx.recv().unwrap().unwrap();
        }
        server.reset_metrics();
        let t0 = std::time::Instant::now();
        let pending: Vec<_> = (0..requests)
            .map(|_| client.submit(x.clone()).unwrap())
            .collect();
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        rps[slot] = requests as f64 / dt;
        let snap = server.shutdown();
        assert_eq!(snap.completed, requests as u64, "lost requests");
        println!("lanes {lanes}: {:>8.0} req/s  ({})", rps[slot], snap.report());
    }
    let speedup = rps[1] / rps[0];
    println!(
        "lane speedup 4v1: {speedup:.2}x (host parallelism: {})",
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    );

    // (2) 2x over-capacity: back-to-back submission outruns service, so
    // the admission window fills almost immediately and stays full —
    // everything past it must come back as the typed shed error.
    let cap = 32usize;
    let offered = if fast { 256usize } else { 1024 };
    let server = mk(1, cap, 4);
    let client = server.client();
    client.infer(x.clone()).unwrap();
    server.reset_metrics();
    let mut rng = Rng::new(17);
    let mut shed = 0u64;
    let mut pending = Vec::new();
    for _ in 0..offered {
        let prio = if rng.f64() < 0.7 {
            Priority::Interactive
        } else {
            Priority::Batch
        };
        match client.submit_prio(x.clone(), prio) {
            Ok(rx) => pending.push(rx),
            Err(e) => match e.downcast_ref::<SubmitError>() {
                Some(SubmitError::Shed { in_flight, cap: c }) => {
                    assert!(*in_flight >= *c, "shed below the cap");
                    shed += 1;
                }
                _ => panic!("expected typed Shed, got: {e:#}"),
            },
        }
    }
    let admitted = pending.len();
    for rx in pending {
        rx.recv().unwrap().unwrap();
    }
    let m = server.metrics_handle();
    let p99_i = m.class_percentile(Priority::Interactive, 99.0);
    let p99_b = m.class_percentile(Priority::Batch, 99.0);
    let expo = m.exposition(&[("model", "resblock"), ("variant", "int8")]);
    assert!(
        expo.contains("dfq_requests_shed"),
        "shed counter missing from Prometheus exposition"
    );
    let shed_rate = shed as f64 / offered as f64;
    assert!(shed > 0, "2x over-capacity load never tripped the cap");
    assert_eq!(shed, m.shed(), "client-side and metrics shed counts differ");
    assert!(
        p99_i <= p99_b,
        "SLO inversion: interactive p99 {p99_i}s > batch p99 {p99_b}s"
    );
    println!(
        "over-capacity (cap {cap}): admitted {admitted}, shed {shed}/{offered} \
         ({:.1}%), p99 interactive {:.6}s vs batch {:.6}s",
        100.0 * shed_rate,
        p99_i,
        p99_b
    );
    server.shutdown();

    // (3) calibrated under-capacity: waves of 16 against a cap of 64,
    // each wave fully drained before the next — the admission window can
    // never fill, so any shed here is a bug (CI gates on it).
    let server = mk(2, 64, 8);
    let client = server.client();
    client.infer(x.clone()).unwrap();
    let waves = if fast { 8usize } else { 24 };
    let mut under_shed = 0u64;
    for _ in 0..waves {
        let wave: Vec<_> = (0..16)
            .map(|i| {
                let prio = if i % 4 == 0 {
                    Priority::Batch
                } else {
                    Priority::Interactive
                };
                client.submit_prio(x.clone(), prio)
            })
            .collect();
        for sub in wave {
            match sub {
                Ok(rx) => {
                    rx.recv().unwrap().unwrap();
                }
                Err(_) => under_shed += 1,
            }
        }
    }
    let under_rate = under_shed as f64 / (waves * 16) as f64;
    assert_eq!(under_shed, 0, "calibrated under-capacity load shed requests");
    println!(
        "under-capacity (cap 64, waves of 16): shed {under_shed}/{} -> rate \
         {under_rate:.4}",
        waves * 16
    );
    server.shutdown();

    let rec = format!(
        "{{\"name\":\"serve/ingress\",\"requests\":{requests},\
         \"lanes1_rps\":{:.1},\"lanes4_rps\":{:.1},\
         \"lane_speedup\":{speedup:.3},\"offered\":{offered},\
         \"admission_cap\":{cap},\"shed\":{shed},\"shed_rate\":{shed_rate:.4},\
         \"p99_interactive\":{p99_i:.6},\"p99_batch\":{p99_b:.6},\
         \"under_capacity_shed_rate\":{under_rate:.4}}}",
        rps[0], rps[1],
    );
    println!("{rec}");
    vec![rec]
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    if quick {
        std::env::set_var("DFQ_BENCH_FAST", "1");
    }
    let mut records = artifact_boot_bench();
    records.extend(observability_overhead_bench());
    records.extend(ingress_bench());
    registry_hot_swap_bench();
    autoscale_bench();
    // persist the boot-comparison records (recompile / copy load / mmap
    // load / evict+reload) plus the observability-overhead records for
    // mechanical diffing across runs — same JSON-lines format as
    // BENCH_qengine.json; CI uploads it
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json");
    let mut body = records.join("\n");
    body.push('\n');
    match std::fs::write(path, body) {
        Ok(()) => println!("\nwrote {} records to {path}", records.len()),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
    if quick {
        return;
    }
    let man = match Manifest::load(dfq::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping manifest-backed serving benches: {e:#}");
            return;
        }
    };
    let fast = std::env::var("DFQ_BENCH_FAST").ok().as_deref() == Some("1");
    let requests = if fast { 32 } else { 512 };

    let backend = dfq::serve::demo::ServeBackend::from_env();
    section(&format!(
        "INT8 serving [{}] — offered load sweep",
        backend.as_str()
    ));
    for rate in [50.0, 200.0, 1000.0] {
        match dfq::serve::demo::run_load_quiet(
            "micronet_v2",
            &dfq::serve::demo::LoadOpts {
                requests,
                rate,
                batch: 64,
                backend,
                seed: 4242,
                ..Default::default()
            },
        ) {
            Ok(s) => println!("rate {rate:>6.0} req/s -> {}", s.report()),
            Err(e) => eprintln!("rate {rate}: {e:#}"),
        }
    }

    section(&format!(
        "INT8 serving [{}] — max batch ablation",
        backend.as_str()
    ));
    for batch in [1usize, 64] {
        match dfq::serve::demo::run_load_quiet(
            "micronet_v2",
            &dfq::serve::demo::LoadOpts {
                requests,
                rate: 500.0,
                batch,
                backend,
                seed: 4242,
                ..Default::default()
            },
        ) {
            Ok(s) => println!("batch {batch:>3} -> {}", s.report()),
            Err(e) => eprintln!("batch {batch}: {e:#}"),
        }
    }

    section("engine-backed server — batching window ablation");
    let entry = man.arch("micronet_v2").unwrap();
    let model = Model::load(man.path(&entry.model)).unwrap();
    let folded = bn_fold::fold(&model).unwrap();
    for delay_ms in [0u64, 2, 10] {
        let m2 = folded.clone();
        let server = Server::start(
            ServeConfig {
                max_batch: 32,
                max_delay: Duration::from_millis(delay_ms),
                queue_depth: 2048,
                ..ServeConfig::default()
            },
            move || {
                let cfg = QuantCfg::fp32(&m2);
                Ok(Box::new(EngineExecutor {
                    model: m2,
                    cfg,
                    max_batch: 32,
                }))
            },
        );
        let client = server.client();
        let x = Tensor::full(&[1, 3, 32, 32], 0.5);
        let mut pending = Vec::new();
        for _ in 0..requests.min(128) {
            pending.push(client.submit(x.clone()).unwrap());
        }
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let snap = server.shutdown();
        println!("window {delay_ms:>2} ms -> {}", snap.report());
    }
}
