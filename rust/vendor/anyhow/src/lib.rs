//! Minimal, offline-compatible subset of the `anyhow` error-handling API.
//!
//! The build image has no crates.io access, so this vendored shim provides
//! exactly the surface the crate uses: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`] macros and the [`Context`] extension trait for
//! both `Result` and `Option`. Error values carry a context chain;
//! `{e}` prints the outermost message, `{e:#}` the full `a: b: c` chain
//! (matching real-anyhow formatting closely enough for logs and tests).

use std::fmt;

/// A dynamic error with a chain of context messages.
///
/// `msgs[0]` is the outermost (most recently attached) message; the last
/// entry is the root cause.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msgs: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.msgs.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(String::as_str)
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs.join(": "))
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`;
// that is what makes the blanket conversion below coherent (the same
// trick real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to `Result`
/// and `Option` values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn context_chain_formats() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
    }

    #[test]
    fn std_errors_convert() {
        let r: Result<()> = (|| {
            let _ = std::fs::read("/definitely/not/a/file")?;
            Ok(())
        })();
        assert!(r.is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }
}
