//! Minimal, offline-compatible subset of the `anyhow` error-handling API.
//!
//! The build image has no crates.io access, so this vendored shim provides
//! exactly the surface the crate uses: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`] macros, the [`Context`] extension trait for
//! both `Result` and `Option`, and [`Error::downcast_ref`] for typed
//! root causes. Error values carry a context chain; `{e}` prints the
//! outermost message, `{e:#}` the full `a: b: c` chain (matching
//! real-anyhow formatting closely enough for logs and tests).

use std::any::Any;
use std::fmt;

/// A dynamic error with a chain of context messages.
///
/// `msgs[0]` is the outermost (most recently attached) message; the last
/// entry is the root cause. An error converted from a concrete
/// `std::error::Error` value also retains that value for
/// [`Error::downcast_ref`]; one built from a bare message does not.
pub struct Error {
    msgs: Vec<String>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msgs: vec![message.to_string()], payload: None }
    }

    /// Attach an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.msgs.insert(0, context.to_string());
        self
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(String::as_str)
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(String::as_str).unwrap_or("")
    }

    /// The typed root cause, when this error was converted from a
    /// concrete error value of type `E` (possibly context-wrapped
    /// since). `None` for message-only errors or a type mismatch.
    pub fn downcast_ref<E: Any>(&self) -> Option<&E> {
        self.payload.as_ref()?.downcast_ref::<E>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs.join(": "))
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`;
// that is what makes the blanket conversion below coherent (the same
// trick real anyhow uses).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs, payload: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to `Result`
/// and `Option` values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F)
        -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("root {}", 42)
    }

    #[test]
    fn context_chain_formats() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root 42");
    }

    #[test]
    fn std_errors_convert() {
        let r: Result<()> = (|| {
            let _ = std::fs::read("/definitely/not/a/file")?;
            Ok(())
        })();
        assert!(r.is_err());
    }

    #[test]
    fn downcast_survives_context_wrapping() {
        #[derive(Debug, PartialEq)]
        struct Typed(u32);
        impl fmt::Display for Typed {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "typed {}", self.0)
            }
        }
        impl std::error::Error for Typed {}
        let e: Error = Error::from(Typed(7)).context("outer");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(7)));
        assert!(e.downcast_ref::<std::io::Error>().is_none());
        assert!(Error::msg("plain").downcast_ref::<Typed>().is_none());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }
}
