//! Load-side of the artifact subsystem: decode a `.dfqm` compiled
//! artifact back into a ready-to-run [`QModel`].
//!
//! Decoding is a *bit-level copy*: every field of every packed op (i8
//! weight codes, i64 folded biases, fixed-point multipliers, f32 grid
//! scales) is restored from its little-endian image — no float
//! arithmetic, no re-planning, no python manifest. A reloaded plan is
//! therefore bitwise-identical in behaviour to the in-memory plan it was
//! compiled from. All structural invariants the packers normally enforce
//! are re-validated here so a corrupt or adversarial file surfaces as a
//! typed [`ArtifactError`] instead of a panic deep inside a kernel.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::graph::{PoolKind, MAX_CONCAT_INPUTS, MAX_POOL_DIM};
use crate::nn::qengine::gemm::{self, KernelKind, PackedB};
use crate::nn::qengine::kernels::{Epilogue, QConv, QConvT};
use crate::nn::qengine::ops::{
    QAddInt, QConcatInt, QLinear, QPoolInt, Requantizer, MAX_REQUANT_MULT,
};
use crate::nn::qengine::plan::{PlannedOp, QModel, QOp};
use crate::nn::qengine::Mult;
use crate::nn::SiteCfg;
use crate::quant::QParams;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::mmap::{ArcSlice, Mmap};

use super::format::{
    malformed, AResult, ByteReader, ContainerReader, SectionBytes,
    SectionStat,
};
use super::{
    ArtifactError, ArtifactInfo, OP_ACTF, OP_ACT_REQUANT, OP_ADDF,
    OP_ADD_INT, OP_CONCATF, OP_CONCAT_INT, OP_CONV, OP_CONVT, OP_CONVTF,
    OP_CONV_F32, OP_GAP, OP_GAPF, OP_LINEAR, OP_LINEARF, OP_POOLF,
    OP_POOL_INT, OP_POOL_RECTF, OP_POOL_RECT_INT, OP_QUANT_IN,
    OP_UPSAMPLE, POOL_AVG, POOL_MAX, SEC_BIAS, SEC_FALLBACK, SEC_META,
    SEC_MULT, SEC_PLAN, SEC_QPARAMS, SEC_WGRID,
};

/// Upper bound on plan dimensions a well-formed artifact can claim
/// (defends slot-arena allocation against corrupt counts).
const MAX_PLAN_DIM: usize = 1 << 20;

/// A fully decoded compiled artifact: serving metadata + the plan.
pub struct Artifact {
    info: ArtifactInfo,
    qmodel: QModel,
}

impl Artifact {
    /// Open and fully decode, with typed errors for every corruption
    /// mode (bad magic, version skew, truncation, CRC mismatch,
    /// malformed content).
    pub fn open_typed(path: &Path) -> AResult<Artifact> {
        let c = ContainerReader::open(path)?;
        let art = Artifact::decode(&c)?;
        trace_open(path, "copy", &c);
        Ok(art)
    }

    /// [`Artifact::open_typed`] with the error erased into the crate's
    /// `anyhow::Result` (the typed value still formats the full story).
    pub fn open(path: impl AsRef<Path>) -> Result<Artifact> {
        Ok(Artifact::open_typed(path.as_ref())?)
    }

    /// Open via a shared read-only memory map: the raw `wgrid.i8` /
    /// `bias.i64` sections decode as zero-copy typed views into the
    /// page-cache-backed bytes, kept alive by an `Arc<Mmap>` inside
    /// each tensor — bitwise-identical behaviour to [`Artifact::open`],
    /// but boot copies nothing and N processes share one physical copy
    /// of the weights. Compressed sections (and big-endian hosts, and
    /// runs with `DFQ_NO_MMAP` set to a non-empty value other than `0`)
    /// fall back to owned storage with the same semantics.
    ///
    /// Caveat inherent to mmap'd IO: truncating the file *while a
    /// model serves from it* can fault; replace artifacts by rename
    /// (the registry's `poll_files` then hot-swaps onto a fresh map).
    pub fn open_mmap_typed(path: &Path) -> AResult<Artifact> {
        if mmap_disabled_by_env() {
            return Artifact::open_typed(path);
        }
        let map = Mmap::map(path).map_err(|e| ArtifactError::Io {
            path: path.display().to_string(),
            msg: e.to_string(),
        })?;
        let c = ContainerReader::parse_mmap(Arc::new(map))?;
        let art = Artifact::decode(&c)?;
        trace_open(path, "mmap", &c);
        Ok(art)
    }

    /// [`Artifact::open_mmap_typed`] with the error erased.
    pub fn open_mmap(path: impl AsRef<Path>) -> Result<Artifact> {
        Ok(Artifact::open_mmap_typed(path.as_ref())?)
    }

    /// Decode an in-memory container image (tests / benches).
    pub fn from_bytes(bytes: Vec<u8>) -> AResult<Artifact> {
        let c = ContainerReader::parse(bytes)?;
        Artifact::decode(&c)
    }

    pub fn info(&self) -> &ArtifactInfo {
        &self.info
    }

    pub fn qmodel(&self) -> &QModel {
        &self.qmodel
    }

    pub fn into_qmodel(self) -> QModel {
        self.qmodel
    }

    pub fn into_parts(self) -> (ArtifactInfo, QModel) {
        (self.info, self.qmodel)
    }

    fn decode(c: &ContainerReader) -> AResult<Artifact> {
        let mut info = decode_meta(c)?;
        info.bytes = c.total_bytes();
        let qmodel = decode_plan(c)?;
        // meta is advisory; the plan stream is authoritative — but the
        // two must agree or the file was stitched together
        if info.ops != qmodel.num_ops()
            || info.fallback_ops != qmodel.fallback_ops()
        {
            return Err(malformed(format!(
                "meta/plan disagree: meta says {} op(s) ({} fallback), \
                 plan decodes {} ({})",
                info.ops,
                info.fallback_ops,
                qmodel.num_ops(),
                qmodel.fallback_ops()
            )));
        }
        Ok(Artifact { info, qmodel })
    }
}

impl QModel {
    /// Rebuild a ready-to-run execution plan from a `.dfqm` compiled
    /// artifact — the zero-float-math boot path: no DFQ pipeline, no
    /// planner, no python manifest.
    pub fn from_artifact(path: impl AsRef<Path>) -> Result<QModel> {
        Ok(Artifact::open_typed(path.as_ref())?.into_qmodel())
    }

    /// [`QModel::from_artifact`] over a shared memory map: weight and
    /// bias tensors are zero-copy views into the page cache (see
    /// [`Artifact::open_mmap_typed`]); logits are bitwise-identical to
    /// the copy path.
    pub fn from_artifact_mmap(path: impl AsRef<Path>) -> Result<QModel> {
        Ok(Artifact::open_mmap_typed(path.as_ref())?.into_qmodel())
    }
}

/// Trace one successful artifact open: storage mode (mmap vs copy) and
/// how many sections were stored compressed (those decode at load and
/// cannot serve as zero-copy views). Free when tracing is disabled.
fn trace_open(path: &Path, mode: &'static str, c: &ContainerReader) {
    crate::obs::trace::emit_with(
        crate::obs::trace::Severity::Info,
        "artifact",
        || {
            let stats = c.section_stats();
            let compressed = stats
                .iter()
                .filter(|s| s.flags & super::format::FLAG_COMPRESSED != 0)
                .count();
            (
                "open".into(),
                vec![
                    ("path", path.display().to_string()),
                    ("mode", mode.to_string()),
                    ("sections", stats.len().to_string()),
                    ("compressed_sections", compressed.to_string()),
                ],
            )
        },
    );
}

/// `DFQ_NO_MMAP` (any non-empty value other than `0`) pins every
/// "mmap" load onto the owned-read fallback — CI uses it to exercise
/// that path on hosts where mapping works.
pub(crate) fn mmap_disabled_by_env() -> bool {
    matches!(std::env::var("DFQ_NO_MMAP"), Ok(v) if !v.is_empty() && v != "0")
}

/// Read only the `meta` section of an artifact (cheap listing /
/// registry scans — skips plan decode entirely).
pub fn inspect(path: impl AsRef<Path>) -> AResult<ArtifactInfo> {
    let c = ContainerReader::open(path.as_ref())?;
    let mut info = decode_meta(&c)?;
    info.bytes = c.total_bytes();
    Ok(info)
}

/// Per-section storage facts (stored vs raw size, crc, flags) for the
/// `dfq inspect` table. Header-only: no CRC checks, no decompression.
pub fn section_table(path: impl AsRef<Path>) -> AResult<Vec<SectionStat>> {
    let c = ContainerReader::open(path.as_ref())?;
    Ok(c.section_stats())
}

fn jerr(e: anyhow::Error) -> ArtifactError {
    malformed(format!("meta json: {e:#}"))
}

fn decode_meta(c: &ContainerReader) -> AResult<ArtifactInfo> {
    let bytes = c.section(SEC_META)?;
    let text = std::str::from_utf8(&bytes)
        .map_err(|_| malformed("meta section is not UTF-8"))?;
    let j = Json::parse(text).map_err(jerr)?;
    let format = j.req("format").and_then(Json::as_str).map_err(jerr)?;
    if format != "dfq-compiled-artifact" {
        return Err(malformed(format!("unknown meta format '{format}'")));
    }
    let shape =
        j.req("input_shape").and_then(Json::as_shape).map_err(jerr)?;
    if shape.len() != 3 {
        return Err(malformed("input_shape must be [C, H, W]"));
    }
    let plan = j.req("plan").map_err(jerr)?;
    let num = |key: &str| -> AResult<usize> {
        plan.req(key).and_then(Json::as_usize).map_err(jerr)
    };
    Ok(ArtifactInfo {
        name: j
            .req("name")
            .and_then(Json::as_str)
            .map_err(jerr)?
            .to_string(),
        input_shape: [shape[0], shape[1], shape[2]],
        num_classes: j
            .req("num_classes")
            .and_then(Json::as_usize)
            .map_err(jerr)?,
        ops: num("ops")?,
        slots: num("slots")?,
        int_layers: num("int_layers")?,
        f32_layers: num("f32_layers")?,
        fallback_ops: num("fallback_ops")?,
        bytes: 0,
    })
}

// -- field validators --------------------------------------------------------

/// Mirror of the engine's `assert_act_grid` as a typed error: the grids
/// an artifact feeds into kernels must satisfy the same invariants the
/// packers assert, or execution would panic.
fn check_act_qparams(qp: &QParams, what: &str) -> AResult<()> {
    if !(2.0..=256.0).contains(&qp.n_levels) {
        return Err(malformed(format!(
            "{what}: activation grid needs 2..=256 levels, got {}",
            qp.n_levels
        )));
    }
    if qp.zero_point.fract() != 0.0
        || qp.zero_point < 0.0
        || qp.zero_point > qp.n_levels - 1.0
    {
        return Err(malformed(format!(
            "{what}: zero point {} not an integer on the grid",
            qp.zero_point
        )));
    }
    if !(qp.scale > 0.0) || !qp.scale.is_finite() {
        return Err(malformed(format!(
            "{what}: scale {} not positive finite",
            qp.scale
        )));
    }
    Ok(())
}

fn check_site(row: &SiteCfg, what: &str) -> AResult<()> {
    let qp = QParams {
        scale: row.scale,
        zero_point: row.zero_point,
        n_levels: row.n_levels,
    };
    check_act_qparams(&qp, what)
}

fn check_mult(m: &Mult, what: &str) -> AResult<()> {
    match m {
        Mult::Fixed { shift, .. } => {
            if !(1..=62).contains(shift) {
                return Err(malformed(format!(
                    "{what}: fixed-point shift {shift} outside 1..=62"
                )));
            }
            Ok(())
        }
        Mult::Float(f) => {
            if f.is_nan() {
                return Err(malformed(format!("{what}: NaN multiplier")));
            }
            Ok(())
        }
    }
}

fn checked_len(a: usize, b: usize, what: &str) -> AResult<usize> {
    a.checked_mul(b)
        .filter(|&n| n <= (1 << 31))
        .ok_or_else(|| malformed(format!("{what}: implausible size {a}×{b}")))
}

// -- plan decode -------------------------------------------------------------

/// Sequential cursors over the typed section streams.
struct Cursors<'a> {
    plan: ByteReader<'a>,
    wgrid: ViewCursor<'a>,
    qparams: ByteReader<'a>,
    bias: ViewCursor<'a>,
    mult: ByteReader<'a>,
    fallback: Option<ByteReader<'a>>,
}

/// A section cursor that can mint zero-copy [`ArcSlice`] views when
/// the stream borrows straight from a live mapping. Falls back to
/// owned decoding for compressed sections, the owned-read path, and
/// big-endian hosts (where reinterpreting little-endian file bytes
/// in place would be wrong).
struct ViewCursor<'a> {
    r: ByteReader<'a>,
    /// `(mapping, absolute container offset of stream byte 0)`.
    src: Option<(Arc<Mmap>, usize)>,
}

impl<'a> ViewCursor<'a> {
    fn new(
        bytes: &'a SectionBytes<'a>,
        name: &'a str,
        map: Option<&Arc<Mmap>>,
    ) -> ViewCursor<'a> {
        let src = match (map, bytes.container_off()) {
            (Some(m), Some(off)) if cfg!(target_endian = "little") => {
                Some((Arc::clone(m), off))
            }
            _ => None,
        };
        ViewCursor { r: ByteReader::new(bytes, name), src }
    }

    fn i8_arc(&mut self, n: usize) -> AResult<ArcSlice<i8>> {
        match &self.src {
            Some((m, base)) => {
                let off = base + self.r.pos();
                self.r.skip(n)?;
                ArcSlice::view(m, off, n).ok_or_else(|| {
                    malformed("i8 view escapes the mapping".to_string())
                })
            }
            None => Ok(self.r.i8_vec(n)?.into()),
        }
    }

    fn i64_arc(&mut self, n: usize) -> AResult<ArcSlice<i64>> {
        match &self.src {
            Some((m, base)) => {
                let off = base + self.r.pos();
                let bytes = n.checked_mul(8).ok_or_else(|| {
                    malformed("i64 count overflow".to_string())
                })?;
                self.r.skip(bytes)?;
                ArcSlice::view(m, off, n).ok_or_else(|| {
                    malformed("i64 view escapes the mapping".to_string())
                })
            }
            None => Ok(self.r.i64_vec(n)?.into()),
        }
    }

    fn expect_end(&self) -> AResult<()> {
        self.r.expect_end()
    }
}

fn get_qparams(r: &mut ByteReader) -> AResult<QParams> {
    Ok(QParams {
        scale: r.f32()?,
        zero_point: r.f32()?,
        n_levels: r.f32()?,
    })
}

fn get_site(r: &mut ByteReader) -> AResult<SiteCfg> {
    Ok(SiteCfg {
        scale: r.f32()?,
        zero_point: r.f32()?,
        n_levels: r.f32()?,
        clip_hi: r.f32()?,
    })
}

fn get_mult(r: &mut ByteReader, what: &str) -> AResult<Mult> {
    let m = match r.u8()? {
        0 => Mult::Fixed { m: r.i32()?, shift: r.u32()? },
        1 => Mult::Float(r.f64()?),
        t => return Err(malformed(format!("{what}: bad mult tag {t}"))),
    };
    check_mult(&m, what)?;
    Ok(m)
}

/// The packer invariant on Q20 requantise multipliers
/// ([`crate::nn::qengine::ops`]'s `MAX_REQUANT_MULT`): positive and far
/// from the i64 overflow edge of `m · (q − z)`.
fn check_requant_mult(m: i64, what: &str) -> AResult<()> {
    if m <= 0 {
        return Err(malformed(format!(
            "{what}: non-positive multiplier {m}"
        )));
    }
    if m > MAX_REQUANT_MULT {
        return Err(malformed(format!(
            "{what}: implausible multiplier {m}"
        )));
    }
    Ok(())
}

fn get_pool_kind(r: &mut ByteReader, what: &str) -> AResult<PoolKind> {
    match r.u8()? {
        POOL_MAX => Ok(PoolKind::Max),
        POOL_AVG => Ok(PoolKind::Avg),
        t => Err(malformed(format!("{what}: bad pool kind tag {t}"))),
    }
}

/// Decode and validate a pool window: the same invariants
/// `QPoolInt::pack` asserts (no zero dims, no all-padding windows, and
/// the packer's plausibility cap — an unbounded `k` from a corrupt file
/// would underflow `h + 2·pad − k` at run time, which is a panic, not a
/// typed error).
fn get_pool_window(
    r: &mut ByteReader,
    what: &str,
) -> AResult<(usize, usize, usize)> {
    let k = r.u32()? as usize;
    let stride = r.u32()? as usize;
    let pad = r.u32()? as usize;
    if k == 0 || stride == 0 {
        return Err(malformed(format!("{what}: zero window/stride")));
    }
    if k > MAX_POOL_DIM || stride > MAX_POOL_DIM {
        return Err(malformed(format!(
            "{what}: implausible pool window (k {k}, stride {stride})"
        )));
    }
    if pad >= k {
        return Err(malformed(format!(
            "{what}: pad {pad} >= window {k} (empty windows)"
        )));
    }
    Ok((k, stride, pad))
}

/// Decode and validate a per-axis (v4 rectangular/global) pool window:
/// the `QPoolInt::pack` invariants applied to each axis independently,
/// plus the canonical-form rule for global pools (a corrupt global flag
/// on a real window, or a fabricated window on a global pool, is a
/// malformed file — the executor would silently pool the wrong extent).
#[allow(clippy::type_complexity)]
fn get_pool_rect(
    r: &mut ByteReader,
    what: &str,
) -> AResult<((usize, usize), (usize, usize), (usize, usize), bool)> {
    let global = match r.u8()? {
        0 => false,
        1 => true,
        t => {
            return Err(malformed(format!("{what}: bad global flag {t}")))
        }
    };
    let mut k = (0usize, 0usize);
    let mut stride = (0usize, 0usize);
    let mut pad = (0usize, 0usize);
    for d in [&mut k, &mut stride, &mut pad] {
        d.0 = r.u32()? as usize;
        d.1 = r.u32()? as usize;
    }
    for (axis, (kd, sd, pd)) in
        [(k.0, stride.0, pad.0), (k.1, stride.1, pad.1)].into_iter().enumerate()
    {
        if kd == 0 || sd == 0 {
            return Err(malformed(format!(
                "{what}: zero window/stride on axis {axis}"
            )));
        }
        if kd > MAX_POOL_DIM || sd > MAX_POOL_DIM {
            return Err(malformed(format!(
                "{what}: implausible pool window on axis {axis} \
                 (k {kd}, stride {sd})"
            )));
        }
        if pd >= kd {
            return Err(malformed(format!(
                "{what}: pad {pd} >= window {kd} on axis {axis} \
                 (empty windows)"
            )));
        }
    }
    if global && (k != (1, 1) || stride != (1, 1) || pad != (0, 0)) {
        return Err(malformed(format!(
            "{what}: global pool not in canonical form \
             (k {k:?}, stride {stride:?}, pad {pad:?})"
        )));
    }
    Ok((k, stride, pad, global))
}

fn fallback_cursor<'a, 'c>(
    cur: &'c mut Cursors<'a>,
) -> AResult<&'c mut ByteReader<'a>> {
    cur.fallback.as_mut().ok_or_else(|| ArtifactError::MissingSection {
        name: SEC_FALLBACK.to_string(),
    })
}

fn get_conv(cur: &mut Cursors, node: usize) -> AResult<QConv> {
    let what = format!("conv op (node {node})");
    let c_out = cur.plan.u32()? as usize;
    let cig = cur.plan.u32()? as usize;
    let kh = cur.plan.u32()? as usize;
    let kw = cur.plan.u32()? as usize;
    let stride = cur.plan.u32()? as usize;
    let pad = cur.plan.u32()? as usize;
    let groups = cur.plan.u32()? as usize;
    if c_out == 0 || cig == 0 || kh == 0 || kw == 0 || stride == 0 {
        return Err(malformed(format!("{what}: zero dimension")));
    }
    if groups != 1 && (cig != 1 || groups != c_out) {
        return Err(malformed(format!(
            "{what}: unsupported grouping (groups {groups}, cig {cig}, \
             c_out {c_out})"
        )));
    }
    let in_qp = get_qparams(&mut cur.plan)?;
    check_act_qparams(&in_qp, &what)?;
    let has_epi = match cur.plan.u8()? {
        0 => false,
        1 => true,
        t => {
            return Err(malformed(format!("{what}: bad epilogue tag {t}")))
        }
    };
    let per = checked_len(cig, kh * kw, &what)?;
    let w_len = checked_len(c_out, per, &what)?;
    let w = cur.wgrid.i8_arc(w_len)?;
    let mut s_w = Vec::with_capacity(c_out);
    let mut zp_w = Vec::with_capacity(c_out);
    let mut bias_f = Vec::with_capacity(c_out);
    for _ in 0..c_out {
        s_w.push(cur.qparams.f32()?);
        zp_w.push(cur.qparams.i32()?);
        bias_f.push(cur.qparams.f32()?);
    }
    let zp_corr = cur.bias.i64_arc(c_out)?;
    let epi = if has_epi {
        let out_qp = get_qparams(&mut cur.plan)?;
        check_act_qparams(&out_qp, &what)?;
        let zp_out = cur.plan.i32()?;
        let q_lo = cur.plan.i32()?;
        let q_hi = cur.plan.i32()?;
        let bias_q = cur.bias.i64_arc(c_out)?;
        let mut mult = Vec::with_capacity(c_out);
        for _ in 0..c_out {
            mult.push(get_mult(&mut cur.mult, &what)?);
        }
        Some(Epilogue { bias_q, mult, zp_out, q_lo, q_hi, out_qp })
    } else {
        None
    };
    // Kernel kind and packed panels are derived state, never serialized:
    // re-detect and re-pack for the host we are deserialising on.
    let mut conv = QConv {
        c_out,
        cig,
        kh,
        kw,
        stride,
        pad,
        groups,
        w,
        zp_w,
        s_w,
        zp_corr,
        bias_f,
        in_qp,
        epi,
        kernel: KernelKind::Scalar,
        packed: PackedB::empty(),
    };
    conv.set_kernel(gemm::active_kind());
    Ok(conv)
}

/// Decode a transposed conv: the logical stride/pad, then the inner
/// stride-1 flipped-kernel conv. The gather-form lowering is only
/// correct when the stored geometry satisfies its derivation
/// (`inner.stride == 1`, `inner.pad == k-1-pad`, square dense kernel),
/// so those relations are re-proved here rather than trusted.
fn get_convt(cur: &mut Cursors, node: usize) -> AResult<QConvT> {
    let what = format!("convT op (node {node})");
    let stride = cur.plan.u32()? as usize;
    let pad = cur.plan.u32()? as usize;
    if stride == 0 {
        return Err(malformed(format!("{what}: zero stride")));
    }
    let inner = get_conv(cur, node)?;
    if inner.kh != inner.kw {
        return Err(malformed(format!(
            "{what}: non-square kernel {}x{}",
            inner.kh, inner.kw
        )));
    }
    if inner.groups != 1 {
        return Err(malformed(format!(
            "{what}: grouped transposed conv (groups {})",
            inner.groups
        )));
    }
    if inner.stride != 1 {
        return Err(malformed(format!(
            "{what}: inner conv stride {} != 1",
            inner.stride
        )));
    }
    if pad >= inner.kh || inner.pad != inner.kh - 1 - pad {
        return Err(malformed(format!(
            "{what}: inner pad {} inconsistent with k {} and logical \
             pad {pad}",
            inner.pad, inner.kh
        )));
    }
    Ok(QConvT { stride, pad, inner })
}

fn get_linear(cur: &mut Cursors, node: usize) -> AResult<QLinear> {
    let what = format!("linear op (node {node})");
    let in_dim = cur.plan.u32()? as usize;
    let out_dim = cur.plan.u32()? as usize;
    if in_dim == 0 || out_dim == 0 {
        return Err(malformed(format!("{what}: zero dimension")));
    }
    let in_qp = get_qparams(&mut cur.plan)?;
    check_act_qparams(&in_qp, &what)?;
    let wt = cur.wgrid.i8_arc(checked_len(in_dim, out_dim, &what)?)?;
    let mut s_w = Vec::with_capacity(out_dim);
    let mut zp_w = Vec::with_capacity(out_dim);
    let mut bias = Vec::with_capacity(out_dim);
    for _ in 0..out_dim {
        s_w.push(cur.qparams.f32()?);
        zp_w.push(cur.qparams.i32()?);
        bias.push(cur.qparams.f32()?);
    }
    let zp_corr = cur.bias.i64_arc(out_dim)?;
    let mut lin = QLinear {
        in_dim,
        out_dim,
        wt,
        zp_w,
        s_w,
        zp_corr,
        bias,
        in_qp,
        kernel: KernelKind::Scalar,
        packed: PackedB::empty(),
    };
    lin.set_kernel(gemm::active_kind());
    Ok(lin)
}

fn get_op(cur: &mut Cursors, node: usize) -> AResult<QOp> {
    Ok(match cur.plan.u8()? {
        OP_QUANT_IN => {
            let qp = get_qparams(&mut cur.plan)?;
            check_act_qparams(&qp, "input quantiser")?;
            QOp::QuantIn { qp }
        }
        OP_CONV => QOp::Conv(Box::new(get_conv(cur, node)?)),
        OP_CONVT => QOp::ConvT(Box::new(get_convt(cur, node)?)),
        OP_CONVTF => {
            let what = format!("convT-f32 op (node {node})");
            let stride = cur.plan.u32()? as usize;
            let pad = cur.plan.u32()? as usize;
            if stride == 0 {
                return Err(malformed(format!("{what}: zero stride")));
            }
            let ndim = cur.plan.u32()? as usize;
            if ndim != 4 {
                return Err(malformed(format!(
                    "{what}: weights need 4 dims, got {ndim}"
                )));
            }
            let mut shape = Vec::with_capacity(ndim);
            let mut count = 1usize;
            for _ in 0..ndim {
                let d = cur.plan.usize()?;
                if d == 0 {
                    return Err(malformed(format!(
                        "{what}: zero weight dimension"
                    )));
                }
                count = checked_len(count, d, &what)?;
                shape.push(d);
            }
            if shape[2] != shape[3] || pad >= shape[2] {
                return Err(malformed(format!(
                    "{what}: bad geometry (k {}x{}, pad {pad})",
                    shape[2], shape[3]
                )));
            }
            let b_len = cur.plan.u32()? as usize;
            let fb = fallback_cursor(cur)?;
            let data = fb.f32_vec(count)?;
            let b = fb.f32_vec(b_len)?;
            QOp::ConvTFp32 { w: Tensor::new(&shape, data), b, stride, pad }
        }
        OP_CONV_F32 => {
            let what = format!("conv-f32 op (node {node})");
            let stride = cur.plan.u32()? as usize;
            let pad = cur.plan.u32()? as usize;
            let groups = cur.plan.u32()? as usize;
            let ndim = cur.plan.u32()? as usize;
            if ndim != 4 {
                return Err(malformed(format!(
                    "{what}: weights need 4 dims, got {ndim}"
                )));
            }
            let mut shape = Vec::with_capacity(ndim);
            let mut count = 1usize;
            for _ in 0..ndim {
                let d = cur.plan.usize()?;
                if d == 0 {
                    return Err(malformed(format!(
                        "{what}: zero weight dimension"
                    )));
                }
                count = checked_len(count, d, &what)?;
                shape.push(d);
            }
            let b_len = cur.plan.u32()? as usize;
            let fb = fallback_cursor(cur)?;
            let data = fb.f32_vec(count)?;
            let b = fb.f32_vec(b_len)?;
            QOp::ConvFp32 {
                w: Tensor::new(&shape, data),
                b,
                stride,
                pad,
                groups,
            }
        }
        OP_ADD_INT => {
            let what = format!("add op (node {node})");
            let ma = cur.plan.i64()?;
            let mb = cur.plan.i64()?;
            check_requant_mult(ma, &what)?;
            check_requant_mult(mb, &what)?;
            let a_qp = get_qparams(&mut cur.plan)?;
            let b_qp = get_qparams(&mut cur.plan)?;
            let out_qp = get_qparams(&mut cur.plan)?;
            check_act_qparams(&a_qp, &what)?;
            check_act_qparams(&b_qp, &what)?;
            check_act_qparams(&out_qp, &what)?;
            QOp::Add(QAddInt { ma, mb, a_qp, b_qp, out_qp })
        }
        OP_ADDF => {
            let row = get_site(&mut cur.plan)?;
            check_site(&row, &format!("add-f32 op (node {node})"))?;
            QOp::AddF { row }
        }
        OP_CONCAT_INT => {
            let what = format!("concat op (node {node})");
            let n_in = cur.plan.u32()? as usize;
            if !(2..=MAX_CONCAT_INPUTS).contains(&n_in) {
                return Err(malformed(format!(
                    "{what}: implausible input count {n_in}"
                )));
            }
            let mut ms = Vec::with_capacity(n_in);
            let mut in_qps = Vec::with_capacity(n_in);
            for i in 0..n_in {
                let m = cur.plan.i64()?;
                check_requant_mult(m, &format!("{what}, input {i}"))?;
                let qp = get_qparams(&mut cur.plan)?;
                check_act_qparams(&qp, &what)?;
                ms.push(m);
                in_qps.push(qp);
            }
            let out_qp = get_qparams(&mut cur.plan)?;
            check_act_qparams(&out_qp, &what)?;
            QOp::Concat(QConcatInt { ms, in_qps, out_qp })
        }
        OP_CONCATF => {
            let row = get_site(&mut cur.plan)?;
            check_site(&row, &format!("concat-f32 op (node {node})"))?;
            QOp::ConcatF { row }
        }
        OP_POOL_INT => {
            let what = format!("pool op (node {node})");
            let kind = get_pool_kind(&mut cur.plan, &what)?;
            let (k, stride, pad) = get_pool_window(&mut cur.plan, &what)?;
            let qp = get_qparams(&mut cur.plan)?;
            check_act_qparams(&qp, &what)?;
            QOp::Pool(QPoolInt {
                kind,
                k: (k, k),
                stride: (stride, stride),
                pad: (pad, pad),
                global: false,
                qp,
            })
        }
        OP_POOLF => {
            let what = format!("pool-f32 op (node {node})");
            let kind = get_pool_kind(&mut cur.plan, &what)?;
            let (k, stride, pad) = get_pool_window(&mut cur.plan, &what)?;
            QOp::PoolF {
                kind,
                k: (k, k),
                stride: (stride, stride),
                pad: (pad, pad),
                global: false,
            }
        }
        OP_POOL_RECT_INT => {
            let what = format!("rect-pool op (node {node})");
            let kind = get_pool_kind(&mut cur.plan, &what)?;
            let (k, stride, pad, global) =
                get_pool_rect(&mut cur.plan, &what)?;
            let qp = get_qparams(&mut cur.plan)?;
            check_act_qparams(&qp, &what)?;
            QOp::Pool(QPoolInt { kind, k, stride, pad, global, qp })
        }
        OP_POOL_RECTF => {
            let what = format!("rect-pool-f32 op (node {node})");
            let kind = get_pool_kind(&mut cur.plan, &what)?;
            let (k, stride, pad, global) =
                get_pool_rect(&mut cur.plan, &what)?;
            QOp::PoolF { kind, k, stride, pad, global }
        }
        OP_ACT_REQUANT => {
            let what = format!("act op (node {node})");
            let q_lo = cur.plan.i32()?;
            let q_hi = cur.plan.i32()?;
            let in_qp = get_qparams(&mut cur.plan)?;
            let out_qp = get_qparams(&mut cur.plan)?;
            check_act_qparams(&in_qp, &what)?;
            check_act_qparams(&out_qp, &what)?;
            let m = get_mult(&mut cur.mult, &what)?;
            QOp::Act(Requantizer { m, q_lo, q_hi, in_qp, out_qp })
        }
        OP_ACTF => {
            let row = get_site(&mut cur.plan)?;
            check_site(&row, &format!("act-f32 op (node {node})"))?;
            QOp::ActF { row }
        }
        OP_GAP => {
            let qp = get_qparams(&mut cur.plan)?;
            check_act_qparams(&qp, &format!("gap op (node {node})"))?;
            QOp::Gap { qp }
        }
        OP_GAPF => QOp::GapF,
        OP_LINEAR => QOp::Linear(get_linear(cur, node)?),
        OP_LINEARF => {
            let what = format!("linear-f32 op (node {node})");
            let out_dim = cur.plan.u32()? as usize;
            let in_dim = cur.plan.u32()? as usize;
            let b_len = cur.plan.u32()? as usize;
            let count = checked_len(out_dim, in_dim, &what)?;
            let fb = fallback_cursor(cur)?;
            let data = fb.f32_vec(count)?;
            let b = fb.f32_vec(b_len)?;
            QOp::LinearF { w: Tensor::new(&[out_dim, in_dim], data), b }
        }
        OP_UPSAMPLE => {
            let factor = cur.plan.u32()? as usize;
            if factor == 0 {
                return Err(malformed(format!(
                    "upsample op (node {node}): zero factor"
                )));
            }
            let grid = match cur.plan.u8()? {
                0 => None,
                1 => {
                    let qp = get_qparams(&mut cur.plan)?;
                    check_act_qparams(
                        &qp,
                        &format!("upsample op (node {node})"),
                    )?;
                    Some(qp)
                }
                t => {
                    return Err(malformed(format!(
                        "upsample op (node {node}): bad grid tag {t}"
                    )))
                }
            };
            QOp::Upsample { factor, grid }
        }
        t => return Err(malformed(format!("unknown op tag {t}"))),
    })
}

fn decode_plan(c: &ContainerReader) -> AResult<QModel> {
    let plan_bytes = c.section(SEC_PLAN)?;
    let wgrid_bytes = c.section(SEC_WGRID)?;
    let qparams_bytes = c.section(SEC_QPARAMS)?;
    let bias_bytes = c.section(SEC_BIAS)?;
    let mult_bytes = c.section(SEC_MULT)?;
    let fallback_bytes = match c.section_size(SEC_FALLBACK) {
        Some(_) => Some(c.section(SEC_FALLBACK)?),
        None => None,
    };
    // when the container is mmap-backed, the wgrid/bias cursors mint
    // zero-copy views (raw sections only — a decompressed payload has
    // no stable mapped region, so it stays owned)
    let map = c.backing_mmap();
    let mut cur = Cursors {
        plan: ByteReader::new(&plan_bytes, SEC_PLAN),
        wgrid: ViewCursor::new(&wgrid_bytes, SEC_WGRID, map),
        qparams: ByteReader::new(&qparams_bytes, SEC_QPARAMS),
        bias: ViewCursor::new(&bias_bytes, SEC_BIAS, map),
        mult: ByteReader::new(&mult_bytes, SEC_MULT),
        fallback: fallback_bytes
            .as_ref()
            .map(|b| ByteReader::new(b, SEC_FALLBACK)),
    };

    let slots = cur.plan.u32()? as usize;
    if slots == 0 || slots > MAX_PLAN_DIM {
        return Err(malformed(format!("implausible slot count {slots}")));
    }
    let n_outputs = cur.plan.u32()? as usize;
    if n_outputs == 0 || n_outputs > slots {
        return Err(malformed(format!(
            "implausible output count {n_outputs} (slots {slots})"
        )));
    }
    let mut outputs = Vec::with_capacity(n_outputs);
    for _ in 0..n_outputs {
        let slot = cur.plan.u32()? as usize;
        let node = cur.plan.u32()? as usize;
        if slot >= slots {
            return Err(malformed(format!(
                "output slot {slot} out of range (slots {slots})"
            )));
        }
        outputs.push((slot, node));
    }
    let int_layers = cur.plan.u32()? as usize;
    let f32_layers = cur.plan.u32()? as usize;
    let fallbacks = cur.plan.u32()? as usize;
    let n_ops = cur.plan.u32()? as usize;
    if n_ops == 0 || n_ops > MAX_PLAN_DIM {
        return Err(malformed(format!("implausible op count {n_ops}")));
    }

    let mut ops = Vec::with_capacity(n_ops);
    for _ in 0..n_ops {
        let node = cur.plan.u32()? as usize;
        let out = cur.plan.u32()? as usize;
        let n_ins = cur.plan.u32()? as usize;
        // concat fans in one slot per branch — the widest legal arity
        // (exact per-tag bounds are enforced after the op decodes)
        if n_ins > MAX_CONCAT_INPUTS {
            return Err(malformed(format!(
                "op at node {node}: implausible input count {n_ins}"
            )));
        }
        let mut ins = Vec::with_capacity(n_ins);
        for _ in 0..n_ins {
            ins.push(cur.plan.u32()? as usize);
        }
        let n_free = cur.plan.u32()? as usize;
        if n_free > slots {
            return Err(malformed(format!(
                "op at node {node}: implausible free list ({n_free})"
            )));
        }
        let mut free_after = Vec::with_capacity(n_free);
        for _ in 0..n_free {
            free_after.push(cur.plan.u32()? as usize);
        }
        for &s in ins.iter().chain(free_after.iter()).chain([out].iter()) {
            if s >= slots {
                return Err(malformed(format!(
                    "op at node {node}: slot {s} out of range \
                     (slots {slots})"
                )));
            }
        }
        let op = get_op(&mut cur, node)?;
        // per-tag arity guard: the executor indexes `ins` positionally,
        // so a too-short list must be rejected here, not panic at run
        // time — and extra slots mean a malformed plan. Only concat
        // legitimately fans in more than two inputs (exactly one slot
        // per multiplier on the integer form).
        let (min_ins, max_ins) = match &op {
            QOp::QuantIn { .. } => (0, 0),
            QOp::Add(_) | QOp::AddF { .. } => (2, 2),
            QOp::Concat(c) => (c.ms.len(), c.ms.len()),
            QOp::ConcatF { .. } => (2, MAX_CONCAT_INPUTS),
            _ => (1, 1),
        };
        if ins.len() < min_ins || ins.len() > max_ins {
            return Err(malformed(format!(
                "op at node {node}: {} input slot(s), expected \
                 {min_ins}..={max_ins}",
                ins.len()
            )));
        }
        ops.push(PlannedOp { node, ins, out, op, free_after });
    }

    // every stream must be fully consumed — leftover bytes mean the
    // writer and reader disagree about the format
    cur.plan.expect_end()?;
    cur.wgrid.expect_end()?;
    cur.qparams.expect_end()?;
    cur.bias.expect_end()?;
    cur.mult.expect_end()?;
    if let Some(fb) = &cur.fallback {
        fb.expect_end()?;
    }

    // the stored summary counters must match what the ops themselves say
    let counted_fallbacks =
        ops.iter().filter(|p| !p.op.describe().1).count();
    let counted_int = ops
        .iter()
        .filter(|p| {
            matches!(p.op, QOp::Conv(_) | QOp::ConvT(_) | QOp::Linear(_))
        })
        .count();
    let counted_f32 = ops
        .iter()
        .filter(|p| {
            matches!(
                p.op,
                QOp::ConvFp32 { .. }
                    | QOp::ConvTFp32 { .. }
                    | QOp::LinearF { .. }
            )
        })
        .count();
    if counted_fallbacks != fallbacks
        || counted_int != int_layers
        || counted_f32 != f32_layers
    {
        return Err(malformed(format!(
            "summary counters disagree with ops: stored \
             ({int_layers} int, {f32_layers} f32, {fallbacks} fallback), \
             counted ({counted_int}, {counted_f32}, {counted_fallbacks})"
        )));
    }

    Ok(QModel {
        ops,
        slots,
        outputs,
        int_layers,
        f32_layers,
        fallbacks,
        profile: None,
    })
}
