//! Self-contained section codec for `.dfqm` cold storage: greedy
//! byte-LZ over an adaptive binary range coder, framed in independent
//! blocks with a claudcompress-style `{raw_len, stored_len}` header per
//! block.
//!
//! The i8 weight grids quantise Gaussian weights, so their byte
//! entropy sits near 7 bits — plain bit-packing cannot shrink them,
//! but an adaptive order-0 literal model does, and the LZ layer folds
//! away the long zero runs and repeated wiring words of the `plan`
//! stream. Every block is stored RAW when coding does not pay, so
//! `compress` never expands a block by more than the 9-byte header.
//!
//! The decoder is corruption-hardened: every failure mode is a typed
//! [`CodecError`] (mapped to `ArtifactError` at the container layer),
//! never a panic — truncated payloads, match distances that reach
//! before the block start, overruns past the declared length, unknown
//! block kinds and total-length mismatches are all explicit errors.

use std::fmt;

/// Independent-block size. Blocks never reference bytes across the
/// boundary, so a corrupt block cannot poison its neighbours.
pub const BLOCK: usize = 1 << 17;

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = MIN_MATCH + 255;
const HASH_BITS: u32 = 16;

const KIND_RAW: u8 = 0;
const KIND_CODED: u8 = 1;

// 11-bit probabilities with shift-5 adaptation — the classic carry-less
// range-coder operating point.
const PROB_BITS: u32 = 11;
const PROB_ONE: u16 = 1 << PROB_BITS;
const PROB_INIT: u16 = PROB_ONE / 2;
const MOVE_BITS: u32 = 5;
const TOP: u32 = 1 << 24;

/// Typed decode failures; the artifact layer wraps them per section.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// The stored payload ended before the stream was complete.
    Truncated { what: String },
    /// The payload is structurally invalid (bad kind byte, impossible
    /// match, length mismatch...).
    Corrupt { what: String },
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Truncated { what } => {
                write!(f, "compressed payload truncated: {what}")
            }
            CodecError::Corrupt { what } => {
                write!(f, "compressed payload corrupt: {what}")
            }
        }
    }
}

fn truncated(what: &str) -> CodecError {
    CodecError::Truncated { what: what.to_string() }
}

fn corrupt(what: String) -> CodecError {
    CodecError::Corrupt { what }
}

// -- range coder -------------------------------------------------------------

struct REnc {
    low: u64,
    range: u32,
    cache: u8,
    cache_size: u64,
    out: Vec<u8>,
}

impl REnc {
    fn new() -> REnc {
        REnc { low: 0, range: u32::MAX, cache: 0, cache_size: 1, out: Vec::new() }
    }

    fn shift_low(&mut self) {
        if (self.low as u32) < 0xFF00_0000 || (self.low >> 32) != 0 {
            let carry = (self.low >> 32) as u8;
            let mut b = self.cache;
            loop {
                self.out.push(b.wrapping_add(carry));
                b = 0xFF;
                self.cache_size -= 1;
                if self.cache_size == 0 {
                    break;
                }
            }
            self.cache = (self.low >> 24) as u8;
        }
        self.cache_size += 1;
        // Keep only the low 32 bits: the byte shifted out is either in
        // `cache` (flushed above) or counted in `cache_size` as a pending
        // 0xFF, and `low >> 32` must stay a pure 0/1 carry flag.
        self.low = (self.low << 8) & 0xFFFF_FFFF;
    }

    fn encode_bit(&mut self, p: &mut u16, bit: u32) {
        let bound = (self.range >> PROB_BITS) * (*p as u32);
        if bit == 0 {
            self.range = bound;
            *p += (PROB_ONE - *p) >> MOVE_BITS;
        } else {
            self.low += bound as u64;
            self.range -= bound;
            *p -= *p >> MOVE_BITS;
        }
        while self.range < TOP {
            self.shift_low();
            self.range <<= 8;
        }
    }

    fn encode_direct(&mut self, value: u32, nbits: u32) {
        for i in (0..nbits).rev() {
            self.range >>= 1;
            if (value >> i) & 1 != 0 {
                self.low += self.range as u64;
            }
            while self.range < TOP {
                self.shift_low();
                self.range <<= 8;
            }
        }
    }

    fn finish(mut self) -> Vec<u8> {
        for _ in 0..5 {
            self.shift_low();
        }
        self.out
    }
}

struct RDec<'a> {
    code: u32,
    range: u32,
    input: &'a [u8],
    pos: usize,
}

impl<'a> RDec<'a> {
    fn new(input: &'a [u8]) -> Result<RDec<'a>, CodecError> {
        let mut d = RDec { code: 0, range: u32::MAX, input, pos: 0 };
        for _ in 0..5 {
            d.code = (d.code << 8) | d.next()? as u32;
        }
        Ok(d)
    }

    fn next(&mut self) -> Result<u8, CodecError> {
        let b = *self
            .input
            .get(self.pos)
            .ok_or_else(|| truncated("range-coder input underrun"))?;
        self.pos += 1;
        Ok(b)
    }

    fn decode_bit(&mut self, p: &mut u16) -> Result<u32, CodecError> {
        let bound = (self.range >> PROB_BITS) * (*p as u32);
        let bit = if self.code < bound {
            self.range = bound;
            *p += (PROB_ONE - *p) >> MOVE_BITS;
            0
        } else {
            self.code -= bound;
            self.range -= bound;
            *p -= *p >> MOVE_BITS;
            1
        };
        while self.range < TOP {
            self.code = (self.code << 8) | self.next()? as u32;
            self.range <<= 8;
        }
        Ok(bit)
    }

    fn decode_direct(&mut self, nbits: u32) -> Result<u32, CodecError> {
        let mut v = 0u32;
        for _ in 0..nbits {
            self.range >>= 1;
            let bit = if self.code >= self.range {
                self.code -= self.range;
                1
            } else {
                0
            };
            v = (v << 1) | bit;
            while self.range < TOP {
                self.code = (self.code << 8) | self.next()? as u32;
                self.range <<= 8;
            }
        }
        Ok(v)
    }
}

fn tree_encode(e: &mut REnc, probs: &mut [u16], nbits: u32, sym: u32) {
    let mut m = 1u32;
    for i in (0..nbits).rev() {
        let bit = (sym >> i) & 1;
        e.encode_bit(&mut probs[m as usize], bit);
        m = (m << 1) | bit;
    }
}

fn tree_decode(
    d: &mut RDec,
    probs: &mut [u16],
    nbits: u32,
) -> Result<u32, CodecError> {
    let mut m = 1u32;
    for _ in 0..nbits {
        m = (m << 1) | d.decode_bit(&mut probs[m as usize])?;
    }
    Ok(m - (1 << nbits))
}

/// Per-block adaptive context: one match flag, a byte tree for
/// literals, a byte tree for match lengths and a 5-bit tree for the
/// distance bit-length (low bits go as direct bits).
struct Model {
    is_match: u16,
    lit: Vec<u16>,
    len: Vec<u16>,
    dist_bits: Vec<u16>,
}

impl Model {
    fn new() -> Model {
        Model {
            is_match: PROB_INIT,
            lit: vec![PROB_INIT; 256],
            len: vec![PROB_INIT; 256],
            dist_bits: vec![PROB_INIT; 32],
        }
    }
}

// -- block LZ ----------------------------------------------------------------

#[inline]
fn hash4(b: &[u8]) -> usize {
    let v = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    (v.wrapping_mul(2_654_435_761) >> (32 - HASH_BITS)) as usize
}

fn encode_block(raw: &[u8], head: &mut [u32]) -> Vec<u8> {
    head.fill(u32::MAX);
    let mut e = REnc::new();
    let mut m = Model::new();
    let n = raw.len();
    let mut i = 0usize;
    while i < n {
        let mut match_len = 0usize;
        let mut match_dist = 0usize;
        if i + MIN_MATCH <= n {
            let h = hash4(&raw[i..]);
            let cand = head[h];
            head[h] = i as u32;
            if cand != u32::MAX {
                let c = cand as usize;
                let max_len = (n - i).min(MAX_MATCH);
                let mut l = 0usize;
                while l < max_len && raw[c + l] == raw[i + l] {
                    l += 1;
                }
                if l >= MIN_MATCH {
                    match_len = l;
                    match_dist = i - c;
                }
            }
        }
        if match_len > 0 {
            e.encode_bit(&mut m.is_match, 1);
            tree_encode(&mut e, &mut m.len, 8, (match_len - MIN_MATCH) as u32);
            let d = match_dist as u32;
            let bl = 32 - d.leading_zeros();
            tree_encode(&mut e, &mut m.dist_bits, 5, bl - 1);
            if bl > 1 {
                e.encode_direct(d & ((1u32 << (bl - 1)) - 1), bl - 1);
            }
            let end = i + match_len;
            i += 1;
            while i < end {
                if i + MIN_MATCH <= n {
                    head[hash4(&raw[i..])] = i as u32;
                }
                i += 1;
            }
        } else {
            e.encode_bit(&mut m.is_match, 0);
            tree_encode(&mut e, &mut m.lit, 8, raw[i] as u32);
            i += 1;
        }
    }
    e.finish()
}

fn decode_block(
    stored: &[u8],
    raw_len: usize,
    out: &mut Vec<u8>,
) -> Result<(), CodecError> {
    let start = out.len();
    let mut d = RDec::new(stored)?;
    let mut m = Model::new();
    while out.len() - start < raw_len {
        if d.decode_bit(&mut m.is_match)? == 1 {
            let len =
                tree_decode(&mut d, &mut m.len, 8)? as usize + MIN_MATCH;
            let bl = tree_decode(&mut d, &mut m.dist_bits, 5)? + 1;
            let dist = if bl == 1 {
                1usize
            } else {
                ((1u32 << (bl - 1)) | d.decode_direct(bl - 1)?) as usize
            };
            let have = out.len() - start;
            if dist > have {
                return Err(corrupt(format!(
                    "match distance {dist} reaches before the block start \
                     (only {have} bytes decoded)"
                )));
            }
            if have + len > raw_len {
                return Err(corrupt(format!(
                    "match of {len} overruns the declared block length \
                     {raw_len}"
                )));
            }
            for _ in 0..len {
                let b = out[out.len() - dist];
                out.push(b);
            }
        } else {
            out.push(tree_decode(&mut d, &mut m.lit, 8)? as u8);
        }
    }
    Ok(())
}

// -- framing -----------------------------------------------------------------

/// Compress `raw` into the framed block stream. Infallible: blocks
/// that do not shrink are stored RAW, so the worst case is the framing
/// overhead (8 bytes + 9 per block).
pub fn compress(raw: &[u8]) -> Vec<u8> {
    assert!(
        raw.len() <= u32::MAX as usize,
        "section too large for the codec frame"
    );
    let mut out = Vec::new();
    out.extend_from_slice(&(raw.len() as u32).to_le_bytes());
    let n_blocks = raw.len().div_ceil(BLOCK);
    out.extend_from_slice(&(n_blocks as u32).to_le_bytes());
    let mut head = vec![u32::MAX; 1 << HASH_BITS];
    for b in raw.chunks(BLOCK) {
        let coded = encode_block(b, &mut head);
        let (kind, payload): (u8, &[u8]) = if coded.len() < b.len() {
            (KIND_CODED, &coded)
        } else {
            (KIND_RAW, b)
        };
        out.extend_from_slice(&(b.len() as u32).to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.push(kind);
        out.extend_from_slice(payload);
    }
    out
}

/// Peek the decompressed length from the frame header without decoding
/// (the `inspect` section table).
pub fn stored_raw_len(stored: &[u8]) -> Result<usize, CodecError> {
    if stored.len() < 4 {
        return Err(truncated("frame header"));
    }
    Ok(u32::from_le_bytes([stored[0], stored[1], stored[2], stored[3]])
        as usize)
}

/// Decompress a framed block stream produced by [`compress`].
pub fn decompress(stored: &[u8]) -> Result<Vec<u8>, CodecError> {
    let mut pos = 0usize;
    let mut u32_at = |p: &mut usize, what: &str| -> Result<u32, CodecError> {
        let b = stored
            .get(*p..*p + 4)
            .ok_or_else(|| truncated(what))?;
        *p += 4;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    };
    let total = u32_at(&mut pos, "frame header")? as usize;
    let n_blocks = u32_at(&mut pos, "frame header")? as usize;
    if n_blocks != total.div_ceil(BLOCK) {
        return Err(corrupt(format!(
            "block count {n_blocks} does not cover the declared length \
             {total}"
        )));
    }
    let mut out = Vec::with_capacity(total.min(stored.len().saturating_mul(64)));
    for blk in 0..n_blocks {
        let braw = u32_at(&mut pos, "block header")? as usize;
        let bstored = u32_at(&mut pos, "block header")? as usize;
        if braw > BLOCK || braw == 0 {
            return Err(corrupt(format!(
                "block {blk} declares an impossible raw length {braw}"
            )));
        }
        let kind = *stored
            .get(pos)
            .ok_or_else(|| truncated("block kind byte"))?;
        pos += 1;
        let payload = stored
            .get(pos..pos + bstored)
            .ok_or_else(|| truncated("block payload"))?;
        pos += bstored;
        match kind {
            KIND_RAW => {
                if bstored != braw {
                    return Err(corrupt(format!(
                        "raw block {blk} stores {bstored} bytes but \
                         declares {braw}"
                    )));
                }
                out.extend_from_slice(payload);
            }
            KIND_CODED => decode_block(payload, braw, &mut out)?,
            k => {
                return Err(corrupt(format!(
                    "unknown block kind {k} in block {blk}"
                )))
            }
        }
    }
    if pos != stored.len() {
        return Err(corrupt(format!(
            "{} trailing bytes after the final block",
            stored.len() - pos
        )));
    }
    if out.len() != total {
        return Err(corrupt(format!(
            "decompressed length mismatch: frame declares {total}, \
             decoded {}",
            out.len()
        )));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn round_trip(raw: &[u8]) -> Vec<u8> {
        let stored = compress(raw);
        assert_eq!(stored_raw_len(&stored).unwrap(), raw.len());
        let back = decompress(&stored).unwrap();
        assert_eq!(back, raw, "round trip of {} bytes", raw.len());
        stored
    }

    #[test]
    fn degenerate_inputs_round_trip() {
        round_trip(&[]);
        round_trip(&[42]);
        round_trip(&[0; 3]);
        round_trip(b"abcd");
    }

    #[test]
    fn compressible_data_shrinks() {
        let zeros = vec![0u8; 100_000];
        let stored = round_trip(&zeros);
        assert!(
            stored.len() < zeros.len() / 50,
            "zero run stored as {} bytes",
            stored.len()
        );
        let pattern: Vec<u8> =
            (0..60_000).map(|i| ((i * 7) % 13) as u8).collect();
        let stored = round_trip(&pattern);
        assert!(stored.len() < pattern.len() / 4);
    }

    #[test]
    fn gaussian_codes_shrink_via_entropy_coding() {
        // the weight-grid shape: Gaussian codes use the full byte range
        // but at ~7 bits of entropy — LZ alone cannot touch this, the
        // adaptive literal model must
        let mut rng = Rng::new(99);
        let codes: Vec<u8> = rng
            .normal_vec(200_000, 40.0)
            .into_iter()
            .map(|v| (v.round().clamp(-128.0, 127.0) as i8) as u8)
            .collect();
        let stored = round_trip(&codes);
        assert!(
            stored.len() < codes.len() * 97 / 100,
            "Gaussian codes must shrink: {} vs {}",
            stored.len(),
            codes.len()
        );
    }

    #[test]
    fn incompressible_data_costs_only_framing() {
        let mut rng = Rng::new(7);
        // uniform random bytes: every block falls back to RAW storage
        let noise: Vec<u8> =
            (0..BLOCK + 1000).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let stored = round_trip(&noise);
        assert!(stored.len() <= noise.len() + 8 + 2 * 9);
    }

    #[test]
    fn multi_block_inputs_round_trip() {
        let mut rng = Rng::new(11);
        let mut data: Vec<u8> = rng
            .normal_vec(2 * BLOCK + 4321, 30.0)
            .into_iter()
            .map(|v| v as i64 as u8)
            .collect();
        data.extend(std::iter::repeat(9u8).take(5000));
        round_trip(&data);
    }

    #[test]
    fn compression_is_deterministic() {
        let mut rng = Rng::new(3);
        let data: Vec<u8> =
            rng.normal_vec(50_000, 25.0).iter().map(|&v| v as u8).collect();
        assert_eq!(compress(&data), compress(&data));
    }

    #[test]
    fn truncation_is_a_typed_error() {
        let data = vec![5u8; 10_000];
        let stored = compress(&data);
        for cut in [0, 3, 7, 8, 12, 16, stored.len() - 1] {
            match decompress(&stored[..cut]) {
                Err(CodecError::Truncated { .. })
                | Err(CodecError::Corrupt { .. }) => {}
                Ok(out) => panic!("cut at {cut} decoded {} bytes", out.len()),
            }
        }
    }

    #[test]
    fn bit_flips_never_panic() {
        let mut rng = Rng::new(21);
        let data: Vec<u8> =
            rng.normal_vec(30_000, 35.0).iter().map(|&v| v as u8).collect();
        let stored = compress(&data);
        for i in (0..stored.len()).step_by(stored.len() / 97 + 1) {
            let mut bad = stored.clone();
            bad[i] ^= 0x10;
            // a flip must surface as a typed error or (rarely, for
            // flips inside a literal) wrong bytes — never a panic
            if let Ok(out) = decompress(&bad) {
                assert_eq!(out.len(), data.len());
            }
        }
    }

    #[test]
    fn declared_length_mismatch_is_corrupt() {
        let stored = compress(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let mut bad = stored.clone();
        bad[0] = bad[0].wrapping_add(1); // frame raw_len no longer matches
        match decompress(&bad) {
            Err(CodecError::Corrupt { .. })
            | Err(CodecError::Truncated { .. }) => {}
            Ok(_) => panic!("length mismatch must not decode"),
        }
    }
}
