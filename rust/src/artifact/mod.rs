//! Compiled-model artifacts: run the DFQ pipeline **once**, ship the
//! resulting integer execution plan as a load-and-go binary.
//!
//! The rest of the crate boots a model by replaying the whole paper
//! pipeline — BN fold → CLE → bias absorption → quantise → plan — on
//! every process start. This subsystem snapshots the *end product* of
//! that work (the planned [`QModel`](crate::nn::qengine::QModel)) into a
//! versioned little-endian container so a serving host pays none of it:
//!
//! * [`writer::write_artifact`] / [`crate::dfq::QuantizedModel::save_artifact`]
//!   — compile + serialise (`dfq compile` on the CLI),
//! * [`reader::Artifact`] /
//!   [`QModel::from_artifact`](crate::nn::qengine::QModel::from_artifact)
//!   — decode back into a ready-to-run plan with **zero float math**
//!   (every multiplier, folded bias and weight code is restored
//!   bit-for-bit, so outputs are bitwise-identical to the in-memory
//!   plan),
//! * [`crate::serve::registry`] — hosts many such artifacts in one
//!   process (`dfq serve --models dir/`).
//!
//! ## Container layout
//!
//! A magic header + BOM-style table of named `{offset, size, crc32}`
//! entries (see [`format`]), with one section per payload kind:
//!
//! | section        | content                                            |
//! |----------------|----------------------------------------------------|
//! | `meta`         | JSON: model name, input shape, classes, plan summary |
//! | `plan`         | op stream: wiring (slots/ins/outs), op tags, small scalars, activation grids |
//! | `wgrid.i8`     | i8 weight codes, kernel layout (transposed / O-major) |
//! | `qparams`      | per-channel weight grids: `(s_w, zp_w, bias_f)`    |
//! | `bias.i64`     | folded i64 biases: `zp_corr`, then `bias_q` per fused conv |
//! | `mult.fix`     | fixed-point requant multipliers (`m·2^-shift` or f64) |
//! | `fallback.f32` | f32 fallback weights (omitted on fully-integer plans) |
//!
//! Per-conv *pre-activation* grids travel as the `Grid`-epilogue output
//! grids of their convs inside `plan` — the form the executor actually
//! consumes. Streams are append-only in op order; the reader replays
//! them with sequential cursors and re-validates every structural
//! invariant, so corrupt files surface as typed [`ArtifactError`]s
//! (bad magic, truncation, CRC mismatch, malformed content) rather than
//! panics.
//!
//! Two storage refinements ride on container version 3:
//!
//! * **Zero-copy loads** — [`Artifact::open_mmap`] /
//!   [`QModel::from_artifact_mmap`](crate::nn::qengine::QModel::from_artifact_mmap)
//!   parse the container over a shared read-only memory map
//!   ([`crate::util::mmap`]) and build the `wgrid.i8` / `bias.i64`
//!   tensors as typed views straight into the page-cache-backed bytes,
//!   bitwise-identical to the copy path. N processes serving the same
//!   zoo share one physical copy of the weights, and evicting a model
//!   frees only the cheap plan structs.
//! * **Compressed cold storage** — `dfq compile --compress` stores the
//!   `wgrid.i8` and `plan` sections as [`codec`] frames (per-section
//!   [`format::FLAG_COMPRESSED`] in the BOM); they are CRC-checked over
//!   the stored bytes and decompressed once at load. v1/v2 artifacts
//!   (flags word always 0) read unchanged.
//!
//! Container version 4 adds the segmentation/detection op tags:
//! transposed conv (`OP_CONVT` wraps the inner flipped-kernel stride-1
//! conv encoding plus the logical stride/pad; `OP_CONVTF` is its f32
//! fallback) and rectangular/global pooling (`OP_POOL_RECT_INT` /
//! `OP_POOL_RECTF` carry per-axis `k/stride/pad` and the global flag;
//! square non-global pools still use the legacy tags). v1–v3 artifacts
//! read unchanged.

pub mod codec;
pub mod format;
pub mod reader;
pub mod writer;

pub use format::{crc32, ArtifactError, SectionStat};
pub use reader::{inspect, section_table, Artifact};
pub use writer::{
    encode_qmodel, encode_qmodel_opts, write_artifact, write_artifact_opts,
};

// Section names (≤ 16 ASCII bytes each; see `format`).
pub(crate) const SEC_META: &str = "meta";
pub(crate) const SEC_PLAN: &str = "plan";
pub(crate) const SEC_WGRID: &str = "wgrid.i8";
pub(crate) const SEC_QPARAMS: &str = "qparams";
pub(crate) const SEC_BIAS: &str = "bias.i64";
pub(crate) const SEC_MULT: &str = "mult.fix";
pub(crate) const SEC_FALLBACK: &str = "fallback.f32";

// Op tags of the `plan` stream (one per `QOp` variant).
pub(crate) const OP_QUANT_IN: u8 = 0;
pub(crate) const OP_CONV: u8 = 1;
pub(crate) const OP_CONV_F32: u8 = 2;
pub(crate) const OP_ADD_INT: u8 = 3;
pub(crate) const OP_ADDF: u8 = 4;
pub(crate) const OP_ACT_REQUANT: u8 = 5;
pub(crate) const OP_ACTF: u8 = 6;
pub(crate) const OP_GAP: u8 = 7;
pub(crate) const OP_GAPF: u8 = 8;
pub(crate) const OP_LINEAR: u8 = 9;
pub(crate) const OP_LINEARF: u8 = 10;
pub(crate) const OP_UPSAMPLE: u8 = 11;
pub(crate) const OP_CONCAT_INT: u8 = 12;
pub(crate) const OP_CONCATF: u8 = 13;
pub(crate) const OP_POOL_INT: u8 = 14;
pub(crate) const OP_POOLF: u8 = 15;
// Version-4 tags: transposed conv + rectangular/global pooling. Square
// non-global pools keep the legacy 14/15 encodings, so models without
// these ops produce byte-identical containers across the version bump.
pub(crate) const OP_CONVT: u8 = 16;
pub(crate) const OP_CONVTF: u8 = 17;
pub(crate) const OP_POOL_RECT_INT: u8 = 18;
pub(crate) const OP_POOL_RECTF: u8 = 19;

// Pool-kind tags inside pool op payloads.
pub(crate) const POOL_MAX: u8 = 0;
pub(crate) const POOL_AVG: u8 = 1;

/// Serving-relevant metadata of a compiled artifact (the `meta` section
/// plus the on-disk size).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactInfo {
    /// Source model name.
    pub name: String,
    /// Expected input `[C, H, W]`.
    pub input_shape: [usize; 3],
    pub num_classes: usize,
    /// Planned op count.
    pub ops: usize,
    /// Dense value slots of the plan.
    pub slots: usize,
    /// Conv/linear layers on the integer path.
    pub int_layers: usize,
    /// Conv/linear layers executing in f32.
    pub f32_layers: usize,
    /// f32 fallback ops surviving planning (0 on a pure-int8 plan).
    pub fallback_ops: usize,
    /// Container size in bytes (0 until written / after open).
    pub bytes: usize,
}

impl ArtifactInfo {
    /// One-line human summary (CLI / registry logs).
    pub fn summary(&self) -> String {
        format!(
            "{} [{}x{}x{} -> {} classes] {} op(s), {} int8 / {} f32 \
             layer(s), {} fallback op(s), {} bytes",
            self.name,
            self.input_shape[0],
            self.input_shape[1],
            self.input_shape[2],
            self.num_classes,
            self.ops,
            self.int_layers,
            self.f32_layers,
            self.fallback_ops,
            self.bytes
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::{quantize_data_free, testutil, BiasCorrMode, DfqConfig};
    use crate::nn::qengine::{PlanOpts, QModel};
    use crate::quant::QScheme;

    fn quantized(seed: u64) -> crate::dfq::QuantizedModel {
        let m = testutil::residual_block_model(seed);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        prep.quantize(
            &QScheme::int8_asymmetric(),
            8,
            BiasCorrMode::None,
            None,
        )
        .unwrap()
    }

    #[test]
    fn encode_decode_is_bitwise_stable() {
        let q = quantized(41);
        let qm = q
            .pack_int8_opts(PlanOpts { int8_only: true, ..Default::default() })
            .unwrap();
        let info = writer::info_for(&q, &qm);
        let bytes = encode_qmodel(&qm, &info);
        // deterministic encoder: same plan -> same bytes
        assert_eq!(bytes, encode_qmodel(&qm, &info));
        let art = Artifact::from_bytes(bytes).unwrap();
        assert_eq!(art.info().name, q.model.name);
        assert_eq!(art.info().fallback_ops, 0);
        let qm2 = art.into_qmodel();
        assert_eq!(qm2.num_ops(), qm.num_ops());
        assert_eq!(qm2.summarize(), qm.summarize());
        let x = testutil::random_input(&q.model, 2, 7);
        let y0 = qm.run_all(&x).unwrap();
        let y1 = qm2.run_all(&x).unwrap();
        assert_eq!(y0.len(), y1.len());
        for (a, b) in y0.iter().zip(&y1) {
            assert_eq!(a.shape(), b.shape());
            assert_eq!(a.data(), b.data(), "decoded plan drifted bitwise");
        }
    }

    #[test]
    fn from_artifact_reads_what_save_wrote() {
        let q = quantized(42);
        let dir = std::env::temp_dir().join(format!(
            "dfq-artifact-test-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("resblock.dfqm");
        let info = write_artifact(&q, PlanOpts::default(), &path).unwrap();
        assert!(info.bytes > 0);
        assert_eq!(inspect(&path).unwrap(), info);
        let qm = QModel::from_artifact(&path).unwrap();
        let x = testutil::random_input(&q.model, 1, 3);
        let want = q.pack_int8().unwrap().run(&x).unwrap();
        let got = qm.run(&x).unwrap();
        assert_eq!(want.data(), got.data());
        std::fs::remove_dir_all(&dir).ok();
    }
}
