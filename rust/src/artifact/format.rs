//! The `.dfqm` compiled-artifact container: a versioned little-endian
//! section file (magic + header + BOM-style table of named
//! `{offset, size, crc32}` entries), plus the byte-cursor codecs the
//! writer/reader build on.
//!
//! ## Layout
//!
//! ```text
//! offset 0   magic          b"DFQP"           (4 bytes)
//!        4   version        u32 LE            (currently 4; 1–3 still read)
//!        8   n_sections     u32 LE
//!       12   reserved       u32 LE            (0)
//!       16   section table  n_sections × 40-byte entries:
//!              name    [u8; 16]  NUL-padded ASCII
//!              offset  u64 LE    absolute, 64-byte aligned
//!              size    u64 LE    stored payload bytes (pre-padding)
//!              crc32   u32 LE    IEEE CRC-32 of the *stored* payload
//!              flags   u32 LE    bit 0 = compressed (v1/v2 wrote 0 here)
//!       ...  section payloads, each 64-byte aligned
//! ```
//!
//! Version 3 repurposed the per-entry pad word as a flags word;
//! [`FLAG_COMPRESSED`] marks a section stored as a [`super::codec`]
//! frame. The CRC always covers the stored bytes, so corruption is
//! caught *before* any decompression runs; unknown flag bits are
//! tolerated on read (forward compatibility — `dfq inspect` warns).
//! The container can be parsed either from an owned byte buffer or
//! straight over a shared [`Mmap`], in which case raw sections borrow
//! from the page cache and report their absolute offset so the decoder
//! can build zero-copy typed views.
//!
//! Every failure mode is a typed [`ArtifactError`] (never a panic):
//! corrupt downloads, truncated copies and version skew all surface as
//! distinct, matchable variants.

use std::borrow::Cow;
use std::fmt;
use std::ops::Deref;
use std::path::Path;
use std::sync::Arc;

use super::codec::{self, CodecError};
use crate::util::mmap::Mmap;

/// Magic of a compiled-plan artifact ("Data-Free Quantized Plan") —
/// distinct from the `b"DFQM"` *source model* container magic so the two
/// `.dfqm` kinds can never be confused at load time.
pub const MAGIC: [u8; 4] = *b"DFQP";

/// Current container format version. Version 2 added the concat/pool2d
/// op tags (12–15) to the plan stream; version 3 turned the per-entry
/// pad word into section flags (compressed storage); version 4 added
/// the transposed-conv and rectangular/global-pool op tags (16–19).
/// Every older version still loads unchanged (v1/v2 wrote zeros in the
/// flags slot; v3 plans simply never contain the new tags).
pub const VERSION: u32 = 4;

/// Oldest format version this build still reads.
pub const MIN_VERSION: u32 = 1;

/// Section-flag bit: the stored payload is a [`super::codec`] frame and
/// must be decompressed after its CRC check.
pub const FLAG_COMPRESSED: u32 = 1;

/// Flag bits this build understands; others are ignored on read
/// (forward compatibility) and reported by `dfq inspect`.
pub const KNOWN_FLAGS: u32 = FLAG_COMPRESSED;

/// Payload alignment (matches the source-model container).
const ALIGN: usize = 64;

/// Fixed header bytes before the section table.
const HEADER_LEN: usize = 16;

/// One section-table entry's encoded size.
const ENTRY_LEN: usize = 40;

const NAME_LEN: usize = 16;

fn pad_to(n: usize) -> usize {
    (ALIGN - n % ALIGN) % ALIGN
}

// -- typed errors ------------------------------------------------------------

/// Everything that can go wrong opening or decoding an artifact. Implements
/// `std::error::Error`, so `?` converts it into the crate-wide
/// `anyhow::Error`; keep the typed form (e.g. via
/// [`crate::artifact::Artifact::open_typed`]) to match on variants.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArtifactError {
    /// Filesystem-level failure (path + OS message).
    Io { path: String, msg: String },
    /// The first four bytes are not [`MAGIC`] (e.g. a *source* `.dfqm`
    /// model container, or not a dfq file at all).
    BadMagic { found: [u8; 4] },
    /// A newer (or corrupt) format version this build cannot read.
    UnsupportedVersion { found: u32 },
    /// The file ends before the named structure does.
    Truncated { what: String },
    /// A section's stored CRC-32 does not match its payload.
    CrcMismatch { section: String, stored: u32, computed: u32 },
    /// A required section is absent from the table.
    MissingSection { name: String },
    /// Structurally invalid content inside an intact container.
    Malformed { what: String },
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::Io { path, msg } => {
                write!(f, "artifact io error at {path}: {msg}")
            }
            ArtifactError::BadMagic { found } => write!(
                f,
                "bad artifact magic {:?} (expected {:?} — a compiled \
                 artifact, not a source model container)",
                String::from_utf8_lossy(found),
                String::from_utf8_lossy(&MAGIC),
            ),
            ArtifactError::UnsupportedVersion { found } => write!(
                f,
                "unsupported artifact version {found} (this build reads \
                 versions {MIN_VERSION}..={VERSION})"
            ),
            ArtifactError::Truncated { what } => {
                write!(f, "truncated artifact: {what}")
            }
            ArtifactError::CrcMismatch { section, stored, computed } => {
                write!(
                    f,
                    "crc mismatch in section '{section}': stored \
                     {stored:#010x}, computed {computed:#010x}"
                )
            }
            ArtifactError::MissingSection { name } => {
                write!(f, "missing artifact section '{name}'")
            }
            ArtifactError::Malformed { what } => {
                write!(f, "malformed artifact: {what}")
            }
        }
    }
}

impl std::error::Error for ArtifactError {}

/// Artifact-local result alias (typed error).
pub type AResult<T> = std::result::Result<T, ArtifactError>;

fn truncated(what: impl Into<String>) -> ArtifactError {
    ArtifactError::Truncated { what: what.into() }
}

pub(crate) fn malformed(what: impl Into<String>) -> ArtifactError {
    ArtifactError::Malformed { what: what.into() }
}

// -- crc32 -------------------------------------------------------------------

const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
}

/// IEEE CRC-32 (the zlib/`crc32` polynomial, reflected).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// -- container writer --------------------------------------------------------

/// Accumulates named sections and emits the final container image.
pub struct ContainerWriter {
    sections: Vec<(String, Vec<u8>, u32)>,
}

impl ContainerWriter {
    pub fn new() -> ContainerWriter {
        ContainerWriter { sections: Vec::new() }
    }

    /// Append one named section (names must be unique, ≤ 16 ASCII bytes).
    pub fn push(&mut self, name: &str, payload: Vec<u8>) {
        self.push_flagged(name, payload, 0);
    }

    /// Append one section stored as a compressed [`super::codec`] frame
    /// — unless compression does not shrink it, in which case the raw
    /// payload is stored (flags 0), so stored size never exceeds raw.
    pub fn push_compressed(&mut self, name: &str, payload: Vec<u8>) {
        let stored = codec::compress(&payload);
        if stored.len() < payload.len() {
            self.push_flagged(name, stored, FLAG_COMPRESSED);
        } else {
            self.push_flagged(name, payload, 0);
        }
    }

    fn push_flagged(&mut self, name: &str, payload: Vec<u8>, flags: u32) {
        assert!(
            name.len() <= NAME_LEN && name.is_ascii(),
            "section name '{name}' must be ≤ {NAME_LEN} ASCII bytes"
        );
        assert!(
            self.sections.iter().all(|(n, _, _)| n != name),
            "duplicate section '{name}'"
        );
        self.sections.push((name.to_string(), payload, flags));
    }

    /// Serialise header + table + aligned payloads.
    pub fn finish(self) -> Vec<u8> {
        let n = self.sections.len();
        let table_end = HEADER_LEN + n * ENTRY_LEN;
        let mut offset = table_end + pad_to(table_end);
        let mut entries = Vec::with_capacity(n);
        for (name, payload, _) in &self.sections {
            entries.push((name.clone(), offset, payload.len(), crc32(payload)));
            offset += payload.len() + pad_to(payload.len());
        }
        let mut out = Vec::with_capacity(offset);
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(n as u32).to_le_bytes());
        out.extend_from_slice(&0u32.to_le_bytes());
        for (i, (name, off, size, crc)) in entries.iter().enumerate() {
            let mut nb = [0u8; NAME_LEN];
            nb[..name.len()].copy_from_slice(name.as_bytes());
            out.extend_from_slice(&nb);
            out.extend_from_slice(&(*off as u64).to_le_bytes());
            out.extend_from_slice(&(*size as u64).to_le_bytes());
            out.extend_from_slice(&crc.to_le_bytes());
            out.extend_from_slice(&self.sections[i].2.to_le_bytes());
        }
        out.resize(out.len() + pad_to(out.len()), 0);
        for (i, (_, payload, _)) in self.sections.iter().enumerate() {
            debug_assert_eq!(out.len(), entries[i].1, "section offset drift");
            out.extend_from_slice(payload);
            if i + 1 < n {
                out.resize(out.len() + pad_to(payload.len()), 0);
            }
        }
        out
    }
}

impl Default for ContainerWriter {
    fn default() -> Self {
        ContainerWriter::new()
    }
}

// -- container reader --------------------------------------------------------

struct Entry {
    name: String,
    offset: usize,
    size: usize,
    crc: u32,
    flags: u32,
}

/// Where the container bytes live: an owned read, or a shared mapping
/// whose raw sections can be served zero-copy.
enum Backing {
    Owned(Vec<u8>),
    Mapped(Arc<Mmap>),
}

impl Backing {
    fn bytes(&self) -> &[u8] {
        match self {
            Backing::Owned(v) => v,
            Backing::Mapped(m) => m,
        }
    }
}

/// One section's payload: CRC-checked stored bytes, decompressed when
/// the entry carries [`FLAG_COMPRESSED`]. Raw sections borrow straight
/// from the container and report their absolute offset so a mmap'd
/// decode can build typed views into the backing pages.
pub struct SectionBytes<'a> {
    data: Cow<'a, [u8]>,
    /// Absolute container offset of `data` when borrowed (raw
    /// sections); `None` for decompressed (owned) payloads.
    container_off: Option<usize>,
}

impl SectionBytes<'_> {
    /// Absolute offset of byte 0 inside the container, when the
    /// payload is a direct borrow of it.
    pub fn container_off(&self) -> Option<usize> {
        self.container_off
    }
}

impl Deref for SectionBytes<'_> {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.data
    }
}

/// Per-section storage facts for `dfq inspect`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SectionStat {
    pub name: String,
    /// Bytes stored in the container (post-compression).
    pub stored: usize,
    /// Decompressed payload size; `None` when the compressed frame
    /// header is unreadable.
    pub raw: Option<usize>,
    pub crc: u32,
    pub flags: u32,
}

impl SectionStat {
    /// Flag bits this build does not understand (warn, don't fail).
    pub fn unknown_flags(&self) -> u32 {
        self.flags & !KNOWN_FLAGS
    }
}

/// A parsed container: the section table plus the raw bytes. Section
/// payloads are CRC-checked on access (over the *stored* bytes, before
/// any decompression).
pub struct ContainerReader {
    data: Backing,
    entries: Vec<Entry>,
}

impl ContainerReader {
    pub fn open(path: &Path) -> AResult<ContainerReader> {
        let data = std::fs::read(path).map_err(|e| ArtifactError::Io {
            path: path.display().to_string(),
            msg: e.to_string(),
        })?;
        ContainerReader::parse(data)
    }

    /// Parse over a shared read-only mapping (zero-copy raw sections).
    pub fn parse_mmap(map: Arc<Mmap>) -> AResult<ContainerReader> {
        ContainerReader::parse_backing(Backing::Mapped(map))
    }

    pub fn parse(data: Vec<u8>) -> AResult<ContainerReader> {
        ContainerReader::parse_backing(Backing::Owned(data))
    }

    fn parse_backing(backing: Backing) -> AResult<ContainerReader> {
        let entries = ContainerReader::parse_entries(backing.bytes())?;
        Ok(ContainerReader { data: backing, entries })
    }

    fn parse_entries(data: &[u8]) -> AResult<Vec<Entry>> {
        if data.len() < HEADER_LEN {
            return Err(truncated("file shorter than the 16-byte header"));
        }
        let magic: [u8; 4] = data[0..4].try_into().unwrap();
        if magic != MAGIC {
            return Err(ArtifactError::BadMagic { found: magic });
        }
        let version = u32::from_le_bytes(data[4..8].try_into().unwrap());
        if !(MIN_VERSION..=VERSION).contains(&version) {
            return Err(ArtifactError::UnsupportedVersion { found: version });
        }
        let n = u32::from_le_bytes(data[8..12].try_into().unwrap()) as usize;
        if n > 1024 {
            return Err(malformed(format!("implausible section count {n}")));
        }
        let table_end = HEADER_LEN + n * ENTRY_LEN;
        if data.len() < table_end {
            return Err(truncated(format!(
                "section table needs {table_end} bytes, file has {}",
                data.len()
            )));
        }
        let mut entries = Vec::with_capacity(n);
        for i in 0..n {
            let base = HEADER_LEN + i * ENTRY_LEN;
            let raw_name = &data[base..base + NAME_LEN];
            let name_end =
                raw_name.iter().position(|&b| b == 0).unwrap_or(NAME_LEN);
            let name = std::str::from_utf8(&raw_name[..name_end])
                .map_err(|_| {
                    malformed(format!("section {i} name is not UTF-8"))
                })?
                .to_string();
            let offset = u64::from_le_bytes(
                data[base + 16..base + 24].try_into().unwrap(),
            ) as usize;
            let size = u64::from_le_bytes(
                data[base + 24..base + 32].try_into().unwrap(),
            ) as usize;
            let crc = u32::from_le_bytes(
                data[base + 32..base + 36].try_into().unwrap(),
            );
            // the pad word of v1/v2 entries (always 0) is the v3 flags
            // word — parsing it unconditionally reads all versions
            let flags = u32::from_le_bytes(
                data[base + 36..base + 40].try_into().unwrap(),
            );
            match offset.checked_add(size) {
                Some(end) if end <= data.len() => {}
                _ => {
                    return Err(truncated(format!(
                        "section '{name}' claims [{offset}, \
                         {offset}+{size}) but file has {} bytes",
                        data.len()
                    )))
                }
            }
            entries.push(Entry { name, offset, size, crc, flags });
        }
        Ok(entries)
    }

    pub fn section_names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// Total container size in bytes.
    pub fn total_bytes(&self) -> usize {
        self.data.bytes().len()
    }

    /// Stored (on-disk) size of a section.
    pub fn section_size(&self, name: &str) -> Option<usize> {
        self.entries.iter().find(|e| e.name == name).map(|e| e.size)
    }

    /// The shared mapping backing this container, if it was opened via
    /// [`ContainerReader::parse_mmap`] — the decoder clones the `Arc`
    /// into every zero-copy tensor view it hands out.
    pub fn backing_mmap(&self) -> Option<&Arc<Mmap>> {
        match &self.data {
            Backing::Owned(_) => None,
            Backing::Mapped(m) => Some(m),
        }
    }

    /// Per-section storage facts (sizes, crc, flags) for `dfq inspect`.
    /// Reads only headers — no CRC checks, no decompression.
    pub fn section_stats(&self) -> Vec<SectionStat> {
        let data = self.data.bytes();
        self.entries
            .iter()
            .map(|e| {
                let raw = if e.flags & FLAG_COMPRESSED != 0 {
                    codec::stored_raw_len(&data[e.offset..e.offset + e.size])
                        .ok()
                } else {
                    Some(e.size)
                };
                SectionStat {
                    name: e.name.clone(),
                    stored: e.size,
                    raw,
                    crc: e.crc,
                    flags: e.flags,
                }
            })
            .collect()
    }

    /// One section's payload, CRC-verified over the stored bytes and
    /// decompressed if the entry is flagged compressed. Unknown flag
    /// bits are ignored (forward compatibility).
    pub fn section(&self, name: &str) -> AResult<SectionBytes<'_>> {
        let e = self
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| ArtifactError::MissingSection {
                name: name.to_string(),
            })?;
        let stored = &self.data.bytes()[e.offset..e.offset + e.size];
        let computed = crc32(stored);
        if computed != e.crc {
            return Err(ArtifactError::CrcMismatch {
                section: name.to_string(),
                stored: e.crc,
                computed,
            });
        }
        if e.flags & FLAG_COMPRESSED != 0 {
            let raw = codec::decompress(stored).map_err(|err| match err {
                CodecError::Truncated { what } => truncated(format!(
                    "section '{name}' compressed payload: {what}"
                )),
                CodecError::Corrupt { what } => malformed(format!(
                    "section '{name}' compressed payload: {what}"
                )),
            })?;
            Ok(SectionBytes { data: Cow::Owned(raw), container_off: None })
        } else {
            Ok(SectionBytes {
                data: Cow::Borrowed(stored),
                container_off: Some(e.offset),
            })
        }
    }
}

// -- byte cursors ------------------------------------------------------------

/// Little-endian append-only encoder (infallible).
#[derive(Default)]
pub struct ByteWriter {
    pub buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn i8_slice(&mut self, v: &[i8]) {
        // i8 → u8 is a bit-level reinterpretation
        self.buf.extend(v.iter().map(|&x| x as u8));
    }

    pub fn i32_slice(&mut self, v: &[i32]) {
        for &x in v {
            self.i32(x);
        }
    }

    pub fn i64_slice(&mut self, v: &[i64]) {
        for &x in v {
            self.i64(x);
        }
    }

    pub fn f32_slice(&mut self, v: &[f32]) {
        for &x in v {
            self.f32(x);
        }
    }
}

/// Little-endian cursor over one section; every read is bounds-checked
/// and fails with a typed [`ArtifactError::Truncated`] naming the
/// section.
pub struct ByteReader<'a> {
    data: &'a [u8],
    pos: usize,
    section: &'a str,
}

impl<'a> ByteReader<'a> {
    pub fn new(data: &'a [u8], section: &'a str) -> ByteReader<'a> {
        ByteReader { data, pos: 0, section }
    }

    fn take(&mut self, n: usize) -> AResult<&'a [u8]> {
        if self.data.len() - self.pos < n {
            return Err(truncated(format!(
                "section '{}' ends at byte {} (wanted {n} more at offset {})",
                self.section,
                self.data.len(),
                self.pos
            )));
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> AResult<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> AResult<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> AResult<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> AResult<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> AResult<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> AResult<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> AResult<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> AResult<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| {
            malformed(format!(
                "section '{}': value {v} exceeds usize",
                self.section
            ))
        })
    }

    pub fn i8_vec(&mut self, n: usize) -> AResult<Vec<i8>> {
        Ok(self.take(n)?.iter().map(|&b| b as i8).collect())
    }

    pub fn i32_vec(&mut self, n: usize) -> AResult<Vec<i32>> {
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| {
            malformed(format!("section '{}': i32 count overflow", self.section))
        })?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn i64_vec(&mut self, n: usize) -> AResult<Vec<i64>> {
        let bytes = self.take(n.checked_mul(8).ok_or_else(|| {
            malformed(format!("section '{}': i64 count overflow", self.section))
        })?)?;
        Ok(bytes
            .chunks_exact(8)
            .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn f32_vec(&mut self, n: usize) -> AResult<Vec<f32>> {
        let bytes = self.take(n.checked_mul(4).ok_or_else(|| {
            malformed(format!("section '{}': f32 count overflow", self.section))
        })?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Bytes consumed so far (stream-relative offset of the cursor).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Advance over `n` bytes without decoding them (zero-copy view
    /// construction) — same typed truncation error as a read.
    pub fn skip(&mut self, n: usize) -> AResult<()> {
        self.take(n).map(|_| ())
    }

    pub fn remaining(&self) -> usize {
        self.data.len() - self.pos
    }

    /// Assert the cursor consumed the whole section (decode integrity).
    pub fn expect_end(&self) -> AResult<()> {
        if self.remaining() != 0 {
            return Err(malformed(format!(
                "section '{}' has {} undecoded trailing bytes",
                self.section,
                self.remaining()
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_reference_value() {
        // zlib.crc32(b"123456789") == 0xcbf43926
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn container_roundtrip() {
        let mut w = ContainerWriter::new();
        w.push("alpha", vec![1, 2, 3]);
        w.push("beta", (0..200u8).collect());
        let bytes = w.finish();
        let r = ContainerReader::parse(bytes).unwrap();
        assert_eq!(r.section_names(), vec!["alpha", "beta"]);
        assert_eq!(&r.section("alpha").unwrap()[..], &[1, 2, 3]);
        let beta = r.section("beta").unwrap();
        assert_eq!(beta.len(), 200);
        assert!(beta.container_off().is_some(), "raw sections borrow");
        assert!(matches!(
            r.section("gamma"),
            Err(ArtifactError::MissingSection { .. })
        ));
    }

    #[test]
    fn compressed_sections_roundtrip_and_report_sizes() {
        let raw: Vec<u8> = std::iter::repeat(7u8).take(4000).collect();
        let mut w = ContainerWriter::new();
        w.push_compressed("z", raw.clone());
        w.push("r", vec![1, 2, 3]);
        let r = ContainerReader::parse(w.finish()).unwrap();
        let z = r.section("z").unwrap();
        assert_eq!(&z[..], &raw[..]);
        assert!(z.container_off().is_none(), "decompressed payloads own");
        let stats = r.section_stats();
        assert_eq!(stats[0].flags, FLAG_COMPRESSED);
        assert_eq!(stats[0].raw, Some(4000));
        assert!(stats[0].stored < 4000, "zero run must shrink");
        assert_eq!(stats[0].unknown_flags(), 0);
        assert_eq!(stats[1].flags, 0);
        assert_eq!(stats[1].raw, Some(3));
    }

    #[test]
    fn incompressible_push_compressed_stores_raw() {
        // compression would expand 3 bytes -> stored raw with flags 0
        let mut w = ContainerWriter::new();
        w.push_compressed("tiny", vec![1, 2, 3]);
        let r = ContainerReader::parse(w.finish()).unwrap();
        assert_eq!(r.section_stats()[0].flags, 0);
        assert_eq!(&r.section("tiny").unwrap()[..], &[1, 2, 3]);
    }

    #[test]
    fn unknown_flag_bits_are_tolerated() {
        let mut w = ContainerWriter::new();
        w.push("s", vec![5; 32]);
        let mut bytes = w.finish();
        // set a future flag bit in the entry's flags word
        let flags_at = HEADER_LEN + 36;
        bytes[flags_at..flags_at + 4].copy_from_slice(&8u32.to_le_bytes());
        let r = ContainerReader::parse(bytes).unwrap();
        assert_eq!(r.section("s").unwrap().len(), 32, "read must not fail");
        assert_eq!(r.section_stats()[0].unknown_flags(), 8);
    }

    #[test]
    fn compressed_payload_corruption_is_typed() {
        let raw: Vec<u8> = (0..5000).map(|i| (i % 7) as u8).collect();
        let mut w = ContainerWriter::new();
        w.push_compressed("z", raw);
        let good = w.finish();
        let r = ContainerReader::parse(good.clone()).unwrap();
        let stat = &r.section_stats()[0];
        assert_eq!(stat.flags, FLAG_COMPRESSED);

        // flip a stored byte: the CRC over stored bytes trips first
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        let r = ContainerReader::parse(bad).unwrap();
        assert!(matches!(
            r.section("z"),
            Err(ArtifactError::CrcMismatch { .. })
        ));

        // declared-length mismatch inside an intact (re-CRC'd) frame:
        // bump the frame's raw_len and restore the entry CRC
        let mut bad = good.clone();
        let table_base = HEADER_LEN;
        let off = u64::from_le_bytes(
            bad[table_base + 16..table_base + 24].try_into().unwrap(),
        ) as usize;
        let size = u64::from_le_bytes(
            bad[table_base + 24..table_base + 32].try_into().unwrap(),
        ) as usize;
        bad[off] = bad[off].wrapping_add(1);
        let crc = crc32(&bad[off..off + size]);
        bad[table_base + 32..table_base + 36]
            .copy_from_slice(&crc.to_le_bytes());
        let r = ContainerReader::parse(bad).unwrap();
        assert!(matches!(
            r.section("z"),
            Err(ArtifactError::Malformed { .. })
                | Err(ArtifactError::Truncated { .. })
        ));
    }

    #[test]
    fn mmap_backed_parse_serves_sections() {
        let mut w = ContainerWriter::new();
        w.push("alpha", (0..64u8).collect());
        let bytes = w.finish();
        let dir = std::env::temp_dir();
        let p = dir.join(format!("dfq_fmt_mmap_{}.dfqm", std::process::id()));
        std::fs::write(&p, &bytes).unwrap();
        let map = Arc::new(Mmap::map(&p).unwrap());
        let r = ContainerReader::parse_mmap(map).unwrap();
        assert!(r.backing_mmap().is_some());
        let s = r.section("alpha").unwrap();
        assert_eq!(s.len(), 64);
        let off = s.container_off().unwrap();
        assert_eq!(off % 64, 0, "payloads stay 64-byte aligned");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupt_containers_are_typed_errors() {
        let mut w = ContainerWriter::new();
        w.push("s", vec![9; 100]);
        let good = w.finish();

        // bad magic
        let mut bad = good.clone();
        bad[0..4].copy_from_slice(b"NOPE");
        assert!(matches!(
            ContainerReader::parse(bad),
            Err(ArtifactError::BadMagic { .. })
        ));

        // future version
        let mut bad = good.clone();
        bad[4..8].copy_from_slice(&99u32.to_le_bytes());
        assert!(matches!(
            ContainerReader::parse(bad),
            Err(ArtifactError::UnsupportedVersion { found: 99 })
        ));

        // truncated payload
        let mut bad = good.clone();
        bad.truncate(good.len() - 50);
        assert!(matches!(
            ContainerReader::parse(bad),
            Err(ArtifactError::Truncated { .. })
        ));

        // flipped payload byte -> crc mismatch on access
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        let r = ContainerReader::parse(bad).unwrap();
        assert!(matches!(
            r.section("s"),
            Err(ArtifactError::CrcMismatch { .. })
        ));
    }

    #[test]
    fn byte_cursor_roundtrip_and_truncation() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.u32(0xDEAD_BEEF);
        w.i64(-5);
        w.f32(1.5);
        w.f64(-2.25);
        w.i8_slice(&[-1, 0, 1]);
        let buf = w.buf;
        let mut r = ByteReader::new(&buf, "t");
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.i64().unwrap(), -5);
        assert_eq!(r.f32().unwrap(), 1.5);
        assert_eq!(r.f64().unwrap(), -2.25);
        assert_eq!(r.i8_vec(3).unwrap(), vec![-1, 0, 1]);
        r.expect_end().unwrap();
        assert!(matches!(r.u8(), Err(ArtifactError::Truncated { .. })));
    }
}
