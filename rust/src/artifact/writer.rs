//! Compile-side of the artifact subsystem: serialise a planned
//! [`QModel`] into the `.dfqm` section container.
//!
//! The writer walks the plan's ops once, scattering each op's payload
//! across the typed section streams (see [`super`] for the layout):
//! small scalars and wiring into `plan`, i8 weight codes into
//! `wgrid.i8`, per-channel grids into `qparams`, folded i64 biases into
//! `bias.i64`, fixed-point requant multipliers into `mult.fix`, and f32
//! fallback tensors into `fallback.f32` (written only when fallback ops
//! exist). Streams are strictly append-only in op order, so the reader
//! replays them with plain sequential cursors — no per-op index needed.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context as _, Result};

use crate::dfq::QuantizedModel;
use crate::nn::qengine::kernels::{QConv, QConvT};
use crate::nn::qengine::ops::QLinear;
use crate::nn::qengine::plan::{PlannedOp, QModel, QOp};
use crate::nn::qengine::{Mult, PlanOpts};
use crate::nn::SiteCfg;
use crate::quant::QParams;
use crate::util::json::Json;

use crate::graph::PoolKind;

use super::format::{ByteWriter, ContainerWriter};
use super::{
    ArtifactInfo, OP_ACTF, OP_ACT_REQUANT, OP_ADDF, OP_ADD_INT,
    OP_CONCATF, OP_CONCAT_INT, OP_CONV, OP_CONVT, OP_CONVTF, OP_CONV_F32,
    OP_GAP, OP_GAPF, OP_LINEAR, OP_LINEARF, OP_POOLF, OP_POOL_INT,
    OP_POOL_RECTF, OP_POOL_RECT_INT, OP_QUANT_IN, OP_UPSAMPLE, POOL_AVG,
    POOL_MAX, SEC_BIAS, SEC_FALLBACK, SEC_META, SEC_MULT, SEC_PLAN,
    SEC_QPARAMS, SEC_WGRID,
};

/// The section streams an encode pass appends to.
struct Streams {
    plan: ByteWriter,
    wgrid: ByteWriter,
    qparams: ByteWriter,
    bias: ByteWriter,
    mult: ByteWriter,
    fallback: ByteWriter,
}

fn put_qparams(w: &mut ByteWriter, qp: &QParams) {
    w.f32(qp.scale);
    w.f32(qp.zero_point);
    w.f32(qp.n_levels);
}

fn put_site(w: &mut ByteWriter, row: &SiteCfg) {
    w.f32(row.scale);
    w.f32(row.zero_point);
    w.f32(row.n_levels);
    w.f32(row.clip_hi);
}

fn put_pool_kind(w: &mut ByteWriter, kind: PoolKind) {
    w.u8(match kind {
        PoolKind::Max => POOL_MAX,
        PoolKind::Avg => POOL_AVG,
    });
}

fn put_mult(w: &mut ByteWriter, m: &Mult) {
    match *m {
        Mult::Fixed { m, shift } => {
            w.u8(0);
            w.i32(m);
            w.u32(shift);
        }
        Mult::Float(f) => {
            w.u8(1);
            w.f64(f);
        }
    }
}

fn put_conv(s: &mut Streams, c: &QConv) {
    let w = &mut s.plan;
    w.u32(c.c_out as u32);
    w.u32(c.cig as u32);
    w.u32(c.kh as u32);
    w.u32(c.kw as u32);
    w.u32(c.stride as u32);
    w.u32(c.pad as u32);
    w.u32(c.groups as u32);
    put_qparams(w, &c.in_qp);
    match &c.epi {
        Some(e) => {
            w.u8(1);
            put_qparams(w, &e.out_qp);
            w.i32(e.zp_out);
            w.i32(e.q_lo);
            w.i32(e.q_hi);
        }
        None => w.u8(0),
    }
    s.wgrid.i8_slice(&c.w);
    for o in 0..c.c_out {
        s.qparams.f32(c.s_w[o]);
        s.qparams.i32(c.zp_w[o]);
        s.qparams.f32(c.bias_f[o]);
    }
    s.bias.i64_slice(&c.zp_corr);
    if let Some(e) = &c.epi {
        s.bias.i64_slice(&e.bias_q);
        for m in &e.mult {
            put_mult(&mut s.mult, m);
        }
    }
}

/// Transposed conv: the logical stride/pad (the zero-insertion
/// geometry), then the inner flipped-kernel stride-1 conv verbatim — the
/// reader re-derives and re-validates the `pad' = k-1-pad` relation.
fn put_convt(s: &mut Streams, c: &QConvT) {
    s.plan.u32(c.stride as u32);
    s.plan.u32(c.pad as u32);
    put_conv(s, &c.inner);
}

/// Per-axis pool window: `kind, global, kh, kw, sh, sw, ph, pw`. Global
/// pools travel in their canonical `k=(1,1) s=(1,1) p=(0,0)` form.
fn put_pool_rect(
    w: &mut ByteWriter,
    kind: PoolKind,
    k: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
    global: bool,
) {
    put_pool_kind(w, kind);
    w.u8(global as u8);
    w.u32(k.0 as u32);
    w.u32(k.1 as u32);
    w.u32(stride.0 as u32);
    w.u32(stride.1 as u32);
    w.u32(pad.0 as u32);
    w.u32(pad.1 as u32);
}

/// Square non-global pools keep the legacy single-scalar encoding, so
/// pre-v4 plans re-encode byte-identically under the v4 writer.
fn pool_is_square(
    k: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
    global: bool,
) -> bool {
    !global && k.0 == k.1 && stride.0 == stride.1 && pad.0 == pad.1
}

fn put_linear(s: &mut Streams, l: &QLinear) {
    let w = &mut s.plan;
    w.u32(l.in_dim as u32);
    w.u32(l.out_dim as u32);
    put_qparams(w, &l.in_qp);
    s.wgrid.i8_slice(&l.wt);
    for o in 0..l.out_dim {
        s.qparams.f32(l.s_w[o]);
        s.qparams.i32(l.zp_w[o]);
        s.qparams.f32(l.bias[o]);
    }
    s.bias.i64_slice(&l.zp_corr);
}

fn put_op(s: &mut Streams, p: &PlannedOp) {
    let w = &mut s.plan;
    w.u32(p.node as u32);
    w.u32(p.out as u32);
    w.u32(p.ins.len() as u32);
    for &i in &p.ins {
        w.u32(i as u32);
    }
    w.u32(p.free_after.len() as u32);
    for &f in &p.free_after {
        w.u32(f as u32);
    }
    match &p.op {
        QOp::QuantIn { qp } => {
            w.u8(OP_QUANT_IN);
            put_qparams(w, qp);
        }
        QOp::Conv(c) => {
            w.u8(OP_CONV);
            put_conv(s, c);
        }
        QOp::ConvT(c) => {
            w.u8(OP_CONVT);
            put_convt(s, c);
        }
        QOp::ConvTFp32 { w: wt, b, stride, pad } => {
            w.u8(OP_CONVTF);
            w.u32(*stride as u32);
            w.u32(*pad as u32);
            w.u32(wt.shape().len() as u32);
            for &d in wt.shape() {
                w.u64(d as u64);
            }
            w.u32(b.len() as u32);
            s.fallback.f32_slice(wt.data());
            s.fallback.f32_slice(b);
        }
        QOp::ConvFp32 { w: wt, b, stride, pad, groups } => {
            w.u8(OP_CONV_F32);
            w.u32(*stride as u32);
            w.u32(*pad as u32);
            w.u32(*groups as u32);
            w.u32(wt.shape().len() as u32);
            for &d in wt.shape() {
                w.u64(d as u64);
            }
            w.u32(b.len() as u32);
            s.fallback.f32_slice(wt.data());
            s.fallback.f32_slice(b);
        }
        QOp::Add(a) => {
            w.u8(OP_ADD_INT);
            w.i64(a.ma);
            w.i64(a.mb);
            put_qparams(w, &a.a_qp);
            put_qparams(w, &a.b_qp);
            put_qparams(w, &a.out_qp);
        }
        QOp::AddF { row } => {
            w.u8(OP_ADDF);
            put_site(w, row);
        }
        QOp::Concat(c) => {
            w.u8(OP_CONCAT_INT);
            w.u32(c.ms.len() as u32);
            for (m, qp) in c.ms.iter().zip(&c.in_qps) {
                w.i64(*m);
                put_qparams(w, qp);
            }
            put_qparams(w, &c.out_qp);
        }
        QOp::ConcatF { row } => {
            w.u8(OP_CONCATF);
            put_site(w, row);
        }
        QOp::Pool(pl) => {
            if pool_is_square(pl.k, pl.stride, pl.pad, pl.global) {
                w.u8(OP_POOL_INT);
                put_pool_kind(w, pl.kind);
                w.u32(pl.k.0 as u32);
                w.u32(pl.stride.0 as u32);
                w.u32(pl.pad.0 as u32);
            } else {
                w.u8(OP_POOL_RECT_INT);
                put_pool_rect(w, pl.kind, pl.k, pl.stride, pl.pad, pl.global);
            }
            put_qparams(w, &pl.qp);
        }
        QOp::PoolF { kind, k, stride, pad, global } => {
            if pool_is_square(*k, *stride, *pad, *global) {
                w.u8(OP_POOLF);
                put_pool_kind(w, *kind);
                w.u32(k.0 as u32);
                w.u32(stride.0 as u32);
                w.u32(pad.0 as u32);
            } else {
                w.u8(OP_POOL_RECTF);
                put_pool_rect(w, *kind, *k, *stride, *pad, *global);
            }
        }
        QOp::Act(r) => {
            w.u8(OP_ACT_REQUANT);
            w.i32(r.q_lo);
            w.i32(r.q_hi);
            put_qparams(w, &r.in_qp);
            put_qparams(w, &r.out_qp);
            put_mult(&mut s.mult, &r.m);
        }
        QOp::ActF { row } => {
            w.u8(OP_ACTF);
            put_site(w, row);
        }
        QOp::Gap { qp } => {
            w.u8(OP_GAP);
            put_qparams(w, qp);
        }
        QOp::GapF => w.u8(OP_GAPF),
        QOp::Linear(l) => {
            w.u8(OP_LINEAR);
            put_linear(s, l);
        }
        QOp::LinearF { w: wt, b } => {
            w.u8(OP_LINEARF);
            w.u32(wt.shape()[0] as u32);
            w.u32(wt.shape()[1] as u32);
            w.u32(b.len() as u32);
            s.fallback.f32_slice(wt.data());
            s.fallback.f32_slice(b);
        }
        QOp::Upsample { factor, grid } => {
            w.u8(OP_UPSAMPLE);
            w.u32(*factor as u32);
            match grid {
                Some(qp) => {
                    w.u8(1);
                    put_qparams(w, qp);
                }
                None => w.u8(0),
            }
        }
    }
}

fn meta_json(info: &ArtifactInfo) -> String {
    let mut m = BTreeMap::new();
    m.insert(
        "format".to_string(),
        Json::Str("dfq-compiled-artifact".into()),
    );
    m.insert("name".to_string(), Json::Str(info.name.clone()));
    m.insert(
        "input_shape".to_string(),
        Json::Arr(
            info.input_shape.iter().map(|&d| Json::Num(d as f64)).collect(),
        ),
    );
    m.insert(
        "num_classes".to_string(),
        Json::Num(info.num_classes as f64),
    );
    let mut plan = BTreeMap::new();
    plan.insert("ops".to_string(), Json::Num(info.ops as f64));
    plan.insert("slots".to_string(), Json::Num(info.slots as f64));
    plan.insert(
        "int_layers".to_string(),
        Json::Num(info.int_layers as f64),
    );
    plan.insert(
        "f32_layers".to_string(),
        Json::Num(info.f32_layers as f64),
    );
    plan.insert(
        "fallback_ops".to_string(),
        Json::Num(info.fallback_ops as f64),
    );
    m.insert("plan".to_string(), Json::Obj(plan));
    Json::Obj(m).to_string()
}

/// Serialise one planned model (+ its serving metadata) into the full
/// container image. Pure function of its inputs — no float math, no
/// clock, no environment — so identical plans produce identical bytes.
pub fn encode_qmodel(qm: &QModel, info: &ArtifactInfo) -> Vec<u8> {
    encode_qmodel_opts(qm, info, false)
}

/// [`encode_qmodel`] with section compression control. `compress`
/// stores the bulky `wgrid.i8` and `plan` sections as [`super::codec`]
/// frames (per-section `FLAG_COMPRESSED` in the BOM) when that actually
/// shrinks them; the small per-channel streams stay raw so mmap'd loads
/// can still view `bias.i64` in place. Equally deterministic.
pub fn encode_qmodel_opts(
    qm: &QModel,
    info: &ArtifactInfo,
    compress: bool,
) -> Vec<u8> {
    let mut s = Streams {
        plan: ByteWriter::new(),
        wgrid: ByteWriter::new(),
        qparams: ByteWriter::new(),
        bias: ByteWriter::new(),
        mult: ByteWriter::new(),
        fallback: ByteWriter::new(),
    };
    s.plan.u32(qm.slots as u32);
    s.plan.u32(qm.outputs.len() as u32);
    for &(slot, node) in &qm.outputs {
        s.plan.u32(slot as u32);
        s.plan.u32(node as u32);
    }
    s.plan.u32(qm.int_layers as u32);
    s.plan.u32(qm.f32_layers as u32);
    s.plan.u32(qm.fallbacks as u32);
    s.plan.u32(qm.ops.len() as u32);
    for p in &qm.ops {
        put_op(&mut s, p);
    }

    let mut c = ContainerWriter::new();
    c.push(SEC_META, meta_json(info).into_bytes());
    if compress {
        c.push_compressed(SEC_PLAN, s.plan.buf);
        c.push_compressed(SEC_WGRID, s.wgrid.buf);
    } else {
        c.push(SEC_PLAN, s.plan.buf);
        c.push(SEC_WGRID, s.wgrid.buf);
    }
    c.push(SEC_QPARAMS, s.qparams.buf);
    c.push(SEC_BIAS, s.bias.buf);
    c.push(SEC_MULT, s.mult.buf);
    // fallback weights are optional: omit the section entirely on a
    // fully-integer plan (the common case) — readers only ask for it
    // when they decode a fallback op
    if !s.fallback.buf.is_empty() {
        c.push(SEC_FALLBACK, s.fallback.buf);
    }
    c.finish()
}

/// Metadata for a model about to be compiled (pulled off the quantised
/// model's graph).
pub(crate) fn info_for(q: &QuantizedModel, qm: &QModel) -> ArtifactInfo {
    ArtifactInfo {
        name: q.model.name.clone(),
        input_shape: q.model.input_shape,
        num_classes: q.model.num_classes,
        ops: qm.num_ops(),
        slots: qm.slots,
        int_layers: qm.int_layers,
        f32_layers: qm.f32_layers,
        fallback_ops: qm.fallback_ops(),
        bytes: 0,
    }
}

/// Compile `q` into an execution plan (per `opts`) and write it to
/// `path` as a `.dfqm` compiled artifact. Returns the artifact metadata
/// (including the byte size written).
pub fn write_artifact(
    q: &QuantizedModel,
    opts: PlanOpts,
    path: impl AsRef<Path>,
) -> Result<ArtifactInfo> {
    write_artifact_opts(q, opts, false, path)
}

/// [`write_artifact`] with section compression control (`dfq compile
/// --compress`).
pub fn write_artifact_opts(
    q: &QuantizedModel,
    opts: PlanOpts,
    compress: bool,
    path: impl AsRef<Path>,
) -> Result<ArtifactInfo> {
    let qm = q.pack_int8_opts(opts)?;
    let mut info = info_for(q, &qm);
    let bytes = encode_qmodel_opts(&qm, &info, compress);
    info.bytes = bytes.len();
    std::fs::write(path.as_ref(), bytes).with_context(|| {
        format!("writing artifact {}", path.as_ref().display())
    })?;
    Ok(info)
}
