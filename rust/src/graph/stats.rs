//! Data-free Gaussian statistics propagation over a folded graph.
//!
//! The paper derives everything data-free from BatchNorm parameters:
//! conv pre-activations ~ N(β, γ²) per channel (§4.1.3 / §4.2.1). This
//! module propagates those Gaussians through act / add / gap nodes to
//! obtain, for **every tensor** in the folded graph:
//!
//! * the expected value `E[x]` per channel — consumed by the analytic bias
//!   correction (eq. 17), and
//! * a per-tensor activation range (β ± n·γ, n = 6; §5 experimental
//!   setup) — consumed by the activation quantiser.
//!
//! Residual inputs use the paper's §5.1.2 rule: mean and variance of a
//! sum of branches is the sum of means and variances.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use super::{ActKind, ChannelStats, Model, Op};
use crate::dfq::clipped_normal::{clipped_mean, clipped_var};

/// Per-channel Gaussian description of every tensor in the folded graph.
#[derive(Debug, Clone)]
pub struct TensorStats {
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

impl TensorStats {
    fn uniform01(ch: usize) -> TensorStats {
        // Model input: images in [0, 1]; U(0,1) has mean .5, std 1/sqrt(12).
        TensorStats {
            mean: vec![0.5; ch],
            std: vec![(1.0f32 / 12.0).sqrt(); ch],
        }
    }
}

/// Statistics for every node output (keyed by node id; `usize::MAX` is
/// unused — the input node is id 0 in every spec).
pub fn propagate(model: &Model) -> Result<HashMap<usize, TensorStats>> {
    assert!(model.folded, "stats propagation requires a folded graph");
    let mut out: HashMap<usize, TensorStats> = HashMap::new();
    for n in &model.nodes {
        let st = match &n.op {
            Op::Input => TensorStats::uniform01(model.input_shape[0]),
            Op::Conv { out_ch, .. } => {
                match model.act_stats.get(&n.id) {
                    Some(ChannelStats { mean, std }) => TensorStats {
                        mean: mean.clone(),
                        std: std.clone(),
                    },
                    // Head convs without BN: push the input Gaussian
                    // through the affine layer (independence assumption).
                    None => conv_pushforward(model, n.id, *out_ch, &out)?,
                }
            }
            Op::ConvT2d { out_ch, .. } => {
                match model.act_stats.get(&n.id) {
                    Some(ChannelStats { mean, std }) => TensorStats {
                        mean: mean.clone(),
                        std: std.clone(),
                    },
                    // BN-less decoder heads: the full-tap output position
                    // of a transposed conv sees exactly the dense-conv
                    // affine map (every k² weight once), so the conv
                    // pushforward is the conservative per-channel envelope.
                    None => conv_pushforward(model, n.id, *out_ch, &out)?,
                }
            }
            Op::Linear { out_dim, .. } => {
                linear_pushforward(model, n.id, *out_dim, &out)?
            }
            Op::Act(kind) => {
                let x = &out[&n.inputs[0]];
                let hi = match kind {
                    ActKind::Relu => f64::INFINITY,
                    ActKind::Relu6 => 6.0,
                };
                let mut mean = Vec::with_capacity(x.mean.len());
                let mut std = Vec::with_capacity(x.std.len());
                for c in 0..x.mean.len() {
                    let (mu, sg) = (x.mean[c] as f64, x.std[c] as f64);
                    mean.push(clipped_mean(mu, sg, 0.0, hi) as f32);
                    std.push(clipped_var(mu, sg, 0.0, hi).sqrt() as f32);
                }
                TensorStats { mean, std }
            }
            Op::Add => {
                let a = &out[&n.inputs[0]];
                let b = &out[&n.inputs[1]];
                TensorStats {
                    mean: a
                        .mean
                        .iter()
                        .zip(&b.mean)
                        .map(|(x, y)| x + y)
                        .collect(),
                    std: a
                        .std
                        .iter()
                        .zip(&b.std)
                        .map(|(x, y)| (x * x + y * y).sqrt())
                        .collect(),
                }
            }
            Op::Concat => {
                // channel concatenation: the output channel axis is the
                // inputs' channel axes stacked in input order
                let mut mean = Vec::new();
                let mut std = Vec::new();
                for &i in &n.inputs {
                    mean.extend_from_slice(&out[&i].mean);
                    std.extend_from_slice(&out[&i].std);
                }
                TensorStats { mean, std }
            }
            Op::Gap => {
                // Spatial averaging keeps the mean; variance shrinks but
                // gap outputs are not quantisation sites, so the exact
                // factor is irrelevant — keep it conservative.
                out[&n.inputs[0]].clone()
            }
            Op::Pool2d { .. } => {
                // max-pool shifts mass toward the channel maximum and
                // avg-pool shrinks the variance; both stay inside the
                // input's β ± n·γ envelope, and pool outputs stay on the
                // input grid (not sites) — keep the input stats
                // conservatively.
                out[&n.inputs[0]].clone()
            }
            Op::Upsample { .. } => out[&n.inputs[0]].clone(),
            Op::BatchNorm { .. } => unreachable!("folded graph"),
        };
        out.insert(n.id, st);
    }
    Ok(out)
}

/// `E[y]`, `Std[y]` for a conv without BN stats: `y = W x + b` with x
/// per-channel
/// Gaussian and channels independent.
fn conv_pushforward(
    model: &Model,
    id: usize,
    out_ch: usize,
    stats: &HashMap<usize, TensorStats>,
) -> Result<TensorStats> {
    let n = model.node(id);
    let (w_name, b_name, groups, k) = match &n.op {
        Op::Conv { w, b, groups, k, .. } => {
            (w.clone(), b.clone(), *groups, *k)
        }
        Op::ConvT2d { w, b, k, .. } => (w.clone(), b.clone(), 1, *k),
        _ => unreachable!(),
    };
    let x = stats
        .get(&n.inputs[0])
        .ok_or_else(|| anyhow!("missing input stats for node {id}"))?;
    let w = model.tensor(&w_name)?;
    let b = match &b_name {
        Some(b) => model.tensor(b)?.data().to_vec(),
        None => vec![0.0; out_ch],
    };
    let in_per_group = w.shape()[1];
    let mut mean = vec![0f32; out_ch];
    let mut var = vec![0f32; out_ch];
    let spatial = k * k;
    for o in 0..out_ch {
        let ch = w.out_channel(o);
        let mut m = b[o] as f64;
        let mut v = 0f64;
        for i in 0..in_per_group {
            // map (o, i) to the absolute input channel for grouped convs
            let ci = if groups == 1 {
                i
            } else {
                o * in_per_group + i // depthwise: in_per_group == 1
            };
            let (xm, xs) = (x.mean[ci] as f64, x.std[ci] as f64);
            for s in 0..spatial {
                let wv = ch[i * spatial + s] as f64;
                m += wv * xm;
                v += wv * wv * xs * xs;
            }
        }
        mean[o] = m as f32;
        var[o] = v as f32;
    }
    Ok(TensorStats { mean, std: var.iter().map(|v| v.sqrt()).collect() })
}

fn linear_pushforward(
    model: &Model,
    id: usize,
    out_dim: usize,
    stats: &HashMap<usize, TensorStats>,
) -> Result<TensorStats> {
    let n = model.node(id);
    let (w_name, b_name) = match &n.op {
        Op::Linear { w, b, .. } => (w.clone(), b.clone()),
        _ => unreachable!(),
    };
    let x = stats
        .get(&n.inputs[0])
        .ok_or_else(|| anyhow!("missing input stats for node {id}"))?;
    let w = model.tensor(&w_name)?;
    let b = model.tensor(&b_name)?.data();
    let in_dim = w.shape()[1];
    let mut mean = vec![0f32; out_dim];
    let mut std = vec![0f32; out_dim];
    for o in 0..out_dim {
        let row = &w.data()[o * in_dim..(o + 1) * in_dim];
        let mut m = b[o] as f64;
        let mut v = 0f64;
        for i in 0..in_dim {
            m += row[i] as f64 * x.mean[i] as f64;
            v += (row[i] as f64).powi(2) * (x.std[i] as f64).powi(2);
        }
        mean[o] = m as f32;
        std[o] = v.sqrt() as f32;
    }
    Ok(TensorStats { mean, std })
}

/// Data-free activation range for a quantisation site (paper §5):
/// per-channel β ± n·γ reduced to a tensor-wide (min, max), with the
/// minimum clipped by the activation's lower bound.
pub fn site_range(
    stats: &TensorStats,
    n_sigma: f32,
    clip: Option<(f32, f32)>,
) -> (f32, f32) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for c in 0..stats.mean.len() {
        lo = lo.min(stats.mean[c] - n_sigma * stats.std[c]);
        hi = hi.max(stats.mean[c] + n_sigma * stats.std[c]);
    }
    if let Some((a, b)) = clip {
        lo = lo.max(a);
        hi = hi.min(b);
    }
    if hi <= lo {
        hi = lo + 1e-6;
    }
    (lo, hi)
}
