//! Model graph IR — an SSA node list mirroring `python/compile/specs.py`.
//!
//! The IR is the substrate every DFQ pass operates on: nodes reference
//! named weight tensors held in [`Model::tensors`]; node ids are stable
//! across passes (BN folding removes nodes but never renumbers), so the
//! AOT executable argument order derived here matches the python side by
//! construction (validated against the artifact manifest at load time).

pub mod io;
pub mod stats;

use std::collections::{BTreeMap, HashMap};

use anyhow::{anyhow, bail, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;

/// The evaluation task of a model (drives dataset + metric selection).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    Classification,
    Segmentation,
    Detection,
}

impl Task {
    pub fn parse(s: &str) -> Result<Task> {
        Ok(match s {
            "classification" => Task::Classification,
            "segmentation" => Task::Segmentation,
            "detection" => Task::Detection,
            _ => bail!("unknown task '{s}'"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Task::Classification => "classification",
            Task::Segmentation => "segmentation",
            Task::Detection => "detection",
        }
    }
}

/// Activation kinds appearing in the zoo.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActKind {
    Relu,
    Relu6,
}

impl ActKind {
    pub fn parse(s: &str) -> Result<ActKind> {
        Ok(match s {
            "relu" => ActKind::Relu,
            "relu6" => ActKind::Relu6,
            _ => bail!("unknown activation '{s}'"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ActKind::Relu => "relu",
            ActKind::Relu6 => "relu6",
        }
    }

    /// Upper clip value (`f32::INFINITY` for plain ReLU).
    pub fn clip_hi(&self) -> f32 {
        match self {
            ActKind::Relu => f32::INFINITY,
            ActKind::Relu6 => 6.0,
        }
    }
}

/// Fan-in cap of a concat node: the integer engine packs one Q20
/// multiplier per input and the artifact codec enforces the same bound,
/// so [`Model::validate`] rejects wider merges at the source.
pub const MAX_CONCAT_INPUTS: usize = 64;

/// Plausibility cap on pool2d window size and stride (shared with the
/// integer engine packer and the artifact reader).
pub const MAX_POOL_DIM: usize = 1024;

/// Pooling kinds of [`Op::Pool2d`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

impl PoolKind {
    pub fn parse(s: &str) -> Result<PoolKind> {
        Ok(match s {
            "max" => PoolKind::Max,
            "avg" => PoolKind::Avg,
            _ => bail!("unknown pool kind '{s}'"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            PoolKind::Max => "max",
            PoolKind::Avg => "avg",
        }
    }
}

/// Graph operations. Convolution weights are OIHW; linear weights [O, I].
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    Input,
    Conv {
        w: String,
        b: Option<String>,
        in_ch: usize,
        out_ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
        groups: usize,
    },
    BatchNorm {
        ch: usize,
        gamma: String,
        beta: String,
        mean: String,
        var: String,
    },
    Act(ActKind),
    Add,
    /// Channel concatenation of ≥ 2 NCHW inputs (same N, H, W) — a
    /// quantisation site: every input is requantised onto one shared
    /// output grid (inception-style branch merges).
    Concat,
    Gap,
    /// Spatial pooling with a rectangular `(kh, kw)` window. Out-of-bounds
    /// window positions are excluded (max ignores padding; avg divides by
    /// the number of in-bounds taps), so both kinds stay on the input
    /// grid. With `global` set the window covers the full spatial extent
    /// of the input (the stored `k`/`stride`/`pad` are the canonical
    /// placeholders `(1,1)/(1,1)/(0,0)`); output is N×C×1×1.
    Pool2d {
        kind: PoolKind,
        k: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
        global: bool,
    },
    /// Transposed convolution (decoder upsampling head). Weights are
    /// `[out_ch, in_ch, k, k]` — out-channel first, like `Conv`, so
    /// per-out-channel passes (BN folding, CLE, bias correction) apply
    /// unchanged. Dense only (no groups); requires `pad < k` so the
    /// gather-form lowering (zero-insertion + flipped-kernel conv with
    /// `pad' = k - 1 - pad`) stays valid.
    ConvT2d {
        w: String,
        b: Option<String>,
        in_ch: usize,
        out_ch: usize,
        k: usize,
        stride: usize,
        pad: usize,
    },
    Linear {
        w: String,
        b: String,
        in_dim: usize,
        out_dim: usize,
    },
    Upsample {
        factor: usize,
    },
}

impl Op {
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Conv { .. } => "conv",
            Op::ConvT2d { .. } => "convT",
            Op::BatchNorm { .. } => "bn",
            Op::Act(_) => "act",
            Op::Add => "add",
            Op::Concat => "concat",
            Op::Gap => "gap",
            Op::Pool2d { .. } => "pool2d",
            Op::Linear { .. } => "linear",
            Op::Upsample { .. } => "upsample",
        }
    }

    /// Square-window pooling (the historical form): `k × k` window,
    /// uniform stride and pad on both axes.
    pub fn pool2d(kind: PoolKind, k: usize, stride: usize, pad: usize) -> Op {
        Op::Pool2d {
            kind,
            k: (k, k),
            stride: (stride, stride),
            pad: (pad, pad),
            global: false,
        }
    }

    /// Global pooling over the full spatial extent (canonical form:
    /// placeholder window `(1,1)`, stride `(1,1)`, pad `(0,0)`).
    pub fn global_pool2d(kind: PoolKind) -> Op {
        Op::Pool2d {
            kind,
            k: (1, 1),
            stride: (1, 1),
            pad: (0, 0),
            global: true,
        }
    }

    /// Is this a depthwise convolution?
    pub fn is_depthwise(&self) -> bool {
        matches!(self, Op::Conv { groups, in_ch, .. }
            if *groups > 1 && groups == in_ch)
    }
}

/// One SSA node.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    pub id: usize,
    pub inputs: Vec<usize>,
    pub op: Op,
}

/// Per-channel Gaussian statistics of a conv's pre-activation output,
/// carried from the folded BatchNorm parameters (mean = β, std = |γ|)
/// and kept up to date by every DFQ pass.
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelStats {
    pub mean: Vec<f32>,
    pub std: Vec<f32>,
}

/// A model: graph + named weight tensors + metadata.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub task: Task,
    pub input_shape: [usize; 3],
    pub num_classes: usize,
    pub nodes: Vec<Node>,
    pub outputs: Vec<usize>,
    pub tensors: BTreeMap<String, Tensor>,
    pub meta: BTreeMap<String, Json>,
    /// conv node id -> pre-activation stats (populated by BN folding).
    pub act_stats: HashMap<usize, ChannelStats>,
    /// True once BatchNorm has been folded away.
    pub folded: bool,
}

impl Model {
    pub fn node(&self, id: usize) -> &Node {
        self.nodes.iter().find(|n| n.id == id).expect("node id")
    }

    pub fn node_mut(&mut self, id: usize) -> &mut Node {
        self.nodes.iter_mut().find(|n| n.id == id).expect("node id")
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow!("missing tensor '{name}'"))
    }

    pub fn tensor_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.tensors
            .get_mut(name)
            .ok_or_else(|| anyhow!("missing tensor '{name}'"))
    }

    /// Nodes consuming the output of `id`, in node order.
    pub fn consumers(&self, id: usize) -> Vec<&Node> {
        self.nodes.iter().filter(|n| n.inputs.contains(&id)).collect()
    }

    /// All conv/convT/linear nodes in order (the quantizable layers).
    pub fn layers(&self) -> Vec<&Node> {
        self.nodes
            .iter()
            .filter(|n| {
                matches!(
                    n.op,
                    Op::Conv { .. } | Op::ConvT2d { .. } | Op::Linear { .. }
                )
            })
            .collect()
    }

    /// Weight-argument order of the AOT executable (DESIGN.md §3):
    /// `[w, b]` per conv/linear in node order. Requires a folded model.
    pub fn weight_args(&self) -> Vec<String> {
        assert!(self.folded, "weight_args requires a folded model");
        let mut out = Vec::new();
        for n in &self.nodes {
            match &n.op {
                Op::Conv { w, b, .. } => {
                    out.push(w.clone());
                    out.push(b.clone().expect("folded conv has bias"));
                }
                Op::ConvT2d { w, b, .. } => {
                    out.push(w.clone());
                    out.push(b.clone().expect("folded convT has bias"));
                }
                Op::Linear { w, b, .. } => {
                    out.push(w.clone());
                    out.push(b.clone());
                }
                _ => {}
            }
        }
        out
    }

    /// Activation quantisation sites: index 0 = model input, then every
    /// act/add/concat node in node order (folded graph).
    pub fn act_sites(&self) -> Vec<Site> {
        assert!(self.folded, "act_sites requires a folded model");
        let mut sites = vec![Site::Input];
        for n in &self.nodes {
            match n.op {
                Op::Act(kind) => sites.push(Site::Act { node: n.id, kind }),
                Op::Add => sites.push(Site::Add { node: n.id }),
                Op::Concat => sites.push(Site::Concat { node: n.id }),
                _ => {}
            }
        }
        sites
    }

    /// Total number of weight parameters.
    pub fn param_count(&self) -> usize {
        self.tensors.values().map(|t| t.len()).sum()
    }

    /// Basic structural validation (shapes consistent with ops).
    pub fn validate(&self) -> Result<()> {
        for n in &self.nodes {
            match &n.op {
                Op::Conv { w, b, out_ch, in_ch, k, groups, .. } => {
                    let wt = self.tensor(w)?;
                    let want =
                        [*out_ch, in_ch / groups, *k, *k];
                    if wt.shape() != want {
                        bail!("node {}: weight {:?} != {:?}", n.id,
                              wt.shape(), want);
                    }
                    if let Some(b) = b {
                        if self.tensor(b)?.shape() != [*out_ch] {
                            bail!("node {}: bad bias shape", n.id);
                        }
                    }
                }
                Op::ConvT2d { w, b, out_ch, in_ch, k, stride, pad } => {
                    let wt = self.tensor(w)?;
                    let want = [*out_ch, *in_ch, *k, *k];
                    if wt.shape() != want {
                        bail!("node {}: convT weight {:?} != {:?}", n.id,
                              wt.shape(), want);
                    }
                    if let Some(b) = b {
                        if self.tensor(b)?.shape() != [*out_ch] {
                            bail!("node {}: bad convT bias shape", n.id);
                        }
                    }
                    if *k == 0 || *stride == 0 {
                        bail!("node {}: convT with zero k/stride", n.id);
                    }
                    if *pad >= *k {
                        // the gather-form lowering needs pad' = k-1-pad >= 0
                        bail!(
                            "node {}: convT pad {pad} >= kernel {k}",
                            n.id
                        );
                    }
                }
                Op::Linear { w, b, in_dim, out_dim } => {
                    if self.tensor(w)?.shape() != [*out_dim, *in_dim] {
                        bail!("node {}: bad linear weight", n.id);
                    }
                    if self.tensor(b)?.shape() != [*out_dim] {
                        bail!("node {}: bad linear bias", n.id);
                    }
                }
                Op::BatchNorm { ch, gamma, beta, mean, var } => {
                    for t in [gamma, beta, mean, var] {
                        if self.tensor(t)?.shape() != [*ch] {
                            bail!("node {}: bad bn param {t}", n.id);
                        }
                    }
                }
                Op::Concat => {
                    if !(2..=MAX_CONCAT_INPUTS).contains(&n.inputs.len()) {
                        bail!(
                            "node {}: concat needs 2..={MAX_CONCAT_INPUTS} \
                             inputs, has {}",
                            n.id,
                            n.inputs.len()
                        );
                    }
                }
                Op::Pool2d { k, stride, pad, global, .. } => {
                    if *global && (*k != (1, 1) || *stride != (1, 1)
                        || *pad != (0, 0))
                    {
                        bail!(
                            "node {}: global pool2d must use the canonical \
                             k=(1,1)/stride=(1,1)/pad=(0,0) placeholders",
                            n.id
                        );
                    }
                    for ((kd, sd), pd) in [(k.0, stride.0), (k.1, stride.1)]
                        .into_iter()
                        .zip([pad.0, pad.1])
                    {
                        if kd == 0 || sd == 0 {
                            bail!("node {}: pool2d with zero k/stride", n.id);
                        }
                        if kd > MAX_POOL_DIM || sd > MAX_POOL_DIM {
                            bail!(
                                "node {}: pool2d window/stride beyond \
                                 {MAX_POOL_DIM}",
                                n.id
                            );
                        }
                        if pd >= kd {
                            // a window fully inside the padding would have
                            // no valid taps (avg would divide by zero) —
                            // enforced per axis so rectangular windows
                            // cannot smuggle an empty window along the
                            // short axis
                            bail!(
                                "node {}: pool2d pad {pd} >= window {kd}",
                                n.id
                            );
                        }
                    }
                }
                _ => {}
            }
            for &i in &n.inputs {
                if !self.nodes.iter().any(|m| m.id == i) {
                    bail!("node {}: dangling input {i}", n.id);
                }
            }
        }
        for &o in &self.outputs {
            if !self.nodes.iter().any(|m| m.id == o) {
                bail!("dangling output {o}");
            }
        }
        Ok(())
    }
}

/// An activation fake-quantisation site in the executable contract.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Site {
    Input,
    Act { node: usize, kind: ActKind },
    Add { node: usize },
    Concat { node: usize },
}

impl Site {
    pub fn node_id(&self) -> Option<usize> {
        match self {
            Site::Input => None,
            Site::Act { node, .. }
            | Site::Add { node }
            | Site::Concat { node } => Some(*node),
        }
    }
}
