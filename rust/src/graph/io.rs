//! `.dfqm` model and `.dfqd` dataset container IO.
//!
//! Format (little-endian, see python/compile/dfqm.py — the writer):
//! magic(4) | version u32 | hdr_len u64 | JSON header | 64-byte-aligned
//! raw blobs at header-recorded offsets relative to the blob base.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{ActKind, Model, Node, Op, PoolKind, Task};
use crate::tensor::Tensor;
use crate::util::json::Json;

const ALIGN: usize = 64;

fn pad(n: usize) -> usize {
    (ALIGN - n % ALIGN) % ALIGN
}

/// Raw parsed container.
pub struct Container {
    pub magic: [u8; 4],
    pub header: Json,
    data: Vec<u8>,
    blob_base: usize,
}

impl Container {
    pub fn open(path: &Path) -> Result<Container> {
        let data = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        if data.len() < 16 {
            bail!("{}: truncated container", path.display());
        }
        let magic: [u8; 4] = data[0..4].try_into().unwrap();
        if &magic != b"DFQM" && &magic != b"DFQD" {
            bail!("{}: bad magic {:?}", path.display(), magic);
        }
        let version = u32::from_le_bytes(data[4..8].try_into().unwrap());
        if version != 1 {
            bail!("unsupported container version {version}");
        }
        let hdr_len =
            u64::from_le_bytes(data[8..16].try_into().unwrap()) as usize;
        let header = Json::parse(
            std::str::from_utf8(&data[16..16 + hdr_len])
                .context("header not UTF-8")?,
        )?;
        let blob_base = 16 + hdr_len + pad(16 + hdr_len);
        Ok(Container { magic, header, data, blob_base })
    }

    /// Read one f32 array by table entry.
    pub fn f32_array(&self, meta: &Json) -> Result<(Vec<usize>, Vec<f32>)> {
        let shape = meta.req("shape")?.as_shape()?;
        let dtype = meta.req("dtype")?.as_str()?;
        if dtype != "f32" {
            bail!("expected f32 array, got {dtype}");
        }
        let off = self.blob_base + meta.req("offset")?.as_usize()?;
        let count: usize = shape.iter().product::<usize>().max(1);
        let bytes = &self.data[off..off + 4 * count];
        let mut out = Vec::with_capacity(count);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok((shape, out))
    }

    /// Read one i32 array by table entry.
    pub fn i32_array(&self, meta: &Json) -> Result<(Vec<usize>, Vec<i32>)> {
        let shape = meta.req("shape")?.as_shape()?;
        let dtype = meta.req("dtype")?.as_str()?;
        if dtype != "i32" {
            bail!("expected i32 array, got {dtype}");
        }
        let off = self.blob_base + meta.req("offset")?.as_usize()?;
        let count: usize = shape.iter().product::<usize>().max(1);
        let bytes = &self.data[off..off + 4 * count];
        let mut out = Vec::with_capacity(count);
        for c in bytes.chunks_exact(4) {
            out.push(i32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok((shape, out))
    }
}

fn parse_node(j: &Json) -> Result<Node> {
    let id = j.req("id")?.as_usize()?;
    let inputs: Vec<usize> = j
        .req("inputs")?
        .as_arr()?
        .iter()
        .map(|v| v.as_usize())
        .collect::<Result<_>>()?;
    let op = match j.req("op")?.as_str()? {
        "input" => Op::Input,
        "conv" => Op::Conv {
            w: j.req("w")?.as_str()?.to_string(),
            b: match j.req("b")? {
                Json::Null => None,
                v => Some(v.as_str()?.to_string()),
            },
            in_ch: j.req("in_ch")?.as_usize()?,
            out_ch: j.req("out_ch")?.as_usize()?,
            k: j.req("k")?.as_usize()?,
            stride: j.req("stride")?.as_usize()?,
            pad: j.req("pad")?.as_usize()?,
            groups: j.req("groups")?.as_usize()?,
        },
        "bn" => Op::BatchNorm {
            ch: j.req("ch")?.as_usize()?,
            gamma: j.req("gamma")?.as_str()?.to_string(),
            beta: j.req("beta")?.as_str()?.to_string(),
            mean: j.req("mean")?.as_str()?.to_string(),
            var: j.req("var")?.as_str()?.to_string(),
        },
        "act" => Op::Act(ActKind::parse(j.req("kind")?.as_str()?)?),
        "add" => Op::Add,
        "concat" => Op::Concat,
        "gap" => Op::Gap,
        "pool2d" => {
            let kind = PoolKind::parse(j.req("kind")?.as_str()?)?;
            if j.get("kh").is_some() {
                // rectangular / global form (container additions for the
                // segmentation/detection heads); legacy square readers
                // never see these keys because the writer keeps emitting
                // k/stride/pad for square non-global pools
                Op::Pool2d {
                    kind,
                    k: (j.req("kh")?.as_usize()?, j.req("kw")?.as_usize()?),
                    stride: (
                        j.req("sh")?.as_usize()?,
                        j.req("sw")?.as_usize()?,
                    ),
                    pad: (j.req("ph")?.as_usize()?, j.req("pw")?.as_usize()?),
                    global: matches!(j.get("global"), Some(Json::Bool(true))),
                }
            } else {
                let k = j.req("k")?.as_usize()?;
                let stride = j.req("stride")?.as_usize()?;
                let pad = j.req("pad")?.as_usize()?;
                Op::pool2d(kind, k, stride, pad)
            }
        }
        "convT" => Op::ConvT2d {
            w: j.req("w")?.as_str()?.to_string(),
            b: match j.req("b")? {
                Json::Null => None,
                v => Some(v.as_str()?.to_string()),
            },
            in_ch: j.req("in_ch")?.as_usize()?,
            out_ch: j.req("out_ch")?.as_usize()?,
            k: j.req("k")?.as_usize()?,
            stride: j.req("stride")?.as_usize()?,
            pad: j.req("pad")?.as_usize()?,
        },
        "linear" => Op::Linear {
            w: j.req("w")?.as_str()?.to_string(),
            b: j.req("b")?.as_str()?.to_string(),
            in_dim: j.req("in_dim")?.as_usize()?,
            out_dim: j.req("out_dim")?.as_usize()?,
        },
        "upsample" => Op::Upsample { factor: j.req("factor")?.as_usize()? },
        other => bail!("unknown op '{other}'"),
    };
    Ok(Node { id, inputs, op })
}

fn node_to_json(n: &Node) -> Json {
    let mut m = BTreeMap::new();
    m.insert("id".into(), Json::Num(n.id as f64));
    m.insert(
        "inputs".into(),
        Json::Arr(n.inputs.iter().map(|&i| Json::Num(i as f64)).collect()),
    );
    let s = |v: &str| Json::Str(v.to_string());
    let num = |v: usize| Json::Num(v as f64);
    match &n.op {
        Op::Input => {
            m.insert("op".into(), s("input"));
        }
        Op::Conv { w, b, in_ch, out_ch, k, stride, pad, groups } => {
            m.insert("op".into(), s("conv"));
            m.insert("w".into(), s(w));
            m.insert(
                "b".into(),
                b.as_ref().map(|x| s(x)).unwrap_or(Json::Null),
            );
            m.insert("in_ch".into(), num(*in_ch));
            m.insert("out_ch".into(), num(*out_ch));
            m.insert("k".into(), num(*k));
            m.insert("stride".into(), num(*stride));
            m.insert("pad".into(), num(*pad));
            m.insert("groups".into(), num(*groups));
        }
        Op::BatchNorm { ch, gamma, beta, mean, var } => {
            m.insert("op".into(), s("bn"));
            m.insert("ch".into(), num(*ch));
            m.insert("gamma".into(), s(gamma));
            m.insert("beta".into(), s(beta));
            m.insert("mean".into(), s(mean));
            m.insert("var".into(), s(var));
        }
        Op::Act(kind) => {
            m.insert("op".into(), s("act"));
            m.insert("kind".into(), s(kind.as_str()));
        }
        Op::Add => {
            m.insert("op".into(), s("add"));
        }
        Op::Concat => {
            m.insert("op".into(), s("concat"));
        }
        Op::Gap => {
            m.insert("op".into(), s("gap"));
        }
        Op::Pool2d { kind, k, stride, pad, global } => {
            m.insert("op".into(), s("pool2d"));
            m.insert("kind".into(), s(kind.as_str()));
            if !*global && k.0 == k.1 && stride.0 == stride.1 && pad.0 == pad.1
            {
                // legacy square encoding — containers with only square
                // pools stay readable by pre-rectangular loaders
                m.insert("k".into(), num(k.0));
                m.insert("stride".into(), num(stride.0));
                m.insert("pad".into(), num(pad.0));
            } else {
                m.insert("kh".into(), num(k.0));
                m.insert("kw".into(), num(k.1));
                m.insert("sh".into(), num(stride.0));
                m.insert("sw".into(), num(stride.1));
                m.insert("ph".into(), num(pad.0));
                m.insert("pw".into(), num(pad.1));
                m.insert("global".into(), Json::Bool(*global));
            }
        }
        Op::ConvT2d { w, b, in_ch, out_ch, k, stride, pad } => {
            m.insert("op".into(), s("convT"));
            m.insert("w".into(), s(w));
            m.insert(
                "b".into(),
                b.as_ref().map(|x| s(x)).unwrap_or(Json::Null),
            );
            m.insert("in_ch".into(), num(*in_ch));
            m.insert("out_ch".into(), num(*out_ch));
            m.insert("k".into(), num(*k));
            m.insert("stride".into(), num(*stride));
            m.insert("pad".into(), num(*pad));
        }
        Op::Linear { w, b, in_dim, out_dim } => {
            m.insert("op".into(), s("linear"));
            m.insert("w".into(), s(w));
            m.insert("b".into(), s(b));
            m.insert("in_dim".into(), num(*in_dim));
            m.insert("out_dim".into(), num(*out_dim));
        }
        Op::Upsample { factor } => {
            m.insert("op".into(), s("upsample"));
            m.insert("factor".into(), num(*factor));
        }
    }
    Json::Obj(m)
}

impl Model {
    /// Load a model from a `.dfqm` container.
    pub fn load(path: impl AsRef<Path>) -> Result<Model> {
        let c = Container::open(path.as_ref())?;
        if &c.magic != b"DFQM" {
            bail!("not a model container");
        }
        let h = &c.header;
        let nodes: Vec<Node> = h
            .req("nodes")?
            .as_arr()?
            .iter()
            .map(parse_node)
            .collect::<Result<_>>()?;
        let outputs: Vec<usize> = h
            .req("outputs")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_>>()?;
        let ishape = h.req("input_shape")?.as_shape()?;
        if ishape.len() != 3 {
            bail!("input_shape must be [C, H, W]");
        }
        let mut tensors = BTreeMap::new();
        for (name, meta) in h.req("tensors")?.as_obj()? {
            let (shape, data) = c.f32_array(meta)?;
            tensors.insert(name.clone(), Tensor::new(&shape, data));
        }
        let meta = match h.get("meta") {
            Some(Json::Obj(m)) => m.clone(),
            _ => BTreeMap::new(),
        };
        // A folded model has no bn nodes; re-derive stats saved in meta.
        let folded = !nodes.iter().any(|n| matches!(n.op, Op::BatchNorm { .. }));
        let mut act_stats = HashMap::new();
        if let Some(Json::Obj(st)) = meta.get("act_stats") {
            for (k, v) in st {
                let id: usize = k.parse().context("act_stats key")?;
                let mean = v.req("mean")?.as_arr()?.iter()
                    .map(|x| x.as_f64().map(|f| f as f32))
                    .collect::<Result<Vec<_>>>()?;
                let std = v.req("std")?.as_arr()?.iter()
                    .map(|x| x.as_f64().map(|f| f as f32))
                    .collect::<Result<Vec<_>>>()?;
                act_stats.insert(id, super::ChannelStats { mean, std });
            }
        }
        let model = Model {
            name: h.req("name")?.as_str()?.to_string(),
            task: Task::parse(h.req("task")?.as_str()?)?,
            input_shape: [ishape[0], ishape[1], ishape[2]],
            num_classes: h.req("num_classes")?.as_usize()?,
            nodes,
            outputs,
            tensors,
            meta,
            act_stats,
            folded,
        };
        model.validate()?;
        Ok(model)
    }

    /// Save the model back to a `.dfqm` container (graph as-is; folded
    /// models round-trip too — the loader re-derives `folded` from the
    /// absence of bn nodes via [`Model::load`] + meta flag).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut header = BTreeMap::new();
        header.insert("kind".into(), Json::Str("model".into()));
        header.insert("name".into(), Json::Str(self.name.clone()));
        header.insert("task".into(), Json::Str(self.task.as_str().into()));
        header.insert(
            "input_shape".into(),
            Json::Arr(
                self.input_shape
                    .iter()
                    .map(|&d| Json::Num(d as f64))
                    .collect(),
            ),
        );
        header.insert(
            "num_classes".into(),
            Json::Num(self.num_classes as f64),
        );
        header.insert(
            "nodes".into(),
            Json::Arr(self.nodes.iter().map(node_to_json).collect()),
        );
        header.insert(
            "outputs".into(),
            Json::Arr(
                self.outputs.iter().map(|&o| Json::Num(o as f64)).collect(),
            ),
        );
        let mut meta = self.meta.clone();
        if !self.act_stats.is_empty() {
            let mut st = BTreeMap::new();
            for (id, cs) in &self.act_stats {
                let mut o = BTreeMap::new();
                o.insert(
                    "mean".into(),
                    Json::Arr(cs.mean.iter()
                        .map(|&x| Json::Num(x as f64)).collect()),
                );
                o.insert(
                    "std".into(),
                    Json::Arr(cs.std.iter()
                        .map(|&x| Json::Num(x as f64)).collect()),
                );
                st.insert(id.to_string(), Json::Obj(o));
            }
            meta.insert("act_stats".into(), Json::Obj(st));
        }
        if !meta.is_empty() {
            header.insert("meta".into(), Json::Obj(meta));
        }

        let mut table = BTreeMap::new();
        let mut blobs: Vec<&[f32]> = Vec::new();
        let mut off = 0usize;
        for (name, t) in &self.tensors {
            let mut m = BTreeMap::new();
            m.insert(
                "shape".into(),
                Json::Arr(
                    t.shape().iter().map(|&d| Json::Num(d as f64)).collect(),
                ),
            );
            m.insert("dtype".into(), Json::Str("f32".into()));
            m.insert("offset".into(), Json::Num(off as f64));
            table.insert(name.clone(), Json::Obj(m));
            let bytes = t.len() * 4;
            off += bytes + pad(bytes);
            blobs.push(t.data());
        }
        header.insert("tensors".into(), Json::Obj(table));

        let hdr = Json::Obj(header).to_string().into_bytes();
        let mut out = Vec::new();
        out.extend_from_slice(b"DFQM");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&(hdr.len() as u64).to_le_bytes());
        out.extend_from_slice(&hdr);
        out.resize(out.len() + pad(16 + hdr.len()), 0);
        for blob in blobs {
            let start = out.len();
            for &x in blob {
                out.extend_from_slice(&x.to_le_bytes());
            }
            out.resize(out.len() + pad(out.len() - start), 0);
        }
        std::fs::write(path.as_ref(), out)
            .with_context(|| format!("writing {}", path.as_ref().display()))
    }
}

/// A loaded evaluation dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub task: Task,
    /// Images (N, C, H, W).
    pub x: Tensor,
    /// Classification / segmentation labels (flattened).
    pub labels: Vec<i32>,
    pub label_shape: Vec<usize>,
    /// Detection ground truth (N, MAX_OBJ, 5): [cls, x1, y1, x2, y2].
    pub boxes: Option<Tensor>,
}

impl Dataset {
    pub fn load(path: impl AsRef<Path>) -> Result<Dataset> {
        let c = Container::open(path.as_ref())?;
        if &c.magic != b"DFQD" {
            bail!("not a dataset container");
        }
        let h = &c.header;
        let arrays = h.req("arrays")?.as_obj()?;
        let (xs, xd) = c.f32_array(
            arrays.get("x").context("dataset missing 'x'")?,
        )?;
        let task = Task::parse(h.req("task")?.as_str()?)?;
        let (labels, label_shape, boxes) = if task == Task::Detection {
            let (bs, bd) = c.f32_array(
                arrays.get("boxes").context("missing 'boxes'")?,
            )?;
            (Vec::new(), Vec::new(), Some(Tensor::new(&bs, bd)))
        } else {
            let (ls, ld) = c.i32_array(
                arrays.get("y").context("missing 'y'")?,
            )?;
            (ld, ls, None)
        };
        Ok(Dataset {
            name: h.req("name")?.as_str()?.to_string(),
            task,
            x: Tensor::new(&xs, xd),
            labels,
            label_shape,
            boxes,
        })
    }

    pub fn len(&self) -> usize {
        self.x.dim(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy a [lo, hi) batch of images.
    pub fn batch(&self, lo: usize, hi: usize) -> Tensor {
        let per: usize = self.x.shape()[1..].iter().product();
        let mut shape = self.x.shape().to_vec();
        shape[0] = hi - lo;
        Tensor::new(&shape, self.x.data()[lo * per..hi * per].to_vec())
    }
}
