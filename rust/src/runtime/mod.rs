//! PJRT runtime — loads the AOT-lowered HLO artifacts and executes them
//! on the CPU PJRT client via the `xla` crate.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): jax
//! ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md). One compiled executable per
//! (architecture, batch size); weights are passed as runtime arguments —
//! already fake-quantised by the DFQ pipeline — so a single executable
//! serves FP32 eval and every quantised configuration.
//!
//! The `xla` bindings are not part of the offline crate set, so the real
//! implementation is gated behind the `pjrt` cargo feature (which
//! additionally requires adding the `xla = "0.5"` dependency by hand).
//! The default build exports API-compatible stubs whose constructors
//! return a descriptive error: every artifact-dependent caller already
//! skips gracefully when `Manifest::load` or `Runtime::cpu` fails, and
//! the pure-Rust engines ([`crate::nn`] and [`crate::nn::qengine`])
//! carry the full test/serve load without PJRT.

pub mod manifest;

pub use manifest::{ArchEntry, Manifest};

/// Executable metadata (argument contract).
#[derive(Debug, Clone, Copy)]
pub struct ExecMeta {
    pub batch: usize,
    pub input_shape: [usize; 3],
    pub num_weights: usize,
    pub num_sites: usize,
    pub num_outputs: usize,
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::path::Path;

    use anyhow::{bail, Context, Result};

    use super::{ExecMeta, Manifest};
    use crate::graph::Model;
    use crate::nn::QuantCfg;
    use crate::tensor::Tensor;

    /// Shared PJRT client (CPU).
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            Ok(Runtime { client: xla::PjRtClient::cpu()? })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile one HLO text artifact.
        pub fn load(&self, hlo_path: &Path, meta: ExecMeta) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path.to_str().context("non-UTF-8 artifact path")?,
            )
            .with_context(|| format!("parsing {}", hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", hlo_path.display()))?;
            Ok(Executable { exe, meta })
        }

        /// Load the quant-sim executable of `arch` at `batch` from the
        /// manifest, validating the weight-argument contract against `model`.
        pub fn load_model_exec(
            &self,
            manifest: &Manifest,
            arch: &str,
            batch: usize,
            model: &Model,
        ) -> Result<Executable> {
            let entry = manifest.arch(arch)?;
            let hlo = entry.hlo.get(&batch).ok_or_else(|| {
                anyhow::anyhow!("no batch-{batch} HLO for {arch}")
            })?;
            // contract validation: Rust-side folded order == python manifest
            let rust_order = model.weight_args();
            if rust_order.len() != entry.weight_args.len() {
                bail!(
                    "{arch}: weight arg count mismatch rust={} manifest={}",
                    rust_order.len(),
                    entry.weight_args.len()
                );
            }
            for (r, (name, _, shape)) in
                rust_order.iter().zip(&entry.weight_args)
            {
                if r != name {
                    bail!("{arch}: weight order mismatch: rust {r} vs {name}");
                }
                let t = model.tensor(name)?;
                if t.shape() != &shape[..] {
                    bail!(
                        "{arch}: {name} shape {:?} vs manifest {:?}",
                        t.shape(),
                        shape
                    );
                }
            }
            let sites = model.act_sites().len();
            if sites != entry.num_sites {
                bail!(
                    "{arch}: site count mismatch {sites} vs {}",
                    entry.num_sites
                );
            }
            self.load(
                &manifest.path(hlo),
                ExecMeta {
                    batch,
                    input_shape: model.input_shape,
                    num_weights: rust_order.len(),
                    num_sites: sites,
                    num_outputs: entry.num_outputs,
                },
            )
        }
    }

    /// A compiled quant-sim executable.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub meta: ExecMeta,
    }

    fn literal_from(t: &Tensor) -> Result<xla::Literal> {
        let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
    }

    impl Executable {
        /// Build the weight-literal set for a model once; reuse across calls.
        pub fn bind_weights(&self, model: &Model) -> Result<BoundWeights> {
            let mut lits = Vec::with_capacity(self.meta.num_weights);
            for name in model.weight_args() {
                lits.push(literal_from(model.tensor(&name)?)?);
            }
            Ok(BoundWeights { lits })
        }

        /// Execute with arbitrary tensor arguments (no contract checks) —
        /// used for standalone kernel artifacts and microbenches.
        pub fn run_raw(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
            let lits = args
                .iter()
                .map(|t| literal_from(t))
                .collect::<Result<Vec<_>>>()?;
            let refs: Vec<&xla::Literal> = lits.iter().collect();
            let bufs = self.exe.execute::<&xla::Literal>(&refs)?;
            let result = bufs[0][0].to_literal_sync()?;
            let outs = result.to_tuple()?;
            let mut tensors = Vec::with_capacity(outs.len());
            for lit in outs {
                let shape = lit.array_shape()?;
                let dims: Vec<usize> =
                    shape.dims().iter().map(|&d| d as usize).collect();
                tensors.push(Tensor::new(&dims, lit.to_vec()?));
            }
            Ok(tensors)
        }

        /// Execute on one batch. `x` must be (batch, C, H, W); `cfg` rows
        /// must match the executable's site count.
        pub fn run(
            &self,
            x: &Tensor,
            weights: &BoundWeights,
            cfg: &QuantCfg,
        ) -> Result<Vec<Tensor>> {
            if x.shape()[0] != self.meta.batch {
                bail!(
                    "batch mismatch: got {}, executable expects {}",
                    x.shape()[0],
                    self.meta.batch
                );
            }
            if cfg.rows.len() != self.meta.num_sites {
                bail!(
                    "QuantCfg rows {} != sites {}",
                    cfg.rows.len(),
                    self.meta.num_sites
                );
            }
            let x_lit = literal_from(x)?;
            let qcfg = Tensor::new(&[self.meta.num_sites, 4], cfg.to_flat());
            let q_lit = literal_from(&qcfg)?;

            let mut borrowed: Vec<&xla::Literal> =
                Vec::with_capacity(2 + weights.lits.len());
            borrowed.push(&x_lit);
            for l in &weights.lits {
                borrowed.push(l);
            }
            borrowed.push(&q_lit);

            let bufs = self.exe.execute::<&xla::Literal>(&borrowed)?;
            let result = bufs[0][0].to_literal_sync()?;
            let outs = result.to_tuple()?;
            let mut tensors = Vec::with_capacity(outs.len());
            for lit in outs {
                let shape = lit.array_shape()?;
                let dims: Vec<usize> =
                    shape.dims().iter().map(|&d| d as usize).collect();
                let data: Vec<f32> = lit.to_vec()?;
                tensors.push(Tensor::new(&dims, data));
            }
            Ok(tensors)
        }
    }

    /// Weight literals bound to an executable's argument order.
    pub struct BoundWeights {
        lits: Vec<xla::Literal>,
    }

    impl BoundWeights {
        pub fn len(&self) -> usize {
            self.lits.len()
        }

        pub fn is_empty(&self) -> bool {
            self.lits.is_empty()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{BoundWeights, Executable, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::{ExecMeta, Manifest};
    use crate::graph::Model;
    use crate::nn::QuantCfg;
    use crate::tensor::Tensor;

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: built without the `pjrt` cargo feature \
         (use the pure-Rust engine / qengine backends)";

    /// Stub PJRT client; construction always fails with a clear message.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            bail!("{UNAVAILABLE}")
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load(
            &self,
            _hlo_path: &Path,
            _meta: ExecMeta,
        ) -> Result<Executable> {
            bail!("{UNAVAILABLE}")
        }

        pub fn load_model_exec(
            &self,
            _manifest: &Manifest,
            _arch: &str,
            _batch: usize,
            _model: &Model,
        ) -> Result<Executable> {
            bail!("{UNAVAILABLE}")
        }
    }

    /// Stub executable (never constructible; methods satisfy callers).
    pub struct Executable {
        pub meta: ExecMeta,
    }

    impl Executable {
        pub fn bind_weights(&self, _model: &Model) -> Result<BoundWeights> {
            bail!("{UNAVAILABLE}")
        }

        pub fn run_raw(&self, _args: &[&Tensor]) -> Result<Vec<Tensor>> {
            bail!("{UNAVAILABLE}")
        }

        pub fn run(
            &self,
            _x: &Tensor,
            _weights: &BoundWeights,
            _cfg: &QuantCfg,
        ) -> Result<Vec<Tensor>> {
            bail!("{UNAVAILABLE}")
        }
    }

    /// Stub weight bindings.
    pub struct BoundWeights {}

    impl BoundWeights {
        pub fn len(&self) -> usize {
            0
        }

        pub fn is_empty(&self) -> bool {
            true
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::{BoundWeights, Executable, Runtime};
