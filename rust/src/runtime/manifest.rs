//! `artifacts/manifest.json` — the contract between the python build
//! path and the Rust runtime. Written by `python/compile/aot.py`; the
//! loader validates the Rust-side derived executable argument order
//! against it so python/Rust graph folding can never drift silently.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One architecture entry.
#[derive(Debug, Clone)]
pub struct ArchEntry {
    pub task: String,
    /// Corrupted ("pretrained original") model container.
    pub model: String,
    /// Clean (pre-corruption) model container.
    pub model_clean: String,
    /// batch size -> HLO text file.
    pub hlo: BTreeMap<usize, String>,
    /// Executable weight-argument order: (tensor name, kind, shape).
    pub weight_args: Vec<(String, String, Vec<usize>)>,
    /// Number of activation quantisation sites (incl. the input site).
    pub num_sites: usize,
    pub num_outputs: usize,
}

/// The parsed artifact index.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub archs: BTreeMap<String, ArchEntry>,
    /// task -> split -> dataset file.
    pub datasets: BTreeMap<String, BTreeMap<String, String>>,
    pub kernel_bench: Option<(String, usize, usize, usize)>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let j = Json::parse(&text)?;
        let mut archs = BTreeMap::new();
        for (name, e) in j.req("archs")?.as_obj()? {
            let mut hlo = BTreeMap::new();
            for (b, p) in e.req("hlo")?.as_obj()? {
                hlo.insert(b.parse::<usize>()?, p.as_str()?.to_string());
            }
            let weight_args = e
                .req("weight_args")?
                .as_arr()?
                .iter()
                .map(|w| -> Result<_> {
                    let w = w.as_arr()?;
                    Ok((
                        w[0].as_str()?.to_string(),
                        w[1].as_str()?.to_string(),
                        w[2].as_shape()?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            archs.insert(
                name.clone(),
                ArchEntry {
                    task: e.req("task")?.as_str()?.to_string(),
                    model: e.req("model")?.as_str()?.to_string(),
                    model_clean: e.req("model_clean")?.as_str()?.to_string(),
                    hlo,
                    weight_args,
                    num_sites: e.req("sites")?.as_arr()?.len(),
                    num_outputs: e.req("num_outputs")?.as_usize()?,
                },
            );
        }
        let mut datasets = BTreeMap::new();
        for (task, splits) in j.req("datasets")?.as_obj()? {
            let mut m = BTreeMap::new();
            for (split, p) in splits.as_obj()? {
                m.insert(split.clone(), p.as_str()?.to_string());
            }
            datasets.insert(task.clone(), m);
        }
        let kernel_bench = match j.get("kernel_bench") {
            Some(k) => Some((
                k.req("hlo")?.as_str()?.to_string(),
                k.req("m")?.as_usize()?,
                k.req("k")?.as_usize()?,
                k.req("n")?.as_usize()?,
            )),
            None => None,
        };
        Ok(Manifest { dir, archs, datasets, kernel_bench })
    }

    pub fn arch(&self, name: &str) -> Result<&ArchEntry> {
        self.archs
            .get(name)
            .ok_or_else(|| anyhow!("unknown architecture '{name}'"))
    }

    pub fn path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    /// Dataset file path for (task, split).
    pub fn dataset(&self, task: &str, split: &str) -> Result<PathBuf> {
        let f = self
            .datasets
            .get(task)
            .and_then(|m| m.get(split))
            .ok_or_else(|| anyhow!("no dataset for {task}/{split}"))?;
        Ok(self.dir.join(f))
    }
}
