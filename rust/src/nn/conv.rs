//! Convolutions: an im2col+GEMM fast path and an independent direct
//! (naive loop) implementation used as its correctness oracle in tests.

use crate::tensor::Tensor;
use crate::util::parallel;

/// Matrix multiply C[m,n] = A[m,k] @ B[k,n]  (row-major slices).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0f32; m * n];
    {
        let cells = parallel::as_send_cells(&mut c);
        parallel::par_chunks(m, |lo, hi| {
            for i in lo..hi {
                let arow = &a[i * k..(i + 1) * k];
                // SAFETY: rows [lo, hi) are written by this chunk only.
                let crow = unsafe { cells.slice(i * n, n) };
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
        });
    }
    c
}

/// conv2d over NCHW input with OIHW weights (stride/pad symmetric),
/// supporting depthwise (`groups == in_ch`) and dense (`groups == 1`).
/// im2col + GEMM; bias added per output channel.
pub fn conv2d(
    x: &Tensor,
    w: &Tensor,
    b: Option<&[f32]>,
    stride: usize,
    pad: usize,
    groups: usize,
) -> Tensor {
    let (n, c_in, h, wd) = dims4(x);
    let (c_out, cig, kh, kw) = dims4(w);
    debug_assert_eq!(cig * groups, c_in);
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (wd + 2 * pad - kw) / stride + 1;
    let mut out = Tensor::zeros(&[n, c_out, oh, ow]);

    if groups == 1 {
        // im2col: (oh*ow, c_in*kh*kw) per image, GEMM against
        // (c_in*kh*kw, c_out) reshaped weights.
        let kdim = c_in * kh * kw;
        // w is OIHW -> transpose to (kdim, c_out)
        let mut wt = vec![0f32; kdim * c_out];
        for o in 0..c_out {
            let ch = w.out_channel(o);
            for kk in 0..kdim {
                wt[kk * c_out + o] = ch[kk];
            }
        }
        let mut col = vec![0f32; oh * ow * kdim];
        for img in 0..n {
            im2col_into(
                x.data(),
                c_in,
                h,
                wd,
                img,
                kh,
                kw,
                stride,
                pad,
                oh,
                ow,
                0.0,
                &mut col,
            );
            let y = matmul(&col, &wt, oh * ow, kdim, c_out);
            let od = out.data_mut();
            let base = img * c_out * oh * ow;
            for o in 0..c_out {
                let bias = b.map(|bb| bb[o]).unwrap_or(0.0);
                for p in 0..oh * ow {
                    od[base + o * oh * ow + p] = y[p * c_out + o] + bias;
                }
            }
        }
    } else {
        // depthwise: direct shifted accumulation (k*k fused multiply-adds)
        debug_assert_eq!(cig, 1, "only depthwise grouping supported");
        let od = out.data_mut();
        let xd = x.data();
        let wdat = w.data();
        for img in 0..n {
            for c in 0..c_in {
                let xoff = (img * c_in + c) * h * wd;
                let ooff = (img * c_out + c) * oh * ow;
                let wch = &wdat[c * kh * kw..(c + 1) * kh * kw];
                let bias = b.map(|bb| bb[c]).unwrap_or(0.0);
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = bias;
                        let iy0 = oy * stride;
                        let ix0 = ox * stride;
                        for dy in 0..kh {
                            let iy = iy0 + dy;
                            if iy < pad || iy >= h + pad {
                                continue;
                            }
                            for dx in 0..kw {
                                let ix = ix0 + dx;
                                if ix < pad || ix >= wd + pad {
                                    continue;
                                }
                                acc += xd[xoff + (iy - pad) * wd + (ix - pad)]
                                    * wch[dy * kw + dx];
                            }
                        }
                        od[ooff + oy * ow + ox] = acc;
                    }
                }
            }
        }
    }
    out
}

/// Extract im2col patches for one image into `col` laid out as
/// (oh*ow, c_in*kh*kw) row-major. Generic over the element type so the
/// f32 engine and the integer engine ([`super::qengine`]) share the
/// layout code; `fill` is the padding value (0.0 for f32, the input
/// zero-point for u8 grids, where it *represents* 0).
#[allow(clippy::too_many_arguments)]
pub(crate) fn im2col_into<T: Copy>(
    xd: &[T],
    c_in: usize,
    h: usize,
    wd: usize,
    img: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    fill: T,
    col: &mut [T],
) {
    let kdim = c_in * kh * kw;
    col.fill(fill);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * kdim;
            for c in 0..c_in {
                let xoff = (img * c_in + c) * h * wd;
                for dy in 0..kh {
                    let iy = oy * stride + dy;
                    if iy < pad || iy >= h + pad {
                        continue;
                    }
                    let src = xoff + (iy - pad) * wd;
                    let dst = row + (c * kh + dy) * kw;
                    for dx in 0..kw {
                        let ix = ox * stride + dx;
                        if ix < pad || ix >= wd + pad {
                            continue;
                        }
                        col[dst + dx] = xd[src + (ix - pad)];
                    }
                }
            }
        }
    }
}

/// Zero-insertion expansion of an NCHW tensor: each input pixel lands
/// at `(y·stride, x·stride)` of an `(h-1)·stride+1` grid, everything
/// else is `fill`. This is the gather-form front half of a transposed
/// conv; the integer engine reuses it with `fill = zero_point` (the
/// code that *represents* 0 on the activation grid).
pub(crate) fn expand_strided<T: Copy>(
    xd: &[T],
    n_c: usize,
    h: usize,
    w: usize,
    stride: usize,
    fill: T,
) -> (Vec<T>, usize, usize) {
    let (eh, ew) = ((h - 1) * stride + 1, (w - 1) * stride + 1);
    let mut out = vec![fill; n_c * eh * ew];
    for i in 0..n_c {
        let xoff = i * h * w;
        let ooff = i * eh * ew;
        for y in 0..h {
            for x in 0..w {
                out[ooff + y * stride * ew + x * stride] =
                    xd[xoff + y * w + x];
            }
        }
    }
    (out, eh, ew)
}

/// Spatially flip an OIHW kernel: `out[o,i,dy,dx] = w[o,i,k-1-dy,k-1-dx]`.
pub(crate) fn flip_kernel(w: &Tensor) -> Tensor {
    let (c_out, c_in, kh, kw) = dims4(w);
    let wd = w.data();
    let mut out = vec![0f32; wd.len()];
    for oi in 0..c_out * c_in {
        let base = oi * kh * kw;
        for dy in 0..kh {
            for dx in 0..kw {
                out[base + dy * kw + dx] =
                    wd[base + (kh - 1 - dy) * kw + (kw - 1 - dx)];
            }
        }
    }
    Tensor::new(&[c_out, c_in, kh, kw], out)
}

/// Transposed conv2d (gather form): zero-insert between input pixels,
/// then a stride-1 conv with the spatially flipped kernel and
/// `pad' = k - 1 - pad` (requires `pad < k`). Weights are
/// `[out_ch, in_ch, k, k]` — out-channel first, matching [`Op::ConvT2d`].
/// Output is `(h-1)·stride - 2·pad + k` per spatial dim.
pub fn conv_transpose2d(
    x: &Tensor,
    w: &Tensor,
    b: Option<&[f32]>,
    stride: usize,
    pad: usize,
) -> Tensor {
    let (n, c_in, h, wd) = dims4(x);
    let (_, _, kh, kw) = dims4(w);
    debug_assert!(pad < kh && pad < kw, "convT pad {pad} >= kernel");
    let (ex, eh, ew) = expand_strided(x.data(), n * c_in, h, wd, stride, 0.0);
    let expanded = Tensor::new(&[n, c_in, eh, ew], ex);
    conv2d(&expanded, &flip_kernel(w), b, 1, kh - 1 - pad, 1)
}

/// Independent scatter-form transposed conv (oracle for the gather
/// form): every input pixel scatters `x·w` into the output window.
pub fn conv_transpose2d_direct(
    x: &Tensor,
    w: &Tensor,
    b: Option<&[f32]>,
    stride: usize,
    pad: usize,
) -> Tensor {
    let (n, c_in, h, wd) = dims4(x);
    let (c_out, _, kh, kw) = dims4(w);
    let oh = (h - 1) * stride + kh - 2 * pad;
    let ow = (wd - 1) * stride + kw - 2 * pad;
    let mut acc = vec![0f64; n * c_out * oh * ow];
    let xd = x.data();
    let wdat = w.data();
    for img in 0..n {
        for i in 0..c_in {
            let xoff = (img * c_in + i) * h * wd;
            for o in 0..c_out {
                let woff = (o * c_in + i) * kh * kw;
                let ooff = (img * c_out + o) * oh * ow;
                for iy in 0..h {
                    for ix in 0..wd {
                        let xv = xd[xoff + iy * wd + ix] as f64;
                        for dy in 0..kh {
                            let oy =
                                (iy * stride + dy) as isize - pad as isize;
                            if oy < 0 || oy >= oh as isize {
                                continue;
                            }
                            for dx in 0..kw {
                                let ox = (ix * stride + dx) as isize
                                    - pad as isize;
                                if ox < 0 || ox >= ow as isize {
                                    continue;
                                }
                                acc[ooff
                                    + oy as usize * ow
                                    + ox as usize] += xv
                                    * wdat[woff + dy * kw + dx] as f64;
                            }
                        }
                    }
                }
            }
        }
    }
    let mut out = Tensor::zeros(&[n, c_out, oh, ow]);
    let od = out.data_mut();
    for img in 0..n {
        for o in 0..c_out {
            let bias = b.map(|bb| bb[o]).unwrap_or(0.0) as f64;
            let base = (img * c_out + o) * oh * ow;
            for p in 0..oh * ow {
                od[base + p] = (acc[base + p] + bias) as f32;
            }
        }
    }
    out
}

/// Independent naive conv (triple-checked oracle for property tests).
pub fn conv2d_direct(
    x: &Tensor,
    w: &Tensor,
    b: Option<&[f32]>,
    stride: usize,
    pad: usize,
    groups: usize,
) -> Tensor {
    let (n, c_in, h, wd) = dims4(x);
    let (c_out, cig, kh, kw) = dims4(w);
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (wd + 2 * pad - kw) / stride + 1;
    let opg = c_out / groups; // out channels per group
    let mut out = Tensor::zeros(&[n, c_out, oh, ow]);
    let od = out.data_mut();
    let xd = x.data();
    for img in 0..n {
        for o in 0..c_out {
            let g = o / opg;
            let bias = b.map(|bb| bb[o]).unwrap_or(0.0);
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = bias as f64;
                    for i in 0..cig {
                        let ci = g * cig + i;
                        for dy in 0..kh {
                            for dx in 0..kw {
                                let iy = (oy * stride + dy) as isize
                                    - pad as isize;
                                let ix = (ox * stride + dx) as isize
                                    - pad as isize;
                                if iy < 0
                                    || ix < 0
                                    || iy >= h as isize
                                    || ix >= wd as isize
                                {
                                    continue;
                                }
                                let xv = xd[(img * c_in + ci) * h * wd
                                    + iy as usize * wd
                                    + ix as usize];
                                let wv = w.data()[((o * cig + i) * kh
                                    + dy)
                                    * kw
                                    + dx];
                                acc += (xv * wv) as f64;
                            }
                        }
                    }
                    od[(img * c_out + o) * oh * ow + oy * ow + ox] =
                        acc as f32;
                }
            }
        }
    }
    out
}

pub fn dims4(t: &Tensor) -> (usize, usize, usize, usize) {
    let s = t.shape();
    debug_assert_eq!(s.len(), 4);
    (s[0], s[1], s[2], s[3])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
        Tensor::new(shape, rng.normal_vec(shape.iter().product(), 1.0))
    }

    #[test]
    fn im2col_matches_direct_dense() {
        let mut rng = Rng::new(5);
        for (stride, pad, k) in [(1, 1, 3), (2, 1, 3), (1, 0, 1), (2, 0, 1)] {
            let x = rand_tensor(&mut rng, &[2, 3, 8, 8]);
            let w = rand_tensor(&mut rng, &[4, 3, k, k]);
            let b: Vec<f32> = rng.normal_vec(4, 1.0);
            let got = conv2d(&x, &w, Some(&b), stride, pad, 1);
            let want = conv2d_direct(&x, &w, Some(&b), stride, pad, 1);
            assert_eq!(got.shape(), want.shape());
            assert!(
                got.max_abs_diff(&want) < 1e-4,
                "s={stride} p={pad} k={k}: {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn depthwise_matches_direct() {
        let mut rng = Rng::new(6);
        for stride in [1, 2] {
            let x = rand_tensor(&mut rng, &[2, 6, 8, 8]);
            let w = rand_tensor(&mut rng, &[6, 1, 3, 3]);
            let b: Vec<f32> = rng.normal_vec(6, 1.0);
            let got = conv2d(&x, &w, Some(&b), stride, 1, 6);
            let want = conv2d_direct(&x, &w, Some(&b), stride, 1, 6);
            assert!(got.max_abs_diff(&want) < 1e-4);
        }
    }

    #[test]
    fn conv_transpose_gather_matches_scatter() {
        let mut rng = Rng::new(9);
        for (stride, pad, k) in
            [(1, 0, 3), (2, 1, 3), (2, 0, 2), (3, 1, 4), (1, 2, 3)]
        {
            let x = rand_tensor(&mut rng, &[2, 3, 5, 6]);
            let w = rand_tensor(&mut rng, &[4, 3, k, k]);
            let b: Vec<f32> = rng.normal_vec(4, 1.0);
            let got = conv_transpose2d(&x, &w, Some(&b), stride, pad);
            let want = conv_transpose2d_direct(&x, &w, Some(&b), stride, pad);
            assert_eq!(got.shape(), want.shape(), "s={stride} p={pad} k={k}");
            assert!(
                got.max_abs_diff(&want) < 1e-4,
                "s={stride} p={pad} k={k}: {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn conv_transpose_upsamples_identity_kernel() {
        // 1x1 input, k=2, stride=2, pad=0: each pixel becomes a 2x2
        // block scaled by the kernel taps
        let x = Tensor::new(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let w = Tensor::new(&[1, 1, 2, 2], vec![1., 1., 1., 1.]);
        let y = conv_transpose2d(&x, &w, None, 2, 0);
        assert_eq!(y.shape(), &[1, 1, 4, 4]);
        assert_eq!(
            y.data(),
            &[1., 1., 2., 2., 1., 1., 2., 2., 3., 3., 4., 4., 3., 3., 4., 4.]
        );
    }

    #[test]
    fn matmul_small() {
        // [[1,2],[3,4]] @ [[1,0],[0,1]] = same
        let a = [1., 2., 3., 4.];
        let b = [1., 0., 0., 1.];
        assert_eq!(matmul(&a, &b, 2, 2, 2), vec![1., 2., 3., 4.]);
    }
}
