//! Elementwise / pooling ops + the fake-quantisation primitive.
//!
//! `fake_quant` must agree bit-for-bit with the Pallas kernel's epilogue
//! (python/compile/kernels/ref.py): divide by scale, round ties-to-even,
//! clamp to [0, n_levels-1], undo the affine map.

use crate::tensor::Tensor;

/// Quantize-dequantize a value on an affine grid. `n_levels <= 0` is the
/// identity (used to disable activation quantisation per site).
#[inline]
pub fn fake_quant_scalar(x: f32, scale: f32, zp: f32, n_levels: f32) -> f32 {
    if n_levels <= 0.0 {
        return x;
    }
    let q = (x / scale).round_ties_even() + zp;
    let q = q.clamp(0.0, (n_levels - 1.0).max(1.0));
    (q - zp) * scale
}

/// In-place fake-quant over a tensor.
pub fn fake_quant(t: &mut Tensor, scale: f32, zp: f32, n_levels: f32) {
    if n_levels <= 0.0 {
        return;
    }
    for x in t.data_mut() {
        *x = fake_quant_scalar(*x, scale, zp, n_levels);
    }
}

/// Clipped-linear activation: clamp(x, 0, hi). `hi = inf` is plain ReLU.
pub fn clip_act(t: &mut Tensor, hi: f32) {
    for x in t.data_mut() {
        *x = x.clamp(0.0, hi);
    }
}

/// Elementwise sum (same shapes).
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    Tensor::new(
        a.shape(),
        a.data().iter().zip(b.data()).map(|(x, y)| x + y).collect(),
    )
}

/// Channel concatenation of NCHW tensors (same N, H, W). Shape
/// agreement is a hard assertion (like [`add`]): a mismatched graph
/// must fail loudly, not interleave planes silently.
pub fn concat_channels(xs: &[&Tensor]) -> Tensor {
    assert!(!xs.is_empty(), "concat of zero tensors");
    let s0 = xs[0].shape();
    assert_eq!(s0.len(), 4, "concat wants NCHW inputs, got {s0:?}");
    let (n, h, w) = (s0[0], s0[2], s0[3]);
    for x in xs {
        let s = x.shape();
        assert!(
            s.len() == 4 && s[0] == n && s[2] == h && s[3] == w,
            "concat input {s:?} incompatible with {s0:?}"
        );
    }
    let c_out: usize = xs.iter().map(|x| x.shape()[1]).sum();
    let mut out = Tensor::zeros(&[n, c_out, h, w]);
    let od = out.data_mut();
    let hw = h * w;
    for img in 0..n {
        let mut off = img * c_out * hw;
        for x in xs {
            let c = x.shape()[1];
            let base = img * c * hw;
            od[off..off + c * hw]
                .copy_from_slice(&x.data()[base..base + c * hw]);
            off += c * hw;
        }
    }
    out
}

/// Pooled output length for one spatial dim. Callers must reject
/// windows larger than the padded input first (`h + 2·pad ≥ k`), or the
/// subtraction underflows.
#[inline]
pub(crate) fn pool_out(h: usize, k: usize, stride: usize, pad: usize) -> usize {
    (h + 2 * pad - k) / stride + 1
}

/// Walk every pool window over `n_c` contiguous (image, channel)
/// planes: gathers each window's in-bounds elements into a reused
/// buffer and calls `emit(out_index, window)` per output position.
/// Window/stride/pad are per-axis `(h, w)` pairs (rectangular windows
/// for the detection heads). Generic over the element type so the f32
/// oracle and the integer engine share the bounds/padding logic (the
/// [`super::conv::im2col_into`] precedent for convs).
#[allow(clippy::too_many_arguments)]
pub(crate) fn pool_windows<T: Copy>(
    xd: &[T],
    n_c: usize,
    h: usize,
    w: usize,
    k: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
    mut emit: impl FnMut(usize, &[T]),
) {
    let oh = pool_out(h, k.0, stride.0, pad.0);
    let ow = pool_out(w, k.1, stride.1, pad.1);
    let mut win = Vec::with_capacity(k.0 * k.1);
    for i in 0..n_c {
        let xoff = i * h * w;
        let ooff = i * oh * ow;
        for oy in 0..oh {
            for ox in 0..ow {
                win.clear();
                for dy in 0..k.0 {
                    let iy = oy * stride.0 + dy;
                    if iy < pad.0 || iy >= h + pad.0 {
                        continue;
                    }
                    for dx in 0..k.1 {
                        let ix = ox * stride.1 + dx;
                        if ix < pad.1 || ix >= w + pad.1 {
                            continue;
                        }
                        win.push(xd[xoff + (iy - pad.0) * w + (ix - pad.1)]);
                    }
                }
                debug_assert!(!win.is_empty(), "empty pool window");
                emit(ooff + oy * ow + ox, &win);
            }
        }
    }
}

/// Max pool (N, C, H, W) with a k×k window. Out-of-bounds (padding)
/// positions are excluded from the max, so the output values are always
/// actual input values (grid-preserving for quantised grids).
pub fn max_pool2d(x: &Tensor, k: usize, stride: usize, pad: usize) -> Tensor {
    pool2d(x, (k, k), (stride, stride), (pad, pad), true)
}

/// Average pool (N, C, H, W) with a k×k window, averaging over the
/// in-bounds taps only (`count_include_pad = false`).
pub fn avg_pool2d(x: &Tensor, k: usize, stride: usize, pad: usize) -> Tensor {
    pool2d(x, (k, k), (stride, stride), (pad, pad), false)
}

/// Rectangular max pool: per-axis `(kh, kw)` window/stride/pad.
pub fn max_pool2d_rect(
    x: &Tensor,
    k: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
) -> Tensor {
    pool2d(x, k, stride, pad, true)
}

/// Rectangular average pool over in-bounds taps only.
pub fn avg_pool2d_rect(
    x: &Tensor,
    k: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
) -> Tensor {
    pool2d(x, k, stride, pad, false)
}

fn pool2d(
    x: &Tensor,
    k: (usize, usize),
    stride: (usize, usize),
    pad: (usize, usize),
    max: bool,
) -> Tensor {
    let s = x.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    // per-axis pad < k: no window can land fully inside the padding
    // (the avg path would otherwise divide by a zero tap count)
    assert!(
        pad.0 < k.0 && pad.1 < k.1,
        "pool2d pad {pad:?} >= window {k:?}"
    );
    assert!(
        h + 2 * pad.0 >= k.0 && w + 2 * pad.1 >= k.1,
        "pool2d window {k:?} exceeds padded input {h}x{w} (pad {pad:?})"
    );
    let oh = pool_out(h, k.0, stride.0, pad.0);
    let ow = pool_out(w, k.1, stride.1, pad.1);
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    let od = out.data_mut();
    // one reduction per kind, over the window's in-bounds values only
    pool_windows(x.data(), n * c, h, w, k, stride, pad, |o, win| {
        od[o] = if max {
            win.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v))
        } else {
            (win.iter().map(|&v| v as f64).sum::<f64>() / win.len() as f64)
                as f32
        };
    });
    out
}

/// Global average pool (N, C, H, W) -> (N, C).
pub fn global_avg_pool(x: &Tensor) -> Tensor {
    let s = x.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    let spatial = h * w;
    let mut out = Tensor::zeros(&[n, c]);
    let od = out.data_mut();
    let xd = x.data();
    for i in 0..n {
        for ch in 0..c {
            let base = (i * c + ch) * spatial;
            let mut acc = 0f64;
            for p in 0..spatial {
                acc += xd[base + p] as f64;
            }
            od[i * c + ch] = (acc / spatial as f64) as f32;
        }
    }
    out
}

/// Nearest-neighbour upsample by an integer factor (N, C, H, W).
pub fn upsample_nearest(x: &Tensor, f: usize) -> Tensor {
    let s = x.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    let (oh, ow) = (h * f, w * f);
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    let od = out.data_mut();
    let xd = x.data();
    for i in 0..n * c {
        let xoff = i * h * w;
        let ooff = i * oh * ow;
        for oy in 0..oh {
            let iy = oy / f;
            for ox in 0..ow {
                od[ooff + oy * ow + ox] = xd[xoff + iy * w + ox / f];
            }
        }
    }
    out
}

/// Linear layer `y[n, o] = x[n, i] @ w[o, i]^T + b[o]`.
pub fn linear(x: &Tensor, w: &Tensor, b: &[f32]) -> Tensor {
    let (n, in_dim) = (x.shape()[0], x.shape()[1]);
    let out_dim = w.shape()[0];
    debug_assert_eq!(w.shape()[1], in_dim);
    let mut out = Tensor::zeros(&[n, out_dim]);
    let od = out.data_mut();
    for i in 0..n {
        let xrow = &x.data()[i * in_dim..(i + 1) * in_dim];
        for o in 0..out_dim {
            let wrow = &w.data()[o * in_dim..(o + 1) * in_dim];
            let mut acc = b[o] as f64;
            for k in 0..in_dim {
                acc += (xrow[k] * wrow[k]) as f64;
            }
            od[i * out_dim + o] = acc as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fake_quant_grid() {
        // INT8 asymmetric grid [0, 255], scale .1, zp 10
        let y = fake_quant_scalar(0.5, 0.1, 10.0, 256.0);
        assert!((y - 0.5).abs() < 1e-6);
        // clamps below zero-point floor
        let y = fake_quant_scalar(-5.0, 0.1, 10.0, 256.0);
        assert!((y - (-1.0)).abs() < 1e-6); // q clamps to 0 -> (0-10)*.1
        // identity when disabled
        assert_eq!(fake_quant_scalar(0.1234, 0.1, 0.0, 0.0), 0.1234);
    }

    #[test]
    fn fake_quant_ties_even() {
        // x/s = 0.5 rounds to 0 (ties-to-even), 1.5 rounds to 2
        assert_eq!(fake_quant_scalar(0.5, 1.0, 0.0, 16.0), 0.0);
        assert_eq!(fake_quant_scalar(1.5, 1.0, 0.0, 16.0), 2.0);
    }

    #[test]
    fn pool_and_upsample() {
        let x = Tensor::new(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        assert_eq!(global_avg_pool(&x).data(), &[2.5]);
        let u = upsample_nearest(&x, 2);
        assert_eq!(u.shape(), &[1, 1, 4, 4]);
        assert_eq!(u.data()[0..4], [1., 1., 2., 2.]);
        assert_eq!(u.data()[12..16], [3., 3., 4., 4.]);
    }

    #[test]
    fn linear_matches_manual() {
        let x = Tensor::new(&[1, 3], vec![1., 2., 3.]);
        let w = Tensor::new(&[2, 3], vec![1., 0., 0., 0., 1., 1.]);
        let y = linear(&x, &w, &[10.0, 20.0]);
        assert_eq!(y.data(), &[11.0, 25.0]);
    }

    #[test]
    fn concat_channels_stacks_in_order() {
        let a = Tensor::new(&[2, 1, 1, 2], vec![1., 2., 5., 6.]);
        let b = Tensor::new(&[2, 2, 1, 2], vec![3., 4., 30., 40., 7., 8., 70., 80.]);
        let y = concat_channels(&[&a, &b]);
        assert_eq!(y.shape(), &[2, 3, 1, 2]);
        assert_eq!(
            y.data(),
            &[1., 2., 3., 4., 30., 40., 5., 6., 7., 8., 70., 80.]
        );
    }

    #[test]
    fn pool2d_matches_manual() {
        // 1x1x3x3: max/avg with k=2, s=1, p=0
        let x = Tensor::new(
            &[1, 1, 3, 3],
            vec![1., 2., 3., 4., 5., 6., 7., 8., 9.],
        );
        let mx = max_pool2d(&x, 2, 1, 0);
        assert_eq!(mx.shape(), &[1, 1, 2, 2]);
        assert_eq!(mx.data(), &[5., 6., 8., 9.]);
        let av = avg_pool2d(&x, 2, 1, 0);
        assert_eq!(av.data(), &[3., 4., 6., 7.]);
        // padded: corners average over the valid taps only
        let av = avg_pool2d(&x, 3, 2, 1);
        assert_eq!(av.shape(), &[1, 1, 2, 2]);
        assert_eq!(av.data()[0], (1. + 2. + 4. + 5.) / 4.0);
        // padded max ignores out-of-bounds
        let mx = max_pool2d(&x, 3, 2, 1);
        assert_eq!(mx.data(), &[5., 6., 8., 9.]);
    }

    #[test]
    fn rect_pool_matches_manual() {
        // 1x1x2x4: a 1x3 window with stride (1,1), pad (0,1)
        let x = Tensor::new(
            &[1, 1, 2, 4],
            vec![1., 2., 3., 4., 5., 6., 7., 8.],
        );
        let mx = max_pool2d_rect(&x, (1, 3), (1, 1), (0, 1));
        assert_eq!(mx.shape(), &[1, 1, 2, 4]);
        assert_eq!(mx.data(), &[2., 3., 4., 4., 6., 7., 8., 8.]);
        let av = avg_pool2d_rect(&x, (1, 3), (1, 1), (0, 1));
        // edges average the two in-bounds taps only
        assert_eq!(av.data()[0], 1.5);
        assert_eq!(av.data()[1], 2.0);
        assert_eq!(av.data()[3], 3.5);
        // square wrappers still agree with the rect core
        let sq = max_pool2d(&x, 2, 1, 0);
        let rc = max_pool2d_rect(&x, (2, 2), (1, 1), (0, 0));
        assert_eq!(sq.data(), rc.data());
    }

    #[test]
    fn clip_act_relu6() {
        let mut t = Tensor::from_vec(vec![-1.0, 3.0, 9.0]);
        clip_act(&mut t, 6.0);
        assert_eq!(t.data(), &[0.0, 3.0, 6.0]);
    }
}
