//! Elementwise / pooling ops + the fake-quantisation primitive.
//!
//! `fake_quant` must agree bit-for-bit with the Pallas kernel's epilogue
//! (python/compile/kernels/ref.py): divide by scale, round ties-to-even,
//! clamp to [0, n_levels-1], undo the affine map.

use crate::tensor::Tensor;

/// Quantize-dequantize a value on an affine grid. `n_levels <= 0` is the
/// identity (used to disable activation quantisation per site).
#[inline]
pub fn fake_quant_scalar(x: f32, scale: f32, zp: f32, n_levels: f32) -> f32 {
    if n_levels <= 0.0 {
        return x;
    }
    let q = (x / scale).round_ties_even() + zp;
    let q = q.clamp(0.0, (n_levels - 1.0).max(1.0));
    (q - zp) * scale
}

/// In-place fake-quant over a tensor.
pub fn fake_quant(t: &mut Tensor, scale: f32, zp: f32, n_levels: f32) {
    if n_levels <= 0.0 {
        return;
    }
    for x in t.data_mut() {
        *x = fake_quant_scalar(*x, scale, zp, n_levels);
    }
}

/// Clipped-linear activation: clamp(x, 0, hi). `hi = inf` is plain ReLU.
pub fn clip_act(t: &mut Tensor, hi: f32) {
    for x in t.data_mut() {
        *x = x.clamp(0.0, hi);
    }
}

/// Elementwise sum (same shapes).
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    Tensor::new(
        a.shape(),
        a.data().iter().zip(b.data()).map(|(x, y)| x + y).collect(),
    )
}

/// Global average pool (N, C, H, W) -> (N, C).
pub fn global_avg_pool(x: &Tensor) -> Tensor {
    let s = x.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    let spatial = h * w;
    let mut out = Tensor::zeros(&[n, c]);
    let od = out.data_mut();
    let xd = x.data();
    for i in 0..n {
        for ch in 0..c {
            let base = (i * c + ch) * spatial;
            let mut acc = 0f64;
            for p in 0..spatial {
                acc += xd[base + p] as f64;
            }
            od[i * c + ch] = (acc / spatial as f64) as f32;
        }
    }
    out
}

/// Nearest-neighbour upsample by an integer factor (N, C, H, W).
pub fn upsample_nearest(x: &Tensor, f: usize) -> Tensor {
    let s = x.shape();
    let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
    let (oh, ow) = (h * f, w * f);
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    let od = out.data_mut();
    let xd = x.data();
    for i in 0..n * c {
        let xoff = i * h * w;
        let ooff = i * oh * ow;
        for oy in 0..oh {
            let iy = oy / f;
            for ox in 0..ow {
                od[ooff + oy * ow + ox] = xd[xoff + iy * w + ox / f];
            }
        }
    }
    out
}

/// Linear layer y[n, o] = x[n, i] @ w[o, i]^T + b[o].
pub fn linear(x: &Tensor, w: &Tensor, b: &[f32]) -> Tensor {
    let (n, in_dim) = (x.shape()[0], x.shape()[1]);
    let out_dim = w.shape()[0];
    debug_assert_eq!(w.shape()[1], in_dim);
    let mut out = Tensor::zeros(&[n, out_dim]);
    let od = out.data_mut();
    for i in 0..n {
        let xrow = &x.data()[i * in_dim..(i + 1) * in_dim];
        for o in 0..out_dim {
            let wrow = &w.data()[o * in_dim..(o + 1) * in_dim];
            let mut acc = b[o] as f64;
            for k in 0..in_dim {
                acc += (xrow[k] * wrow[k]) as f64;
            }
            od[i * out_dim + o] = acc as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fake_quant_grid() {
        // INT8 asymmetric grid [0, 255], scale .1, zp 10
        let y = fake_quant_scalar(0.5, 0.1, 10.0, 256.0);
        assert!((y - 0.5).abs() < 1e-6);
        // clamps below zero-point floor
        let y = fake_quant_scalar(-5.0, 0.1, 10.0, 256.0);
        assert!((y - (-1.0)).abs() < 1e-6); // q clamps to 0 -> (0-10)*.1
        // identity when disabled
        assert_eq!(fake_quant_scalar(0.1234, 0.1, 0.0, 0.0), 0.1234);
    }

    #[test]
    fn fake_quant_ties_even() {
        // x/s = 0.5 rounds to 0 (ties-to-even), 1.5 rounds to 2
        assert_eq!(fake_quant_scalar(0.5, 1.0, 0.0, 16.0), 0.0);
        assert_eq!(fake_quant_scalar(1.5, 1.0, 0.0, 16.0), 2.0);
    }

    #[test]
    fn pool_and_upsample() {
        let x = Tensor::new(&[1, 1, 2, 2], vec![1., 2., 3., 4.]);
        assert_eq!(global_avg_pool(&x).data(), &[2.5]);
        let u = upsample_nearest(&x, 2);
        assert_eq!(u.shape(), &[1, 1, 4, 4]);
        assert_eq!(u.data()[0..4], [1., 1., 2., 2.]);
        assert_eq!(u.data()[12..16], [3., 3., 4., 4.]);
    }

    #[test]
    fn linear_matches_manual() {
        let x = Tensor::new(&[1, 3], vec![1., 2., 3.]);
        let w = Tensor::new(&[2, 3], vec![1., 0., 0., 0., 1., 1.]);
        let y = linear(&x, &w, &[10.0, 20.0]);
        assert_eq!(y.data(), &[11.0, 25.0]);
    }

    #[test]
    fn clip_act_relu6() {
        let mut t = Tensor::from_vec(vec![-1.0, 3.0, 9.0]);
        clip_act(&mut t, 6.0);
        assert_eq!(t.data(), &[0.0, 3.0, 6.0]);
    }
}
