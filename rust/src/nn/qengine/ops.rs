//! Integer op kernels beyond convolution: requantise-add for residual
//! connections, requantise-concat for branch merges, integer global
//! average pooling, integer max/avg spatial pooling, the int8 linear
//! head, standalone activation requantisation, and grid-preserving
//! layout ops.
//!
//! Together with the conv kernels these cover every op of MobileNet- and
//! inception-style graphs (branchy concat blocks, max-pool stems), so a
//! packed plan can run end-to-end with zero f32 fallback layers. Each op
//! matches the fake-quant f32 oracle within one quantisation step per
//! element (single integer rounding per op; round-half-away vs the
//! oracle's ties-to-even only moves exact ties) — max-pool is *exact*
//! (a monotone selection never leaves the grid).

use anyhow::{anyhow, bail, Result};

use crate::graph::{PoolKind, MAX_CONCAT_INPUTS, MAX_POOL_DIM};
use crate::nn::SiteCfg;
use crate::quant::QParams;
use crate::tensor::{QTensor, Tensor};
use crate::util::mmap::ArcSlice;

use super::gemm::{self, KernelKind, PackedB};
use super::kernels::{
    act_clamp, fold_weight_grids, mult_for, round_shift, Mult, Scratch,
};
use super::{assert_act_grid, QActTensor};

/// `round(t / d)`, half away from zero (`d > 0`).
#[inline]
fn div_round(t: i64, d: i64) -> i64 {
    let r = (2 * t.abs() + d) / (2 * d);
    if t >= 0 {
        r
    } else {
        -r
    }
}

// -- requantise-add ----------------------------------------------------------

/// Fractional bits of the requantise-add multipliers. Q20 keeps
/// `255 · 2^20 · (s_in/s_out)` far inside i64 while bounding the
/// multiplier quantisation error at `2^-21` per code unit — negligible
/// next to the single half-step rounding.
pub const ADD_FRAC_BITS: u32 = 20;

/// Upper bound on a Q20 requantise multiplier: a scale ratio of 2^20
/// (far beyond any sane grid pair; `255·2^40` still sits comfortably
/// inside i64). Enforced by the packers and re-validated by the
/// artifact reader so a corrupt multiplier can't overflow at run time.
pub(crate) const MAX_REQUANT_MULT: i64 = 1 << 40;

/// A residual add packed for integer execution: both inputs rescale onto
/// the add-site output grid with Q20 fixed-point multipliers and one
/// shared rounding, `q = zp_o + round((m_a·(q_a-z_a) + m_b·(q_b-z_b)) /
/// 2^20)` — the gemmlowp/TFLite two-input requantise-add.
#[derive(Debug, Clone)]
pub struct QAddInt {
    /// `round(s_a/s_o · 2^20)`, `round(s_b/s_o · 2^20)`.
    pub(crate) ma: i64,
    pub(crate) mb: i64,
    pub(crate) a_qp: QParams,
    pub(crate) b_qp: QParams,
    pub(crate) out_qp: QParams,
}

impl QAddInt {
    pub fn pack(a: &QParams, b: &QParams, out: &QParams) -> Result<QAddInt> {
        assert_act_grid(a);
        assert_act_grid(b);
        assert_act_grid(out);
        let unit = (1i64 << ADD_FRAC_BITS) as f64;
        let ma = (a.scale as f64 / out.scale as f64 * unit).round() as i64;
        let mb = (b.scale as f64 / out.scale as f64 * unit).round() as i64;
        if ma <= 0 || mb <= 0 {
            bail!("degenerate requantise-add multipliers ({ma}, {mb})");
        }
        if ma > MAX_REQUANT_MULT || mb > MAX_REQUANT_MULT {
            bail!("implausible requantise-add multipliers ({ma}, {mb})");
        }
        Ok(QAddInt { ma, mb, a_qp: *a, b_qp: *b, out_qp: *out })
    }

    pub fn out_params(&self) -> QParams {
        self.out_qp
    }

    pub fn run(&self, a: &QActTensor, b: &QActTensor) -> Result<QActTensor> {
        if a.shape != b.shape {
            bail!("add shape mismatch: {:?} vs {:?}", a.shape, b.shape);
        }
        if a.qp != self.a_qp || b.qp != self.b_qp {
            bail!(
                "add input grids mismatch: packed for ({:?}, {:?}), got \
                 ({:?}, {:?})",
                self.a_qp,
                self.b_qp,
                a.qp,
                b.qp
            );
        }
        let za = self.a_qp.zero_point as i64;
        let zb = self.b_qp.zero_point as i64;
        let zo = self.out_qp.zero_point as i64;
        let n_hi = self.out_qp.n_levels as i64 - 1;
        let codes = a
            .codes
            .iter()
            .zip(&b.codes)
            .map(|(&qa, &qb)| {
                let t = self.ma * (qa as i64 - za)
                    + self.mb * (qb as i64 - zb);
                (round_shift(t, ADD_FRAC_BITS) + zo).clamp(0, n_hi) as u8
            })
            .collect();
        Ok(QActTensor { shape: a.shape.clone(), codes, qp: self.out_qp })
    }
}

// -- requantise-concat --------------------------------------------------------

/// A channel concatenation packed for integer execution: every input is
/// rescaled onto the shared concat-site output grid with a Q20
/// fixed-point multiplier and one rounding per element,
/// `q = zp_o + round(m_i·(q - z_i) / 2^20)` — the [`QAddInt`] requantise
/// arithmetic applied per branch instead of summed.
#[derive(Debug, Clone)]
pub struct QConcatInt {
    /// `round(s_i/s_o · 2^20)` per input.
    pub(crate) ms: Vec<i64>,
    pub(crate) in_qps: Vec<QParams>,
    pub(crate) out_qp: QParams,
}

impl QConcatInt {
    pub fn pack(ins: &[QParams], out: &QParams) -> Result<QConcatInt> {
        if ins.len() < 2 {
            bail!("concat needs >= 2 inputs, got {}", ins.len());
        }
        if ins.len() > MAX_CONCAT_INPUTS {
            bail!(
                "concat fan-in {} exceeds {MAX_CONCAT_INPUTS} branches",
                ins.len()
            );
        }
        assert_act_grid(out);
        let unit = (1i64 << ADD_FRAC_BITS) as f64;
        let mut ms = Vec::with_capacity(ins.len());
        for qp in ins {
            assert_act_grid(qp);
            let m = (qp.scale as f64 / out.scale as f64 * unit).round() as i64;
            if m <= 0 {
                bail!("degenerate requantise-concat multiplier ({m})");
            }
            if m > MAX_REQUANT_MULT {
                bail!("implausible requantise-concat multiplier ({m})");
            }
            ms.push(m);
        }
        Ok(QConcatInt { ms, in_qps: ins.to_vec(), out_qp: *out })
    }

    pub fn num_inputs(&self) -> usize {
        self.ms.len()
    }

    pub fn out_params(&self) -> QParams {
        self.out_qp
    }

    pub fn run(&self, xs: &[&QActTensor]) -> Result<QActTensor> {
        if xs.len() != self.ms.len() {
            bail!(
                "concat packed for {} inputs, got {}",
                self.ms.len(),
                xs.len()
            );
        }
        let s0 = &xs[0].shape;
        if s0.len() != 4 {
            bail!("concat wants NCHW inputs, got {:?}", s0);
        }
        let (n, h, w) = (s0[0], s0[2], s0[3]);
        let mut c_out = 0usize;
        for (i, x) in xs.iter().enumerate() {
            if x.shape.len() != 4
                || x.shape[0] != n
                || x.shape[2] != h
                || x.shape[3] != w
            {
                bail!(
                    "concat input {i} shape {:?} incompatible with {:?}",
                    x.shape,
                    s0
                );
            }
            if x.qp != self.in_qps[i] {
                bail!(
                    "concat input {i} grid mismatch: packed for {:?}, \
                     got {:?}",
                    self.in_qps[i],
                    x.qp
                );
            }
            c_out += x.shape[1];
        }
        let zo = self.out_qp.zero_point as i64;
        let n_hi = self.out_qp.n_levels as i64 - 1;
        let hw = h * w;
        let mut codes = vec![0u8; n * c_out * hw];
        for img in 0..n {
            let mut off = img * c_out * hw;
            for (i, x) in xs.iter().enumerate() {
                let c = x.shape[1];
                let zi = self.in_qps[i].zero_point as i64;
                let m = self.ms[i];
                let base = img * c * hw;
                for (dst, &q) in codes[off..off + c * hw]
                    .iter_mut()
                    .zip(&x.codes[base..base + c * hw])
                {
                    let t = m * (q as i64 - zi);
                    *dst = (round_shift(t, ADD_FRAC_BITS) + zo)
                        .clamp(0, n_hi) as u8;
                }
                off += c * hw;
            }
        }
        Ok(QActTensor {
            shape: vec![n, c_out, h, w],
            codes,
            qp: self.out_qp,
        })
    }
}

// -- integer spatial pooling --------------------------------------------------

/// A spatial pool packed for integer execution — grid-preserving for
/// both kinds: max of u8 codes (dequantisation is monotone, so
/// `max(codes)` *is* the code of the f32 max — exact) and an
/// i64-accumulate rounded average on the input grid (within half a
/// step of the f32 mean). Windows are per-axis `(kh, kw)` (rectangular
/// pools for the detection heads); a `global` pool takes its full
/// spatial extent as the window at run time. Out-of-bounds window
/// positions are excluded, matching [`crate::nn::ops::max_pool2d_rect`]
/// / `avg_pool2d_rect`.
#[derive(Debug, Clone)]
pub struct QPoolInt {
    pub(crate) kind: PoolKind,
    pub(crate) k: (usize, usize),
    pub(crate) stride: (usize, usize),
    pub(crate) pad: (usize, usize),
    /// Full-extent window (`(h, w)` of the runtime input), stored in
    /// the canonical `k=(1,1), stride=(1,1), pad=(0,0)` form.
    pub(crate) global: bool,
    pub(crate) qp: QParams,
}

impl QPoolInt {
    pub fn pack(
        kind: PoolKind,
        k: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
        global: bool,
        qp: &QParams,
    ) -> Result<QPoolInt> {
        if global && (k != (1, 1) || stride != (1, 1) || pad != (0, 0)) {
            bail!(
                "global pool wants its canonical k=(1,1) s=(1,1) p=(0,0) \
                 form, got k={k:?} s={stride:?} p={pad:?}"
            );
        }
        for ((kd, sd), pd) in [(k.0, stride.0), (k.1, stride.1)]
            .into_iter()
            .zip([pad.0, pad.1])
        {
            if kd == 0 || sd == 0 {
                bail!("pool with zero window/stride");
            }
            if kd > MAX_POOL_DIM || sd > MAX_POOL_DIM {
                bail!("implausible pool window (k {kd}, stride {sd})");
            }
            if pd >= kd {
                bail!("pool pad {pd} >= window {kd} (empty windows)");
            }
        }
        assert_act_grid(qp);
        Ok(QPoolInt { kind, k, stride, pad, global, qp: *qp })
    }

    /// Square-window convenience used by the legacy artifact decode
    /// path and tests.
    pub fn pack_square(
        kind: PoolKind,
        k: usize,
        stride: usize,
        pad: usize,
        qp: &QParams,
    ) -> Result<QPoolInt> {
        QPoolInt::pack(
            kind,
            (k, k),
            (stride, stride),
            (pad, pad),
            false,
            qp,
        )
    }

    pub fn out_params(&self) -> QParams {
        self.qp
    }

    pub fn run(&self, x: &QActTensor) -> Result<QActTensor> {
        if x.shape.len() != 4 {
            bail!("pool wants NCHW input, got {:?}", x.shape);
        }
        if x.qp != self.qp {
            bail!(
                "pool input grid mismatch: packed for {:?}, got {:?}",
                self.qp,
                x.qp
            );
        }
        let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let (k, stride, pad) = if self.global {
            if h == 0 || w == 0 {
                bail!("global pool over empty spatial dims {h}x{w}");
            }
            ((h, w), (1, 1), (0, 0))
        } else {
            (self.k, self.stride, self.pad)
        };
        if h + 2 * pad.0 < k.0 || w + 2 * pad.1 < k.1 {
            // typed error, not a usize underflow inside pool_out
            bail!(
                "pool window {k:?} exceeds padded input {h}x{w} (pad {pad:?})"
            );
        }
        let oh = crate::nn::ops::pool_out(h, k.0, stride.0, pad.0);
        let ow = crate::nn::ops::pool_out(w, k.1, stride.1, pad.1);
        let z = self.qp.zero_point as i64;
        let n_hi = self.qp.n_levels as i64 - 1;
        let mut codes = vec![0u8; n * c * oh * ow];
        // one reduction per kind, over the shared padded window walk
        // (`pool_windows` — the same bounds logic as the f32 oracle)
        match self.kind {
            PoolKind::Max => crate::nn::ops::pool_windows(
                &x.codes,
                n * c,
                h,
                w,
                k,
                stride,
                pad,
                |o, win| {
                    // u8 max over the window: dequantisation is
                    // monotone, so this is exactly the code of the
                    // f32 max
                    codes[o] = win
                        .iter()
                        .copied()
                        .max()
                        .expect("pad < k: non-empty window");
                },
            ),
            PoolKind::Avg => crate::nn::ops::pool_windows(
                &x.codes,
                n * c,
                h,
                w,
                k,
                stride,
                pad,
                |o, win| {
                    let taps = win.len() as i64;
                    let acc: i64 = win.iter().map(|&v| v as i64).sum();
                    codes[o] = (z + div_round(acc - taps * z, taps))
                        .clamp(0, n_hi) as u8;
                },
            ),
        }
        Ok(QActTensor { shape: vec![n, c, oh, ow], codes, qp: self.qp })
    }
}

// -- integer global average pool --------------------------------------------

/// Integer global average pool (N, C, H, W) → (N, C): i64 accumulate of
/// the codes and a single rounded division back onto the *input* grid
/// (the mean of on-grid values always lies inside the grid's range, so
/// no new grid is needed). Within half a step of the exact f32 mean.
pub fn gap_int(x: &QActTensor) -> Result<QActTensor> {
    if x.shape.len() != 4 {
        bail!("gap_int wants NCHW input, got {:?}", x.shape);
    }
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let hw = h * w;
    if hw == 0 {
        bail!("gap_int over empty spatial dims");
    }
    let z = x.qp.zero_point as i64;
    let n_hi = x.qp.n_levels as i64 - 1;
    let mut codes = Vec::with_capacity(n * c);
    for i in 0..n * c {
        let base = i * hw;
        let sum: i64 =
            x.codes[base..base + hw].iter().map(|&q| q as i64).sum();
        let q = z + div_round(sum - hw as i64 * z, hw as i64);
        codes.push(q.clamp(0, n_hi) as u8);
    }
    Ok(QActTensor { shape: vec![n, c], codes, qp: x.qp })
}

// -- int8 linear head --------------------------------------------------------

/// The linear head packed for integer execution: the same u8×i8→i32 GEMM
/// as the conv path with per-output-channel zero-point folding
/// (`-z_in·colsum[o] + I·z_in·zp_w[o]`), finished by an exact f32
/// epilogue — logits are model outputs, so they dequantise rather than
/// requantise.
#[derive(Debug, Clone)]
pub struct QLinear {
    pub(crate) in_dim: usize,
    pub(crate) out_dim: usize,
    /// Transposed (in_dim, out_dim) i8 codes for the GEMM.
    /// [`ArcSlice`] so artifact decode can alias the mmap'd `wgrid.i8`
    /// section; the pack path stores an owned vec.
    pub(crate) wt: ArcSlice<i8>,
    /// Signed-storage weight zero point (`zp_w - 128`) per output.
    pub(crate) zp_w: Vec<i32>,
    pub(crate) s_w: Vec<f32>,
    /// `-z_in·colsum[o] + I·z_in·zp_w[o]` per output.
    pub(crate) zp_corr: ArcSlice<i64>,
    pub(crate) bias: Vec<f32>,
    pub(crate) in_qp: QParams,
    /// Inner-kernel flavour (derived state, like the conv's — recorded
    /// at pack/decode time, never serialized).
    pub(crate) kernel: KernelKind,
    /// SIMD weight panels for `kernel` (empty for scalar plans).
    pub(crate) packed: PackedB,
}

impl QLinear {
    /// Pack a linear layer from its retained `[O, I]` i8 weight codes.
    pub fn pack(w: &QTensor, bias: &[f32], in_qp: &QParams) -> Result<QLinear> {
        let shape = w.shape();
        if shape.len() != 2 {
            bail!("QLinear wants [O, I] weights, got {:?}", shape);
        }
        let (out_dim, in_dim) = (shape[0], shape[1]);
        if bias.len() != out_dim {
            bail!("bias len {} != out dim {}", bias.len(), out_dim);
        }
        assert_act_grid(in_qp);
        // same folding + (I, O) transpose as the dense conv packer
        let fw = fold_weight_grids(w, out_dim, in_dim, in_qp, true)?;
        let mut lin = QLinear {
            in_dim,
            out_dim,
            wt: fw.w.into(),
            zp_w: fw.zp_w,
            s_w: fw.s_w,
            zp_corr: fw.zp_corr.into(),
            bias: bias.to_vec(),
            in_qp: *in_qp,
            kernel: KernelKind::Scalar,
            packed: PackedB::empty(),
        };
        lin.set_kernel(gemm::active_kind());
        Ok(lin)
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// The inner-kernel flavour this layer currently dispatches to.
    pub fn kernel_kind(&self) -> KernelKind {
        self.kernel
    }

    /// Re-target this layer's inner kernel and rebuild the packed
    /// panels (plan-level `force_scalar`, dispatch bisection tests).
    pub fn set_kernel(&mut self, kind: KernelKind) {
        if self.kernel != kind {
            self.kernel = kind;
            self.rebuild_packed();
        }
    }

    /// Re-derive the packed SIMD panels from the canonical transposed
    /// weights (derived state, never serialized).
    pub(crate) fn rebuild_packed(&mut self) {
        self.packed = if self.kernel != KernelKind::Scalar {
            PackedB::pack(self.kernel, &self.wt, self.in_dim, self.out_dim)
        } else {
            PackedB::empty()
        };
    }

    /// u8 codes in → f32 logits out. Accepts (N, I) or any shape whose
    /// trailing dims flatten to I (e.g. a (N, C, 1, 1) feature map).
    pub fn run(
        &self,
        x: &QActTensor,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let n = *x
            .shape
            .first()
            .ok_or_else(|| anyhow!("QLinear input needs a batch dim"))?;
        let per: usize = x.shape[1..].iter().product();
        if per != self.in_dim {
            bail!(
                "input shape {:?} incompatible with linear ({} inputs)",
                x.shape,
                self.in_dim
            );
        }
        if x.qp != self.in_qp {
            bail!(
                "input grid mismatch: layer packed for {:?}, got {:?}",
                self.in_qp,
                x.qp
            );
        }
        if scratch.acc.len() < n * self.out_dim {
            scratch.acc.resize(n * self.out_dim, 0);
        }
        if scratch.rows.len() < n {
            scratch.rows.resize(n, 0);
        }
        if self.packed.is_empty() {
            gemm::qgemm_into_kind(
                KernelKind::Scalar,
                &x.codes,
                &self.wt,
                n,
                self.in_dim,
                self.out_dim,
                &mut scratch.acc[..n * self.out_dim],
            );
        } else {
            gemm::qgemm_packed_into(
                &x.codes,
                &self.packed,
                n,
                &mut scratch.acc[..n * self.out_dim],
            );
        }
        gemm::rowsums_u8_into(&x.codes, n, self.in_dim, &mut scratch.rows[..n]);
        let s_in = self.in_qp.scale as f64;
        let mut out = Tensor::zeros(&[n, self.out_dim]);
        let od = out.data_mut();
        for i in 0..n {
            for o in 0..self.out_dim {
                let t = scratch.acc[i * self.out_dim + o] as i64
                    - self.zp_w[o] as i64 * scratch.rows[i] as i64
                    + self.zp_corr[o];
                od[i * self.out_dim + o] = (t as f64
                    * (s_in * self.s_w[o] as f64)
                    + self.bias[o] as f64)
                    as f32;
            }
        }
        Ok(out)
    }
}

// -- standalone activation requantisation -----------------------------------

/// A standalone activation site over a quantised input: one fixed-point
/// multiplier from the input grid onto the site grid with the site's
/// clamped-ReLU bounds folded into the integer clamp — no f32 round
/// trip. Used when an act node is not fused into its producing conv
/// (e.g. a ReLU following a residual add).
#[derive(Debug, Clone)]
pub struct Requantizer {
    pub(crate) m: Mult,
    pub(crate) q_lo: i32,
    pub(crate) q_hi: i32,
    pub(crate) in_qp: QParams,
    pub(crate) out_qp: QParams,
}

impl Requantizer {
    pub fn pack(in_qp: &QParams, row: &SiteCfg) -> Result<Requantizer> {
        if !(2.0..=256.0).contains(&row.n_levels) {
            bail!(
                "requantizer needs a quantised site (2..=256 levels), \
                 got {}",
                row.n_levels
            );
        }
        let out_qp = QParams {
            scale: row.scale,
            zero_point: row.zero_point,
            n_levels: row.n_levels,
        };
        assert_act_grid(in_qp);
        assert_act_grid(&out_qp);
        let (q_lo, q_hi) = act_clamp(row, &out_qp);
        Ok(Requantizer {
            m: mult_for(in_qp.scale as f64 / row.scale as f64),
            q_lo,
            q_hi,
            in_qp: *in_qp,
            out_qp,
        })
    }

    pub fn out_params(&self) -> QParams {
        self.out_qp
    }

    pub fn run(&self, x: &QActTensor) -> Result<QActTensor> {
        if x.qp != self.in_qp {
            bail!(
                "input grid mismatch: requantizer packed for {:?}, got {:?}",
                self.in_qp,
                x.qp
            );
        }
        // dispatched plane requant: 16-lane SIMD shift kernel when the
        // multiplier is an exact power of two, scalar otherwise —
        // bitwise-identical either way (see `gemm::requant_codes`)
        let mut codes = vec![0u8; x.codes.len()];
        gemm::requant_codes(
            &x.codes,
            &mut codes,
            &self.m,
            self.in_qp.zero_point as i32,
            self.out_qp.zero_point as i32,
            self.q_lo,
            self.q_hi,
        );
        Ok(QActTensor { shape: x.shape.clone(), codes, qp: self.out_qp })
    }
}

// -- layout ops --------------------------------------------------------------

/// Nearest-neighbour upsample on u8 codes (grid-preserving).
pub fn upsample_codes(x: &QActTensor, f: usize) -> QActTensor {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (h * f, w * f);
    let mut out = vec![0u8; n * c * oh * ow];
    for i in 0..n * c {
        let xoff = i * h * w;
        let ooff = i * oh * ow;
        for oy in 0..oh {
            let iy = oy / f;
            for ox in 0..ow {
                out[ooff + oy * ow + ox] = x.codes[xoff + iy * w + ox / f];
            }
        }
    }
    QActTensor { shape: vec![n, c, oh, ow], codes: out, qp: x.qp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ops as fops;
    use crate::quant::params_for_range;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn round_helpers_match_f64() {
        for t in [-1001i64, -500, -3, 0, 3, 499, 1000, 123457] {
            let want = (t as f64 / 1024.0).abs().round() as i64
                * if t < 0 { -1 } else { 1 };
            assert_eq!(round_shift(t, 10), want, "t={t}");
            for d in [1i64, 3, 7, 49] {
                let w = (t as f64 / d as f64).abs().round() as i64
                    * if t < 0 { -1 } else { 1 };
                assert_eq!(div_round(t, d), w, "t={t} d={d}");
            }
        }
    }

    #[test]
    fn upsample_codes_matches_f32() {
        let mut rng = Rng::new(6);
        let t = Tensor::new(&[1, 2, 3, 3], rng.normal_vec(18, 1.0));
        let qp = params_for_range(-3.0, 3.0, 8, false);
        let q = QActTensor::quantize(&t, &qp);
        let up = upsample_codes(&q, 2);
        let want = fops::upsample_nearest(&q.dequantize(), 2);
        assert_eq!(up.dequantize(), want);
    }

    #[test]
    fn concat_requant_matches_oracle_within_one_step() {
        let mut rng = Rng::new(11);
        let qa = params_for_range(0.0, 3.0, 8, false);
        let qb = params_for_range(0.0, 5.0, 8, false);
        let qo = params_for_range(0.0, 4.0, 8, false);
        let a = QActTensor::quantize(
            &Tensor::new(&[2, 3, 4, 4], rng.normal_vec(96, 1.0)),
            &qa,
        );
        let b = QActTensor::quantize(
            &Tensor::new(&[2, 2, 4, 4], rng.normal_vec(64, 1.5)),
            &qb,
        );
        let cc = QConcatInt::pack(&[qa, qb], &qo).unwrap();
        let got = cc.run(&[&a, &b]).unwrap();
        assert_eq!(got.shape, vec![2, 5, 4, 4]);
        assert_eq!(got.qp, qo);
        let mut want =
            fops::concat_channels(&[&a.dequantize(), &b.dequantize()]);
        crate::nn::ops::fake_quant(
            &mut want, qo.scale, qo.zero_point, qo.n_levels,
        );
        let diff = got.dequantize().max_abs_diff(&want);
        assert!(
            diff <= qo.scale * 1.001,
            "concat off by {diff} (> one step {})",
            qo.scale
        );
    }

    #[test]
    fn concat_rejects_mismatches() {
        let qp = params_for_range(0.0, 1.0, 8, false);
        assert!(QConcatInt::pack(&[qp], &qp).is_err(), "single input");
        let cc = QConcatInt::pack(&[qp, qp], &qp).unwrap();
        let a = QActTensor {
            shape: vec![1, 2, 2, 2],
            codes: vec![0; 8],
            qp,
        };
        let b = QActTensor {
            shape: vec![1, 2, 3, 2], // wrong H
            codes: vec![0; 12],
            qp,
        };
        assert!(cc.run(&[&a, &b]).is_err());
        assert!(cc.run(&[&a]).is_err(), "arity mismatch");
    }

    #[test]
    fn max_pool_int_is_exact() {
        let mut rng = Rng::new(12);
        for (k, stride, pad) in [(2, 2, 0), (3, 2, 1), (3, 1, 1)] {
            let t = Tensor::new(&[2, 3, 7, 7], rng.normal_vec(294, 1.0));
            let qp = params_for_range(t.min(), t.max(), 8, false);
            let q = QActTensor::quantize(&t, &qp);
            let p = QPoolInt::pack_square(PoolKind::Max, k, stride, pad, &qp)
                .unwrap();
            let got = p.run(&q).unwrap();
            let want = fops::max_pool2d(&q.dequantize(), k, stride, pad);
            assert_eq!(got.qp, qp);
            assert_eq!(
                got.dequantize(),
                want,
                "max-pool k={k} s={stride} p={pad} must be exact"
            );
        }
    }

    #[test]
    fn avg_pool_int_within_half_step() {
        let mut rng = Rng::new(13);
        for (k, stride, pad) in [(2, 2, 0), (3, 2, 1), (3, 1, 1)] {
            let t = Tensor::new(&[2, 3, 8, 8], rng.normal_vec(384, 1.0));
            let qp = params_for_range(t.min(), t.max(), 8, false);
            let q = QActTensor::quantize(&t, &qp);
            let p = QPoolInt::pack_square(PoolKind::Avg, k, stride, pad, &qp)
                .unwrap();
            let got = p.run(&q).unwrap();
            let want = fops::avg_pool2d(&q.dequantize(), k, stride, pad);
            assert_eq!(got.shape, want.shape());
            let diff = got.dequantize().max_abs_diff(&want);
            assert!(
                diff <= qp.scale / 2.0 + 1e-5,
                "avg-pool k={k} s={stride} p={pad} off by {diff}"
            );
        }
    }

    #[test]
    fn pool_pack_rejects_degenerate_windows() {
        let qp = params_for_range(0.0, 1.0, 8, false);
        assert!(QPoolInt::pack_square(PoolKind::Max, 0, 1, 0, &qp).is_err());
        assert!(QPoolInt::pack_square(PoolKind::Max, 2, 0, 0, &qp).is_err());
        assert!(QPoolInt::pack_square(PoolKind::Avg, 2, 1, 2, &qp).is_err());
        // per-axis pad < k: the W axis alone can be degenerate
        assert!(QPoolInt::pack(
            PoolKind::Avg,
            (2, 2),
            (1, 1),
            (0, 2),
            false,
            &qp
        )
        .is_err());
        // non-canonical global form
        assert!(QPoolInt::pack(
            PoolKind::Max,
            (2, 2),
            (1, 1),
            (0, 0),
            true,
            &qp
        )
        .is_err());
    }

    #[test]
    fn rect_and_global_pool_match_oracle() {
        let mut rng = Rng::new(14);
        let t = Tensor::new(&[2, 3, 4, 8], rng.normal_vec(192, 1.0));
        let qp = params_for_range(t.min(), t.max(), 8, false);
        let q = QActTensor::quantize(&t, &qp);
        // rectangular max: exact
        let p = QPoolInt::pack(
            PoolKind::Max,
            (1, 3),
            (1, 2),
            (0, 1),
            false,
            &qp,
        )
        .unwrap();
        let got = p.run(&q).unwrap();
        let want = fops::max_pool2d_rect(
            &q.dequantize(),
            (1, 3),
            (1, 2),
            (0, 1),
        );
        assert_eq!(got.shape, vec![2, 3, 4, 4]);
        assert_eq!(got.dequantize(), want, "rect max-pool must be exact");
        // global avg: full-extent window, within half a step
        let g = QPoolInt::pack(
            PoolKind::Avg,
            (1, 1),
            (1, 1),
            (0, 0),
            true,
            &qp,
        )
        .unwrap();
        let got = g.run(&q).unwrap();
        assert_eq!(got.shape, vec![2, 3, 1, 1]);
        let want = fops::avg_pool2d_rect(
            &q.dequantize(),
            (4, 8),
            (1, 1),
            (0, 0),
        );
        let diff = got.dequantize().max_abs_diff(&want);
        assert!(diff <= qp.scale / 2.0 + 1e-5, "global avg off by {diff}");
        // global max equals gap-free max over all positions
        let gm = QPoolInt::pack(
            PoolKind::Max,
            (1, 1),
            (1, 1),
            (0, 0),
            true,
            &qp,
        )
        .unwrap();
        let got = gm.run(&q).unwrap();
        let want = fops::max_pool2d_rect(
            &q.dequantize(),
            (4, 8),
            (1, 1),
            (0, 0),
        );
        assert_eq!(got.dequantize(), want, "global max must be exact");
    }

    #[test]
    fn gap_int_stays_on_grid() {
        let mut rng = Rng::new(7);
        let t = Tensor::new(&[2, 3, 4, 4], rng.normal_vec(96, 1.0));
        let qp = params_for_range(t.min(), t.max(), 8, false);
        let q = QActTensor::quantize(&t, &qp);
        let g = gap_int(&q).unwrap();
        assert_eq!(g.shape, vec![2, 3]);
        assert_eq!(g.qp, qp);
        let want = fops::global_avg_pool(&q.dequantize());
        let diff = g.dequantize().max_abs_diff(&want);
        assert!(
            diff <= qp.scale / 2.0 + 1e-5,
            "gap off grid mean by {diff} (> half step {})",
            qp.scale / 2.0
        );
    }
}
