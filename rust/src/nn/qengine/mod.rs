//! True int8 execution: a *planned* integer op pipeline over the
//! retained quantisation grids.
//!
//! The f32 engine ([`crate::nn::forward`]) *simulates* quantisation: it
//! computes every op in f32 over fake-quantised values. This module
//! executes the same function on the integer grids themselves, in three
//! layers:
//!
//! * [`gemm`] — the microkernel layer: u8×i8→i32 GEMM with one-time
//!   runtime kernel dispatch ([`gemm::active_kind`]: AVX2 on x86_64,
//!   NEON on aarch64, scalar k-unroll otherwise or under
//!   `DFQ_FORCE_SCALAR=1`), 64-byte-aligned packed weight panels
//!   ([`gemm::PackedB`], built once at plan time), a 4×16 register-tile
//!   inner kernel per SIMD target, and the vectorised
//!   requantise/depthwise-window helpers. Every path is
//!   bitwise-identical to the scalar oracle
//!   [`gemm::qgemm_into_scalar`] (see the module docs for the overflow
//!   and regrouping arguments).
//! * [`kernels`] — mechanism: the packed conv layer over that GEMM,
//!   integer im2col shared with the f32 engine via
//!   [`crate::nn::conv::im2col_into`] (the input zero-point is the
//!   padding value — `zp_in` *represents* 0), gemmlowp zero-point
//!   folding (`Σ(qa-za)(qw-zw) = Σ qa·qw - zw·rowsum - za·colsum +
//!   K·za·zw`, the static half pre-folded into i64 biases at pack time),
//!   fixed-point requantisation (`M = s_in·s_w/s_out` as an i64
//!   multiplier + shift) with fused clamped-ReLU/ReLU6 epilogues and a
//!   shift-only fast path when a channel's multiplier is an exact power
//!   of two, a channel-parallel depthwise direct path (8-wide SIMD
//!   interior spans, scalar padding edges), and the [`kernels::Scratch`]
//!   buffer arena (64-byte-aligned [`crate::util::align::AVec`]
//!   buffers) every plan run recycles across layers.
//! * [`ops`] — the remaining integer ops: requantise-add for residual
//!   connections (both inputs rescaled onto the add-site grid with Q20
//!   fixed-point multipliers and a single shared rounding), integer
//!   global average pooling (i64 accumulate + one rounded division on
//!   the input grid), the int8 linear head (same GEMM, per-output
//!   zero-point folding, exact f32 logits), standalone activation
//!   requantisation, and grid-preserving upsampling.
//! * [`plan`] — policy: [`plan::plan`] compiles the folded graph into a
//!   [`QModel`] — every node resolved to a typed `QOp` with
//!   precomputed multipliers, dense value slots and
//!   free-after-last-use bookkeeping — so the run loop never asks "does
//!   this layer have a grid?". `run_all` is batch-parallel over images,
//!   drawing [`Scratch`] arenas from a per-run pool (at most one grown
//!   arena per worker, recycled across images). A plan also round-trips
//!   through the `.dfqm` compiled-artifact container
//!   ([`crate::artifact`], [`QModel::from_artifact`]) with
//!   bitwise-identical outputs.
//!
//! ## Integer coverage matrix
//!
//! | graph op     | integer lowering                 | fallback (f32 input) |
//! |--------------|----------------------------------|----------------------|
//! | input        | quantise onto site-0 grid        | —                    |
//! | conv (dense) | GEMM + fused requant / f32 out   | fake-quant f32 conv  |
//! | conv (dw)    | direct + fused requant / f32 out | fake-quant f32 conv  |
//! | convT (dense)| zero-insert + flipped-kernel stride-1 GEMM ([`kernels::QConvT`]) | fake-quant f32 convT |
//! | act          | fused into conv, or requantizer  | clip + quantise      |
//! | add          | requantise-add                   | f32 add + quantise   |
//! | concat       | requantise-concat (Q20 per input)| f32 concat + quantise|
//! | gap          | integer mean on input grid       | f32 mean             |
//! | pool2d (max) | exact code max (grid-preserving; square, rect, global) | f32 max-pool |
//! | pool2d (avg) | i64 accumulate + rounded mean (square, rect, global)   | f32 avg-pool |
//! | linear       | GEMM + f32 logits                | f32 linear           |
//! | upsample     | code copy (grid-preserving)      | f32 copy             |
//!
//! ## Kernel dispatch
//!
//! | hot loop            | scalar            | AVX2 (x86_64)             | NEON (aarch64)          |
//! |---------------------|-------------------|---------------------------|-------------------------|
//! | dense GEMM          | 4-wide k-unroll   | 4×16 tile, `madd_epi16`   | 4×16 tile, `vmlal_s16`  |
//! | depthwise interior  | direct window     | 8-wide `mullo_epi32`      | 8-wide `vmlal_s16`      |
//! | depthwise edges     | direct window     | (scalar)                  | (scalar)                |
//! | requantizer (pow2)  | rounding shift    | 16-lane i16 shift         | 16-lane i16 shift       |
//! | requantizer (other) | `apply_mult`      | (scalar)                  | (scalar)                |
//! | conv epilogue       | shift fast path / `apply_mult` | (scalar — position-major acc vs channel-major out would need a gather) | (scalar, ditto) |
//!
//! All SIMD cells are bitwise-identical to their scalar column —
//! enforced by `tests/qengine_parity.rs` property tests over remainder
//! tails, every `EpiSpec`, per-channel and per-tensor grids. Dispatch
//! is pinned to the scalar column by `DFQ_FORCE_SCALAR=1` or
//! [`PlanOpts::force_scalar`].
//!
//! MobileNet-style graphs (convs + depthwise + residual adds + GAP +
//! linear head), inception-style graphs (max-pool stems, multi-branch
//! concat blocks, avg-pool branches), **and** segmentation/detection
//! heads (transposed-conv decoders, rectangular and global max/avg
//! pools, multi-scale concat — `deeplab_head_model`, `ssd_head_model`)
//! therefore plan with **zero** fallback ops; fallbacks only appear when a value genuinely
//! has no quantised grid (e.g. a conv that is itself a model output
//! feeding further layers), are reported by [`QModel::summarize`], and
//! can be rejected outright with [`PlanOpts::int8_only`]. Parity with
//! the fake-quant oracle is one quantisation step per element per op
//! (`tests/qengine_parity.rs`); integer max-pool is exact.

pub mod gemm;
pub mod kernels;
pub mod ops;
pub mod plan;

pub use gemm::{
    active_kind, available_kinds, qgemm, qgemm_into, qgemm_into_kind,
    qgemm_into_scalar, rowsums_u8, rowsums_u8_into, KernelKind,
};
pub use kernels::{
    apply_mult, mult_for, EpiSpec, Mult, QConv, QConvT, Scratch,
};
pub use ops::{
    gap_int, upsample_codes, QAddInt, QConcatInt, QLinear, QPoolInt,
    Requantizer,
};
pub use plan::{plan, AuxGrids, OpStat, PlanOpts, QModel, RunProfile};

use crate::quant::QParams;
use crate::tensor::Tensor;

// -- quantised activation tensors -------------------------------------------

/// A feature map held as u8 grid codes with one per-tensor grid.
#[derive(Debug, Clone, PartialEq)]
pub struct QActTensor {
    pub shape: Vec<usize>,
    pub codes: Vec<u8>,
    pub qp: QParams,
}

pub(crate) fn assert_act_grid(qp: &QParams) {
    assert!(
        (2.0..=256.0).contains(&qp.n_levels),
        "activation grid needs 2..=256 levels, got {}",
        qp.n_levels
    );
    assert!(
        qp.zero_point.fract() == 0.0
            && qp.zero_point >= 0.0
            && qp.zero_point <= qp.n_levels - 1.0,
        "activation zero point {} not an integer on the grid",
        qp.zero_point
    );
}

impl QActTensor {
    /// Quantise an f32 tensor onto `qp` (same rounding as `fake_quant`,
    /// via the shared [`crate::tensor::qtensor::code_of`]).
    pub fn quantize(t: &Tensor, qp: &QParams) -> QActTensor {
        assert_act_grid(qp);
        let codes = t
            .data()
            .iter()
            .map(|&x| crate::tensor::qtensor::code_of(x, qp))
            .collect();
        QActTensor { shape: t.shape().to_vec(), codes, qp: *qp }
    }

    /// Exact f32 image of the codes.
    pub fn dequantize(&self) -> Tensor {
        let zp = self.qp.zero_point;
        let s = self.qp.scale;
        Tensor::new(
            &self.shape,
            self.codes.iter().map(|&q| (q as f32 - zp) * s).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn qact_quantize_dequantize_roundtrip() {
        let mut rng = Rng::new(5);
        let t = Tensor::new(&[2, 3, 4, 4], rng.normal_vec(96, 1.0));
        let qp = crate::quant::params_for_range(t.min(), t.max(), 8, false);
        let q = QActTensor::quantize(&t, &qp);
        assert!(q.dequantize().max_abs_diff(&t) <= qp.scale / 2.0 + 1e-6);
    }
}
