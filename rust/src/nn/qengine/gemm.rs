//! The int8 GEMM microkernel layer: one-time runtime kernel dispatch,
//! packed weight panels, explicit SIMD inner kernels (`std::arch`), and
//! the vectorised requantise/depthwise helpers the packed layers share.
//!
//! # Dispatch table
//!
//! | kind     | target            | selected when                        |
//! |----------|-------------------|--------------------------------------|
//! | `Scalar` | any               | fallback; `DFQ_FORCE_SCALAR=1`; or `PlanOpts::force_scalar` |
//! | `Avx2`   | `x86_64`          | `is_x86_feature_detected!("avx2")`   |
//! | `Neon`   | `aarch64`         | always (NEON is mandatory on A64)    |
//!
//! Detection runs once per process ([`active_kind`], `OnceLock`); plans
//! record their kind at pack time so a single process can host both a
//! forced-scalar plan and a native plan side by side. The scalar path is
//! the row-parallel 4-wide k-unroll from PR 3 and `qgemm_into_scalar`
//! below stays the bitwise-equality oracle for every other path.
//!
//! # Tiling and packing layout
//!
//! The register tile is `MR × NR = 4 × 16`: four GEMM rows against one
//! 16-column weight panel, accumulated entirely in registers (2×ymm or
//! 4×int32x4 per row). Loops run panel-outer / k-slab / row-block-inner:
//! K is blocked in [`KC`]-deep slabs so the active panel slab (≤ 16 KiB)
//! stays L1-resident across the whole M sweep even when `cig·kh·kw`
//! grows past the cache (deep pointwise convs, wide linear heads). The
//! first slab *stores* its register tile, later slabs *load-add* —
//! i32 wrapping addition is associative/commutative, so the slab
//! regrouping of the k-sum is bitwise-invisible, and `KC` is even so
//! slab boundaries never split an AVX2 k-pair (only the final slab may
//! be odd, handled exactly like the old odd-k tail).
//!
//! Weight panels are packed once at plan-build time ([`PackedB`]):
//!
//! * **AVX2** packs `i8 → i16` pairs: for each 16-column panel, k-pairs
//!   are interleaved as `[b(k,j), b(k+1,j)]` per column — 32 i16 = one
//!   64-byte cache line per k-pair. The kernel widens activations the
//!   same way (`a(k) | a(k+1) << 16` broadcast) and uses
//!   `_mm256_madd_epi16`. We deliberately do NOT use the classic
//!   `maddubs` u8×i8 kernel: `_mm256_maddubs_epi16` saturates its i16
//!   pair-sum (max `255·127·2 = 64770 > i16::MAX`), which would break
//!   bitwise equality with the scalar oracle. `madd_epi16` on widened
//!   operands is exact: `|a0·b0 + a1·b1| ≤ 2·255·128 = 65280 < 2^31`,
//!   and i32 wrapping addition is associative/commutative, so regrouping
//!   the k-sum cannot change any output. K-odd tails and N-tail columns
//!   are zero-padded in the panel — zero products are exact.
//! * **NEON** packs k-major `[kk][16 × i8]` rows; the kernel widens with
//!   `vmovl_s8`/`vdup_n_s16` and accumulates via `vmlal_s16`
//!   (i16×i16→i32 multiply-accumulate, exact for these ranges).
//!
//! Per-row zero skips (ReLU sparsity) are carried over from the scalar
//! kernel: skipping an all-zero activation pair adds zero to every lane,
//! which is bitwise-neutral.

use std::sync::OnceLock;

use crate::util::align::AVec;
use crate::util::parallel::{self, SendCells};

use super::kernels::{apply_mult, pow2_shift, round_shift, Mult, ShiftMult};

// -- runtime dispatch --------------------------------------------------------

/// A compiled-in inner-kernel flavour. All variants exist on every
/// target so plans and tests can name them portably; only the kinds in
/// [`available_kinds`] may actually run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// Row-parallel scalar 4-wide k-unroll (the reference dispatch
    /// target; also what `DFQ_FORCE_SCALAR=1` pins).
    Scalar,
    /// x86_64 AVX2 `madd_epi16` microkernel on pair-packed i16 panels.
    Avx2,
    /// aarch64 NEON `vmlal_s16` microkernel on k-major i8 panels.
    Neon,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
            KernelKind::Neon => "neon",
        }
    }
}

fn env_force_scalar() -> bool {
    matches!(std::env::var("DFQ_FORCE_SCALAR"), Ok(v) if !v.is_empty() && v != "0")
}

fn detect() -> KernelKind {
    if env_force_scalar() {
        return KernelKind::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return KernelKind::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return KernelKind::Neon;
        }
    }
    KernelKind::Scalar
}

/// The kernel kind new plans pack for, detected once per process.
/// `DFQ_FORCE_SCALAR=1` (read at first use) pins this to
/// [`KernelKind::Scalar`]; per-plan forcing without env games goes
/// through `PlanOpts::force_scalar`.
pub fn active_kind() -> KernelKind {
    static KIND: OnceLock<KernelKind> = OnceLock::new();
    *KIND.get_or_init(detect)
}

/// Every kind this binary can actually run on this host (scalar first).
/// The dispatch property tests sweep this list against the scalar
/// oracle.
pub fn available_kinds() -> Vec<KernelKind> {
    let mut kinds = vec![KernelKind::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            kinds.push(KernelKind::Avx2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            kinds.push(KernelKind::Neon);
        }
    }
    kinds
}

/// Whether `kind` is compiled in *and* runnable on this host.
pub fn kind_supported(kind: KernelKind) -> bool {
    available_kinds().contains(&kind)
}

// -- packed weight panels ----------------------------------------------------

/// Panel width (output channels per panel) shared by every SIMD kernel.
pub(crate) const NR: usize = 16;
/// Register-tile height (GEMM rows per inner-kernel call).
pub(crate) const MR: usize = 4;
/// K-dimension cache-blocking depth: one panel slab is `KC × NR` codes
/// (16 KiB of i16 pairs on AVX2, 8 KiB of i8 on NEON), sized to sit in
/// L1 alongside the activation rows. Must stay even — AVX2 panels
/// interleave k-pairs, and an odd slab boundary would split one.
pub(crate) const KC: usize = 512;

/// A weight matrix re-laid-out for one SIMD kernel kind. Derived state:
/// rebuilt from the canonical row-major `w` after plan build or artifact
/// decode, never serialized. `Scalar` plans keep it empty.
#[derive(Debug, Clone)]
pub struct PackedB {
    pub(crate) kind: KernelKind,
    /// AVX2 pair-interleaved panels (64-byte aligned).
    i16s: AVec<i16>,
    /// NEON k-major panels (64-byte aligned).
    i8s: AVec<i8>,
    k: usize,
    n: usize,
    /// K rounded up to even (AVX2 pair layout).
    kp: usize,
}

impl Default for PackedB {
    fn default() -> PackedB {
        PackedB::empty()
    }
}

impl PackedB {
    /// A panel-less placeholder (scalar plans, depthwise convs).
    pub fn empty() -> PackedB {
        PackedB {
            kind: KernelKind::Scalar,
            i16s: AVec::new(),
            i8s: AVec::new(),
            k: 0,
            n: 0,
            kp: 0,
        }
    }

    /// Pack row-major `b[k × n]` into `kind`'s panel layout.
    pub fn pack(kind: KernelKind, b: &[i8], k: usize, n: usize) -> PackedB {
        assert!(b.len() == k * n, "PackedB::pack: bad weight buffer");
        assert!(kind_supported(kind), "PackedB::pack: {kind:?} unavailable");
        let mut pb = PackedB::empty();
        pb.kind = kind;
        pb.k = k;
        pb.n = n;
        pb.kp = k + (k & 1);
        let panels = n.div_ceil(NR);
        match kind {
            KernelKind::Scalar => {}
            KernelKind::Avx2 => {
                // layout: [panel][k-pair][j·2 + (kk&1)], zero-padded on
                // both the odd-k row and the n-tail columns
                pb.i16s.resize(panels * pb.kp * NR, 0);
                for pn in 0..panels {
                    let base = pn * pb.kp * NR;
                    for kk in 0..k {
                        let row = base + (kk / 2) * 2 * NR + (kk & 1);
                        for j in 0..NR {
                            let col = pn * NR + j;
                            if col < n {
                                pb.i16s[row + j * 2] = b[kk * n + col] as i16;
                            }
                        }
                    }
                }
            }
            KernelKind::Neon => {
                // layout: [panel][kk][16 × i8], zero-padded n-tail
                pb.i8s.resize(panels * k * NR, 0);
                for pn in 0..panels {
                    let base = pn * k * NR;
                    for kk in 0..k {
                        for j in 0..NR {
                            let col = pn * NR + j;
                            if col < n {
                                pb.i8s[base + kk * NR + j] = b[kk * n + col];
                            }
                        }
                    }
                }
            }
        }
        pb
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.kind == KernelKind::Scalar
    }
}

// -- GEMM entry points -------------------------------------------------------

/// C[m,n] = A[m,k] · B[k,n] with u8 activations × i8 weights → i32
/// accumulators, written into the caller's buffer, using the process'
/// [`active_kind`]. SIMD kinds pack `b` on the fly — the packed layers
/// ([`super::QConv`] / [`super::QLinear`]) pre-pack at plan build and go
/// through [`qgemm_packed_into`] instead. Bitwise-identical to
/// [`qgemm_into_scalar`] for every dispatch target (see module docs).
pub fn qgemm_into(a: &[u8], b: &[i8], m: usize, k: usize, n: usize, c: &mut [i32]) {
    qgemm_into_kind(active_kind(), a, b, m, k, n, c);
}

/// [`qgemm_into`] with an explicit kernel kind — the dispatch property
/// tests and per-kernel benches drive every compiled-in path through
/// this. Panics if `kind` is not runnable on this host.
pub fn qgemm_into_kind(
    kind: KernelKind,
    a: &[u8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [i32],
) {
    assert!(c.len() == m * n, "qgemm_into: bad output buffer");
    match kind {
        KernelKind::Scalar => {
            c.fill(0);
            let cells = parallel::as_send_cells(c);
            parallel::par_chunks(m, |lo, hi| {
                for i in lo..hi {
                    let arow = &a[i * k..(i + 1) * k];
                    // SAFETY: rows [lo, hi) written by this chunk only.
                    let crow = unsafe { cells.slice(i * n, n) };
                    qgemm_row_unrolled(arow, b, k, n, crow);
                }
            });
        }
        _ => {
            let pb = PackedB::pack(kind, b, k, n);
            qgemm_packed_into(a, &pb, m, c);
        }
    }
}

/// Packed-panel GEMM driver: `c[m × pb.n] = a[m × pb.k] · B`, row-block
/// parallel, panel-outer so each 16-column panel stays cache-resident
/// across the M loop. Fully overwrites `c` (the kernels store, they do
/// not accumulate into memory).
pub(crate) fn qgemm_packed_into(a: &[u8], pb: &PackedB, m: usize, c: &mut [i32]) {
    let (k, n) = (pb.k, pb.n);
    assert!(c.len() == m * n, "qgemm_packed_into: bad output buffer");
    assert!(a.len() >= m * k, "qgemm_packed_into: bad activation buffer");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0);
        return;
    }
    let cells = parallel::as_send_cells(c);
    parallel::par_chunks(m, |lo, hi| match pb.kind {
        KernelKind::Scalar => {
            unreachable!("scalar plans carry no packed panels")
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: pack() checked AVX2 availability; rows [lo, hi) of c
        // are written by this chunk only.
        KernelKind::Avx2 => unsafe { avx2::gemm_rows(a, pb, lo, hi, &cells) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above, for NEON.
        KernelKind::Neon => unsafe { neon::gemm_rows(a, pb, lo, hi, &cells) },
        #[allow(unreachable_patterns)]
        _ => unreachable!("kernel kind not compiled for this target"),
    });
}

/// One GEMM row, k unrolled by 4: every iteration loads four activation
/// codes, skips fully-zero blocks, and accumulates the four partial
/// products into a register before the single store back to `crow[j]`.
/// The scalar tail handles `k % 4` trailing elements with the per-element
/// zero skip of the original loop.
#[inline]
fn qgemm_row_unrolled(arow: &[u8], b: &[i8], k: usize, n: usize, crow: &mut [i32]) {
    let mut kk = 0usize;
    while kk + 4 <= k {
        let a0 = arow[kk] as i32;
        let a1 = arow[kk + 1] as i32;
        let a2 = arow[kk + 2] as i32;
        let a3 = arow[kk + 3] as i32;
        if (a0 | a1 | a2 | a3) == 0 {
            kk += 4;
            continue;
        }
        let b0 = &b[kk * n..(kk + 1) * n];
        let b1 = &b[(kk + 1) * n..(kk + 2) * n];
        let b2 = &b[(kk + 2) * n..(kk + 3) * n];
        let b3 = &b[(kk + 3) * n..(kk + 4) * n];
        for j in 0..n {
            let mut t = crow[j];
            t += a0 * b0[j] as i32;
            t += a1 * b1[j] as i32;
            t += a2 * b2[j] as i32;
            t += a3 * b3[j] as i32;
            crow[j] = t;
        }
        kk += 4;
    }
    for kt in kk..k {
        let av = arow[kt] as i32;
        if av == 0 {
            continue;
        }
        let brow = &b[kt * n..(kt + 1) * n];
        for j in 0..n {
            crow[j] += av * brow[j] as i32;
        }
    }
}

/// Reference scalar GEMM loop: the bitwise-equality oracle every
/// dispatch target (including the unrolled scalar path) is tested
/// against.
pub fn qgemm_into_scalar(
    a: &[u8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [i32],
) {
    assert!(c.len() == m * n, "qgemm_into_scalar: bad output buffer");
    c.fill(0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j] as i32;
            }
        }
    }
}

/// Allocating wrapper around [`qgemm_into`].
pub fn qgemm(a: &[u8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    qgemm_into(a, b, m, k, n, &mut c);
    c
}

/// Per-row sums of a u8 matrix (the gemmlowp rowsum correction input),
/// written into the caller's buffer.
pub fn rowsums_u8_into(a: &[u8], m: usize, k: usize, out: &mut [i32]) {
    assert!(out.len() == m, "rowsums_u8_into: bad output buffer");
    for (i, o) in out.iter_mut().enumerate() {
        *o = a[i * k..(i + 1) * k].iter().map(|&v| v as i32).sum();
    }
}

/// Allocating wrapper around [`rowsums_u8_into`].
pub fn rowsums_u8(a: &[u8], m: usize, k: usize) -> Vec<i32> {
    let mut out = vec![0i32; m];
    rowsums_u8_into(a, m, k, &mut out);
    out
}

// -- vectorised requantise ---------------------------------------------------

/// Requantise a full code plane: `dst[i] = clamp(round((src[i] − z_in) ·
/// M) + zp_out, q_lo, q_hi)`. When `M` is an exact power of two with a
/// right shift in `1..=15` and a SIMD kind is active, a 16-lane i16
/// shift kernel runs (`t = q − z_in ∈ [−255, 255]` fits i16; `|t| +
/// 2^(s−1) ≤ 255 + 2^14` never overflows); a *generic* fixed-point
/// multiplier takes the 8-lane 64-bit-product kernel
/// ([`requant_i32`]) through a stack-chunked i32 widening of the
/// codes; everything else is a scalar loop with the same shift
/// classification. Bitwise-identical every way: the shift idiom
/// `sign(t) · ((|t| + half) >> s)` and the generic rounding divide
/// both reproduce the scalar round-half-away-from-zero exactly.
pub(crate) fn requant_codes(
    src: &[u8],
    dst: &mut [u8],
    m: &Mult,
    z_in: i32,
    zp_out: i32,
    q_lo: i32,
    q_hi: i32,
) {
    assert!(dst.len() == src.len(), "requant_codes: bad output buffer");
    let shift = pow2_shift(m);
    if let Some(ShiftMult::Right(s)) = shift {
        if (1..=15).contains(&s) {
            match active_kind() {
                #[cfg(target_arch = "x86_64")]
                KernelKind::Avx2 => {
                    let head = src.len() - src.len() % 16;
                    // SAFETY: active_kind() checked AVX2 availability.
                    unsafe {
                        avx2::requant_shift(
                            &src[..head],
                            &mut dst[..head],
                            s,
                            z_in,
                            zp_out,
                            q_lo,
                            q_hi,
                        );
                    }
                    requant_scalar(
                        &src[head..],
                        &mut dst[head..],
                        m,
                        z_in,
                        zp_out,
                        q_lo,
                        q_hi,
                    );
                    return;
                }
                #[cfg(target_arch = "aarch64")]
                KernelKind::Neon => {
                    let head = src.len() - src.len() % 16;
                    // SAFETY: active_kind() checked NEON availability.
                    unsafe {
                        neon::requant_shift(
                            &src[..head],
                            &mut dst[..head],
                            s,
                            z_in,
                            zp_out,
                            q_lo,
                            q_hi,
                        );
                    }
                    requant_scalar(
                        &src[head..],
                        &mut dst[head..],
                        m,
                        z_in,
                        zp_out,
                        q_lo,
                        q_hi,
                    );
                    return;
                }
                _ => {}
            }
        }
    }
    if let Mult::Fixed { m: mf, shift } = *m {
        // generic (non-pow2) fixed-point multiplier: widen the codes to
        // i32 in stack chunks and run the 64-bit-product SIMD kernel.
        // z_in ∈ [0, 255] keeps |t| ≤ 255 and shift ≥ 9 then bounds
        // |round(t·mf·2^-shift)| < 2^30, so the scalar path's `as i32`
        // truncation is the identity and both paths stay
        // bitwise-identical (degenerate multipliers stay scalar).
        if mf > 0
            && (9..=62).contains(&shift)
            && (0..=255).contains(&z_in)
            && active_kind() != KernelKind::Scalar
        {
            const CHUNK: usize = 128;
            let mut t = [0i32; CHUNK];
            for (sc, dc) in src.chunks(CHUNK).zip(dst.chunks_mut(CHUNK)) {
                for (ti, &q) in t.iter_mut().zip(sc) {
                    *ti = q as i32 - z_in;
                }
                requant_i32(&t[..sc.len()], dc, mf, shift, zp_out, q_lo, q_hi);
            }
            return;
        }
    }
    requant_scalar(src, dst, m, z_in, zp_out, q_lo, q_hi);
}

/// Requantise a contiguous i32 plane with a generic fixed-point
/// multiplier: `dst[i] = clamp(round(src[i] · m · 2^-shift) + zp_out,
/// q_lo, q_hi)`, round half away from zero, the add/clamp in the i64
/// domain (never truncated through i32 first) — exactly the dense conv
/// epilogue's scalar arithmetic. Exact for every i32 input: `|src[i]| <
/// 2^31` and `m < 2^31` keep the product below `2^62`, inside i64, so
/// the SIMD lanes are bitwise-equal to [`apply_mult`]'s i128 reference.
/// `q_lo/q_hi` must lie in `[0, 255]` (u8 output grid). Requires
/// `m > 0` and `shift ∈ 1..=62` (the `mult_for` envelope).
pub(crate) fn requant_i32(
    src: &[i32],
    dst: &mut [u8],
    m: i32,
    shift: u32,
    zp_out: i32,
    q_lo: i32,
    q_hi: i32,
) {
    assert!(dst.len() == src.len(), "requant_i32: bad output buffer");
    assert!(
        m > 0 && (1..=62).contains(&shift),
        "requant_i32: multiplier outside the fixed-point envelope"
    );
    let mu = Mult::Fixed { m, shift };
    let scalar = |src: &[i32], dst: &mut [u8]| {
        for (d, &t) in dst.iter_mut().zip(src) {
            let q = (apply_mult(t as i64, &mu) + zp_out as i64)
                .clamp(q_lo as i64, q_hi as i64);
            *d = q as u8;
        }
    };
    match active_kind() {
        #[cfg(target_arch = "x86_64")]
        KernelKind::Avx2 => {
            let head = src.len() - src.len() % 8;
            // SAFETY: active_kind() checked AVX2 availability.
            unsafe {
                avx2::requant_mul(
                    &src[..head],
                    &mut dst[..head],
                    m,
                    shift,
                    zp_out,
                    q_lo,
                    q_hi,
                );
            }
            scalar(&src[head..], &mut dst[head..]);
        }
        #[cfg(target_arch = "aarch64")]
        KernelKind::Neon => {
            let head = src.len() - src.len() % 8;
            // SAFETY: active_kind() checked NEON availability.
            unsafe {
                neon::requant_mul(
                    &src[..head],
                    &mut dst[..head],
                    m,
                    shift,
                    zp_out,
                    q_lo,
                    q_hi,
                );
            }
            scalar(&src[head..], &mut dst[head..]);
        }
        _ => scalar(src, dst),
    }
}

fn requant_scalar(
    src: &[u8],
    dst: &mut [u8],
    m: &Mult,
    z_in: i32,
    zp_out: i32,
    q_lo: i32,
    q_hi: i32,
) {
    match pow2_shift(m) {
        Some(ShiftMult::Right(s)) => {
            for (d, &q) in dst.iter_mut().zip(src) {
                let t = (q as i32 - z_in) as i64;
                let v = round_shift(t, s) as i32;
                *d = (v + zp_out).clamp(q_lo, q_hi) as u8;
            }
        }
        _ => {
            for (d, &q) in dst.iter_mut().zip(src) {
                let t = (q as i32 - z_in) as i64;
                let v = apply_mult(t, m) as i32;
                *d = (v + zp_out).clamp(q_lo, q_hi) as u8;
            }
        }
    }
}

// -- depthwise span kernel ---------------------------------------------------

/// Accumulate one depthwise window over 8 consecutive output columns
/// (stride 1, fully in-bounds): `acc[e] += Σ_taps q·w`, `sx[e] += Σ_taps
/// q`, for `e ∈ 0..8`, where `codes[base + dy·wd + dx + e]` addresses
/// tap `(dy, dx)` of output column `e`. SIMD lanes accumulate in i32;
/// the caller must guarantee `kh·kw ≤ 65_000` so every partial sum stays
/// under `2^31` (`|Σ| ≤ taps · 255·128`), which makes the i32 lanes
/// bitwise-equal to the scalar i64 accumulation.
pub(crate) fn dw_span8(
    kind: KernelKind,
    codes: &[u8],
    base: usize,
    wd: usize,
    kh: usize,
    kw: usize,
    wch: &[i8],
    acc: &mut [i32; 8],
    sx: &mut [i32; 8],
) {
    debug_assert!(base + (kh - 1) * wd + kw - 1 + 7 < codes.len());
    match kind {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the conv guards dispatch — kind came from active_kind.
        KernelKind::Avx2 => unsafe {
            avx2::dw8(codes.as_ptr().add(base), wd, kh, kw, wch, acc, sx)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: as above.
        KernelKind::Neon => unsafe {
            neon::dw8(codes.as_ptr().add(base), wd, kh, kw, wch, acc, sx)
        },
        _ => {
            for (dy, wrow) in wch.chunks_exact(kw).enumerate().take(kh) {
                for (dx, &w) in wrow.iter().enumerate() {
                    let src = base + dy * wd + dx;
                    for e in 0..8 {
                        let q = codes[src + e] as i32;
                        acc[e] += q * w as i32;
                        sx[e] += q;
                    }
                }
            }
        }
    }
}

// -- x86_64 AVX2 kernels -----------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{PackedB, SendCells, KC, MR, NR};
    use std::arch::x86_64::*;

    /// # Safety
    /// AVX2 must be available; rows `[lo, hi)` of the output must be
    /// exclusively owned by this call.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm_rows(
        a: &[u8],
        pb: &PackedB,
        lo: usize,
        hi: usize,
        cells: &SendCells<i32>,
    ) {
        let (k, n, kp) = (pb.k, pb.n, pb.kp);
        let panels = pb.i16s.as_ptr();
        for pn in 0..n.div_ceil(NR) {
            let panel = panels.add(pn * kp * NR);
            let j0 = pn * NR;
            let width = NR.min(n - j0);
            // k-slabs: KC is even, so a slab of the pair-interleaved
            // panel starts at element offset k0·NR and only the final
            // slab can carry an odd tail
            let mut k0 = 0usize;
            while k0 < k {
                let klen = KC.min(k - k0);
                let pslab = panel.add(k0 * NR);
                let arow = a.as_ptr().add(k0);
                let mut i = lo;
                if k0 == 0 {
                    while i + MR <= hi {
                        mk::<MR, false>(arow.add(i * k), k, klen, pslab, cells.ptr_at(i * n + j0), n, width);
                        i += MR;
                    }
                    while i < hi {
                        mk::<1, false>(arow.add(i * k), k, klen, pslab, cells.ptr_at(i * n + j0), n, width);
                        i += 1;
                    }
                } else {
                    while i + MR <= hi {
                        mk::<MR, true>(arow.add(i * k), k, klen, pslab, cells.ptr_at(i * n + j0), n, width);
                        i += MR;
                    }
                    while i < hi {
                        mk::<1, true>(arow.add(i * k), k, klen, pslab, cells.ptr_at(i * n + j0), n, width);
                        i += 1;
                    }
                }
                k0 += klen;
            }
        }
    }

    /// `R × 16` register tile over one k-slab: two i32 ymm accumulators
    /// per row, one broadcast activation pair per k-pair, `madd_epi16`
    /// dot products. `ACC = false` stores the tile into `c` (first
    /// slab), `ACC = true` load-adds (later slabs); `width < NR` spills
    /// through a stack buffer.
    ///
    /// # Safety
    /// AVX2; `a` addresses `R` rows of stride `stride` and at least `k`
    /// valid codes each; `panel` holds `k.next_multiple_of(2) × NR`
    /// i16s; `c` addresses an `R × width` tile of stride `n`.
    #[target_feature(enable = "avx2")]
    unsafe fn mk<const R: usize, const ACC: bool>(
        a: *const u8,
        stride: usize,
        k: usize,
        panel: *const i16,
        c: *mut i32,
        n: usize,
        width: usize,
    ) {
        let mut acc = [[_mm256_setzero_si256(); 2]; R];
        let pairs = k / 2;
        for p in 0..pairs {
            let b_lo = _mm256_loadu_si256(panel.add(p * 2 * NR) as *const __m256i);
            let b_hi = _mm256_loadu_si256(panel.add(p * 2 * NR + NR) as *const __m256i);
            for r in 0..R {
                let a0 = *a.add(r * stride + 2 * p) as u32;
                let a1 = *a.add(r * stride + 2 * p + 1) as u32;
                let pair = (a0 | (a1 << 16)) as i32;
                if pair == 0 {
                    continue; // adding zero to every lane is exact
                }
                let av = _mm256_set1_epi32(pair);
                acc[r][0] = _mm256_add_epi32(acc[r][0], _mm256_madd_epi16(av, b_lo));
                acc[r][1] = _mm256_add_epi32(acc[r][1], _mm256_madd_epi16(av, b_hi));
            }
        }
        if k % 2 == 1 {
            // final odd k: the packed pair row is (b[k-1], 0)
            let b_lo = _mm256_loadu_si256(panel.add(pairs * 2 * NR) as *const __m256i);
            let b_hi = _mm256_loadu_si256(panel.add(pairs * 2 * NR + NR) as *const __m256i);
            for r in 0..R {
                let a0 = *a.add(r * stride + k - 1) as u32;
                if a0 == 0 {
                    continue;
                }
                let av = _mm256_set1_epi32(a0 as i32);
                acc[r][0] = _mm256_add_epi32(acc[r][0], _mm256_madd_epi16(av, b_lo));
                acc[r][1] = _mm256_add_epi32(acc[r][1], _mm256_madd_epi16(av, b_hi));
            }
        }
        if width == NR {
            for r in 0..R {
                let (p0, p1) = (c.add(r * n) as *mut __m256i, c.add(r * n + 8) as *mut __m256i);
                let (mut v0, mut v1) = (acc[r][0], acc[r][1]);
                if ACC {
                    v0 = _mm256_add_epi32(_mm256_loadu_si256(p0), v0);
                    v1 = _mm256_add_epi32(_mm256_loadu_si256(p1), v1);
                }
                _mm256_storeu_si256(p0, v0);
                _mm256_storeu_si256(p1, v1);
            }
        } else {
            let mut buf = [0i32; NR];
            for r in 0..R {
                _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, acc[r][0]);
                _mm256_storeu_si256(buf.as_mut_ptr().add(8) as *mut __m256i, acc[r][1]);
                if ACC {
                    for (j, &v) in buf.iter().enumerate().take(width) {
                        *c.add(r * n + j) = (*c.add(r * n + j)).wrapping_add(v);
                    }
                } else {
                    std::ptr::copy_nonoverlapping(buf.as_ptr(), c.add(r * n), width);
                }
            }
        }
    }

    /// 16-lane power-of-two requantise: `sign(t)·((|t| + 2^(s−1)) >> s)`
    /// on i16 lanes, then add-zp / clamp / narrow.
    ///
    /// # Safety
    /// AVX2; `src.len() == dst.len()` and a multiple of 16; `1 ≤ s ≤ 15`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn requant_shift(
        src: &[u8],
        dst: &mut [u8],
        s: u32,
        z_in: i32,
        zp_out: i32,
        q_lo: i32,
        q_hi: i32,
    ) {
        let z = _mm256_set1_epi16(z_in as i16);
        let zp = _mm256_set1_epi16(zp_out as i16);
        let lo = _mm256_set1_epi16(q_lo as i16);
        let hi = _mm256_set1_epi16(q_hi as i16);
        let half = _mm256_set1_epi16(1 << (s - 1));
        let cnt = _mm_cvtsi32_si128(s as i32);
        for (sc, dc) in src.chunks_exact(16).zip(dst.chunks_exact_mut(16)) {
            let q8 = _mm_loadu_si128(sc.as_ptr() as *const __m128i);
            let t = _mm256_sub_epi16(_mm256_cvtepu8_epi16(q8), z);
            // |t| ≤ 255, + half ≤ 255 + 2^14: no i16 overflow; srl on a
            // non-negative value is the arithmetic shift
            let v = _mm256_srl_epi16(_mm256_add_epi16(_mm256_abs_epi16(t), half), cnt);
            let r = _mm256_sign_epi16(v, t); // 0 when t == 0, as scalar
            let q = _mm256_add_epi16(r, zp);
            let q = _mm256_min_epi16(_mm256_max_epi16(q, lo), hi);
            // pack 16 i16 → 16 u8 (exact: q ∈ [q_lo, q_hi] ⊆ [0, 255])
            let p = _mm256_packus_epi16(q, q);
            let p = _mm256_permute4x64_epi64::<0b11011000>(p);
            _mm_storeu_si128(
                dc.as_mut_ptr() as *mut __m128i,
                _mm256_castsi256_si128(p),
            );
        }
    }

    /// Broadcast constants of the generic fixed-point requant kernel.
    struct RqConst {
        maskv: __m256i,
        thr0: __m256i,
        zp: __m256i,
        lo: __m256i,
        hi: __m256i,
        cs: __m128i,
        cinv: __m128i,
    }

    /// `clamp(round(p · 2^-s) + zp, lo, hi)` on 4 i64 lanes, round half
    /// away from zero. AVX2 has no 64-bit arithmetic shift: emulate as
    /// `srl(p, s) | sll(sign_smear, 64−s)`, then add 1 where the kept
    /// remainder clears the sign-adjusted halfway mark — the gemmlowp
    /// rounding-divide identity, bitwise-equal to the i128 scalar.
    ///
    /// # Safety
    /// AVX2.
    #[target_feature(enable = "avx2")]
    #[inline]
    unsafe fn rq_lane4(p: __m256i, c: &RqConst) -> __m256i {
        let zero = _mm256_setzero_si256();
        let negm = _mm256_cmpgt_epi64(zero, p);
        let sh = _mm256_or_si256(
            _mm256_srl_epi64(p, c.cs),
            _mm256_sll_epi64(negm, c.cinv),
        );
        let rem = _mm256_and_si256(p, c.maskv);
        // threshold is (mask >> 1) + 1 for negative p (negm = −1)
        let thr = _mm256_sub_epi64(c.thr0, negm);
        let up = _mm256_cmpgt_epi64(rem, thr);
        let v = _mm256_add_epi64(_mm256_sub_epi64(sh, up), c.zp);
        // clamp while still in the i64 domain (the scalar reference
        // never truncates before clamping)
        let v = _mm256_blendv_epi8(v, c.lo, _mm256_cmpgt_epi64(c.lo, v));
        _mm256_blendv_epi8(v, c.hi, _mm256_cmpgt_epi64(v, c.hi))
    }

    /// 8-lane generic fixed-point requantise: exact 64-bit products
    /// `t·m` via `mul_epi32` on sign-extended lanes, the [`rq_lane4`]
    /// rounding divide + clamp, exact narrowing. Deliberately avoids
    /// `mulhrs`-style idioms: the full i64 product sidesteps their
    /// half-up-only rounding, keeping every lane bitwise-equal to the
    /// scalar i128 reference.
    ///
    /// # Safety
    /// AVX2; `src.len() == dst.len()` and a multiple of 8; `m > 0`;
    /// `1 ≤ s ≤ 62`; `[q_lo, q_hi] ⊆ [0, 255]`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn requant_mul(
        src: &[i32],
        dst: &mut [u8],
        m: i32,
        s: u32,
        zp_out: i32,
        q_lo: i32,
        q_hi: i32,
    ) {
        let mask = (1i64 << s) - 1;
        let c = RqConst {
            maskv: _mm256_set1_epi64x(mask),
            thr0: _mm256_set1_epi64x(mask >> 1),
            zp: _mm256_set1_epi64x(zp_out as i64),
            lo: _mm256_set1_epi64x(q_lo as i64),
            hi: _mm256_set1_epi64x(q_hi as i64),
            cs: _mm_cvtsi32_si128(s as i32),
            cinv: _mm_cvtsi32_si128(64 - s as i32),
        };
        let mv = _mm256_set1_epi64x(m as i64);
        for (sc, dc) in src.chunks_exact(8).zip(dst.chunks_exact_mut(8)) {
            let t = _mm256_loadu_si256(sc.as_ptr() as *const __m256i);
            let t_lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(t));
            let t_hi =
                _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(t));
            // the low dword of each sign-extended lane IS the i32
            // value, so mul_epi32 (signed 32×32→64) is the exact t·m
            let q_a = rq_lane4(_mm256_mul_epi32(t_lo, mv), &c);
            let q_b = rq_lane4(_mm256_mul_epi32(t_hi, mv), &c);
            // 2×4 i64 → 8 ordered i32: clamped values fit [0, 255], so
            // keeping each lane's low dword is exact
            let a32 = _mm256_shuffle_epi32::<0b11_01_10_00>(q_a);
            let b32 = _mm256_shuffle_epi32::<0b11_01_10_00>(q_b);
            let v32 = _mm256_permute4x64_epi64::<0b11_01_10_00>(
                _mm256_unpacklo_epi64(a32, b32),
            );
            // 8 i32 → 8 u8 (saturating packs are exact in [0, 255])
            let p16 = _mm256_permute4x64_epi64::<0b11011000>(
                _mm256_packs_epi32(v32, v32),
            );
            let p8 = _mm_packus_epi16(
                _mm256_castsi256_si128(p16),
                _mm256_castsi256_si128(p16),
            );
            _mm_storel_epi64(dc.as_mut_ptr() as *mut __m128i, p8);
        }
    }

    /// 8-wide depthwise window accumulate (see [`super::dw_span8`]).
    ///
    /// # Safety
    /// AVX2; `codes` addresses every tap of all 8 columns; `wch` holds
    /// `kh·kw` weights.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dw8(
        codes: *const u8,
        wd: usize,
        kh: usize,
        kw: usize,
        wch: &[i8],
        acc_out: &mut [i32; 8],
        sx_out: &mut [i32; 8],
    ) {
        let mut acc = _mm256_loadu_si256(acc_out.as_ptr() as *const __m256i);
        let mut sx = _mm256_loadu_si256(sx_out.as_ptr() as *const __m256i);
        for dy in 0..kh {
            for dx in 0..kw {
                let q8 = _mm_loadl_epi64(codes.add(dy * wd + dx) as *const __m128i);
                let q = _mm256_cvtepu8_epi32(q8);
                let w = _mm256_set1_epi32(wch[dy * kw + dx] as i32);
                acc = _mm256_add_epi32(acc, _mm256_mullo_epi32(q, w));
                sx = _mm256_add_epi32(sx, q);
            }
        }
        _mm256_storeu_si256(acc_out.as_mut_ptr() as *mut __m256i, acc);
        _mm256_storeu_si256(sx_out.as_mut_ptr() as *mut __m256i, sx);
    }
}

// -- aarch64 NEON kernels ----------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{PackedB, SendCells, KC, MR, NR};
    use std::arch::aarch64::*;

    /// # Safety
    /// NEON must be available; rows `[lo, hi)` of the output must be
    /// exclusively owned by this call.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemm_rows(
        a: &[u8],
        pb: &PackedB,
        lo: usize,
        hi: usize,
        cells: &SendCells<i32>,
    ) {
        let (k, n) = (pb.k, pb.n);
        let panels = pb.i8s.as_ptr();
        for pn in 0..n.div_ceil(NR) {
            let panel = panels.add(pn * k * NR);
            let j0 = pn * NR;
            let width = NR.min(n - j0);
            // k-slabs over the k-major panel: slab offset is k0·NR
            let mut k0 = 0usize;
            while k0 < k {
                let klen = KC.min(k - k0);
                let pslab = panel.add(k0 * NR);
                let arow = a.as_ptr().add(k0);
                let mut i = lo;
                if k0 == 0 {
                    while i + MR <= hi {
                        mk::<MR, false>(arow.add(i * k), k, klen, pslab, cells.ptr_at(i * n + j0), n, width);
                        i += MR;
                    }
                    while i < hi {
                        mk::<1, false>(arow.add(i * k), k, klen, pslab, cells.ptr_at(i * n + j0), n, width);
                        i += 1;
                    }
                } else {
                    while i + MR <= hi {
                        mk::<MR, true>(arow.add(i * k), k, klen, pslab, cells.ptr_at(i * n + j0), n, width);
                        i += MR;
                    }
                    while i < hi {
                        mk::<1, true>(arow.add(i * k), k, klen, pslab, cells.ptr_at(i * n + j0), n, width);
                        i += 1;
                    }
                }
                k0 += klen;
            }
        }
    }

    /// `R × 16` register tile over one k-slab: four int32x4 accumulators
    /// per row, `vmovl_s8`-widened panel rows, `vmlal_s16` against the
    /// broadcast activation. `ACC = false` stores the tile into `c`
    /// (first slab), `ACC = true` load-adds (later slabs).
    ///
    /// # Safety
    /// NEON; `a` addresses `R` rows of stride `stride` and at least `k`
    /// valid codes each; `panel` holds `k × NR` i8s; `c` addresses an
    /// `R × width` tile of stride `n`.
    #[target_feature(enable = "neon")]
    unsafe fn mk<const R: usize, const ACC: bool>(
        a: *const u8,
        stride: usize,
        k: usize,
        panel: *const i8,
        c: *mut i32,
        n: usize,
        width: usize,
    ) {
        let mut acc = [[vdupq_n_s32(0); 4]; R];
        for kk in 0..k {
            let bv = vld1q_s8(panel.add(kk * NR));
            let b_lo = vmovl_s8(vget_low_s8(bv));
            let b_hi = vmovl_s8(vget_high_s8(bv));
            for r in 0..R {
                let av = *a.add(r * stride + kk);
                if av == 0 {
                    continue; // adding zero to every lane is exact
                }
                let ad = vdup_n_s16(av as i16);
                acc[r][0] = vmlal_s16(acc[r][0], vget_low_s16(b_lo), ad);
                acc[r][1] = vmlal_s16(acc[r][1], vget_high_s16(b_lo), ad);
                acc[r][2] = vmlal_s16(acc[r][2], vget_low_s16(b_hi), ad);
                acc[r][3] = vmlal_s16(acc[r][3], vget_high_s16(b_hi), ad);
            }
        }
        if width == NR {
            for r in 0..R {
                for (q, &v) in acc[r].iter().enumerate() {
                    let p = c.add(r * n + 4 * q);
                    let v = if ACC { vaddq_s32(vld1q_s32(p), v) } else { v };
                    vst1q_s32(p, v);
                }
            }
        } else {
            let mut buf = [0i32; NR];
            for r in 0..R {
                for (q, &v) in acc[r].iter().enumerate() {
                    vst1q_s32(buf.as_mut_ptr().add(4 * q), v);
                }
                if ACC {
                    for (j, &v) in buf.iter().enumerate().take(width) {
                        *c.add(r * n + j) = (*c.add(r * n + j)).wrapping_add(v);
                    }
                } else {
                    std::ptr::copy_nonoverlapping(buf.as_ptr(), c.add(r * n), width);
                }
            }
        }
    }

    /// 16-lane power-of-two requantise (see the AVX2 twin for the
    /// bounds argument).
    ///
    /// # Safety
    /// NEON; `src.len() == dst.len()` and a multiple of 16; `1 ≤ s ≤ 15`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn requant_shift(
        src: &[u8],
        dst: &mut [u8],
        s: u32,
        z_in: i32,
        zp_out: i32,
        q_lo: i32,
        q_hi: i32,
    ) {
        let z = vdupq_n_s16(z_in as i16);
        let zp = vdupq_n_s16(zp_out as i16);
        let lo = vdupq_n_s16(q_lo as i16);
        let hi = vdupq_n_s16(q_hi as i16);
        let half = vdupq_n_s16(1 << (s - 1));
        let neg_s = vdupq_n_s16(-(s as i16));
        let zero = vdupq_n_s16(0);
        for (sc, dc) in src.chunks_exact(16).zip(dst.chunks_exact_mut(16)) {
            let q8 = vld1q_u8(sc.as_ptr());
            let halves = [
                vreinterpretq_s16_u16(vmovl_u8(vget_low_u8(q8))),
                vreinterpretq_s16_u16(vmovl_u8(vget_high_u8(q8))),
            ];
            let mut out = [vdup_n_u8(0); 2];
            for (o, &h) in out.iter_mut().zip(&halves) {
                let t = vsubq_s16(h, z);
                // non-negative, so the arithmetic right shift (vshl by
                // a negative count) is the truncating division
                let v = vshlq_s16(vaddq_s16(vabsq_s16(t), half), neg_s);
                let r = vbslq_s16(vcltq_s16(t, zero), vnegq_s16(v), v);
                let q = vaddq_s16(r, zp);
                let q = vminq_s16(vmaxq_s16(q, lo), hi);
                *o = vqmovun_s16(q); // exact: q ∈ [q_lo, q_hi] ⊆ [0, 255]
            }
            vst1q_u8(dc.as_mut_ptr(), vcombine_u8(out[0], out[1]));
        }
    }

    /// Broadcast constants of the generic fixed-point requant kernel.
    struct RqConst {
        mask: int64x2_t,
        thr0: int64x2_t,
        neg_s: int64x2_t,
        zp: int64x2_t,
        lo: int64x2_t,
        hi: int64x2_t,
    }

    /// `clamp(round(p · 2^-s) + zp, lo, hi)` on 2 i64 lanes, round half
    /// away from zero: arithmetic shift via a negative `vshlq_s64`
    /// count, then add 1 where the kept remainder clears the
    /// sign-adjusted halfway mark — the gemmlowp rounding-divide
    /// identity, bitwise-equal to the i128 scalar.
    ///
    /// # Safety
    /// NEON.
    #[target_feature(enable = "neon")]
    #[inline]
    unsafe fn rq_lane2(p: int64x2_t, c: &RqConst) -> int64x2_t {
        let negm = vcltq_s64(p, vdupq_n_s64(0));
        let sh = vshlq_s64(p, c.neg_s);
        let rem = vandq_s64(p, c.mask);
        // threshold is (mask >> 1) + 1 for negative p (negm = −1)
        let thr = vsubq_s64(c.thr0, vreinterpretq_s64_u64(negm));
        let up = vcgtq_s64(rem, thr);
        let v = vaddq_s64(vsubq_s64(sh, vreinterpretq_s64_u64(up)), c.zp);
        // clamp while still in the i64 domain (the scalar reference
        // never truncates before clamping)
        let v = vbslq_s64(vcltq_s64(v, c.lo), c.lo, v);
        vbslq_s64(vcgtq_s64(v, c.hi), c.hi, v)
    }

    /// 8-lane generic fixed-point requantise (see the AVX2 twin for the
    /// rounding identity): exact `vmull_s32` 64-bit products, the
    /// [`rq_lane2`] rounding divide + clamp, exact narrowing.
    ///
    /// # Safety
    /// NEON; `src.len() == dst.len()` and a multiple of 8; `m > 0`;
    /// `1 ≤ s ≤ 62`; `[q_lo, q_hi] ⊆ [0, 255]`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn requant_mul(
        src: &[i32],
        dst: &mut [u8],
        m: i32,
        s: u32,
        zp_out: i32,
        q_lo: i32,
        q_hi: i32,
    ) {
        let mask = (1i64 << s) - 1;
        let c = RqConst {
            mask: vdupq_n_s64(mask),
            thr0: vdupq_n_s64(mask >> 1),
            neg_s: vdupq_n_s64(-(s as i64)),
            zp: vdupq_n_s64(zp_out as i64),
            lo: vdupq_n_s64(q_lo as i64),
            hi: vdupq_n_s64(q_hi as i64),
        };
        let mv = vdup_n_s32(m);
        for (sc, dc) in src.chunks_exact(8).zip(dst.chunks_exact_mut(8)) {
            let t_a = vld1q_s32(sc.as_ptr());
            let t_b = vld1q_s32(sc.as_ptr().add(4));
            let q0 = rq_lane2(vmull_s32(vget_low_s32(t_a), mv), &c);
            let q1 = rq_lane2(vmull_s32(vget_high_s32(t_a), mv), &c);
            let q2 = rq_lane2(vmull_s32(vget_low_s32(t_b), mv), &c);
            let q3 = rq_lane2(vmull_s32(vget_high_s32(t_b), mv), &c);
            // i64 → i32 → i16 truncation is exact: clamped values fit
            // [0, 255]
            let v_a = vcombine_s32(vmovn_s64(q0), vmovn_s64(q1));
            let v_b = vcombine_s32(vmovn_s64(q2), vmovn_s64(q3));
            let p16 = vcombine_s16(vmovn_s32(v_a), vmovn_s32(v_b));
            vst1_u8(dc.as_mut_ptr(), vqmovun_s16(p16));
        }
    }

    /// 8-wide depthwise window accumulate (see [`super::dw_span8`]).
    ///
    /// # Safety
    /// NEON; `codes` addresses every tap of all 8 columns; `wch` holds
    /// `kh·kw` weights.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dw8(
        codes: *const u8,
        wd: usize,
        kh: usize,
        kw: usize,
        wch: &[i8],
        acc_out: &mut [i32; 8],
        sx_out: &mut [i32; 8],
    ) {
        let mut acc = [
            vld1q_s32(acc_out.as_ptr()),
            vld1q_s32(acc_out.as_ptr().add(4)),
        ];
        let mut sx = [
            vld1q_s32(sx_out.as_ptr()),
            vld1q_s32(sx_out.as_ptr().add(4)),
        ];
        for dy in 0..kh {
            for dx in 0..kw {
                let q8 = vld1_u8(codes.add(dy * wd + dx));
                let q16 = vreinterpretq_s16_u16(vmovl_u8(q8));
                let w = vdup_n_s16(wch[dy * kw + dx] as i16);
                acc[0] = vmlal_s16(acc[0], vget_low_s16(q16), w);
                acc[1] = vmlal_s16(acc[1], vget_high_s16(q16), w);
                sx[0] = vaddw_s16(sx[0], vget_low_s16(q16));
                sx[1] = vaddw_s16(sx[1], vget_high_s16(q16));
            }
        }
        vst1q_s32(acc_out.as_mut_ptr(), acc[0]);
        vst1q_s32(acc_out.as_mut_ptr().add(4), acc[1]);
        vst1q_s32(sx_out.as_mut_ptr(), sx[0]);
        vst1q_s32(sx_out.as_mut_ptr().add(4), sx[1]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_case(rng: &mut Rng, m: usize, k: usize, n: usize) -> (Vec<u8>, Vec<i8>) {
        let mut a: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
        // plant zeros so the skip branches execute
        for v in a.iter_mut().step_by(3) {
            *v = 0;
        }
        let b: Vec<i8> =
            (0..k * n).map(|_| (rng.below(256) as i64 - 128) as i8).collect();
        (a, b)
    }

    #[test]
    fn detection_is_stable_and_scalar_is_available() {
        assert_eq!(active_kind(), active_kind());
        let kinds = available_kinds();
        assert_eq!(kinds[0], KernelKind::Scalar);
        assert!(kinds.contains(&active_kind()));
    }

    #[test]
    fn every_available_kind_matches_the_scalar_oracle() {
        let mut rng = Rng::new(9000);
        // remainder tails on every axis: m % MR, n % NR, k odd
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (4, 16, 16),
            (5, 17, 16),
            (4, 16, 17),
            (3, 2, 35),
            (7, 31, 13),
            (9, 33, 31),
            (13, 64, 48),
            (2, 1, 16),
            (8, 18, 1),
            // K-blocking: k > KC with exact-multiple, odd-tail and
            // ragged-n shapes (2 and 4 slabs)
            (3, 2 * KC, 16),
            (5, KC + 1, 21),
            (6, 3 * KC + 1, 17),
        ] {
            let (a, b) = random_case(&mut rng, m, k, n);
            let mut want = vec![0i32; m * n];
            qgemm_into_scalar(&a, &b, m, k, n, &mut want);
            for kind in available_kinds() {
                let mut got = vec![-1i32; m * n];
                qgemm_into_kind(kind, &a, &b, m, k, n, &mut got);
                assert_eq!(got, want, "{kind:?} diverged at ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn k_blocked_slabs_match_the_scalar_oracle_bitwise() {
        // deep-K shapes force the multi-slab store/load-add path; the
        // slab regrouping of the wrapping i32 k-sum must be invisible
        let mut rng = Rng::new(9004);
        for &(m, k, n) in &[
            (1usize, KC + 1, 1usize), // single row, odd final slab
            (MR, 2 * KC, NR),         // exact tiles, exact slabs
            (MR + 1, 2 * KC + 7, NR + 3), // every tail at once
        ] {
            let (a, b) = random_case(&mut rng, m, k, n);
            let mut want = vec![0i32; m * n];
            qgemm_into_scalar(&a, &b, m, k, n, &mut want);
            for kind in available_kinds() {
                let mut got = vec![-1i32; m * n];
                qgemm_into_kind(kind, &a, &b, m, k, n, &mut got);
                assert_eq!(got, want, "{kind:?} diverged at ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn packed_panels_are_aligned_and_prepack_matches_otf() {
        let mut rng = Rng::new(9001);
        let (m, k, n) = (6usize, 19usize, 21usize);
        let (a, b) = random_case(&mut rng, m, k, n);
        let mut want = vec![0i32; m * n];
        qgemm_into_scalar(&a, &b, m, k, n, &mut want);
        for kind in available_kinds() {
            if kind == KernelKind::Scalar {
                continue;
            }
            let pb = PackedB::pack(kind, &b, k, n);
            assert_eq!(pb.i16s.as_ptr() as usize % 64, 0);
            assert_eq!(pb.i8s.as_ptr() as usize % 64, 0);
            let mut got = vec![0i32; m * n];
            qgemm_packed_into(&a, &pb, m, &mut got);
            assert_eq!(got, want, "prepacked {kind:?} diverged");
        }
    }

    #[test]
    fn requant_codes_matches_scalar_for_pow2_and_generic() {
        let mut rng = Rng::new(9002);
        let src: Vec<u8> = (0..1000).map(|_| rng.below(256) as u8).collect();
        let cases = [
            Mult::Fixed { m: 1 << 30, shift: 33 }, // pow2: SIMD shift path
            Mult::Fixed { m: 1 << 30, shift: 31 },
            Mult::Fixed { m: (1 << 30) + 12345, shift: 33 }, // generic
            mult_for_test(0.437),
        ];
        for mu in &cases {
            for &(z_in, zp_out, q_lo, q_hi) in
                &[(0i32, 0i32, 0i32, 255i32), (128, 3, 0, 255), (7, 128, 5, 250)]
            {
                let mut got = vec![0u8; src.len()];
                requant_codes(&src, &mut got, mu, z_in, zp_out, q_lo, q_hi);
                for (i, &q) in src.iter().enumerate() {
                    let t = (q as i32 - z_in) as i64;
                    let want =
                        (apply_mult(t, mu) as i32 + zp_out).clamp(q_lo, q_hi);
                    assert_eq!(
                        got[i] as i32, want,
                        "requant {mu:?} z_in={z_in} diverged at {i}"
                    );
                }
            }
        }
    }

    fn mult_for_test(x: f64) -> Mult {
        super::super::kernels::mult_for(x)
    }

    #[test]
    fn requant_i32_matches_apply_mult_bitwise() {
        let mut rng = Rng::new(9005);
        let mut src: Vec<i32> =
            vec![0, 1, -1, 255, -255, i32::MAX, i32::MIN, 1 << 20, -(1 << 20)];
        for _ in 0..503 {
            // full-range i32, odd length so the SIMD tail runs
            src.push(rng.below(1 << 32) as u32 as i32);
        }
        for _ in 0..16 {
            let m = ((1usize << 30) + rng.below(1 << 30)) as i32;
            let shift = (1 + rng.below(62)) as u32;
            let mu = Mult::Fixed { m, shift };
            for &(zp, q_lo, q_hi) in &[(0, 0, 255), (128, 3, 250)] {
                let mut got = vec![0u8; src.len()];
                requant_i32(&src, &mut got, m, shift, zp, q_lo, q_hi);
                for (i, &t) in src.iter().enumerate() {
                    let want = (apply_mult(t as i64, &mu) + zp as i64)
                        .clamp(q_lo as i64, q_hi as i64)
                        as u8;
                    assert_eq!(
                        got[i], want,
                        "requant_i32 m={m} shift={shift} diverged at {i} \
                         (t={t})"
                    );
                }
            }
        }
    }

    #[test]
    fn requant_codes_generic_mults_cover_all_codes_bitwise() {
        let mut rng = Rng::new(9006);
        let src: Vec<u8> = (0u8..=255).collect();
        for _ in 0..32 {
            // random non-pow2 mantissa in [2^30, 2^31), shift across the
            // whole SIMD window 9..=62, random grids
            let m = ((1usize << 30) + rng.below(1 << 30)) as i32;
            let shift = (9 + rng.below(54)) as u32;
            let mu = Mult::Fixed { m, shift };
            let z_in = rng.below(256) as i32;
            let zp_out = rng.below(256) as i32;
            let mut got = vec![0u8; src.len()];
            requant_codes(&src, &mut got, &mu, z_in, zp_out, 0, 255);
            let mut want = vec![0u8; src.len()];
            requant_scalar(&src, &mut want, &mu, z_in, zp_out, 0, 255);
            assert_eq!(
                got, want,
                "generic requant m={m} shift={shift} z_in={z_in} diverged"
            );
        }
    }

    #[test]
    fn dw_span8_matches_scalar_reference() {
        let mut rng = Rng::new(9003);
        let (h, wd, kh, kw) = (6usize, 14usize, 3usize, 3usize);
        let codes: Vec<u8> = (0..h * wd).map(|_| rng.below(256) as u8).collect();
        let wch: Vec<i8> =
            (0..kh * kw).map(|_| (rng.below(256) as i64 - 128) as i8).collect();
        let base = wd + 2; // window fully in bounds for 8 columns
        let (mut acc_s, mut sx_s) = ([3i32; 8], [-1i32; 8]);
        dw_span8(KernelKind::Scalar, &codes, base, wd, kh, kw, &wch, &mut acc_s, &mut sx_s);
        for kind in available_kinds() {
            let (mut acc, mut sx) = ([3i32; 8], [-1i32; 8]);
            dw_span8(kind, &codes, base, wd, kh, kw, &wch, &mut acc, &mut sx);
            assert_eq!(acc, acc_s, "{kind:?} dw acc diverged");
            assert_eq!(sx, sx_s, "{kind:?} dw sx diverged");
        }
    }
}
