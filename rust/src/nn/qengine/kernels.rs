//! Integer compute kernels: the u8×i8→i32 GEMM, fixed-point
//! requantisation multipliers, the shared scratch arena, and the packed
//! convolution layer ([`QConv`]) with its fused epilogues.
//!
//! Everything here is *mechanism*; policy (which kernel runs where, on
//! which grid) lives in the plan compiler ([`super::plan`]).

use anyhow::{anyhow, bail, Result};

use crate::nn::conv::im2col_into;
use crate::nn::SiteCfg;
use crate::quant::QParams;
use crate::tensor::{QTensor, Tensor};
use crate::util::parallel;

use super::{assert_act_grid, QActTensor};

// -- scratch arena -----------------------------------------------------------

/// Reusable per-run scratch buffers: im2col patches, GEMM accumulators
/// and row sums. The plan executor allocates one `Scratch` per
/// `run_batch` call and recycles it across every layer (buffers grow to
/// the largest layer once, then stop allocating).
#[derive(Debug, Default)]
pub struct Scratch {
    pub(crate) col: Vec<u8>,
    pub(crate) acc: Vec<i32>,
    pub(crate) rows: Vec<i32>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }
}

// -- integer GEMM primitives ------------------------------------------------

/// C[m,n] = A[m,k] · B[k,n] with u8 activations × i8 weights → i32
/// accumulators, written into the caller's buffer. Row-parallel chunking
/// as in the f32 [`crate::nn::conv::matmul`]; the inner kernel is a
/// 4-wide k-unroll ([`qgemm_row_unrolled`]) that keeps each output
/// element in a register across the four partial products. The all-zero
/// block skip exploits ReLU sparsity (post-ReLU grids have `zp == 0`, so
/// code 0 is exactly value 0). Results are bitwise-identical to the
/// scalar saxpy loop: i32 wrapping addition is associative and
/// commutative, so regrouping the k-sum cannot change any output.
pub fn qgemm_into(a: &[u8], b: &[i8], m: usize, k: usize, n: usize, c: &mut [i32]) {
    assert!(c.len() == m * n, "qgemm_into: bad output buffer");
    c.fill(0);
    let cells = parallel::as_send_cells(c);
    parallel::par_chunks(m, |lo, hi| {
        for i in lo..hi {
            let arow = &a[i * k..(i + 1) * k];
            // SAFETY: rows [lo, hi) are written by this chunk only.
            let crow = unsafe { cells.slice(i * n, n) };
            qgemm_row_unrolled(arow, b, k, n, crow);
        }
    });
}

/// One GEMM row, k unrolled by 4: every iteration loads four activation
/// codes, skips fully-zero blocks, and accumulates the four partial
/// products into a register before the single store back to `crow[j]`.
/// The scalar tail handles `k % 4` trailing elements with the per-element
/// zero skip of the original loop.
#[inline]
fn qgemm_row_unrolled(arow: &[u8], b: &[i8], k: usize, n: usize, crow: &mut [i32]) {
    let mut kk = 0usize;
    while kk + 4 <= k {
        let a0 = arow[kk] as i32;
        let a1 = arow[kk + 1] as i32;
        let a2 = arow[kk + 2] as i32;
        let a3 = arow[kk + 3] as i32;
        if (a0 | a1 | a2 | a3) == 0 {
            kk += 4;
            continue;
        }
        let b0 = &b[kk * n..(kk + 1) * n];
        let b1 = &b[(kk + 1) * n..(kk + 2) * n];
        let b2 = &b[(kk + 2) * n..(kk + 3) * n];
        let b3 = &b[(kk + 3) * n..(kk + 4) * n];
        for j in 0..n {
            let mut t = crow[j];
            t += a0 * b0[j] as i32;
            t += a1 * b1[j] as i32;
            t += a2 * b2[j] as i32;
            t += a3 * b3[j] as i32;
            crow[j] = t;
        }
        kk += 4;
    }
    for kt in kk..k {
        let av = arow[kt] as i32;
        if av == 0 {
            continue;
        }
        let brow = &b[kt * n..(kt + 1) * n];
        for j in 0..n {
            crow[j] += av * brow[j] as i32;
        }
    }
}

/// Reference scalar GEMM row loop (the pre-unroll kernel), kept for the
/// bitwise-equivalence tests and the kernel benches.
pub fn qgemm_into_scalar(
    a: &[u8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [i32],
) {
    assert!(c.len() == m * n, "qgemm_into_scalar: bad output buffer");
    c.fill(0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j] as i32;
            }
        }
    }
}

/// Allocating wrapper around [`qgemm_into`].
pub fn qgemm(a: &[u8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    qgemm_into(a, b, m, k, n, &mut c);
    c
}

/// Per-row sums of a u8 matrix (the gemmlowp rowsum correction input),
/// written into the caller's buffer.
pub fn rowsums_u8_into(a: &[u8], m: usize, k: usize, out: &mut [i32]) {
    assert!(out.len() == m, "rowsums_u8_into: bad output buffer");
    for (i, o) in out.iter_mut().enumerate() {
        *o = a[i * k..(i + 1) * k].iter().map(|&v| v as i32).sum();
    }
}

/// Allocating wrapper around [`rowsums_u8_into`].
pub fn rowsums_u8(a: &[u8], m: usize, k: usize) -> Vec<i32> {
    let mut out = vec![0i32; m];
    rowsums_u8_into(a, m, k, &mut out);
    out
}

// -- fixed-point requantisation ---------------------------------------------

/// A positive real multiplier `M` as `m · 2^-shift` with `m ∈ [2^30,
/// 2^31)`; degenerate magnitudes fall back to f64 rounding.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mult {
    Fixed { m: i32, shift: u32 },
    Float(f64),
}

/// Decompose `x > 0` into the i64 fixed-point form.
pub fn mult_for(x: f64) -> Mult {
    if !x.is_finite() || x <= 0.0 {
        return Mult::Float(x.max(0.0));
    }
    let mut v = x;
    let mut e = 0i32;
    while v < 0.5 {
        v *= 2.0;
        e -= 1;
    }
    while v >= 1.0 {
        v /= 2.0;
        e += 1;
    }
    let mut m = (v * (1u64 << 31) as f64).round() as i64;
    let mut shift = 31 - e;
    if m == 1i64 << 31 {
        m >>= 1;
        shift -= 1;
    }
    if !(1..=62).contains(&shift) {
        return Mult::Float(x);
    }
    Mult::Fixed { m: m as i32, shift: shift as u32 }
}

/// `round(t · M)` (round half away from zero for the fixed-point form —
/// within the engine's one-step tolerance of the oracle's ties-to-even).
#[inline]
pub fn apply_mult(t: i64, m: &Mult) -> i64 {
    match *m {
        Mult::Fixed { m, shift } => {
            let prod = t as i128 * m as i128;
            let half = 1i128 << (shift - 1);
            let r = if prod >= 0 {
                (prod + half) >> shift
            } else {
                -((-prod + half) >> shift)
            };
            r as i64
        }
        Mult::Float(f) => (t as f64 * f).round() as i64,
    }
}

/// Integer clamp bounds implementing a site's clipped-ReLU on its output
/// grid: `q_lo = clamp(zp, 0, n-1)` (value 0 after the ReLU floor),
/// `q_hi` from the site's `clip_hi` (ReLU6) or the grid ceiling.
pub(crate) fn act_clamp(row: &SiteCfg, out_qp: &QParams) -> (i32, i32) {
    let zp_out = out_qp.zero_point as i32;
    let n_hi = out_qp.n_levels as i32 - 1;
    let q_lo = zp_out.clamp(0, n_hi);
    let q_hi = if row.clip_hi.is_finite() {
        (zp_out + (row.clip_hi / row.scale).round() as i32).clamp(q_lo, n_hi)
    } else {
        n_hi
    };
    (q_lo, q_hi)
}

// -- packed convolution layers ----------------------------------------------

/// How a packed conv finishes.
#[derive(Debug, Clone, Copy)]
pub enum EpiSpec<'a> {
    /// No integer epilogue: i32 accumulate, exact f32 output
    /// ([`QConv::run_f32`]) — for convs whose value must stay f32
    /// (model outputs).
    F32,
    /// Fused activation site: requantise onto the site grid with the
    /// clamped-ReLU/ReLU6 bounds folded into the integer clamp.
    Act(&'a SiteCfg),
    /// Plain requantisation onto a grid with *no* activation (clamp is
    /// the grid's own `[0, n-1]`): residual-branch convs land on their
    /// pre-activation grid before the integer add.
    Grid(QParams),
}

/// Per-output-channel weight-grid folding shared by the GEMM packers
/// ([`QConv::pack`], `QLinear::pack`).
pub(crate) struct FoldedWeights {
    /// i8 codes laid out for the kernel: (K, O) when transposed (dense
    /// GEMM / linear head), O-major otherwise (depthwise direct).
    pub w: Vec<i8>,
    /// Signed-storage weight zero point (`zp_w - 128`) per out channel.
    pub zp_w: Vec<i32>,
    pub s_w: Vec<f32>,
    /// `-zp_in·colsum[o] + K·zp_in·zp_w[o]` per out channel (the static
    /// half of the gemmlowp zero-point identity).
    pub zp_corr: Vec<i64>,
}

/// Fold a retained weight tensor for integer execution: signed-storage
/// zero points, per-channel scales (per-tensor grids broadcast), the
/// static gemmlowp correction constants, and the kernel layout. `per`
/// is the reduction length per output channel (`cig·kh·kw` / `in_dim`).
pub(crate) fn fold_weight_grids(
    w: &QTensor,
    c_out: usize,
    per: usize,
    in_qp: &QParams,
    transpose: bool,
) -> Result<FoldedWeights> {
    let codes = w.codes_i8().ok_or_else(|| {
        anyhow!(
            "integer packing wants signed (i8) weight codes, got {}",
            w.storage()
        )
    })?;
    let zp_in = in_qp.zero_point as i64;
    let mut zp_w = Vec::with_capacity(c_out);
    let mut s_w = Vec::with_capacity(c_out);
    let mut zp_corr = Vec::with_capacity(c_out);
    for o in 0..c_out {
        let p = w.param_for_channel(o);
        let z = p.zero_point as i32 - 128;
        zp_w.push(z);
        s_w.push(p.scale);
        let colsum: i64 = codes[o * per..(o + 1) * per]
            .iter()
            .map(|&v| v as i64)
            .sum();
        zp_corr.push(-zp_in * colsum + per as i64 * zp_in * z as i64);
    }
    let w_packed = if transpose {
        let mut wt = vec![0i8; per * c_out];
        for o in 0..c_out {
            for kk in 0..per {
                wt[kk * c_out + o] = codes[o * per + kk];
            }
        }
        wt
    } else {
        codes.to_vec()
    };
    Ok(FoldedWeights { w: w_packed, zp_w, s_w, zp_corr })
}

/// Fused requant epilogue: integer bias (zero-point corrections + the
/// f32 bias folded onto the accumulator grid), per-channel multipliers,
/// and the clamp implementing both the output grid and (when fused with
/// an activation) the clipped-ReLU bounds. Fields are crate-visible so
/// the artifact codec ([`crate::artifact`]) can ship and rebuild packed
/// layers bit-for-bit without re-deriving anything from f32.
#[derive(Debug, Clone)]
pub(crate) struct Epilogue {
    /// `round(b/(s_in·s_w)) - zp_in·colsum + K·zp_in·zp_w` per channel.
    pub(crate) bias_q: Vec<i64>,
    /// `s_in·s_w[o]/s_out` per channel.
    pub(crate) mult: Vec<Mult>,
    pub(crate) zp_out: i32,
    pub(crate) q_lo: i32,
    pub(crate) q_hi: i32,
    pub(crate) out_qp: QParams,
}

fn make_epilogue(
    bias: &[f32],
    s_w: &[f32],
    zp_corr: &[i64],
    in_qp: &QParams,
    out_qp: QParams,
    q_lo: i32,
    q_hi: i32,
) -> Epilogue {
    let c_out = bias.len();
    let mut bias_q = Vec::with_capacity(c_out);
    let mut mult = Vec::with_capacity(c_out);
    for o in 0..c_out {
        let acc_scale = in_qp.scale as f64 * s_w[o] as f64;
        bias_q.push((bias[o] as f64 / acc_scale).round() as i64 + zp_corr[o]);
        mult.push(mult_for(acc_scale / out_qp.scale as f64));
    }
    Epilogue {
        bias_q,
        mult,
        zp_out: out_qp.zero_point as i32,
        q_lo,
        q_hi,
        out_qp,
    }
}

/// One conv layer packed for integer execution: offset i8 weight codes,
/// per-channel grids, zero-point correction constants, and (when
/// requantising) the fused [`Epilogue`].
#[derive(Debug, Clone)]
pub struct QConv {
    pub(crate) c_out: usize,
    pub(crate) cig: usize,
    pub(crate) kh: usize,
    pub(crate) kw: usize,
    pub(crate) stride: usize,
    pub(crate) pad: usize,
    pub(crate) groups: usize,
    /// groups == 1: transposed (kdim, c_out) for the GEMM;
    /// depthwise: O-major (c, kh·kw).
    pub(crate) w: Vec<i8>,
    /// Signed-storage weight zero point (`zp_w - 128`) per out channel.
    pub(crate) zp_w: Vec<i32>,
    pub(crate) s_w: Vec<f32>,
    /// `-zp_in·colsum[o] + K·zp_in·zp_w[o]` per out channel.
    pub(crate) zp_corr: Vec<i64>,
    pub(crate) bias_f: Vec<f32>,
    pub(crate) in_qp: QParams,
    pub(crate) epi: Option<Epilogue>,
}

impl QConv {
    /// Pack one conv layer. `w` must hold signed (i8) codes with OIHW
    /// shape; `in_qp` is the grid of the layer's input feature map.
    /// `epi` selects the epilogue: [`EpiSpec::Act`] fuses the consuming
    /// activation site (requant + clamped-ReLU bounds), [`EpiSpec::Grid`]
    /// requantises onto a plain grid (residual branches), and
    /// [`EpiSpec::F32`] keeps the exact f32 output ([`QConv::run_f32`]).
    pub fn pack(
        w: &QTensor,
        bias: &[f32],
        stride: usize,
        pad: usize,
        groups: usize,
        in_qp: &QParams,
        epi: EpiSpec,
    ) -> Result<QConv> {
        let shape = w.shape();
        if shape.len() != 4 {
            bail!("QConv wants OIHW weights, got {:?}", shape);
        }
        let (c_out, cig, kh, kw) = (shape[0], shape[1], shape[2], shape[3]);
        if groups != 1 && (cig != 1 || groups != c_out) {
            bail!("QConv supports dense or depthwise grouping only");
        }
        if bias.len() != c_out {
            bail!("bias len {} != out channels {}", bias.len(), c_out);
        }
        assert_act_grid(in_qp);
        let per = cig * kh * kw;
        // dense GEMM wants (kdim, c_out); depthwise stays O-major
        let fw = fold_weight_grids(w, c_out, per, in_qp, groups == 1)?;

        let epi = match epi {
            EpiSpec::F32 => None,
            EpiSpec::Act(row) => {
                if !(2.0..=256.0).contains(&row.n_levels) {
                    bail!(
                        "fused epilogue needs a quantised site \
                         (2..=256 levels), got {}",
                        row.n_levels
                    );
                }
                let out_qp = QParams {
                    scale: row.scale,
                    zero_point: row.zero_point,
                    n_levels: row.n_levels,
                };
                assert_act_grid(&out_qp);
                let (q_lo, q_hi) = act_clamp(row, &out_qp);
                Some(make_epilogue(
                    bias, &fw.s_w, &fw.zp_corr, in_qp, out_qp, q_lo, q_hi,
                ))
            }
            EpiSpec::Grid(out_qp) => {
                assert_act_grid(&out_qp);
                let n_hi = out_qp.n_levels as i32 - 1;
                Some(make_epilogue(
                    bias, &fw.s_w, &fw.zp_corr, in_qp, out_qp, 0, n_hi,
                ))
            }
        };

        Ok(QConv {
            c_out,
            cig,
            kh,
            kw,
            stride,
            pad,
            groups,
            w: fw.w,
            zp_w: fw.zp_w,
            s_w: fw.s_w,
            zp_corr: fw.zp_corr,
            bias_f: bias.to_vec(),
            in_qp: *in_qp,
            epi,
        })
    }

    pub fn out_channels(&self) -> usize {
        self.c_out
    }

    /// Does this layer requantise (u8 out) rather than emit exact f32?
    pub fn is_fused(&self) -> bool {
        self.epi.is_some()
    }

    pub fn is_depthwise(&self) -> bool {
        self.groups > 1
    }

    /// Output grid when the layer requantises.
    pub fn out_params(&self) -> Option<QParams> {
        self.epi.as_ref().map(|e| e.out_qp)
    }

    fn check_input(&self, x: &QActTensor) -> Result<(usize, usize, usize)> {
        if x.qp != self.in_qp {
            bail!(
                "input grid mismatch: layer packed for {:?}, got {:?}",
                self.in_qp,
                x.qp
            );
        }
        if x.shape.len() != 4 || x.shape[1] != self.cig * self.groups {
            bail!(
                "input shape {:?} incompatible with conv ({} channels)",
                x.shape,
                self.cig * self.groups
            );
        }
        Ok((x.shape[0], x.shape[2], x.shape[3]))
    }

    /// Integer accumulators + im2col row sums for one image into the
    /// scratch arena (dense path) — the shared front half of both run
    /// paths.
    fn accumulate_dense(
        &self,
        x: &QActTensor,
        img: usize,
        h: usize,
        wd: usize,
        oh: usize,
        ow: usize,
        scratch: &mut Scratch,
    ) {
        let kdim = self.cig * self.kh * self.kw;
        let ohw = oh * ow;
        im2col_into(
            &x.codes,
            self.cig,
            h,
            wd,
            img,
            self.kh,
            self.kw,
            self.stride,
            self.pad,
            oh,
            ow,
            self.in_qp.zero_point as u8,
            &mut scratch.col[..ohw * kdim],
        );
        rowsums_u8_into(
            &scratch.col[..ohw * kdim],
            ohw,
            kdim,
            &mut scratch.rows[..ohw],
        );
        qgemm_into(
            &scratch.col[..ohw * kdim],
            &self.w,
            ohw,
            kdim,
            self.c_out,
            &mut scratch.acc[..ohw * self.c_out],
        );
    }

    fn reserve(&self, scratch: &mut Scratch, oh: usize, ow: usize) {
        let kdim = self.cig * self.kh * self.kw;
        let ohw = oh * ow;
        if scratch.col.len() < ohw * kdim {
            scratch.col.resize(ohw * kdim, 0);
        }
        if scratch.acc.len() < ohw * self.c_out {
            scratch.acc.resize(ohw * self.c_out, 0);
        }
        if scratch.rows.len() < ohw {
            scratch.rows.resize(ohw, 0);
        }
    }

    /// Fused path: u8 in → u8 out on the packed output grid
    /// (convenience wrapper allocating its own scratch).
    pub fn run_q(&self, x: &QActTensor) -> Result<QActTensor> {
        self.run_q_with(x, &mut Scratch::new())
    }

    /// Fused path over a caller-provided scratch arena.
    pub fn run_q_with(
        &self,
        x: &QActTensor,
        scratch: &mut Scratch,
    ) -> Result<QActTensor> {
        let epi = self
            .epi
            .as_ref()
            .ok_or_else(|| anyhow!("QConv not packed with a fused epilogue"))?;
        let (n, h, wd) = self.check_input(x)?;
        let oh = (h + 2 * self.pad - self.kh) / self.stride + 1;
        let ow = (wd + 2 * self.pad - self.kw) / self.stride + 1;
        let ohw = oh * ow;
        let mut out = vec![0u8; n * self.c_out * ohw];

        if self.groups == 1 {
            self.reserve(scratch, oh, ow);
            for img in 0..n {
                self.accumulate_dense(x, img, h, wd, oh, ow, scratch);
                let base = img * self.c_out * ohw;
                for o in 0..self.c_out {
                    let zpw = self.zp_w[o] as i64;
                    let bq = epi.bias_q[o];
                    let m = &epi.mult[o];
                    let dst = &mut out[base + o * ohw..base + (o + 1) * ohw];
                    for (p, d) in dst.iter_mut().enumerate() {
                        let t = scratch.acc[p * self.c_out + o] as i64
                            - zpw * scratch.rows[p] as i64
                            + bq;
                        let q = (apply_mult(t, m) + epi.zp_out as i64)
                            .clamp(epi.q_lo as i64, epi.q_hi as i64);
                        *d = q as u8;
                    }
                }
            }
        } else {
            let requant = |c: usize, t: i64| {
                let q = (apply_mult(t + epi.bias_q[c], &epi.mult[c])
                    + epi.zp_out as i64)
                    .clamp(epi.q_lo as i64, epi.q_hi as i64);
                q as u8
            };
            self.depthwise(x, n, h, wd, oh, ow, requant, &mut out);
        }
        Ok(QActTensor {
            shape: vec![n, self.c_out, oh, ow],
            codes: out,
            qp: epi.out_qp,
        })
    }

    /// Unfused path: u8 in → exact f32 pre-activation output (integer
    /// accumulate, float epilogue). Matches the f32 oracle's conv output
    /// on the same fake-quantised operands up to f32 rounding
    /// (convenience wrapper allocating its own scratch).
    pub fn run_f32(&self, x: &QActTensor) -> Result<Tensor> {
        self.run_f32_with(x, &mut Scratch::new())
    }

    /// Unfused path over a caller-provided scratch arena.
    pub fn run_f32_with(
        &self,
        x: &QActTensor,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let (n, h, wd) = self.check_input(x)?;
        let oh = (h + 2 * self.pad - self.kh) / self.stride + 1;
        let ow = (wd + 2 * self.pad - self.kw) / self.stride + 1;
        let ohw = oh * ow;
        let mut out = Tensor::zeros(&[n, self.c_out, oh, ow]);
        let od = out.data_mut();

        if self.groups == 1 {
            self.reserve(scratch, oh, ow);
            for img in 0..n {
                self.accumulate_dense(x, img, h, wd, oh, ow, scratch);
                let base = img * self.c_out * ohw;
                for o in 0..self.c_out {
                    let zpw = self.zp_w[o] as i64;
                    let corr = self.zp_corr[o];
                    let scale = self.in_qp.scale as f64 * self.s_w[o] as f64;
                    let bias = self.bias_f[o];
                    let dst =
                        &mut od[base + o * ohw..base + (o + 1) * ohw];
                    for (p, d) in dst.iter_mut().enumerate() {
                        let t = scratch.acc[p * self.c_out + o] as i64
                            - zpw * scratch.rows[p] as i64
                            + corr;
                        *d = (t as f64 * scale) as f32 + bias;
                    }
                }
            }
        } else {
            let scales: Vec<f64> = (0..self.c_out)
                .map(|c| self.in_qp.scale as f64 * self.s_w[c] as f64)
                .collect();
            let f32_epi = |c: usize, t: i64| {
                ((t + self.zp_corr[c]) as f64 * scales[c]) as f32
                    + self.bias_f[c]
            };
            self.depthwise(x, n, h, wd, oh, ow, f32_epi, od);
        }
        Ok(out)
    }

    /// Depthwise direct core, parallel over (image, channel) blocks and
    /// generic over the per-element epilogue (u8 requant on the fused
    /// path, exact f32 on the unfused path). `t` handed to the epilogue
    /// is the raw rowsum-corrected i64 accumulator; the closure adds its
    /// own per-channel constants.
    #[allow(clippy::too_many_arguments)]
    fn depthwise<T, F>(
        &self,
        x: &QActTensor,
        n: usize,
        h: usize,
        wd: usize,
        oh: usize,
        ow: usize,
        epilogue: F,
        out: &mut [T],
    ) where
        F: Fn(usize, i64) -> T + Sync,
    {
        let c = self.c_out;
        let khw = self.kh * self.kw;
        let zp_in = self.in_qp.zero_point as i32;
        let ohw = oh * ow;
        let cells = parallel::as_send_cells(out);
        parallel::par_chunks(n * c, |lo, hi| {
            for i in lo..hi {
                let ch = i % c;
                let xoff = i * h * wd;
                // SAFETY: block i is written by this chunk only.
                let dst = unsafe { cells.slice(i * ohw, ohw) };
                let wch = &self.w[ch * khw..(ch + 1) * khw];
                let zpw = self.zp_w[ch] as i64;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let (acc, sx) = self.dw_patch(
                            &x.codes, xoff, h, wd, oy, ox, wch, zp_in,
                        );
                        let t = acc - zpw * sx as i64;
                        dst[oy * ow + ox] = epilogue(ch, t);
                    }
                }
            }
        });
    }

    /// One depthwise kernel window: (Σ q·w, Σ q) with out-of-bounds
    /// positions read as `zp_in` (they represent exact zeros).
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn dw_patch(
        &self,
        codes: &[u8],
        xoff: usize,
        h: usize,
        wd: usize,
        oy: usize,
        ox: usize,
        wch: &[i8],
        zp_in: i32,
    ) -> (i64, i32) {
        let mut acc = 0i64;
        let mut sx = 0i32;
        let iy0 = oy * self.stride;
        let ix0 = ox * self.stride;
        for dy in 0..self.kh {
            let iy = iy0 + dy;
            for dx in 0..self.kw {
                let ix = ix0 + dx;
                let q = if iy < self.pad
                    || iy >= h + self.pad
                    || ix < self.pad
                    || ix >= wd + self.pad
                {
                    zp_in
                } else {
                    codes[xoff + (iy - self.pad) * wd + (ix - self.pad)]
                        as i32
                };
                acc += (q * wch[dy * self.kw + dx] as i32) as i64;
                sx += q;
            }
        }
        (acc, sx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mult_roundtrips_magnitudes() {
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let m = rng.log_uniform(1e-6, 1e3) as f64;
            let fm = mult_for(m);
            for _ in 0..20 {
                let t = (rng.uniform(-1e6, 1e6)) as i64;
                let got = apply_mult(t, &fm);
                let want = (t as f64 * m).round() as i64;
                assert!(
                    (got - want).abs() <= 1,
                    "M={m} t={t}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn mult_degenerate_falls_back() {
        assert!(matches!(mult_for(0.0), Mult::Float(_)));
        assert!(matches!(mult_for(f64::INFINITY), Mult::Float(_)));
        assert_eq!(apply_mult(100, &Mult::Float(0.5)), 50);
    }

    #[test]
    fn qgemm_matches_naive() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (7, 13, 5);
        let a: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
        let b: Vec<i8> =
            (0..k * n).map(|_| rng.below(256) as i8).collect();
        let got = qgemm(&a, &b, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let want: i32 = (0..k)
                    .map(|kk| a[i * k + kk] as i32 * b[kk * n + j] as i32)
                    .sum();
                assert_eq!(got[i * n + j], want);
            }
        }
    }

    #[test]
    fn qgemm_unrolled_bitwise_matches_scalar() {
        // the 4-wide k-unroll must agree with the scalar loop bit for bit
        // on every shape class: k % 4 == 0..3, all-zero blocks, extremes
        let mut rng = Rng::new(21);
        for (m, k, n) in
            [(1, 1, 1), (3, 4, 5), (5, 7, 3), (2, 9, 8), (4, 18, 11)]
        {
            let mut a: Vec<u8> =
                (0..m * k).map(|_| rng.below(256) as u8).collect();
            // plant zero runs so whole unroll blocks get skipped
            for v in a.iter_mut().step_by(3) {
                *v = 0;
            }
            let b: Vec<i8> =
                (0..k * n).map(|_| rng.below(256) as i8).collect();
            let mut fast = vec![0i32; m * n];
            let mut slow = vec![0i32; m * n];
            qgemm_into(&a, &b, m, k, n, &mut fast);
            qgemm_into_scalar(&a, &b, m, k, n, &mut slow);
            assert_eq!(fast, slow, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn rowsums_match() {
        let a: Vec<u8> = vec![1, 2, 3, 250, 251, 252];
        assert_eq!(rowsums_u8(&a, 2, 3), vec![6, 753]);
    }

    #[test]
    fn scratch_reuse_is_transparent() {
        // the same layer run with a fresh scratch and an oversized
        // recycled scratch must agree exactly
        let mut rng = Rng::new(9);
        let t = crate::tensor::Tensor::new(
            &[4, 1, 3, 3],
            rng.normal_vec(36, 0.5),
        );
        let (_, codes) = crate::quant::quantize_weights_retaining(
            &mut t.clone(),
            &crate::quant::QScheme::int8_asymmetric(),
        )
        .unwrap();
        let x = crate::tensor::Tensor::new(&[1, 1, 6, 6], rng.normal_vec(36, 1.0));
        let in_qp = crate::quant::params_for_range(x.min(), x.max(), 8, false);
        let xq = QActTensor::quantize(&x, &in_qp);
        let row = SiteCfg {
            scale: 0.05,
            zero_point: 0.0,
            n_levels: 256.0,
            clip_hi: f32::INFINITY,
        };
        let qc = QConv::pack(
            &codes,
            &[0.0; 4],
            1,
            1,
            1,
            &in_qp,
            EpiSpec::Act(&row),
        )
        .unwrap();
        let fresh = qc.run_q(&xq).unwrap();
        let mut big = Scratch::new();
        big.col.resize(10_000, 7);
        big.acc.resize(10_000, -3);
        big.rows.resize(10_000, 11);
        let recycled = qc.run_q_with(&xq, &mut big).unwrap();
        assert_eq!(fresh, recycled);
    }
}
