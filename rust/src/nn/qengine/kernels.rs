//! Integer compute layers: fixed-point requantisation multipliers, the
//! shared scratch arena, and the packed convolution layer ([`QConv`])
//! with its fused epilogues. The GEMM microkernels themselves (packed
//! panels, SIMD inner loops, runtime dispatch) live in [`super::gemm`].
//!
//! Everything here is *mechanism*; policy (which kernel runs where, on
//! which grid) lives in the plan compiler ([`super::plan`]).

use anyhow::{anyhow, bail, Result};

use crate::nn::conv::im2col_into;
use crate::nn::SiteCfg;
use crate::quant::QParams;
use crate::tensor::{QTensor, Tensor};
use crate::util::align::AVec;
use crate::util::mmap::ArcSlice;
use crate::util::parallel;

use super::gemm::{self, KernelKind, PackedB};
use super::{assert_act_grid, QActTensor};

/// Depthwise SIMD accumulates windows in i32 lanes; with `kh·kw` taps of
/// magnitude ≤ `255·128` the partial sums stay below `2^31` for up to
/// this many taps, keeping the lanes bitwise-equal to the scalar i64
/// accumulation. Larger (absurd) kernels fall back to the scalar path.
const DW_SIMD_MAX_TAPS: usize = 65_000;

// -- scratch arena -----------------------------------------------------------

/// Reusable per-run scratch buffers: im2col patches, GEMM accumulators
/// and row sums. The plan executor allocates one `Scratch` per
/// `run_batch` call and recycles it across every layer (buffers grow to
/// the largest layer once, then stop allocating). Buffers are 64-byte
/// aligned ([`AVec`]) so SIMD kernels never straddle a cache line, and
/// stay aligned through pool reuse and growth.
#[derive(Debug, Default)]
pub struct Scratch {
    pub(crate) col: AVec<u8>,
    pub(crate) acc: AVec<i32>,
    pub(crate) rows: AVec<i32>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }
}

// -- fixed-point requantisation ---------------------------------------------

/// A positive real multiplier `M` as `m · 2^-shift` with `m ∈ [2^30,
/// 2^31)`; degenerate magnitudes fall back to f64 rounding.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mult {
    Fixed { m: i32, shift: u32 },
    Float(f64),
}

/// Decompose `x > 0` into the i64 fixed-point form.
pub fn mult_for(x: f64) -> Mult {
    if !x.is_finite() || x <= 0.0 {
        return Mult::Float(x.max(0.0));
    }
    let mut v = x;
    let mut e = 0i32;
    while v < 0.5 {
        v *= 2.0;
        e -= 1;
    }
    while v >= 1.0 {
        v /= 2.0;
        e += 1;
    }
    let mut m = (v * (1u64 << 31) as f64).round() as i64;
    let mut shift = 31 - e;
    if m == 1i64 << 31 {
        m >>= 1;
        shift -= 1;
    }
    if !(1..=62).contains(&shift) {
        return Mult::Float(x);
    }
    Mult::Fixed { m: m as i32, shift: shift as u32 }
}

/// `round(t · M)` (round half away from zero for the fixed-point form —
/// within the engine's one-step tolerance of the oracle's ties-to-even).
#[inline]
pub fn apply_mult(t: i64, m: &Mult) -> i64 {
    match *m {
        Mult::Fixed { m, shift } => {
            let prod = t as i128 * m as i128;
            let half = 1i128 << (shift - 1);
            let r = if prod >= 0 {
                (prod + half) >> shift
            } else {
                -((-prod + half) >> shift)
            };
            r as i64
        }
        Mult::Float(f) => (t as f64 * f).round() as i64,
    }
}

/// Round-half-away-from-zero arithmetic right shift: `round(t · 2^-s)`.
/// `shift == 0` is the identity. Shared by the integer add/concat ops
/// ([`super::ops`]) and the power-of-two epilogue fast path.
#[inline]
pub(crate) fn round_shift(t: i64, shift: u32) -> i64 {
    if shift == 0 {
        return t;
    }
    let half = 1i64 << (shift - 1);
    if t >= 0 {
        (t + half) >> shift
    } else {
        -((-t + half) >> shift)
    }
}

/// A [`Mult`] that happens to be an exact power of two, collapsed to a
/// shift (the observation of Oh et al. 2020: power-of-two scales turn
/// requantisation into pure shifts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum ShiftMult {
    /// `M = 2^-s`: a pure rounding right shift (the common case — the
    /// accumulator grid is much finer than the output grid).
    Right(u32),
    /// `M = 1` exactly.
    Exact,
    /// `M = 2^s`: an exact left shift.
    Left(u32),
}

/// Classify a multiplier as an exact power of two. [`mult_for`]
/// normalizes every mantissa into `[2^30, 2^31)`, so `M = 2^e` lands
/// exactly on `m == 2^30` with `shift == 30 − e`: the classification
/// needs no extra plan state and no wire-format change, it just pattern
/// matches the existing `Mult`.
#[inline]
pub(crate) fn pow2_shift(m: &Mult) -> Option<ShiftMult> {
    const POW2_M: i32 = 1 << 30;
    match *m {
        Mult::Fixed { m: POW2_M, shift } => Some(match shift {
            31.. => ShiftMult::Right(shift - 30),
            30 => ShiftMult::Exact,
            _ => ShiftMult::Left(30 - shift),
        }),
        _ => None,
    }
}

/// Apply a power-of-two multiplier: bitwise-identical to [`apply_mult`]
/// on the `Mult` it was classified from, without the 64×32 product or
/// the i128 intermediate. Proof sketch (divide the i128 identity
/// through by the `2^30` mantissa): for `shift > 30`,
/// `(|t|·2^30 + 2^(shift−1)) >> shift == (|t| + 2^(shift−31)) >>
/// (shift−30)`, which is exactly [`round_shift`]`(t, shift−30)` with
/// its half-away rounding; `shift == 30` cancels to the identity; and
/// `shift < 30` makes the rounding term vanish, leaving the exact left
/// shift (engine accumulators stay ≪ 2^40, so no i64 overflow).
#[inline]
pub(crate) fn apply_pow2(t: i64, s: &ShiftMult) -> i64 {
    match *s {
        ShiftMult::Right(sh) => round_shift(t, sh),
        ShiftMult::Exact => t,
        ShiftMult::Left(sh) => t << sh,
    }
}

/// Integer clamp bounds implementing a site's clipped-ReLU on its output
/// grid: `q_lo = clamp(zp, 0, n-1)` (value 0 after the ReLU floor),
/// `q_hi` from the site's `clip_hi` (ReLU6) or the grid ceiling.
pub(crate) fn act_clamp(row: &SiteCfg, out_qp: &QParams) -> (i32, i32) {
    let zp_out = out_qp.zero_point as i32;
    let n_hi = out_qp.n_levels as i32 - 1;
    let q_lo = zp_out.clamp(0, n_hi);
    let q_hi = if row.clip_hi.is_finite() {
        (zp_out + (row.clip_hi / row.scale).round() as i32).clamp(q_lo, n_hi)
    } else {
        n_hi
    };
    (q_lo, q_hi)
}

// -- packed convolution layers ----------------------------------------------

/// How a packed conv finishes.
#[derive(Debug, Clone, Copy)]
pub enum EpiSpec<'a> {
    /// No integer epilogue: i32 accumulate, exact f32 output
    /// ([`QConv::run_f32`]) — for convs whose value must stay f32
    /// (model outputs).
    F32,
    /// Fused activation site: requantise onto the site grid with the
    /// clamped-ReLU/ReLU6 bounds folded into the integer clamp.
    Act(&'a SiteCfg),
    /// Plain requantisation onto a grid with *no* activation (clamp is
    /// the grid's own `[0, n-1]`): residual-branch convs land on their
    /// pre-activation grid before the integer add.
    Grid(QParams),
}

/// Per-output-channel weight-grid folding shared by the GEMM packers
/// ([`QConv::pack`], `QLinear::pack`).
pub(crate) struct FoldedWeights {
    /// i8 codes laid out for the kernel: (K, O) when transposed (dense
    /// GEMM / linear head), O-major otherwise (depthwise direct).
    pub w: Vec<i8>,
    /// Signed-storage weight zero point (`zp_w - 128`) per out channel.
    pub zp_w: Vec<i32>,
    pub s_w: Vec<f32>,
    /// `-zp_in·colsum[o] + K·zp_in·zp_w[o]` per out channel (the static
    /// half of the gemmlowp zero-point identity).
    pub zp_corr: Vec<i64>,
}

/// Fold a retained weight tensor for integer execution: signed-storage
/// zero points, per-channel scales (per-tensor grids broadcast), the
/// static gemmlowp correction constants, and the kernel layout. `per`
/// is the reduction length per output channel (`cig·kh·kw` / `in_dim`).
pub(crate) fn fold_weight_grids(
    w: &QTensor,
    c_out: usize,
    per: usize,
    in_qp: &QParams,
    transpose: bool,
) -> Result<FoldedWeights> {
    let codes = w.codes_i8().ok_or_else(|| {
        anyhow!(
            "integer packing wants signed (i8) weight codes, got {}",
            w.storage()
        )
    })?;
    let zp_in = in_qp.zero_point as i64;
    let mut zp_w = Vec::with_capacity(c_out);
    let mut s_w = Vec::with_capacity(c_out);
    let mut zp_corr = Vec::with_capacity(c_out);
    for o in 0..c_out {
        let p = w.param_for_channel(o);
        let z = p.zero_point as i32 - 128;
        zp_w.push(z);
        s_w.push(p.scale);
        let colsum: i64 = codes[o * per..(o + 1) * per]
            .iter()
            .map(|&v| v as i64)
            .sum();
        zp_corr.push(-zp_in * colsum + per as i64 * zp_in * z as i64);
    }
    let w_packed = if transpose {
        let mut wt = vec![0i8; per * c_out];
        for o in 0..c_out {
            for kk in 0..per {
                wt[kk * c_out + o] = codes[o * per + kk];
            }
        }
        wt
    } else {
        codes.to_vec()
    };
    Ok(FoldedWeights { w: w_packed, zp_w, s_w, zp_corr })
}

/// Fused requant epilogue: integer bias (zero-point corrections + the
/// f32 bias folded onto the accumulator grid), per-channel multipliers,
/// and the clamp implementing both the output grid and (when fused with
/// an activation) the clipped-ReLU bounds. Fields are crate-visible so
/// the artifact codec ([`crate::artifact`]) can ship and rebuild packed
/// layers bit-for-bit without re-deriving anything from f32.
#[derive(Debug, Clone)]
pub(crate) struct Epilogue {
    /// `round(b/(s_in·s_w)) - zp_in·colsum + K·zp_in·zp_w` per channel.
    /// [`ArcSlice`] so artifact decode can alias the mapped `bias.i64`
    /// section instead of copying it.
    pub(crate) bias_q: ArcSlice<i64>,
    /// `s_in·s_w[o]/s_out` per channel.
    pub(crate) mult: Vec<Mult>,
    pub(crate) zp_out: i32,
    pub(crate) q_lo: i32,
    pub(crate) q_hi: i32,
    pub(crate) out_qp: QParams,
}

fn make_epilogue(
    bias: &[f32],
    s_w: &[f32],
    zp_corr: &[i64],
    in_qp: &QParams,
    out_qp: QParams,
    q_lo: i32,
    q_hi: i32,
) -> Epilogue {
    let c_out = bias.len();
    let mut bias_q = Vec::with_capacity(c_out);
    let mut mult = Vec::with_capacity(c_out);
    for o in 0..c_out {
        let acc_scale = in_qp.scale as f64 * s_w[o] as f64;
        bias_q.push((bias[o] as f64 / acc_scale).round() as i64 + zp_corr[o]);
        mult.push(mult_for(acc_scale / out_qp.scale as f64));
    }
    Epilogue {
        bias_q: bias_q.into(),
        mult,
        zp_out: out_qp.zero_point as i32,
        q_lo,
        q_hi,
        out_qp,
    }
}

/// One conv layer packed for integer execution: offset i8 weight codes,
/// per-channel grids, zero-point correction constants, and (when
/// requantising) the fused [`Epilogue`].
#[derive(Debug, Clone)]
pub struct QConv {
    pub(crate) c_out: usize,
    pub(crate) cig: usize,
    pub(crate) kh: usize,
    pub(crate) kw: usize,
    pub(crate) stride: usize,
    pub(crate) pad: usize,
    pub(crate) groups: usize,
    /// groups == 1: transposed (kdim, c_out) for the GEMM;
    /// depthwise: O-major (c, kh·kw). [`ArcSlice`] so artifact decode
    /// can alias the mmap'd `wgrid.i8` section (page-cache backed)
    /// instead of copying it; pack paths store an owned vec.
    pub(crate) w: ArcSlice<i8>,
    /// Signed-storage weight zero point (`zp_w - 128`) per out channel.
    pub(crate) zp_w: Vec<i32>,
    pub(crate) s_w: Vec<f32>,
    /// `-zp_in·colsum[o] + K·zp_in·zp_w[o]` per out channel.
    pub(crate) zp_corr: ArcSlice<i64>,
    pub(crate) bias_f: Vec<f32>,
    pub(crate) in_qp: QParams,
    pub(crate) epi: Option<Epilogue>,
    /// Inner-kernel flavour this layer dispatches to. Derived state
    /// (like `packed`): recorded at pack/decode time, never serialized.
    pub(crate) kernel: KernelKind,
    /// SIMD weight panels for `kernel` (empty for scalar plans and
    /// depthwise layers), rebuilt from the canonical `w` on demand.
    pub(crate) packed: PackedB,
}

impl QConv {
    /// Pack one conv layer. `w` must hold signed (i8) codes with OIHW
    /// shape; `in_qp` is the grid of the layer's input feature map.
    /// `epi` selects the epilogue: [`EpiSpec::Act`] fuses the consuming
    /// activation site (requant + clamped-ReLU bounds), [`EpiSpec::Grid`]
    /// requantises onto a plain grid (residual branches), and
    /// [`EpiSpec::F32`] keeps the exact f32 output ([`QConv::run_f32`]).
    pub fn pack(
        w: &QTensor,
        bias: &[f32],
        stride: usize,
        pad: usize,
        groups: usize,
        in_qp: &QParams,
        epi: EpiSpec,
    ) -> Result<QConv> {
        let shape = w.shape();
        if shape.len() != 4 {
            bail!("QConv wants OIHW weights, got {:?}", shape);
        }
        let (c_out, cig, kh, kw) = (shape[0], shape[1], shape[2], shape[3]);
        if groups != 1 && (cig != 1 || groups != c_out) {
            bail!("QConv supports dense or depthwise grouping only");
        }
        if bias.len() != c_out {
            bail!("bias len {} != out channels {}", bias.len(), c_out);
        }
        assert_act_grid(in_qp);
        let per = cig * kh * kw;
        // dense GEMM wants (kdim, c_out); depthwise stays O-major
        let fw = fold_weight_grids(w, c_out, per, in_qp, groups == 1)?;

        let epi = match epi {
            EpiSpec::F32 => None,
            EpiSpec::Act(row) => {
                if !(2.0..=256.0).contains(&row.n_levels) {
                    bail!(
                        "fused epilogue needs a quantised site \
                         (2..=256 levels), got {}",
                        row.n_levels
                    );
                }
                let out_qp = QParams {
                    scale: row.scale,
                    zero_point: row.zero_point,
                    n_levels: row.n_levels,
                };
                assert_act_grid(&out_qp);
                let (q_lo, q_hi) = act_clamp(row, &out_qp);
                Some(make_epilogue(
                    bias, &fw.s_w, &fw.zp_corr, in_qp, out_qp, q_lo, q_hi,
                ))
            }
            EpiSpec::Grid(out_qp) => {
                assert_act_grid(&out_qp);
                let n_hi = out_qp.n_levels as i32 - 1;
                Some(make_epilogue(
                    bias, &fw.s_w, &fw.zp_corr, in_qp, out_qp, 0, n_hi,
                ))
            }
        };

        let mut conv = QConv {
            c_out,
            cig,
            kh,
            kw,
            stride,
            pad,
            groups,
            w: fw.w.into(),
            zp_w: fw.zp_w,
            s_w: fw.s_w,
            zp_corr: fw.zp_corr.into(),
            bias_f: bias.to_vec(),
            in_qp: *in_qp,
            epi,
            kernel: KernelKind::Scalar,
            packed: PackedB::empty(),
        };
        conv.set_kernel(gemm::active_kind());
        Ok(conv)
    }

    pub fn out_channels(&self) -> usize {
        self.c_out
    }

    /// Does this layer requantise (u8 out) rather than emit exact f32?
    pub fn is_fused(&self) -> bool {
        self.epi.is_some()
    }

    pub fn is_depthwise(&self) -> bool {
        self.groups > 1
    }

    /// Output grid when the layer requantises.
    pub fn out_params(&self) -> Option<QParams> {
        self.epi.as_ref().map(|e| e.out_qp)
    }

    /// The inner-kernel flavour this layer currently dispatches to.
    pub fn kernel_kind(&self) -> KernelKind {
        self.kernel
    }

    /// Re-target this layer's inner kernel and rebuild the packed
    /// panels (plan-level `force_scalar`, dispatch bisection tests).
    pub fn set_kernel(&mut self, kind: KernelKind) {
        if self.kernel != kind {
            self.kernel = kind;
            self.rebuild_packed();
        }
    }

    /// Re-derive the packed SIMD panels from the canonical weights for
    /// the current kernel kind. Panels are derived state — rebuilt here
    /// after plan build or artifact decode, never serialized, so the
    /// `.dfqm` wire format and its bitwise-output guarantee are
    /// untouched. Depthwise layers keep no panels (direct window
    /// kernel); scalar plans keep none either.
    pub(crate) fn rebuild_packed(&mut self) {
        self.packed = if self.groups == 1 && self.kernel != KernelKind::Scalar
        {
            let kdim = self.cig * self.kh * self.kw;
            PackedB::pack(self.kernel, &self.w, kdim, self.c_out)
        } else {
            PackedB::empty()
        };
    }

    fn check_input(&self, x: &QActTensor) -> Result<(usize, usize, usize)> {
        if x.qp != self.in_qp {
            bail!(
                "input grid mismatch: layer packed for {:?}, got {:?}",
                self.in_qp,
                x.qp
            );
        }
        if x.shape.len() != 4 || x.shape[1] != self.cig * self.groups {
            bail!(
                "input shape {:?} incompatible with conv ({} channels)",
                x.shape,
                self.cig * self.groups
            );
        }
        Ok((x.shape[0], x.shape[2], x.shape[3]))
    }

    /// Integer accumulators + im2col row sums for one image into the
    /// scratch arena (dense path) — the shared front half of both run
    /// paths.
    fn accumulate_dense(
        &self,
        x: &QActTensor,
        img: usize,
        h: usize,
        wd: usize,
        oh: usize,
        ow: usize,
        scratch: &mut Scratch,
    ) {
        let kdim = self.cig * self.kh * self.kw;
        let ohw = oh * ow;
        im2col_into(
            &x.codes,
            self.cig,
            h,
            wd,
            img,
            self.kh,
            self.kw,
            self.stride,
            self.pad,
            oh,
            ow,
            self.in_qp.zero_point as u8,
            &mut scratch.col[..ohw * kdim],
        );
        gemm::rowsums_u8_into(
            &scratch.col[..ohw * kdim],
            ohw,
            kdim,
            &mut scratch.rows[..ohw],
        );
        if self.packed.is_empty() {
            gemm::qgemm_into_kind(
                KernelKind::Scalar,
                &scratch.col[..ohw * kdim],
                &self.w,
                ohw,
                kdim,
                self.c_out,
                &mut scratch.acc[..ohw * self.c_out],
            );
        } else {
            gemm::qgemm_packed_into(
                &scratch.col[..ohw * kdim],
                &self.packed,
                ohw,
                &mut scratch.acc[..ohw * self.c_out],
            );
        }
    }

    fn reserve(&self, scratch: &mut Scratch, oh: usize, ow: usize) {
        let kdim = self.cig * self.kh * self.kw;
        let ohw = oh * ow;
        if scratch.col.len() < ohw * kdim {
            scratch.col.resize(ohw * kdim, 0);
        }
        if scratch.acc.len() < ohw * self.c_out {
            scratch.acc.resize(ohw * self.c_out, 0);
        }
        if scratch.rows.len() < ohw {
            scratch.rows.resize(ohw, 0);
        }
    }

    /// Fused path: u8 in → u8 out on the packed output grid
    /// (convenience wrapper allocating its own scratch).
    pub fn run_q(&self, x: &QActTensor) -> Result<QActTensor> {
        self.run_q_with(x, &mut Scratch::new())
    }

    /// Fused path over a caller-provided scratch arena.
    pub fn run_q_with(
        &self,
        x: &QActTensor,
        scratch: &mut Scratch,
    ) -> Result<QActTensor> {
        let epi = self
            .epi
            .as_ref()
            .ok_or_else(|| anyhow!("QConv not packed with a fused epilogue"))?;
        let (n, h, wd) = self.check_input(x)?;
        let oh = (h + 2 * self.pad - self.kh) / self.stride + 1;
        let ow = (wd + 2 * self.pad - self.kw) / self.stride + 1;
        let ohw = oh * ow;
        let mut out = vec![0u8; n * self.c_out * ohw];

        if self.groups == 1 {
            self.reserve(scratch, oh, ow);
            for img in 0..n {
                self.accumulate_dense(x, img, h, wd, oh, ow, scratch);
                let base = img * self.c_out * ohw;
                for o in 0..self.c_out {
                    let zpw = self.zp_w[o] as i64;
                    let bq = epi.bias_q[o];
                    let m = &epi.mult[o];
                    let dst = &mut out[base + o * ohw..base + (o + 1) * ohw];
                    // classify once per channel, outside the position
                    // loop: power-of-two multipliers collapse the
                    // requant to a pure rounding shift (no 64×32
                    // product, no i128), bitwise-identical to the
                    // general path
                    match pow2_shift(m) {
                        Some(sh) => {
                            for (p, d) in dst.iter_mut().enumerate() {
                                let t = scratch.acc[p * self.c_out + o]
                                    as i64
                                    - zpw * scratch.rows[p] as i64
                                    + bq;
                                let q = (apply_pow2(t, &sh)
                                    + epi.zp_out as i64)
                                    .clamp(epi.q_lo as i64, epi.q_hi as i64);
                                *d = q as u8;
                            }
                        }
                        None => {
                            let fixed = match *m {
                                Mult::Fixed { m: mf, shift }
                                    if mf > 0
                                        && (1..=62).contains(&shift)
                                        && gemm::active_kind()
                                            != KernelKind::Scalar =>
                                {
                                    Some((mf, shift))
                                }
                                _ => None,
                            };
                            if let Some((mf, shift)) = fixed {
                                // generic multiplier, SIMD: gather the
                                // strided accumulator column into
                                // contiguous i32 chunks for the 64-bit
                                // product kernel; a chunk whose
                                // pre-requant term escapes i32 (the
                                // kernel's exactness envelope) takes
                                // the exact scalar epilogue instead
                                const CH: usize = 128;
                                let mut t32 = [0i32; CH];
                                let mut p0 = 0usize;
                                while p0 < ohw {
                                    let len = CH.min(ohw - p0);
                                    let mut fits = true;
                                    for (i, ti) in
                                        t32[..len].iter_mut().enumerate()
                                    {
                                        let p = p0 + i;
                                        let t = scratch.acc
                                            [p * self.c_out + o]
                                            as i64
                                            - zpw * scratch.rows[p] as i64
                                            + bq;
                                        fits &= i32::try_from(t).is_ok();
                                        *ti = t as i32;
                                    }
                                    if fits {
                                        gemm::requant_i32(
                                            &t32[..len],
                                            &mut dst[p0..p0 + len],
                                            mf,
                                            shift,
                                            epi.zp_out,
                                            epi.q_lo,
                                            epi.q_hi,
                                        );
                                    } else {
                                        for (i, d) in dst[p0..p0 + len]
                                            .iter_mut()
                                            .enumerate()
                                        {
                                            let p = p0 + i;
                                            let t = scratch.acc
                                                [p * self.c_out + o]
                                                as i64
                                                - zpw
                                                    * scratch.rows[p] as i64
                                                + bq;
                                            let q = (apply_mult(t, m)
                                                + epi.zp_out as i64)
                                                .clamp(
                                                    epi.q_lo as i64,
                                                    epi.q_hi as i64,
                                                );
                                            *d = q as u8;
                                        }
                                    }
                                    p0 += len;
                                }
                            } else {
                                for (p, d) in dst.iter_mut().enumerate() {
                                    let t = scratch.acc[p * self.c_out + o]
                                        as i64
                                        - zpw * scratch.rows[p] as i64
                                        + bq;
                                    let q = (apply_mult(t, m)
                                        + epi.zp_out as i64)
                                        .clamp(
                                            epi.q_lo as i64,
                                            epi.q_hi as i64,
                                        );
                                    *d = q as u8;
                                }
                            }
                        }
                    }
                }
            }
        } else {
            let shifts: Vec<Option<ShiftMult>> =
                epi.mult.iter().map(pow2_shift).collect();
            let requant = |c: usize, t: i64| {
                let t = t + epi.bias_q[c];
                let v = match &shifts[c] {
                    Some(sh) => apply_pow2(t, sh),
                    None => apply_mult(t, &epi.mult[c]),
                };
                (v + epi.zp_out as i64).clamp(epi.q_lo as i64, epi.q_hi as i64)
                    as u8
            };
            self.depthwise(x, n, h, wd, oh, ow, requant, &mut out);
        }
        Ok(QActTensor {
            shape: vec![n, self.c_out, oh, ow],
            codes: out,
            qp: epi.out_qp,
        })
    }

    /// Unfused path: u8 in → exact f32 pre-activation output (integer
    /// accumulate, float epilogue). Matches the f32 oracle's conv output
    /// on the same fake-quantised operands up to f32 rounding
    /// (convenience wrapper allocating its own scratch).
    pub fn run_f32(&self, x: &QActTensor) -> Result<Tensor> {
        self.run_f32_with(x, &mut Scratch::new())
    }

    /// Unfused path over a caller-provided scratch arena.
    pub fn run_f32_with(
        &self,
        x: &QActTensor,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let (n, h, wd) = self.check_input(x)?;
        let oh = (h + 2 * self.pad - self.kh) / self.stride + 1;
        let ow = (wd + 2 * self.pad - self.kw) / self.stride + 1;
        let ohw = oh * ow;
        let mut out = Tensor::zeros(&[n, self.c_out, oh, ow]);
        let od = out.data_mut();

        if self.groups == 1 {
            self.reserve(scratch, oh, ow);
            for img in 0..n {
                self.accumulate_dense(x, img, h, wd, oh, ow, scratch);
                let base = img * self.c_out * ohw;
                for o in 0..self.c_out {
                    let zpw = self.zp_w[o] as i64;
                    let corr = self.zp_corr[o];
                    let scale = self.in_qp.scale as f64 * self.s_w[o] as f64;
                    let bias = self.bias_f[o];
                    let dst =
                        &mut od[base + o * ohw..base + (o + 1) * ohw];
                    for (p, d) in dst.iter_mut().enumerate() {
                        let t = scratch.acc[p * self.c_out + o] as i64
                            - zpw * scratch.rows[p] as i64
                            + corr;
                        *d = (t as f64 * scale) as f32 + bias;
                    }
                }
            }
        } else {
            let scales: Vec<f64> = (0..self.c_out)
                .map(|c| self.in_qp.scale as f64 * self.s_w[c] as f64)
                .collect();
            let f32_epi = |c: usize, t: i64| {
                ((t + self.zp_corr[c]) as f64 * scales[c]) as f32
                    + self.bias_f[c]
            };
            self.depthwise(x, n, h, wd, oh, ow, f32_epi, od);
        }
        Ok(out)
    }

    /// Depthwise direct core, parallel over (image, channel) blocks and
    /// generic over the per-element epilogue (u8 requant on the fused
    /// path, exact f32 on the unfused path). `t` handed to the epilogue
    /// is the raw rowsum-corrected i64 accumulator; the closure adds its
    /// own per-channel constants.
    ///
    /// Stride-1 layers run fully-in-bounds interior columns through the
    /// 8-wide SIMD window kernel ([`gemm::dw_span8`]); padding edges,
    /// strided layers, and span tails take the scalar [`Self::dw_patch`].
    /// The split is bitwise-invisible: in-bounds windows never read the
    /// `zp_in` padding value, and the i32-lane guard
    /// ([`DW_SIMD_MAX_TAPS`]) keeps SIMD partial sums exactly equal to
    /// the scalar i64 accumulation.
    #[allow(clippy::too_many_arguments)]
    fn depthwise<T, F>(
        &self,
        x: &QActTensor,
        n: usize,
        h: usize,
        wd: usize,
        oh: usize,
        ow: usize,
        epilogue: F,
        out: &mut [T],
    ) where
        F: Fn(usize, i64) -> T + Sync,
    {
        let c = self.c_out;
        let khw = self.kh * self.kw;
        let zp_in = self.in_qp.zero_point as i32;
        let ohw = oh * ow;
        let simd = self.kernel != KernelKind::Scalar
            && self.stride == 1
            && khw <= DW_SIMD_MAX_TAPS;
        let cells = parallel::as_send_cells(out);
        parallel::par_chunks(n * c, |lo, hi| {
            for i in lo..hi {
                let ch = i % c;
                let xoff = i * h * wd;
                // SAFETY: block i is written by this chunk only.
                let dst = unsafe { cells.slice(i * ohw, ohw) };
                let wch = &self.w[ch * khw..(ch + 1) * khw];
                let zpw = self.zp_w[ch] as i64;
                for oy in 0..oh {
                    // rows whose every tap is in bounds (stride 1):
                    // `iy = oy + dy − pad ∈ [0, h)` for all `dy`
                    let y_in = simd
                        && oy >= self.pad
                        && oy + self.kh <= h + self.pad;
                    let mut ox = 0usize;
                    if y_in {
                        let x_lo = self.pad.min(ow);
                        let x_hi = (wd + self.pad + 1)
                            .saturating_sub(self.kw)
                            .min(ow);
                        while ox < x_lo {
                            let (acc, sx) = self.dw_patch(
                                &x.codes, xoff, h, wd, oy, ox, wch, zp_in,
                            );
                            dst[oy * ow + ox] =
                                epilogue(ch, acc - zpw * sx as i64);
                            ox += 1;
                        }
                        while ox + 8 <= x_hi {
                            let base = xoff
                                + (oy - self.pad) * wd
                                + (ox - self.pad);
                            let mut acc8 = [0i32; 8];
                            let mut sx8 = [0i32; 8];
                            gemm::dw_span8(
                                self.kernel,
                                &x.codes,
                                base,
                                wd,
                                self.kh,
                                self.kw,
                                wch,
                                &mut acc8,
                                &mut sx8,
                            );
                            for e in 0..8 {
                                let t =
                                    acc8[e] as i64 - zpw * sx8[e] as i64;
                                dst[oy * ow + ox + e] = epilogue(ch, t);
                            }
                            ox += 8;
                        }
                    }
                    while ox < ow {
                        let (acc, sx) = self.dw_patch(
                            &x.codes, xoff, h, wd, oy, ox, wch, zp_in,
                        );
                        dst[oy * ow + ox] =
                            epilogue(ch, acc - zpw * sx as i64);
                        ox += 1;
                    }
                }
            }
        });
    }

    /// One depthwise kernel window: (Σ q·w, Σ q) with out-of-bounds
    /// positions read as `zp_in` (they represent exact zeros).
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn dw_patch(
        &self,
        codes: &[u8],
        xoff: usize,
        h: usize,
        wd: usize,
        oy: usize,
        ox: usize,
        wch: &[i8],
        zp_in: i32,
    ) -> (i64, i32) {
        let mut acc = 0i64;
        let mut sx = 0i32;
        let iy0 = oy * self.stride;
        let ix0 = ox * self.stride;
        for dy in 0..self.kh {
            let iy = iy0 + dy;
            for dx in 0..self.kw {
                let ix = ix0 + dx;
                let q = if iy < self.pad
                    || iy >= h + self.pad
                    || ix < self.pad
                    || ix >= wd + self.pad
                {
                    zp_in
                } else {
                    codes[xoff + (iy - self.pad) * wd + (ix - self.pad)]
                        as i32
                };
                acc += (q * wch[dy * self.kw + dx] as i32) as i64;
                sx += q;
            }
        }
        (acc, sx)
    }
}

// -- packed transposed convolution -------------------------------------------

/// A transposed conv packed for integer execution via the gather-form
/// lowering: zero-insertion expansion of the input codes (each inserted
/// position carries the input zero point — the exact quantised zero)
/// followed by a stride-1 [`QConv`] over the spatially flipped kernel
/// with `pad' = k-1-pad`. The inner conv owns the weights, grids and
/// fused epilogue, so every requantisation / zero-point identity — and
/// the bitwise scalar-vs-SIMD dispatch guarantee — is inherited
/// unchanged.
#[derive(Debug, Clone)]
pub struct QConvT {
    /// Logical transposed-conv stride (the zero-insertion factor).
    pub(crate) stride: usize,
    /// Logical transposed-conv padding (`inner.pad == k - 1 - pad`).
    pub(crate) pad: usize,
    pub(crate) inner: QConv,
}

impl QConvT {
    /// Pack one transposed conv layer. `w` must hold signed (i8) codes
    /// with the dense `[c_out, c_in, k, k]` layout; `pad < k` (graph
    /// validation enforces it) keeps the lowering's `pad' = k-1-pad`
    /// in range. Dense only — no grouping.
    pub fn pack(
        w: &QTensor,
        bias: &[f32],
        stride: usize,
        pad: usize,
        in_qp: &QParams,
        epi: EpiSpec,
    ) -> Result<QConvT> {
        let shape = w.shape();
        if shape.len() != 4 || shape[2] != shape[3] {
            bail!("QConvT wants square OIHW weights, got {:?}", shape);
        }
        let k = shape[2];
        if stride == 0 {
            bail!("QConvT with zero stride");
        }
        if pad >= k {
            bail!(
                "QConvT pad {pad} >= kernel {k} (the gather lowering \
                 wants pad' = k-1-pad >= 0)"
            );
        }
        let codes = w.codes_i8().ok_or_else(|| {
            anyhow!(
                "integer packing wants signed (i8) weight codes, got {}",
                w.storage()
            )
        })?;
        // flip the kernel spatially; the out-channel dim (and with it
        // any per-channel grid) is untouched
        let mut flipped = vec![0i8; codes.len()];
        for oi in 0..shape[0] * shape[1] {
            let base = oi * k * k;
            for dy in 0..k {
                for dx in 0..k {
                    flipped[base + dy * k + dx] =
                        codes[base + (k - 1 - dy) * k + (k - 1 - dx)];
                }
            }
        }
        let wf = QTensor::from_codes_i8(shape, flipped, w.params().to_vec())?;
        let inner = QConv::pack(&wf, bias, 1, k - 1 - pad, 1, in_qp, epi)?;
        Ok(QConvT { stride, pad, inner })
    }

    pub fn out_channels(&self) -> usize {
        self.inner.c_out
    }

    /// Does this layer requantise (u8 out) rather than emit exact f32?
    pub fn is_fused(&self) -> bool {
        self.inner.is_fused()
    }

    /// Output grid when the layer requantises.
    pub fn out_params(&self) -> Option<QParams> {
        self.inner.out_params()
    }

    /// The inner-kernel flavour this layer currently dispatches to.
    pub fn kernel_kind(&self) -> KernelKind {
        self.inner.kernel_kind()
    }

    /// Re-target the inner kernel (plan-level `force_scalar`).
    pub fn set_kernel(&mut self, kind: KernelKind) {
        self.inner.set_kernel(kind)
    }

    /// Zero-insertion expansion of the input codes: pixel `(y, x)` moves
    /// to `(y·s, x·s)` of an `((h-1)·s+1, (w-1)·s+1)` grid whose other
    /// positions hold the input zero point exactly.
    fn expand(&self, x: &QActTensor) -> Result<QActTensor> {
        if x.shape.len() != 4 {
            bail!("convT wants NCHW input, got {:?}", x.shape);
        }
        let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let (ex, eh, ew) = crate::nn::conv::expand_strided(
            &x.codes,
            n * c,
            h,
            w,
            self.stride,
            self.inner.in_qp.zero_point as u8,
        );
        Ok(QActTensor { shape: vec![n, c, eh, ew], codes: ex, qp: x.qp })
    }

    /// Fused path: u8 in → u8 out on the packed output grid.
    pub fn run_q(&self, x: &QActTensor) -> Result<QActTensor> {
        self.run_q_with(x, &mut Scratch::new())
    }

    /// Fused path over a caller-provided scratch arena.
    pub fn run_q_with(
        &self,
        x: &QActTensor,
        scratch: &mut Scratch,
    ) -> Result<QActTensor> {
        self.inner.run_q_with(&self.expand(x)?, scratch)
    }

    /// Unfused path: u8 in → exact f32 pre-activation output.
    pub fn run_f32(&self, x: &QActTensor) -> Result<Tensor> {
        self.run_f32_with(x, &mut Scratch::new())
    }

    /// Unfused path over a caller-provided scratch arena.
    pub fn run_f32_with(
        &self,
        x: &QActTensor,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        self.inner.run_f32_with(&self.expand(x)?, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mult_roundtrips_magnitudes() {
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let m = rng.log_uniform(1e-6, 1e3) as f64;
            let fm = mult_for(m);
            for _ in 0..20 {
                let t = (rng.uniform(-1e6, 1e6)) as i64;
                let got = apply_mult(t, &fm);
                let want = (t as f64 * m).round() as i64;
                assert!(
                    (got - want).abs() <= 1,
                    "M={m} t={t}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn mult_degenerate_falls_back() {
        assert!(matches!(mult_for(0.0), Mult::Float(_)));
        assert!(matches!(mult_for(f64::INFINITY), Mult::Float(_)));
        assert_eq!(apply_mult(100, &Mult::Float(0.5)), 50);
    }

    #[test]
    fn pow2_multiplier_shift_path_matches_apply_mult() {
        let mut rng = Rng::new(44);
        for e in -16i32..=8 {
            let m = mult_for(2f64.powi(e));
            let sh = pow2_shift(&m)
                .unwrap_or_else(|| panic!("2^{e} not classified: {m:?}"));
            for _ in 0..200 {
                let t = rng.uniform(-1e9, 1e9) as i64;
                assert_eq!(
                    apply_pow2(t, &sh),
                    apply_mult(t, &m),
                    "2^{e} diverged at t={t}"
                );
            }
            // the boundary cases the rounding proof leans on
            for t in [-3i64, -1, 0, 1, 3, 12345, -54321] {
                assert_eq!(apply_pow2(t, &sh), apply_mult(t, &m));
            }
        }
        // non-pow2 multipliers are never classified
        assert!(pow2_shift(&mult_for(0.3)).is_none());
        assert!(pow2_shift(&Mult::Float(0.5)).is_none());
        assert!(pow2_shift(&Mult::Fixed { m: (1 << 30) + 1, shift: 35 })
            .is_none());
    }

    #[test]
    fn scratch_buffers_stay_aligned_through_reuse_and_growth() {
        let mut s = Scratch::new();
        s.col.resize(100, 1);
        s.acc.resize(100, 2);
        s.rows.resize(100, 3);
        let check = |s: &Scratch, when: &str| {
            assert_eq!(s.col.as_ptr() as usize % 64, 0, "col {when}");
            assert_eq!(s.acc.as_ptr() as usize % 64, 0, "acc {when}");
            assert_eq!(s.rows.as_ptr() as usize % 64, 0, "rows {when}");
        };
        check(&s, "after first fill");
        // pool reuse: shrink for a small layer, then grow past capacity
        s.col.resize(10, 0);
        s.acc.resize(10, 0);
        s.rows.resize(10, 0);
        s.col.resize(50_000, 0);
        s.acc.resize(50_000, 0);
        s.rows.resize(50_000, 0);
        check(&s, "after regrowth");
    }

    #[test]
    fn scratch_reuse_is_transparent() {
        // the same layer run with a fresh scratch and an oversized
        // recycled scratch must agree exactly
        let mut rng = Rng::new(9);
        let t = crate::tensor::Tensor::new(
            &[4, 1, 3, 3],
            rng.normal_vec(36, 0.5),
        );
        let (_, codes) = crate::quant::quantize_weights_retaining(
            &mut t.clone(),
            &crate::quant::QScheme::int8_asymmetric(),
        )
        .unwrap();
        let x = crate::tensor::Tensor::new(&[1, 1, 6, 6], rng.normal_vec(36, 1.0));
        let in_qp = crate::quant::params_for_range(x.min(), x.max(), 8, false);
        let xq = QActTensor::quantize(&x, &in_qp);
        let row = SiteCfg {
            scale: 0.05,
            zero_point: 0.0,
            n_levels: 256.0,
            clip_hi: f32::INFINITY,
        };
        let qc = QConv::pack(
            &codes,
            &[0.0; 4],
            1,
            1,
            1,
            &in_qp,
            EpiSpec::Act(&row),
        )
        .unwrap();
        let fresh = qc.run_q(&xq).unwrap();
        let mut big = Scratch::new();
        big.col.resize(10_000, 7);
        big.acc.resize(10_000, -3);
        big.rows.resize(10_000, 11);
        let recycled = qc.run_q_with(&xq, &mut big).unwrap();
        assert_eq!(fresh, recycled);
    }

    #[test]
    fn conv_simd_dispatch_is_bitwise_identical_to_scalar() {
        // dense and depthwise fixtures (odd spatial sizes force span
        // tails and padding edges), fused and f32 epilogues, native
        // dispatch vs the forced-scalar reference
        let mut rng = Rng::new(77);
        for (c_out, cig, ks, groups, stride, pad) in [
            (8usize, 3usize, 3usize, 1usize, 1usize, 1usize),
            (17, 3, 1, 1, 1, 0),
            (5, 2, 3, 1, 2, 1),
            (6, 1, 3, 6, 1, 1),  // depthwise: SIMD spans + edges
            (10, 1, 5, 10, 1, 2), // depthwise, wider window
        ] {
            let t = crate::tensor::Tensor::new(
                &[c_out, cig, ks, ks],
                rng.normal_vec(c_out * cig * ks * ks, 0.5),
            );
            let (_, codes) = crate::quant::quantize_weights_retaining(
                &mut t.clone(),
                &crate::quant::QScheme::int8_asymmetric(),
            )
            .unwrap();
            let c_in = cig * groups;
            let x = crate::tensor::Tensor::new(
                &[2, c_in, 11, 13],
                rng.normal_vec(2 * c_in * 11 * 13, 1.0),
            );
            let in_qp =
                crate::quant::params_for_range(x.min(), x.max(), 8, false);
            let xq = QActTensor::quantize(&x, &in_qp);
            let row = SiteCfg {
                scale: 0.04,
                zero_point: 3.0,
                n_levels: 256.0,
                clip_hi: f32::INFINITY,
            };
            let bias: Vec<f32> = (0..c_out).map(|o| o as f32 * 0.1).collect();
            for fused in [true, false] {
                let spec = if fused {
                    EpiSpec::Act(&row)
                } else {
                    EpiSpec::F32
                };
                let native = QConv::pack(
                    &codes, &bias, stride, pad, groups, &in_qp, spec,
                )
                .unwrap();
                let mut scalar = native.clone();
                scalar.set_kernel(KernelKind::Scalar);
                assert_eq!(scalar.kernel_kind(), KernelKind::Scalar);
                if fused {
                    let a = native.run_q(&xq).unwrap();
                    let b = scalar.run_q(&xq).unwrap();
                    assert_eq!(
                        a.codes, b.codes,
                        "fused dispatch diverged (groups={groups})"
                    );
                } else {
                    let a = native.run_f32(&xq).unwrap();
                    let b = scalar.run_f32(&xq).unwrap();
                    assert_eq!(
                        a.data(),
                        b.data(),
                        "f32 dispatch diverged (groups={groups})"
                    );
                }
            }
        }
    }

    #[test]
    fn convt_gather_lowering_matches_f32_reference() {
        // the packed transposed conv against the f32 oracle on the same
        // fake-quantised operands, plus the scalar-vs-native bitwise
        // guarantee on the fused path
        let mut rng = Rng::new(91);
        for (c_out, c_in, k, stride, pad) in [
            (4usize, 3usize, 3usize, 2usize, 1usize),
            (5, 2, 4, 2, 1),
            (3, 3, 3, 1, 0),
            (2, 4, 2, 3, 0),
        ] {
            let t = crate::tensor::Tensor::new(
                &[c_out, c_in, k, k],
                rng.normal_vec(c_out * c_in * k * k, 0.5),
            );
            let (_, codes) = crate::quant::quantize_weights_retaining(
                &mut t.clone(),
                &crate::quant::QScheme::int8_asymmetric(),
            )
            .unwrap();
            let x = crate::tensor::Tensor::new(
                &[2, c_in, 5, 6],
                rng.normal_vec(2 * c_in * 5 * 6, 1.0),
            );
            let in_qp =
                crate::quant::params_for_range(x.min(), x.max(), 8, false);
            let xq = QActTensor::quantize(&x, &in_qp);
            let bias: Vec<f32> =
                (0..c_out).map(|o| o as f32 * 0.1 - 0.2).collect();

            // f32 path: integer accumulate + float epilogue vs the
            // oracle's conv_transpose2d on the dequantised operands
            let qc = QConvT::pack(
                &codes, &bias, stride, pad, &in_qp, EpiSpec::F32,
            )
            .unwrap();
            let got = qc.run_f32(&xq).unwrap();
            let want = crate::nn::conv::conv_transpose2d(
                &xq.dequantize(),
                &codes.dequantize(),
                Some(&bias),
                stride,
                pad,
            );
            assert_eq!(got.shape(), want.shape(), "k={k} s={stride}");
            let d = got.max_abs_diff(&want);
            assert!(d < 1e-3, "convT f32 path off by {d} (k={k} s={stride})");

            // fused path: scalar vs native dispatch must be bitwise
            let row = SiteCfg {
                scale: 0.05,
                zero_point: 2.0,
                n_levels: 256.0,
                clip_hi: f32::INFINITY,
            };
            let native = QConvT::pack(
                &codes, &bias, stride, pad, &in_qp, EpiSpec::Act(&row),
            )
            .unwrap();
            let mut scalar = native.clone();
            scalar.set_kernel(KernelKind::Scalar);
            let a = native.run_q(&xq).unwrap();
            let b = scalar.run_q(&xq).unwrap();
            assert_eq!(a.codes, b.codes, "convT dispatch diverged");
            assert_eq!(
                a.shape,
                vec![2, c_out, 4 * stride + k - 2 * pad,
                     5 * stride + k - 2 * pad],
            );
        }
    }

    #[test]
    fn convt_pack_rejects_degenerate_geometry() {
        let mut rng = Rng::new(92);
        let t = crate::tensor::Tensor::new(&[2, 2, 3, 3], rng.normal_vec(36, 0.5));
        let (_, codes) = crate::quant::quantize_weights_retaining(
            &mut t.clone(),
            &crate::quant::QScheme::int8_asymmetric(),
        )
        .unwrap();
        let qp = crate::quant::params_for_range(-1.0, 1.0, 8, false);
        let b = [0.0f32; 2];
        assert!(QConvT::pack(&codes, &b, 0, 1, &qp, EpiSpec::F32).is_err());
        assert!(QConvT::pack(&codes, &b, 2, 3, &qp, EpiSpec::F32).is_err());
    }
}
