//! Plan compilation and execution: resolve a quantised model into a
//! typed integer op pipeline *once*, then run it with no per-step
//! "does this layer have a grid?" branching.
//!
//! [`plan`] walks the folded graph and lowers every node to a [`QOp`]
//! with precomputed requantisation multipliers, dense value slots
//! (no hashmap on the hot path) and free-after-last-use bookkeeping, so
//! peak live memory is the widest cut of the graph rather than the sum
//! of all feature maps. Ops that cannot run on the integer path (an
//! input with no quantised grid) are lowered to explicit f32 fallback
//! ops — visible in [`QModel::summarize`], counted by
//! [`QModel::fallback_ops`], and rejected outright under
//! [`PlanOpts::int8_only`].

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::graph::{Model, Op, PoolKind};
use crate::nn::ops as fops;
use crate::nn::{QuantCfg, SiteCfg};
use crate::quant::QParams;
use crate::tensor::{QTensor, Tensor};
use crate::util::parallel;

use super::gemm::KernelKind;
use super::kernels::{EpiSpec, QConv, QConvT, Scratch};
use super::ops::{
    gap_int, upsample_codes, QAddInt, QConcatInt, QLinear, QPoolInt,
    Requantizer,
};
use super::QActTensor;

/// Planner policy knobs.
#[derive(Debug, Clone, Copy, Default)]
pub struct PlanOpts {
    /// Refuse any plan containing an f32 fallback op instead of silently
    /// executing it in f32.
    pub int8_only: bool,
    /// Pin every GEMM-backed op to the scalar reference kernel instead
    /// of the runtime-dispatched SIMD microkernel (same effect as the
    /// `DFQ_FORCE_SCALAR=1` environment override, but per-plan).
    pub force_scalar: bool,
    /// Accumulate a per-op [`RunProfile`] (wall time, bytes moved, GEMM
    /// calls per kernel flavour) on every run. Off by default; when off
    /// the run loop is the untouched non-instrumented path, so outputs
    /// *and* per-op execution are bit-for-bit identical to a plan
    /// compiled without this flag.
    pub profile: bool,
}

/// Extra grids the planner may use beyond the activation-site rows:
/// per-conv *pre-activation* grids (data-free β ± n·γ, see
/// [`crate::quant::ranges::preact_qparams`]) let residual-branch convs
/// requantise onto an explicit grid instead of falling back to f32.
#[derive(Debug, Clone, Default)]
pub struct AuxGrids {
    /// conv node id → pre-activation grid.
    pub preact: Vec<(usize, QParams)>,
}

impl AuxGrids {
    pub fn empty() -> AuxGrids {
        AuxGrids::default()
    }

    fn preact_of(&self, id: usize) -> Option<QParams> {
        self.preact.iter().find(|(n, _)| *n == id).map(|(_, p)| *p)
    }
}

/// One resolved operation of the execution plan.
pub(crate) enum QOp {
    /// Quantise the model input onto the site-0 grid.
    QuantIn { qp: QParams },
    /// Integer conv; the packed epilogue decides the output kind
    /// (requantised u8 when fused, exact f32 otherwise).
    Conv(Box<QConv>),
    /// Pure f32 conv fallback (the layer's input has no quantised grid);
    /// runs over the fake-quantised weights, exactly like the oracle.
    ConvFp32 {
        w: Tensor,
        b: Vec<f32>,
        stride: usize,
        pad: usize,
        groups: usize,
    },
    /// Integer transposed conv (gather-form lowering over a packed
    /// stride-1 [`QConv`]); the epilogue decides the output kind.
    ConvT(Box<QConvT>),
    /// Pure f32 transposed-conv fallback over fake-quantised weights.
    ConvTFp32 { w: Tensor, b: Vec<f32>, stride: usize, pad: usize },
    /// Integer requantise-add on the add-site grid.
    Add(QAddInt),
    /// f32 add fallback (≥ 1 f32 input), quantised onto the site grid.
    AddF { row: SiteCfg },
    /// Integer requantise-concat onto the concat-site grid (one Q20
    /// multiplier per input branch).
    Concat(QConcatInt),
    /// f32 concat fallback (≥ 1 f32 input), quantised onto the site grid.
    ConcatF { row: SiteCfg },
    /// Grid-preserving integer spatial pool (exact max / rounded avg;
    /// rectangular windows and full-extent global pools included).
    Pool(QPoolInt),
    /// f32 pool fallback (per-axis window; `global` takes the full
    /// runtime extent).
    PoolF {
        kind: PoolKind,
        k: (usize, usize),
        stride: (usize, usize),
        pad: (usize, usize),
        global: bool,
    },
    /// Standalone activation: integer requant with fused clip bounds.
    Act(Requantizer),
    /// f32 activation fallback: clip + quantise from f32.
    ActF { row: SiteCfg },
    /// Integer global average pool (stays on the input grid).
    Gap { qp: QParams },
    /// f32 GAP fallback.
    GapF,
    /// Int8 linear head (integer GEMM, exact f32 logits).
    Linear(QLinear),
    /// f32 linear fallback (f32 input).
    LinearF { w: Tensor, b: Vec<f32> },
    /// Nearest-neighbour upsample (grid-preserving; works on either
    /// value kind, counted as neither integer nor fallback).
    Upsample { factor: usize, grid: Option<QParams> },
}

impl QOp {
    /// (display label, runs on the integer path, output grid).
    pub(crate) fn describe(&self) -> (String, bool, Option<QParams>) {
        match self {
            QOp::QuantIn { qp } => {
                ("quantize-input [int8]".into(), true, Some(*qp))
            }
            QOp::Conv(c) => {
                let base = if c.is_depthwise() { "conv-dw" } else { "conv" };
                match c.out_params() {
                    Some(qp) => {
                        (format!("{base} [int8]"), true, Some(qp))
                    }
                    None => (format!("{base} [int8->f32]"), true, None),
                }
            }
            QOp::ConvFp32 { .. } => {
                ("conv [f32 FALLBACK]".into(), false, None)
            }
            QOp::ConvT(c) => match c.out_params() {
                Some(qp) => ("convT [int8]".into(), true, Some(qp)),
                None => ("convT [int8->f32]".into(), true, None),
            },
            QOp::ConvTFp32 { .. } => {
                ("convT [f32 FALLBACK]".into(), false, None)
            }
            QOp::Add(a) => {
                ("add-requant [int8]".into(), true, Some(a.out_params()))
            }
            QOp::AddF { row } => {
                ("add [f32 FALLBACK]".into(), false, Some(row_qp(row)))
            }
            QOp::Concat(c) => {
                ("concat-requant [int8]".into(), true, Some(c.out_params()))
            }
            QOp::ConcatF { row } => {
                ("concat [f32 FALLBACK]".into(), false, Some(row_qp(row)))
            }
            QOp::Pool(p) => {
                let label = match (p.kind, p.global) {
                    (PoolKind::Max, false) => "pool-max [int8]",
                    (PoolKind::Avg, false) => "pool-avg [int8]",
                    (PoolKind::Max, true) => "pool-max-global [int8]",
                    (PoolKind::Avg, true) => "pool-avg-global [int8]",
                };
                (label.into(), true, Some(p.out_params()))
            }
            QOp::PoolF { .. } => {
                ("pool [f32 FALLBACK]".into(), false, None)
            }
            QOp::Act(r) => {
                ("act-requant [int8]".into(), true, Some(r.out_params()))
            }
            QOp::ActF { row } => {
                ("act [f32 FALLBACK]".into(), false, Some(row_qp(row)))
            }
            QOp::Gap { qp } => ("gap [int8]".into(), true, Some(*qp)),
            QOp::GapF => ("gap [f32 FALLBACK]".into(), false, None),
            QOp::Linear(_) => ("linear [int8->f32]".into(), true, None),
            QOp::LinearF { .. } => {
                ("linear [f32 FALLBACK]".into(), false, None)
            }
            QOp::Upsample { grid, .. } => ("upsample".into(), true, *grid),
        }
    }
}

/// One scheduled op: which slots it reads/writes and which slots die
/// after it runs.
pub(crate) struct PlannedOp {
    /// Graph node whose value this op produces.
    pub node: usize,
    pub ins: Vec<usize>,
    pub out: usize,
    pub op: QOp,
    /// Slots whose last consumer is this op (released after it runs).
    pub free_after: Vec<usize>,
}

/// Runtime value: a quantised feature map or an exact f32 tensor.
enum Val {
    Q(QActTensor),
    F(Tensor),
}

impl Val {
    fn to_f32(&self) -> Tensor {
        match self {
            Val::Q(q) => q.dequantize(),
            Val::F(t) => t.clone(),
        }
    }

    fn as_q(&self) -> Result<&QActTensor> {
        match self {
            Val::Q(q) => Ok(q),
            Val::F(_) => bail!("expected a quantised value"),
        }
    }
}

/// Runtime accounting for one planned op, accumulated across runs by a
/// profiling-enabled [`QModel`] (see [`PlanOpts::profile`]).
#[derive(Debug, Clone)]
pub struct OpStat {
    /// Graph node whose value this op produces.
    pub node: usize,
    /// Display label from the plan (same text as [`QModel::summarize`]).
    pub label: String,
    /// Runs on the integer path.
    pub int8: bool,
    /// Inner-kernel flavour for GEMM-backed ops (dense conv / linear).
    pub kernel: Option<KernelKind>,
    /// GEMM invocations one execution of this op performs (1 for dense
    /// conv and linear, 0 elsewhere — depthwise uses the direct path).
    pub gemm_per_call: u64,
    /// Executions accumulated.
    pub calls: u64,
    /// Total wall seconds inside this op.
    pub secs: f64,
    /// Activation bytes moved: input values read + output value
    /// written, per call (weights are resident and not counted).
    pub bytes: u64,
    /// Total GEMM invocations (`calls * gemm_per_call`).
    pub gemm_calls: u64,
}

/// Per-op runtime profile of a planned model: one [`OpStat`] per plan
/// op, plus run-level totals. Merging is exact, so the batch-parallel
/// path can accumulate per-worker profiles without synchronising per op.
#[derive(Debug, Clone, Default)]
pub struct RunProfile {
    pub ops: Vec<OpStat>,
    /// Batches accumulated (one per `run_batch`-equivalent pass).
    pub runs: u64,
    /// Wall seconds of whole profiled passes (includes arena setup and
    /// output collection, so it is an upper bound on the per-op sum).
    pub total_secs: f64,
}

impl RunProfile {
    fn for_ops(ops: &[PlannedOp]) -> RunProfile {
        let ops = ops
            .iter()
            .map(|p| {
                let (label, int8, _) = p.op.describe();
                let (kernel, gemm_per_call) = match &p.op {
                    QOp::Conv(c) => (
                        Some(c.kernel_kind()),
                        if c.is_depthwise() { 0 } else { 1 },
                    ),
                    QOp::ConvT(c) => (Some(c.kernel_kind()), 1),
                    QOp::Linear(l) => (Some(l.kernel_kind()), 1),
                    _ => (None, 0),
                };
                OpStat {
                    node: p.node,
                    label,
                    int8,
                    kernel,
                    gemm_per_call,
                    calls: 0,
                    secs: 0.0,
                    bytes: 0,
                    gemm_calls: 0,
                }
            })
            .collect();
        RunProfile { ops, runs: 0, total_secs: 0.0 }
    }

    /// Fold another profile of the *same plan* in (counters add).
    pub fn merge(&mut self, other: &RunProfile) {
        assert_eq!(
            self.ops.len(),
            other.ops.len(),
            "profiles of different plans"
        );
        for (a, b) in self.ops.iter_mut().zip(&other.ops) {
            a.calls += b.calls;
            a.secs += b.secs;
            a.bytes += b.bytes;
            a.gemm_calls += b.gemm_calls;
        }
        self.runs += other.runs;
        self.total_secs += other.total_secs;
    }

    /// Sum of per-op wall seconds.
    pub fn secs(&self) -> f64 {
        self.ops.iter().map(|o| o.secs).sum()
    }

    /// Sum of per-op activation bytes moved.
    pub fn bytes(&self) -> u64 {
        self.ops.iter().map(|o| o.bytes).sum()
    }

    /// Total GEMM invocations grouped by kernel flavour.
    pub fn gemm_by_kind(&self) -> Vec<(KernelKind, u64)> {
        let mut out: Vec<(KernelKind, u64)> = Vec::new();
        for o in &self.ops {
            let (Some(k), true) = (o.kernel, o.gemm_calls > 0) else {
                continue;
            };
            match out.iter_mut().find(|(kk, _)| *kk == k) {
                Some((_, n)) => *n += o.gemm_calls,
                None => out.push((k, o.gemm_calls)),
            }
        }
        out
    }

    /// The per-op time/bytes/kernel table `dfq profile` prints: one row
    /// per plan op in execution order, plus a totals row.
    pub fn table(&self) -> String {
        let total = self.secs().max(f64::MIN_POSITIVE);
        let mut s = format!(
            "{:<5} {:<4} {:<24} {:<6} {:>6} {:>11} {:>6} {:>9} {:>5}\n",
            "op", "node", "kind", "kernel", "calls", "time", "%", "MB",
            "gemm"
        );
        for (i, o) in self.ops.iter().enumerate() {
            s.push_str(&format!(
                "[{i:>3}] {:<4} {:<24} {:<6} {:>6} {:>11} {:>5.1}% \
                 {:>9.2} {:>5}\n",
                o.node,
                o.label,
                o.kernel.map(|k| k.name()).unwrap_or("-"),
                o.calls,
                crate::util::bench::fmt_secs(o.secs),
                100.0 * o.secs / total,
                o.bytes as f64 / 1e6,
                o.gemm_calls,
            ));
        }
        let gemm: u64 = self.ops.iter().map(|o| o.gemm_calls).sum();
        s.push_str(&format!(
            "total: {} over {} run(s)  {:.2} MB moved  {} gemm call(s)",
            crate::util::bench::fmt_secs(self.secs()),
            self.runs,
            self.bytes() as f64 / 1e6,
            gemm,
        ));
        for (k, n) in self.gemm_by_kind() {
            s.push_str(&format!("  [{} x{}]", k.name(), n));
        }
        s.push('\n');
        s
    }
}

/// A model compiled for integer execution: f32 in (images), f32 out
/// (dequantised primary outputs), everything between on integer grids
/// wherever the graph allows.
pub struct QModel {
    pub(crate) ops: Vec<PlannedOp>,
    pub(crate) slots: usize,
    /// Output slot / node id pairs, in model output order.
    pub(crate) outputs: Vec<(usize, usize)>,
    /// Conv/linear layers executing on the integer path.
    pub int_layers: usize,
    /// Conv/linear layers falling back to f32.
    pub f32_layers: usize,
    pub(crate) fallbacks: usize,
    /// Shared per-op runtime accounting, present iff profiling is on
    /// ([`PlanOpts::profile`] / [`QModel::enable_profiling`]). `None`
    /// keeps every run on the untouched non-instrumented loop.
    pub(crate) profile: Option<Arc<Mutex<RunProfile>>>,
}

fn row_qp(row: &SiteCfg) -> QParams {
    QParams {
        scale: row.scale,
        zero_point: row.zero_point,
        n_levels: row.n_levels,
    }
}

/// Compile a quantised model (fake-quant weights + retained integer
/// codes + activation site grids + optional aux grids) into a [`QModel`]
/// execution plan. Requires every activation site quantised to ≤ 8 bits
/// and retained codes for every conv/linear layer on the integer path.
pub fn plan(
    model: &Model,
    int_weights: &[(usize, QTensor)],
    cfg: &QuantCfg,
    aux: &AuxGrids,
    opts: PlanOpts,
) -> Result<QModel> {
    if !model.folded {
        bail!("plan requires a folded model");
    }
    let sites = model.act_sites();
    if sites.len() != cfg.rows.len() {
        bail!("QuantCfg rows {} != sites {}", cfg.rows.len(), sites.len());
    }
    for (i, r) in cfg.rows.iter().enumerate() {
        if !(2.0..=256.0).contains(&r.n_levels) {
            bail!(
                "int8 path requires every activation site quantised to \
                 2..=256 levels; site {i} has n_levels = {} \
                 (quantise with act_bits in 1..=8)",
                r.n_levels
            );
        }
    }
    let site_of = |id: usize| -> Option<usize> {
        sites.iter().position(|s| s.node_id() == Some(id))
    };
    let weights_of = |id: usize| -> Option<&QTensor> {
        int_weights.iter().find(|(wid, _)| *wid == id).map(|(_, t)| t)
    };

    let mut ops: Vec<PlannedOp> = Vec::new();
    // node id -> dense value slot
    let mut slot_of: HashMap<usize, usize> = HashMap::new();
    let mut slots = 0usize;
    let mut intern = |slot_of: &mut HashMap<usize, usize>, id: usize| {
        *slot_of.entry(id).or_insert_with(|| {
            let s = slots;
            slots += 1;
            s
        })
    };
    // node id -> Some(grid) when its value is quantised, None when f32
    let mut grids: HashMap<usize, Option<QParams>> = HashMap::new();
    let mut fused_acts: HashSet<usize> = HashSet::new();
    let mut int_layers = 0usize;
    let mut f32_layers = 0usize;

    for n in &model.nodes {
        let input_slot = |slot_of: &HashMap<usize, usize>,
                          id: usize|
         -> Result<usize> {
            slot_of
                .get(&id)
                .copied()
                .ok_or_else(|| anyhow!("node {} used before production", id))
        };
        match &n.op {
            Op::Input => {
                let qp = row_qp(&cfg.rows[0]);
                let out = intern(&mut slot_of, n.id);
                ops.push(PlannedOp {
                    node: n.id,
                    ins: vec![],
                    out,
                    op: QOp::QuantIn { qp },
                    free_after: vec![],
                });
                grids.insert(n.id, Some(qp));
            }
            Op::Conv { w, b, stride, pad, groups, out_ch, .. } => {
                let input = n.inputs[0];
                let in_slot = input_slot(&slot_of, input)?;
                let bias: Vec<f32> = match b {
                    Some(b) => model.tensor(b)?.data().to_vec(),
                    None => vec![0.0; *out_ch],
                };
                let in_grid = grids
                    .get(&input)
                    .cloned()
                    .ok_or_else(|| anyhow!("conv {} before input", n.id))?;
                match in_grid {
                    Some(in_qp) => {
                        let wq = weights_of(n.id).ok_or_else(|| {
                            anyhow!(
                                "no retained int8 weight codes for conv \
                                 node {} (quantise with bits <= 8)",
                                n.id
                            )
                        })?;
                        let cons = model.consumers(n.id);
                        let is_out = model.outputs.contains(&n.id);
                        // fuse when the conv's only consumer is an act
                        // and the conv's pre-activation value is not
                        // itself a model output (fusion stores the
                        // result under the act node id only)
                        let fuse = match cons.as_slice() {
                            [c] if matches!(c.op, Op::Act(_)) && !is_out => {
                                Some(c.id)
                            }
                            _ => None,
                        };
                        if let Some(act_id) = fuse {
                            let row = cfg.rows[site_of(act_id)
                                .expect("act node is a site")];
                            let conv = QConv::pack(
                                wq,
                                &bias,
                                *stride,
                                *pad,
                                *groups,
                                &in_qp,
                                EpiSpec::Act(&row),
                            )?;
                            let out = intern(&mut slot_of, act_id);
                            ops.push(PlannedOp {
                                node: act_id,
                                ins: vec![in_slot],
                                out,
                                op: QOp::Conv(Box::new(conv)),
                                free_after: vec![],
                            });
                            grids.insert(act_id, Some(row_qp(&row)));
                            grids.insert(n.id, None);
                            fused_acts.insert(act_id);
                        } else {
                            // not act-fused: requantise onto the conv's
                            // pre-activation grid when one is available
                            // and a downstream op wants a quantised
                            // value; model outputs stay exact f32
                            let epi = if !is_out && !cons.is_empty() {
                                match aux.preact_of(n.id) {
                                    Some(qp) => EpiSpec::Grid(qp),
                                    None => EpiSpec::F32,
                                }
                            } else {
                                EpiSpec::F32
                            };
                            let grid = match &epi {
                                EpiSpec::Grid(qp) => Some(*qp),
                                _ => None,
                            };
                            let conv = QConv::pack(
                                wq,
                                &bias,
                                *stride,
                                *pad,
                                *groups,
                                &in_qp,
                                epi,
                            )?;
                            let out = intern(&mut slot_of, n.id);
                            ops.push(PlannedOp {
                                node: n.id,
                                ins: vec![in_slot],
                                out,
                                op: QOp::Conv(Box::new(conv)),
                                free_after: vec![],
                            });
                            grids.insert(n.id, grid);
                        }
                        int_layers += 1;
                    }
                    None => {
                        // f32 input (e.g. a branch an upstream fallback
                        // already dequantised): exact f32 fallback over
                        // the fake-quantised weights.
                        let wt = model.tensor(w)?.clone();
                        let out = intern(&mut slot_of, n.id);
                        ops.push(PlannedOp {
                            node: n.id,
                            ins: vec![in_slot],
                            out,
                            op: QOp::ConvFp32 {
                                w: wt,
                                b: bias,
                                stride: *stride,
                                pad: *pad,
                                groups: *groups,
                            },
                            free_after: vec![],
                        });
                        grids.insert(n.id, None);
                        f32_layers += 1;
                    }
                }
            }
            Op::ConvT2d { w, b, stride, pad, out_ch, .. } => {
                // the dense-conv lowering shape-for-shape: fuse the sole
                // consuming act, else requantise onto the pre-activation
                // grid when one exists, else exact f32 out; an f32 input
                // takes the oracle fallback
                let input = n.inputs[0];
                let in_slot = input_slot(&slot_of, input)?;
                let bias: Vec<f32> = match b {
                    Some(b) => model.tensor(b)?.data().to_vec(),
                    None => vec![0.0; *out_ch],
                };
                let in_grid = grids
                    .get(&input)
                    .cloned()
                    .ok_or_else(|| anyhow!("convT {} before input", n.id))?;
                match in_grid {
                    Some(in_qp) => {
                        let wq = weights_of(n.id).ok_or_else(|| {
                            anyhow!(
                                "no retained int8 weight codes for convT \
                                 node {} (quantise with bits <= 8)",
                                n.id
                            )
                        })?;
                        let cons = model.consumers(n.id);
                        let is_out = model.outputs.contains(&n.id);
                        let fuse = match cons.as_slice() {
                            [c] if matches!(c.op, Op::Act(_)) && !is_out => {
                                Some(c.id)
                            }
                            _ => None,
                        };
                        if let Some(act_id) = fuse {
                            let row = cfg.rows[site_of(act_id)
                                .expect("act node is a site")];
                            let conv = QConvT::pack(
                                wq,
                                &bias,
                                *stride,
                                *pad,
                                &in_qp,
                                EpiSpec::Act(&row),
                            )?;
                            let out = intern(&mut slot_of, act_id);
                            ops.push(PlannedOp {
                                node: act_id,
                                ins: vec![in_slot],
                                out,
                                op: QOp::ConvT(Box::new(conv)),
                                free_after: vec![],
                            });
                            grids.insert(act_id, Some(row_qp(&row)));
                            grids.insert(n.id, None);
                            fused_acts.insert(act_id);
                        } else {
                            let epi = if !is_out && !cons.is_empty() {
                                match aux.preact_of(n.id) {
                                    Some(qp) => EpiSpec::Grid(qp),
                                    None => EpiSpec::F32,
                                }
                            } else {
                                EpiSpec::F32
                            };
                            let grid = match &epi {
                                EpiSpec::Grid(qp) => Some(*qp),
                                _ => None,
                            };
                            let conv = QConvT::pack(
                                wq, &bias, *stride, *pad, &in_qp, epi,
                            )?;
                            let out = intern(&mut slot_of, n.id);
                            ops.push(PlannedOp {
                                node: n.id,
                                ins: vec![in_slot],
                                out,
                                op: QOp::ConvT(Box::new(conv)),
                                free_after: vec![],
                            });
                            grids.insert(n.id, grid);
                        }
                        int_layers += 1;
                    }
                    None => {
                        let wt = model.tensor(w)?.clone();
                        let out = intern(&mut slot_of, n.id);
                        ops.push(PlannedOp {
                            node: n.id,
                            ins: vec![in_slot],
                            out,
                            op: QOp::ConvTFp32 {
                                w: wt,
                                b: bias,
                                stride: *stride,
                                pad: *pad,
                            },
                            free_after: vec![],
                        });
                        grids.insert(n.id, None);
                        f32_layers += 1;
                    }
                }
            }
            Op::Act(_) => {
                if fused_acts.contains(&n.id) {
                    continue;
                }
                let row = cfg.rows[site_of(n.id).expect("act site")];
                let in_slot = input_slot(&slot_of, n.inputs[0])?;
                let in_grid = grids
                    .get(&n.inputs[0])
                    .cloned()
                    .ok_or_else(|| anyhow!("act {} dangling", n.id))?;
                let op = match in_grid {
                    Some(in_qp) => QOp::Act(Requantizer::pack(&in_qp, &row)?),
                    None => QOp::ActF { row },
                };
                let out = intern(&mut slot_of, n.id);
                ops.push(PlannedOp {
                    node: n.id,
                    ins: vec![in_slot],
                    out,
                    op,
                    free_after: vec![],
                });
                grids.insert(n.id, Some(row_qp(&row)));
            }
            Op::Add => {
                let row = cfg.rows[site_of(n.id).expect("add site")];
                let (a, b) = (n.inputs[0], n.inputs[1]);
                let sa = input_slot(&slot_of, a)?;
                let sb = input_slot(&slot_of, b)?;
                let ga = grids
                    .get(&a)
                    .cloned()
                    .ok_or_else(|| anyhow!("add {} dangling", n.id))?;
                let gb = grids
                    .get(&b)
                    .cloned()
                    .ok_or_else(|| anyhow!("add {} dangling", n.id))?;
                let op = match (ga, gb) {
                    (Some(qa), Some(qb)) => {
                        QOp::Add(QAddInt::pack(&qa, &qb, &row_qp(&row))?)
                    }
                    _ => QOp::AddF { row },
                };
                let out = intern(&mut slot_of, n.id);
                ops.push(PlannedOp {
                    node: n.id,
                    ins: vec![sa, sb],
                    out,
                    op,
                    free_after: vec![],
                });
                grids.insert(n.id, Some(row_qp(&row)));
            }
            Op::Concat => {
                let row = cfg.rows[site_of(n.id).expect("concat site")];
                let mut ins = Vec::with_capacity(n.inputs.len());
                let mut in_grids = Vec::with_capacity(n.inputs.len());
                for &i in &n.inputs {
                    ins.push(input_slot(&slot_of, i)?);
                    in_grids.push(grids.get(&i).cloned().ok_or_else(
                        || anyhow!("concat {} dangling input {i}", n.id),
                    )?);
                }
                let op = if in_grids.iter().all(|g| g.is_some()) {
                    let qps: Vec<QParams> = in_grids
                        .iter()
                        .map(|g| (*g).expect("all quantised"))
                        .collect();
                    // unpackable integer concat (fan-in beyond the cap,
                    // or a grid pair whose multiplier degenerates)
                    // degrades to the f32 fallback like every other
                    // no-grid path — counted, reported, and fatal only
                    // under `int8_only`
                    match QConcatInt::pack(&qps, &row_qp(&row)) {
                        Ok(c) => QOp::Concat(c),
                        Err(_) => QOp::ConcatF { row },
                    }
                } else {
                    QOp::ConcatF { row }
                };
                let out = intern(&mut slot_of, n.id);
                ops.push(PlannedOp {
                    node: n.id,
                    ins,
                    out,
                    op,
                    free_after: vec![],
                });
                grids.insert(n.id, Some(row_qp(&row)));
            }
            Op::Pool2d { kind, k, stride, pad, global } => {
                let in_slot = input_slot(&slot_of, n.inputs[0])?;
                let in_grid = grids
                    .get(&n.inputs[0])
                    .cloned()
                    .ok_or_else(|| anyhow!("pool {} dangling", n.id))?;
                // an unpackable window (validate-bypassing graph)
                // degrades to the counted f32 fallback, like concat
                let fallback = || QOp::PoolF {
                    kind: *kind,
                    k: *k,
                    stride: *stride,
                    pad: *pad,
                    global: *global,
                };
                let (op, grid) = match in_grid {
                    Some(qp) => {
                        match QPoolInt::pack(
                            *kind, *k, *stride, *pad, *global, &qp,
                        ) {
                            Ok(p) => (QOp::Pool(p), Some(qp)),
                            Err(_) => (fallback(), None),
                        }
                    }
                    None => (fallback(), None),
                };
                let out = intern(&mut slot_of, n.id);
                ops.push(PlannedOp {
                    node: n.id,
                    ins: vec![in_slot],
                    out,
                    op,
                    free_after: vec![],
                });
                grids.insert(n.id, grid);
            }
            Op::Gap => {
                let in_slot = input_slot(&slot_of, n.inputs[0])?;
                let in_grid = grids
                    .get(&n.inputs[0])
                    .cloned()
                    .ok_or_else(|| anyhow!("gap {} dangling", n.id))?;
                let (op, grid) = match in_grid {
                    Some(qp) => (QOp::Gap { qp }, Some(qp)),
                    None => (QOp::GapF, None),
                };
                let out = intern(&mut slot_of, n.id);
                ops.push(PlannedOp {
                    node: n.id,
                    ins: vec![in_slot],
                    out,
                    op,
                    free_after: vec![],
                });
                grids.insert(n.id, grid);
            }
            Op::Linear { w, b, .. } => {
                let in_slot = input_slot(&slot_of, n.inputs[0])?;
                let bias = model.tensor(b)?.data().to_vec();
                let in_grid = grids
                    .get(&n.inputs[0])
                    .cloned()
                    .ok_or_else(|| anyhow!("linear {} dangling", n.id))?;
                let op = match in_grid {
                    Some(in_qp) => {
                        let wq = weights_of(n.id).ok_or_else(|| {
                            anyhow!(
                                "no retained int8 weight codes for linear \
                                 node {} (quantise with bits <= 8)",
                                n.id
                            )
                        })?;
                        int_layers += 1;
                        QOp::Linear(QLinear::pack(wq, &bias, &in_qp)?)
                    }
                    None => {
                        f32_layers += 1;
                        QOp::LinearF { w: model.tensor(w)?.clone(), b: bias }
                    }
                };
                let out = intern(&mut slot_of, n.id);
                ops.push(PlannedOp {
                    node: n.id,
                    ins: vec![in_slot],
                    out,
                    op,
                    free_after: vec![],
                });
                grids.insert(n.id, None);
            }
            Op::Upsample { factor } => {
                let in_slot = input_slot(&slot_of, n.inputs[0])?;
                let g = grids
                    .get(&n.inputs[0])
                    .cloned()
                    .ok_or_else(|| anyhow!("upsample {} dangling", n.id))?;
                let out = intern(&mut slot_of, n.id);
                ops.push(PlannedOp {
                    node: n.id,
                    ins: vec![in_slot],
                    out,
                    op: QOp::Upsample { factor: *factor, grid: g },
                    free_after: vec![],
                });
                grids.insert(n.id, g);
            }
            Op::BatchNorm { .. } => {
                bail!("plan requires a folded model (found bn node {})", n.id)
            }
        }
    }

    // Output slots (fused conv results live under the act node id).
    let outputs: Vec<(usize, usize)> = model
        .outputs
        .iter()
        .map(|o| {
            slot_of
                .get(o)
                .copied()
                .map(|s| (s, *o))
                .ok_or_else(|| anyhow!("missing output node {o}"))
        })
        .collect::<Result<_>>()?;

    // Free-after-last-use: a slot dies after its last consuming op
    // (model outputs are always kept).
    let keep: HashSet<usize> = outputs.iter().map(|&(s, _)| s).collect();
    let mut last_use: HashMap<usize, usize> = HashMap::new();
    for (i, p) in ops.iter().enumerate() {
        for &s in &p.ins {
            last_use.insert(s, i);
        }
    }
    for (slot, i) in last_use {
        if !keep.contains(&slot) {
            ops[i].free_after.push(slot);
        }
    }

    if opts.force_scalar {
        for p in &mut ops {
            match &mut p.op {
                QOp::Conv(c) => c.set_kernel(KernelKind::Scalar),
                QOp::ConvT(c) => c.set_kernel(KernelKind::Scalar),
                QOp::Linear(l) => l.set_kernel(KernelKind::Scalar),
                _ => {}
            }
        }
    }

    let fallbacks = ops
        .iter()
        .filter(|p| !p.op.describe().1)
        .count();
    if opts.int8_only && fallbacks > 0 {
        let list: Vec<String> = ops
            .iter()
            .filter(|p| !p.op.describe().1)
            .map(|p| format!("node {} {}", p.node, p.op.describe().0))
            .collect();
        bail!(
            "int8_only plan has {fallbacks} f32 fallback op(s): {}",
            list.join(", ")
        );
    }

    // plan-compilation trace: one summary event, Warn when the plan
    // carries f32 fallbacks (free when tracing is disabled)
    let sev = if fallbacks > 0 {
        crate::obs::trace::Severity::Warn
    } else {
        crate::obs::trace::Severity::Info
    };
    crate::obs::trace::emit_with(sev, "plan", || {
        let fb: Vec<String> = ops
            .iter()
            .filter(|p| !p.op.describe().1)
            .map(|p| format!("node {} {}", p.node, p.op.describe().0))
            .collect();
        (
            "compiled".into(),
            vec![
                ("ops", ops.len().to_string()),
                ("int_layers", int_layers.to_string()),
                ("f32_layers", f32_layers.to_string()),
                ("fallbacks", fallbacks.to_string()),
                ("fallback_ops", fb.join("; ")),
            ],
        )
    });

    let profile = opts
        .profile
        .then(|| Arc::new(Mutex::new(RunProfile::for_ops(&ops))));
    Ok(QModel { ops, slots, outputs, int_layers, f32_layers, fallbacks, profile })
}

impl QModel {
    /// Forward one batch: quantise the input, execute the plan over the
    /// slot arena, dequantise every model output to f32. Batches with
    /// more than one image are split per image and run in parallel
    /// ([`crate::util::parallel`]) — per-image results are
    /// bitwise-identical to [`QModel::run_batch`] because every kernel
    /// is image-independent. Scratch arenas are drawn from a shared
    /// per-run pool, so at most `workers` arenas are ever grown (instead
    /// of one allocation set per image) and each is recycled across the
    /// images its worker processes.
    pub fn run_all(&self, x: &Tensor) -> Result<Vec<Tensor>> {
        let n = x.shape().first().copied().unwrap_or(0);
        if n <= 1 || parallel::workers() <= 1 {
            return self.run_batch(x);
        }
        let per: usize = x.shape()[1..].iter().product();
        let mut shape1 = x.shape().to_vec();
        shape1[0] = 1;
        // per-worker scratch pool: an arm checks an arena out, runs its
        // image, and returns it grown — reuse is transparent because
        // every kernel writes before it reads its scratch region
        let pool: std::sync::Mutex<Vec<Scratch>> =
            std::sync::Mutex::new(Vec::new());
        let runs: Vec<Option<Result<Vec<Tensor>, String>>> =
            parallel::par_map(n, |i| {
                let xi = Tensor::new(
                    &shape1,
                    x.data()[i * per..(i + 1) * per].to_vec(),
                );
                let mut scratch =
                    pool.lock().unwrap().pop().unwrap_or_default();
                // one level of parallelism only: the per-image kernels
                // run serially inside this arm instead of spawning
                // workers² threads
                let out = parallel::with_nested_serial(|| {
                    self.run_batch_with(&xi, &mut scratch)
                })
                .map_err(|e| format!("{e:#}"));
                pool.lock().unwrap().push(scratch);
                Some(out)
            });
        let mut per_image: Vec<Vec<Tensor>> = Vec::with_capacity(n);
        for r in runs {
            per_image.push(
                r.expect("par_map fills every slot")
                    .map_err(|e| anyhow!("{e}"))?,
            );
        }
        let k = per_image[0].len();
        let mut res = Vec::with_capacity(k);
        for j in 0..k {
            let mut shape = per_image[0][j].shape().to_vec();
            shape[0] = n;
            let mut data = Vec::with_capacity(shape.iter().product());
            for img in &per_image {
                data.extend_from_slice(img[j].data());
            }
            res.push(Tensor::new(&shape, data));
        }
        Ok(res)
    }

    /// Reference serial path: the whole batch flows through the plan in
    /// one pass (also the n ≤ 1 fast path of [`QModel::run_all`]).
    pub fn run_batch(&self, x: &Tensor) -> Result<Vec<Tensor>> {
        self.run_batch_with(x, &mut Scratch::new())
    }

    /// [`QModel::run_batch`] over a caller-provided scratch arena (the
    /// batch-parallel path hands each worker a pooled arena). When
    /// profiling is off (the default) this is the untouched
    /// non-instrumented loop; when on, a local [`RunProfile`] is
    /// accumulated and folded into the shared profile once per batch,
    /// and outputs are bitwise-identical either way.
    pub fn run_batch_with(
        &self,
        x: &Tensor,
        scratch: &mut Scratch,
    ) -> Result<Vec<Tensor>> {
        if let Some(shared) = &self.profile {
            let mut local = RunProfile::for_ops(&self.ops);
            let out = self.run_batch_profiled(x, scratch, &mut local);
            shared.lock().unwrap().merge(&local);
            return out;
        }
        let mut arena: Vec<Option<Val>> = Vec::with_capacity(self.slots);
        arena.resize_with(self.slots, || None);
        for p in &self.ops {
            let y = exec(p, x, &arena, scratch)?;
            arena[p.out] = Some(y);
            for &s in &p.free_after {
                arena[s] = None;
            }
        }
        self.outputs
            .iter()
            .map(|&(s, node)| {
                arena[s]
                    .as_ref()
                    .map(Val::to_f32)
                    .ok_or_else(|| anyhow!("missing output node {node}"))
            })
            .collect()
    }

    /// The instrumented twin of the [`QModel::run_batch_with`] loop:
    /// identical op execution plus per-op wall time and activation-byte
    /// accounting into `prof`.
    fn run_batch_profiled(
        &self,
        x: &Tensor,
        scratch: &mut Scratch,
        prof: &mut RunProfile,
    ) -> Result<Vec<Tensor>> {
        let t_run = Instant::now();
        let mut arena: Vec<Option<Val>> = Vec::with_capacity(self.slots);
        arena.resize_with(self.slots, || None);
        for (i, p) in self.ops.iter().enumerate() {
            let in_bytes: u64 = if p.ins.is_empty() {
                (x.data().len() * 4) as u64
            } else {
                p.ins
                    .iter()
                    .map(|&s| {
                        arena[s].as_ref().map(val_bytes).unwrap_or(0)
                    })
                    .sum()
            };
            let t0 = Instant::now();
            let y = exec(p, x, &arena, scratch)?;
            let st = &mut prof.ops[i];
            st.secs += t0.elapsed().as_secs_f64();
            st.calls += 1;
            st.bytes += in_bytes + val_bytes(&y);
            st.gemm_calls += st.gemm_per_call;
            arena[p.out] = Some(y);
            for &s in &p.free_after {
                arena[s] = None;
            }
        }
        let out = self
            .outputs
            .iter()
            .map(|&(s, node)| {
                arena[s]
                    .as_ref()
                    .map(Val::to_f32)
                    .ok_or_else(|| anyhow!("missing output node {node}"))
            })
            .collect();
        prof.runs += 1;
        prof.total_secs += t_run.elapsed().as_secs_f64();
        out
    }

    /// Forward one batch, returning the primary output.
    pub fn run(&self, x: &Tensor) -> Result<Tensor> {
        self.run_all(x)?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("model has no outputs"))
    }

    /// Number of f32 fallback ops surviving planning (0 on a fully
    /// integer plan).
    pub fn fallback_ops(&self) -> usize {
        self.fallbacks
    }

    /// Is per-op profiling accumulating on this model?
    pub fn profiling(&self) -> bool {
        self.profile.is_some()
    }

    /// Turn per-op profiling on for a model planned (or loaded from an
    /// artifact) without [`PlanOpts::profile`]. Idempotent.
    pub fn enable_profiling(&mut self) {
        if self.profile.is_none() {
            let p = RunProfile::for_ops(&self.ops);
            self.profile = Some(Arc::new(Mutex::new(p)));
        }
    }

    /// Snapshot of the accumulated per-op profile (`None` when
    /// profiling is off).
    pub fn profile(&self) -> Option<RunProfile> {
        self.profile.as_ref().map(|p| p.lock().unwrap().clone())
    }

    /// Zero the accumulated profile (e.g. after warm-up runs).
    pub fn reset_profile(&self) {
        if let Some(p) = &self.profile {
            let mut g = p.lock().unwrap();
            *g = RunProfile::for_ops(&self.ops);
        }
    }

    /// Number of planned ops.
    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    /// One-line execution-plan summary (for logs).
    pub fn summary(&self) -> String {
        format!(
            "{} int8 layer(s), {} f32 fallback layer(s), {} fallback \
             op(s), {} op(s), {} value slot(s)",
            self.int_layers,
            self.f32_layers,
            self.fallbacks,
            self.ops.len(),
            self.slots
        )
    }

    /// Op-level plan report: one line per op with its kind, execution
    /// path and output grid (for logs, debugging, and the plan tests).
    pub fn summarize(&self) -> String {
        let mut s = format!("execution plan: {}\n", self.summary());
        for (i, p) in self.ops.iter().enumerate() {
            let (label, _, grid) = p.op.describe();
            let grid = match grid {
                Some(qp) => format!(
                    "grid(s={:.6}, zp={}, n={})",
                    qp.scale, qp.zero_point, qp.n_levels
                ),
                None => "f32".to_string(),
            };
            s.push_str(&format!(
                "  [{i:>3}] node {:>3}  {label:<22} -> {grid}\n",
                p.node
            ));
        }
        s
    }
}

/// Activation payload size of a runtime value (u8 codes, or f32 words).
fn val_bytes(v: &Val) -> u64 {
    match v {
        Val::Q(q) => q.codes.len() as u64,
        Val::F(t) => (t.data().len() * 4) as u64,
    }
}

fn exec(
    p: &PlannedOp,
    x: &Tensor,
    arena: &[Option<Val>],
    scratch: &mut Scratch,
) -> Result<Val> {
    let val = |i: usize| -> Result<&Val> {
        arena[p.ins[i]].as_ref().ok_or_else(|| {
            anyhow!("plan slot {} consumed before production", p.ins[i])
        })
    };
    Ok(match &p.op {
        QOp::QuantIn { qp } => Val::Q(QActTensor::quantize(x, qp)),
        QOp::Conv(c) => {
            let xin = val(0)?.as_q()?;
            if c.is_fused() {
                Val::Q(c.run_q_with(xin, scratch)?)
            } else {
                Val::F(c.run_f32_with(xin, scratch)?)
            }
        }
        QOp::ConvFp32 { w, b, stride, pad, groups } => {
            let xin = val(0)?.to_f32();
            Val::F(crate::nn::conv::conv2d(
                &xin,
                w,
                Some(b),
                *stride,
                *pad,
                *groups,
            ))
        }
        QOp::ConvT(c) => {
            let xin = val(0)?.as_q()?;
            if c.is_fused() {
                Val::Q(c.run_q_with(xin, scratch)?)
            } else {
                Val::F(c.run_f32_with(xin, scratch)?)
            }
        }
        QOp::ConvTFp32 { w, b, stride, pad } => {
            let xin = val(0)?.to_f32();
            Val::F(crate::nn::conv::conv_transpose2d(
                &xin,
                w,
                Some(b),
                *stride,
                *pad,
            ))
        }
        QOp::Add(add) => {
            Val::Q(add.run(val(0)?.as_q()?, val(1)?.as_q()?)?)
        }
        QOp::AddF { row } => {
            let t = fops::add(&val(0)?.to_f32(), &val(1)?.to_f32());
            Val::Q(QActTensor::quantize(&t, &row_qp(row)))
        }
        QOp::Concat(c) => {
            let mut ins = Vec::with_capacity(p.ins.len());
            for i in 0..p.ins.len() {
                ins.push(val(i)?.as_q()?);
            }
            Val::Q(c.run(&ins)?)
        }
        QOp::ConcatF { row } => {
            let fs: Vec<Tensor> =
                (0..p.ins.len()).map(|i| Ok(val(i)?.to_f32()))
                    .collect::<Result<_>>()?;
            let refs: Vec<&Tensor> = fs.iter().collect();
            let t = fops::concat_channels(&refs);
            Val::Q(QActTensor::quantize(&t, &row_qp(row)))
        }
        QOp::Pool(pl) => Val::Q(pl.run(val(0)?.as_q()?)?),
        QOp::PoolF { kind, k, stride, pad, global } => {
            let xin = val(0)?.to_f32();
            let s = xin.shape();
            if s.len() != 4 {
                bail!("pool wants NCHW input, got {s:?}");
            }
            let (k, stride, pad) = if *global {
                ((s[2], s[3]), (1, 1), (0, 0))
            } else {
                (*k, *stride, *pad)
            };
            if s[2] + 2 * pad.0 < k.0 || s[3] + 2 * pad.1 < k.1 {
                bail!("pool window {k:?} exceeds input {s:?} (pad {pad:?})");
            }
            Val::F(match kind {
                PoolKind::Max => fops::max_pool2d_rect(&xin, k, stride, pad),
                PoolKind::Avg => fops::avg_pool2d_rect(&xin, k, stride, pad),
            })
        }
        QOp::Act(rq) => Val::Q(rq.run(val(0)?.as_q()?)?),
        QOp::ActF { row } => {
            let mut t = val(0)?.to_f32();
            fops::clip_act(&mut t, row.clip_hi);
            Val::Q(QActTensor::quantize(&t, &row_qp(row)))
        }
        QOp::Gap { .. } => Val::Q(gap_int(val(0)?.as_q()?)?),
        QOp::GapF => Val::F(fops::global_avg_pool(&val(0)?.to_f32())),
        QOp::Linear(l) => Val::F(l.run(val(0)?.as_q()?, scratch)?),
        QOp::LinearF { w, b } => {
            Val::F(fops::linear(&val(0)?.to_f32(), w, b))
        }
        QOp::Upsample { factor, .. } => match val(0)? {
            Val::Q(q) => Val::Q(upsample_codes(q, *factor)),
            Val::F(t) => Val::F(fops::upsample_nearest(t, *factor)),
        },
    })
}
