//! Pure-Rust reference engine over the folded graph.
//!
//! Implements exactly the executable contract of DESIGN.md §3 (the same
//! semantics the AOT-lowered JAX/Pallas graph executes on PJRT), so it
//! serves as (a) the correctness oracle for the runtime, (b) the
//! substrate for the empirical bias-correction pass (needs per-layer
//! pre-activation means), and (c) a PJRT-free fallback engine.

pub mod conv;
pub mod ops;
pub mod qengine;

use std::collections::HashMap;

use anyhow::Result;

use crate::graph::{Model, Op, Site};
use crate::tensor::Tensor;

/// Per-site activation quantisation row: `(scale, zero_point, n_levels,
/// clip_hi)` — one row per [`Model::act_sites`] entry, `n_levels == 0`
/// disables fake-quant at that site (FP32 eval).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SiteCfg {
    pub scale: f32,
    pub zero_point: f32,
    pub n_levels: f32,
    pub clip_hi: f32,
}

impl SiteCfg {
    pub fn fp32(clip_hi: f32) -> SiteCfg {
        SiteCfg { scale: 1.0, zero_point: 0.0, n_levels: 0.0, clip_hi }
    }
}

/// Full activation-quantisation configuration for one executable call.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantCfg {
    pub rows: Vec<SiteCfg>,
}

impl QuantCfg {
    /// FP32 passthrough: no fake-quant anywhere, clip bounds follow the
    /// activation kinds in the graph.
    pub fn fp32(model: &Model) -> QuantCfg {
        let rows = model
            .act_sites()
            .iter()
            .map(|s| match s {
                Site::Input => SiteCfg::fp32(f32::INFINITY),
                Site::Act { kind, .. } => SiteCfg::fp32(kind.clip_hi()),
                Site::Add { .. } | Site::Concat { .. } => {
                    SiteCfg::fp32(f32::INFINITY)
                }
            })
            .collect();
        QuantCfg { rows }
    }

    /// Flatten to the f32[S, 4] layout of the PJRT executable argument.
    /// Infinite clip bounds map to 1e30 (matches the python lowering).
    pub fn to_flat(&self) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.rows.len() * 4);
        for r in &self.rows {
            v.push(r.scale);
            v.push(r.zero_point);
            v.push(r.n_levels);
            v.push(if r.clip_hi.is_finite() { r.clip_hi } else { 1e30 });
        }
        v
    }
}

/// Run the folded graph on a batch; returns the output tensors.
pub fn forward(model: &Model, x: &Tensor, cfg: &QuantCfg) -> Result<Vec<Tensor>> {
    let vals = forward_collect(model, x, cfg)?;
    Ok(model.outputs.iter().map(|o| vals[o].clone()).collect())
}

/// Run the folded graph keeping every node output (instrumented mode —
/// used by empirical bias correction and engine cross-checks).
pub fn forward_collect(
    model: &Model,
    x: &Tensor,
    cfg: &QuantCfg,
) -> Result<HashMap<usize, Tensor>> {
    assert!(model.folded, "engine requires a folded model");
    let sites = model.act_sites();
    debug_assert_eq!(sites.len(), cfg.rows.len(), "QuantCfg row mismatch");
    let site_of = |id: usize| -> Option<usize> {
        sites.iter().position(|s| s.node_id() == Some(id))
    };

    let mut vals: HashMap<usize, Tensor> = HashMap::new();
    let mut x0 = x.clone();
    let r0 = cfg.rows[0];
    ops::fake_quant(&mut x0, r0.scale, r0.zero_point, r0.n_levels);
    vals.insert(0, x0);

    for n in &model.nodes {
        let y = match &n.op {
            Op::Input => continue,
            Op::Conv { w, b, stride, pad, groups, .. } => {
                let xin = &vals[&n.inputs[0]];
                let wt = model.tensor(w)?;
                let bias = match b {
                    Some(b) => Some(model.tensor(b)?.data()),
                    None => None,
                };
                conv::conv2d(xin, wt, bias, *stride, *pad, *groups)
            }
            Op::Act(_) => {
                let row = cfg.rows[site_of(n.id).expect("act site")];
                let mut t = vals[&n.inputs[0]].clone();
                ops::clip_act(&mut t, row.clip_hi);
                ops::fake_quant(&mut t, row.scale, row.zero_point, row.n_levels);
                t
            }
            Op::Add => {
                let row = cfg.rows[site_of(n.id).expect("add site")];
                let mut t =
                    ops::add(&vals[&n.inputs[0]], &vals[&n.inputs[1]]);
                ops::fake_quant(&mut t, row.scale, row.zero_point, row.n_levels);
                t
            }
            Op::Concat => {
                let row = cfg.rows[site_of(n.id).expect("concat site")];
                let ins: Vec<&Tensor> =
                    n.inputs.iter().map(|i| &vals[i]).collect();
                let mut t = ops::concat_channels(&ins);
                ops::fake_quant(&mut t, row.scale, row.zero_point, row.n_levels);
                t
            }
            Op::Gap => ops::global_avg_pool(&vals[&n.inputs[0]]),
            Op::Pool2d { kind, k, stride, pad, global } => {
                let x = &vals[&n.inputs[0]];
                // a global pool is a single full-extent window
                let (k, stride, pad) = if *global {
                    let s = x.shape();
                    ((s[2], s[3]), (1, 1), (0, 0))
                } else {
                    (*k, *stride, *pad)
                };
                match kind {
                    crate::graph::PoolKind::Max => {
                        ops::max_pool2d_rect(x, k, stride, pad)
                    }
                    crate::graph::PoolKind::Avg => {
                        ops::avg_pool2d_rect(x, k, stride, pad)
                    }
                }
            }
            Op::ConvT2d { w, b, stride, pad, .. } => {
                let xin = &vals[&n.inputs[0]];
                let wt = model.tensor(w)?;
                let bias = match b {
                    Some(b) => Some(model.tensor(b)?.data()),
                    None => None,
                };
                conv::conv_transpose2d(xin, wt, bias, *stride, *pad)
            }
            Op::Linear { w, b, .. } => {
                let wt = model.tensor(w)?;
                let bias = model.tensor(b)?.data();
                ops::linear(&vals[&n.inputs[0]], wt, bias)
            }
            Op::Upsample { factor } => {
                ops::upsample_nearest(&vals[&n.inputs[0]], *factor)
            }
            Op::BatchNorm { .. } => {
                unreachable!("folded model has no bn nodes")
            }
        };
        vals.insert(n.id, y);
    }
    Ok(vals)
}

/// Per-layer *pre-activation* channel means over a batch: conv/linear
/// node id -> per-out-channel mean. The instrumentation the empirical
/// bias-correction procedure (paper appendix D) consumes.
pub fn preact_channel_means(
    model: &Model,
    x: &Tensor,
    cfg: &QuantCfg,
) -> Result<HashMap<usize, Vec<f32>>> {
    let vals = forward_collect(model, x, cfg)?;
    let mut out = HashMap::new();
    for n in &model.nodes {
        match &n.op {
            Op::Conv { out_ch, .. } | Op::ConvT2d { out_ch, .. } => {
                let t = &vals[&n.id];
                let s = t.shape();
                out.insert(
                    n.id,
                    crate::util::stats::channel_means(
                        t.data(),
                        s[0],
                        *out_ch,
                        s[2] * s[3],
                    ),
                );
            }
            Op::Linear { out_dim, .. } => {
                let t = &vals[&n.id];
                out.insert(
                    n.id,
                    crate::util::stats::channel_means(
                        t.data(),
                        t.shape()[0],
                        *out_dim,
                        1,
                    ),
                );
            }
            _ => {}
        }
    }
    Ok(out)
}
