//! True int8 execution: integer im2col + u8×i8→i32 GEMM with fixed-point
//! requantisation, and a packed whole-graph executor.
//!
//! The f32 engine ([`super::forward`]) *simulates* quantisation: it
//! computes every conv in f32 over fake-quantised values. This module
//! executes the same function on the integer grids themselves:
//!
//! * activations are u8 codes on their site grid `(s_in, zp_in)`,
//! * weights are i8 offset codes (`q - 128`) from the retained
//!   [`QTensor`] grids of [`crate::dfq::QuantizedModel`],
//! * a conv is `acc[p,o] = Σ_k a[p,k]·w[k,o]` in i32 (the GEMM reuses the
//!   [`crate::util::parallel`] row-chunking of the f32 path, and the
//!   im2col layout code is shared via [`super::conv::im2col_into`] with
//!   the input zero-point as padding value — `zp_in` *represents* 0),
//! * zero-point cross terms are folded per the gemmlowp identity
//!   `Σ(qa-za)(qw-zw) = Σ qa·qw - zw·rowsum(qa) - za·colsum(qw) + K·za·zw`
//!   (colsum/K terms are baked into an i32 bias at pack time; the rowsum
//!   term costs one pass per im2col row),
//! * requantisation to the next site grid multiplies by
//!   `M = s_in·s_w/s_out` as an i64 fixed-point multiplier + shift, with
//!   the clamped-ReLU/ReLU6 of the site fused into the integer clamp
//!   `q ∈ [max(0, zp_out), zp_out + round(clip_hi/s_out)]` — matching the
//!   f32 oracle's `clip_act` + `fake_quant` semantics to within one
//!   quantisation step per element.
//!
//! Ops with no integer kernel (GAP, the linear head, residual adds) fall
//! back to exact f32 over dequantised on-grid values, which is
//! bit-identical to what the oracle computes at those nodes.

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, bail, Result};

use crate::graph::{Model, Op};
use crate::quant::QParams;
use crate::tensor::{QTensor, Tensor};
use crate::util::parallel;

use super::conv::im2col_into;
use super::{ops, QuantCfg, SiteCfg};

// -- quantised activation tensors -------------------------------------------

/// A feature map held as u8 grid codes with one per-tensor grid.
#[derive(Debug, Clone, PartialEq)]
pub struct QActTensor {
    pub shape: Vec<usize>,
    pub codes: Vec<u8>,
    pub qp: QParams,
}

fn assert_act_grid(qp: &QParams) {
    assert!(
        (2.0..=256.0).contains(&qp.n_levels),
        "activation grid needs 2..=256 levels, got {}",
        qp.n_levels
    );
    assert!(
        qp.zero_point.fract() == 0.0
            && qp.zero_point >= 0.0
            && qp.zero_point <= qp.n_levels - 1.0,
        "activation zero point {} not an integer on the grid",
        qp.zero_point
    );
}

impl QActTensor {
    /// Quantise an f32 tensor onto `qp` (same rounding as `fake_quant`,
    /// via the shared [`crate::tensor::qtensor::code_of`]).
    pub fn quantize(t: &Tensor, qp: &QParams) -> QActTensor {
        assert_act_grid(qp);
        let codes = t
            .data()
            .iter()
            .map(|&x| crate::tensor::qtensor::code_of(x, qp))
            .collect();
        QActTensor { shape: t.shape().to_vec(), codes, qp: *qp }
    }

    /// Exact f32 image of the codes.
    pub fn dequantize(&self) -> Tensor {
        let zp = self.qp.zero_point;
        let s = self.qp.scale;
        Tensor::new(
            &self.shape,
            self.codes.iter().map(|&q| (q as f32 - zp) * s).collect(),
        )
    }
}

// -- integer GEMM primitives ------------------------------------------------

/// C[m,n] = A[m,k] · B[k,n] with u8 activations × i8 weights → i32
/// accumulators. Same saxpy-style loop and row-parallel chunking as the
/// f32 [`super::conv::matmul`]; the `q == 0` skip exploits ReLU sparsity
/// (post-ReLU grids have `zp == 0`, so code 0 is exactly value 0).
pub fn qgemm(a: &[u8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * n];
    {
        let cells = parallel::as_send_cells(&mut c);
        parallel::par_chunks(m, |lo, hi| {
            for i in lo..hi {
                let arow = &a[i * k..(i + 1) * k];
                // SAFETY: rows [lo, hi) are written by this chunk only.
                let crow = unsafe { cells.slice(i * n, n) };
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0 {
                        continue;
                    }
                    let av = av as i32;
                    let brow = &b[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += av * brow[j] as i32;
                    }
                }
            }
        });
    }
    c
}

/// Per-row sums of a u8 matrix (the gemmlowp rowsum correction input).
pub fn rowsums_u8(a: &[u8], m: usize, k: usize) -> Vec<i32> {
    (0..m)
        .map(|i| a[i * k..(i + 1) * k].iter().map(|&v| v as i32).sum())
        .collect()
}

// -- fixed-point requantisation ---------------------------------------------

/// A positive real multiplier `M` as `m · 2^-shift` with `m ∈ [2^30,
/// 2^31)`; degenerate magnitudes fall back to f64 rounding.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mult {
    Fixed { m: i32, shift: u32 },
    Float(f64),
}

/// Decompose `x > 0` into the i64 fixed-point form.
pub fn mult_for(x: f64) -> Mult {
    if !x.is_finite() || x <= 0.0 {
        return Mult::Float(x.max(0.0));
    }
    let mut v = x;
    let mut e = 0i32;
    while v < 0.5 {
        v *= 2.0;
        e -= 1;
    }
    while v >= 1.0 {
        v /= 2.0;
        e += 1;
    }
    let mut m = (v * (1u64 << 31) as f64).round() as i64;
    let mut shift = 31 - e;
    if m == 1i64 << 31 {
        m >>= 1;
        shift -= 1;
    }
    if !(1..=62).contains(&shift) {
        return Mult::Float(x);
    }
    Mult::Fixed { m: m as i32, shift: shift as u32 }
}

/// `round(t · M)` (round half away from zero for the fixed-point form —
/// within the engine's one-step tolerance of the oracle's ties-to-even).
#[inline]
pub fn apply_mult(t: i64, m: &Mult) -> i64 {
    match *m {
        Mult::Fixed { m, shift } => {
            let prod = t as i128 * m as i128;
            let half = 1i128 << (shift - 1);
            let r = if prod >= 0 {
                (prod + half) >> shift
            } else {
                -((-prod + half) >> shift)
            };
            r as i64
        }
        Mult::Float(f) => (t as f64 * f).round() as i64,
    }
}

// -- packed convolution layers ----------------------------------------------

/// Fused requant epilogue: integer bias (zero-point corrections + the
/// f32 bias folded onto the accumulator grid), per-channel multipliers,
/// and the clamp implementing both the output grid and the activation's
/// clipped-ReLU bounds.
#[derive(Debug, Clone)]
struct Epilogue {
    /// `round(b/(s_in·s_w)) - zp_in·colsum + K·zp_in·zp_w` per channel.
    bias_q: Vec<i64>,
    /// `s_in·s_w[o]/s_out` per channel.
    mult: Vec<Mult>,
    zp_out: i32,
    q_lo: i32,
    q_hi: i32,
    out_qp: QParams,
}

/// One conv layer packed for integer execution: offset i8 weight codes,
/// per-channel grids, zero-point correction constants, and (when fused
/// with an activation site) the requant [`Epilogue`].
#[derive(Debug, Clone)]
pub struct QConv {
    c_out: usize,
    cig: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    groups: usize,
    /// groups == 1: transposed (kdim, c_out) for the GEMM;
    /// depthwise: O-major (c, kh·kw).
    w: Vec<i8>,
    /// Signed-storage weight zero point (`zp_w - 128`) per out channel.
    zp_w: Vec<i32>,
    s_w: Vec<f32>,
    /// `-zp_in·colsum[o] + K·zp_in·zp_w[o]` per out channel.
    zp_corr: Vec<i64>,
    bias_f: Vec<f32>,
    in_qp: QParams,
    epi: Option<Epilogue>,
}

impl QConv {
    /// Pack one conv layer. `w` must hold signed (i8) codes with OIHW
    /// shape; `in_qp` is the grid of the layer's input feature map.
    /// `fused` carries the activation site row this conv feeds (when it
    /// is the site's only producer): the epilogue then requantises to
    /// that grid with the site's clip bounds fused (ReLU at `zp_out`,
    /// ReLU6 via `clip_hi`). Without `fused`, [`QConv::run_f32`] must be
    /// used (integer accumulate, f32 output).
    pub fn pack(
        w: &QTensor,
        bias: &[f32],
        stride: usize,
        pad: usize,
        groups: usize,
        in_qp: &QParams,
        fused: Option<&SiteCfg>,
    ) -> Result<QConv> {
        let shape = w.shape();
        if shape.len() != 4 {
            bail!("QConv wants OIHW weights, got {:?}", shape);
        }
        let (c_out, cig, kh, kw) = (shape[0], shape[1], shape[2], shape[3]);
        if groups != 1 && (cig != 1 || groups != c_out) {
            bail!("QConv supports dense or depthwise grouping only");
        }
        if bias.len() != c_out {
            bail!("bias len {} != out channels {}", bias.len(), c_out);
        }
        assert_act_grid(in_qp);
        let codes = w
            .codes_i8()
            .ok_or_else(|| anyhow!("QConv wants signed (i8) weight codes"))?;
        let per = cig * kh * kw;
        let zp_in = in_qp.zero_point as i64;

        // Per-channel grids (per-tensor grids broadcast).
        let mut zp_w = Vec::with_capacity(c_out);
        let mut s_w = Vec::with_capacity(c_out);
        for o in 0..c_out {
            let p = w.param_for_channel(o);
            zp_w.push(p.zero_point as i32 - 128);
            s_w.push(p.scale);
        }

        // colsum + the constant zero-point correction terms.
        let mut zp_corr = Vec::with_capacity(c_out);
        for o in 0..c_out {
            let colsum: i64 = codes[o * per..(o + 1) * per]
                .iter()
                .map(|&v| v as i64)
                .sum();
            zp_corr.push(
                -zp_in * colsum + per as i64 * zp_in * zp_w[o] as i64,
            );
        }

        // Weight layout for the kernels.
        let w_packed = if groups == 1 {
            // transpose OIHW -> (kdim, c_out) once, at pack time
            let mut wt = vec![0i8; per * c_out];
            for o in 0..c_out {
                for kk in 0..per {
                    wt[kk * c_out + o] = codes[o * per + kk];
                }
            }
            wt
        } else {
            codes.to_vec()
        };

        let epi = match fused {
            None => None,
            Some(row) => {
                if !(2.0..=256.0).contains(&row.n_levels) {
                    bail!(
                        "fused epilogue needs a quantised site \
                         (2..=256 levels), got {}",
                        row.n_levels
                    );
                }
                let out_qp = QParams {
                    scale: row.scale,
                    zero_point: row.zero_point,
                    n_levels: row.n_levels,
                };
                assert_act_grid(&out_qp);
                let zp_out = out_qp.zero_point as i32;
                let n_hi = out_qp.n_levels as i32 - 1;
                let q_lo = zp_out.clamp(0, n_hi); // clamp(x, 0, ..) of the act
                let q_hi = if row.clip_hi.is_finite() {
                    (zp_out + (row.clip_hi / row.scale).round() as i32)
                        .clamp(q_lo, n_hi)
                } else {
                    n_hi
                };
                let mut bias_q = Vec::with_capacity(c_out);
                let mut mult = Vec::with_capacity(c_out);
                for o in 0..c_out {
                    let acc_scale = in_qp.scale as f64 * s_w[o] as f64;
                    bias_q.push(
                        (bias[o] as f64 / acc_scale).round() as i64
                            + zp_corr[o],
                    );
                    mult.push(mult_for(acc_scale / row.scale as f64));
                }
                Some(Epilogue { bias_q, mult, zp_out, q_lo, q_hi, out_qp })
            }
        };

        Ok(QConv {
            c_out,
            cig,
            kh,
            kw,
            stride,
            pad,
            groups,
            w: w_packed,
            zp_w,
            s_w,
            zp_corr,
            bias_f: bias.to_vec(),
            in_qp: *in_qp,
            epi,
        })
    }

    pub fn out_channels(&self) -> usize {
        self.c_out
    }

    pub fn is_fused(&self) -> bool {
        self.epi.is_some()
    }

    fn check_input(&self, x: &QActTensor) -> Result<(usize, usize, usize)> {
        if x.qp != self.in_qp {
            bail!(
                "input grid mismatch: layer packed for {:?}, got {:?}",
                self.in_qp,
                x.qp
            );
        }
        if x.shape.len() != 4 || x.shape[1] != self.cig * self.groups {
            bail!(
                "input shape {:?} incompatible with conv ({} channels)",
                x.shape,
                self.cig * self.groups
            );
        }
        Ok((x.shape[0], x.shape[2], x.shape[3]))
    }

    /// Integer accumulators for one image, plus the im2col row sums
    /// (dense) — the shared front half of both run paths.
    #[allow(clippy::too_many_arguments)]
    fn accumulate_dense(
        &self,
        x: &QActTensor,
        img: usize,
        h: usize,
        wd: usize,
        oh: usize,
        ow: usize,
        col: &mut [u8],
    ) -> (Vec<i32>, Vec<i32>) {
        let c_in = self.cig;
        let kdim = c_in * self.kh * self.kw;
        im2col_into(
            &x.codes,
            c_in,
            h,
            wd,
            img,
            self.kh,
            self.kw,
            self.stride,
            self.pad,
            oh,
            ow,
            self.in_qp.zero_point as u8,
            col,
        );
        let rows = rowsums_u8(col, oh * ow, kdim);
        let acc = qgemm(col, &self.w, oh * ow, kdim, self.c_out);
        (acc, rows)
    }

    /// Fused path: u8 in → u8 out on the activation site grid.
    pub fn run_q(&self, x: &QActTensor) -> Result<QActTensor> {
        let epi = self
            .epi
            .as_ref()
            .ok_or_else(|| anyhow!("QConv not packed with a fused epilogue"))?;
        let (n, h, wd) = self.check_input(x)?;
        let oh = (h + 2 * self.pad - self.kh) / self.stride + 1;
        let ow = (wd + 2 * self.pad - self.kw) / self.stride + 1;
        let ohw = oh * ow;
        let mut out = vec![0u8; n * self.c_out * ohw];

        if self.groups == 1 {
            let kdim = self.cig * self.kh * self.kw;
            let mut col = vec![0u8; ohw * kdim];
            for img in 0..n {
                let (acc, rows) =
                    self.accumulate_dense(x, img, h, wd, oh, ow, &mut col);
                let base = img * self.c_out * ohw;
                for o in 0..self.c_out {
                    let zpw = self.zp_w[o] as i64;
                    let bq = epi.bias_q[o];
                    let m = &epi.mult[o];
                    let dst = &mut out[base + o * ohw..base + (o + 1) * ohw];
                    for (p, d) in dst.iter_mut().enumerate() {
                        let t = acc[p * self.c_out + o] as i64
                            - zpw * rows[p] as i64
                            + bq;
                        let q = (apply_mult(t, m) + epi.zp_out as i64)
                            .clamp(epi.q_lo as i64, epi.q_hi as i64);
                        *d = q as u8;
                    }
                }
            }
        } else {
            let requant = |c: usize, t: i64| {
                let q = (apply_mult(t + epi.bias_q[c], &epi.mult[c])
                    + epi.zp_out as i64)
                    .clamp(epi.q_lo as i64, epi.q_hi as i64);
                q as u8
            };
            self.depthwise(x, n, h, wd, oh, ow, requant, &mut out);
        }
        Ok(QActTensor {
            shape: vec![n, self.c_out, oh, ow],
            codes: out,
            qp: epi.out_qp,
        })
    }

    /// Unfused path: u8 in → exact f32 pre-activation output (integer
    /// accumulate, float epilogue). Matches the f32 oracle's conv output
    /// on the same fake-quantised operands up to f32 rounding.
    pub fn run_f32(&self, x: &QActTensor) -> Result<Tensor> {
        let (n, h, wd) = self.check_input(x)?;
        let oh = (h + 2 * self.pad - self.kh) / self.stride + 1;
        let ow = (wd + 2 * self.pad - self.kw) / self.stride + 1;
        let ohw = oh * ow;
        let mut out = Tensor::zeros(&[n, self.c_out, oh, ow]);
        let od = out.data_mut();

        if self.groups == 1 {
            let kdim = self.cig * self.kh * self.kw;
            let mut col = vec![0u8; ohw * kdim];
            for img in 0..n {
                let (acc, rows) =
                    self.accumulate_dense(x, img, h, wd, oh, ow, &mut col);
                let base = img * self.c_out * ohw;
                for o in 0..self.c_out {
                    let zpw = self.zp_w[o] as i64;
                    let corr = self.zp_corr[o];
                    let scale = self.in_qp.scale as f64 * self.s_w[o] as f64;
                    let bias = self.bias_f[o];
                    let dst =
                        &mut od[base + o * ohw..base + (o + 1) * ohw];
                    for (p, d) in dst.iter_mut().enumerate() {
                        let t = acc[p * self.c_out + o] as i64
                            - zpw * rows[p] as i64
                            + corr;
                        *d = (t as f64 * scale) as f32 + bias;
                    }
                }
            }
        } else {
            self.depthwise_f32(x, n, h, wd, oh, ow, od);
        }
        Ok(out)
    }

    /// Depthwise integer core with a per-element epilogue producing u8.
    /// `t` handed to the epilogue is the raw rowsum-corrected i64
    /// accumulator; the closure adds its own per-channel constants
    /// (`bias_q` already folds the static zero-point correction).
    #[allow(clippy::too_many_arguments)]
    fn depthwise<F>(
        &self,
        x: &QActTensor,
        n: usize,
        h: usize,
        wd: usize,
        oh: usize,
        ow: usize,
        epilogue: F,
        out: &mut [u8],
    ) where
        F: Fn(usize, i64) -> u8,
    {
        let c = self.c_out;
        let khw = self.kh * self.kw;
        let zp_in = self.in_qp.zero_point as i32;
        for img in 0..n {
            for ch in 0..c {
                let xoff = (img * c + ch) * h * wd;
                let ooff = (img * c + ch) * oh * ow;
                let wch = &self.w[ch * khw..(ch + 1) * khw];
                let zpw = self.zp_w[ch] as i64;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let (acc, sx) = self.dw_patch(
                            &x.codes, xoff, h, wd, oy, ox, wch, zp_in,
                        );
                        let t = acc - zpw * sx as i64;
                        out[ooff + oy * ow + ox] = epilogue(ch, t);
                    }
                }
            }
        }
    }

    /// Depthwise integer core with the f32 epilogue.
    #[allow(clippy::too_many_arguments)]
    fn depthwise_f32(
        &self,
        x: &QActTensor,
        n: usize,
        h: usize,
        wd: usize,
        oh: usize,
        ow: usize,
        out: &mut [f32],
    ) {
        let c = self.c_out;
        let khw = self.kh * self.kw;
        let zp_in = self.in_qp.zero_point as i32;
        for img in 0..n {
            for ch in 0..c {
                let xoff = (img * c + ch) * h * wd;
                let ooff = (img * c + ch) * oh * ow;
                let wch = &self.w[ch * khw..(ch + 1) * khw];
                let zpw = self.zp_w[ch] as i64;
                let scale = self.in_qp.scale as f64 * self.s_w[ch] as f64;
                let bias = self.bias_f[ch];
                for oy in 0..oh {
                    for ox in 0..ow {
                        let (acc, sx) = self.dw_patch(
                            &x.codes, xoff, h, wd, oy, ox, wch, zp_in,
                        );
                        let t = acc - zpw * sx as i64 + self.zp_corr[ch];
                        out[ooff + oy * ow + ox] =
                            (t as f64 * scale) as f32 + bias;
                    }
                }
            }
        }
    }

    /// One depthwise kernel window: (Σ q·w, Σ q) with out-of-bounds
    /// positions read as `zp_in` (they represent exact zeros).
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn dw_patch(
        &self,
        codes: &[u8],
        xoff: usize,
        h: usize,
        wd: usize,
        oy: usize,
        ox: usize,
        wch: &[i8],
        zp_in: i32,
    ) -> (i64, i32) {
        let mut acc = 0i64;
        let mut sx = 0i32;
        let iy0 = oy * self.stride;
        let ix0 = ox * self.stride;
        for dy in 0..self.kh {
            let iy = iy0 + dy;
            for dx in 0..self.kw {
                let ix = ix0 + dx;
                let q = if iy < self.pad
                    || iy >= h + self.pad
                    || ix < self.pad
                    || ix >= wd + self.pad
                {
                    zp_in
                } else {
                    codes[xoff + (iy - self.pad) * wd + (ix - self.pad)]
                        as i32
                };
                acc += (q * wch[dy * self.kw + dx] as i32) as i64;
                sx += q;
            }
        }
        (acc, sx)
    }
}

// -- packed whole-graph executor --------------------------------------------

/// Runtime value: a quantised feature map or an exact f32 tensor.
enum Val {
    Q(QActTensor),
    F(Tensor),
}

impl Val {
    fn to_f32(&self) -> Tensor {
        match self {
            Val::Q(q) => q.dequantize(),
            Val::F(t) => t.clone(),
        }
    }

    fn as_q(&self) -> Result<&QActTensor> {
        match self {
            Val::Q(q) => Ok(q),
            Val::F(_) => bail!("expected a quantised value"),
        }
    }
}

enum Step {
    /// Quantise the model input onto the site-0 grid.
    QuantInput { node: usize, qp: QParams },
    /// Integer conv fused with its single consuming activation site;
    /// the result is stored under the *act* node id.
    ConvQ { input: usize, act_node: usize, conv: Box<QConv> },
    /// Integer conv, f32 output (no single fused act consumer).
    ConvF { node: usize, input: usize, conv: Box<QConv> },
    /// Pure f32 conv fallback (the layer's input has no quantised grid);
    /// runs over the fake-quantised weights, exactly like the oracle.
    ConvFp32 {
        node: usize,
        input: usize,
        w: Tensor,
        b: Vec<f32>,
        stride: usize,
        pad: usize,
        groups: usize,
    },
    /// Standalone activation site: clip + quantise onto its grid.
    ActQ { node: usize, input: usize, row: SiteCfg },
    /// Residual add, requantised onto the add site grid.
    AddQ { node: usize, a: usize, b: usize, row: SiteCfg },
    Gap { node: usize, input: usize },
    LinearF { node: usize, input: usize, w: Tensor, b: Vec<f32> },
    Upsample { node: usize, input: usize, factor: usize },
}

/// A model packed for integer execution: f32 in (images), f32 out
/// (dequantised primary outputs), everything between on integer grids
/// wherever the graph allows.
pub struct QModel {
    steps: Vec<Step>,
    outputs: Vec<usize>,
    /// Conv/linear layers executing on the integer path.
    pub int_layers: usize,
    /// Layers falling back to exact f32 (no quantised input grid).
    pub f32_layers: usize,
}

fn row_qp(row: &SiteCfg) -> QParams {
    QParams {
        scale: row.scale,
        zero_point: row.zero_point,
        n_levels: row.n_levels,
    }
}

/// Pack a quantised model (fake-quant weights + retained integer codes +
/// activation site grids) into a [`QModel`]. Requires every activation
/// site quantised to ≤ 8 bits and retained codes for every conv layer.
pub fn pack(
    model: &Model,
    int_weights: &[(usize, QTensor)],
    cfg: &QuantCfg,
) -> Result<QModel> {
    if !model.folded {
        bail!("pack requires a folded model");
    }
    let sites = model.act_sites();
    if sites.len() != cfg.rows.len() {
        bail!("QuantCfg rows {} != sites {}", cfg.rows.len(), sites.len());
    }
    for (i, r) in cfg.rows.iter().enumerate() {
        if !(2.0..=256.0).contains(&r.n_levels) {
            bail!(
                "int8 path requires every activation site quantised to \
                 2..=256 levels; site {i} has n_levels = {} \
                 (quantise with act_bits in 1..=8)",
                r.n_levels
            );
        }
    }
    let site_of = |id: usize| -> Option<usize> {
        sites.iter().position(|s| s.node_id() == Some(id))
    };
    let weights_of = |id: usize| -> Option<&QTensor> {
        int_weights.iter().find(|(wid, _)| *wid == id).map(|(_, t)| t)
    };

    let mut steps = Vec::new();
    // node id -> Some(grid) when its value is quantised, None when f32
    let mut grids: HashMap<usize, Option<QParams>> = HashMap::new();
    let mut fused_acts: HashSet<usize> = HashSet::new();
    let mut int_layers = 0usize;
    let mut f32_layers = 0usize;

    for n in &model.nodes {
        match &n.op {
            Op::Input => {
                let qp = row_qp(&cfg.rows[0]);
                steps.push(Step::QuantInput { node: n.id, qp });
                grids.insert(n.id, Some(qp));
            }
            Op::Conv { w, b, stride, pad, groups, out_ch, .. } => {
                let input = n.inputs[0];
                let bias: Vec<f32> = match b {
                    Some(b) => model.tensor(b)?.data().to_vec(),
                    None => vec![0.0; *out_ch],
                };
                let in_grid = grids
                    .get(&input)
                    .cloned()
                    .ok_or_else(|| anyhow!("conv {} before input", n.id))?;
                match in_grid {
                    Some(in_qp) => {
                        let wq = weights_of(n.id).ok_or_else(|| {
                            anyhow!(
                                "no retained int8 weight codes for conv \
                                 node {} (quantise with bits <= 8)",
                                n.id
                            )
                        })?;
                        // fuse when the conv's only consumer is an act
                        // and the conv's pre-activation value is not
                        // itself a model output (fusion stores the
                        // result under the act node id only)
                        let cons = model.consumers(n.id);
                        let fuse = match cons.as_slice() {
                            [c] if matches!(c.op, Op::Act(_))
                                && !model.outputs.contains(&n.id) =>
                            {
                                Some(c.id)
                            }
                            _ => None,
                        };
                        match fuse {
                            Some(act_id) => {
                                let row = cfg.rows[site_of(act_id)
                                    .expect("act node is a site")];
                                let conv = QConv::pack(
                                    wq, &bias, *stride, *pad, *groups,
                                    &in_qp, Some(&row),
                                )?;
                                steps.push(Step::ConvQ {
                                    input,
                                    act_node: act_id,
                                    conv: Box::new(conv),
                                });
                                grids.insert(act_id, Some(row_qp(&row)));
                                grids.insert(n.id, None);
                                fused_acts.insert(act_id);
                            }
                            None => {
                                let conv = QConv::pack(
                                    wq, &bias, *stride, *pad, *groups,
                                    &in_qp, None,
                                )?;
                                steps.push(Step::ConvF {
                                    node: n.id,
                                    input,
                                    conv: Box::new(conv),
                                });
                                grids.insert(n.id, None);
                            }
                        }
                        int_layers += 1;
                    }
                    None => {
                        // f32 input (e.g. post-GAP): exact f32 fallback
                        // over the fake-quantised weights.
                        let wt = model.tensor(w)?.clone();
                        steps.push(Step::ConvFp32 {
                            node: n.id,
                            input,
                            w: wt,
                            b: bias,
                            stride: *stride,
                            pad: *pad,
                            groups: *groups,
                        });
                        grids.insert(n.id, None);
                        f32_layers += 1;
                    }
                }
            }
            Op::Act(_) => {
                if fused_acts.contains(&n.id) {
                    continue;
                }
                let row = cfg.rows[site_of(n.id).expect("act site")];
                steps.push(Step::ActQ { node: n.id, input: n.inputs[0], row });
                grids.insert(n.id, Some(row_qp(&row)));
            }
            Op::Add => {
                let row = cfg.rows[site_of(n.id).expect("add site")];
                steps.push(Step::AddQ {
                    node: n.id,
                    a: n.inputs[0],
                    b: n.inputs[1],
                    row,
                });
                grids.insert(n.id, Some(row_qp(&row)));
            }
            Op::Gap => {
                steps.push(Step::Gap { node: n.id, input: n.inputs[0] });
                grids.insert(n.id, None);
            }
            Op::Linear { w, b, .. } => {
                steps.push(Step::LinearF {
                    node: n.id,
                    input: n.inputs[0],
                    w: model.tensor(w)?.clone(),
                    b: model.tensor(b)?.data().to_vec(),
                });
                grids.insert(n.id, None);
                f32_layers += 1;
            }
            Op::Upsample { factor } => {
                steps.push(Step::Upsample {
                    node: n.id,
                    input: n.inputs[0],
                    factor: *factor,
                });
                let g = grids
                    .get(&n.inputs[0])
                    .cloned()
                    .ok_or_else(|| anyhow!("upsample {} dangling", n.id))?;
                grids.insert(n.id, g);
            }
            Op::BatchNorm { .. } => {
                bail!("pack requires a folded model (found bn node {})", n.id)
            }
        }
    }

    Ok(QModel { steps, outputs: model.outputs.clone(), int_layers, f32_layers })
}

impl QModel {
    /// Forward one batch: quantise the input, execute the packed steps,
    /// dequantise every model output to f32.
    pub fn run_all(&self, x: &Tensor) -> Result<Vec<Tensor>> {
        let mut vals: HashMap<usize, Val> = HashMap::new();
        for step in &self.steps {
            let (id, y) = match step {
                Step::QuantInput { node, qp } => {
                    (*node, Val::Q(QActTensor::quantize(x, qp)))
                }
                Step::ConvQ { input, act_node, conv } => {
                    let y = conv.run_q(vals[input].as_q()?)?;
                    (*act_node, Val::Q(y))
                }
                Step::ConvF { node, input, conv } => {
                    let y = conv.run_f32(vals[input].as_q()?)?;
                    (*node, Val::F(y))
                }
                Step::ConvFp32 { node, input, w, b, stride, pad, groups } => {
                    let xin = vals[input].to_f32();
                    let y = super::conv::conv2d(
                        &xin,
                        w,
                        Some(b),
                        *stride,
                        *pad,
                        *groups,
                    );
                    (*node, Val::F(y))
                }
                Step::ActQ { node, input, row } => {
                    let mut t = vals[input].to_f32();
                    ops::clip_act(&mut t, row.clip_hi);
                    (*node, Val::Q(QActTensor::quantize(&t, &row_qp(row))))
                }
                Step::AddQ { node, a, b, row } => {
                    let t = ops::add(&vals[a].to_f32(), &vals[b].to_f32());
                    (*node, Val::Q(QActTensor::quantize(&t, &row_qp(row))))
                }
                Step::Gap { node, input } => {
                    let t = ops::global_avg_pool(&vals[input].to_f32());
                    (*node, Val::F(t))
                }
                Step::LinearF { node, input, w, b } => {
                    let t = ops::linear(&vals[input].to_f32(), w, b);
                    (*node, Val::F(t))
                }
                Step::Upsample { node, input, factor } => {
                    let v = match &vals[input] {
                        Val::Q(q) => Val::Q(upsample_codes(q, *factor)),
                        Val::F(t) => {
                            Val::F(ops::upsample_nearest(t, *factor))
                        }
                    };
                    (*node, v)
                }
            };
            vals.insert(id, y);
        }
        self.outputs
            .iter()
            .map(|o| {
                vals.get(o)
                    .map(Val::to_f32)
                    .ok_or_else(|| anyhow!("missing output node {o}"))
            })
            .collect()
    }

    /// Forward one batch, returning the primary output.
    pub fn run(&self, x: &Tensor) -> Result<Tensor> {
        self.run_all(x)?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("model has no outputs"))
    }

    /// One-line execution-plan summary (for logs and `inspect`).
    pub fn summary(&self) -> String {
        format!(
            "{} int8 layer(s), {} f32 fallback layer(s), {} step(s)",
            self.int_layers,
            self.f32_layers,
            self.steps.len()
        )
    }
}

/// Nearest-neighbour upsample on u8 codes (grid-preserving).
fn upsample_codes(x: &QActTensor, f: usize) -> QActTensor {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (h * f, w * f);
    let mut out = vec![0u8; n * c * oh * ow];
    for i in 0..n * c {
        let xoff = i * h * w;
        let ooff = i * oh * ow;
        for oy in 0..oh {
            let iy = oy / f;
            for ox in 0..ow {
                out[ooff + oy * ow + ox] = x.codes[xoff + iy * w + ox / f];
            }
        }
    }
    QActTensor { shape: vec![n, c, oh, ow], codes: out, qp: x.qp }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mult_roundtrips_magnitudes() {
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let m = rng.log_uniform(1e-6, 1e3) as f64;
            let fm = mult_for(m);
            for _ in 0..20 {
                let t = (rng.uniform(-1e6, 1e6)) as i64;
                let got = apply_mult(t, &fm);
                let want = (t as f64 * m).round() as i64;
                assert!(
                    (got - want).abs() <= 1,
                    "M={m} t={t}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn mult_degenerate_falls_back() {
        assert!(matches!(mult_for(0.0), Mult::Float(_)));
        assert!(matches!(mult_for(f64::INFINITY), Mult::Float(_)));
        assert_eq!(apply_mult(100, &Mult::Float(0.5)), 50);
    }

    #[test]
    fn qgemm_matches_naive() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (7, 13, 5);
        let a: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
        let b: Vec<i8> =
            (0..k * n).map(|_| rng.below(256) as i8).collect();
        let got = qgemm(&a, &b, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let want: i32 = (0..k)
                    .map(|kk| a[i * k + kk] as i32 * b[kk * n + j] as i32)
                    .sum();
                assert_eq!(got[i * n + j], want);
            }
        }
    }

    #[test]
    fn rowsums_match() {
        let a: Vec<u8> = vec![1, 2, 3, 250, 251, 252];
        assert_eq!(rowsums_u8(&a, 2, 3), vec![6, 753]);
    }

    #[test]
    fn qact_quantize_dequantize_roundtrip() {
        let mut rng = Rng::new(5);
        let t = Tensor::new(&[2, 3, 4, 4], rng.normal_vec(96, 1.0));
        let qp = crate::quant::params_for_range(t.min(), t.max(), 8, false);
        let q = QActTensor::quantize(&t, &qp);
        assert!(q.dequantize().max_abs_diff(&t) <= qp.scale / 2.0 + 1e-6);
    }

    #[test]
    fn upsample_codes_matches_f32() {
        let mut rng = Rng::new(6);
        let t = Tensor::new(&[1, 2, 3, 3], rng.normal_vec(18, 1.0));
        let qp = crate::quant::params_for_range(-3.0, 3.0, 8, false);
        let q = QActTensor::quantize(&t, &qp);
        let up = upsample_codes(&q, 2);
        let want = ops::upsample_nearest(&q.dequantize(), 2);
        assert_eq!(up.dequantize(), want);
    }
}
