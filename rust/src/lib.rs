//! # dfq — Data-Free Quantization, reproduced as a deployable stack
//!
//! Rust implementation of *"Data-Free Quantization Through Weight
//! Equalization and Bias Correction"* (Nagel et al., ICCV 2019) as a
//! three-layer system:
//!
//! * **Layer 3 (this crate)** — model graph IR, the DFQ compiler passes
//!   ([`dfq`]), a pure-Rust reference engine ([`nn`]), a PJRT-backed
//!   runtime ([`runtime`]) executing JAX/Pallas-lowered HLO artifacts,
//!   a serving coordinator ([`serve`]) and the full evaluation /
//!   benchmark harness ([`eval`], [`experiments`]).
//! * **Layer 2/1 (python, build-time only)** — JAX model zoo and the
//!   fused fake-quant Pallas kernel, AOT-lowered to `artifacts/*.hlo.txt`
//!   by `make artifacts`. Python never runs on the request path.
//!
//! The public API a downstream user touches:
//!
//! ```no_run
//! use dfq::graph::Model;
//! use dfq::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
//! use dfq::quant::QScheme;
//!
//! let model = Model::load("artifacts/micronet_v2.dfqm").unwrap();
//! let prepared = quantize_data_free(&model, &DfqConfig::default()).unwrap();
//! let q = prepared
//!     .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::Analytic, None)
//!     .unwrap();
//! # let _ = q;
//! ```

pub mod dfq;
pub mod eval;
pub mod experiments;
pub mod graph;
pub mod nn;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

pub use anyhow::{Error, Result};

/// Locate the artifacts directory: `$DFQ_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("DFQ_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
