//! # dfq — Data-Free Quantization, reproduced as a deployable stack
//!
//! Rust implementation of *"Data-Free Quantization Through Weight
//! Equalization and Bias Correction"* (Nagel et al., ICCV 2019) as a
//! three-layer system:
//!
//! * **Layer 3 (this crate)** — model graph IR, the DFQ compiler passes
//!   ([`dfq`]), a pure-Rust reference engine ([`nn`]), a PJRT-backed
//!   runtime ([`runtime`]) executing JAX/Pallas-lowered HLO artifacts,
//!   a serving coordinator ([`serve`]) and the full evaluation /
//!   benchmark harness ([`eval`], [`experiments`]).
//! * **Layer 2/1 (python, build-time only)** — JAX model zoo and the
//!   fused fake-quant Pallas kernel, AOT-lowered to `artifacts/*.hlo.txt`
//!   by `make artifacts`. Python never runs on the request path.
//!
//! The public API a downstream user touches:
//!
//! ```no_run
//! use dfq::graph::Model;
//! use dfq::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
//! use dfq::quant::QScheme;
//!
//! let model = Model::load("artifacts/micronet_v2.dfqm").unwrap();
//! let prepared = quantize_data_free(&model, &DfqConfig::default()).unwrap();
//! let q = prepared
//!     .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::Analytic, None)
//!     .unwrap();
//! # let _ = q;
//! ```
//!
//! ## True int8 execution
//!
//! Beyond the fake-quant *simulation* the engines above run, the crate
//! executes DFQ output on real integer grids:
//!
//! * [`tensor::QTensor`] holds u8/i8 grid codes with per-tensor or
//!   per-channel [`quant::QParams`]; [`dfq::Prepared::quantize`] retains
//!   the integer weight grids it computes
//!   ([`dfq::QuantizedModel::int_weights`]).
//! * [`dfq::QuantizedModel::pack_int8`] *compiles* the model into an
//!   [`nn::qengine::QModel`] execution plan: every node resolved to a
//!   typed integer op with precomputed fixed-point multipliers and dense
//!   value slots — integer im2col + u8×i8→i32 GEMM convs with i64 biases
//!   pre-folded with the input zero-points
//!   (`Σ(qa-za)(qw-zw) = Σ qa·qw - zw·rowsum - za·colsum + K·za·zw`),
//!   a channel-parallel depthwise direct path, requantise-add for
//!   residual connections, integer global average pooling, an int8
//!   linear head, and fused clamped-ReLU/ReLU6 epilogues
//!   (`M = s_in·s_w/s_out` as an i64 multiplier + shift). A
//!   MobileNet-style graph plans with zero f32 fallback ops
//!   ([`nn::qengine::PlanOpts::int8_only`] makes that a hard guarantee);
//!   `run_all` is batch-parallel over images. Parity with the fake-quant
//!   oracle is one quantisation step per element per op.
//! * [`serve::QuantExecutor`] plugs the packed model into the serving
//!   router as a `BatchExecutor`, so one [`serve::Router`] hosts
//!   f32-oracle and int8 variants side by side:
//!
//! ```no_run
//! # use dfq::graph::Model;
//! # use dfq::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
//! # use dfq::quant::QScheme;
//! use dfq::serve::{QuantExecutor, ServeConfig, Server};
//!
//! # let model = Model::load("artifacts/micronet_v2.dfqm").unwrap();
//! # let prepared = quantize_data_free(&model, &DfqConfig::default()).unwrap();
//! let q = prepared
//!     .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::Analytic, None)
//!     .unwrap();
//! let server = Server::start(ServeConfig::default(), move || {
//!     Ok(Box::new(QuantExecutor::from_quantized(&q, 64)?))
//! });
//! # drop(server);
//! ```
//!
//! ## Compiled artifacts and multi-model serving
//!
//! The whole pipeline above runs *once* at compile time: [`artifact`]
//! snapshots the planned integer model into a versioned `.dfqm`
//! container (magic + CRC-checked section table holding the i8 weight
//! grids, per-channel grids, folded i64 biases and fixed-point
//! multipliers), and
//! [`nn::qengine::QModel::from_artifact`] reloads it with zero float
//! math — outputs are bitwise-identical to the in-memory plan. On top,
//! [`serve::Registry`] lazy-loads a directory of artifacts and hosts
//! one batching router per model:
//!
//! ```no_run
//! # use dfq::graph::Model;
//! # use dfq::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
//! # use dfq::quant::QScheme;
//! use dfq::nn::qengine::PlanOpts;
//! use dfq::serve::{Registry, ServeConfig};
//!
//! # let model = Model::load("artifacts/micronet_v2.dfqm").unwrap();
//! # let prepared = quantize_data_free(&model, &DfqConfig::default()).unwrap();
//! # let q = prepared
//! #     .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::Analytic, None)
//! #     .unwrap();
//! // compile once (CLI: `dfq compile micronet_v2 -o models/micronet.dfqm`)
//! q.save_artifact(
//!     "models/micronet.dfqm",
//!     PlanOpts { int8_only: true, ..Default::default() },
//! )
//! .unwrap();
//! // serve many (CLI: `dfq serve --models models/`)
//! let mut reg = Registry::new(ServeConfig::default());
//! reg.scan_dir("models").unwrap();
//! let client = reg.client("micronet", "int8").unwrap();
//! # drop(client);
//! ```
//!
//! Module map: [`graph`] (IR + containers) → [`dfq`] (the paper's
//! passes, composed by the [`dfq::pass::PassManager`] with per-pass
//! diagnostics — `dfq report` prints the table) →
//! [`quant`]/[`tensor`] (grids and integer codes) → [`nn`]
//! (f32 oracle + the [`nn::qengine`] integer planner/kernels) →
//! [`artifact`] (compiled-plan serialisation) → [`serve`]
//! (batching servers, router, the [`serve::autoscale`] variant
//! autoscaler, and the multi-model registry with hot-swap/eviction
//! lifecycle) → [`runtime`] (PJRT), with [`eval`]/[`experiments`]
//! reproducing the paper's tables. Cross-cutting: [`obs`] — the
//! observability layer (bounded event tracing, log-bucket latency
//! histograms behind [`serve::Metrics`], Prometheus-style/JSON export)
//! and the per-op runtime profile [`nn::qengine::RunProfile`]
//! (`dfq profile`, the runtime twin of `dfq report`).

pub mod artifact;
pub mod dfq;
pub mod eval;
pub mod experiments;
pub mod graph;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

pub use anyhow::{Error, Result};

/// Locate the artifacts directory: `$DFQ_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("DFQ_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
