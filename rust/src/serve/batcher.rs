//! Dynamic batching policy.
//!
//! Classic serving trade-off (vLLM-style): wait up to `max_delay` after
//! the first queued request to fill a batch of `max_batch`, but never
//! hold a full batch. Single-threaded collector over an mpsc channel.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// A batching decision loop over any request type.
pub struct Batcher {
    pub max_batch: usize,
    pub max_delay: Duration,
}

impl Batcher {
    /// Block for the next batch. Returns `None` when the channel closed
    /// and no requests remain.
    pub fn next_batch<T>(&self, rx: &Receiver<T>) -> Option<Vec<T>> {
        let first = rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.max_delay;
        while batch.len() < self.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let b = Batcher { max_batch: 3, max_delay: Duration::from_millis(1) };
        assert_eq!(b.next_batch(&rx).unwrap(), vec![0, 1, 2]);
        assert_eq!(b.next_batch(&rx).unwrap(), vec![3, 4]);
    }

    #[test]
    fn closes_cleanly() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        let b = Batcher { max_batch: 4, max_delay: Duration::from_millis(1) };
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn waits_for_stragglers() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let tx2 = tx.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            tx2.send(2).unwrap();
        });
        let b =
            Batcher { max_batch: 2, max_delay: Duration::from_millis(200) };
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch, vec![1, 2]);
    }
}
