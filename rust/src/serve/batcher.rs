//! Dynamic batching policy and SLO-class scheduling.
//!
//! Classic serving trade-off (vLLM-style): wait up to `max_delay` after
//! the first queued request to fill a batch of `max_batch`, but never
//! hold a full batch. Single-threaded collector over an mpsc channel.
//!
//! On top of the arrival batcher sits [`WeightedBacklog`], the per-lane
//! SLO scheduler: requests carry a [`Priority`] class, interactive work
//! drains first, and a starvation bound guarantees batch-class work
//! ships at least every `starvation_limit` formed batches.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// SLO class of one request. `Interactive` is latency-sensitive and
/// drains first; `Batch` is throughput work that may wait, bounded by
/// the [`WeightedBacklog`] starvation limit.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    #[default]
    Interactive,
    Batch,
}

impl Priority {
    /// Stable index (histogram/label slot): interactive 0, batch 1.
    pub fn idx(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
        }
    }

    /// Label value used in the metrics exposition.
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

/// Batches a batch-class reservation after this many consecutive formed
/// batches shipped no batch-class work while some was waiting.
pub const DEFAULT_STARVATION_LIMIT: u32 = 4;

/// Two-class weighted scheduler: a FIFO per [`Priority`], drained
/// interactive-first with a starvation bound. Arrival order is
/// preserved *within* a class, so the scheduler is deterministic given
/// the arrival sequence.
#[derive(Debug)]
pub struct WeightedBacklog<T> {
    classes: [VecDeque<T>; 2], // indexed by Priority::idx()
    /// Consecutive [`WeightedBacklog::take`]s that shipped no
    /// batch-class item while batch work was waiting.
    starved: u32,
    limit: u32,
}

impl<T> WeightedBacklog<T> {
    pub fn new(starvation_limit: u32) -> WeightedBacklog<T> {
        WeightedBacklog {
            classes: [VecDeque::new(), VecDeque::new()],
            starved: 0,
            limit: starvation_limit.max(1),
        }
    }

    pub fn push(&mut self, prio: Priority, item: T) {
        self.classes[prio.idx()].push_back(item);
    }

    pub fn len(&self) -> usize {
        self.classes.iter().map(VecDeque::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.classes.iter().all(VecDeque::is_empty)
    }

    /// Form the next batch of at most `max` items.
    ///
    /// Policy: interactive first, spill leftover slots to batch-class.
    /// Once `starvation_limit` consecutive batches have shipped no
    /// batch-class work while some waited, `max(1, max/4)` slots are
    /// *reserved* for batch-class before interactive fills the rest —
    /// interactive load can therefore delay batch work, but never
    /// indefinitely.
    pub fn take(&mut self, max: usize) -> Vec<(Priority, T)> {
        let max = max.max(1);
        let mut out = Vec::new();
        let batch_waiting = !self.classes[Priority::Batch.idx()].is_empty();
        if batch_waiting && self.starved >= self.limit {
            let reserve = (max / 4).max(1);
            for _ in 0..reserve {
                match self.classes[Priority::Batch.idx()].pop_front() {
                    Some(t) => out.push((Priority::Batch, t)),
                    None => break,
                }
            }
        }
        while out.len() < max {
            if let Some(t) =
                self.classes[Priority::Interactive.idx()].pop_front()
            {
                out.push((Priority::Interactive, t));
            } else {
                break;
            }
        }
        while out.len() < max {
            match self.classes[Priority::Batch.idx()].pop_front() {
                Some(t) => out.push((Priority::Batch, t)),
                None => break,
            }
        }
        if !out.is_empty() {
            let shipped_batch =
                out.iter().any(|(p, _)| *p == Priority::Batch);
            if shipped_batch {
                self.starved = 0;
            } else if batch_waiting {
                self.starved += 1;
            }
        }
        out
    }

    /// Drain everything, interactive first (shutdown path — the
    /// starvation counter no longer matters).
    pub fn drain_all(&mut self) -> Vec<(Priority, T)> {
        let mut out = Vec::with_capacity(self.len());
        for (i, q) in self.classes.iter_mut().enumerate() {
            let p = if i == 0 { Priority::Interactive } else { Priority::Batch };
            out.extend(q.drain(..).map(|t| (p, t)));
        }
        out
    }
}

/// A batching decision loop over any request type.
pub struct Batcher {
    pub max_batch: usize,
    pub max_delay: Duration,
}

impl Batcher {
    /// Block for the next batch. Returns `None` when the channel closed
    /// and no requests remain.
    pub fn next_batch<T>(&self, rx: &Receiver<T>) -> Option<Vec<T>> {
        let first = rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.max_delay;
        while batch.len() < self.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel();
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        let b = Batcher { max_batch: 3, max_delay: Duration::from_millis(1) };
        assert_eq!(b.next_batch(&rx).unwrap(), vec![0, 1, 2]);
        assert_eq!(b.next_batch(&rx).unwrap(), vec![3, 4]);
    }

    #[test]
    fn closes_cleanly() {
        let (tx, rx) = mpsc::channel::<u32>();
        drop(tx);
        let b = Batcher { max_batch: 4, max_delay: Duration::from_millis(1) };
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn waits_for_stragglers() {
        let (tx, rx) = mpsc::channel();
        tx.send(1).unwrap();
        let tx2 = tx.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            tx2.send(2).unwrap();
        });
        let b =
            Batcher { max_batch: 2, max_delay: Duration::from_millis(200) };
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch, vec![1, 2]);
    }

    #[test]
    fn backlog_drains_interactive_first_then_spills() {
        let mut b = WeightedBacklog::new(4);
        b.push(Priority::Batch, "b0");
        b.push(Priority::Interactive, "i0");
        b.push(Priority::Interactive, "i1");
        assert_eq!(b.len(), 3);
        let got = b.take(4);
        // both interactive ship first, leftover slots spill to batch
        assert_eq!(
            got.iter().map(|(_, t)| *t).collect::<Vec<_>>(),
            vec!["i0", "i1", "b0"]
        );
        assert!(b.is_empty());
    }

    #[test]
    fn backlog_bounds_batch_class_starvation() {
        let mut b = WeightedBacklog::new(2);
        b.push(Priority::Batch, -1i32);
        // endless interactive pressure: feed more than one batch's worth
        // every round so batch-class work never ships for free
        for i in 0..8 {
            b.push(Priority::Interactive, i);
        }
        let all_interactive = |v: &[(Priority, i32)]| {
            v.iter().all(|(p, _)| *p == Priority::Interactive)
        };
        // rounds 1 and 2: pure interactive (starvation builds)
        for _ in 0..2 {
            for i in 100..104 {
                b.push(Priority::Interactive, i);
            }
            assert!(all_interactive(&b.take(4)));
        }
        // round 3: the bound trips — max(1, 4/4) slot is reserved for
        // the starving batch-class request before interactive fills up
        for i in 200..204 {
            b.push(Priority::Interactive, i);
        }
        let got = b.take(4);
        assert_eq!(got[0], (Priority::Batch, -1));
        assert_eq!(got.len(), 4);
    }

    #[test]
    fn backlog_keeps_fifo_within_a_class() {
        let mut b = WeightedBacklog::new(4);
        for i in 0..6 {
            let p = if i % 2 == 0 {
                Priority::Interactive
            } else {
                Priority::Batch
            };
            b.push(p, i);
        }
        let got: Vec<i32> =
            b.take(6).into_iter().map(|(_, t)| t).collect();
        assert_eq!(got, vec![0, 2, 4, 1, 3, 5]);
        // drain_all empties everything that remains
        b.push(Priority::Batch, 9);
        b.push(Priority::Interactive, 8);
        let rest: Vec<i32> =
            b.drain_all().into_iter().map(|(_, t)| t).collect();
        assert_eq!(rest, vec![8, 9]);
        assert!(b.is_empty());
    }
}
