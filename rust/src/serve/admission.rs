//! Bounded admission control: cap the number of in-flight requests per
//! model and shed the excess immediately instead of queueing forever.
//!
//! An [`AdmissionQueue`] is a lock-free counter triple shared by every
//! lane (and every variant) of one model: [`AdmissionQueue::try_admit`]
//! either hands out an [`AdmissionPermit`] or rejects with the observed
//! in-flight count. The permit rides inside the request and releases
//! its slot on `Drop`, so *every* exit path — answered, failed at
//! executor construction, died with a drained channel — returns the
//! slot without any per-path bookkeeping.
//!
//! Overload therefore stays memory-bounded: at most `cap` requests
//! (plus the rejections' error returns) exist per model at any instant,
//! and callers see a typed [`SubmitError::Shed`] they can back off on.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Why a submission did not enter the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The admission cap was reached: the request was rejected
    /// immediately (load shedding), not queued.
    Shed {
        /// In-flight requests observed at rejection time.
        in_flight: u64,
        /// The configured cap ([`super::ServeConfig::admission_cap`]).
        cap: u64,
    },
    /// The server behind this handle is shut down.
    Closed,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Shed { in_flight, cap } => write!(
                f,
                "request shed: {in_flight} in flight >= admission cap {cap}"
            ),
            SubmitError::Closed => write!(f, "server is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Per-model in-flight cap with shed/admit accounting. `cap == 0`
/// means unbounded (admission always succeeds; counters still track).
#[derive(Debug, Default)]
pub struct AdmissionQueue {
    cap: u64,
    in_flight: AtomicU64,
    admitted: AtomicU64,
    shed: AtomicU64,
}

impl AdmissionQueue {
    pub fn new(cap: usize) -> AdmissionQueue {
        AdmissionQueue { cap: cap as u64, ..AdmissionQueue::default() }
    }

    /// An always-admitting queue (counters still run).
    pub fn unbounded() -> AdmissionQueue {
        AdmissionQueue::new(0)
    }

    /// The configured cap (`0` = unbounded).
    pub fn cap(&self) -> u64 {
        self.cap
    }

    /// Requests currently holding a permit.
    pub fn in_flight(&self) -> u64 {
        self.in_flight.load(Ordering::Relaxed)
    }

    /// Total admissions granted.
    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    /// Total rejections.
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Claim one in-flight slot. `Err(in_flight)` means the cap is
    /// reached and the request must be shed; the failed reservation is
    /// rolled back before returning, so rejected submissions leave no
    /// residue.
    pub fn try_admit(self: &Arc<Self>) -> Result<AdmissionPermit, u64> {
        let prev = self.in_flight.fetch_add(1, Ordering::AcqRel);
        if self.cap != 0 && prev >= self.cap {
            self.in_flight.fetch_sub(1, Ordering::AcqRel);
            self.shed.fetch_add(1, Ordering::Relaxed);
            return Err(prev);
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Ok(AdmissionPermit { queue: Arc::clone(self) })
    }
}

/// One claimed in-flight slot; releases on `Drop`. Carried inside the
/// queued request so the slot frees exactly when the request's life
/// ends, whichever path it takes.
#[derive(Debug)]
pub struct AdmissionPermit {
    queue: Arc<AdmissionQueue>,
}

impl Drop for AdmissionPermit {
    fn drop(&mut self) {
        self.queue.in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_cap_then_sheds() {
        let q = Arc::new(AdmissionQueue::new(2));
        let p1 = q.try_admit().unwrap();
        let p2 = q.try_admit().unwrap();
        assert_eq!(q.in_flight(), 2);
        let err = q.try_admit().unwrap_err();
        assert_eq!(err, 2);
        assert_eq!(q.shed(), 1);
        assert_eq!(q.in_flight(), 2, "rejected claim must roll back");
        // releasing one slot re-opens admission
        drop(p1);
        assert_eq!(q.in_flight(), 1);
        let p3 = q.try_admit().unwrap();
        assert_eq!(q.admitted(), 3);
        drop((p2, p3));
        assert_eq!(q.in_flight(), 0);
    }

    #[test]
    fn unbounded_queue_always_admits() {
        let q = Arc::new(AdmissionQueue::unbounded());
        let permits: Vec<_> =
            (0..1000).map(|_| q.try_admit().unwrap()).collect();
        assert_eq!(q.in_flight(), 1000);
        assert_eq!(q.shed(), 0);
        drop(permits);
        assert_eq!(q.in_flight(), 0);
    }

    #[test]
    fn concurrent_admission_never_exceeds_cap() {
        let q = Arc::new(AdmissionQueue::new(16));
        let peak = Arc::new(AtomicU64::new(0));
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let q = Arc::clone(&q);
                let peak = Arc::clone(&peak);
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        if let Ok(p) = q.try_admit() {
                            peak.fetch_max(
                                q.in_flight(),
                                Ordering::Relaxed,
                            );
                            drop(p);
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert!(peak.load(Ordering::Relaxed) <= 16);
        assert_eq!(q.in_flight(), 0);
        assert_eq!(q.admitted() + q.shed(), 8 * 500);
    }

    #[test]
    fn submit_error_formats_and_types() {
        let e = SubmitError::Shed { in_flight: 9, cap: 8 };
        assert!(e.to_string().contains("9 in flight"));
        assert!(e.to_string().contains("cap 8"));
        let any: anyhow::Error = e.into();
        assert_eq!(
            any.downcast_ref::<SubmitError>(),
            Some(&SubmitError::Shed { in_flight: 9, cap: 8 })
        );
        assert!(SubmitError::Closed.to_string().contains("shut down"));
    }
}
