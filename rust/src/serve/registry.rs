//! Multi-model serving registry: name → `.dfqm` compiled artifact (or
//! in-memory quantised model), lazily loaded into one batching
//! [`Router`] per model.
//!
//! The registry is the second deployment surface the artifact subsystem
//! enables: a host process points at a directory of compiled artifacts
//! (`dfq serve --models dir/`), and each model boots on first use by
//! *decoding* its plan ([`crate::artifact`]) instead of re-running the
//! DFQ pipeline — no python manifest, no float math, and as many models
//! per process as memory allows. Every model keeps its own worker
//! thread(s), queue and [`Metrics`](super::Metrics), so tenants are
//! isolated and snapshots are per (model, variant).
//!
//! ## Lifecycle
//!
//! Registered models move through `registered → resident → evicted →
//! resident → …`:
//!
//! * **Hot swap** — [`Registry::reload`] re-reads a model's source and
//!   swaps the router behind every [`LiveClient`] *before* draining the
//!   old server, so no in-flight request is dropped;
//!   [`Registry::poll_files`] does the same automatically for every
//!   resident artifact whose file changed on disk. A failed swap
//!   (corrupt or version-skewed replacement) surfaces the typed
//!   [`ArtifactError`](crate::artifact::ArtifactError) and leaves the
//!   old model serving.
//! * **Eviction** — [`Registry::evict`] (or the
//!   [`ServeConfig::max_resident`](super::ServeConfig::max_resident)
//!   cap, which evicts least-recently-used models automatically) drains
//!   a resident model and frees its plan; the next request re-loads it
//!   lazily. Snapshots of retired server generations are kept and
//!   returned by [`Registry::shutdown`].

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex, RwLock};
use std::time::SystemTime;

use anyhow::{anyhow, bail, Result};

use crate::artifact::Artifact;
use crate::dfq::QuantizedModel;
use crate::obs::trace;
use crate::obs::Severity;
use crate::tensor::Tensor;

use super::autoscale::AdaptiveClient;
use super::{
    AdmissionQueue, BatchExecutor, Client, EngineExecutor, Priority,
    QuantExecutor, Router, ServeConfig, Server, Snapshot, SubmitError,
    TrySubmitErr,
};

/// The variant every registry model exposes (true-int8 plan).
pub const VARIANT_INT8: &str = "int8";
/// The fake-quant f32 oracle variant (in-memory models only).
pub const VARIANT_F32: &str = "f32";

/// Where a registered model comes from.
enum Source {
    /// A `.dfqm` compiled artifact on disk (lazily decoded).
    File(PathBuf),
    /// An in-memory quantised model (hosts the f32 oracle variant too).
    Memory(Box<QuantizedModel>),
}

/// Serving metadata of a loaded model.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    /// Expected input `[C, H, W]`.
    pub input_shape: [usize; 3],
    pub num_classes: usize,
    /// Variant names hosted by this model's router.
    pub variants: Vec<String>,
    /// `"artifact"` or `"memory"`.
    pub source: &'static str,
    /// Execution-plan summary of the int8 variant.
    pub plan: String,
}

struct Hosted {
    router: Router,
    info: ModelInfo,
}

/// `(len, mtime)` of a source file at load time — enough to notice a
/// rewritten artifact without hashing payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct FileStamp {
    len: u64,
    mtime: Option<SystemTime>,
}

fn stamp_of(source: &Source) -> Option<FileStamp> {
    match source {
        Source::File(path) => std::fs::metadata(path).ok().map(|m| {
            FileStamp { len: m.len(), mtime: m.modified().ok() }
        }),
        Source::Memory(_) => None,
    }
}

fn dir_stamp(dir: &Path) -> Option<FileStamp> {
    std::fs::metadata(dir)
        .ok()
        .map(|m| FileStamp { len: m.len(), mtime: m.modified().ok() })
}

/// Per-watcher state for [`Registry::poll_files_debounced`]: the last
/// observed parent-directory stamps plus the quiet-call backoff
/// schedule. One per watch loop; fresh state means the first call always
/// runs a full poll.
#[derive(Debug)]
pub struct WatchDebounce {
    /// Last observed `(len, mtime)` per watched parent directory
    /// (`None` stamp = directory currently unreadable).
    dirs: HashMap<PathBuf, Option<FileStamp>>,
    /// Consecutive debounced calls since the last full per-file poll.
    quiet: u32,
    /// Quiet-call count that triggers the next full poll (doubles to a
    /// cap of 8).
    next_full: u32,
}

impl WatchDebounce {
    pub fn new() -> WatchDebounce {
        WatchDebounce { dirs: HashMap::new(), quiet: 0, next_full: 1 }
    }
}

impl Default for WatchDebounce {
    fn default() -> WatchDebounce {
        WatchDebounce::new()
    }
}

struct Entry {
    source: Source,
    hosted: Option<Hosted>,
    /// Hot-swap-safe client slots handed out as [`LiveClient`]s; reload
    /// re-points them at the new server generation.
    live: HashMap<String, Arc<RwLock<Client>>>,
    /// Source-file stamp at load time (file sources only).
    stamp: Option<FileStamp>,
    /// Touch counter value of the last access (LRU eviction order).
    last_used: u64,
    /// Snapshots of server generations retired by evict/reload.
    retired: Vec<(String, Snapshot)>,
}

/// A hot-swap-safe submission handle: requests go to whatever server
/// generation currently backs the `(model, variant)` slot, so a
/// [`Registry::reload`] under live traffic loses nothing — the old
/// generation drains its queue while new submissions flow to the new
/// one. Cheap to clone. After an *eviction* the slot points at a
/// drained server until the model is touched through the registry
/// again (lazy re-load), so keep using [`Registry::live_client`] on the
/// request path when eviction is enabled.
#[derive(Clone)]
pub struct LiveClient {
    slot: Arc<RwLock<Client>>,
}

impl LiveClient {
    /// Submit one image (1, C, H, W); returns a receiver for the result.
    /// Interactive SLO class — see [`LiveClient::submit_prio`].
    pub fn submit(&self, x: Tensor) -> Result<Receiver<Result<Tensor>>> {
        self.submit_prio(x, Priority::Interactive)
    }

    /// Submit with an explicit SLO class. A hot-swap race (the cloned
    /// generation drained before the send landed) is retried once
    /// against the swapped-in slot; a typed
    /// [`SubmitError::Shed`](super::SubmitError::Shed) rejection is
    /// surfaced as-is — shedding signals real overload, and an
    /// immediate retry would defeat the admission cap.
    pub fn submit_prio(
        &self,
        x: Tensor,
        prio: Priority,
    ) -> Result<Receiver<Result<Tensor>>> {
        // clone the current-generation client so the slot lock is not
        // held while a full queue blocks the send
        let client = self.slot.read().unwrap().clone();
        match client.try_submit_prio(x, prio) {
            Ok(rx) => Ok(rx),
            Err(TrySubmitErr::Shed { in_flight, cap }) => {
                Err(SubmitError::Shed { in_flight, cap }.into())
            }
            Err(TrySubmitErr::Closed(x)) => {
                // lost a race with a hot swap: the generation we cloned
                // drained before the send landed. The slot already holds
                // the replacement — retry once against it.
                let client = self.slot.read().unwrap().clone();
                client.submit_prio(x, prio)
            }
        }
    }

    /// Submit and block for the answer. A response channel that dies
    /// without a payload means the request was never executed (workers
    /// always answer before exiting), so when that race with a hot swap
    /// happens the request is resubmitted once against the swapped-in
    /// generation.
    pub fn infer(&self, x: Tensor) -> Result<Tensor> {
        self.infer_prio(x, Priority::Interactive)
    }

    /// [`LiveClient::infer`] with an explicit SLO class.
    pub fn infer_prio(&self, x: Tensor, prio: Priority) -> Result<Tensor> {
        match self.submit_prio(x.clone(), prio)?.recv() {
            Ok(result) => result,
            Err(_) => self
                .submit_prio(x, prio)?
                .recv()
                .map_err(|_| anyhow!("server dropped the request"))?,
        }
    }
}

/// Named multi-model registry over lazily-loaded serving routers.
pub struct Registry {
    cfg: ServeConfig,
    entries: BTreeMap<String, Entry>,
    /// Monotonic touch counter backing the LRU eviction order.
    clock: u64,
}

impl Registry {
    /// `cfg` applies to every server the registry starts;
    /// [`ServeConfig::max_resident`](super::ServeConfig::max_resident)
    /// bounds how many models stay loaded at once.
    pub fn new(cfg: ServeConfig) -> Registry {
        Registry { cfg, entries: BTreeMap::new(), clock: 0 }
    }

    /// Register a compiled artifact by path (not loaded until first
    /// use). Fails on duplicate names.
    pub fn register_file(
        &mut self,
        name: impl Into<String>,
        path: impl Into<PathBuf>,
    ) -> Result<()> {
        self.insert(name.into(), Source::File(path.into()))
    }

    /// Register an in-memory quantised model (hosts `f32` + `int8`
    /// variants, like the single-model CLI serve path).
    pub fn register_quantized(
        &mut self,
        name: impl Into<String>,
        q: QuantizedModel,
    ) -> Result<()> {
        self.insert(name.into(), Source::Memory(Box::new(q)))
    }

    fn insert(&mut self, name: String, source: Source) -> Result<()> {
        if name.is_empty() {
            bail!("registry model name must be non-empty");
        }
        if self.entries.contains_key(&name) {
            bail!("model '{name}' already registered");
        }
        self.entries.insert(
            name,
            Entry {
                source,
                hosted: None,
                live: HashMap::new(),
                stamp: None,
                last_used: 0,
                retired: Vec::new(),
            },
        );
        Ok(())
    }

    /// Register every compiled artifact in `dir` (files with a `.dfqm`
    /// extension *and* the compiled-artifact magic; source-model
    /// containers sharing the extension are skipped). Names are file
    /// stems. Returns the registered names in **sorted order**
    /// regardless of directory enumeration order, so multi-tenant load
    /// runs over a directory are reproducible.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use dfq::serve::{registry::VARIANT_INT8, Registry, ServeConfig};
    ///
    /// let mut reg = Registry::new(ServeConfig::default());
    /// // registers every compiled model; nothing is loaded yet
    /// let names = reg.scan_dir("models/").unwrap();
    /// for name in &names {
    ///     // first touch decodes the artifact and boots the router
    ///     let client = reg.client(name, VARIANT_INT8).unwrap();
    ///     # let _ = client;
    /// }
    /// ```
    pub fn scan_dir(&mut self, dir: impl AsRef<Path>) -> Result<Vec<String>> {
        let dir = dir.as_ref();
        let mut names = Vec::new();
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .map_err(|e| anyhow!("reading {}: {e}", dir.display()))?
            .filter_map(|r| r.ok().map(|d| d.path()))
            .filter(|p| {
                p.extension().and_then(|e| e.to_str()) == Some("dfqm")
            })
            .collect();
        paths.sort();
        for path in paths {
            if !has_artifact_magic(&path) {
                continue; // a source-model .dfqm (magic DFQM), not a plan
            }
            let Some(stem) =
                path.file_stem().and_then(|s| s.to_str()).map(String::from)
            else {
                continue;
            };
            self.register_file(stem.clone(), &path)?;
            names.push(stem);
        }
        Ok(names)
    }

    /// All registered model names.
    pub fn models(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    /// Names of models whose routers are live.
    pub fn loaded(&self) -> Vec<String> {
        self.entries
            .iter()
            .filter(|(_, e)| e.hosted.is_some())
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// Submission handle for one (model, variant); loads the model on
    /// first use. `variant` is [`VARIANT_INT8`] for every model,
    /// [`VARIANT_F32`] additionally for in-memory registrations.
    ///
    /// # Example
    ///
    /// ```
    /// use dfq::dfq::{quantize_data_free, testutil, BiasCorrMode, DfqConfig};
    /// use dfq::quant::QScheme;
    /// use dfq::serve::{registry::VARIANT_INT8, Registry, ServeConfig};
    ///
    /// let m = testutil::two_layer_model(7, true);
    /// let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
    /// let q = prep
    ///     .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
    ///     .unwrap();
    /// let mut reg = Registry::new(ServeConfig::default());
    /// reg.register_quantized("two_layer", q).unwrap();
    /// let client = reg.client("two_layer", VARIANT_INT8).unwrap();
    /// let y = client.infer(testutil::random_input(&m, 1, 1)).unwrap();
    /// assert_eq!(y.shape()[0], 1);
    /// reg.shutdown();
    /// ```
    pub fn client(&mut self, model: &str, variant: &str) -> Result<Client> {
        self.ensure_loaded(model)?.router.client(variant)
    }

    /// Like [`Registry::client`] but hot-swap-safe: the returned handle
    /// keeps working across [`Registry::reload`] /
    /// [`Registry::poll_files`] swaps of this model.
    pub fn live_client(
        &mut self,
        model: &str,
        variant: &str,
    ) -> Result<LiveClient> {
        self.ensure_loaded(model)?;
        let e = self.entries.get_mut(model).expect("just loaded");
        if let Some(slot) = e.live.get(variant) {
            return Ok(LiveClient { slot: slot.clone() });
        }
        let client = e
            .hosted
            .as_ref()
            .expect("just loaded")
            .router
            .client(variant)?;
        let slot = Arc::new(RwLock::new(client));
        e.live.insert(variant.to_string(), slot.clone());
        Ok(LiveClient { slot })
    }

    /// A steering handle over this model's `f32` + `int8` variants (see
    /// [`crate::serve::autoscale`]): requests route to whichever variant
    /// the autoscaler currently selects, using
    /// [`ServeConfig::autoscale`](super::ServeConfig::autoscale) (or the
    /// default policy). Only in-memory registrations host the f32
    /// oracle, so artifact-backed models are rejected here.
    ///
    /// Unlike [`LiveClient`], the returned handle is bound to the
    /// *current* server generation: a [`Registry::reload`] or eviction
    /// (explicit or via the
    /// [`ServeConfig::max_resident`](super::ServeConfig::max_resident)
    /// cap) of this model invalidates it — obtain a fresh one
    /// afterwards. Keep autoscaled models out of the eviction cap's
    /// reach (or off caps entirely) when holding one long-term.
    pub fn adaptive_client(&mut self, model: &str) -> Result<AdaptiveClient> {
        let policy = self.cfg.autoscale.unwrap_or_default();
        let h = self.ensure_loaded(model)?;
        let f32_lane = h.router.lane(VARIANT_F32).map_err(|e| {
            e.context(format!(
                "model '{model}' hosts no f32 oracle variant \
                 (autoscaling needs an in-memory registration)"
            ))
        })?;
        let int8_lane = h.router.lane(VARIANT_INT8)?;
        Ok(AdaptiveClient::new(f32_lane, int8_lane, policy))
    }

    /// Serving metadata; loads the model on first use.
    pub fn info(&mut self, model: &str) -> Result<ModelInfo> {
        Ok(self.ensure_loaded(model)?.info.clone())
    }

    /// Metrics snapshot for one (model, variant). Errors when the model
    /// was never loaded (no traffic means no router to ask).
    pub fn metrics(&self, model: &str, variant: &str) -> Result<Snapshot> {
        let e = self
            .entries
            .get(model)
            .ok_or_else(|| anyhow!("no model '{model}' registered"))?;
        match &e.hosted {
            Some(h) => h.router.metrics(variant),
            None => bail!("model '{model}' not loaded"),
        }
    }

    /// Drain a resident model's servers and free its plan; the next
    /// request through the registry re-loads it lazily. Queued requests
    /// are still answered (the shutdown drains before joining). Returns
    /// `false` when the model was not resident. The per-generation
    /// snapshots are retained and returned by [`Registry::shutdown`].
    pub fn evict(&mut self, model: &str) -> Result<bool> {
        let e = self
            .entries
            .get_mut(model)
            .ok_or_else(|| anyhow!("no model '{model}' registered"))?;
        match e.hosted.take() {
            None => Ok(false),
            Some(h) => {
                trace::emit_with(Severity::Info, "registry", || {
                    ("evict".into(), vec![("model", model.to_string())])
                });
                for (variant, snap) in h.router.shutdown() {
                    e.retired.push((variant, snap));
                }
                Ok(true)
            }
        }
    }

    /// Hot-swap one model: re-read its source (the `.dfqm` file for
    /// artifact registrations, a fresh plan for in-memory ones) and
    /// swap the router behind every [`LiveClient`] *before* draining
    /// the old generation — in-flight and queued requests complete on
    /// the old server while new submissions hit the new one, so nothing
    /// is dropped. Every retired lane is stop-signalled before any is
    /// joined ([`super::Router::shutdown`] drains them concurrently),
    /// so swap latency does not scale with
    /// [`ServeConfig::lanes_per_model`](super::ServeConfig::lanes_per_model).
    /// The new generation is *warmed up* (one zero batch per
    /// variant) before any slot flips, so the first real request after a
    /// swap never pays worker spin-up or arena-growth latency. On
    /// failure (missing / corrupt / version-skewed file) the typed
    /// [`ArtifactError`](crate::artifact::ArtifactError) is returned and
    /// the old generation keeps serving untouched.
    pub fn reload(&mut self, model: &str) -> Result<()> {
        if !self.entries.contains_key(model) {
            bail!("no model '{model}' registered");
        }
        // reloading a non-resident model is just a load: same resident
        // cap, same LRU touch
        if self.entries[model].hosted.is_none() {
            self.ensure_loaded(model)?;
            return Ok(());
        }
        self.clock += 1;
        let clock = self.clock;
        let cfg = self.cfg;
        let e = self.entries.get_mut(model).expect("checked above");
        // warm the new generation (one batch per variant) before the
        // LiveClient slots flip, so the first post-swap request never
        // pays cold-start latency
        let hosted = match load_and_repoint(cfg, model, e, true) {
            Ok(h) => h,
            Err(err) => {
                trace::emit_with(Severity::Warn, "registry", || {
                    (
                        "reload failed".into(),
                        vec![
                            ("model", model.to_string()),
                            ("error", format!("{err:#}")),
                        ],
                    )
                });
                return Err(err);
            }
        };
        trace::emit_with(Severity::Info, "registry", || {
            ("reload".into(), vec![("model", model.to_string())])
        });
        if let Some(old) = e.hosted.replace(hosted) {
            for (variant, snap) in old.router.shutdown() {
                e.retired.push((variant, snap));
            }
        }
        // the swapped-in generation is the freshest thing in the
        // registry — it must not be the next LRU victim
        e.last_used = clock;
        Ok(())
    }

    /// Reload every *resident* artifact-backed model whose file changed
    /// on disk since it was loaded (by length + mtime). Returns one
    /// `(name, result)` per attempted swap — a failed swap keeps the
    /// old generation serving and is retried on the next poll (the
    /// stamp only advances on success, so a half-written file heals
    /// itself once the writer finishes). A *deleted* file is not a new
    /// version: the resident plan keeps serving and no swap is
    /// attempted until a file is back at the path.
    pub fn poll_files(&mut self) -> Vec<(String, Result<()>)> {
        let stale: Vec<String> = self
            .entries
            .iter()
            .filter(|(_, e)| {
                e.hosted.is_some()
                    && matches!(e.source, Source::File(_))
                    && match stamp_of(&e.source) {
                        Some(now) => Some(now) != e.stamp,
                        None => false, // file gone: keep serving
                    }
            })
            .map(|(name, _)| name.clone())
            .collect();
        if !stale.is_empty() {
            trace::emit_with(Severity::Info, "registry", || {
                (
                    "poll".into(),
                    vec![("stale", stale.len().to_string())],
                )
            });
        }
        stale
            .into_iter()
            .map(|name| {
                let r = self.reload(&name);
                (name, r)
            })
            .collect()
    }

    /// [`Registry::poll_files`] behind a directory-level debounce: one
    /// `stat` per *distinct parent directory* of the resident
    /// artifact-backed models instead of one per file. A changed
    /// directory stamp (a replace-by-rename deploy, a new or deleted
    /// file) triggers an immediate full poll; an unchanged one falls
    /// back to a doubling schedule of full polls (1, 2, 4, then every
    /// 8th quiet call) so in-place rewrites — which do *not* bump the
    /// parent's mtime — are still caught within at most 8 debounced
    /// calls. With a 1000-model zoo on one directory, a quiet watch
    /// tick costs 1 stat instead of 1000.
    pub fn poll_files_debounced(
        &mut self,
        db: &mut WatchDebounce,
    ) -> Vec<(String, Result<()>)> {
        let dirs: Vec<PathBuf> = {
            let mut v: Vec<PathBuf> = self
                .entries
                .values()
                .filter(|e| e.hosted.is_some())
                .filter_map(|e| match &e.source {
                    Source::File(p) => {
                        p.parent().map(|d| d.to_path_buf())
                    }
                    Source::Memory(_) => None,
                })
                .collect();
            v.sort();
            v.dedup();
            v
        };
        let mut stamps = HashMap::with_capacity(dirs.len());
        let mut changed = false;
        for dir in dirs {
            let now = dir_stamp(&dir);
            if db.dirs.get(&dir) != Some(&now) {
                changed = true;
            }
            stamps.insert(dir, now);
        }
        db.dirs = stamps;
        if changed {
            db.quiet = 0;
            db.next_full = 1;
            return self.poll_files();
        }
        db.quiet += 1;
        if db.quiet < db.next_full {
            return Vec::new(); // debounced: no per-file stats this call
        }
        db.quiet = 0;
        db.next_full = (db.next_full * 2).min(8);
        let swaps = self.poll_files();
        if !swaps.is_empty() {
            db.next_full = 1; // in-place writer active: poll eagerly
        }
        swaps
    }

    /// One Prometheus-style text exposition document covering every
    /// *resident* `(model, variant)` server, labelled
    /// `{model="...",variant="..."}`. Models iterate in name order and
    /// variants in sorted order, so the document is reproducible. Note
    /// the dialect repeats `# HELP`/`# TYPE` headers per (model,
    /// variant) series — accepted by
    /// [`check_exposition`](crate::obs::check_exposition), which is the
    /// format this crate promises.
    pub fn exposition(&self) -> String {
        let mut out = String::new();
        for (name, e) in &self.entries {
            if let Some(h) = &e.hosted {
                for (variant, m) in h.router.metrics_handles() {
                    out.push_str(&m.exposition(&[
                        ("model", name.as_str()),
                        ("variant", variant),
                    ]));
                }
            }
        }
        out
    }

    /// Stop every live router; returns `(model, variant, snapshot)` per
    /// server generation — including generations retired earlier by
    /// evict/reload, so multi-generation totals add up.
    pub fn shutdown(self) -> Vec<(String, String, Snapshot)> {
        let mut out = Vec::new();
        for (name, e) in self.entries {
            for (variant, snap) in e.retired {
                out.push((name.clone(), variant, snap));
            }
            if let Some(h) = e.hosted {
                for (variant, snap) in h.router.shutdown() {
                    out.push((name.clone(), variant, snap));
                }
            }
        }
        out
    }

    fn ensure_loaded(&mut self, model: &str) -> Result<&Hosted> {
        if !self.entries.contains_key(model) {
            bail!("no model '{model}' registered");
        }
        self.clock += 1;
        let clock = self.clock;
        if self.entries[model].hosted.is_none() {
            // make room first so the resident cap holds *during* the
            // load, then decode/plan
            self.enforce_cap(model);
            let cfg = self.cfg;
            let e = self.entries.get_mut(model).expect("checked above");
            let hosted = load_and_repoint(cfg, model, e, false)?;
            trace::emit_with(Severity::Info, "registry", || {
                (
                    "load".into(),
                    vec![
                        ("model", model.to_string()),
                        ("source", hosted.info.source.to_string()),
                    ],
                )
            });
            e.hosted = Some(hosted);
        }
        let e = self.entries.get_mut(model).expect("checked above");
        e.last_used = clock;
        Ok(e.hosted.as_ref().expect("just loaded"))
    }

    /// Evict least-recently-used resident models (never `keep`) until a
    /// slot is free under [`ServeConfig::max_resident`]. Soft cap: when
    /// only `keep` remains resident nothing more can go.
    fn enforce_cap(&mut self, keep: &str) {
        let cap = self.cfg.max_resident;
        if cap == 0 {
            return;
        }
        while self
            .entries
            .values()
            .filter(|e| e.hosted.is_some())
            .count()
            >= cap
        {
            let victim = self
                .entries
                .iter()
                .filter(|(name, e)| {
                    e.hosted.is_some() && name.as_str() != keep
                })
                .min_by_key(|(_, e)| e.last_used)
                .map(|(name, _)| name.clone());
            match victim {
                Some(name) => {
                    trace::emit_with(Severity::Info, "registry", || {
                        (
                            "evict_lru".into(),
                            vec![
                                ("victim", name.clone()),
                                ("keep", keep.to_string()),
                            ],
                        )
                    });
                    let _ = self.evict(&name);
                }
                None => break,
            }
        }
    }
}

/// Header-only candidate probe: reads the fixed 4-byte magic (never the
/// payload — a multi-gigabyte non-artifact sharing the `.dfqm`
/// extension costs one small read to reject). Deliberately checks the
/// magic only: a version-skewed artifact must still *register*, so its
/// first load surfaces the typed `UnsupportedVersion` error instead of
/// the model silently vanishing from the registry.
fn has_artifact_magic(path: &Path) -> bool {
    use std::io::Read as _;
    let Ok(mut f) = std::fs::File::open(path) else { return false };
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic).is_ok()
        && magic == crate::artifact::format::MAGIC
}

/// Shared tail of lazy (re-)load and hot swap: read the entry's source,
/// build the new generation, and re-point every live slot at it. The
/// file stamp is taken *before* the read so a write racing the load
/// re-triggers the next poll instead of being missed; it only advances
/// when the load succeeds.
fn load_and_repoint(
    cfg: ServeConfig,
    name: &str,
    e: &mut Entry,
    warm: bool,
) -> Result<Hosted> {
    let stamp = stamp_of(&e.source);
    let hosted = load_entry(cfg, name, &e.source)?;
    if warm {
        warm_up(&hosted);
    }
    for (variant, slot) in &e.live {
        if let Ok(client) = hosted.router.client(variant) {
            *slot.write().unwrap() = client;
        }
    }
    e.stamp = stamp;
    Ok(hosted)
}

/// Pre-run one batch through every variant of a freshly built generation
/// *before* any live slot is re-pointed at it: first-request latency
/// (worker spin-up, scratch-arena growth, lazily-faulted weight pages)
/// is paid here instead of by the first real request after a hot swap.
/// Best-effort — a warm-up failure never fails the swap; the same error
/// would surface on the first real request anyway.
fn warm_up(hosted: &Hosted) {
    let [c, h, w] = hosted.info.input_shape;
    let x = Tensor::zeros(&[1, c, h, w]);
    for variant in &hosted.info.variants {
        if let Ok(client) = hosted.router.client(variant) {
            let _ = client.infer(x.clone());
        }
    }
}

/// Turn a pre-built pool of executors (one per lane, constructed
/// eagerly so load errors surface at load time, not per-request) into
/// the lane factory [`Server::start_sharded_shared`] expects: each lane
/// pops one executor. Pre-building sidesteps any `Clone` requirement on
/// the executor while keeping every lane on its own scratch arenas.
fn lane_pool(
    execs: Vec<Box<dyn BatchExecutor + Send>>,
) -> impl Fn() -> Result<Box<dyn BatchExecutor>> + Send + Sync + 'static {
    let pool = Mutex::new(execs);
    move || {
        let exec: Box<dyn BatchExecutor> = pool
            .lock()
            .unwrap()
            .pop()
            .ok_or_else(|| anyhow!("lane executor pool exhausted"))?;
        Ok(exec)
    }
}

fn load_entry(cfg: ServeConfig, name: &str, source: &Source) -> Result<Hosted> {
    let max_batch = cfg.max_batch;
    let lanes = cfg.lanes_per_model.max(1);
    // one admission queue per *model*, shared by every lane of every
    // variant — the cap bounds the model's total in-flight work, so
    // spreading load across variants cannot exceed it
    let admission = Arc::new(AdmissionQueue::new(cfg.admission_cap));
    match source {
        Source::File(path) => {
            // mmap by default: weight tensors become typed views into
            // the page-cache-backed mapping, so N resident models, N
            // lanes, or N serving processes on one zoo share physical
            // weight pages and a cold boot skips the full-file read.
            // Each lane decodes its own plan (scratch arenas are
            // per-worker); with mmap the per-lane cost is the decode
            // walk, not a weight copy.
            let mut pool: Vec<Box<dyn BatchExecutor + Send>> =
                Vec::with_capacity(lanes);
            let mut meta = None;
            for _ in 0..lanes {
                let art = if cfg.mmap {
                    Artifact::open_mmap(path)?
                } else {
                    Artifact::open(path)?
                };
                let (ainfo, qmodel) = art.into_parts();
                if meta.is_none() {
                    meta = Some((ainfo, qmodel.summary()));
                }
                pool.push(Box::new(QuantExecutor { qmodel, max_batch }));
            }
            let (ainfo, plan) = meta.expect("lanes >= 1");
            let mut router = Router::new();
            router.add(
                VARIANT_INT8,
                Server::start_sharded_shared(
                    cfg,
                    admission,
                    lane_pool(pool),
                ),
            );
            Ok(Hosted {
                router,
                info: ModelInfo {
                    name: name.to_string(),
                    input_shape: ainfo.input_shape,
                    num_classes: ainfo.num_classes,
                    variants: vec![VARIANT_INT8.to_string()],
                    source: "artifact",
                    plan,
                },
            })
        }
        Source::Memory(q) => {
            // build the plans eagerly so load errors surface here (and
            // the summary is reportable), then hand them to the workers
            let mut int8_pool: Vec<Box<dyn BatchExecutor + Send>> =
                Vec::with_capacity(lanes);
            let mut f32_pool: Vec<Box<dyn BatchExecutor + Send>> =
                Vec::with_capacity(lanes);
            let mut plan = None;
            for _ in 0..lanes {
                let qmodel = q.pack_int8()?;
                if plan.is_none() {
                    plan = Some(qmodel.summary());
                }
                int8_pool
                    .push(Box::new(QuantExecutor { qmodel, max_batch }));
                f32_pool.push(Box::new(EngineExecutor {
                    model: q.model.clone(),
                    cfg: q.act_cfg.clone(),
                    max_batch,
                }));
            }
            let plan = plan.expect("lanes >= 1");
            let mut router = Router::new();
            router.add(
                VARIANT_F32,
                Server::start_sharded_shared(
                    cfg,
                    admission.clone(),
                    lane_pool(f32_pool),
                ),
            );
            router.add(
                VARIANT_INT8,
                Server::start_sharded_shared(
                    cfg,
                    admission,
                    lane_pool(int8_pool),
                ),
            );
            Ok(Hosted {
                router,
                info: ModelInfo {
                    name: name.to_string(),
                    input_shape: q.model.input_shape,
                    num_classes: q.model.num_classes,
                    variants: vec![
                        VARIANT_F32.to_string(),
                        VARIANT_INT8.to_string(),
                    ],
                    source: "memory",
                    plan,
                },
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::{quantize_data_free, testutil, BiasCorrMode, DfqConfig};
    use crate::nn::qengine::PlanOpts;
    use crate::quant::QScheme;
    use crate::tensor::Tensor;

    fn quantized(seed: u64) -> QuantizedModel {
        let m = testutil::residual_block_model(seed);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        prep.quantize(
            &QScheme::int8_asymmetric(),
            8,
            BiasCorrMode::None,
            None,
        )
        .unwrap()
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("dfq-registry-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn registry_lazy_loads_and_serves_two_models() {
        let dir = temp_dir("two");
        let qa = quantized(61);
        let qb = quantized(62);
        qa.save_artifact(dir.join("model_a.dfqm"), PlanOpts::default())
            .unwrap();
        qb.save_artifact(dir.join("model_b.dfqm"), PlanOpts::default())
            .unwrap();

        let mut reg = Registry::new(ServeConfig::default());
        let names = reg.scan_dir(&dir).unwrap();
        assert_eq!(names, vec!["model_a", "model_b"]);
        assert!(reg.loaded().is_empty(), "scan must not load anything");

        // interleave concurrent submissions to both models
        let xa = testutil::random_input(&qa.model, 1, 5);
        let xb = testutil::random_input(&qb.model, 1, 6);
        let ca = reg.client("model_a", VARIANT_INT8).unwrap();
        let cb = reg.client("model_b", VARIANT_INT8).unwrap();
        assert_eq!(reg.loaded().len(), 2);
        let pending: Vec<_> = (0..4)
            .flat_map(|_| {
                vec![
                    ("a", ca.submit(xa.clone()).unwrap()),
                    ("b", cb.submit(xb.clone()).unwrap()),
                ]
            })
            .collect();

        let want_a = qa.pack_int8().unwrap().run(&xa).unwrap();
        let want_b = qb.pack_int8().unwrap().run(&xb).unwrap();
        for (tag, rx) in pending {
            let y = rx.recv().unwrap().unwrap();
            let want = if tag == "a" { &want_a } else { &want_b };
            assert_eq!(
                y.data(),
                want.data(),
                "registry output drifted from the in-memory plan ({tag})"
            );
        }
        let snaps = reg.shutdown();
        assert_eq!(snaps.len(), 2);
        for (_, _, s) in &snaps {
            assert_eq!(s.completed, 4);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn memory_models_host_both_variants() {
        let q = quantized(63);
        let x = testutil::random_input(&q.model, 1, 9);
        let mut reg = Registry::new(ServeConfig::default());
        reg.register_quantized("res", q).unwrap();
        let info = reg.info("res").unwrap();
        assert_eq!(info.variants, vec!["f32", "int8"]);
        assert_eq!(info.source, "memory");
        let y_f32 =
            reg.client("res", VARIANT_F32).unwrap().infer(x.clone()).unwrap();
        let y_int8 =
            reg.client("res", VARIANT_INT8).unwrap().infer(x).unwrap();
        assert_eq!(y_f32.shape(), y_int8.shape());
        assert!(reg.metrics("res", VARIANT_INT8).unwrap().completed == 1);
        reg.shutdown();
    }

    #[test]
    fn scan_skips_source_model_containers() {
        let dir = temp_dir("skip");
        let q = quantized(64);
        // a *source* model container shares the extension but not the magic
        q.model.save(dir.join("source_model.dfqm")).unwrap();
        q.save_artifact(dir.join("compiled.dfqm"), PlanOpts::default())
            .unwrap();
        let mut reg = Registry::new(ServeConfig::default());
        assert_eq!(reg.scan_dir(&dir).unwrap(), vec!["compiled"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Bytes this process has read through syscalls (`rchar` from
    /// `/proc/self/io`); `None` off Linux — the caller skips the
    /// byte-accounting assertion there.
    fn process_read_bytes() -> Option<u64> {
        let io = std::fs::read_to_string("/proc/self/io").ok()?;
        io.lines()
            .find_map(|l| l.strip_prefix("rchar: "))
            .and_then(|v| v.trim().parse().ok())
    }

    #[test]
    fn scan_skips_large_non_artifact_files_by_header_probe() {
        let dir = temp_dir("big");
        let q = quantized(67);
        q.save_artifact(dir.join("real.dfqm"), PlanOpts::default()).unwrap();
        // a 64 MiB sparse file with the right extension but no artifact
        // header: the probe must reject it from its first bytes
        {
            let f = std::fs::File::create(dir.join("big_junk.dfqm")).unwrap();
            f.set_len(64 << 20).unwrap();
        }
        // short garbage and an empty file must not panic either
        std::fs::write(dir.join("tiny.dfqm"), b"DF").unwrap();
        std::fs::write(dir.join("empty.dfqm"), b"").unwrap();
        // right magic, future version -> still registers (the typed
        // UnsupportedVersion error belongs to the load, not the scan)
        let mut skewed = b"DFQP".to_vec();
        skewed.extend_from_slice(&99u32.to_le_bytes());
        skewed.extend_from_slice(&[0u8; 64]);
        std::fs::write(dir.join("skewed.dfqm"), skewed).unwrap();

        let mut reg = Registry::new(ServeConfig::default());
        let before = process_read_bytes();
        assert_eq!(reg.scan_dir(&dir).unwrap(), vec!["real", "skewed"]);
        // falsifiable header-only guarantee: scanning must not read the
        // 64 MiB payload. The budget is generous (other test threads
        // share the counter) but far below the junk-file size.
        if let (Some(a), Some(b)) = (before, process_read_bytes()) {
            assert!(
                b - a < 32 << 20,
                "scan read {} bytes — not a header-only probe",
                b - a
            );
        }
        // the skewed artifact fails at load with a real error
        let err = reg.client("skewed", VARIANT_INT8).unwrap_err();
        assert!(
            format!("{err:#}").contains("version"),
            "expected an UnsupportedVersion load error, got: {err:#}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn debounced_poll_catches_inplace_rewrites_via_backoff() {
        let dir = temp_dir("debounce");
        let path = dir.join("model.dfqm");
        quantized(68)
            .save_artifact(&path, PlanOpts::default())
            .unwrap();
        let mut reg = Registry::new(ServeConfig::default());
        reg.register_file("model", &path).unwrap();
        reg.client("model", VARIANT_INT8).unwrap(); // make it resident
        let mut db = WatchDebounce::new();
        // steady state: no change means no swaps, whichever schedule
        // branch each call lands on
        for _ in 0..4 {
            assert!(reg.poll_files_debounced(&mut db).is_empty());
        }
        // in-place rewrite: the parent dir mtime does NOT change, so
        // only the backoff schedule of full per-file polls can see it —
        // within at most 8 debounced calls by construction
        quantized(69)
            .save_artifact(&path, PlanOpts::default())
            .unwrap();
        let swapped = (0..8).any(|_| {
            reg.poll_files_debounced(&mut db)
                .iter()
                .any(|(n, r)| n == "model" && r.is_ok())
        });
        assert!(
            swapped,
            "in-place rewrite not caught within 8 debounced polls"
        );
        reg.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn debounced_poll_sees_rename_deploys_from_the_dir_stamp() {
        let dir = temp_dir("debounce-mv");
        let path = dir.join("model.dfqm");
        quantized(70)
            .save_artifact(&path, PlanOpts::default())
            .unwrap();
        let mut reg = Registry::new(ServeConfig::default());
        reg.register_file("model", &path).unwrap();
        reg.client("model", VARIANT_INT8).unwrap();
        let mut db = WatchDebounce::new();
        reg.poll_files_debounced(&mut db); // warm the dir stamps
        // replace-by-rename (the recommended deploy): creating + renaming
        // bumps the parent dir mtime, so the swap lands on the next
        // debounced call without waiting out the backoff schedule
        let tmp = dir.join("model.dfqm.tmp");
        quantized(71).save_artifact(&tmp, PlanOpts::default()).unwrap();
        std::fs::rename(&tmp, &path).unwrap();
        let swapped = (0..2).any(|_| {
            reg.poll_files_debounced(&mut db)
                .iter()
                .any(|(n, r)| n == "model" && r.is_ok())
        });
        assert!(swapped, "rename deploy not caught by the dir stamp");
        reg.shutdown();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mmap_and_copy_loads_serve_identical_logits() {
        let dir = temp_dir("mmap-parity");
        let q = quantized(72);
        let path = dir.join("model.dfqm");
        q.save_artifact(&path, PlanOpts::default()).unwrap();
        let x = testutil::random_input(&q.model, 1, 11);
        let mut got = Vec::new();
        for mmap in [true, false] {
            let mut reg = Registry::new(ServeConfig {
                mmap,
                ..ServeConfig::default()
            });
            reg.register_file("m", &path).unwrap();
            got.push(
                reg.client("m", VARIANT_INT8)
                    .unwrap()
                    .infer(x.clone())
                    .unwrap(),
            );
            reg.shutdown();
        }
        assert_eq!(
            got[0].data(),
            got[1].data(),
            "mmap-loaded registry output drifted from the copy load"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_registry_serves_identical_logits_and_merged_totals() {
        let q = quantized(73);
        let x = testutil::random_input(&q.model, 1, 13);
        let want = q.pack_int8().unwrap().run(&x).unwrap();
        let mut reg = Registry::new(ServeConfig {
            lanes_per_model: 3,
            ..ServeConfig::default()
        });
        reg.register_quantized("m", q).unwrap();
        let client = reg.live_client("m", VARIANT_INT8).unwrap();
        let pending: Vec<_> = (0..12)
            .map(|i| {
                let p = if i % 2 == 0 {
                    Priority::Interactive
                } else {
                    Priority::Batch
                };
                client.submit_prio(x.clone(), p).unwrap()
            })
            .collect();
        for rx in pending {
            let y = rx.recv().unwrap().unwrap();
            assert_eq!(
                y.data(),
                want.data(),
                "sharded lane output drifted from the serial plan"
            );
        }
        let int8 = reg.metrics("m", VARIANT_INT8).unwrap();
        assert_eq!(
            int8.completed, 12,
            "per-lane traffic must merge into the shared variant view"
        );
        reg.shutdown();
    }

    #[test]
    fn unknown_names_and_variants_error() {
        let mut reg = Registry::new(ServeConfig::default());
        assert!(reg.client("ghost", VARIANT_INT8).is_err());
        assert!(reg.metrics("ghost", VARIANT_INT8).is_err());
        let q = quantized(65);
        reg.register_quantized("m", q).unwrap();
        assert!(reg.register_quantized("m", quantized(66)).is_err());
        assert!(reg.client("m", "no-such-variant").is_err());
        // bad file registrations fail at load, not registration
        reg.register_file("broken", "/definitely/missing.dfqm").unwrap();
        assert!(reg.client("broken", VARIANT_INT8).is_err());
        let x = Tensor::full(&[1, 3, 8, 8], 0.5);
        assert!(reg
            .client("m", VARIANT_INT8)
            .unwrap()
            .infer(x)
            .is_ok());
        reg.shutdown();
    }
}
