//! Serving demo / load generator: Poisson arrivals against the batching
//! server backed by the INT8 DFQ model on a selectable backend — PJRT
//! (production), the fake-quant f32 engine, or the true-int8
//! [`QuantExecutor`] plan. Used by `dfq serve`, the `serve_quantized`
//! example and the serving bench.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
use crate::graph::io::Dataset;
use crate::graph::Model;
use crate::quant::QScheme;
use crate::runtime::{Manifest, Runtime};
use crate::serve::{
    registry, AdaptiveClient, AutoscalePolicy, BatchExecutor,
    EngineExecutor, PjrtExecutor, QuantExecutor, Registry, ServeConfig,
    Server, Snapshot,
};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Which executor backs the serve worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServeBackend {
    /// AOT-compiled PJRT executable (production path).
    #[default]
    Pjrt,
    /// Pure-Rust fake-quant f32 engine (PJRT-free hosts / oracle).
    Engine,
    /// True-int8 planned executor ([`crate::nn::qengine`]).
    Qengine,
}

impl ServeBackend {
    pub fn parse(s: &str) -> Result<ServeBackend> {
        Ok(match s {
            "pjrt" => ServeBackend::Pjrt,
            "engine" => ServeBackend::Engine,
            "qengine" | "int8" => ServeBackend::Qengine,
            _ => bail!("unknown serve backend '{s}' (pjrt|engine|qengine)"),
        })
    }

    /// Backend from the `DFQ_BACKEND` env var; absent means PJRT, an
    /// unrecognised value falls back to PJRT *with a warning* (a typo
    /// must not silently benchmark the wrong engine).
    pub fn from_env() -> ServeBackend {
        match std::env::var("DFQ_BACKEND") {
            Ok(s) => ServeBackend::parse(&s).unwrap_or_else(|e| {
                eprintln!("[serve] {e:#}; defaulting to pjrt");
                ServeBackend::Pjrt
            }),
            Err(_) => ServeBackend::Pjrt,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ServeBackend::Pjrt => "pjrt",
            ServeBackend::Engine => "engine",
            ServeBackend::Qengine => "qengine",
        }
    }
}

/// How often the `--metrics-dump` writer refreshes its file, in
/// submitted requests. Coarse on purpose: the dump is a scrape surface,
/// not a trace.
const DUMP_EVERY: usize = 32;

/// Overwrite `path` with a fresh text exposition document (best-effort
/// during the run; the final write propagates errors from the caller).
fn dump_exposition(path: &Path, text: &str) {
    if let Err(e) = std::fs::write(path, text) {
        eprintln!("[serve] metrics dump to {} failed: {e}", path.display());
    }
}

/// Start a server for `arch`'s INT8-DFQ model on `backend` (built inside
/// the worker thread), fire `requests` Poisson arrivals at `rate` req/s
/// (`seed` fixes the arrival process), and report latency/throughput.
/// `metrics_dump` periodically overwrites the file with a Prometheus-style
/// text exposition and prints a one-line JSON summary at the end.
pub fn run_load(
    arch: &str,
    requests: usize,
    rate: f64,
    batch: usize,
    backend: ServeBackend,
    seed: u64,
    metrics_dump: Option<&Path>,
) -> Result<()> {
    let snapshot = run_load_quiet(
        arch,
        requests,
        rate,
        batch,
        backend,
        seed,
        metrics_dump,
    )?;
    println!("serve[{arch}/{}] {}", backend.as_str(), snapshot.report());
    Ok(())
}

/// Same as [`run_load`] but returns the metrics snapshot (bench use).
pub fn run_load_quiet(
    arch: &str,
    requests: usize,
    rate: f64,
    batch: usize,
    backend: ServeBackend,
    seed: u64,
    metrics_dump: Option<&Path>,
) -> Result<Snapshot> {
    let manifest = Manifest::load(crate::artifacts_dir())?;
    let entry = manifest.arch(arch)?.clone();
    let arch_name = arch.to_string();
    eprintln!("[serve] loading dataset...");

    // requests are real test images, cycled
    let ds = Dataset::load(manifest.dataset(&entry.task, "test")?)?;
    let images: Vec<Tensor> =
        (0..64.min(ds.len())).map(|i| ds.batch(i, i + 1)).collect();

    let server = Server::start(
        ServeConfig {
            max_batch: batch,
            max_delay: Duration::from_millis(3),
            queue_depth: 4096,
            ..ServeConfig::default()
        },
        move || {
            // constructed on the worker thread: PJRT handles are !Send
            eprintln!("[serve] worker: loading model...");
            let manifest = Manifest::load(crate::artifacts_dir())?;
            let model =
                Model::load(manifest.path(&manifest.arch(&arch_name)?.model))?;
            eprintln!("[serve] worker: running DFQ...");
            let prep = quantize_data_free(&model, &DfqConfig::default())?;
            let q = prep.quantize(
                &QScheme::int8_asymmetric(),
                8,
                BiasCorrMode::Analytic,
                None,
            )?;
            match backend {
                ServeBackend::Pjrt => {
                    eprintln!("[serve] worker: creating PJRT client...");
                    let rt = Runtime::cpu()?;
                    eprintln!(
                        "[serve] worker: compiling executable (batch {batch})..."
                    );
                    let exec = rt.load_model_exec(
                        &manifest, &arch_name, batch, &q.model,
                    )?;
                    let weights = exec.bind_weights(&q.model)?;
                    eprintln!("[serve] worker: ready");
                    Ok(Box::new(PjrtExecutor {
                        exec,
                        weights,
                        cfg: q.act_cfg,
                    }) as Box<dyn BatchExecutor>)
                }
                ServeBackend::Engine => {
                    eprintln!("[serve] worker: ready (fake-quant engine)");
                    Ok(Box::new(EngineExecutor {
                        model: q.model,
                        cfg: q.act_cfg,
                        max_batch: batch,
                    }) as Box<dyn BatchExecutor>)
                }
                ServeBackend::Qengine => {
                    let ex = QuantExecutor::from_quantized(&q, batch)?;
                    eprintln!(
                        "[serve] worker: int8 plan ready — {}",
                        ex.qmodel.summary()
                    );
                    Ok(Box::new(ex) as Box<dyn BatchExecutor>)
                }
            }
        },
    );

    let client = server.client();
    // warm-up: the first request pays executor construction (and PJRT
    // compilation on that backend); exclude it from the measured load
    client.infer(images[0].clone())?;
    server.reset_metrics();
    let metrics = server.metrics_handle();
    let labels = [("model", arch), ("variant", backend.as_str())];
    let mut rng = Rng::new(seed);
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        pending.push(client.submit(images[i % images.len()].clone())?);
        if let Some(path) = metrics_dump {
            if i % DUMP_EVERY == 0 {
                dump_exposition(path, &metrics.exposition(&labels));
            }
        }
        let gap = rng.exp(rate);
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.05)));
        }
    }
    for rx in pending {
        rx.recv()??;
    }
    if let Some(path) = metrics_dump {
        std::fs::write(path, metrics.exposition(&labels))?;
        println!(
            "{}",
            metrics.json_line(&format!("serve/{arch}/{}", backend.as_str()))
        );
    }
    Ok(server.shutdown())
}

/// Options for [`run_registry_load`] (`dfq serve --models dir/`).
#[derive(Debug, Clone)]
pub struct RegistryLoadOpts {
    pub requests: usize,
    /// Poisson arrival rate, req/s.
    pub rate: f64,
    pub batch: usize,
    /// Resident-model cap (0 = unbounded): exceeding it evicts the
    /// least-recently-used model, which lazily re-loads on next use.
    pub max_resident: usize,
    /// Poll the artifact files during the run and hot-swap any model
    /// whose `.dfqm` changed on disk (`dfq serve --models dir/ --watch`).
    pub watch: bool,
    /// Load artifacts via [`crate::artifact::Artifact::open_mmap`]
    /// (zero-copy weight views over the page cache, the default);
    /// `dfq serve --models dir/ --no-mmap` clears it.
    pub mmap: bool,
    /// Seed of the Poisson arrival process and the probe inputs
    /// (`dfq serve ... --seed N`; a fixed default keeps runs
    /// reproducible).
    pub seed: u64,
    /// Periodically overwrite this file with a Prometheus-style text
    /// exposition covering every resident (model, variant) server
    /// (`dfq serve ... --metrics-dump FILE`).
    pub metrics_dump: Option<PathBuf>,
}

impl Default for RegistryLoadOpts {
    fn default() -> Self {
        RegistryLoadOpts {
            requests: 256,
            rate: 200.0,
            batch: 64,
            max_resident: 0,
            watch: false,
            mmap: true,
            seed: 4242,
            metrics_dump: None,
        }
    }
}

/// Multi-tenant load over a directory of compiled `.dfqm` artifacts:
/// scan + load every model into a [`Registry`] (no python manifest, no
/// DFQ re-run — the plans boot straight off the artifact bytes), fire
/// Poisson arrivals round-robin across models on the int8 variant, and
/// return per-`model/variant` metrics (one entry per server generation
/// when hot swaps or evictions happened). Used by
/// `dfq serve --models dir/` and the serving bench.
pub fn run_registry_load(
    dir: &str,
    opts: RegistryLoadOpts,
) -> Result<Vec<(String, Snapshot)>> {
    let RegistryLoadOpts {
        requests,
        rate,
        batch,
        max_resident,
        watch,
        mmap,
        seed,
        metrics_dump,
    } = opts;
    let mut reg = Registry::new(ServeConfig {
        max_batch: batch,
        max_delay: Duration::from_millis(3),
        queue_depth: 4096,
        max_resident,
        mmap,
        ..ServeConfig::default()
    });
    let names = reg.scan_dir(dir)?;
    if names.is_empty() {
        bail!("no compiled .dfqm artifacts found in {dir}");
    }
    // probe every model once for its input shape (under a resident cap
    // this also exercises evict → lazy re-load before the measured load)
    let mut inputs = Vec::with_capacity(names.len());
    let mut rng = Rng::new(seed);
    for name in &names {
        let info = reg.info(name)?;
        eprintln!("[serve] {name}: {} ({})", info.plan, info.source);
        let [c, h, w] = info.input_shape;
        let data: Vec<f32> = (0..c * h * w).map(|_| rng.f32()).collect();
        inputs.push(Tensor::new(&[1, c, h, w], data));
    }
    let mut pending = Vec::with_capacity(requests);
    // dir-stamp debounce lets the watch tick run 4x as often as the old
    // per-file poll for less stat traffic on quiet zoos: a quiet tick is
    // one stat per artifact *directory*, not per artifact
    let mut watch_db = crate::serve::WatchDebounce::new();
    for i in 0..requests {
        if watch && i > 0 && i % 16 == 0 {
            for (name, r) in reg.poll_files_debounced(&mut watch_db) {
                match r {
                    Ok(()) => eprintln!("[serve] hot-swapped '{name}'"),
                    Err(e) => eprintln!(
                        "[serve] swap of '{name}' failed (old model keeps \
                         serving): {e:#}"
                    ),
                }
            }
        }
        let k = i % names.len();
        // route through the registry each time: under a resident cap
        // this is what re-loads evicted models lazily
        let client = reg.live_client(&names[k], registry::VARIANT_INT8)?;
        pending.push(client.submit(inputs[k].clone())?);
        if let Some(path) = &metrics_dump {
            if i % DUMP_EVERY == 0 {
                dump_exposition(path, &reg.exposition());
            }
        }
        let gap = rng.exp(rate);
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.05)));
        }
    }
    for rx in pending {
        rx.recv()??;
    }
    if let Some(path) = &metrics_dump {
        std::fs::write(path, reg.exposition())?;
    }
    Ok(reg
        .shutdown()
        .into_iter()
        .map(|(model, variant, snap)| (format!("{model}/{variant}"), snap))
        .collect())
}

/// Drive Poisson arrivals through an [`AdaptiveClient`], with a burst
/// of `burst` back-to-back submissions injected at the halfway point to
/// build queue depth (the shed trigger). Waits for every response;
/// returns how many requests failed (0 on a healthy run).
pub fn drive_adaptive(
    client: &AdaptiveClient,
    inputs: &[Tensor],
    requests: usize,
    rate: f64,
    burst: usize,
    seed: u64,
) -> Result<u64> {
    let mut rng = Rng::new(seed);
    let mut pending = Vec::with_capacity(requests + burst);
    for i in 0..requests {
        pending.push(client.submit(inputs[i % inputs.len()].clone())?);
        if i == requests / 2 {
            for j in 0..burst {
                pending
                    .push(client.submit(inputs[j % inputs.len()].clone())?);
            }
        }
        let gap = rng.exp(rate);
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.05)));
        }
    }
    let mut failed = 0u64;
    for rx in pending {
        match rx.recv() {
            Ok(Ok(_)) => {}
            _ => failed += 1,
        }
    }
    Ok(failed)
}

/// `dfq serve <arch> --autoscale`: host the f32 oracle and the int8
/// plan of one DFQ-quantised model behind an [`AdaptiveClient`] and
/// fire Poisson load (plus a mid-run burst) so the autoscaler steers
/// between them; prints the routing split, the transition trace and a
/// JSON record.
pub fn run_adaptive_load(
    arch: &str,
    requests: usize,
    rate: f64,
    batch: usize,
    seed: u64,
) -> Result<()> {
    let manifest = Manifest::load(crate::artifacts_dir())?;
    let entry = manifest.arch(arch)?.clone();
    let ds = Dataset::load(manifest.dataset(&entry.task, "test")?)?;
    let images: Vec<Tensor> =
        (0..64.min(ds.len())).map(|i| ds.batch(i, i + 1)).collect();
    let model = Model::load(manifest.path(&entry.model))?;
    let prep = quantize_data_free(&model, &DfqConfig::default())?;
    let q = prep.quantize(
        &QScheme::int8_asymmetric(),
        8,
        BiasCorrMode::Analytic,
        None,
    )?;
    let mut reg = Registry::new(ServeConfig {
        max_batch: batch,
        max_delay: Duration::from_millis(3),
        queue_depth: 4096,
        autoscale: Some(AutoscalePolicy::default()),
        ..ServeConfig::default()
    });
    reg.register_quantized(arch, q)?;
    let client = reg.adaptive_client(arch)?;
    let burst = requests.min(128);
    let failed =
        drive_adaptive(&client, &images, requests, rate, burst, seed)?;
    let report = client.report();
    println!("autoscale[{arch}] {}", report.summary_line());
    for t in &report.transitions {
        println!("  {}", t.describe());
    }
    println!("{}", report.json(&format!("serve/{arch}/autoscale")));
    for (model, variant, snap) in reg.shutdown() {
        println!("serve[{model}/{variant}] {}", snap.report());
    }
    if failed > 0 {
        bail!("{failed} request(s) failed under adaptive routing");
    }
    Ok(())
}
