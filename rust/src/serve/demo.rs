//! Serving demo / load generator: Poisson arrivals against the batching
//! server backed by the INT8 DFQ model on a selectable backend — PJRT
//! (production), the fake-quant f32 engine, or the true-int8
//! [`QuantExecutor`] plan. Used by `dfq serve`, the `serve_quantized`
//! example and the serving bench.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
use crate::graph::io::Dataset;
use crate::graph::Model;
use crate::quant::QScheme;
use crate::runtime::{Manifest, Runtime};
use crate::serve::{
    registry, BatchExecutor, EngineExecutor, PjrtExecutor, QuantExecutor,
    Registry, ServeConfig, Server, Snapshot,
};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Which executor backs the serve worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServeBackend {
    /// AOT-compiled PJRT executable (production path).
    #[default]
    Pjrt,
    /// Pure-Rust fake-quant f32 engine (PJRT-free hosts / oracle).
    Engine,
    /// True-int8 planned executor ([`crate::nn::qengine`]).
    Qengine,
}

impl ServeBackend {
    pub fn parse(s: &str) -> Result<ServeBackend> {
        Ok(match s {
            "pjrt" => ServeBackend::Pjrt,
            "engine" => ServeBackend::Engine,
            "qengine" | "int8" => ServeBackend::Qengine,
            _ => bail!("unknown serve backend '{s}' (pjrt|engine|qengine)"),
        })
    }

    /// Backend from the `DFQ_BACKEND` env var; absent means PJRT, an
    /// unrecognised value falls back to PJRT *with a warning* (a typo
    /// must not silently benchmark the wrong engine).
    pub fn from_env() -> ServeBackend {
        match std::env::var("DFQ_BACKEND") {
            Ok(s) => ServeBackend::parse(&s).unwrap_or_else(|e| {
                eprintln!("[serve] {e:#}; defaulting to pjrt");
                ServeBackend::Pjrt
            }),
            Err(_) => ServeBackend::Pjrt,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ServeBackend::Pjrt => "pjrt",
            ServeBackend::Engine => "engine",
            ServeBackend::Qengine => "qengine",
        }
    }
}

/// Start a server for `arch`'s INT8-DFQ model on `backend` (built inside
/// the worker thread), fire `requests` Poisson arrivals at `rate` req/s,
/// and report latency/throughput.
pub fn run_load(
    arch: &str,
    requests: usize,
    rate: f64,
    batch: usize,
    backend: ServeBackend,
) -> Result<()> {
    let snapshot = run_load_quiet(arch, requests, rate, batch, backend)?;
    println!("serve[{arch}/{}] {}", backend.as_str(), snapshot.report());
    Ok(())
}

/// Same as [`run_load`] but returns the metrics snapshot (bench use).
pub fn run_load_quiet(
    arch: &str,
    requests: usize,
    rate: f64,
    batch: usize,
    backend: ServeBackend,
) -> Result<Snapshot> {
    let manifest = Manifest::load(crate::artifacts_dir())?;
    let entry = manifest.arch(arch)?.clone();
    let arch_name = arch.to_string();
    eprintln!("[serve] loading dataset...");

    // requests are real test images, cycled
    let ds = Dataset::load(manifest.dataset(&entry.task, "test")?)?;
    let images: Vec<Tensor> =
        (0..64.min(ds.len())).map(|i| ds.batch(i, i + 1)).collect();

    let server = Server::start(
        ServeConfig {
            max_batch: batch,
            max_delay: Duration::from_millis(3),
            queue_depth: 4096,
        },
        move || {
            // constructed on the worker thread: PJRT handles are !Send
            eprintln!("[serve] worker: loading model...");
            let manifest = Manifest::load(crate::artifacts_dir())?;
            let model =
                Model::load(manifest.path(&manifest.arch(&arch_name)?.model))?;
            eprintln!("[serve] worker: running DFQ...");
            let prep = quantize_data_free(&model, &DfqConfig::default())?;
            let q = prep.quantize(
                &QScheme::int8_asymmetric(),
                8,
                BiasCorrMode::Analytic,
                None,
            )?;
            match backend {
                ServeBackend::Pjrt => {
                    eprintln!("[serve] worker: creating PJRT client...");
                    let rt = Runtime::cpu()?;
                    eprintln!(
                        "[serve] worker: compiling executable (batch {batch})..."
                    );
                    let exec = rt.load_model_exec(
                        &manifest, &arch_name, batch, &q.model,
                    )?;
                    let weights = exec.bind_weights(&q.model)?;
                    eprintln!("[serve] worker: ready");
                    Ok(Box::new(PjrtExecutor {
                        exec,
                        weights,
                        cfg: q.act_cfg,
                    }) as Box<dyn BatchExecutor>)
                }
                ServeBackend::Engine => {
                    eprintln!("[serve] worker: ready (fake-quant engine)");
                    Ok(Box::new(EngineExecutor {
                        model: q.model,
                        cfg: q.act_cfg,
                        max_batch: batch,
                    }) as Box<dyn BatchExecutor>)
                }
                ServeBackend::Qengine => {
                    let ex = QuantExecutor::from_quantized(&q, batch)?;
                    eprintln!(
                        "[serve] worker: int8 plan ready — {}",
                        ex.qmodel.summary()
                    );
                    Ok(Box::new(ex) as Box<dyn BatchExecutor>)
                }
            }
        },
    );

    let client = server.client();
    // warm-up: the first request pays executor construction (and PJRT
    // compilation on that backend); exclude it from the measured load
    client.infer(images[0].clone())?;
    server.reset_metrics();
    let mut rng = Rng::new(4242);
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        pending.push(client.submit(images[i % images.len()].clone())?);
        let gap = rng.exp(rate);
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.05)));
        }
    }
    for rx in pending {
        rx.recv()??;
    }
    Ok(server.shutdown())
}

/// Multi-tenant load over a directory of compiled `.dfqm` artifacts:
/// scan + load every model into a [`Registry`] (no python manifest, no
/// DFQ re-run — the plans boot straight off the artifact bytes), fire
/// `requests` Poisson arrivals round-robin across models on the int8
/// variant, and return per-`model/variant` metrics. Used by
/// `dfq serve --models dir/` and the serving bench.
pub fn run_registry_load(
    dir: &str,
    requests: usize,
    rate: f64,
    batch: usize,
) -> Result<Vec<(String, Snapshot)>> {
    let mut reg = Registry::new(ServeConfig {
        max_batch: batch,
        max_delay: Duration::from_millis(3),
        queue_depth: 4096,
    });
    let names = reg.scan_dir(dir)?;
    if names.is_empty() {
        bail!("no compiled .dfqm artifacts found in {dir}");
    }
    // load every model up front (lazy loading is for request-path use;
    // a load generator wants the boot cost out of the measured window)
    let mut inputs = Vec::with_capacity(names.len());
    let mut clients = Vec::with_capacity(names.len());
    let mut rng = Rng::new(4242);
    for name in &names {
        let info = reg.info(name)?;
        eprintln!("[serve] {name}: {} ({})", info.plan, info.source);
        let [c, h, w] = info.input_shape;
        let data: Vec<f32> = (0..c * h * w).map(|_| rng.f32()).collect();
        inputs.push(Tensor::new(&[1, c, h, w], data));
        clients.push(reg.client(name, registry::VARIANT_INT8)?);
    }
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        let k = i % names.len();
        pending.push(clients[k].submit(inputs[k].clone())?);
        let gap = rng.exp(rate);
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.05)));
        }
    }
    for rx in pending {
        rx.recv()??;
    }
    Ok(reg
        .shutdown()
        .into_iter()
        .map(|(model, variant, snap)| (format!("{model}/{variant}"), snap))
        .collect())
}
