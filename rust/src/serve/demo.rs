//! Serving demo / load generator: trace-driven arrivals (diurnal
//! sinusoid + burst windows over a Poisson base process, Zipf-skewed
//! model popularity, two-class SLO mix — all seeded) against the
//! batching server backed by the INT8 DFQ model on a selectable
//! backend — PJRT (production), the fake-quant f32 engine, or the
//! true-int8 [`QuantExecutor`] plan. Used by `dfq serve`, the
//! `serve_quantized` example and the serving bench.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
use crate::graph::io::Dataset;
use crate::graph::Model;
use crate::quant::QScheme;
use crate::runtime::{Manifest, Runtime};
use crate::serve::{
    registry, AdaptiveClient, AutoscalePolicy, BatchExecutor,
    EngineExecutor, PjrtExecutor, Priority, QuantExecutor, Registry,
    ServeConfig, Server, Snapshot, SubmitError,
};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Trace-driven arrival model. Time is *virtual* — the accumulated sum
/// of sampled inter-arrival gaps — so the whole trace (arrival times,
/// model choices, SLO classes) is a pure function of the seed and never
/// depends on wall-clock scheduling.
///
/// The instantaneous rate is a diurnal sinusoid over the base rate with
/// periodic burst windows multiplied on top:
/// `rate(t) = rate · (1 + amp · sin(2πt / period)) · (burst? mult : 1)`.
#[derive(Debug, Clone)]
pub struct LoadGen {
    /// Base arrival rate, req/s.
    pub rate: f64,
    /// Diurnal modulation amplitude in `[0, 1)` (0 = flat Poisson).
    pub diurnal_amp: f64,
    /// Diurnal period in virtual seconds (a 24 h cycle compressed to
    /// something a bench can sweep).
    pub diurnal_period: f64,
    /// Rate multiplier inside a burst window (1 = no bursts).
    pub burst_mult: f64,
    /// Virtual seconds between burst-window starts.
    pub burst_every: f64,
    /// Burst-window length, virtual seconds.
    pub burst_len: f64,
    /// Zipf popularity exponent across models: weight of the k-th model
    /// is `1/(k+1)^s`. 0 keeps the legacy deterministic round-robin.
    pub zipf_s: f64,
    /// Fraction of arrivals in the [`Priority::Interactive`] class.
    pub slo_mix: f64,
}

impl LoadGen {
    /// Plain Poisson arrivals, uniform round-robin, all-interactive —
    /// the legacy load shape.
    pub fn poisson(rate: f64) -> LoadGen {
        LoadGen {
            rate,
            diurnal_amp: 0.0,
            diurnal_period: 4.0,
            burst_mult: 1.0,
            burst_every: 2.0,
            burst_len: 0.25,
            zipf_s: 0.0,
            slo_mix: 1.0,
        }
    }

    /// Instantaneous arrival rate at virtual time `t`.
    pub fn rate_at(&self, t: f64) -> f64 {
        let mut r = self.rate;
        if self.diurnal_amp > 0.0 && self.diurnal_period > 0.0 {
            let phase = 2.0 * std::f64::consts::PI * t / self.diurnal_period;
            r *= 1.0 + self.diurnal_amp * phase.sin();
        }
        if self.burst_mult > 1.0
            && self.burst_every > 0.0
            && t.rem_euclid(self.burst_every) < self.burst_len
        {
            r *= self.burst_mult;
        }
        r.max(1e-9)
    }

    /// Sample the next inter-arrival gap at virtual time `t`
    /// (exponential at the instantaneous rate).
    pub fn next_gap(&self, rng: &mut Rng, t: f64) -> f64 {
        rng.exp(self.rate_at(t))
    }

    /// Sample the SLO class of one arrival.
    pub fn pick_class(&self, rng: &mut Rng) -> Priority {
        if rng.f64() < self.slo_mix {
            Priority::Interactive
        } else {
            Priority::Batch
        }
    }

    /// Cumulative Zipf popularity distribution over `n` models (index =
    /// popularity rank). Empty when `zipf_s == 0` — callers fall back
    /// to round-robin.
    pub fn zipf_cdf(&self, n: usize) -> Vec<f64> {
        if self.zipf_s <= 0.0 || n == 0 {
            return Vec::new();
        }
        let mut cdf: Vec<f64> = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(self.zipf_s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        cdf
    }

    /// Sample a model index: Zipf-skewed when `cdf` is non-empty,
    /// otherwise deterministic round-robin on the arrival index `i`.
    pub fn pick_model(
        &self,
        cdf: &[f64],
        rng: &mut Rng,
        i: usize,
        n: usize,
    ) -> usize {
        if cdf.is_empty() {
            return i % n.max(1);
        }
        let u = rng.f64();
        cdf.partition_point(|&c| c <= u).min(cdf.len() - 1)
    }
}

/// Which executor backs the serve worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServeBackend {
    /// AOT-compiled PJRT executable (production path).
    #[default]
    Pjrt,
    /// Pure-Rust fake-quant f32 engine (PJRT-free hosts / oracle).
    Engine,
    /// True-int8 planned executor ([`crate::nn::qengine`]).
    Qengine,
}

impl ServeBackend {
    pub fn parse(s: &str) -> Result<ServeBackend> {
        Ok(match s {
            "pjrt" => ServeBackend::Pjrt,
            "engine" => ServeBackend::Engine,
            "qengine" | "int8" => ServeBackend::Qengine,
            _ => bail!("unknown serve backend '{s}' (pjrt|engine|qengine)"),
        })
    }

    /// Backend from the `DFQ_BACKEND` env var; absent means PJRT, an
    /// unrecognised value falls back to PJRT *with a warning* (a typo
    /// must not silently benchmark the wrong engine).
    pub fn from_env() -> ServeBackend {
        match std::env::var("DFQ_BACKEND") {
            Ok(s) => ServeBackend::parse(&s).unwrap_or_else(|e| {
                eprintln!("[serve] {e:#}; defaulting to pjrt");
                ServeBackend::Pjrt
            }),
            Err(_) => ServeBackend::Pjrt,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ServeBackend::Pjrt => "pjrt",
            ServeBackend::Engine => "engine",
            ServeBackend::Qengine => "qengine",
        }
    }
}

/// How often the `--metrics-dump` writer refreshes its file, in
/// submitted requests. Coarse on purpose: the dump is a scrape surface,
/// not a trace.
const DUMP_EVERY: usize = 32;

/// Overwrite `path` with a fresh text exposition document (best-effort
/// during the run; the final write propagates errors from the caller).
fn dump_exposition(path: &Path, text: &str) {
    if let Err(e) = std::fs::write(path, text) {
        eprintln!("[serve] metrics dump to {} failed: {e}", path.display());
    }
}

/// Options for [`run_load`] / [`run_load_quiet`] (`dfq serve <arch>`).
#[derive(Debug, Clone)]
pub struct LoadOpts {
    pub requests: usize,
    /// Poisson base arrival rate, req/s.
    pub rate: f64,
    pub batch: usize,
    pub backend: ServeBackend,
    /// Seed of the arrival process and SLO-class draws.
    pub seed: u64,
    /// Worker lanes behind the server (`--lanes N`).
    pub lanes: usize,
    /// In-flight admission cap, 0 = unbounded (`--admission-cap N`).
    /// Over-cap submissions are shed (counted, not served).
    pub admission_cap: usize,
    /// Fraction of arrivals in the interactive SLO class
    /// (`--slo-mix F`, default 1.0 = all interactive).
    pub slo_mix: f64,
    /// Periodically overwrite this file with a Prometheus-style text
    /// exposition (`--metrics-dump FILE`).
    pub metrics_dump: Option<PathBuf>,
}

impl Default for LoadOpts {
    fn default() -> Self {
        LoadOpts {
            requests: 256,
            rate: 200.0,
            batch: 64,
            backend: ServeBackend::default(),
            seed: 4242,
            lanes: 1,
            admission_cap: 0,
            slo_mix: 1.0,
            metrics_dump: None,
        }
    }
}

/// Start a server for `arch`'s INT8-DFQ model on the configured backend
/// (built inside each worker lane), fire seeded arrivals, and report
/// latency/throughput. With a metrics dump path set, the file is
/// periodically overwritten with a Prometheus-style text exposition and
/// a one-line JSON summary prints at the end.
pub fn run_load(arch: &str, opts: &LoadOpts) -> Result<()> {
    let backend = opts.backend;
    let snapshot = run_load_quiet(arch, opts)?;
    println!("serve[{arch}/{}] {}", backend.as_str(), snapshot.report());
    Ok(())
}

/// Same as [`run_load`] but returns the metrics snapshot (bench use).
pub fn run_load_quiet(arch: &str, opts: &LoadOpts) -> Result<Snapshot> {
    let requests = opts.requests;
    let batch = opts.batch;
    let backend = opts.backend;
    let metrics_dump = opts.metrics_dump.as_deref();
    let manifest = Manifest::load(crate::artifacts_dir())?;
    let entry = manifest.arch(arch)?.clone();
    let arch_name = arch.to_string();
    eprintln!("[serve] loading dataset...");

    // requests are real test images, cycled
    let ds = Dataset::load(manifest.dataset(&entry.task, "test")?)?;
    let images: Vec<Tensor> =
        (0..64.min(ds.len())).map(|i| ds.batch(i, i + 1)).collect();

    let server = Server::start_sharded(
        ServeConfig {
            max_batch: batch,
            max_delay: Duration::from_millis(3),
            queue_depth: 4096,
            lanes_per_model: opts.lanes.max(1),
            admission_cap: opts.admission_cap,
            ..ServeConfig::default()
        },
        move || {
            // constructed on the worker thread: PJRT handles are !Send
            eprintln!("[serve] worker: loading model...");
            let manifest = Manifest::load(crate::artifacts_dir())?;
            let model =
                Model::load(manifest.path(&manifest.arch(&arch_name)?.model))?;
            eprintln!("[serve] worker: running DFQ...");
            let prep = quantize_data_free(&model, &DfqConfig::default())?;
            let q = prep.quantize(
                &QScheme::int8_asymmetric(),
                8,
                BiasCorrMode::Analytic,
                None,
            )?;
            match backend {
                ServeBackend::Pjrt => {
                    eprintln!("[serve] worker: creating PJRT client...");
                    let rt = Runtime::cpu()?;
                    eprintln!(
                        "[serve] worker: compiling executable (batch {batch})..."
                    );
                    let exec = rt.load_model_exec(
                        &manifest, &arch_name, batch, &q.model,
                    )?;
                    let weights = exec.bind_weights(&q.model)?;
                    eprintln!("[serve] worker: ready");
                    Ok(Box::new(PjrtExecutor {
                        exec,
                        weights,
                        cfg: q.act_cfg,
                    }) as Box<dyn BatchExecutor>)
                }
                ServeBackend::Engine => {
                    eprintln!("[serve] worker: ready (fake-quant engine)");
                    Ok(Box::new(EngineExecutor {
                        model: q.model,
                        cfg: q.act_cfg,
                        max_batch: batch,
                    }) as Box<dyn BatchExecutor>)
                }
                ServeBackend::Qengine => {
                    let ex = QuantExecutor::from_quantized(&q, batch)?;
                    eprintln!(
                        "[serve] worker: int8 plan ready — {}",
                        ex.qmodel.summary()
                    );
                    Ok(Box::new(ex) as Box<dyn BatchExecutor>)
                }
            }
        },
    );

    let client = server.client();
    // warm-up: the first request pays executor construction (and PJRT
    // compilation on that backend); exclude it from the measured load
    client.infer(images[0].clone())?;
    server.reset_metrics();
    let metrics = server.metrics_handle();
    let labels = [("model", arch), ("variant", backend.as_str())];
    let traffic = LoadGen {
        slo_mix: opts.slo_mix,
        ..LoadGen::poisson(opts.rate)
    };
    let mut rng = Rng::new(opts.seed);
    let mut t = 0.0;
    let mut shed = 0u64;
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        let prio = traffic.pick_class(&mut rng);
        match client.submit_prio(images[i % images.len()].clone(), prio) {
            Ok(rx) => pending.push(rx),
            Err(e)
                if matches!(
                    e.downcast_ref::<SubmitError>(),
                    Some(SubmitError::Shed { .. })
                ) =>
            {
                shed += 1;
            }
            Err(e) => return Err(e),
        }
        if let Some(path) = metrics_dump {
            if i % DUMP_EVERY == 0 {
                dump_exposition(path, &metrics.exposition(&labels));
            }
        }
        let gap = traffic.next_gap(&mut rng, t);
        t += gap;
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.05)));
        }
    }
    for rx in pending {
        rx.recv()??;
    }
    if shed > 0 {
        eprintln!("[serve] shed {shed}/{requests} over-cap submissions");
    }
    if let Some(path) = metrics_dump {
        std::fs::write(path, metrics.exposition(&labels))?;
        println!(
            "{}",
            metrics.json_line(&format!("serve/{arch}/{}", backend.as_str()))
        );
    }
    Ok(server.shutdown())
}

/// Options for [`run_registry_load`] (`dfq serve --models dir/`).
#[derive(Debug, Clone)]
pub struct RegistryLoadOpts {
    pub requests: usize,
    /// Poisson arrival rate, req/s.
    pub rate: f64,
    pub batch: usize,
    /// Resident-model cap (0 = unbounded): exceeding it evicts the
    /// least-recently-used model, which lazily re-loads on next use.
    pub max_resident: usize,
    /// Poll the artifact files during the run and hot-swap any model
    /// whose `.dfqm` changed on disk (`dfq serve --models dir/ --watch`).
    pub watch: bool,
    /// Load artifacts via [`crate::artifact::Artifact::open_mmap`]
    /// (zero-copy weight views over the page cache, the default);
    /// `dfq serve --models dir/ --no-mmap` clears it.
    pub mmap: bool,
    /// Seed of the Poisson arrival process and the probe inputs
    /// (`dfq serve ... --seed N`; a fixed default keeps runs
    /// reproducible).
    pub seed: u64,
    /// Periodically overwrite this file with a Prometheus-style text
    /// exposition covering every resident (model, variant) server
    /// (`dfq serve ... --metrics-dump FILE`).
    pub metrics_dump: Option<PathBuf>,
    /// Worker lanes per (model, variant) (`--lanes N`).
    pub lanes: usize,
    /// Per-model in-flight admission cap, 0 = unbounded
    /// (`--admission-cap N`). Over-cap submissions shed typed.
    pub admission_cap: usize,
    /// Fraction of arrivals in the interactive SLO class
    /// (`--slo-mix F`, default 1.0 = all interactive).
    pub slo_mix: f64,
    /// Zipf popularity exponent across models (`--zipf S`; 0 keeps the
    /// legacy round-robin).
    pub zipf_s: f64,
    /// Diurnal rate-modulation amplitude in `[0, 1)`
    /// (`--diurnal-amp F`; 0 = flat Poisson).
    pub diurnal_amp: f64,
    /// Burst-window rate multiplier (`--burst-mult F`; 1 = no bursts).
    pub burst_mult: f64,
}

impl Default for RegistryLoadOpts {
    fn default() -> Self {
        RegistryLoadOpts {
            requests: 256,
            rate: 200.0,
            batch: 64,
            max_resident: 0,
            watch: false,
            mmap: true,
            seed: 4242,
            metrics_dump: None,
            lanes: 1,
            admission_cap: 0,
            slo_mix: 1.0,
            zipf_s: 0.0,
            diurnal_amp: 0.0,
            burst_mult: 1.0,
        }
    }
}

/// Multi-tenant load over a directory of compiled `.dfqm` artifacts:
/// scan + load every model into a [`Registry`] (no python manifest, no
/// DFQ re-run — the plans boot straight off the artifact bytes), fire
/// trace-driven arrivals (see [`LoadGen`]) across models on the int8
/// variant, and return per-`model/variant` metrics (one entry per
/// server generation when hot swaps or evictions happened). Used by
/// `dfq serve --models dir/` and the serving bench.
pub fn run_registry_load(
    dir: &str,
    opts: RegistryLoadOpts,
) -> Result<Vec<(String, Snapshot)>> {
    let RegistryLoadOpts {
        requests,
        rate,
        batch,
        max_resident,
        watch,
        mmap,
        seed,
        metrics_dump,
        lanes,
        admission_cap,
        slo_mix,
        zipf_s,
        diurnal_amp,
        burst_mult,
    } = opts;
    let traffic = LoadGen {
        diurnal_amp,
        burst_mult,
        zipf_s,
        slo_mix,
        ..LoadGen::poisson(rate)
    };
    let mut reg = Registry::new(ServeConfig {
        max_batch: batch,
        max_delay: Duration::from_millis(3),
        queue_depth: 4096,
        max_resident,
        mmap,
        lanes_per_model: lanes.max(1),
        admission_cap,
        ..ServeConfig::default()
    });
    let names = reg.scan_dir(dir)?;
    if names.is_empty() {
        bail!("no compiled .dfqm artifacts found in {dir}");
    }
    // probe every model once for its input shape (under a resident cap
    // this also exercises evict → lazy re-load before the measured load)
    let mut inputs = Vec::with_capacity(names.len());
    let mut rng = Rng::new(seed);
    for name in &names {
        let info = reg.info(name)?;
        eprintln!("[serve] {name}: {} ({})", info.plan, info.source);
        let [c, h, w] = info.input_shape;
        let data: Vec<f32> = (0..c * h * w).map(|_| rng.f32()).collect();
        inputs.push(Tensor::new(&[1, c, h, w], data));
    }
    let mut pending = Vec::with_capacity(requests);
    let cdf = traffic.zipf_cdf(names.len());
    let mut t = 0.0;
    let mut shed = 0u64;
    // dir-stamp debounce lets the watch tick run 4x as often as the old
    // per-file poll for less stat traffic on quiet zoos: a quiet tick is
    // one stat per artifact *directory*, not per artifact
    let mut watch_db = crate::serve::WatchDebounce::new();
    for i in 0..requests {
        if watch && i > 0 && i % 16 == 0 {
            for (name, r) in reg.poll_files_debounced(&mut watch_db) {
                match r {
                    Ok(()) => eprintln!("[serve] hot-swapped '{name}'"),
                    Err(e) => eprintln!(
                        "[serve] swap of '{name}' failed (old model keeps \
                         serving): {e:#}"
                    ),
                }
            }
        }
        let k = traffic.pick_model(&cdf, &mut rng, i, names.len());
        let prio = traffic.pick_class(&mut rng);
        // route through the registry each time: under a resident cap
        // this is what re-loads evicted models lazily
        let client = reg.live_client(&names[k], registry::VARIANT_INT8)?;
        match client.submit_prio(inputs[k].clone(), prio) {
            Ok(rx) => pending.push(rx),
            Err(e)
                if matches!(
                    e.downcast_ref::<SubmitError>(),
                    Some(SubmitError::Shed { .. })
                ) =>
            {
                shed += 1;
            }
            Err(e) => return Err(e),
        }
        if let Some(path) = &metrics_dump {
            if i % DUMP_EVERY == 0 {
                dump_exposition(path, &reg.exposition());
            }
        }
        let gap = traffic.next_gap(&mut rng, t);
        t += gap;
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.05)));
        }
    }
    for rx in pending {
        rx.recv()??;
    }
    if shed > 0 {
        eprintln!("[serve] shed {shed}/{requests} over-cap submissions");
    }
    if let Some(path) = &metrics_dump {
        std::fs::write(path, reg.exposition())?;
    }
    Ok(reg
        .shutdown()
        .into_iter()
        .map(|(model, variant, snap)| (format!("{model}/{variant}"), snap))
        .collect())
}

/// Drive Poisson arrivals through an [`AdaptiveClient`], with a burst
/// of `burst` back-to-back submissions injected at the halfway point to
/// build queue depth (the shed trigger). Waits for every response;
/// returns how many requests failed (0 on a healthy run).
pub fn drive_adaptive(
    client: &AdaptiveClient,
    inputs: &[Tensor],
    requests: usize,
    rate: f64,
    burst: usize,
    seed: u64,
) -> Result<u64> {
    let mut rng = Rng::new(seed);
    let mut pending = Vec::with_capacity(requests + burst);
    for i in 0..requests {
        pending.push(client.submit(inputs[i % inputs.len()].clone())?);
        if i == requests / 2 {
            for j in 0..burst {
                pending
                    .push(client.submit(inputs[j % inputs.len()].clone())?);
            }
        }
        let gap = rng.exp(rate);
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap.min(0.05)));
        }
    }
    let mut failed = 0u64;
    for rx in pending {
        match rx.recv() {
            Ok(Ok(_)) => {}
            _ => failed += 1,
        }
    }
    Ok(failed)
}

/// `dfq serve <arch> --autoscale`: host the f32 oracle and the int8
/// plan of one DFQ-quantised model behind an [`AdaptiveClient`] and
/// fire Poisson load (plus a mid-run burst) so the autoscaler steers
/// between them; prints the routing split, the transition trace and a
/// JSON record.
pub fn run_adaptive_load(
    arch: &str,
    requests: usize,
    rate: f64,
    batch: usize,
    seed: u64,
) -> Result<()> {
    let manifest = Manifest::load(crate::artifacts_dir())?;
    let entry = manifest.arch(arch)?.clone();
    let ds = Dataset::load(manifest.dataset(&entry.task, "test")?)?;
    let images: Vec<Tensor> =
        (0..64.min(ds.len())).map(|i| ds.batch(i, i + 1)).collect();
    let model = Model::load(manifest.path(&entry.model))?;
    let prep = quantize_data_free(&model, &DfqConfig::default())?;
    let q = prep.quantize(
        &QScheme::int8_asymmetric(),
        8,
        BiasCorrMode::Analytic,
        None,
    )?;
    let mut reg = Registry::new(ServeConfig {
        max_batch: batch,
        max_delay: Duration::from_millis(3),
        queue_depth: 4096,
        autoscale: Some(AutoscalePolicy::default()),
        ..ServeConfig::default()
    });
    reg.register_quantized(arch, q)?;
    let client = reg.adaptive_client(arch)?;
    let burst = requests.min(128);
    let failed =
        drive_adaptive(&client, &images, requests, rate, burst, seed)?;
    let report = client.report();
    println!("autoscale[{arch}] {}", report.summary_line());
    for t in &report.transitions {
        println!("  {}", t.describe());
    }
    println!("{}", report.json(&format!("serve/{arch}/autoscale")));
    for (model, variant, snap) in reg.shutdown() {
        println!("serve[{model}/{variant}] {}", snap.report());
    }
    if failed > 0 {
        bail!("{failed} request(s) failed under adaptive routing");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_gen_is_deterministic_and_diurnal() {
        let traffic =
            LoadGen { diurnal_amp: 0.5, ..LoadGen::poisson(100.0) };
        // sinusoid peaks a quarter-period in, troughs at three quarters
        let peak = traffic.rate_at(traffic.diurnal_period * 0.25);
        let trough = traffic.rate_at(traffic.diurnal_period * 0.75);
        assert!((140.0..160.0).contains(&peak), "peak {peak}");
        assert!((40.0..60.0).contains(&trough), "trough {trough}");
        // same seed -> identical trace; different seed -> different one
        let gaps = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut t = 0.0;
            (0..64)
                .map(|_| {
                    let g = traffic.next_gap(&mut rng, t);
                    t += g;
                    g
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(gaps(7), gaps(7));
        assert_ne!(gaps(7), gaps(8));
    }

    #[test]
    fn load_gen_bursts_multiply_the_rate() {
        let traffic = LoadGen {
            burst_mult: 4.0,
            burst_every: 2.0,
            burst_len: 0.25,
            ..LoadGen::poisson(50.0)
        };
        assert_eq!(traffic.rate_at(0.1), 200.0); // inside the window
        assert_eq!(traffic.rate_at(1.0), 50.0); // between windows
        assert_eq!(traffic.rate_at(2.1), 200.0); // next window
    }

    #[test]
    fn load_gen_zipf_skews_popularity_and_mix_splits_classes() {
        let traffic = LoadGen {
            zipf_s: 1.2,
            slo_mix: 0.75,
            ..LoadGen::poisson(100.0)
        };
        let cdf = traffic.zipf_cdf(4);
        assert_eq!(cdf.len(), 4);
        assert!((cdf[3] - 1.0).abs() < 1e-12, "cdf must end at 1");
        let mut rng = Rng::new(11);
        let mut counts = [0usize; 4];
        let mut interactive = 0usize;
        for i in 0..4000 {
            counts[traffic.pick_model(&cdf, &mut rng, i, 4)] += 1;
            if traffic.pick_class(&mut rng) == Priority::Interactive {
                interactive += 1;
            }
        }
        assert!(
            counts[0] > counts[1] && counts[1] > counts[3],
            "no Zipf skew: {counts:?}"
        );
        let frac = interactive as f64 / 4000.0;
        assert!((0.70..0.80).contains(&frac), "slo mix off: {frac}");
        // zipf_s == 0 keeps the legacy deterministic round-robin
        let rr = LoadGen::poisson(1.0);
        assert!(rr.zipf_cdf(4).is_empty());
        assert_eq!(rr.pick_model(&[], &mut rng, 6, 4), 2);
    }
}
