//! Serving coordinator — the L3 deployment surface.
//!
//! A request router over model variants, each backed by a worker thread
//! that dynamically batches requests (see [`batcher`]) and executes them
//! on a [`BatchExecutor`] — either the PJRT executable (production) or
//! the pure-Rust engine (tests / PJRT-free hosts). Executors are
//! constructed *inside* their worker thread via a factory closure, so
//! non-`Send` PJRT handles never cross threads. For hosting many models
//! at once from compiled `.dfqm` artifacts, see [`registry`] (the
//! `dfq serve --models dir/` surface) and `src/serve/README.md`.
//!
//! Two adaptive layers sit on top:
//!
//! * [`autoscale`] — a metrics-driven policy that steers one model's
//!   traffic between its `f32` oracle and `int8` variants (shed to int8
//!   when p95 latency or queue depth crosses a threshold, recover with
//!   hysteresis; `dfq serve <arch> --autoscale`);
//! * registry lifecycle — hot reload of a changed `.dfqm` behind a
//!   [`registry::LiveClient`] without dropping in-flight requests, and
//!   LRU eviction of idle models under
//!   [`ServeConfig::max_resident`] with lazy re-load.

pub mod admission;
pub mod autoscale;
pub mod batcher;
pub mod demo;
pub mod metrics;
pub mod registry;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::graph::Model;
use crate::nn::{self, QuantCfg};
use crate::tensor::Tensor;

use batcher::WeightedBacklog;

pub use admission::{AdmissionPermit, AdmissionQueue, SubmitError};
pub use autoscale::{
    AdaptiveClient, AdaptiveReport, AutoscalePolicy, Autoscaler,
};
pub use batcher::Priority;
pub use metrics::{Metrics, Snapshot, WindowCursor};
pub use registry::{LiveClient, ModelInfo, Registry, WatchDebounce};

/// Anything that can run a padded batch of images.
pub trait BatchExecutor {
    /// Largest batch the executor accepts.
    fn max_batch(&self) -> usize;
    /// Run (n, C, H, W) with n <= max_batch; returns the primary output
    /// with leading dimension n.
    fn run_batch(&mut self, x: &Tensor) -> Result<Tensor>;
}

/// Reference-engine executor (Send; usable anywhere).
pub struct EngineExecutor {
    pub model: Model,
    pub cfg: QuantCfg,
    pub max_batch: usize,
}

impl BatchExecutor for EngineExecutor {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn run_batch(&mut self, x: &Tensor) -> Result<Tensor> {
        nn::forward(&self.model, x, &self.cfg)?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("model produced no outputs"))
    }
}

/// True-int8 executor over the packed integer engine
/// ([`crate::nn::qengine`]): quantises each incoming batch onto the
/// input grid, runs u8×i8 GEMM convs with fused requant epilogues, and
/// dequantises the primary output. Send like [`EngineExecutor`], so the
/// router can host an f32-oracle variant and an int8 variant side by
/// side (see [`Router`]).
pub struct QuantExecutor {
    pub qmodel: crate::nn::qengine::QModel,
    pub max_batch: usize,
}

impl QuantExecutor {
    /// Build from a DFQ-quantised model (weights quantised at ≤ 8 bits,
    /// activations quantised — see
    /// [`crate::dfq::QuantizedModel::pack_int8`]).
    pub fn from_quantized(
        q: &crate::dfq::QuantizedModel,
        max_batch: usize,
    ) -> Result<QuantExecutor> {
        Ok(QuantExecutor { qmodel: q.pack_int8()?, max_batch })
    }

    /// Like [`QuantExecutor::from_quantized`] but refuses any plan that
    /// still contains an f32 fallback op (`PlanOpts { int8_only: true, ..Default::default() }`)
    /// — deployments promising pure 8-bit inference get an error, not a
    /// silent partial fallback.
    pub fn from_quantized_strict(
        q: &crate::dfq::QuantizedModel,
        max_batch: usize,
    ) -> Result<QuantExecutor> {
        let opts = crate::nn::qengine::PlanOpts { int8_only: true, ..Default::default() };
        Ok(QuantExecutor { qmodel: q.pack_int8_opts(opts)?, max_batch })
    }

    /// Boot straight from a `.dfqm` compiled artifact — decodes the
    /// stored plan ([`crate::artifact`]) instead of re-running the DFQ
    /// pipeline; no manifest, no float math.
    pub fn from_artifact(
        path: impl AsRef<std::path::Path>,
        max_batch: usize,
    ) -> Result<QuantExecutor> {
        Ok(QuantExecutor {
            qmodel: crate::nn::qengine::QModel::from_artifact(path)?,
            max_batch,
        })
    }
}

impl BatchExecutor for QuantExecutor {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn run_batch(&mut self, x: &Tensor) -> Result<Tensor> {
        self.qmodel.run(x)
    }
}

/// PJRT-backed executor holding the compiled executable + bound weights.
/// Construct it inside the worker thread (see [`Server::start`]).
pub struct PjrtExecutor {
    pub exec: crate::runtime::Executable,
    pub weights: crate::runtime::BoundWeights,
    pub cfg: QuantCfg,
}

impl BatchExecutor for PjrtExecutor {
    fn max_batch(&self) -> usize {
        self.exec.meta.batch
    }

    fn run_batch(&mut self, x: &Tensor) -> Result<Tensor> {
        let b = self.exec.meta.batch;
        let n = x.shape()[0];
        let input = if n == b { x.clone() } else { pad(x, b) };
        let out = self
            .exec
            .run(&input, &self.weights, &self.cfg)?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("executable produced no outputs"))?;
        Ok(if n == b { out } else { truncate(&out, n) })
    }
}

struct Request {
    x: Tensor, // (1, C, H, W)
    resp: Sender<Result<Tensor>>,
    enqueued: Instant,
    /// SLO class: the per-lane [`WeightedBacklog`] drains interactive
    /// work first (starvation-bounded), and latency is recorded per
    /// class.
    prio: Priority,
    /// The admission slot this request holds; released on drop, so any
    /// exit path (answered, failed, drained) frees it.
    permit: Option<AdmissionPermit>,
}

/// Queue message: a job, or an explicit stop. The stop sentinel (rather
/// than sender-disconnect) ends the worker even while `Client` clones
/// are still alive -- dropping only the server's sender would leave the
/// worker parked in `recv` forever.
enum Msg {
    Job(Request),
    Stop,
}

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    pub max_batch: usize,
    pub max_delay: Duration,
    pub queue_depth: usize,
    /// Steering policy for [`Registry::adaptive_client`] /
    /// [`AdaptiveClient`]; `None` falls back to the default
    /// [`AutoscalePolicy`].
    pub autoscale: Option<AutoscalePolicy>,
    /// Registry resident-model cap: loading a model beyond this evicts
    /// the least-recently-used resident one (gracefully — its queue
    /// drains first). `0` means unbounded.
    pub max_resident: usize,
    /// Registry artifact loads go through [`crate::artifact::Artifact::open_mmap`]
    /// (zero-copy weight views over a shared read-only mapping; the
    /// page cache backs every resident model) instead of reading the
    /// file into memory. On by default; `dfq serve --models DIR
    /// --no-mmap` or `DFQ_NO_MMAP=1` turn it off.
    pub mmap: bool,
    /// Worker lanes per (model, variant) server started through
    /// [`Server::start_sharded`] — each lane is its own queue + worker
    /// thread + executor instance, and submissions least-loaded-balance
    /// across them. `dfq serve --lanes N`. [`Server::start`] (single
    /// executor factory) always runs one lane.
    pub lanes_per_model: usize,
    /// Admission cap: maximum in-flight requests per model before
    /// submissions are rejected with [`SubmitError::Shed`] instead of
    /// queueing. `0` means unbounded. `dfq serve --admission-cap N`.
    pub admission_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            queue_depth: 1024,
            autoscale: None,
            max_resident: 0,
            mmap: true,
            lanes_per_model: 1,
            admission_cap: 0,
        }
    }
}

/// One lane of a server: its queue sender, a lock-free count of
/// requests submitted but not yet scheduled (the balancer's load
/// signal), and the lane-local metrics view.
struct LaneHandle {
    tx: SyncSender<Msg>,
    queued: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
}

/// What a lane worker records into: the shared per-variant [`Metrics`]
/// (exposition / windows / autoscaler — identical semantics to the
/// single-lane world) plus its lane-local view, and the lane's queued
/// counter. With one lane the two metrics handles alias and are
/// recorded once.
struct LaneCtx {
    shared: Arc<Metrics>,
    lane: Arc<Metrics>,
    queued: Arc<AtomicU64>,
}

impl LaneCtx {
    /// `n` requests left the waiting set (scheduled for execution).
    fn dequeued(&self, n: u64) {
        self.queued.fetch_sub(n, Ordering::AcqRel);
        self.shared.dequeued(n);
    }

    fn record(&self, batch: usize, lats: &[(f64, Priority)]) {
        self.shared.record_batch_classed(batch, lats);
        if !Arc::ptr_eq(&self.shared, &self.lane) {
            self.lane.record_batch_classed(batch, lats);
        }
    }
}

/// One model-variant server: N worker lanes, each a request queue +
/// worker thread + executor instance, behind one admission queue and
/// one shared per-variant [`Metrics`].
pub struct Server {
    lanes: Arc<Vec<LaneHandle>>,
    rr: Arc<AtomicUsize>,
    metrics: Arc<Metrics>,
    admission: Arc<AdmissionQueue>,
    workers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Spawn a single-lane server. `factory` builds the executor on the
    /// worker thread (PJRT handles are not `Send`). One executor means
    /// one lane regardless of [`ServeConfig::lanes_per_model`]; use
    /// [`Server::start_sharded`] for sharded ingress.
    pub fn start<F>(cfg: ServeConfig, factory: F) -> Server
    where
        F: FnOnce() -> Result<Box<dyn BatchExecutor>> + Send + 'static,
    {
        let once = Mutex::new(Some(factory));
        Server::start_lanes(cfg, 1, None, move || {
            let f = once
                .lock()
                .unwrap()
                .take()
                .expect("single-lane factory called once");
            f()
        })
    }

    /// Spawn [`ServeConfig::lanes_per_model`] worker lanes, calling
    /// `factory` once per lane (each lane owns its executor instance).
    /// Submissions least-loaded-balance across lanes; per-lane metrics
    /// additionally merge into the shared per-variant view.
    pub fn start_sharded<F>(cfg: ServeConfig, factory: F) -> Server
    where
        F: Fn() -> Result<Box<dyn BatchExecutor>> + Send + Sync + 'static,
    {
        let n = cfg.lanes_per_model.max(1);
        Server::start_lanes(cfg, n, None, factory)
    }

    /// Like [`Server::start_sharded`] but sharing an externally-owned
    /// [`AdmissionQueue`] — the registry passes one queue per *model*
    /// so its cap spans all variants.
    pub fn start_sharded_shared<F>(
        cfg: ServeConfig,
        admission: Arc<AdmissionQueue>,
        factory: F,
    ) -> Server
    where
        F: Fn() -> Result<Box<dyn BatchExecutor>> + Send + Sync + 'static,
    {
        let n = cfg.lanes_per_model.max(1);
        Server::start_lanes(cfg, n, Some(admission), factory)
    }

    fn start_lanes<F>(
        cfg: ServeConfig,
        n: usize,
        admission: Option<Arc<AdmissionQueue>>,
        factory: F,
    ) -> Server
    where
        F: Fn() -> Result<Box<dyn BatchExecutor>> + Send + Sync + 'static,
    {
        let factory = Arc::new(factory);
        let metrics = Arc::new(Metrics::default());
        let admission = admission.unwrap_or_else(|| {
            Arc::new(AdmissionQueue::new(cfg.admission_cap))
        });
        let mut lanes = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        for lane_id in 0..n {
            let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.queue_depth);
            // one lane: the lane view IS the shared view (no double
            // recording); sharded: separate histograms, merged at
            // record time
            let lane_metrics = if n == 1 {
                metrics.clone()
            } else {
                Arc::new(Metrics::default())
            };
            let ctx = LaneCtx {
                shared: metrics.clone(),
                lane: lane_metrics.clone(),
                queued: Arc::new(AtomicU64::new(0)),
            };
            let queued = ctx.queued.clone();
            let f = factory.clone();
            let worker = std::thread::spawn(move || {
                let mut exec = match f() {
                    Ok(e) => e,
                    Err(e) => {
                        crate::obs::trace::emit_with(
                            crate::obs::Severity::Error,
                            "serve",
                            || {
                                (
                                    "executor construction failed".into(),
                                    vec![
                                        ("lane", lane_id.to_string()),
                                        ("error", format!("{e:#}")),
                                    ],
                                )
                            },
                        );
                        // fail every request with the construction error
                        drain_with_error(rx, e, &ctx);
                        return;
                    }
                };
                crate::obs::trace::emit_with(
                    crate::obs::Severity::Debug,
                    "serve",
                    || {
                        (
                            "worker up".into(),
                            vec![
                                ("lane", lane_id.to_string()),
                                (
                                    "max_batch",
                                    exec.max_batch().to_string(),
                                ),
                            ],
                        )
                    },
                );
                worker_loop(rx, cfg, exec.as_mut(), &ctx);
            });
            lanes.push(LaneHandle { tx, queued, metrics: lane_metrics });
            workers.push(worker);
        }
        Server {
            lanes: Arc::new(lanes),
            rr: Arc::new(AtomicUsize::new(0)),
            metrics,
            admission,
            workers,
        }
    }

    /// A cheap cloneable submission handle.
    pub fn client(&self) -> Client {
        Client {
            lanes: self.lanes.clone(),
            rr: self.rr.clone(),
            metrics: self.metrics.clone(),
            admission: self.admission.clone(),
        }
    }

    pub fn metrics(&self) -> Snapshot {
        self.metrics.snapshot()
    }

    /// Shared handle to this server's live metrics (autoscaler input).
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Per-lane metrics views, in lane order. With one lane this is the
    /// same handle as [`Server::metrics_handle`]; sharded lanes each
    /// record their own slice of the traffic (summing to the shared
    /// view).
    pub fn lane_metrics(&self) -> Vec<Arc<Metrics>> {
        self.lanes.iter().map(|l| l.metrics.clone()).collect()
    }

    /// Number of worker lanes.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// This server's admission queue (shared across lanes; possibly
    /// across variants when started via
    /// [`Server::start_sharded_shared`]).
    pub fn admission_handle(&self) -> Arc<AdmissionQueue> {
        self.admission.clone()
    }

    /// Clear recorded metrics (use after warm-up traffic).
    pub fn reset_metrics(&self) {
        self.metrics.reset();
        for l in self.lanes.iter() {
            if !Arc::ptr_eq(&l.metrics, &self.metrics) {
                l.metrics.reset();
            }
        }
    }

    /// Send the stop sentinel to every lane without joining — phase one
    /// of a concurrent drain ([`Router::shutdown`] signals *all* its
    /// servers before joining any, so retired lanes drain in parallel).
    pub fn signal_stop(&self) {
        for lane in self.lanes.iter() {
            if lane.tx.try_send(Msg::Stop).is_err() {
                // queue full: block until the draining worker frees a
                // slot; a dead worker makes this fail, which is fine —
                // it needs no sentinel
                let _ = lane.tx.send(Msg::Stop);
            }
        }
    }

    /// Stop every lane (queued jobs are still served) and join the
    /// workers. Live `Client` handles error out afterwards.
    pub fn shutdown(mut self) -> Snapshot {
        self.signal_stop();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let snap = self.metrics.snapshot();
        crate::obs::trace::emit_with(
            crate::obs::Severity::Debug,
            "serve",
            || {
                (
                    "drain".into(),
                    vec![("completed", snap.completed.to_string())],
                )
            },
        );
        snap
    }
}

fn drain_with_error(rx: Receiver<Msg>, e: anyhow::Error, ctx: &LaneCtx) {
    let msg = format!("executor construction failed: {e:#}");
    let fail = |req: Request| {
        ctx.dequeued(1);
        let _ = req.resp.send(Err(anyhow!("{msg}")));
    };
    while let Ok(m) = rx.recv() {
        match m {
            Msg::Job(req) => fail(req),
            Msg::Stop => break,
        }
    }
    // jobs can race in behind the Stop sentinel; answer what is already
    // buffered instead of letting it vanish with the channel
    while let Ok(m) = rx.try_recv() {
        if let Msg::Job(req) = m {
            fail(req);
        }
    }
}

fn worker_loop(
    rx: Receiver<Msg>,
    cfg: ServeConfig,
    exec: &mut dyn BatchExecutor,
    ctx: &LaneCtx,
) {
    let policy = batcher::Batcher {
        max_batch: cfg.max_batch.min(exec.max_batch()),
        max_delay: cfg.max_delay,
    };
    let mut backlog: WeightedBacklog<Request> =
        WeightedBacklog::new(batcher::DEFAULT_STARVATION_LIMIT);
    loop {
        let mut stop = false;
        if backlog.is_empty() {
            // block like the plain batcher: first arrival, then fill
            // until max_batch or the delay deadline
            match policy.next_batch(&rx) {
                Some(msgs) => {
                    for m in msgs {
                        match m {
                            Msg::Job(r) => backlog.push(r.prio, r),
                            Msg::Stop => stop = true,
                        }
                    }
                }
                None => break, // channel closed and nothing queued
            }
        } else {
            // backlog pending: top up without blocking so buffered
            // arrivals join this scheduling round
            while let Ok(m) = rx.try_recv() {
                match m {
                    Msg::Job(r) => backlog.push(r.prio, r),
                    Msg::Stop => stop = true,
                }
            }
        }
        if stop {
            // a submission racing a shutdown/hot-swap can land behind
            // the Stop sentinel while the channel is still open. Serve
            // what is already buffered so it drains rather than
            // vanishing. The race is then fully covered client-side: a
            // send after the channel closes fails at `submit` (the
            // registry's `LiveClient` retries it on the replacement
            // generation), and a send that slips into the buffer in the
            // instant before close dies with its response channel —
            // which the caller observes as a recv error, and
            // `LiveClient::infer` resubmits (an unanswered request was
            // never executed).
            while let Ok(m) = rx.try_recv() {
                if let Msg::Job(r) = m {
                    backlog.push(r.prio, r);
                }
            }
            while !backlog.is_empty() {
                run_scheduled(&mut backlog, policy.max_batch, exec, ctx);
            }
            break;
        }
        run_scheduled(&mut backlog, policy.max_batch, exec, ctx);
    }
}

/// Take one scheduled batch off the backlog (interactive first,
/// starvation-bounded) and execute it.
fn run_scheduled(
    backlog: &mut WeightedBacklog<Request>,
    max_batch: usize,
    exec: &mut dyn BatchExecutor,
    ctx: &LaneCtx,
) {
    let batch: Vec<Request> =
        backlog.take(max_batch).into_iter().map(|(_, r)| r).collect();
    if batch.is_empty() {
        return;
    }
    // the batch is scheduled: the depth gauge drops *before* execution
    // so the autoscaler sees waiting work, not in-flight work
    ctx.dequeued(batch.len() as u64);
    serve_batch(batch, exec, ctx);
}

/// Execute one assembled batch and reply to every request in it.
fn serve_batch(
    batch: Vec<Request>,
    exec: &mut dyn BatchExecutor,
    ctx: &LaneCtx,
) {
    let n = batch.len();
    let x = stack(&batch);
    let result = exec.run_batch(&x);
    let done = Instant::now();
    match result {
        Ok(out) => {
            let per: usize = out.shape()[1..].iter().product();
            let mut shape: Vec<usize> = out.shape().to_vec();
            shape[0] = 1;
            // record *before* replying so a client that resets
            // metrics right after its response cannot race the
            // bookkeeping of its own batch
            let lats: Vec<(f64, Priority)> = batch
                .iter()
                .map(|r| ((done - r.enqueued).as_secs_f64(), r.prio))
                .collect();
            ctx.record(n, &lats);
            for (i, req) in batch.into_iter().enumerate() {
                let one = Tensor::new(
                    &shape,
                    out.data()[i * per..(i + 1) * per].to_vec(),
                );
                let _ = req.resp.send(Ok(one));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for req in batch {
                let _ = req.resp.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

fn stack(reqs: &[Request]) -> Tensor {
    let mut shape = reqs[0].x.shape().to_vec();
    shape[0] = reqs.len();
    let mut data = Vec::with_capacity(shape.iter().product());
    for r in reqs {
        data.extend_from_slice(r.x.data());
    }
    Tensor::new(&shape, data)
}

fn pad(x: &Tensor, batch: usize) -> Tensor {
    let mut shape = x.shape().to_vec();
    let per: usize = shape[1..].iter().product();
    let n = shape[0];
    shape[0] = batch;
    let mut data = vec![0f32; batch * per];
    data[..n * per].copy_from_slice(x.data());
    Tensor::new(&shape, data)
}

fn truncate(x: &Tensor, n: usize) -> Tensor {
    let mut shape = x.shape().to_vec();
    let per: usize = shape[1..].iter().product();
    shape[0] = n;
    Tensor::new(&shape, x.data()[..n * per].to_vec())
}

/// Why a `try_submit` did not enqueue: the server is gone (tensor
/// handed back so a newer route can retry without cloning), or the
/// admission cap shed the request (no retry — that's the point).
pub(crate) enum TrySubmitErr {
    Closed(Tensor),
    Shed { in_flight: u64, cap: u64 },
}

/// Submission handle for one server.
#[derive(Clone)]
pub struct Client {
    lanes: Arc<Vec<LaneHandle>>,
    rr: Arc<AtomicUsize>,
    /// Same handle the server records into — submissions bump the live
    /// queue-depth gauge so the autoscaler sees backlog as it forms.
    metrics: Arc<Metrics>,
    admission: Arc<AdmissionQueue>,
}

impl Client {
    /// Submit one image (1, C, H, W) as interactive-class; returns a
    /// receiver for the result.
    pub fn submit(&self, x: Tensor) -> Result<Receiver<Result<Tensor>>> {
        self.submit_prio(x, Priority::Interactive)
    }

    /// Submit one image with an explicit SLO class. An over-cap
    /// submission fails immediately with a typed
    /// [`SubmitError::Shed`] in the error chain (downcastable) instead
    /// of queueing.
    pub fn submit_prio(
        &self,
        x: Tensor,
        prio: Priority,
    ) -> Result<Receiver<Result<Tensor>>> {
        self.try_submit_prio(x, prio).map_err(|e| match e {
            TrySubmitErr::Closed(_) => SubmitError::Closed.into(),
            TrySubmitErr::Shed { in_flight, cap } => {
                SubmitError::Shed { in_flight, cap }.into()
            }
        })
    }

    /// Least-loaded lane, scanning from a rotating start so ties (the
    /// idle steady state) round-robin instead of pinning lane 0.
    fn pick_lane(&self) -> &LaneHandle {
        let lanes = &*self.lanes;
        if lanes.len() == 1 {
            return &lanes[0];
        }
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % lanes.len();
        let mut best = start;
        let mut best_q = lanes[start].queued.load(Ordering::Relaxed);
        for k in 1..lanes.len() {
            let i = (start + k) % lanes.len();
            let q = lanes[i].queued.load(Ordering::Relaxed);
            if q < best_q {
                best = i;
                best_q = q;
            }
        }
        &lanes[best]
    }

    /// Like [`Client::submit_prio`] but hands the tensor back when this
    /// server is gone, so a caller holding a newer route (the registry's
    /// hot-swap [`LiveClient`]) can retry without cloning the input.
    /// A shed is *not* retryable — the admission queue spans server
    /// generations of the same model.
    pub(crate) fn try_submit_prio(
        &self,
        x: Tensor,
        prio: Priority,
    ) -> std::result::Result<Receiver<Result<Tensor>>, TrySubmitErr> {
        let permit = match self.admission.try_admit() {
            Ok(p) => Some(p),
            Err(in_flight) => {
                let cap = self.admission.cap();
                self.metrics.shed_one();
                crate::obs::trace::emit_with(
                    crate::obs::Severity::Warn,
                    "serve",
                    || {
                        (
                            "shed".into(),
                            vec![
                                ("in_flight", in_flight.to_string()),
                                ("cap", cap.to_string()),
                                ("class", prio.as_str().to_string()),
                            ],
                        )
                    },
                );
                return Err(TrySubmitErr::Shed { in_flight, cap });
            }
        };
        self.metrics.accepted_one();
        let (rtx, rrx) = mpsc::channel();
        let lane = self.pick_lane();
        self.metrics.enqueued();
        lane.queued.fetch_add(1, Ordering::AcqRel);
        match lane.tx.send(Msg::Job(Request {
            x,
            resp: rtx,
            enqueued: Instant::now(),
            prio,
            permit,
        })) {
            Ok(()) => Ok(rrx),
            Err(mpsc::SendError(Msg::Job(req))) => {
                lane.queued.fetch_sub(1, Ordering::AcqRel);
                self.metrics.dequeued(1);
                // dismantle the request: the admission permit drops
                // here, freeing the slot for the retry route
                Err(TrySubmitErr::Closed(req.x))
            }
            Err(mpsc::SendError(Msg::Stop)) => {
                unreachable!("submit only sends jobs")
            }
        }
    }

    /// Submit and block for the answer (interactive-class).
    pub fn infer(&self, x: Tensor) -> Result<Tensor> {
        self.infer_prio(x, Priority::Interactive)
    }

    /// Submit with an explicit SLO class and block for the answer.
    pub fn infer_prio(&self, x: Tensor, prio: Priority) -> Result<Tensor> {
        self.submit_prio(x, prio)?
            .recv()
            .map_err(|_| anyhow!("server dropped the request"))?
    }
}

/// Request router across named model variants.
#[derive(Default)]
pub struct Router {
    servers: HashMap<String, Server>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    pub fn add(&mut self, name: impl Into<String>, server: Server) {
        self.servers.insert(name.into(), server);
    }

    pub fn client(&self, name: &str) -> Result<Client> {
        Ok(self
            .servers
            .get(name)
            .ok_or_else(|| anyhow!("no model variant '{name}'"))?
            .client())
    }

    pub fn variants(&self) -> Vec<&str> {
        self.servers.keys().map(|s| s.as_str()).collect()
    }

    pub fn metrics(&self, name: &str) -> Result<Snapshot> {
        Ok(self
            .servers
            .get(name)
            .ok_or_else(|| anyhow!("no model variant '{name}'"))?
            .metrics())
    }

    /// `(variant, live metrics)` for every hosted variant, sorted by
    /// variant name so rendered expositions are reproducible.
    pub fn metrics_handles(&self) -> Vec<(&str, Arc<Metrics>)> {
        let mut v: Vec<(&str, Arc<Metrics>)> = self
            .servers
            .iter()
            .map(|(k, s)| (k.as_str(), s.metrics_handle()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(b.0));
        v
    }

    /// One variant's `(client, live metrics)` pair — the lane shape the
    /// [`AdaptiveClient`] steers between.
    pub fn lane(&self, name: &str) -> Result<(Client, Arc<Metrics>)> {
        let s = self
            .servers
            .get(name)
            .ok_or_else(|| anyhow!("no model variant '{name}'"))?;
        Ok((s.client(), s.metrics_handle()))
    }

    /// Stop every variant server and collect their final snapshots.
    ///
    /// Two-phase: the stop sentinel goes to **every lane of every
    /// server first**, then the workers are joined. All retired lanes
    /// therefore drain concurrently — a hot-swapped router with
    /// `lanes_per_model` lanes × variants drains in the time of its
    /// slowest lane, not the sum (the old serial drain scaled with lane
    /// count). The zero-dropped-requests invariant is unchanged: every
    /// queued job is still served before its worker exits.
    pub fn shutdown(self) -> Vec<(String, Snapshot)> {
        for s in self.servers.values() {
            s.signal_stop();
        }
        self.servers
            .into_iter()
            .map(|(k, s)| (k, s.shutdown()))
            .collect()
    }
}

#[allow(dead_code)]
fn _assert_traits() {
    fn is_send<T: Send>() {}
    is_send::<EngineExecutor>();
    is_send::<QuantExecutor>();
    is_send::<Client>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::{bn_fold, testutil};

    fn engine_server(max_batch: usize, delay_ms: u64) -> Server {
        let model =
            bn_fold::fold(&testutil::two_layer_model(71, true)).unwrap();
        let cfg = QuantCfg::fp32(&model);
        Server::start(
            ServeConfig {
                max_batch,
                max_delay: Duration::from_millis(delay_ms),
                queue_depth: 128,
                ..ServeConfig::default()
            },
            move || {
                Ok(Box::new(EngineExecutor { model, cfg, max_batch: 64 }))
            },
        )
    }

    #[test]
    fn serves_single_requests() {
        let server = engine_server(8, 1);
        let client = server.client();
        let x = Tensor::full(&[1, 3, 8, 8], 0.5);
        let y = client.infer(x).unwrap();
        assert_eq!(y.shape()[0], 1);
        let snap = server.shutdown();
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = engine_server(16, 20);
        let mut rxs = Vec::new();
        let client = server.client();
        for i in 0..12 {
            let x = Tensor::full(&[1, 3, 8, 8], i as f32 / 12.0);
            rxs.push(client.submit(x).unwrap());
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        let snap = server.shutdown();
        assert_eq!(snap.completed, 12);
        // with a 20ms window everything lands in few batches
        assert!(snap.batch_size.unwrap().mean > 1.5);
    }

    #[test]
    fn router_routes_and_errors() {
        let mut router = Router::new();
        router.add("fp32", engine_server(4, 1));
        assert!(router.client("fp32").is_ok());
        assert!(router.client("missing").is_err());
        let x = Tensor::full(&[1, 3, 8, 8], 0.1);
        let y = router.client("fp32").unwrap().infer(x).unwrap();
        assert_eq!(y.shape()[0], 1);
        router.shutdown();
    }

    #[test]
    fn int8_variant_serves_and_matches_oracle() {
        use crate::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
        use crate::quant::QScheme;

        let m = testutil::two_layer_model(73, true);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        let q = prep
            .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
            .unwrap();
        // per-layer requant rounding is bounded by one step on the final
        // activation grid (tight parity is asserted per layer in
        // tests/qengine_parity.rs); leave headroom for a rare upstream
        // rounding-boundary flip propagating through layer 2
        let tol = q.act_cfg.rows.last().unwrap().scale * 4.001;

        let mut router = Router::new();
        let (oracle_model, oracle_cfg) = (q.model.clone(), q.act_cfg.clone());
        router.add(
            "fp32-oracle",
            Server::start(ServeConfig::default(), move || {
                Ok(Box::new(EngineExecutor {
                    model: oracle_model,
                    cfg: oracle_cfg,
                    max_batch: 16,
                }))
            }),
        );
        let q2 = q.clone();
        router.add(
            "int8",
            Server::start(ServeConfig::default(), move || {
                Ok(Box::new(QuantExecutor::from_quantized(&q2, 16)?))
            }),
        );

        let x = testutil::random_input(&m, 1, 9);
        let y_oracle = router.client("fp32-oracle").unwrap().infer(x.clone())
            .unwrap();
        let y_int8 = router.client("int8").unwrap().infer(x).unwrap();
        assert_eq!(y_oracle.shape(), y_int8.shape());
        assert!(
            y_int8.max_abs_diff(&y_oracle) <= tol,
            "int8 variant off by {} (> {tol})",
            y_int8.max_abs_diff(&y_oracle)
        );
        router.shutdown();
    }

    #[test]
    fn batch_outputs_match_individual() {
        // determinism: the same image served alone or in a batch gives
        // identical outputs
        let server = engine_server(8, 30);
        let client = server.client();
        let x = Tensor::full(&[1, 3, 8, 8], 0.25);
        let solo = client.infer(x.clone()).unwrap();
        let mut rxs = Vec::new();
        for _ in 0..6 {
            rxs.push(client.submit(x.clone()).unwrap());
        }
        for rx in rxs {
            let y = rx.recv().unwrap().unwrap();
            assert!(y.max_abs_diff(&solo) < 1e-6);
        }
        server.shutdown();
    }

    #[test]
    fn sharded_lanes_spread_traffic_and_merge_into_shared_metrics() {
        let model =
            bn_fold::fold(&testutil::two_layer_model(77, true)).unwrap();
        let cfg = QuantCfg::fp32(&model);
        let server = Server::start_sharded(
            ServeConfig {
                lanes_per_model: 3,
                max_batch: 4,
                max_delay: Duration::from_millis(1),
                ..ServeConfig::default()
            },
            move || {
                Ok(Box::new(EngineExecutor {
                    model: model.clone(),
                    cfg: cfg.clone(),
                    max_batch: 8,
                }))
            },
        );
        assert_eq!(server.lanes(), 3);
        let client = server.client();
        let x = Tensor::full(&[1, 3, 8, 8], 0.5);
        let want = client.infer(x.clone()).unwrap();
        let mut rxs = Vec::new();
        for i in 0..29 {
            let prio = if i % 3 == 0 {
                Priority::Batch
            } else {
                Priority::Interactive
            };
            rxs.push(client.submit_prio(x.clone(), prio).unwrap());
        }
        for rx in rxs {
            let y = rx.recv().unwrap().unwrap();
            assert!(y.max_abs_diff(&want) < 1e-6, "lanes must agree");
        }
        let lane_totals: Vec<u64> = server
            .lane_metrics()
            .iter()
            .map(|m| m.snapshot().completed)
            .collect();
        let snap = server.shutdown();
        assert_eq!(snap.completed, 30);
        assert_eq!(
            lane_totals.iter().sum::<u64>(),
            30,
            "per-lane metrics must merge to the shared total: {lane_totals:?}"
        );
        assert!(
            lane_totals.iter().all(|&t| t > 0),
            "idle-tie round-robin should reach every lane: {lane_totals:?}"
        );
        // both SLO classes recorded into their own streams
        assert_eq!(snap.latency_interactive.unwrap().n, 20);
        assert_eq!(snap.latency_batch.unwrap().n, 10);
        assert_eq!(snap.accepted, 30);
        assert_eq!(snap.shed, 0);
    }

    /// Executor that blocks on an external gate, making admission-cap
    /// tests deterministic: a permit stays held exactly until the gate
    /// releases its batch.
    struct GateExec {
        gate: std::sync::mpsc::Receiver<()>,
    }

    impl BatchExecutor for GateExec {
        fn max_batch(&self) -> usize {
            1
        }
        fn run_batch(&mut self, x: &Tensor) -> Result<Tensor> {
            self.gate
                .recv()
                .map_err(|_| anyhow!("gate closed"))?;
            Ok(x.clone())
        }
    }

    #[test]
    fn admission_cap_sheds_with_typed_error_and_recovers() {
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let server = Server::start(
            ServeConfig {
                admission_cap: 1,
                max_batch: 1,
                max_delay: Duration::from_millis(1),
                ..ServeConfig::default()
            },
            move || Ok(Box::new(GateExec { gate: gate_rx })),
        );
        let client = server.client();
        let x = Tensor::full(&[1, 2, 2, 2], 0.5);
        // #1 holds the only slot while the gate blocks it
        let rx1 = client.submit(x.clone()).unwrap();
        // #2 is over cap: typed, immediate rejection — not queued
        let err = client.submit(x.clone()).unwrap_err();
        match err.downcast_ref::<SubmitError>() {
            Some(SubmitError::Shed { in_flight, cap }) => {
                assert_eq!((*in_flight, *cap), (1, 1));
            }
            other => panic!("expected typed Shed, got {other:?}"),
        }
        // the shed is visible in metrics + exposition
        assert_eq!(server.metrics().shed, 1);
        let text = server.metrics_handle().exposition(&[]);
        assert!(text.contains("dfq_requests_shed 1"), "{text}");
        // release #1; its permit frees on reply, so admission recovers
        gate_tx.send(()).unwrap();
        rx1.recv().unwrap().unwrap();
        let rx3 = loop {
            // the permit drops moments after the reply lands; poll past
            // the tiny race window
            match client.submit(x.clone()) {
                Ok(rx) => break rx,
                Err(_) => std::thread::sleep(Duration::from_millis(1)),
            }
        };
        gate_tx.send(()).unwrap();
        rx3.recv().unwrap().unwrap();
        drop(gate_tx);
        let snap = server.shutdown();
        assert_eq!(snap.completed, 2);
        assert_eq!(snap.accepted, 2);
        assert_eq!(snap.shed, 1);
    }
}
