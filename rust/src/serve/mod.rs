//! Serving coordinator — the L3 deployment surface.
//!
//! A request router over model variants, each backed by a worker thread
//! that dynamically batches requests (see [`batcher`]) and executes them
//! on a [`BatchExecutor`] — either the PJRT executable (production) or
//! the pure-Rust engine (tests / PJRT-free hosts). Executors are
//! constructed *inside* their worker thread via a factory closure, so
//! non-`Send` PJRT handles never cross threads. For hosting many models
//! at once from compiled `.dfqm` artifacts, see [`registry`] (the
//! `dfq serve --models dir/` surface) and `src/serve/README.md`.
//!
//! Two adaptive layers sit on top:
//!
//! * [`autoscale`] — a metrics-driven policy that steers one model's
//!   traffic between its `f32` oracle and `int8` variants (shed to int8
//!   when p95 latency or queue depth crosses a threshold, recover with
//!   hysteresis; `dfq serve <arch> --autoscale`);
//! * registry lifecycle — hot reload of a changed `.dfqm` behind a
//!   [`registry::LiveClient`] without dropping in-flight requests, and
//!   LRU eviction of idle models under
//!   [`ServeConfig::max_resident`] with lazy re-load.

pub mod autoscale;
pub mod batcher;
pub mod demo;
pub mod metrics;
pub mod registry;

use std::collections::HashMap;
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::graph::Model;
use crate::nn::{self, QuantCfg};
use crate::tensor::Tensor;

pub use autoscale::{
    AdaptiveClient, AdaptiveReport, AutoscalePolicy, Autoscaler,
};
pub use metrics::{Metrics, Snapshot, WindowCursor};
pub use registry::{LiveClient, ModelInfo, Registry, WatchDebounce};

/// Anything that can run a padded batch of images.
pub trait BatchExecutor {
    /// Largest batch the executor accepts.
    fn max_batch(&self) -> usize;
    /// Run (n, C, H, W) with n <= max_batch; returns the primary output
    /// with leading dimension n.
    fn run_batch(&mut self, x: &Tensor) -> Result<Tensor>;
}

/// Reference-engine executor (Send; usable anywhere).
pub struct EngineExecutor {
    pub model: Model,
    pub cfg: QuantCfg,
    pub max_batch: usize,
}

impl BatchExecutor for EngineExecutor {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn run_batch(&mut self, x: &Tensor) -> Result<Tensor> {
        nn::forward(&self.model, x, &self.cfg)?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("model produced no outputs"))
    }
}

/// True-int8 executor over the packed integer engine
/// ([`crate::nn::qengine`]): quantises each incoming batch onto the
/// input grid, runs u8×i8 GEMM convs with fused requant epilogues, and
/// dequantises the primary output. Send like [`EngineExecutor`], so the
/// router can host an f32-oracle variant and an int8 variant side by
/// side (see [`Router`]).
pub struct QuantExecutor {
    pub qmodel: crate::nn::qengine::QModel,
    pub max_batch: usize,
}

impl QuantExecutor {
    /// Build from a DFQ-quantised model (weights quantised at ≤ 8 bits,
    /// activations quantised — see
    /// [`crate::dfq::QuantizedModel::pack_int8`]).
    pub fn from_quantized(
        q: &crate::dfq::QuantizedModel,
        max_batch: usize,
    ) -> Result<QuantExecutor> {
        Ok(QuantExecutor { qmodel: q.pack_int8()?, max_batch })
    }

    /// Like [`QuantExecutor::from_quantized`] but refuses any plan that
    /// still contains an f32 fallback op (`PlanOpts { int8_only: true, ..Default::default() }`)
    /// — deployments promising pure 8-bit inference get an error, not a
    /// silent partial fallback.
    pub fn from_quantized_strict(
        q: &crate::dfq::QuantizedModel,
        max_batch: usize,
    ) -> Result<QuantExecutor> {
        let opts = crate::nn::qengine::PlanOpts { int8_only: true, ..Default::default() };
        Ok(QuantExecutor { qmodel: q.pack_int8_opts(opts)?, max_batch })
    }

    /// Boot straight from a `.dfqm` compiled artifact — decodes the
    /// stored plan ([`crate::artifact`]) instead of re-running the DFQ
    /// pipeline; no manifest, no float math.
    pub fn from_artifact(
        path: impl AsRef<std::path::Path>,
        max_batch: usize,
    ) -> Result<QuantExecutor> {
        Ok(QuantExecutor {
            qmodel: crate::nn::qengine::QModel::from_artifact(path)?,
            max_batch,
        })
    }
}

impl BatchExecutor for QuantExecutor {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn run_batch(&mut self, x: &Tensor) -> Result<Tensor> {
        self.qmodel.run(x)
    }
}

/// PJRT-backed executor holding the compiled executable + bound weights.
/// Construct it inside the worker thread (see [`Server::start`]).
pub struct PjrtExecutor {
    pub exec: crate::runtime::Executable,
    pub weights: crate::runtime::BoundWeights,
    pub cfg: QuantCfg,
}

impl BatchExecutor for PjrtExecutor {
    fn max_batch(&self) -> usize {
        self.exec.meta.batch
    }

    fn run_batch(&mut self, x: &Tensor) -> Result<Tensor> {
        let b = self.exec.meta.batch;
        let n = x.shape()[0];
        let input = if n == b { x.clone() } else { pad(x, b) };
        let out = self
            .exec
            .run(&input, &self.weights, &self.cfg)?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("executable produced no outputs"))?;
        Ok(if n == b { out } else { truncate(&out, n) })
    }
}

struct Request {
    x: Tensor, // (1, C, H, W)
    resp: Sender<Result<Tensor>>,
    enqueued: Instant,
}

/// Queue message: a job, or an explicit stop. The stop sentinel (rather
/// than sender-disconnect) ends the worker even while `Client` clones
/// are still alive -- dropping only the server's sender would leave the
/// worker parked in `recv` forever.
enum Msg {
    Job(Request),
    Stop,
}

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    pub max_batch: usize,
    pub max_delay: Duration,
    pub queue_depth: usize,
    /// Steering policy for [`Registry::adaptive_client`] /
    /// [`AdaptiveClient`]; `None` falls back to the default
    /// [`AutoscalePolicy`].
    pub autoscale: Option<AutoscalePolicy>,
    /// Registry resident-model cap: loading a model beyond this evicts
    /// the least-recently-used resident one (gracefully — its queue
    /// drains first). `0` means unbounded.
    pub max_resident: usize,
    /// Registry artifact loads go through [`crate::artifact::Artifact::open_mmap`]
    /// (zero-copy weight views over a shared read-only mapping; the
    /// page cache backs every resident model) instead of reading the
    /// file into memory. On by default; `dfq serve --models DIR
    /// --no-mmap` or `DFQ_NO_MMAP=1` turn it off.
    pub mmap: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 64,
            max_delay: Duration::from_millis(2),
            queue_depth: 1024,
            autoscale: None,
            max_resident: 0,
            mmap: true,
        }
    }
}

/// One model-variant server: a worker thread + request queue.
pub struct Server {
    tx: SyncSender<Msg>,
    metrics: Arc<Metrics>,
    worker: Option<JoinHandle<()>>,
}

impl Server {
    /// Spawn the worker. `factory` builds the executor on the worker
    /// thread (PJRT handles are not `Send`).
    pub fn start<F>(cfg: ServeConfig, factory: F) -> Server
    where
        F: FnOnce() -> Result<Box<dyn BatchExecutor>> + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel::<Msg>(cfg.queue_depth);
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let worker = std::thread::spawn(move || {
            let mut exec = match factory() {
                Ok(e) => e,
                Err(e) => {
                    crate::obs::trace::emit_with(
                        crate::obs::Severity::Error,
                        "serve",
                        || {
                            (
                                "executor construction failed".into(),
                                vec![("error", format!("{e:#}"))],
                            )
                        },
                    );
                    // fail every request with the construction error
                    drain_with_error(rx, e, &m2);
                    return;
                }
            };
            crate::obs::trace::emit_with(
                crate::obs::Severity::Debug,
                "serve",
                || {
                    (
                        "worker up".into(),
                        vec![("max_batch", exec.max_batch().to_string())],
                    )
                },
            );
            worker_loop(rx, cfg, exec.as_mut(), &m2);
        });
        Server { tx, metrics, worker: Some(worker) }
    }

    /// A cheap cloneable submission handle.
    pub fn client(&self) -> Client {
        Client { tx: self.tx.clone(), metrics: self.metrics.clone() }
    }

    pub fn metrics(&self) -> Snapshot {
        self.metrics.snapshot()
    }

    /// Shared handle to this server's live metrics (autoscaler input).
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    /// Clear recorded metrics (use after warm-up traffic).
    pub fn reset_metrics(&self) {
        self.metrics.reset();
    }

    /// Stop the worker (queued jobs are still served) and join it.
    /// Live `Client` handles error out afterwards.
    pub fn shutdown(mut self) -> Snapshot {
        let _ = self.tx.send(Msg::Stop);
        drop(self.tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        let snap = self.metrics.snapshot();
        crate::obs::trace::emit_with(
            crate::obs::Severity::Debug,
            "serve",
            || {
                (
                    "drain".into(),
                    vec![("completed", snap.completed.to_string())],
                )
            },
        );
        snap
    }
}

fn drain_with_error(rx: Receiver<Msg>, e: anyhow::Error, metrics: &Metrics) {
    let msg = format!("executor construction failed: {e:#}");
    let fail = |req: Request| {
        metrics.dequeued(1);
        let _ = req.resp.send(Err(anyhow!("{msg}")));
    };
    while let Ok(m) = rx.recv() {
        match m {
            Msg::Job(req) => fail(req),
            Msg::Stop => break,
        }
    }
    // jobs can race in behind the Stop sentinel; answer what is already
    // buffered instead of letting it vanish with the channel
    while let Ok(m) = rx.try_recv() {
        if let Msg::Job(req) = m {
            fail(req);
        }
    }
}

fn worker_loop(
    rx: Receiver<Msg>,
    cfg: ServeConfig,
    exec: &mut dyn BatchExecutor,
    metrics: &Metrics,
) {
    let policy = batcher::Batcher {
        max_batch: cfg.max_batch.min(exec.max_batch()),
        max_delay: cfg.max_delay,
    };
    while let Some(msgs) = policy.next_batch(&rx) {
        let mut stop = false;
        let mut batch = Vec::with_capacity(msgs.len());
        for m in msgs {
            match m {
                Msg::Job(req) => batch.push(req),
                Msg::Stop => stop = true,
            }
        }
        if !batch.is_empty() {
            // the batch has left the queue: the depth gauge drops
            // *before* execution so the autoscaler sees waiting work,
            // not in-flight work
            metrics.dequeued(batch.len() as u64);
            serve_batch(batch, exec, metrics);
        }
        if stop {
            // a submission racing a shutdown/hot-swap can land behind
            // the Stop sentinel while the channel is still open. Serve
            // what is already buffered so it drains rather than
            // vanishing. The race is then fully covered client-side: a
            // send after the channel closes fails at `submit` (the
            // registry's `LiveClient` retries it on the replacement
            // generation), and a send that slips into the buffer in the
            // instant before close dies with its response channel —
            // which the caller observes as a recv error, and
            // `LiveClient::infer` resubmits (an unanswered request was
            // never executed).
            drain_backlog(&rx, policy.max_batch, exec, metrics);
            break;
        }
    }
}

/// Serve every job already sitting in the queue, in batches, without
/// blocking for more. Used on the shutdown path after the Stop
/// sentinel.
fn drain_backlog(
    rx: &Receiver<Msg>,
    max_batch: usize,
    exec: &mut dyn BatchExecutor,
    metrics: &Metrics,
) {
    loop {
        let mut batch = Vec::new();
        while batch.len() < max_batch {
            match rx.try_recv() {
                Ok(Msg::Job(req)) => batch.push(req),
                Ok(Msg::Stop) => {}
                Err(_) => break,
            }
        }
        if batch.is_empty() {
            break;
        }
        metrics.dequeued(batch.len() as u64);
        serve_batch(batch, exec, metrics);
    }
}

/// Execute one assembled batch and reply to every request in it.
fn serve_batch(
    batch: Vec<Request>,
    exec: &mut dyn BatchExecutor,
    metrics: &Metrics,
) {
    let n = batch.len();
    let x = stack(&batch);
    let result = exec.run_batch(&x);
    let done = Instant::now();
    match result {
        Ok(out) => {
            let per: usize = out.shape()[1..].iter().product();
            let mut shape: Vec<usize> = out.shape().to_vec();
            shape[0] = 1;
            // record *before* replying so a client that resets
            // metrics right after its response cannot race the
            // bookkeeping of its own batch
            let lats: Vec<f64> = batch
                .iter()
                .map(|r| (done - r.enqueued).as_secs_f64())
                .collect();
            metrics.record_batch(n, &lats);
            for (i, req) in batch.into_iter().enumerate() {
                let one = Tensor::new(
                    &shape,
                    out.data()[i * per..(i + 1) * per].to_vec(),
                );
                let _ = req.resp.send(Ok(one));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for req in batch {
                let _ = req.resp.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

fn stack(reqs: &[Request]) -> Tensor {
    let mut shape = reqs[0].x.shape().to_vec();
    shape[0] = reqs.len();
    let mut data = Vec::with_capacity(shape.iter().product());
    for r in reqs {
        data.extend_from_slice(r.x.data());
    }
    Tensor::new(&shape, data)
}

fn pad(x: &Tensor, batch: usize) -> Tensor {
    let mut shape = x.shape().to_vec();
    let per: usize = shape[1..].iter().product();
    let n = shape[0];
    shape[0] = batch;
    let mut data = vec![0f32; batch * per];
    data[..n * per].copy_from_slice(x.data());
    Tensor::new(&shape, data)
}

fn truncate(x: &Tensor, n: usize) -> Tensor {
    let mut shape = x.shape().to_vec();
    let per: usize = shape[1..].iter().product();
    shape[0] = n;
    Tensor::new(&shape, x.data()[..n * per].to_vec())
}

/// Submission handle for one server.
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Msg>,
    /// Same handle the server records into — submissions bump the live
    /// queue-depth gauge so the autoscaler sees backlog as it forms.
    metrics: Arc<Metrics>,
}

impl Client {
    /// Submit one image (1, C, H, W); returns a receiver for the result.
    pub fn submit(&self, x: Tensor) -> Result<Receiver<Result<Tensor>>> {
        self.try_submit(x).map_err(|_| anyhow!("server is shut down"))
    }

    /// Like [`Client::submit`] but hands the tensor back when this
    /// server is gone, so a caller holding a newer route (the registry's
    /// hot-swap [`LiveClient`]) can retry without cloning the input.
    pub(crate) fn try_submit(
        &self,
        x: Tensor,
    ) -> std::result::Result<Receiver<Result<Tensor>>, Tensor> {
        let (rtx, rrx) = mpsc::channel();
        self.metrics.enqueued();
        match self
            .tx
            .send(Msg::Job(Request { x, resp: rtx, enqueued: Instant::now() }))
        {
            Ok(()) => Ok(rrx),
            Err(mpsc::SendError(Msg::Job(req))) => {
                self.metrics.dequeued(1);
                Err(req.x)
            }
            Err(mpsc::SendError(Msg::Stop)) => {
                unreachable!("submit only sends jobs")
            }
        }
    }

    /// Submit and block for the answer.
    pub fn infer(&self, x: Tensor) -> Result<Tensor> {
        self.submit(x)?
            .recv()
            .map_err(|_| anyhow!("server dropped the request"))?
    }
}

/// Request router across named model variants.
#[derive(Default)]
pub struct Router {
    servers: HashMap<String, Server>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    pub fn add(&mut self, name: impl Into<String>, server: Server) {
        self.servers.insert(name.into(), server);
    }

    pub fn client(&self, name: &str) -> Result<Client> {
        Ok(self
            .servers
            .get(name)
            .ok_or_else(|| anyhow!("no model variant '{name}'"))?
            .client())
    }

    pub fn variants(&self) -> Vec<&str> {
        self.servers.keys().map(|s| s.as_str()).collect()
    }

    pub fn metrics(&self, name: &str) -> Result<Snapshot> {
        Ok(self
            .servers
            .get(name)
            .ok_or_else(|| anyhow!("no model variant '{name}'"))?
            .metrics())
    }

    /// `(variant, live metrics)` for every hosted variant, sorted by
    /// variant name so rendered expositions are reproducible.
    pub fn metrics_handles(&self) -> Vec<(&str, Arc<Metrics>)> {
        let mut v: Vec<(&str, Arc<Metrics>)> = self
            .servers
            .iter()
            .map(|(k, s)| (k.as_str(), s.metrics_handle()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(b.0));
        v
    }

    /// One variant's `(client, live metrics)` pair — the lane shape the
    /// [`AdaptiveClient`] steers between.
    pub fn lane(&self, name: &str) -> Result<(Client, Arc<Metrics>)> {
        let s = self
            .servers
            .get(name)
            .ok_or_else(|| anyhow!("no model variant '{name}'"))?;
        Ok((s.client(), s.metrics_handle()))
    }

    pub fn shutdown(self) -> Vec<(String, Snapshot)> {
        self.servers
            .into_iter()
            .map(|(k, s)| (k.clone(), s.shutdown()))
            .collect()
    }
}

#[allow(dead_code)]
fn _assert_traits() {
    fn is_send<T: Send>() {}
    is_send::<EngineExecutor>();
    is_send::<QuantExecutor>();
    is_send::<Client>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::{bn_fold, testutil};

    fn engine_server(max_batch: usize, delay_ms: u64) -> Server {
        let model =
            bn_fold::fold(&testutil::two_layer_model(71, true)).unwrap();
        let cfg = QuantCfg::fp32(&model);
        Server::start(
            ServeConfig {
                max_batch,
                max_delay: Duration::from_millis(delay_ms),
                queue_depth: 128,
                ..ServeConfig::default()
            },
            move || {
                Ok(Box::new(EngineExecutor { model, cfg, max_batch: 64 }))
            },
        )
    }

    #[test]
    fn serves_single_requests() {
        let server = engine_server(8, 1);
        let client = server.client();
        let x = Tensor::full(&[1, 3, 8, 8], 0.5);
        let y = client.infer(x).unwrap();
        assert_eq!(y.shape()[0], 1);
        let snap = server.shutdown();
        assert_eq!(snap.completed, 1);
    }

    #[test]
    fn batches_concurrent_requests() {
        let server = engine_server(16, 20);
        let mut rxs = Vec::new();
        let client = server.client();
        for i in 0..12 {
            let x = Tensor::full(&[1, 3, 8, 8], i as f32 / 12.0);
            rxs.push(client.submit(x).unwrap());
        }
        for rx in rxs {
            assert!(rx.recv().unwrap().is_ok());
        }
        let snap = server.shutdown();
        assert_eq!(snap.completed, 12);
        // with a 20ms window everything lands in few batches
        assert!(snap.batch_size.unwrap().mean > 1.5);
    }

    #[test]
    fn router_routes_and_errors() {
        let mut router = Router::new();
        router.add("fp32", engine_server(4, 1));
        assert!(router.client("fp32").is_ok());
        assert!(router.client("missing").is_err());
        let x = Tensor::full(&[1, 3, 8, 8], 0.1);
        let y = router.client("fp32").unwrap().infer(x).unwrap();
        assert_eq!(y.shape()[0], 1);
        router.shutdown();
    }

    #[test]
    fn int8_variant_serves_and_matches_oracle() {
        use crate::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
        use crate::quant::QScheme;

        let m = testutil::two_layer_model(73, true);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        let q = prep
            .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::None, None)
            .unwrap();
        // per-layer requant rounding is bounded by one step on the final
        // activation grid (tight parity is asserted per layer in
        // tests/qengine_parity.rs); leave headroom for a rare upstream
        // rounding-boundary flip propagating through layer 2
        let tol = q.act_cfg.rows.last().unwrap().scale * 4.001;

        let mut router = Router::new();
        let (oracle_model, oracle_cfg) = (q.model.clone(), q.act_cfg.clone());
        router.add(
            "fp32-oracle",
            Server::start(ServeConfig::default(), move || {
                Ok(Box::new(EngineExecutor {
                    model: oracle_model,
                    cfg: oracle_cfg,
                    max_batch: 16,
                }))
            }),
        );
        let q2 = q.clone();
        router.add(
            "int8",
            Server::start(ServeConfig::default(), move || {
                Ok(Box::new(QuantExecutor::from_quantized(&q2, 16)?))
            }),
        );

        let x = testutil::random_input(&m, 1, 9);
        let y_oracle = router.client("fp32-oracle").unwrap().infer(x.clone())
            .unwrap();
        let y_int8 = router.client("int8").unwrap().infer(x).unwrap();
        assert_eq!(y_oracle.shape(), y_int8.shape());
        assert!(
            y_int8.max_abs_diff(&y_oracle) <= tol,
            "int8 variant off by {} (> {tol})",
            y_int8.max_abs_diff(&y_oracle)
        );
        router.shutdown();
    }

    #[test]
    fn batch_outputs_match_individual() {
        // determinism: the same image served alone or in a batch gives
        // identical outputs
        let server = engine_server(8, 30);
        let client = server.client();
        let x = Tensor::full(&[1, 3, 8, 8], 0.25);
        let solo = client.infer(x.clone()).unwrap();
        let mut rxs = Vec::new();
        for _ in 0..6 {
            rxs.push(client.submit(x.clone()).unwrap());
        }
        for rx in rxs {
            let y = rx.recv().unwrap().unwrap();
            assert!(y.max_abs_diff(&solo) < 1e-6);
        }
        server.shutdown();
    }
}
