//! Metrics-driven variant autoscaling: steer a model's traffic between
//! the `f32` oracle and the true-int8 plan from live serving metrics.
//!
//! The paper's deployment pitch is that the int8 model is the *cheap*
//! variant of the same network; a serving host can therefore treat the
//! pair as a two-rung autoscaling ladder. [`Autoscaler`] is the policy:
//! a deterministic state machine that consumes per-window observations
//! of the **active** variant ([`Obs`]: live queue depth + windowed p95
//! latency from [`Metrics::window_from`](super::Metrics::window_from))
//! and decides which variant should take new traffic:
//!
//! ```text
//!             queue >= queue_shed  OR  window p95 >= p95_shed
//!        F32 ────────────────────────────────────────────────▶ Int8
//!      (oracle)                                             (cheap)
//!        ◀────────────────────────────────────────────────
//!             queue <= queue_recover AND window p95 <= p95_recover
//!                        (or the lane went fully idle)
//! ```
//!
//! Flap control is two-fold: the recover thresholds are *stricter* than
//! the shed thresholds (classic hysteresis band), and every switch arms
//! a dwell counter of [`AutoscalePolicy::min_dwell`] ticks during which
//! no further switch is considered.
//!
//! [`AdaptiveClient`] is the mechanism: a submission handle over both
//! variants of one router that ticks the policy every
//! [`AutoscalePolicy::tick_every`] submissions and routes each request
//! to the currently-selected variant. Obtain one from
//! [`Registry::adaptive_client`](super::Registry::adaptive_client)
//! (in-memory registrations host both variants) or build one from any
//! router's lanes; drive it from the CLI with `dfq serve <arch>
//! --autoscale`.

use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::tensor::Tensor;
use crate::util::bench::fmt_secs;

use super::metrics::WindowCursor;
use super::{Client, Metrics};

/// Which variant of a model takes new traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// The fake-quant f32 oracle (reference quality).
    F32,
    /// The true-int8 execution plan (cheap, shed target).
    Int8,
}

impl Target {
    /// The registry variant name this target routes to.
    pub fn as_str(&self) -> &'static str {
        match self {
            Target::F32 => "f32",
            Target::Int8 => "int8",
        }
    }

    fn idx(self) -> usize {
        match self {
            Target::F32 => 0,
            Target::Int8 => 1,
        }
    }
}

/// Thresholds and flap control for the [`Autoscaler`]. All fields are
/// plain data so the policy can ride inside
/// [`ServeConfig`](super::ServeConfig).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AutoscalePolicy {
    /// Shed to int8 when the active window's p95 latency reaches this.
    pub p95_shed: Duration,
    /// Return to f32 only when the window p95 is back below this
    /// (stricter than `p95_shed` — the hysteresis band).
    pub p95_recover: Duration,
    /// Shed to int8 when the live queue depth reaches this.
    pub queue_shed: usize,
    /// Return to f32 only when the queue is at most this deep.
    pub queue_recover: usize,
    /// Minimum completed requests in a window before its p95 counts as
    /// *shed* evidence (recovery accepts any calm window — see
    /// [`Autoscaler::tick`]).
    pub min_window: usize,
    /// Ticks to hold the new target after any switch (anti-flap dwell).
    pub min_dwell: u32,
    /// Submissions between policy ticks in [`AdaptiveClient`].
    pub tick_every: u32,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            p95_shed: Duration::from_millis(25),
            p95_recover: Duration::from_millis(8),
            queue_shed: 32,
            queue_recover: 2,
            min_window: 8,
            min_dwell: 4,
            tick_every: 16,
        }
    }
}

/// One observation window of the **active** variant.
#[derive(Debug, Clone, Copy, Default)]
pub struct Obs {
    /// Live queue depth (submitted, not yet picked up by the worker).
    pub queue_depth: usize,
    /// Requests completed in this window.
    pub window_n: usize,
    /// p95 latency over the window (`None` when the window is empty).
    pub window_p95: Option<Duration>,
}

/// One recorded target switch (the autoscale trace).
#[derive(Debug, Clone)]
pub struct Transition {
    /// Tick number at which the switch happened (1-based).
    pub tick: u64,
    pub from: Target,
    pub to: Target,
    /// Human-readable trigger, e.g. `queue 41 >= 32`.
    pub reason: String,
}

impl Transition {
    /// One log line, e.g. `tick 12: f32 -> int8 (queue 41 >= 32)`.
    pub fn describe(&self) -> String {
        format!(
            "tick {}: {} -> {} ({})",
            self.tick,
            self.from.as_str(),
            self.to.as_str(),
            self.reason
        )
    }
}

/// The deterministic steering state machine. Pure policy — it never
/// touches a queue or a thread, so every trajectory is unit-testable.
#[derive(Debug)]
pub struct Autoscaler {
    policy: AutoscalePolicy,
    target: Target,
    dwell: u32,
    ticks: u64,
    transitions: Vec<Transition>,
}

impl Autoscaler {
    /// Starts on the f32 oracle (quality-first; load sheds to int8).
    pub fn new(policy: AutoscalePolicy) -> Autoscaler {
        Autoscaler {
            policy,
            target: Target::F32,
            dwell: 0,
            ticks: 0,
            transitions: Vec::new(),
        }
    }

    /// The variant new traffic should go to.
    pub fn target(&self) -> Target {
        self.target
    }

    /// Every switch so far, in order.
    pub fn transitions(&self) -> &[Transition] {
        &self.transitions
    }

    /// Feed one observation window of the active variant; returns the
    /// (possibly new) target. Within `min_dwell` ticks of a switch the
    /// observation only burns dwell — no decision is made.
    pub fn tick(&mut self, obs: &Obs) -> Target {
        self.ticks += 1;
        if self.dwell > 0 {
            self.dwell -= 1;
            return self.target;
        }
        let p = self.policy;
        match self.target {
            Target::F32 => {
                let p95_hot = obs.window_n >= p.min_window
                    && obs.window_p95.is_some_and(|l| l >= p.p95_shed);
                if obs.queue_depth >= p.queue_shed {
                    self.switch(
                        Target::Int8,
                        format!(
                            "queue {} >= {}",
                            obs.queue_depth, p.queue_shed
                        ),
                    );
                } else if p95_hot {
                    self.switch(
                        Target::Int8,
                        format!(
                            "p95 {} >= {}",
                            fmt_secs(obs.window_p95.unwrap().as_secs_f64()),
                            fmt_secs(p.p95_shed.as_secs_f64())
                        ),
                    );
                }
            }
            Target::Int8 => {
                let calm_queue = obs.queue_depth <= p.queue_recover;
                // `min_window` gates *shedding* (do not overreact to a
                // sparse hot window); recovery is the safe direction, so
                // any calm evidence counts — an idle lane, or a window
                // of any size whose p95 is under the recover line.
                // Otherwise a steady trickle (1..min_window completions
                // per window) could pin the router on int8 forever.
                let calm_p95 = obs.window_n == 0
                    || obs.window_p95.is_some_and(|l| l <= p.p95_recover);
                if calm_queue && calm_p95 {
                    self.switch(
                        Target::F32,
                        format!(
                            "recovered: queue {} <= {}, window calm",
                            obs.queue_depth, p.queue_recover
                        ),
                    );
                }
            }
        }
        self.target
    }

    fn switch(&mut self, to: Target, reason: String) {
        let t = Transition {
            tick: self.ticks,
            from: self.target,
            to,
            reason,
        };
        crate::obs::trace::emit_with(
            crate::obs::trace::Severity::Info,
            "autoscale",
            || {
                (
                    "transition".into(),
                    vec![
                        ("tick", t.tick.to_string()),
                        ("from", t.from.as_str().to_string()),
                        ("to", t.to.as_str().to_string()),
                        ("reason", t.reason.clone()),
                    ],
                )
            },
        );
        self.transitions.push(t);
        self.target = to;
        self.dwell = self.policy.min_dwell;
    }
}

struct Lane {
    client: Client,
    metrics: Arc<Metrics>,
    cursor: WindowCursor,
    routed: u64,
}

struct Shared {
    lanes: [Lane; 2], // indexed by Target::idx()
    scaler: Autoscaler,
    submitted: u64,
}

/// A submission handle that routes each request to the variant the
/// [`Autoscaler`] currently selects. Cheap to clone; clones share the
/// policy state, so concurrent submitters steer together.
///
/// The two lanes are bound to the server generation they were built
/// from: if the model behind them is hot-swapped or evicted (see the
/// registry lifecycle), submissions error and a fresh handle must be
/// obtained — unlike
/// [`registry::LiveClient`](super::registry::LiveClient), this handle
/// does not follow swaps.
#[derive(Clone)]
pub struct AdaptiveClient {
    shared: Arc<Mutex<Shared>>,
}

impl AdaptiveClient {
    /// Build from the two lanes of one model: `(client, metrics)` of the
    /// f32 oracle variant and of the int8 variant (see
    /// [`Router::lane`](super::Router::lane)).
    pub fn new(
        f32_lane: (Client, Arc<Metrics>),
        int8_lane: (Client, Arc<Metrics>),
        policy: AutoscalePolicy,
    ) -> AdaptiveClient {
        let lane = |(client, metrics): (Client, Arc<Metrics>)| Lane {
            client,
            metrics,
            cursor: WindowCursor::default(),
            routed: 0,
        };
        AdaptiveClient {
            shared: Arc::new(Mutex::new(Shared {
                lanes: [lane(f32_lane), lane(int8_lane)],
                scaler: Autoscaler::new(policy),
                submitted: 0,
            })),
        }
    }

    /// The variant the next submission will route to.
    pub fn target(&self) -> Target {
        self.shared.lock().unwrap().scaler.target()
    }

    /// Submit one image (1, C, H, W) to the currently-selected variant;
    /// every `tick_every`-th submission first feeds the policy a fresh
    /// observation window of the active lane.
    pub fn submit(&self, x: Tensor) -> Result<Receiver<Result<Tensor>>> {
        let client = {
            let mut guard = self.shared.lock().unwrap();
            let s = &mut *guard;
            s.submitted += 1;
            let every = s.scaler.policy.tick_every.max(1) as u64;
            if s.submitted % every == 0 {
                let lane = &mut s.lanes[s.scaler.target().idx()];
                let (cursor, window) =
                    lane.metrics.window_from(lane.cursor);
                lane.cursor = cursor;
                let obs = Obs {
                    queue_depth: lane.metrics.queue_depth() as usize,
                    window_n: window.map_or(0, |w| w.n),
                    window_p95: window
                        .map(|w| Duration::from_secs_f64(w.p95)),
                };
                s.scaler.tick(&obs);
            }
            let lane = &mut s.lanes[s.scaler.target().idx()];
            lane.routed += 1;
            lane.client.clone()
        };
        // the send happens outside the lock: a full queue blocks this
        // submitter, not every clone of the adaptive client
        client.submit(x)
    }

    /// Submit and block for the answer.
    pub fn infer(&self, x: Tensor) -> Result<Tensor> {
        self.submit(x)?
            .recv()
            .map_err(|_| anyhow!("server dropped the request"))?
    }

    /// Routing totals + the full transition trace so far.
    pub fn report(&self) -> AdaptiveReport {
        let s = self.shared.lock().unwrap();
        AdaptiveReport {
            routed_f32: s.lanes[Target::F32.idx()].routed,
            routed_int8: s.lanes[Target::Int8.idx()].routed,
            transitions: s.scaler.transitions().to_vec(),
            target: s.scaler.target(),
        }
    }
}

/// What an adaptive session did: where traffic went and every switch.
#[derive(Debug, Clone)]
pub struct AdaptiveReport {
    pub routed_f32: u64,
    pub routed_int8: u64,
    pub transitions: Vec<Transition>,
    /// Target at report time.
    pub target: Target,
}

impl AdaptiveReport {
    /// One human-readable summary line.
    pub fn summary_line(&self) -> String {
        format!(
            "routed {} -> f32, {} -> int8  ({} transition(s), final {})",
            self.routed_f32,
            self.routed_int8,
            self.transitions.len(),
            self.target.as_str()
        )
    }

    /// One machine-readable record (same line-per-record convention as
    /// the bench JSON).
    pub fn json(&self, name: &str) -> String {
        format!(
            "{{\"name\":{:?},\"routed_f32\":{},\"routed_int8\":{},\
             \"transitions\":{},\"final\":{:?}}}",
            name,
            self.routed_f32,
            self.routed_int8,
            self.transitions.len(),
            self.target.as_str()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> AutoscalePolicy {
        AutoscalePolicy {
            p95_shed: Duration::from_millis(20),
            p95_recover: Duration::from_millis(5),
            queue_shed: 8,
            queue_recover: 1,
            min_window: 4,
            min_dwell: 2,
            tick_every: 1,
        }
    }

    fn obs(depth: usize, n: usize, p95_ms: u64) -> Obs {
        Obs {
            queue_depth: depth,
            window_n: n,
            window_p95: if n == 0 {
                None
            } else {
                Some(Duration::from_millis(p95_ms))
            },
        }
    }

    #[test]
    fn sheds_on_queue_depth() {
        let mut a = Autoscaler::new(policy());
        assert_eq!(a.target(), Target::F32);
        assert_eq!(a.tick(&obs(7, 0, 0)), Target::F32); // below threshold
        assert_eq!(a.tick(&obs(9, 0, 0)), Target::Int8);
        let t = &a.transitions()[0];
        assert_eq!((t.from, t.to), (Target::F32, Target::Int8));
        assert!(t.reason.contains("queue"), "{}", t.reason);
        assert!(t.describe().contains("f32 -> int8"));
    }

    #[test]
    fn sheds_on_windowed_p95_but_not_on_sparse_windows() {
        let mut a = Autoscaler::new(policy());
        // 2 completions < min_window: a hot p95 over a sparse window is
        // not evidence
        assert_eq!(a.tick(&obs(0, 2, 500)), Target::F32);
        assert_eq!(a.tick(&obs(0, 8, 30)), Target::Int8);
        assert!(a.transitions()[0].reason.contains("p95"));
    }

    #[test]
    fn dwell_holds_the_target_after_a_switch() {
        let mut a = Autoscaler::new(policy());
        a.tick(&obs(20, 0, 0)); // shed, arms dwell = 2
        let calm = obs(0, 8, 1);
        assert_eq!(a.tick(&calm), Target::Int8); // dwell 2 -> 1
        assert_eq!(a.tick(&calm), Target::Int8); // dwell 1 -> 0
        assert_eq!(a.tick(&calm), Target::F32); // now free to recover
        assert_eq!(a.transitions().len(), 2);
    }

    #[test]
    fn hysteresis_band_blocks_recovery() {
        let mut a = Autoscaler::new(policy());
        a.tick(&obs(20, 0, 0)); // shed
        a.tick(&obs(0, 8, 1)); // burn dwell
        a.tick(&obs(0, 8, 1));
        // p95 10ms is below the 20ms shed line but above the 5ms recover
        // line: inside the band nothing moves, in either direction
        for _ in 0..10 {
            assert_eq!(a.tick(&obs(0, 8, 10)), Target::Int8);
        }
        // queue still deep: no recovery either
        assert_eq!(a.tick(&obs(3, 8, 1)), Target::Int8);
        // genuinely calm: recover
        assert_eq!(a.tick(&obs(0, 8, 1)), Target::F32);
    }

    #[test]
    fn idle_lane_counts_as_recovered() {
        let mut a = Autoscaler::new(policy());
        a.tick(&obs(20, 0, 0)); // shed
        a.tick(&obs(0, 0, 0)); // dwell
        a.tick(&obs(0, 0, 0)); // dwell
        // no traffic at all: empty window + empty queue means healthy
        assert_eq!(a.tick(&obs(0, 0, 0)), Target::F32);
    }

    #[test]
    fn trickle_traffic_still_recovers() {
        let mut a = Autoscaler::new(policy());
        a.tick(&obs(20, 0, 0)); // shed
        a.tick(&obs(0, 2, 1)); // dwell
        a.tick(&obs(0, 2, 1)); // dwell
        // 2 completions per window is below min_window, but min_window
        // only gates shedding: sparse *calm* evidence must not pin the
        // router on int8 forever
        assert_eq!(a.tick(&obs(0, 2, 1)), Target::F32);
        // while a sparse window above the recover line still holds
        let mut b = Autoscaler::new(policy());
        b.tick(&obs(20, 0, 0));
        b.tick(&obs(0, 2, 10));
        b.tick(&obs(0, 2, 10));
        assert_eq!(b.tick(&obs(0, 2, 10)), Target::Int8);
    }

    #[test]
    fn adaptive_client_routes_and_reports() {
        use crate::dfq::{bn_fold, testutil};
        use crate::nn::QuantCfg;
        use crate::serve::{
            EngineExecutor, Router, ServeConfig, Server,
        };

        let start = |seed| {
            let model =
                bn_fold::fold(&testutil::two_layer_model(seed, true))
                    .unwrap();
            let cfg = QuantCfg::fp32(&model);
            Server::start(ServeConfig::default(), move || {
                Ok(Box::new(EngineExecutor {
                    model,
                    cfg,
                    max_batch: 8,
                }))
            })
        };
        let mut router = Router::new();
        router.add("f32", start(81));
        router.add("int8", start(81));
        // queue_shed = 0 makes the very first tick shed, and a dwell
        // longer than the run pins the target afterwards: the routing
        // split below is fully deterministic
        let p = AutoscalePolicy {
            queue_shed: 0,
            min_dwell: 16,
            tick_every: 1,
            ..AutoscalePolicy::default()
        };
        let client = AdaptiveClient::new(
            router.lane("f32").unwrap(),
            router.lane("int8").unwrap(),
            p,
        );
        assert_eq!(client.target(), Target::F32);
        let x = crate::tensor::Tensor::full(&[1, 3, 8, 8], 0.5);
        for _ in 0..4 {
            client.infer(x.clone()).unwrap();
        }
        assert_eq!(client.target(), Target::Int8);
        let rep = client.report();
        assert_eq!(rep.routed_f32, 0, "first tick precedes first route");
        assert_eq!(rep.routed_int8, 4);
        assert_eq!(rep.transitions.len(), 1);
        assert!(rep.summary_line().contains("transition"));
        let j = rep.json("autoscale/test");
        assert!(j.contains("\"routed_int8\":4"), "{j}");
        router.shutdown();
    }
}
