//! Serving metrics: request latency, batch-size distribution, throughput,
//! and a live queue-depth gauge.
//!
//! Every server keeps one [`Metrics`]; the registry reports them per
//! `(model, variant)`. Storage is **constant-size**: both series live in
//! fixed log-bucket histograms ([`crate::obs::hist::Histogram`]), so an
//! always-on server records forever without the old 16 384-sample trim
//! cliff — counters and means are exact, percentiles are bucket upper
//! bounds (≤ ~2.2% relative error, see the `obs::hist` docs).
//!
//! Three consumption styles:
//!
//! * [`Metrics::snapshot`] — cumulative, for end-of-run reporting;
//! * [`Metrics::window_from`] — incremental windows over the latency
//!   stream, consumed by the serve-layer autoscaler
//!   ([`super::autoscale`]) to steer on *recent* behaviour. Windows are
//!   histogram differences against per-cursor checkpoints, so
//!   consecutive windows partition the stream **exactly** — a consumer
//!   arbitrarily far behind still sees every sample exactly once
//!   (previously a trim would silently eat the prefix);
//! * [`Metrics::exposition`] / [`Metrics::json_line`] — machine-readable
//!   export (Prometheus-style text, one-line JSON) rendered by
//!   [`crate::obs::export`]; `dfq serve --metrics-dump FILE` writes the
//!   former periodically.

use std::sync::Mutex;
use std::time::Instant;

use crate::obs::export::{json_escape, Exposition};
use crate::obs::hist::Histogram;
use crate::util::stats::Summary;

use super::batcher::Priority;

/// Checkpoints retained for [`Metrics::window_from`] consumers. Each is
/// one histogram (~9 KiB). A `Metrics` normally has one window consumer
/// (its autoscaler lane); with more than `MAX_CHECKPOINTS` concurrently
/// *stale* cursors the oldest falls back to a superset window — counts
/// stay exact, its percentiles then cover a slightly longer tail.
const MAX_CHECKPOINTS: usize = 8;

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    lat: Histogram,
    batch: Histogram,
    /// Per-SLO-class latency streams, indexed by [`Priority::idx`].
    /// `lat` stays the combined stream so [`Metrics::window_from`]
    /// consumers (the autoscaler) are unchanged.
    lat_class: [Histogram; 2],
    /// Latency samples recorded this epoch — the absolute stream
    /// position [`WindowCursor`]s index.
    total: usize,
    completed: u64,
    /// Submissions rejected by admission control (load shedding).
    shed: u64,
    /// Submissions that passed admission control.
    accepted: u64,
    /// Requests submitted but not yet pulled off the queue by the worker.
    depth: u64,
    /// Bumped by [`Metrics::reset`] so stale [`WindowCursor`]s are
    /// detected exactly rather than by index comparison.
    epoch: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
    /// Cumulative-histogram snapshots at cursor positions, ascending by
    /// `idx` (window = current histogram − checkpoint).
    checkpoints: Vec<Checkpoint>,
}

struct Checkpoint {
    idx: usize,
    lat: Histogram,
}

/// Opaque position in the recorded-latency stream, used to consume
/// disjoint windows via [`Metrics::window_from`]. `Default` starts at
/// the beginning; a cursor from before a [`Metrics::reset`] is detected
/// by epoch and restarts cleanly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WindowCursor {
    epoch: u64,
    idx: usize,
}

/// A point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub completed: u64,
    pub latency: Option<Summary>,
    pub batch_size: Option<Summary>,
    /// completed requests / wall seconds between first and last completion
    pub throughput: f64,
    /// Requests waiting in the queue at snapshot time.
    pub queue_depth: u64,
    /// Submissions rejected by admission control.
    pub shed: u64,
    /// Submissions that passed admission control.
    pub accepted: u64,
    /// Latency of the interactive SLO class only.
    pub latency_interactive: Option<Summary>,
    /// Latency of the batch SLO class only.
    pub latency_batch: Option<Summary>,
}

impl Metrics {
    pub fn record_batch(&self, batch: usize, latencies: &[f64]) {
        let mut m = self.inner.lock().unwrap();
        let now = Instant::now();
        m.started.get_or_insert(now);
        m.finished = Some(now);
        m.completed += latencies.len() as u64;
        m.batch.record(batch as f64);
        for &l in latencies {
            m.lat.record(l);
        }
        m.total += latencies.len();
    }

    /// Like [`Metrics::record_batch`] but each latency carries its SLO
    /// class: the combined stream records every sample (so windows and
    /// the existing quantiles are identical to the unclassed path) and
    /// each class additionally lands in its own histogram.
    pub fn record_batch_classed(
        &self,
        batch: usize,
        latencies: &[(f64, Priority)],
    ) {
        let mut m = self.inner.lock().unwrap();
        let now = Instant::now();
        m.started.get_or_insert(now);
        m.finished = Some(now);
        m.completed += latencies.len() as u64;
        m.batch.record(batch as f64);
        for &(l, p) in latencies {
            m.lat.record(l);
            m.lat_class[p.idx()].record(l);
        }
        m.total += latencies.len();
    }

    /// One submission rejected by admission control.
    pub fn shed_one(&self) {
        self.inner.lock().unwrap().shed += 1;
    }

    /// One submission admitted past admission control.
    pub fn accepted_one(&self) {
        self.inner.lock().unwrap().accepted += 1;
    }

    /// Total submissions rejected by admission control.
    pub fn shed(&self) -> u64 {
        self.inner.lock().unwrap().shed
    }

    /// Latency percentile of one SLO class (log-bucket upper bound,
    /// seconds; 0 when the class has no samples).
    pub fn class_percentile(&self, class: Priority, p: f64) -> f64 {
        let m = self.inner.lock().unwrap();
        m.lat_class[class.idx()].percentile(p)
    }

    /// One request entered the queue (called by `Client::submit`).
    pub fn enqueued(&self) {
        self.inner.lock().unwrap().depth += 1;
    }

    /// `n` requests left the queue (called by the worker when it pulls a
    /// batch). Saturating: a concurrent [`Metrics::reset`] must never
    /// underflow the gauge.
    pub fn dequeued(&self, n: u64) {
        let mut m = self.inner.lock().unwrap();
        m.depth = m.depth.saturating_sub(n);
    }

    /// Live queue depth (requests submitted but not yet picked up).
    pub fn queue_depth(&self) -> u64 {
        self.inner.lock().unwrap().depth
    }

    /// Drop all recorded samples (e.g. after a warm-up request). The
    /// queue-depth gauge survives — requests in flight are still in
    /// flight after a reset — and the window epoch advances so stale
    /// [`WindowCursor`]s restart instead of slicing a wrong window.
    pub fn reset(&self) {
        let mut m = self.inner.lock().unwrap();
        let (depth, epoch) = (m.depth, m.epoch);
        *m = Inner::default();
        m.depth = depth;
        m.epoch = epoch + 1;
    }

    /// Cumulative snapshot of everything recorded so far.
    ///
    /// # Example
    ///
    /// ```
    /// use dfq::serve::Metrics;
    ///
    /// let m = Metrics::default();
    /// m.record_batch(2, &[0.004, 0.006]);
    /// let snap = m.snapshot();
    /// assert_eq!(snap.completed, 2);
    /// assert!(snap.latency.unwrap().p95 >= 0.004);
    /// ```
    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        Snapshot {
            completed: m.completed,
            latency: m.lat.summary(),
            batch_size: m.batch.summary(),
            throughput: m.throughput(),
            queue_depth: m.depth,
            shed: m.shed,
            accepted: m.accepted,
            latency_interactive: m.lat_class
                [Priority::Interactive.idx()]
            .summary(),
            latency_batch: m.lat_class[Priority::Batch.idx()].summary(),
        }
    }

    /// Summary of the latencies recorded after `cursor`, plus the new
    /// cursor. Feed the returned cursor back in to consume disjoint
    /// windows; a cursor minted before a [`Metrics::reset`] is from an
    /// older epoch and restarts from the beginning of the new samples.
    ///
    /// Windows **partition the stream exactly**: the summary's `n`
    /// counts precisely the samples recorded since `cursor`, no matter
    /// how far behind the consumer fell (there is no longer a trimmed
    /// prefix to lose). Percentiles are bucket bounds over the window's
    /// histogram difference.
    pub fn window_from(
        &self,
        cursor: WindowCursor,
    ) -> (WindowCursor, Option<Summary>) {
        let mut m = self.inner.lock().unwrap();
        let total = m.total;
        let start = if cursor.epoch == m.epoch {
            cursor.idx.min(total)
        } else {
            0
        };
        let n = total - start;
        let summary = if n == 0 {
            None
        } else {
            // best checkpoint at or before the window start (idx 0 is
            // an implicit empty histogram); an evicted exact checkpoint
            // degrades to a superset window with the count kept exact
            let base = m
                .checkpoints
                .iter()
                .rev()
                .find(|c| c.idx <= start)
                .map(|c| &c.lat);
            let win = match base {
                Some(b) => m.lat.diff(b),
                None => m.lat.clone(),
            };
            win.summary().map(|mut s| {
                s.n = n;
                s
            })
        };
        // checkpoint the stream position the returned cursor names
        if m.checkpoints.last().map(|c| c.idx) != Some(total) {
            let snap = m.lat.clone();
            m.checkpoints.push(Checkpoint { idx: total, lat: snap });
            if m.checkpoints.len() > MAX_CHECKPOINTS {
                m.checkpoints.remove(0);
            }
        }
        (WindowCursor { epoch: m.epoch, idx: total }, summary)
    }

    /// Prometheus-style text exposition of everything this `Metrics`
    /// tracks (counters, gauges, quantile gauges, and the latency /
    /// batch-size histograms with exact bucket counts). `labels` are
    /// attached to every sample line.
    pub fn exposition(&self, labels: &[(&str, &str)]) -> String {
        let m = self.inner.lock().unwrap();
        let mut e = Exposition::new();
        e.counter(
            "dfq_requests_completed",
            "Requests completed since start (or last reset).",
            labels,
            m.completed as f64,
        );
        e.gauge(
            "dfq_queue_depth",
            "Requests submitted but not yet picked up by the worker.",
            labels,
            m.depth as f64,
        );
        e.gauge(
            "dfq_throughput_rps",
            "Completed requests per wall second (first to last completion).",
            labels,
            m.throughput(),
        );
        e.counter(
            "dfq_requests_shed",
            "Submissions rejected by admission control (load shedding).",
            labels,
            m.shed as f64,
        );
        e.counter(
            "dfq_requests_accepted",
            "Submissions admitted past admission control.",
            labels,
            m.accepted as f64,
        );
        let quantiles: Vec<(Vec<(&str, &str)>, f64)> =
            [("0.5", 50.0), ("0.95", 95.0), ("0.99", 99.0)]
                .iter()
                .map(|&(q, p)| {
                    let mut ls = labels.to_vec();
                    ls.push(("quantile", q));
                    (ls, m.lat.percentile(p))
                })
                .collect();
        let rows: Vec<(&[(&str, &str)], f64)> =
            quantiles.iter().map(|(ls, v)| (ls.as_slice(), *v)).collect();
        e.gauge_set(
            "dfq_latency_quantile_seconds",
            "Latency quantiles (log-bucket upper bounds).",
            &rows,
        );
        let mut class_q: Vec<(Vec<(&str, &str)>, f64)> = Vec::new();
        for c in [Priority::Interactive, Priority::Batch] {
            for (q, p) in [("0.95", 95.0), ("0.99", 99.0)] {
                let mut ls = labels.to_vec();
                ls.push(("class", c.as_str()));
                ls.push(("quantile", q));
                class_q.push((ls, m.lat_class[c.idx()].percentile(p)));
            }
        }
        let class_rows: Vec<(&[(&str, &str)], f64)> =
            class_q.iter().map(|(ls, v)| (ls.as_slice(), *v)).collect();
        e.gauge_set(
            "dfq_latency_class_quantile_seconds",
            "Per-SLO-class latency quantiles (log-bucket upper bounds).",
            &class_rows,
        );
        e.histogram(
            "dfq_latency_seconds",
            "Request latency from enqueue to reply.",
            labels,
            &m.lat,
        );
        e.histogram(
            "dfq_batch_size",
            "Executed batch sizes.",
            labels,
            &m.batch,
        );
        e.finish()
    }

    /// One-line JSON record of the cumulative state (the machine twin
    /// of [`Snapshot::report`]).
    pub fn json_line(&self, name: &str) -> String {
        let m = self.inner.lock().unwrap();
        format!(
            "{{\"name\":\"{}\",\"completed\":{},\"throughput\":{:.3},\
             \"queue_depth\":{},\"p50_s\":{:.6},\"p95_s\":{:.6},\
             \"p99_s\":{:.6},\"mean_batch\":{:.2},\"shed\":{},\
             \"accepted\":{},\"p99_interactive_s\":{:.6},\
             \"p99_batch_s\":{:.6}}}",
            json_escape(name),
            m.completed,
            m.throughput(),
            m.depth,
            m.lat.percentile(50.0),
            m.lat.percentile(95.0),
            m.lat.percentile(99.0),
            m.batch.mean(),
            m.shed,
            m.accepted,
            m.lat_class[Priority::Interactive.idx()].percentile(99.0),
            m.lat_class[Priority::Batch.idx()].percentile(99.0),
        )
    }
}

impl Inner {
    fn throughput(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => {
                self.completed as f64 / (b - a).as_secs_f64()
            }
            _ => 0.0,
        }
    }
}

impl Snapshot {
    pub fn report(&self) -> String {
        let lat = self
            .latency
            .as_ref()
            .map(|l| {
                format!(
                    "latency p50 {} p95 {}",
                    crate::util::bench::fmt_secs(l.p50),
                    crate::util::bench::fmt_secs(l.p95)
                )
            })
            .unwrap_or_else(|| "no requests".into());
        let bs = self
            .batch_size
            .as_ref()
            .map(|b| format!("mean batch {:.1}", b.mean))
            .unwrap_or_default();
        format!(
            "{} reqs  {:.1} req/s  {lat}  {bs}",
            self.completed, self.throughput
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::default();
        m.record_batch(4, &[0.01, 0.02, 0.03, 0.04]);
        m.record_batch(2, &[0.01, 0.01]);
        let s = m.snapshot();
        assert_eq!(s.completed, 6);
        assert_eq!(s.batch_size.as_ref().unwrap().n, 2);
        let lat = s.latency.unwrap();
        assert!(lat.mean > 0.0);
        assert_eq!(lat.n, 6);
        // percentiles are log-bucket upper bounds of the exact sample
        assert!(lat.p95 >= 0.04 && lat.p95 <= 0.04 * 1.03);
        assert!(s.report().contains("reqs"));
    }

    #[test]
    fn counters_stay_exact_without_sample_trimming() {
        let m = Metrics::default();
        let chunk = vec![0.001f64; 2048];
        let (mut cur, _) = m.window_from(WindowCursor::default());
        for _ in 0..12 {
            m.record_batch(chunk.len(), &chunk);
            let (c, w) = m.window_from(cur);
            assert_eq!(w.unwrap().n, chunk.len());
            cur = c;
        }
        // storage is constant-size histograms now: nothing was trimmed,
        // the cumulative summary covers every sample
        let snap = m.snapshot();
        assert_eq!(snap.completed, 12 * 2048);
        assert_eq!(snap.latency.unwrap().n, 12 * 2048);
        assert_eq!(snap.batch_size.unwrap().n, 12);
    }

    /// Regression for the former `MAX_SAMPLES` trim cliff: a cursor
    /// opened *before* what used to be the 16 384-sample trim boundary
    /// still partitions the stream exactly — no samples vanish from its
    /// window, and successive windows tile the stream.
    #[test]
    fn stale_cursors_partition_the_stream_exactly() {
        let m = Metrics::default();
        let (c0, w) = m.window_from(WindowCursor::default());
        assert!(w.is_none());
        // blow far past the former trim boundary while c0 sleeps
        let chunk = vec![0.002f64; 4096];
        for _ in 0..6 {
            m.record_batch(chunk.len(), &chunk);
        }
        let (c1, w1) = m.window_from(c0);
        let w1 = w1.unwrap();
        assert_eq!(w1.n, 6 * 4096, "stale window lost samples to a trim");
        assert!((w1.mean - 0.002).abs() < 1e-9);
        m.record_batch(100, &vec![0.004f64; 100]);
        let (_, w2) = m.window_from(c1);
        let w2 = w2.unwrap();
        assert_eq!(w2.n, 100, "windows must tile the stream");
        assert!((w2.mean - 0.004).abs() < 1e-9);
        // the windows partition everything ever recorded
        assert_eq!(
            w1.n + w2.n,
            m.snapshot().completed as usize,
            "window n's must sum to the stream length"
        );
        // a second consumer starting from scratch sees the whole stream
        let (_, wall) = m.window_from(WindowCursor::default());
        assert_eq!(wall.unwrap().n, 6 * 4096 + 100);
    }

    #[test]
    fn interleaved_consumers_each_get_exact_counts() {
        let m = Metrics::default();
        let (mut a, _) = m.window_from(WindowCursor::default());
        let (mut b, _) = m.window_from(WindowCursor::default());
        let mut seen_a = 0;
        let mut seen_b = 0;
        for round in 0..10 {
            m.record_batch(8, &[0.001; 8]);
            if round % 2 == 0 {
                let (c, w) = m.window_from(a);
                a = c;
                seen_a += w.map(|s| s.n).unwrap_or(0);
            }
            if round % 3 == 0 {
                let (c, w) = m.window_from(b);
                b = c;
                seen_b += w.map(|s| s.n).unwrap_or(0);
            }
        }
        let (_, wa) = m.window_from(a);
        let (_, wb) = m.window_from(b);
        seen_a += wa.map(|s| s.n).unwrap_or(0);
        seen_b += wb.map(|s| s.n).unwrap_or(0);
        assert_eq!(seen_a, 80, "consumer A missed or double-counted");
        assert_eq!(seen_b, 80, "consumer B missed or double-counted");
    }

    #[test]
    fn queue_depth_gauge_tracks_and_saturates() {
        let m = Metrics::default();
        assert_eq!(m.queue_depth(), 0);
        m.enqueued();
        m.enqueued();
        m.enqueued();
        assert_eq!(m.queue_depth(), 3);
        m.dequeued(2);
        assert_eq!(m.queue_depth(), 1);
        m.dequeued(10); // saturating, never underflows
        assert_eq!(m.queue_depth(), 0);
        // reset keeps the gauge (in-flight work is still in flight)
        m.enqueued();
        m.record_batch(1, &[0.01]);
        m.reset();
        assert_eq!(m.queue_depth(), 1);
        assert_eq!(m.snapshot().completed, 0);
    }

    #[test]
    fn windows_are_disjoint_and_reset_safe() {
        let m = Metrics::default();
        let (c0, w0) = m.window_from(WindowCursor::default());
        assert!(w0.is_none());
        m.record_batch(2, &[0.01, 0.03]);
        let (c1, w1) = m.window_from(c0);
        let w1 = w1.unwrap();
        assert_eq!(w1.n, 2);
        assert!((w1.mean - 0.02).abs() < 1e-12);
        // no new samples -> empty window
        let (c2, w2) = m.window_from(c1);
        assert!(w2.is_none());
        // only the new tail shows up
        m.record_batch(1, &[0.07]);
        let (c3, w3) = m.window_from(c2);
        assert_eq!(w3.unwrap().n, 1);
        // a stale cursor after reset restarts from the first post-reset
        // sample — even when the new stream is already *longer* than the
        // old cursor position (epoch detection, not index comparison)
        m.reset();
        m.record_batch(4, &[0.05, 0.05, 0.05, 0.05]);
        let (c4, w4) = m.window_from(c3);
        assert_eq!(w4.unwrap().n, 4, "post-reset samples were skipped");
        // and the refreshed cursor consumes disjointly again
        let (_, w5) = m.window_from(c4);
        assert!(w5.is_none());
    }

    #[test]
    fn classed_recording_splits_streams_and_counts_sheds() {
        let m = Metrics::default();
        m.record_batch_classed(
            3,
            &[
                (0.002, Priority::Interactive),
                (0.004, Priority::Interactive),
                (0.100, Priority::Batch),
            ],
        );
        m.shed_one();
        m.shed_one();
        m.accepted_one();
        let s = m.snapshot();
        assert_eq!(s.completed, 3);
        assert_eq!(s.shed, 2);
        assert_eq!(s.accepted, 1);
        // combined stream sees every sample; classes split exactly
        assert_eq!(s.latency.unwrap().n, 3);
        let li = s.latency_interactive.unwrap();
        let lb = s.latency_batch.unwrap();
        assert_eq!((li.n, lb.n), (2, 1));
        assert!(li.p95 < lb.p95, "interactive class absorbed batch work");
        // classed recording feeds the same windows as the plain path
        let (_, w) = m.window_from(WindowCursor::default());
        assert_eq!(w.unwrap().n, 3);
        // new counters and class quantiles render in the exposition
        let text = m.exposition(&[("model", "alpha")]);
        crate::obs::export::check_exposition(&text).unwrap();
        assert!(text.contains("dfq_requests_shed"));
        assert!(text.contains("dfq_requests_accepted"));
        assert!(text.contains("class=\"interactive\""));
        assert!(text.contains("class=\"batch\""));
        let line = m.json_line("serve/alpha");
        crate::obs::export::check_json_lines(&line).unwrap();
        assert!(line.contains("\"shed\":2"));
        assert!(line.contains("\"p99_interactive_s\""));
        // reset clears the class histograms and counters too
        m.reset();
        let s2 = m.snapshot();
        assert_eq!((s2.shed, s2.accepted), (0, 0));
        assert!(s2.latency_interactive.is_none());
    }

    #[test]
    fn exposition_and_json_line_are_well_formed() {
        let m = Metrics::default();
        m.record_batch(4, &[0.002, 0.004, 0.008, 0.016]);
        m.enqueued();
        let text =
            m.exposition(&[("model", "alpha"), ("variant", "int8")]);
        crate::obs::export::check_exposition(&text)
            .expect("live exposition must pass the format checker");
        assert!(text.contains("dfq_requests_completed"));
        assert!(text.contains("dfq_latency_seconds_bucket"));
        assert!(text.contains("variant=\"int8\""));
        assert!(text.contains("quantile=\"0.99\""));
        let line = m.json_line("serve/alpha/int8");
        crate::obs::export::check_json_lines(&line).unwrap();
        assert!(line.contains("\"completed\":4"));
        // empty metrics still render validly
        let empty = Metrics::default();
        crate::obs::export::check_exposition(&empty.exposition(&[]))
            .unwrap();
        crate::obs::export::check_json_lines(&empty.json_line("x"))
            .unwrap();
    }
}
