//! Serving metrics: request latency, batch-size distribution, throughput.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Summary;

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    latencies: Vec<f64>,
    batch_sizes: Vec<f64>,
    completed: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// A point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub completed: u64,
    pub latency: Option<Summary>,
    pub batch_size: Option<Summary>,
    /// completed requests / wall seconds between first and last completion
    pub throughput: f64,
}

impl Metrics {
    pub fn record_batch(&self, batch: usize, latencies: &[f64]) {
        let mut m = self.inner.lock().unwrap();
        let now = Instant::now();
        m.started.get_or_insert(now);
        m.finished = Some(now);
        m.completed += latencies.len() as u64;
        m.batch_sizes.push(batch as f64);
        m.latencies.extend_from_slice(latencies);
    }

    /// Drop all recorded samples (e.g. after a warm-up request).
    pub fn reset(&self) {
        let mut m = self.inner.lock().unwrap();
        *m = Inner::default();
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        let wall = match (m.started, m.finished) {
            (Some(a), Some(b)) if b > a => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        Snapshot {
            completed: m.completed,
            latency: if m.latencies.is_empty() {
                None
            } else {
                Some(Summary::of(&m.latencies))
            },
            batch_size: if m.batch_sizes.is_empty() {
                None
            } else {
                Some(Summary::of(&m.batch_sizes))
            },
            throughput: if wall > 0.0 {
                m.completed as f64 / wall
            } else {
                0.0
            },
        }
    }
}

impl Snapshot {
    pub fn report(&self) -> String {
        let lat = self
            .latency
            .as_ref()
            .map(|l| {
                format!(
                    "latency p50 {} p95 {}",
                    crate::util::bench::fmt_secs(l.p50),
                    crate::util::bench::fmt_secs(l.p95)
                )
            })
            .unwrap_or_else(|| "no requests".into());
        let bs = self
            .batch_size
            .as_ref()
            .map(|b| format!("mean batch {:.1}", b.mean))
            .unwrap_or_default();
        format!(
            "{} reqs  {:.1} req/s  {lat}  {bs}",
            self.completed, self.throughput
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::default();
        m.record_batch(4, &[0.01, 0.02, 0.03, 0.04]);
        m.record_batch(2, &[0.01, 0.01]);
        let s = m.snapshot();
        assert_eq!(s.completed, 6);
        assert_eq!(s.batch_size.as_ref().unwrap().n, 2);
        assert!(s.latency.unwrap().mean > 0.0);
        assert!(s.report().contains("reqs"));
    }
}
