//! Serving metrics: request latency, batch-size distribution, throughput,
//! and a live queue-depth gauge.
//!
//! Every server keeps one [`Metrics`]; the registry reports them per
//! `(model, variant)`. Two consumption styles:
//!
//! * [`Metrics::snapshot`] — cumulative, for end-of-run reporting;
//! * [`Metrics::window_from`] — incremental windows over the recorded
//!   latencies, consumed by the serve-layer autoscaler
//!   ([`super::autoscale`]) to make steering decisions on *recent*
//!   behaviour rather than the whole history.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::Summary;

/// Retained samples per series. An always-on server must not grow
/// without bound, so once a series exceeds this the oldest half is
/// discarded: counters (`completed`, throughput) stay exact, summaries
/// cover the retained tail. At ~8 B/sample this bounds each series to
/// ~128 KiB.
const MAX_SAMPLES: usize = 16_384;

#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    latencies: Vec<f64>,
    batch_sizes: Vec<f64>,
    /// Latency samples discarded from the front of `latencies` —
    /// [`WindowCursor`]s index the *absolute* sample stream, so trims
    /// never shift a consumer's window.
    trimmed: usize,
    completed: u64,
    /// Requests submitted but not yet pulled off the queue by the worker.
    depth: u64,
    /// Bumped by [`Metrics::reset`] so stale [`WindowCursor`]s are
    /// detected exactly rather than by index comparison.
    epoch: u64,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// Opaque position in the recorded-latency stream, used to consume
/// disjoint windows via [`Metrics::window_from`]. `Default` starts at
/// the beginning; a cursor from before a [`Metrics::reset`] is detected
/// by epoch and restarts cleanly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WindowCursor {
    epoch: u64,
    idx: usize,
}

/// A point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub completed: u64,
    pub latency: Option<Summary>,
    pub batch_size: Option<Summary>,
    /// completed requests / wall seconds between first and last completion
    pub throughput: f64,
    /// Requests waiting in the queue at snapshot time.
    pub queue_depth: u64,
}

impl Metrics {
    pub fn record_batch(&self, batch: usize, latencies: &[f64]) {
        let mut m = self.inner.lock().unwrap();
        let now = Instant::now();
        m.started.get_or_insert(now);
        m.finished = Some(now);
        m.completed += latencies.len() as u64;
        m.batch_sizes.push(batch as f64);
        m.latencies.extend_from_slice(latencies);
        if m.latencies.len() > MAX_SAMPLES {
            let drop = m.latencies.len() - MAX_SAMPLES / 2;
            m.latencies.drain(..drop);
            m.trimmed += drop;
        }
        if m.batch_sizes.len() > MAX_SAMPLES {
            let drop = m.batch_sizes.len() - MAX_SAMPLES / 2;
            m.batch_sizes.drain(..drop);
        }
    }

    /// One request entered the queue (called by `Client::submit`).
    pub fn enqueued(&self) {
        self.inner.lock().unwrap().depth += 1;
    }

    /// `n` requests left the queue (called by the worker when it pulls a
    /// batch). Saturating: a concurrent [`Metrics::reset`] must never
    /// underflow the gauge.
    pub fn dequeued(&self, n: u64) {
        let mut m = self.inner.lock().unwrap();
        m.depth = m.depth.saturating_sub(n);
    }

    /// Live queue depth (requests submitted but not yet picked up).
    pub fn queue_depth(&self) -> u64 {
        self.inner.lock().unwrap().depth
    }

    /// Drop all recorded samples (e.g. after a warm-up request). The
    /// queue-depth gauge survives — requests in flight are still in
    /// flight after a reset — and the window epoch advances so stale
    /// [`WindowCursor`]s restart instead of slicing a wrong window.
    pub fn reset(&self) {
        let mut m = self.inner.lock().unwrap();
        let (depth, epoch) = (m.depth, m.epoch);
        *m = Inner::default();
        m.depth = depth;
        m.epoch = epoch + 1;
    }

    /// Cumulative snapshot of everything recorded so far.
    ///
    /// # Example
    ///
    /// ```
    /// use dfq::serve::Metrics;
    ///
    /// let m = Metrics::default();
    /// m.record_batch(2, &[0.004, 0.006]);
    /// let snap = m.snapshot();
    /// assert_eq!(snap.completed, 2);
    /// assert!(snap.latency.unwrap().p95 >= 0.004);
    /// ```
    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        let wall = match (m.started, m.finished) {
            (Some(a), Some(b)) if b > a => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        Snapshot {
            completed: m.completed,
            latency: if m.latencies.is_empty() {
                None
            } else {
                Some(Summary::of(&m.latencies))
            },
            batch_size: if m.batch_sizes.is_empty() {
                None
            } else {
                Some(Summary::of(&m.batch_sizes))
            },
            throughput: if wall > 0.0 {
                m.completed as f64 / wall
            } else {
                0.0
            },
            queue_depth: m.depth,
        }
    }

    /// Summary of the latencies recorded after `cursor`, plus the new
    /// cursor. Feed the returned cursor back in to consume disjoint
    /// windows; a cursor minted before a [`Metrics::reset`] is from an
    /// older epoch and restarts from the beginning of the new samples.
    /// A consumer that falls more than `MAX_SAMPLES`' worth behind
    /// sees the retained tail (the trimmed prefix is gone).
    pub fn window_from(
        &self,
        cursor: WindowCursor,
    ) -> (WindowCursor, Option<Summary>) {
        let m = self.inner.lock().unwrap();
        let abs_len = m.trimmed + m.latencies.len();
        let start_abs = if cursor.epoch == m.epoch {
            cursor.idx.min(abs_len)
        } else {
            m.trimmed
        };
        let rel = start_abs.saturating_sub(m.trimmed);
        let summary = if rel < m.latencies.len() {
            Some(Summary::of(&m.latencies[rel..]))
        } else {
            None
        };
        (WindowCursor { epoch: m.epoch, idx: abs_len }, summary)
    }
}

impl Snapshot {
    pub fn report(&self) -> String {
        let lat = self
            .latency
            .as_ref()
            .map(|l| {
                format!(
                    "latency p50 {} p95 {}",
                    crate::util::bench::fmt_secs(l.p50),
                    crate::util::bench::fmt_secs(l.p95)
                )
            })
            .unwrap_or_else(|| "no requests".into());
        let bs = self
            .batch_size
            .as_ref()
            .map(|b| format!("mean batch {:.1}", b.mean))
            .unwrap_or_default();
        format!(
            "{} reqs  {:.1} req/s  {lat}  {bs}",
            self.completed, self.throughput
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::default();
        m.record_batch(4, &[0.01, 0.02, 0.03, 0.04]);
        m.record_batch(2, &[0.01, 0.01]);
        let s = m.snapshot();
        assert_eq!(s.completed, 6);
        assert_eq!(s.batch_size.as_ref().unwrap().n, 2);
        assert!(s.latency.unwrap().mean > 0.0);
        assert!(s.report().contains("reqs"));
    }

    #[test]
    fn sample_history_is_bounded_and_cursors_survive_trimming() {
        let m = Metrics::default();
        let chunk = vec![0.001f64; 2048];
        let (mut cur, _) = m.window_from(WindowCursor::default());
        for _ in 0..12 {
            m.record_batch(chunk.len(), &chunk);
            let (c, w) = m.window_from(cur);
            assert_eq!(
                w.unwrap().n,
                chunk.len(),
                "a kept-up consumer's window must not be affected by trims"
            );
            cur = c;
        }
        // counters stay exact; the retained series is bounded
        let snap = m.snapshot();
        assert_eq!(snap.completed, 12 * 2048);
        assert!(snap.latency.unwrap().n <= 16_384);
        assert!(snap.batch_size.unwrap().n <= 16_384);
        // a consumer that fell behind the trim sees the retained tail
        let (_, w) = m.window_from(WindowCursor::default());
        let n = w.unwrap().n;
        assert!(n <= 16_384 && n > 0, "stale-consumer window n = {n}");
    }

    #[test]
    fn queue_depth_gauge_tracks_and_saturates() {
        let m = Metrics::default();
        assert_eq!(m.queue_depth(), 0);
        m.enqueued();
        m.enqueued();
        m.enqueued();
        assert_eq!(m.queue_depth(), 3);
        m.dequeued(2);
        assert_eq!(m.queue_depth(), 1);
        m.dequeued(10); // saturating, never underflows
        assert_eq!(m.queue_depth(), 0);
        // reset keeps the gauge (in-flight work is still in flight)
        m.enqueued();
        m.record_batch(1, &[0.01]);
        m.reset();
        assert_eq!(m.queue_depth(), 1);
        assert_eq!(m.snapshot().completed, 0);
    }

    #[test]
    fn windows_are_disjoint_and_reset_safe() {
        let m = Metrics::default();
        let (c0, w0) = m.window_from(WindowCursor::default());
        assert!(w0.is_none());
        m.record_batch(2, &[0.01, 0.03]);
        let (c1, w1) = m.window_from(c0);
        let w1 = w1.unwrap();
        assert_eq!(w1.n, 2);
        assert!((w1.mean - 0.02).abs() < 1e-12);
        // no new samples -> empty window
        let (c2, w2) = m.window_from(c1);
        assert!(w2.is_none());
        // only the new tail shows up
        m.record_batch(1, &[0.07]);
        let (c3, w3) = m.window_from(c2);
        assert_eq!(w3.unwrap().n, 1);
        // a stale cursor after reset restarts from the first post-reset
        // sample — even when the new stream is already *longer* than the
        // old cursor position (epoch detection, not index comparison)
        m.reset();
        m.record_batch(4, &[0.05, 0.05, 0.05, 0.05]);
        let (c4, w4) = m.window_from(c3);
        assert_eq!(w4.unwrap().n, 4, "post-reset samples were skipped");
        // and the refreshed cursor consumes disjointly again
        let (_, w5) = m.window_from(c4);
        assert!(w5.is_none());
    }
}
