//! Dense f32 tensors (row-major, NCHW convention for feature maps).
//!
//! Deliberately minimal: the DFQ passes need per-channel views, basic
//! reductions and elementwise maps; the heavy compute lives either in the
//! AOT-compiled PJRT executables or in [`crate::nn`]. Integer tensors
//! (the true int8 execution path) live in [`qtensor`].

pub mod qtensor;

pub use qtensor::{QData, QTensor};

use anyhow::{bail, Result};

/// A dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} vs data len {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(data: Vec<f32>) -> Tensor {
        Tensor { shape: vec![data.len()], data }
    }

    // -- accessors ----------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }

    /// Reshape (must preserve element count).
    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        if shape.iter().product::<usize>() != self.data.len() {
            bail!("reshape {:?} -> {:?} changes element count", self.shape, shape);
        }
        Ok(Tensor { shape: shape.to_vec(), data: self.data.clone() })
    }

    // -- elementwise / reductions -------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for x in &mut self.data {
            *x = f(*x);
        }
    }

    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0f32, |m, &x| m.max(x.abs()))
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.data.iter().map(|&x| x as f64).sum::<f64>()
            / self.data.len() as f64) as f32
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }

    // -- channel views (weights are OIHW; feature maps NCHW) ------------------

    /// Elements of output-channel `o` of an OIHW weight (or O-major 2-D
    /// weight): contiguous slice of length `len / shape[0]`.
    pub fn out_channel(&self, o: usize) -> &[f32] {
        let per = self.data.len() / self.shape[0];
        &self.data[o * per..(o + 1) * per]
    }

    pub fn out_channel_mut(&mut self, o: usize) -> &mut [f32] {
        let per = self.data.len() / self.shape[0];
        &mut self.data[o * per..(o + 1) * per]
    }

    /// Per-output-channel (min, max) over an O-major weight tensor.
    pub fn channel_ranges(&self) -> Vec<(f32, f32)> {
        (0..self.shape[0])
            .map(|o| {
                let ch = self.out_channel(o);
                let mut lo = f32::INFINITY;
                let mut hi = f32::NEG_INFINITY;
                for &x in ch {
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
                (lo, hi)
            })
            .collect()
    }

    /// Scale all weights of input-channel `i` (dim 1 of OIHW / dim 1 of
    /// [O, I] linear weights) by `s`.
    pub fn scale_in_channel(&mut self, i: usize, s: f32) {
        let o_count = self.shape[0];
        let i_count = self.shape[1];
        let spatial: usize = self.shape[2..].iter().product();
        for o in 0..o_count {
            let base = (o * i_count + i) * spatial;
            for x in &mut self.data[base..base + spatial] {
                *x *= s;
            }
        }
    }

    /// Scale all weights of output-channel `o` by `s`.
    pub fn scale_out_channel(&mut self, o: usize, s: f32) {
        for x in self.out_channel_mut(o) {
            *x *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.dim(1), 3);
        let r = t.reshape(&[3, 2]).unwrap();
        assert_eq!(r.shape(), &[3, 2]);
        assert!(t.reshape(&[4]).is_err());
    }

    #[test]
    fn reductions() {
        let t = Tensor::from_vec(vec![-3.0, 1.0, 2.0]);
        assert_eq!(t.min(), -3.0);
        assert_eq!(t.max(), 2.0);
        assert_eq!(t.abs_max(), 3.0);
        assert!((t.mean() - 0.0).abs() < 1e-6);
    }

    #[test]
    fn channel_ops() {
        // OIHW = [2, 2, 1, 1]
        let mut w = Tensor::new(&[2, 2, 1, 1], vec![1., 2., 3., 4.]);
        assert_eq!(w.out_channel(1), &[3., 4.]);
        assert_eq!(w.channel_ranges(), vec![(1., 2.), (3., 4.)]);
        w.scale_out_channel(0, 2.0);
        assert_eq!(w.out_channel(0), &[2., 4.]);
        w.scale_in_channel(1, 10.0);
        assert_eq!(w.data(), &[2., 40., 3., 40.]);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::from_vec(vec![1.0, 2.0]);
        let b = Tensor::from_vec(vec![1.5, 1.0]);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
