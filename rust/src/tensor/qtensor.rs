//! Quantized tensors: u8/i8 integer grids + affine parameters.
//!
//! A [`QTensor`] stores the *actual integer codes* of a quantised tensor
//! rather than their dequantised f32 images — the representation the
//! integer engine ([`crate::nn::qengine`]) executes on. Codes live on the
//! unsigned grid `q ∈ [0, n_levels-1]` of [`QParams`]; the signed storage
//! variant keeps `q - 128` in `i8` (the layout the u8×i8→i32 GEMM wants
//! for weights) and is transparent to `dequantize`.

use anyhow::{bail, Result};

use crate::quant::QParams;

use super::Tensor;

/// Integer payload of a [`QTensor`].
#[derive(Debug, Clone, PartialEq)]
pub enum QData {
    /// Unsigned grid codes `q` (activations).
    U8(Vec<u8>),
    /// Offset grid codes `q - 128` (weights for the u8×i8 GEMM).
    I8(Vec<i8>),
}

impl QData {
    pub fn len(&self) -> usize {
        match self {
            QData::U8(v) => v.len(),
            QData::I8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A quantised dense tensor: integer codes + one grid per tensor or per
/// output channel (dim 0, matching [`Tensor::out_channel`]).
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    shape: Vec<usize>,
    data: QData,
    /// One entry (per-tensor) or `shape[0]` entries (per-channel).
    params: Vec<QParams>,
}

fn check_params(shape: &[usize], params: &[QParams]) -> Result<()> {
    let per_channel_len = shape.first().copied().unwrap_or(1);
    if params.len() != 1 && params.len() != per_channel_len {
        bail!(
            "QTensor wants 1 or {} grids for shape {:?}, got {}",
            per_channel_len,
            shape,
            params.len()
        );
    }
    for p in params {
        if !(2.0..=256.0).contains(&p.n_levels) {
            bail!(
                "QTensor requires 2..=256 levels (8-bit storage), got {}",
                p.n_levels
            );
        }
        if !(p.scale > 0.0) || !p.scale.is_finite() {
            bail!("QTensor requires a positive finite scale, got {}", p.scale);
        }
        if p.zero_point.fract() != 0.0
            || p.zero_point < 0.0
            || p.zero_point > p.n_levels - 1.0
        {
            bail!(
                "QTensor zero point {} not an integer on [0, {}]",
                p.zero_point,
                p.n_levels - 1.0
            );
        }
    }
    Ok(())
}

/// Grid code of one value — bit-identical rounding/clamping to
/// [`crate::nn::ops::fake_quant_scalar`]. The single in-crate source of
/// the f32→code map (also used by the qengine's activation quantiser).
#[inline]
pub(crate) fn code_of(x: f32, p: &QParams) -> u8 {
    let q = (x / p.scale).round_ties_even() + p.zero_point;
    q.clamp(0.0, p.n_levels - 1.0) as u8
}

impl QTensor {
    /// Pack an f32 tensor onto the given grid(s). `params` holds one grid
    /// (per-tensor) or `shape[0]` grids (per-channel along dim 0).
    /// `signed` selects i8 offset storage (`q - 128`).
    pub fn quantize(
        t: &Tensor,
        params: &[QParams],
        signed: bool,
    ) -> Result<QTensor> {
        check_params(t.shape(), params)?;
        let n = t.len();
        let per = if params.len() == 1 {
            n
        } else {
            n / params.len().max(1)
        };
        let grid =
            |i: usize| &params[if params.len() == 1 { 0 } else { i / per }];
        let data = if signed {
            QData::I8(
                t.data()
                    .iter()
                    .enumerate()
                    .map(|(i, &x)| (code_of(x, grid(i)) as i16 - 128) as i8)
                    .collect(),
            )
        } else {
            QData::U8(
                t.data()
                    .iter()
                    .enumerate()
                    .map(|(i, &x)| code_of(x, grid(i)))
                    .collect(),
            )
        };
        Ok(QTensor { shape: t.shape().to_vec(), data, params: params.to_vec() })
    }

    /// Wrap pre-computed unsigned codes (e.g. from an activation kernel).
    pub fn from_codes_u8(
        shape: &[usize],
        codes: Vec<u8>,
        params: Vec<QParams>,
    ) -> Result<QTensor> {
        if shape.iter().product::<usize>() != codes.len() {
            bail!("shape {:?} vs {} codes", shape, codes.len());
        }
        check_params(shape, &params)?;
        Ok(QTensor { shape: shape.to_vec(), data: QData::U8(codes), params })
    }

    /// Wrap pre-computed signed offset codes (e.g. a spatially flipped
    /// weight layout re-using the original per-channel grids).
    pub fn from_codes_i8(
        shape: &[usize],
        codes: Vec<i8>,
        params: Vec<QParams>,
    ) -> Result<QTensor> {
        if shape.iter().product::<usize>() != codes.len() {
            bail!("shape {:?} vs {} codes", shape, codes.len());
        }
        check_params(shape, &params)?;
        Ok(QTensor { shape: shape.to_vec(), data: QData::I8(codes), params })
    }

    /// Unpack to f32 — the exact fake-quantised image of the source
    /// tensor (same rounding as [`crate::nn::ops::fake_quant`]).
    pub fn dequantize(&self) -> Tensor {
        let n = self.data.len();
        let per = if self.params.len() == 1 {
            n
        } else {
            n / self.params.len().max(1)
        };
        let grid = |i: usize| {
            &self.params[if self.params.len() == 1 { 0 } else { i / per }]
        };
        let data: Vec<f32> = match &self.data {
            QData::U8(v) => v
                .iter()
                .enumerate()
                .map(|(i, &q)| {
                    let p = grid(i);
                    (q as f32 - p.zero_point) * p.scale
                })
                .collect(),
            QData::I8(v) => v
                .iter()
                .enumerate()
                .map(|(i, &q)| {
                    let p = grid(i);
                    ((q as i16 + 128) as f32 - p.zero_point) * p.scale
                })
                .collect(),
        };
        Tensor::new(&self.shape, data)
    }

    // -- accessors ----------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn params(&self) -> &[QParams] {
        &self.params
    }

    pub fn per_channel(&self) -> bool {
        self.params.len() > 1
    }

    /// Grid of output-channel `o` (per-tensor grids broadcast).
    pub fn param_for_channel(&self, o: usize) -> &QParams {
        if self.params.len() == 1 {
            &self.params[0]
        } else {
            &self.params[o]
        }
    }

    /// Storage kind of the codes — "u8" (unsigned grid) or "i8" (offset
    /// grid). Surfaced by engine pack errors and plan summaries.
    pub fn storage(&self) -> &'static str {
        match &self.data {
            QData::U8(_) => "u8",
            QData::I8(_) => "i8",
        }
    }

    pub fn codes_u8(&self) -> Option<&[u8]> {
        match &self.data {
            QData::U8(v) => Some(v),
            QData::I8(_) => None,
        }
    }

    pub fn codes_i8(&self) -> Option<&[i8]> {
        match &self.data {
            QData::I8(v) => Some(v),
            QData::U8(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ops::fake_quant_scalar;
    use crate::quant::{params_for_range, quantize_weights, QScheme};
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded_per_tensor() {
        let mut rng = Rng::new(11);
        let t = Tensor::new(&[4, 8], rng.normal_vec(32, 1.5));
        let p = params_for_range(t.min(), t.max(), 8, false);
        for signed in [false, true] {
            let q = QTensor::quantize(&t, &[p], signed).unwrap();
            let back = q.dequantize();
            assert!(back.max_abs_diff(&t) <= p.scale / 2.0 + 1e-6);
        }
    }

    #[test]
    fn matches_fake_quant_bit_exactly() {
        let mut rng = Rng::new(12);
        let t = Tensor::new(&[3, 5], rng.normal_vec(15, 2.0));
        let p = params_for_range(t.min(), t.max(), 6, false);
        let q = QTensor::quantize(&t, &[p], true).unwrap();
        let back = q.dequantize();
        for (i, &x) in t.data().iter().enumerate() {
            let want = fake_quant_scalar(x, p.scale, p.zero_point, p.n_levels);
            assert_eq!(back.data()[i], want, "element {i}");
        }
    }

    #[test]
    fn per_channel_roundtrip() {
        let mut rng = Rng::new(13);
        let mut t = Tensor::new(&[4, 6], rng.normal_vec(24, 1.0));
        // wildly different channel scales
        for o in 0..4 {
            t.scale_out_channel(o, 10f32.powi(o as i32 - 2));
        }
        let mut fq = t.clone();
        let ps = quantize_weights(&mut fq, &QScheme::per_channel(8));
        let q = QTensor::quantize(&t, &ps, true).unwrap();
        assert!(q.per_channel());
        assert_eq!(q.dequantize(), fq);
    }

    #[test]
    fn rejects_bad_grids() {
        let t = Tensor::from_vec(vec![1.0, 2.0]);
        let bad_levels =
            QParams { scale: 0.1, zero_point: 0.0, n_levels: 1024.0 };
        assert!(QTensor::quantize(&t, &[bad_levels], false).is_err());
        let bad_zp = QParams { scale: 0.1, zero_point: 3.5, n_levels: 256.0 };
        assert!(QTensor::quantize(&t, &[bad_zp], false).is_err());
        let p = QParams { scale: 0.1, zero_point: 0.0, n_levels: 256.0 };
        assert!(QTensor::quantize(&t, &[p, p, p], false).is_err());
    }
}
