//! Experiment drivers — one per paper table/figure (DESIGN.md §4).
//!
//! Each driver regenerates the corresponding table or figure series on
//! the SynthShapes substitutes, printing paper-style rows and saving a
//! CSV under `results/`. Shared by the CLI (`dfq table 1`), the examples
//! and the bench targets.

pub mod figures;
pub mod tables;

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{Context as _, Result};

use crate::dfq::{quantize_data_free, BiasCorrMode, DfqConfig};
use crate::eval::{evaluate, Backend};
use crate::graph::io::Dataset;
use crate::graph::Model;
use crate::nn::QuantCfg;
use crate::quant::QScheme;
use crate::runtime::{Executable, Manifest, Runtime};
use crate::tensor::Tensor;
use crate::util::table::Table;

/// Evaluation backend preference (env `DFQ_BACKEND=engine|pjrt`).
fn backend_pref() -> &'static str {
    match std::env::var("DFQ_BACKEND").as_deref() {
        Ok("engine") => "engine",
        _ => "pjrt",
    }
}

/// Per-run evaluation size (env `DFQ_EVAL_LIMIT`, default: full test set).
fn eval_limit() -> Option<usize> {
    std::env::var("DFQ_EVAL_LIMIT").ok().and_then(|s| s.parse().ok())
}

/// Shared state for experiment drivers: manifest, PJRT runtime, loaded
/// datasets/models and compiled executables (cached per arch).
pub struct Context {
    pub manifest: Manifest,
    runtime: Option<Runtime>,
    datasets: HashMap<String, Dataset>,
    calib: HashMap<String, Dataset>,
    models: HashMap<String, Model>,
    execs: HashMap<String, Executable>,
    pub eval_batch: usize,
}

impl Context {
    pub fn new() -> Result<Context> {
        let manifest = Manifest::load(crate::artifacts_dir())?;
        let runtime = if backend_pref() == "pjrt" {
            Some(Runtime::cpu().context("creating PJRT CPU client")?)
        } else {
            None
        };
        Ok(Context {
            manifest,
            runtime,
            datasets: HashMap::new(),
            calib: HashMap::new(),
            models: HashMap::new(),
            execs: HashMap::new(),
            eval_batch: 64,
        })
    }

    /// The corrupted "pretrained original" model of an architecture.
    pub fn model(&mut self, arch: &str) -> Result<Model> {
        if let Some(m) = self.models.get(arch) {
            return Ok(m.clone());
        }
        let entry = self.manifest.arch(arch)?;
        let m = Model::load(self.manifest.path(&entry.model))?;
        self.models.insert(arch.to_string(), m.clone());
        Ok(m)
    }

    pub fn dataset(&mut self, task: &str) -> Result<&Dataset> {
        if !self.datasets.contains_key(task) {
            let ds = Dataset::load(self.manifest.dataset(task, "test")?)?;
            self.datasets.insert(task.to_string(), ds);
        }
        Ok(&self.datasets[task])
    }

    /// Calibration batch (empirical bias correction), limited to 128
    /// images to keep the reference engine tractable on one core.
    pub fn calib_batch(&mut self, task: &str) -> Result<Tensor> {
        if !self.calib.contains_key(task) {
            let ds = Dataset::load(self.manifest.dataset(task, "calib")?)?;
            self.calib.insert(task.to_string(), ds);
        }
        let ds = &self.calib[task];
        Ok(ds.batch(0, ds.len().min(128)))
    }

    /// Evaluate a (possibly quantised) prepared model.
    pub fn eval(
        &mut self,
        arch: &str,
        model: &Model,
        cfg: &QuantCfg,
    ) -> Result<f64> {
        let task = self.manifest.arch(arch)?.task.clone();
        let limit = eval_limit();
        if self.runtime.is_some() {
            let key = format!("{arch}@{}", self.eval_batch);
            if !self.execs.contains_key(&key) {
                let exec = self.runtime.as_ref().unwrap().load_model_exec(
                    &self.manifest,
                    arch,
                    self.eval_batch,
                    model,
                )?;
                self.execs.insert(key.clone(), exec);
            }
            let exec = &self.execs[&key];
            let weights = exec.bind_weights(model)?;
            let ds = {
                if !self.datasets.contains_key(&task) {
                    let d =
                        Dataset::load(self.manifest.dataset(&task, "test")?)?;
                    self.datasets.insert(task.clone(), d);
                }
                &self.datasets[&task]
            };
            evaluate(
                model,
                cfg,
                ds,
                &Backend::Pjrt { exec, weights: &weights },
                limit,
            )
        } else {
            let ds = {
                if !self.datasets.contains_key(&task) {
                    let d =
                        Dataset::load(self.manifest.dataset(&task, "test")?)?;
                    self.datasets.insert(task.clone(), d);
                }
                &self.datasets[&task]
            };
            evaluate(model, cfg, ds, &Backend::Engine, limit)
        }
    }

    /// FP32 + INTn metrics for one (arch, DfqConfig, scheme, bc) cell.
    pub fn eval_config(
        &mut self,
        arch: &str,
        dfq_cfg: &DfqConfig,
        scheme: &QScheme,
        act_bits: u32,
        bc: BiasCorrMode,
    ) -> Result<(f64, f64)> {
        let model = self.model(arch)?;
        let prep = quantize_data_free(&model, dfq_cfg)?;
        let fp = self.eval(arch, &prep.model, &QuantCfg::fp32(&prep.model))?;
        let calib = match bc {
            BiasCorrMode::Empirical => {
                let task = self.manifest.arch(arch)?.task.clone();
                Some(self.calib_batch(&task)?)
            }
            _ => None,
        };
        let q = prep.quantize(scheme, act_bits, bc, calib.as_ref())?;
        let qm = self.eval(arch, &q.model, &q.act_cfg)?;
        Ok((fp, qm))
    }

    /// INTn metric only (when the FP32 column is shared across rows).
    pub fn eval_quant(
        &mut self,
        arch: &str,
        dfq_cfg: &DfqConfig,
        scheme: &QScheme,
        act_bits: u32,
        bc: BiasCorrMode,
    ) -> Result<f64> {
        let model = self.model(arch)?;
        let prep = quantize_data_free(&model, dfq_cfg)?;
        let calib = match bc {
            BiasCorrMode::Empirical => {
                let task = self.manifest.arch(arch)?.task.clone();
                Some(self.calib_batch(&task)?)
            }
            _ => None,
        };
        let q = prep.quantize(scheme, act_bits, bc, calib.as_ref())?;
        self.eval(arch, &q.model, &q.act_cfg)
    }
}

/// Where experiment CSVs land.
pub fn results_dir() -> PathBuf {
    std::env::var_os("DFQ_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

/// Registry: run an experiment by id ("1".."8", "fig1", "fig2", "fig3").
pub fn run(id: &str) -> Result<Vec<Table>> {
    let mut ctx = Context::new()?;
    let tables = match id {
        "1" | "table1" => vec![tables::table1(&mut ctx)?],
        "2" | "table2" => vec![tables::table2(&mut ctx)?],
        "3" | "table3" => vec![tables::table3(&mut ctx)?],
        "4" | "table4" => vec![tables::table4(&mut ctx)?],
        "5" | "table5" => vec![tables::table5(&mut ctx)?],
        "6" | "table6" => vec![tables::table6(&mut ctx)?],
        "7" | "table7" => vec![tables::table7(&mut ctx)?],
        "8" | "table8" => vec![tables::table8(&mut ctx)?],
        "fig1" => vec![figures::fig1(&mut ctx)?],
        "fig2" | "fig6" => figures::fig2_fig6(&mut ctx)?,
        "fig3" => vec![figures::fig3(&mut ctx)?],
        "all" => {
            let mut out = Vec::new();
            for i in 1..=8 {
                out.extend(run(&i.to_string())?);
            }
            out.extend(run("fig1")?);
            out.extend(run("fig2")?);
            out.extend(run("fig3")?);
            return Ok(out);
        }
        other => anyhow::bail!("unknown experiment '{other}'"),
    };
    for t in &tables {
        t.print();
    }
    Ok(tables)
}
