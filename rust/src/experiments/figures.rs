//! Figure drivers: bit-width sweep (Fig. 1), per-channel weight ranges
//! before/after equalization (Fig. 2 / Fig. 6), per-channel biased error
//! before/after bias correction (Fig. 3). Output is CSV series (+
//! paper-style rows printed); plots are a `plot anything` away.

use anyhow::Result;

use crate::dfq::{bn_fold, equalize, quantize_data_free, BiasCorrMode,
                 DfqConfig};
use crate::graph::Op;
use crate::nn::{self, QuantCfg};
use crate::quant::{quantize_weights, QScheme};
use crate::util::table::{pct, Table};

use super::{results_dir, Context};

const V2: &str = "micronet_v2";

/// Fig. 1 — top-1 of MicroNet-V2 vs bit width, original vs DFQ.
/// Weights and activations quantised at the same width.
pub fn fig1(ctx: &mut Context) -> Result<Table> {
    let mut t = Table::new(
        "Figure 1 — MicroNet-V2 top-1 vs bit width",
        &["bits", "original", "DFQ"],
    );
    for bits in [16u32, 12, 10, 8, 6, 5, 4] {
        let scheme = QScheme::int8_asymmetric().with_bits(bits);
        let orig = ctx.eval_quant(
            V2,
            &DfqConfig::baseline(),
            &scheme,
            bits,
            BiasCorrMode::None,
        )?;
        let dfq = ctx.eval_quant(
            V2,
            &DfqConfig::default(),
            &scheme,
            bits,
            BiasCorrMode::Analytic,
        )?;
        t.row(&[bits.to_string(), pct(orig), pct(dfq)]);
    }
    t.save_csv(&results_dir().join("fig1.csv"))?;
    Ok(t)
}

/// Boxplot statistics of per-output-channel weights of a tensor.
fn channel_boxplot(
    t: &crate::tensor::Tensor,
) -> Vec<(f32, f32, f32, f32, f32)> {
    (0..t.shape()[0])
        .map(|o| {
            let mut v: Vec<f32> = t.out_channel(o).to_vec();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let q = |p: f64| {
                let sorted: Vec<f64> =
                    v.iter().map(|&x| x as f64).collect();
                crate::util::stats::percentile_sorted(&sorted, p) as f32
            };
            (v[0], q(25.0), q(50.0), q(75.0), v[v.len() - 1])
        })
        .collect()
}

/// The first depthwise-separable layer's dw conv (paper Figs. 2/6 target).
fn first_dw_conv(model: &crate::graph::Model) -> Option<(usize, String)> {
    model.nodes.iter().find_map(|n| {
        if n.op.is_depthwise() {
            match &n.op {
                Op::Conv { w, .. } => Some((n.id, w.clone())),
                _ => None,
            }
        } else {
            None
        }
    })
}

fn nth_dw_conv(
    model: &crate::graph::Model,
    nth: usize,
) -> Option<(usize, String)> {
    model
        .nodes
        .iter()
        .filter(|n| n.op.is_depthwise())
        .nth(nth)
        .and_then(|n| match &n.op {
            Op::Conv { w, .. } => Some((n.id, w.clone())),
            _ => None,
        })
}

/// Figs. 2 & 6 — per-channel weight ranges of the first depthwise layer,
/// before and after cross-layer equalization.
pub fn fig2_fig6(ctx: &mut Context) -> Result<Vec<Table>> {
    let model = ctx.model(V2)?;
    let folded = bn_fold::fold(&model)?;
    let (_, w_name) = first_dw_conv(&folded)
        .ok_or_else(|| anyhow::anyhow!("no depthwise conv in {V2}"))?;

    let mut out = Vec::new();
    for (fig, equalized) in [("fig2", false), ("fig6", true)] {
        let mut m = folded.clone();
        if equalized {
            crate::dfq::relu6::replace_relu6(&mut m);
            equalize::equalize(&mut m, 40, 1e-4)?;
        }
        let w = m.tensor(&w_name)?;
        let mut t = Table::new(
            format!(
                "Figure {} — per-channel ranges of the first dw layer ({})",
                if equalized { "6" } else { "2" },
                if equalized { "after CLE" } else { "before CLE" }
            ),
            &["channel", "min", "q25", "median", "q75", "max"],
        );
        for (c, (mn, q1, md, q3, mx)) in
            channel_boxplot(w).into_iter().enumerate()
        {
            t.row(&[
                c.to_string(),
                format!("{mn:.4}"),
                format!("{q1:.4}"),
                format!("{md:.4}"),
                format!("{q3:.4}"),
                format!("{mx:.4}"),
            ]);
        }
        t.save_csv(&results_dir().join(format!("{fig}.csv")))?;
        out.push(t);
    }
    Ok(out)
}

/// Fig. 3 — per-channel biased output error of the second depthwise
/// layer introduced by INT8 weight quantisation, before and after
/// analytic bias correction. Errors measured on calibration data
/// (eq. 1: `E[ỹ − y]` per output channel).
pub fn fig3(ctx: &mut Context) -> Result<Table> {
    let model = ctx.model(V2)?;
    // measured on the *unequalized* model, where per-tensor quantisation
    // of the corrupted weights introduces large biased errors (paper
    // Fig. 3 uses the original MobileNetV2)
    let prep = quantize_data_free(&model, &DfqConfig::baseline())?;
    let (layer_id, _) = nth_dw_conv(&prep.model, 1)
        .ok_or_else(|| anyhow::anyhow!("no second dw layer"))?;
    let calib = ctx.calib_batch("classification")?;

    let cfg = QuantCfg::fp32(&prep.model);
    let fp = nn::preact_channel_means(&prep.model, &calib, &cfg)?;

    let measure = |bc: BiasCorrMode| -> Result<Vec<f32>> {
        let mut q = prep.model.clone();
        let names: Vec<String> = q
            .layers()
            .iter()
            .map(|n| match &n.op {
                Op::Conv { w, .. }
                | Op::ConvT2d { w, .. }
                | Op::Linear { w, .. } => w.clone(),
                _ => unreachable!(),
            })
            .collect();
        for w in names {
            let t = q.tensors.get_mut(&w).unwrap();
            quantize_weights(t, &QScheme::int8_asymmetric());
        }
        if bc == BiasCorrMode::Analytic {
            crate::dfq::bias_correct::analytic(&mut q, &prep.model)?;
        }
        let qm = nn::preact_channel_means(&q, &calib, &cfg)?;
        Ok(qm[&layer_id]
            .iter()
            .zip(&fp[&layer_id])
            .map(|(a, b)| a - b)
            .collect())
    };

    let before = measure(BiasCorrMode::None)?;
    let after = measure(BiasCorrMode::Analytic)?;
    let mut t = Table::new(
        "Figure 3 — per-channel biased error (2nd dw layer), INT8 weights",
        &["channel", "error_before_bc", "error_after_bc"],
    );
    for c in 0..before.len() {
        t.row(&[
            c.to_string(),
            format!("{:.6}", before[c]),
            format!("{:.6}", after[c]),
        ]);
    }
    // headline aggregate for quick reading
    let mab = before.iter().map(|x| x.abs()).sum::<f32>() / before.len() as f32;
    let maa = after.iter().map(|x| x.abs()).sum::<f32>() / after.len() as f32;
    t.row(&[
        "mean|err|".into(),
        format!("{mab:.6}"),
        format!("{maa:.6}"),
    ]);
    t.save_csv(&results_dir().join("fig3.csv"))?;
    Ok(t)
}
