//! Table drivers — one per table in the paper's evaluation section.
//! Row structure mirrors the paper exactly; absolute numbers come from
//! the SynthShapes substitutes (DESIGN.md §1), the *shape* of each
//! result is the reproduction target.

use anyhow::Result;

use crate::dfq::{clip, quantize_data_free, BiasCorrMode, DfqConfig};
use crate::quant::QScheme;
use crate::util::table::{pct, Table};

use super::{results_dir, Context};

const V2: &str = "micronet_v2";

fn cfg_baseline() -> DfqConfig {
    DfqConfig::baseline()
}

fn cfg_replace6() -> DfqConfig {
    DfqConfig { replace_relu6: true, ..DfqConfig::baseline() }
}

fn cfg_cle() -> DfqConfig {
    DfqConfig {
        replace_relu6: true,
        equalize: true,
        absorb_bias: false,
        ..DfqConfig::default()
    }
}

fn cfg_cle_ba() -> DfqConfig {
    DfqConfig::default() // replace + equalize + absorb
}

/// The weight-clipping level for the Clip@c baseline rows. The paper's
/// fixed ±15 corresponds to clipping MobileNetV2's corrupted outliers;
/// here the level is the 99th percentile of |w| of the folded corrupted
/// model (env `DFQ_CLIP` overrides).
fn clip_level(ctx: &mut Context, arch: &str) -> Result<f32> {
    if let Ok(v) = std::env::var("DFQ_CLIP") {
        if let Ok(c) = v.parse::<f32>() {
            return Ok(c);
        }
    }
    let model = ctx.model(arch)?;
    let folded = crate::dfq::bn_fold::fold(&model)?;
    Ok(clip::quantile_clip_level(&folded, 0.99))
}

/// Table 1 — cross-layer equalization ablation (MicroNet-V2 top-1).
pub fn table1(ctx: &mut Context) -> Result<Table> {
    let int8 = QScheme::int8_asymmetric();
    let mut t = Table::new(
        "Table 1 — MicroNet-V2 top-1 (FP32 / INT8), CLE ablation",
        &["Model", "FP32", "INT8"],
    );
    for (name, cfg) in [
        ("Original model", cfg_baseline()),
        ("Replace ReLU6", cfg_replace6()),
        ("+ equalization", cfg_cle()),
        ("+ absorbing bias", cfg_cle_ba()),
    ] {
        let (fp, q) =
            ctx.eval_config(V2, &cfg, &int8, 8, BiasCorrMode::None)?;
        t.row(&[name.to_string(), pct(fp), pct(q)]);
    }
    // per-channel reference (paper: [18] post-training per-channel)
    let (fp, q) = ctx.eval_config(
        V2,
        &cfg_baseline(),
        &QScheme::per_channel(8),
        8,
        BiasCorrMode::None,
    )?;
    t.row(&["Per channel quantization".into(), pct(fp), pct(q)]);
    t.save_csv(&results_dir().join("table1.csv"))?;
    Ok(t)
}

/// Table 2 — bias-correction ablation (MicroNet-V2 top-1).
pub fn table2(ctx: &mut Context) -> Result<Table> {
    let int8 = QScheme::int8_asymmetric();
    let c = clip_level(ctx, V2)?;
    let mut t = Table::new(
        format!("Table 2 — MicroNet-V2 top-1, bias correction (clip@{c:.2})"),
        &["Model", "FP32", "INT8"],
    );
    let rows: [(&str, DfqConfig, BiasCorrMode); 6] = [
        ("Original model", cfg_baseline(), BiasCorrMode::None),
        ("Bias Corr", cfg_baseline(), BiasCorrMode::Analytic),
        (
            "Clip @ c",
            DfqConfig { weight_clip: Some(c), ..cfg_baseline() },
            BiasCorrMode::None,
        ),
        (
            "+ Bias Corr",
            DfqConfig { weight_clip: Some(c), ..cfg_baseline() },
            BiasCorrMode::Analytic,
        ),
        ("Rescaling + Bias Absorption", cfg_cle_ba(), BiasCorrMode::None),
        ("+ Bias Corr", cfg_cle_ba(), BiasCorrMode::Analytic),
    ];
    for (name, cfg, bc) in rows {
        // The paper's FP32 column is the clipped model with the same BC
        // applied un-quantised (Table 2: clip loses 4.66% FP32, BC
        // recovers it to −0.57%).
        let model = ctx.model(V2)?;
        let prep = quantize_data_free(&model, &cfg)?;
        let fpm = prep.bias_corrected_fp32(bc, None)?;
        let fp = ctx.eval(V2, &fpm, &crate::nn::QuantCfg::fp32(&fpm))?;
        let q = ctx.eval_quant(V2, &cfg, &int8, 8, bc)?;
        t.row(&[name.to_string(), pct(fp), pct(q)]);
    }
    t.save_csv(&results_dir().join("table2.csv"))?;
    Ok(t)
}

/// Shared driver for Tables 3/4 (other tasks).
fn task_table(
    ctx: &mut Context,
    arch: &str,
    title: &str,
    csv: &str,
) -> Result<Table> {
    let int8 = QScheme::int8_asymmetric();
    let mut t = Table::new(title, &["Model", "FP32", "INT8"]);
    let (fp, q) =
        ctx.eval_config(arch, &cfg_baseline(), &int8, 8, BiasCorrMode::None)?;
    t.row(&["Original model".into(), pct(fp), pct(q)]);
    let (fp, q) = ctx.eval_config(
        arch,
        &cfg_cle_ba(),
        &int8,
        8,
        BiasCorrMode::Analytic,
    )?;
    t.row(&["DFQ (ours)".into(), pct(fp), pct(q)]);
    let (fp, q) = ctx.eval_config(
        arch,
        &cfg_baseline(),
        &QScheme::per_channel(8),
        8,
        BiasCorrMode::None,
    )?;
    t.row(&["Per-channel quantization".into(), pct(fp), pct(q)]);
    t.save_csv(&results_dir().join(csv))?;
    Ok(t)
}

/// Table 3 — semantic segmentation (MicroDeepLab mIoU).
pub fn table3(ctx: &mut Context) -> Result<Table> {
    task_table(
        ctx,
        "microdeeplab",
        "Table 3 — MicroDeepLab (V2 backbone) mIoU on SynthShapes-seg",
        "table3.csv",
    )
}

/// Table 4 — object detection (MicroSSD mAP@0.5).
pub fn table4(ctx: &mut Context) -> Result<Table> {
    task_table(
        ctx,
        "microssd",
        "Table 4 — MicroSSD-lite (V2 backbone) mAP@0.5 on SynthShapes-det",
        "table4.csv",
    )
}

/// Table 5 — model sweep × method at INT8 and INT6.
pub fn table5(ctx: &mut Context) -> Result<Table> {
    let mut t = Table::new(
        "Table 5 — top-1 across models/methods (level-1 only)",
        &["Method", "Model", "FP32", "INT8", "INT6"],
    );
    let archs = ["micronet_v2", "micronet_v1", "microresnet18"];
    for arch in archs {
        // DFQ (CLE + BA + analytic BC)
        let (fp, q8) = ctx.eval_config(
            arch,
            &cfg_cle_ba(),
            &QScheme::int8_asymmetric(),
            8,
            BiasCorrMode::Analytic,
        )?;
        let q6 = ctx.eval_quant(
            arch,
            &cfg_cle_ba(),
            &QScheme::int8_asymmetric().with_bits(6),
            6,
            BiasCorrMode::Analytic,
        )?;
        t.row(&[
            "DFQ (ours)".into(),
            arch.into(),
            pct(fp),
            pct(q8),
            pct(q6),
        ]);
        // direct per-layer quantisation
        let (fp, q8) = ctx.eval_config(
            arch,
            &cfg_baseline(),
            &QScheme::int8_asymmetric(),
            8,
            BiasCorrMode::None,
        )?;
        let q6 = ctx.eval_quant(
            arch,
            &cfg_baseline(),
            &QScheme::int8_asymmetric().with_bits(6),
            6,
            BiasCorrMode::None,
        )?;
        t.row(&["Per-layer".into(), arch.into(), pct(fp), pct(q8), pct(q6)]);
        // per-channel quantisation
        let (fp, q8) = ctx.eval_config(
            arch,
            &cfg_baseline(),
            &QScheme::per_channel(8),
            8,
            BiasCorrMode::None,
        )?;
        let q6 = ctx.eval_quant(
            arch,
            &cfg_baseline(),
            &QScheme::per_channel(6),
            6,
            BiasCorrMode::None,
        )?;
        t.row(&[
            "Per-channel".into(),
            arch.into(),
            pct(fp),
            pct(q8),
            pct(q6),
        ]);
    }
    t.save_csv(&results_dir().join("table5.csv"))?;
    Ok(t)
}

/// Table 6 — analytic vs empirical bias correction.
pub fn table6(ctx: &mut Context) -> Result<Table> {
    let int8 = QScheme::int8_asymmetric();
    let c = clip_level(ctx, V2)?;
    let mut t = Table::new(
        format!("Table 6 — analytic vs empirical BC (INT8, clip@{c:.2})"),
        &["Model", "CLE+BA", "Clip@c"],
    );
    let clip_cfg = DfqConfig { weight_clip: Some(c), ..cfg_baseline() };
    for (name, bc) in [
        ("No BiasCorr", BiasCorrMode::None),
        ("Analytic BiasCorr", BiasCorrMode::Analytic),
        ("Empirical BiasCorr", BiasCorrMode::Empirical),
    ] {
        let a = ctx.eval_quant(V2, &cfg_cle_ba(), &int8, 8, bc)?;
        let b = ctx.eval_quant(V2, &clip_cfg, &int8, 8, bc)?;
        t.row(&[name.to_string(), pct(a), pct(b)]);
    }
    t.save_csv(&results_dir().join("table6.csv"))?;
    Ok(t)
}

/// Table 7 — symmetric vs asymmetric quantisation after DFQ.
pub fn table7(ctx: &mut Context) -> Result<Table> {
    let mut t = Table::new(
        "Table 7 — symmetric vs asymmetric INT8 after DFQ",
        &["Model", "Symmetric", "Asymmetric"],
    );
    for arch in ["micronet_v1", "micronet_v2", "microresnet18"] {
        let sym = ctx.eval_quant(
            arch,
            &cfg_cle_ba(),
            &QScheme::int8_symmetric(),
            8,
            BiasCorrMode::Analytic,
        )?;
        let asym = ctx.eval_quant(
            arch,
            &cfg_cle_ba(),
            &QScheme::int8_asymmetric(),
            8,
            BiasCorrMode::Analytic,
        )?;
        t.row(&[arch.into(), pct(sym), pct(asym)]);
    }
    t.save_csv(&results_dir().join("table7.csv"))?;
    Ok(t)
}

/// Table 8 — DFQ components on top of per-channel quantisation.
pub fn table8(ctx: &mut Context) -> Result<Table> {
    let pc8 = QScheme::per_channel(8);
    let mut t = Table::new(
        "Table 8 — per-channel weights + DFQ components (INT8)",
        &["Model", "No BiasCorr", "BiasCorr"],
    );
    for (name, cfg) in [
        ("Original model", cfg_replace6()),
        ("CLE", cfg_cle()),
        ("CLE+BA", cfg_cle_ba()),
    ] {
        let plain = ctx.eval_quant(V2, &cfg, &pc8, 8, BiasCorrMode::None)?;
        let bc = ctx.eval_quant(V2, &cfg, &pc8, 8, BiasCorrMode::Analytic)?;
        t.row(&[name.to_string(), pct(plain), pct(bc)]);
    }
    t.save_csv(&results_dir().join("table8.csv"))?;
    Ok(t)
}

/// Sanity: corrupted FP32 ≈ clean FP32 (the corruption is
/// function-preserving) — used by integration tests and EXPERIMENTS.md.
pub fn corruption_check(ctx: &mut Context, arch: &str) -> Result<(f64, f64)> {
    let entry = ctx.manifest.arch(arch)?.clone();
    let corrupted = ctx.model(arch)?;
    let clean =
        crate::graph::Model::load(ctx.manifest.path(&entry.model_clean))?;
    let pc = quantize_data_free(&corrupted, &DfqConfig::baseline())?;
    let pl = quantize_data_free(&clean, &DfqConfig::baseline())?;
    let a = ctx.eval(arch, &pc.model, &crate::nn::QuantCfg::fp32(&pc.model))?;
    let b = ctx.eval(arch, &pl.model, &crate::nn::QuantCfg::fp32(&pl.model))?;
    Ok((a, b))
}
