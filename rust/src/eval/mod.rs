//! Evaluation harness: run a (quantised) model over a dataset on either
//! backend and compute the task metric. This is what every experiment
//! driver ([`crate::experiments`]) calls.

pub mod metrics;

use anyhow::{bail, Result};

use crate::graph::io::Dataset;
use crate::graph::{Model, Task};
use crate::nn::{self, QuantCfg};
use crate::runtime::{BoundWeights, Executable};
use crate::tensor::Tensor;

/// Which engine executes the forward passes.
pub enum Backend<'a> {
    /// AOT-compiled PJRT executable (the production path).
    Pjrt { exec: &'a Executable, weights: &'a BoundWeights },
    /// Pure-Rust reference engine.
    Engine,
}

/// Evaluate `model` on `dataset`, returning the task metric
/// (top-1 / mIoU / mAP@0.5 — all as a fraction in [0, 1]).
pub fn evaluate(
    model: &Model,
    cfg: &QuantCfg,
    dataset: &Dataset,
    backend: &Backend,
    limit: Option<usize>,
) -> Result<f64> {
    let n = dataset.len().min(limit.unwrap_or(usize::MAX));
    let outputs = run_all(model, cfg, dataset, backend, n)?;
    metric_for(model.task, &outputs, dataset, n, model.num_classes)
}

/// Forward the first `n` dataset images, concatenating primary outputs.
pub fn run_all(
    model: &Model,
    cfg: &QuantCfg,
    dataset: &Dataset,
    backend: &Backend,
    n: usize,
) -> Result<Tensor> {
    let mut chunks: Vec<Tensor> = Vec::new();
    match backend {
        Backend::Engine => {
            // modest batches keep the working set cache-friendly
            let bs = 32usize;
            let mut lo = 0;
            while lo < n {
                let hi = (lo + bs).min(n);
                let x = dataset.batch(lo, hi);
                let outs = nn::forward(model, &x, cfg)?;
                chunks.push(outs.into_iter().next().unwrap());
                lo = hi;
            }
        }
        Backend::Pjrt { exec, weights } => {
            let bs = exec.meta.batch;
            let mut lo = 0;
            while lo < n {
                let hi = (lo + bs).min(n);
                let x = if hi - lo == bs {
                    dataset.batch(lo, hi)
                } else {
                    pad_batch(&dataset.batch(lo, hi), bs)
                };
                let outs = exec.run(&x, weights, cfg)?;
                let mut out = outs.into_iter().next().unwrap();
                if hi - lo != bs {
                    out = truncate_batch(&out, hi - lo);
                }
                chunks.push(out);
                lo = hi;
            }
        }
    }
    concat_batch(&chunks)
}

fn metric_for(
    task: Task,
    outputs: &Tensor,
    dataset: &Dataset,
    n: usize,
    num_classes: usize,
) -> Result<f64> {
    Ok(match task {
        Task::Classification => metrics::top1(outputs, &dataset.labels[..n]),
        Task::Segmentation => {
            let spatial: usize = dataset.label_shape[1..].iter().product();
            metrics::mean_iou(
                outputs,
                &dataset.labels[..n * spatial],
                crate::eval::SEG_CLASSES,
            )
        }
        Task::Detection => {
            let boxes = dataset
                .boxes
                .as_ref()
                .expect("detection dataset has boxes");
            let gt_all = metrics::gt_boxes(boxes);
            let gt = &gt_all[..n];
            let dets = metrics::decode_detections(
                outputs,
                (dataset.x.shape()[2] / outputs.shape()[2]) as f32,
                0.05,
            );
            let _ = num_classes;
            metrics::mean_ap(&dets, gt, crate::eval::DET_CLASSES, 0.5)
        }
    })
}

/// Number of segmentation classes in SynthShapes-seg (bg + 3 shapes).
pub const SEG_CLASSES: usize = 4;
/// Foreground detection classes in SynthShapes-det.
pub const DET_CLASSES: usize = 3;

fn pad_batch(x: &Tensor, batch: usize) -> Tensor {
    let mut shape = x.shape().to_vec();
    let per: usize = shape[1..].iter().product();
    let n = shape[0];
    shape[0] = batch;
    let mut data = vec![0f32; batch * per];
    data[..n * per].copy_from_slice(x.data());
    Tensor::new(&shape, data)
}

fn truncate_batch(x: &Tensor, n: usize) -> Tensor {
    let mut shape = x.shape().to_vec();
    let per: usize = shape[1..].iter().product();
    shape[0] = n;
    Tensor::new(&shape, x.data()[..n * per].to_vec())
}

fn concat_batch(chunks: &[Tensor]) -> Result<Tensor> {
    if chunks.is_empty() {
        bail!("no evaluation chunks");
    }
    let mut shape = chunks[0].shape().to_vec();
    let n: usize = chunks.iter().map(|c| c.shape()[0]).sum();
    shape[0] = n;
    let mut data = Vec::with_capacity(shape.iter().product());
    for c in chunks {
        data.extend_from_slice(c.data());
    }
    Ok(Tensor::new(&shape, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_truncate_roundtrip() {
        let x = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let p = pad_batch(&x, 4);
        assert_eq!(p.shape(), &[4, 3]);
        assert_eq!(&p.data()[..6], x.data());
        assert_eq!(&p.data()[6..], &[0.; 6]);
        let t = truncate_batch(&p, 2);
        assert_eq!(t.data(), x.data());
    }

    #[test]
    fn concat_shapes() {
        let a = Tensor::new(&[1, 2], vec![1., 2.]);
        let b = Tensor::new(&[2, 2], vec![3., 4., 5., 6.]);
        let c = concat_batch(&[a, b]).unwrap();
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[1., 2., 3., 4., 5., 6.]);
    }
}
