//! Task metrics: top-1 accuracy, mean IoU, detection mAP@0.5.
//!
//! Mirrors the paper's evaluation: ImageNet top-1 (Tables 1/2/5-8),
//! Pascal-VOC mIoU (Table 3) and mAP (Table 4), computed over the
//! SynthShapes substitutes.

use crate::tensor::Tensor;

/// Top-1 accuracy of logits (N, K) against labels (N).
pub fn top1(logits: &Tensor, labels: &[i32]) -> f64 {
    let (n, k) = (logits.shape()[0], logits.shape()[1]);
    debug_assert!(labels.len() >= n);
    let mut hits = 0usize;
    for i in 0..n {
        let row = &logits.data()[i * k..(i + 1) * k];
        let pred = argmax(row);
        if pred as i32 == labels[i] {
            hits += 1;
        }
    }
    hits as f64 / n as f64
}

/// Mean intersection-over-union of per-pixel logits (N, K, H, W) against
/// labels (N, H, W), averaged over classes present in the union.
pub fn mean_iou(logits: &Tensor, labels: &[i32], num_classes: usize) -> f64 {
    let s = logits.shape();
    let (n, k, h, w) = (s[0], s[1], s[2], s[3]);
    let spatial = h * w;
    let mut inter = vec![0u64; num_classes];
    let mut uni = vec![0u64; num_classes];
    for i in 0..n {
        for p in 0..spatial {
            // argmax over channel for pixel p
            let mut best = 0usize;
            let mut bv = f32::NEG_INFINITY;
            for c in 0..k {
                let v = logits.data()[(i * k + c) * spatial + p];
                if v > bv {
                    bv = v;
                    best = c;
                }
            }
            let gt = labels[i * spatial + p] as usize;
            if best == gt {
                inter[gt] += 1;
                uni[gt] += 1;
            } else {
                uni[gt] += 1;
                uni[best] += 1;
            }
        }
    }
    let mut acc = 0f64;
    let mut cnt = 0usize;
    for c in 0..num_classes {
        if uni[c] > 0 {
            acc += inter[c] as f64 / uni[c] as f64;
            cnt += 1;
        }
    }
    if cnt == 0 { 0.0 } else { acc / cnt as f64 }
}

/// One decoded detection.
#[derive(Debug, Clone, Copy)]
pub struct Detection {
    pub image: usize,
    pub class: usize, // foreground class in [0, C)
    pub score: f32,
    pub bbox: [f32; 4], // x1, y1, x2, y2 (pixels)
}

/// Decode SSD-lite grid outputs (N, C+1+4, G, G) into detections.
/// Channel 0 is background; boxes are (cx, cy, w, h) in cell units.
pub fn decode_detections(
    out: &Tensor,
    cell: f32,
    score_thresh: f32,
) -> Vec<Detection> {
    let s = out.shape();
    let (n, ch, g, _) = (s[0], s[1], s[2], s[3]);
    let nc = ch - 4; // classes incl. background
    let cells = g * g;
    let mut dets = Vec::new();
    for i in 0..n {
        for cy in 0..g {
            for cx in 0..g {
                let p = cy * g + cx;
                let at = |c: usize| out.data()[(i * ch + c) * cells + p];
                // softmax over classes
                let mut mx = f32::NEG_INFINITY;
                for c in 0..nc {
                    mx = mx.max(at(c));
                }
                let mut denom = 0f32;
                for c in 0..nc {
                    denom += (at(c) - mx).exp();
                }
                let mut best = 0usize;
                let mut bv = f32::NEG_INFINITY;
                for c in 0..nc {
                    if at(c) > bv {
                        bv = at(c);
                        best = c;
                    }
                }
                if best == 0 {
                    continue; // background
                }
                let score = (at(best) - mx).exp() / denom;
                if score < score_thresh {
                    continue;
                }
                let bcx = (cx as f32 + at(nc)) * cell;
                let bcy = (cy as f32 + at(nc + 1)) * cell;
                let bw = at(nc + 2) * cell;
                let bh = at(nc + 3) * cell;
                dets.push(Detection {
                    image: i,
                    class: best - 1,
                    score,
                    bbox: [
                        bcx - bw / 2.0,
                        bcy - bh / 2.0,
                        bcx + bw / 2.0,
                        bcy + bh / 2.0,
                    ],
                });
            }
        }
    }
    dets
}

pub fn iou(a: &[f32; 4], b: &[f32; 4]) -> f32 {
    let x1 = a[0].max(b[0]);
    let y1 = a[1].max(b[1]);
    let x2 = a[2].min(b[2]);
    let y2 = a[3].min(b[3]);
    let inter = (x2 - x1).max(0.0) * (y2 - y1).max(0.0);
    let area = |r: &[f32; 4]| (r[2] - r[0]).max(0.0) * (r[3] - r[1]).max(0.0);
    let u = area(a) + area(b) - inter;
    if u <= 0.0 {
        0.0
    } else {
        inter / u
    }
}

/// Ground-truth box list per image from the dataset tensor
/// (N, MAX_OBJ, 5) with rows [cls, x1, y1, x2, y2], cls = -1 padding.
pub fn gt_boxes(boxes: &Tensor) -> Vec<Vec<(usize, [f32; 4])>> {
    let s = boxes.shape();
    let (n, m) = (s[0], s[1]);
    let mut out = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..m {
            let r = &boxes.data()[(i * m + j) * 5..(i * m + j) * 5 + 5];
            if r[0] < 0.0 {
                continue;
            }
            out[i].push((r[0] as usize, [r[1], r[2], r[3], r[4]]));
        }
    }
    out
}

/// VOC-style all-point mAP at the given IoU threshold.
pub fn mean_ap(
    dets: &[Detection],
    gt: &[Vec<(usize, [f32; 4])>],
    num_classes: usize,
    iou_thresh: f32,
) -> f64 {
    let mut ap_sum = 0f64;
    let mut classes = 0usize;
    for cls in 0..num_classes {
        let total_gt: usize = gt
            .iter()
            .map(|g| g.iter().filter(|(c, _)| *c == cls).count())
            .sum();
        if total_gt == 0 {
            continue;
        }
        classes += 1;
        let mut cd: Vec<&Detection> =
            dets.iter().filter(|d| d.class == cls).collect();
        cd.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        let mut matched: Vec<Vec<bool>> =
            gt.iter().map(|g| vec![false; g.len()]).collect();
        let mut tp = 0usize;
        let mut fp = 0usize;
        let mut curve: Vec<(f64, f64)> = Vec::new(); // (recall, precision)
        for d in cd {
            let g = &gt[d.image];
            let mut best = -1isize;
            let mut best_iou = iou_thresh;
            for (j, (c, bb)) in g.iter().enumerate() {
                if *c != cls || matched[d.image][j] {
                    continue;
                }
                let v = iou(&d.bbox, bb);
                if v >= best_iou {
                    best_iou = v;
                    best = j as isize;
                }
            }
            if best >= 0 {
                matched[d.image][best as usize] = true;
                tp += 1;
            } else {
                fp += 1;
            }
            curve.push((
                tp as f64 / total_gt as f64,
                tp as f64 / (tp + fp) as f64,
            ));
        }
        // all-point interpolation
        let mut ap = 0f64;
        let mut prev_r = 0f64;
        let mut i = 0;
        while i < curve.len() {
            let r = curve[i].0;
            // max precision at recall >= r
            let pmax = curve[i..]
                .iter()
                .map(|c| c.1)
                .fold(0f64, f64::max);
            ap += (r - prev_r) * pmax;
            prev_r = r;
            // skip to next distinct recall
            while i < curve.len() && curve[i].0 <= r {
                i += 1;
            }
        }
        ap_sum += ap;
    }
    if classes == 0 { 0.0 } else { ap_sum / classes as f64 }
}

fn argmax(xs: &[f32]) -> usize {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            bi = i;
        }
    }
    bi
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_counts() {
        let logits = Tensor::new(&[2, 3], vec![0., 1., 0., 1., 0., 0.]);
        assert_eq!(top1(&logits, &[1, 0]), 1.0);
        assert_eq!(top1(&logits, &[0, 0]), 0.5);
    }

    #[test]
    fn miou_perfect_and_degenerate() {
        // 1 image, 2 classes, 1x2 pixels
        let logits =
            Tensor::new(&[1, 2, 1, 2], vec![1., 0., 0., 1.]);
        assert_eq!(mean_iou(&logits, &[0, 1], 2), 1.0);
        assert!(mean_iou(&logits, &[1, 0], 2) < 0.1);
    }

    #[test]
    fn iou_basics() {
        let a = [0., 0., 2., 2.];
        assert!((iou(&a, &a) - 1.0).abs() < 1e-6);
        assert_eq!(iou(&a, &[2., 2., 4., 4.]), 0.0);
        let half = iou(&a, &[0., 0., 2., 1.]);
        assert!((half - 0.5).abs() < 1e-6);
    }

    #[test]
    fn map_perfect_detector() {
        let gt = vec![vec![(0usize, [0f32, 0., 8., 8.])]];
        let dets = vec![Detection {
            image: 0,
            class: 0,
            score: 0.9,
            bbox: [0., 0., 8., 8.],
        }];
        assert!((mean_ap(&dets, &gt, 3, 0.5) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn map_false_positive_hurts() {
        let gt = vec![vec![(0usize, [0f32, 0., 8., 8.])]];
        let dets = vec![
            Detection { image: 0, class: 0, score: 0.95,
                        bbox: [20., 20., 28., 28.] },
            Detection { image: 0, class: 0, score: 0.9,
                        bbox: [0., 0., 8., 8.] },
        ];
        let ap = mean_ap(&dets, &gt, 3, 0.5);
        assert!(ap < 0.6, "{ap}");
    }

    #[test]
    fn decode_ignores_background() {
        // 1 image, 1x1 grid, 3 fg classes + bg + 4 box ch = 8 channels
        let mut data = vec![0f32; 8];
        data[0] = 5.0; // background wins
        let out = Tensor::new(&[1, 8, 1, 1], data);
        assert!(decode_detections(&out, 8.0, 0.1).is_empty());
    }
}
