//! Compiler-style pass manager for the DFQ pipeline.
//!
//! The paper's Fig. 4 pipeline (BN fold → ReLU6 replace → cross-layer
//! equalization → bias absorption → quantise → bias correction) used to
//! be a hard-coded call sequence inside `quantize_data_free`. This module
//! restructures it as composable graph rewrites: each stage is a [`Pass`]
//! with a name and a `run(&mut Model, &mut PassCx)` entry point; a
//! [`PassManager`] composes the registered passes from a
//! [`DfqConfig`]/scheme and records per-pass diagnostics into a
//! structured [`PipelineReport`]:
//!
//! * per-channel weight-range spread before/after each rewrite (the
//!   paper's Fig. 2 pathology in one number),
//! * the CLE convergence trace — worst |log s| per sweep,
//! * absorbed-bias mass, and the bias-correction |Δb| magnitude.
//!
//! `dfq report <arch>` prints the report as a table and as the shared
//! one-line JSON records (`BenchResult`-style), so the driver can track
//! pass behaviour across PRs mechanically. The composition is
//! bit-for-bit identical to the old call sequence: every pass invokes
//! exactly the function the monolith called, in the same order —
//! diagnostics only *read* the model.

use anyhow::{bail, Result};

use crate::graph::{Model, Op};
use crate::quant::{self, QParams, QScheme};
use crate::tensor::{QTensor, Tensor};
use crate::util::table::Table;

use super::{
    absorb, bias_correct, bn_fold, clip, equalize, relu6, BiasCorrMode,
    DfqConfig,
};

// -- context & reports --------------------------------------------------------

/// Shared state across one pipeline run: inputs the quantisation-side
/// passes read (FP32 reference, calibration batch) and the side outputs
/// the quantize pass produces (per-layer grids + retained integer
/// codes — the planner's inputs).
#[derive(Default)]
pub struct PassCx<'a> {
    /// FP32 reference model the bias-correction passes measure ε against
    /// (required by [`BiasCorrectPass`] with a non-`None` mode).
    pub reference: Option<&'a Model>,
    /// Calibration batch (empirical bias correction only).
    pub calib: Option<&'a Tensor>,
    /// Side output of [`QuantizePass`]: per-layer weight grids.
    pub weight_params: Vec<(usize, Vec<QParams>)>,
    /// Side output of [`QuantizePass`]: retained integer weight codes
    /// (empty when the scheme is wider than 8 bits).
    pub int_weights: Vec<(usize, QTensor)>,
}

/// What one pass did: a primary change count, ordered scalar metrics,
/// and an optional convergence trace.
#[derive(Debug, Clone, Default)]
pub struct PassReport {
    pub name: &'static str,
    /// Pass-specific primary count (nodes folded, sweeps run, channels
    /// absorbed, elements clipped, layers corrected...).
    pub changed: usize,
    /// Ordered `(key, value)` diagnostics.
    pub metrics: Vec<(&'static str, f64)>,
    /// Per-iteration convergence gauge (CLE: max |log s| per sweep).
    pub trace: Vec<f32>,
}

impl PassReport {
    fn new(name: &'static str) -> PassReport {
        PassReport { name, ..PassReport::default() }
    }

    fn push(&mut self, key: &'static str, v: f64) {
        self.metrics.push((key, v));
    }

    pub fn metric(&self, key: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| *k == key).map(|(_, v)| *v)
    }
}

/// Ordered per-pass diagnostics of one pipeline run.
#[derive(Debug, Clone, Default)]
pub struct PipelineReport {
    pub passes: Vec<PassReport>,
}

impl PipelineReport {
    pub fn get(&self, name: &str) -> Option<&PassReport> {
        self.passes.iter().find(|p| p.name == name)
    }

    pub fn extend(&mut self, other: PipelineReport) {
        self.passes.extend(other.passes);
    }

    /// Render as an aligned ASCII table (one row per pass) followed by
    /// the CLE convergence trace, when one was recorded.
    pub fn table(&self) -> String {
        let mut t = Table::new(
            "DFQ pass diagnostics",
            &["pass", "changed", "diagnostics"],
        );
        for p in &self.passes {
            let diag = p
                .metrics
                .iter()
                .map(|(k, v)| format!("{k}={v:.4}"))
                .collect::<Vec<_>>()
                .join("  ");
            t.row(&[p.name.to_string(), p.changed.to_string(), diag]);
        }
        let mut out = t.render();
        for p in &self.passes {
            if !p.trace.is_empty() {
                out.push_str(&format!(
                    "{} convergence (max |log s| per sweep): {}\n",
                    p.name,
                    p.trace
                        .iter()
                        .map(|x| format!("{x:.4}"))
                        .collect::<Vec<_>>()
                        .join(" -> ")
                ));
            }
        }
        out
    }

    /// One machine-readable JSON record per pass (the one-line format
    /// shared with `BenchResult::json`), for the CI / driver trajectory.
    /// Non-finite diagnostics (a pathological model can produce them)
    /// render as `null` — JSON has no Infinity/NaN literals and this
    /// stream must stay parseable for the CI smoke step.
    pub fn json_lines(&self) -> String {
        fn num(v: f64) -> String {
            if v.is_finite() {
                format!("{v:e}")
            } else {
                "null".to_string()
            }
        }
        let mut out = String::new();
        for p in &self.passes {
            let metrics = p
                .metrics
                .iter()
                .map(|(k, v)| format!("{k:?}:{}", num(*v)))
                .collect::<Vec<_>>()
                .join(",");
            let trace = p
                .trace
                .iter()
                .map(|&x| num(x as f64))
                .collect::<Vec<_>>()
                .join(",");
            out.push_str(&format!(
                "{{\"pass\":{:?},\"changed\":{},\"metrics\":{{{metrics}}},\
                 \"trace\":[{trace}]}}\n",
                p.name, p.changed
            ));
        }
        out
    }
}

// -- diagnostics --------------------------------------------------------------

/// Worst per-layer ratio `max_c r_c / min_c r_c` over conv/linear
/// weights (`r_c = 2·max|W_c|` per output channel, dead channels
/// skipped) — the cross-channel range pathology CLE exists to fix, as a
/// single number: 1.0 is perfectly equalised.
pub fn weight_range_spread(m: &Model) -> f64 {
    let mut worst = 1.0f64;
    for n in m.layers() {
        let w = match &n.op {
            Op::Conv { w, .. }
            | Op::ConvT2d { w, .. }
            | Op::Linear { w, .. } => match m.tensor(w) {
                Ok(t) => t,
                Err(_) => continue,
            },
            _ => unreachable!(),
        };
        let mut hi = 0f64;
        let mut lo = f64::INFINITY;
        for (a, b) in w.channel_ranges() {
            let r = 2.0 * a.abs().max(b.abs()) as f64;
            if r > 0.0 {
                hi = hi.max(r);
                lo = lo.min(r);
            }
        }
        if lo.is_finite() && lo > 0.0 {
            worst = worst.max(hi / lo);
        }
    }
    worst
}

// -- the pass trait & manager -------------------------------------------------

/// One composable DFQ rewrite: a stable name and a graph transformation
/// that reports what it did.
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, m: &mut Model, cx: &mut PassCx) -> Result<PassReport>;
}

/// An ordered pass pipeline composed from configuration.
#[derive(Default)]
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
}

impl PassManager {
    pub fn new() -> PassManager {
        PassManager::default()
    }

    /// Builder-style registration.
    pub fn register(mut self, p: impl Pass + 'static) -> PassManager {
        self.passes.push(Box::new(p));
        self
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.passes.iter().map(|p| p.name()).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.passes.is_empty()
    }

    /// Run every pass in registration order, collecting reports.
    pub fn run(&self, m: &mut Model, cx: &mut PassCx) -> Result<PipelineReport> {
        let mut report = PipelineReport::default();
        for p in &self.passes {
            report.passes.push(p.run(m, cx)?);
        }
        Ok(report)
    }

    /// The FP32-function-preserving stages of the paper pipeline, per
    /// config: BN fold, then (each conditional) ReLU6 replacement,
    /// cross-layer equalization, high-bias absorption.
    pub fn fp32_pipeline(cfg: &DfqConfig) -> PassManager {
        let mut pm = PassManager::new().register(BnFoldPass);
        if cfg.replace_relu6 {
            pm = pm.register(Relu6Pass);
        }
        if cfg.equalize {
            pm = pm.register(EqualizePass {
                iters: cfg.eq_iters,
                tol: cfg.eq_tol,
            });
        }
        if cfg.absorb_bias {
            pm = pm.register(AbsorbPass { sigma: cfg.absorb_sigma });
        }
        pm
    }

    /// The weight-clipping baseline stage (runs *after* the reference
    /// snapshot — clipping changes the FP32 function).
    pub fn clip_pipeline(cfg: &DfqConfig) -> PassManager {
        let mut pm = PassManager::new();
        if let Some(c) = cfg.weight_clip {
            pm = pm.register(ClipPass { c });
        }
        pm
    }

    /// The quantisation-side stages: weight quantisation (retaining
    /// integer codes on ≤ 8-bit schemes) and bias correction.
    pub fn quantize_pipeline(scheme: &QScheme, bc: BiasCorrMode) -> PassManager {
        PassManager::new()
            .register(QuantizePass { scheme: *scheme })
            .register(BiasCorrectPass { mode: bc })
    }
}

// -- the registered passes ----------------------------------------------------

/// BatchNorm folding ([`bn_fold::fold`]).
pub struct BnFoldPass;

impl Pass for BnFoldPass {
    fn name(&self) -> &'static str {
        "bn_fold"
    }

    fn run(&self, m: &mut Model, _cx: &mut PassCx) -> Result<PassReport> {
        let before_nodes = m.nodes.len();
        bn_fold::fold_in_place(m)?;
        let mut r = PassReport::new(self.name());
        r.changed = before_nodes - m.nodes.len();
        r.push("spread_after", weight_range_spread(m));
        Ok(r)
    }
}

/// ReLU6 → ReLU replacement ([`relu6::replace_relu6`]).
pub struct Relu6Pass;

impl Pass for Relu6Pass {
    fn name(&self) -> &'static str {
        "relu6"
    }

    fn run(&self, m: &mut Model, _cx: &mut PassCx) -> Result<PassReport> {
        let mut r = PassReport::new(self.name());
        r.changed = relu6::replace_relu6(m);
        Ok(r)
    }
}

/// Cross-layer equalization ([`equalize::equalize_traced`]), recording
/// the per-sweep convergence trace and the weight-range spread it fixed.
pub struct EqualizePass {
    pub iters: usize,
    pub tol: f32,
}

impl Pass for EqualizePass {
    fn name(&self) -> &'static str {
        "equalize"
    }

    fn run(&self, m: &mut Model, _cx: &mut PassCx) -> Result<PassReport> {
        let mut r = PassReport::new(self.name());
        let pairs = equalize::find_pairs(m);
        let through_pool =
            pairs.iter().filter(|p| p.through_pool).count();
        let pairs = pairs.len();
        let spread_before = weight_range_spread(m);
        let trace = equalize::equalize_traced(m, self.iters, self.tol)?;
        r.changed = trace.len(); // sweeps
        r.push("pairs", pairs as f64);
        r.push("pairs_through_pool", through_pool as f64);
        r.push("spread_before", spread_before);
        r.push("spread_after", weight_range_spread(m));
        r.trace = trace;
        Ok(r)
    }
}

/// High-bias absorption ([`absorb::absorb_high_biases_traced`]),
/// recording channel count and absorbed mass.
pub struct AbsorbPass {
    pub sigma: f32,
}

impl Pass for AbsorbPass {
    fn name(&self) -> &'static str {
        "absorb"
    }

    fn run(&self, m: &mut Model, _cx: &mut PassCx) -> Result<PassReport> {
        let mut r = PassReport::new(self.name());
        let (channels, mass) =
            absorb::absorb_high_biases_traced(m, self.sigma)?;
        r.changed = channels;
        r.push("mass", mass);
        Ok(r)
    }
}

/// Weight-clipping baseline ([`clip::clip_weights`]).
pub struct ClipPass {
    pub c: f32,
}

impl Pass for ClipPass {
    fn name(&self) -> &'static str {
        "clip"
    }

    fn run(&self, m: &mut Model, _cx: &mut PassCx) -> Result<PassReport> {
        let mut r = PassReport::new(self.name());
        r.changed = clip::clip_weights(m, self.c)?;
        r.push("level", self.c as f64);
        r.push("spread_after", weight_range_spread(m));
        Ok(r)
    }
}

/// Weight quantisation: fake-quantise every conv/linear weight in place
/// and (on ≤ 8-bit schemes) retain the integer grid codes in the
/// context for the int8 planner — exactly the loop `Prepared::quantize`
/// always ran.
pub struct QuantizePass {
    pub scheme: QScheme,
}

impl Pass for QuantizePass {
    fn name(&self) -> &'static str {
        "quantize"
    }

    fn run(&self, m: &mut Model, cx: &mut PassCx) -> Result<PassReport> {
        let mut r = PassReport::new(self.name());
        let spread_before = weight_range_spread(m);
        let layer_ids: Vec<usize> = m.layers().iter().map(|n| n.id).collect();
        for id in layer_ids {
            let w = match &m.node(id).op {
                Op::Conv { w, .. }
                | Op::ConvT2d { w, .. }
                | Op::Linear { w, .. } => w.clone(),
                _ => unreachable!(),
            };
            let t = m.tensors.get_mut(&w).expect("weight tensor");
            if self.scheme.bits <= 8 {
                // retain the integer grid the fake-quant image comes
                // from — the int8 engine executes these codes directly
                let (ps, codes) =
                    quant::quantize_weights_retaining(t, &self.scheme)?;
                cx.weight_params.push((id, ps));
                cx.int_weights.push((id, codes));
            } else {
                cx.weight_params
                    .push((id, quant::quantize_weights(t, &self.scheme)));
            }
            r.changed += 1;
        }
        r.push("weight_bits", self.scheme.bits as f64);
        r.push("int_layers", cx.int_weights.len() as f64);
        r.push("spread_before", spread_before);
        Ok(r)
    }
}

/// Bias correction against the FP32 reference in the context
/// ([`bias_correct::analytic_traced`] / `empirical_traced`), recording
/// the summed |Δb| magnitude.
pub struct BiasCorrectPass {
    pub mode: BiasCorrMode,
}

impl Pass for BiasCorrectPass {
    fn name(&self) -> &'static str {
        "bias_correct"
    }

    fn run(&self, m: &mut Model, cx: &mut PassCx) -> Result<PassReport> {
        let mut r = PassReport::new(self.name());
        let (layers, magnitude) = match self.mode {
            BiasCorrMode::None => (0, 0.0),
            BiasCorrMode::Analytic => {
                let reference = cx.reference.ok_or_else(|| {
                    anyhow::anyhow!("bias_correct pass needs a reference model")
                })?;
                bias_correct::analytic_traced(m, reference)?
            }
            BiasCorrMode::Empirical => {
                let reference = cx.reference.ok_or_else(|| {
                    anyhow::anyhow!("bias_correct pass needs a reference model")
                })?;
                let Some(calib) = cx.calib else {
                    bail!("empirical bias correction requires calibration data");
                };
                bias_correct::empirical_traced(m, reference, calib)?
            }
        };
        r.changed = layers;
        r.push("magnitude", magnitude);
        Ok(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::testutil::two_layer_model;

    #[test]
    fn fp32_pipeline_respects_config() {
        let full = PassManager::fp32_pipeline(&DfqConfig::default());
        assert_eq!(full.names(), vec!["bn_fold", "relu6", "equalize", "absorb"]);
        let base = PassManager::fp32_pipeline(&DfqConfig::baseline());
        assert_eq!(base.names(), vec!["bn_fold"]);
        assert!(PassManager::clip_pipeline(&DfqConfig::default()).is_empty());
        let clip = PassManager::clip_pipeline(&DfqConfig {
            weight_clip: Some(0.1),
            ..DfqConfig::default()
        });
        assert_eq!(clip.names(), vec!["clip"]);
    }

    #[test]
    fn reports_carry_cle_trace_and_spread() {
        let m = two_layer_model(71, true);
        let mut model = m.clone();
        let mut cx = PassCx::default();
        let report = PassManager::fp32_pipeline(&DfqConfig::default())
            .run(&mut model, &mut cx)
            .unwrap();
        let eq = report.get("equalize").expect("equalize ran");
        assert!(!eq.trace.is_empty());
        assert_eq!(eq.changed, eq.trace.len());
        // the trace ends converged (below tol) on this tiny model
        assert!(*eq.trace.last().unwrap() < 1e-4);
        // both spreads recorded and sane (≥ 1 by construction); the
        // worst-layer metric is not guaranteed monotone per run, so no
        // ordering is asserted here
        let before = eq.metric("spread_before").unwrap();
        let after = eq.metric("spread_after").unwrap();
        assert!(before.is_finite() && before >= 1.0);
        assert!(after.is_finite() && after >= 1.0);
        // renderings mention every pass
        let table = report.table();
        let json = report.json_lines();
        for name in ["bn_fold", "relu6", "equalize", "absorb"] {
            assert!(table.contains(name), "table missing {name}:\n{table}");
            assert!(json.contains(name), "json missing {name}:\n{json}");
        }
        assert!(table.contains("convergence"));
        assert_eq!(json.trim().lines().count(), report.passes.len());
    }
}
