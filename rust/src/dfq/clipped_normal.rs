//! Clipped-normal distribution (paper Appendix C).
//!
//! Given X ~ N(μ, σ²) and a clipped-linear activation f clipping to
//! [a, b], closed-form mean and variance of f(X). Used by the analytic
//! bias correction (§4.2.1) and the data-free activation-range estimator.
//!
//! `erf` is first-party (Abramowitz & Stegun 7.1.26, |ε| ≤ 1.5e-7 —
//! far below the f32 noise floor of the quantities involved).

/// Error function, A&S 7.1.26 rational approximation.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
            - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal PDF.
pub fn phi(x: f64) -> f64 {
    (-(x * x) / 2.0).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal CDF.
pub fn cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Mean of clip(X, a, b) for X ~ N(mu, sigma²)  (paper eq. 38).
///
/// `b` may be `f64::INFINITY` (plain ReLU uses a = 0, b = ∞).
pub fn clipped_mean(mu: f64, sigma: f64, a: f64, b: f64) -> f64 {
    if sigma <= 0.0 {
        return mu.clamp(a, b);
    }
    let alpha = (a - mu) / sigma;
    let (beta, phi_beta, cdf_beta) = if b.is_infinite() {
        (f64::INFINITY, 0.0, 1.0)
    } else {
        let bb = (b - mu) / sigma;
        (bb, phi(bb), cdf(bb))
    };
    let _ = beta;
    sigma * (phi(alpha) - phi_beta) + mu * (cdf_beta - cdf(alpha))
        + a * cdf(alpha)
        + if b.is_infinite() { 0.0 } else { b * (1.0 - cdf_beta) }
}

/// Variance of clip(X, a, b) for X ~ N(mu, sigma²)  (paper eq. 44).
pub fn clipped_var(mu: f64, sigma: f64, a: f64, b: f64) -> f64 {
    if sigma <= 0.0 {
        return 0.0;
    }
    let m = clipped_mean(mu, sigma, a, b);
    let alpha = (a - mu) / sigma;
    let (phi_beta, cdf_beta, b_phi_beta, b_term) = if b.is_infinite() {
        (0.0, 1.0, 0.0, 0.0)
    } else {
        let bb = (b - mu) / sigma;
        (phi(bb), cdf(bb), b * phi(bb), (b - m) * (b - m) * (1.0 - cdf(bb)))
    };
    let z = cdf_beta - cdf(alpha);
    let v = z * (mu * mu + sigma * sigma + m * m - 2.0 * m * mu)
        + sigma * (a * phi(alpha) - b_phi_beta)
        + sigma * (mu - 2.0 * m) * (phi(alpha) - phi_beta)
        + (a - m) * (a - m) * cdf(alpha)
        + b_term;
    v.max(0.0)
}

/// Mean of ReLU(X) (paper eq. 19): a = 0, b = ∞.
pub fn relu_mean(mu: f64, sigma: f64) -> f64 {
    clipped_mean(mu, sigma, 0.0, f64::INFINITY)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn erf_reference_values() {
        // Reference values from tables.
        assert!((erf(0.0)).abs() < 1e-9);
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((erf(2.0) - 0.9953222650).abs() < 1e-6);
    }

    #[test]
    fn relu_mean_standard_normal() {
        // E[ReLU(N(0,1))] = 1/sqrt(2*pi)
        let want = 1.0 / (2.0 * std::f64::consts::PI).sqrt();
        assert!((relu_mean(0.0, 1.0) - want).abs() < 1e-6);
    }

    #[test]
    fn degenerate_sigma() {
        assert_eq!(clipped_mean(3.0, 0.0, 0.0, 6.0), 3.0);
        assert_eq!(clipped_mean(-1.0, 0.0, 0.0, 6.0), 0.0);
        assert_eq!(clipped_mean(9.0, 0.0, 0.0, 6.0), 6.0);
        assert_eq!(clipped_var(5.0, 0.0, 0.0, 6.0), 0.0);
    }

    /// Property: closed forms match Monte-Carlo for random (mu, sigma, b).
    #[test]
    fn matches_monte_carlo() {
        let mut rng = Rng::new(1234);
        for case in 0..20 {
            let mu = rng.uniform(-3.0, 3.0) as f64;
            let sigma = rng.uniform(0.1, 2.5) as f64;
            let b = if case % 3 == 0 {
                f64::INFINITY
            } else {
                rng.uniform(0.5, 6.0) as f64
            };
            let n = 400_000;
            let mut acc = 0.0;
            let mut acc2 = 0.0;
            for _ in 0..n {
                let x = mu + sigma * rng.normal() as f64;
                let c = x.clamp(0.0, b);
                acc += c;
                acc2 += c * c;
            }
            let mc_mean = acc / n as f64;
            let mc_var = acc2 / n as f64 - mc_mean * mc_mean;
            let cm = clipped_mean(mu, sigma, 0.0, b);
            let cv = clipped_var(mu, sigma, 0.0, b);
            assert!(
                (cm - mc_mean).abs() < 0.02,
                "mean: case {case} mu={mu} sigma={sigma} b={b}: {cm} vs {mc_mean}"
            );
            assert!(
                (cv - mc_var).abs() < 0.05 * (1.0 + mc_var),
                "var: case {case} mu={mu} sigma={sigma} b={b}: {cv} vs {mc_var}"
            );
        }
    }
}
