//! The paper's contribution: the Data-Free Quantization pipeline
//! (Fig. 4): BN folding → ReLU6 replacement → cross-layer equalization →
//! high-bias absorption → weight quantisation → bias correction →
//! data-free activation ranges.
//!
//! Each stage is a registered [`pass::Pass`] over
//! [`crate::graph::Model`] (the rewrite itself lives in its own module
//! below); [`pass::PassManager`] composes them per a [`DfqConfig`] and
//! records per-pass diagnostics (weight-range spread, the CLE
//! convergence trace, absorbed-bias mass, bias-correction magnitude)
//! into a [`pass::PipelineReport`] — printed by `dfq report <arch>`.
//! [`quantize_data_free`] runs the FP32-preserving pipeline, and
//! [`Prepared::quantize`] the quantisation-side one, producing the
//! deployable quantised model + activation config for the engines.

pub mod absorb;
pub mod bias_correct;
pub mod bn_fold;
pub mod clip;
pub mod clipped_normal;
pub mod equalize;
pub mod pass;
pub mod relu6;
/// Test fixtures (also used by the integration/property test targets).
pub mod testutil;

use anyhow::Result;

use crate::graph::Model;
use crate::nn::{qengine, QuantCfg};
use crate::quant::{self, QParams, QScheme};
use crate::tensor::QTensor;

pub use pass::{Pass, PassCx, PassManager, PassReport, PipelineReport};

/// Bias-correction mode (paper §4.2 / appendix D).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BiasCorrMode {
    #[default]
    None,
    /// Level-1 analytic correction via clipped-normal BN statistics.
    Analytic,
    /// Level-2 empirical correction on calibration data.
    Empirical,
}

/// Pipeline configuration. `Default` is the paper's full DFQ recipe
/// minus bias correction (select it at [`Prepared::quantize`] time).
#[derive(Debug, Clone, PartialEq)]
pub struct DfqConfig {
    /// Replace ReLU6 by ReLU before equalization (§5.1.1).
    pub replace_relu6: bool,
    /// Cross-layer equalization (§4.1).
    pub equalize: bool,
    /// Max CLE sweeps / convergence tolerance on |log s|.
    pub eq_iters: usize,
    pub eq_tol: f32,
    /// High-bias absorption (§4.1.3).
    pub absorb_bias: bool,
    /// σ multiplier in `c = max(0, β − n·γ)`.
    pub absorb_sigma: f32,
    /// Optional weight clipping (baseline, §5.1.2): clamp |w| ≤ c.
    pub weight_clip: Option<f32>,
}

impl Default for DfqConfig {
    fn default() -> Self {
        DfqConfig {
            replace_relu6: true,
            equalize: true,
            eq_iters: 40,
            eq_tol: 1e-4,
            absorb_bias: true,
            absorb_sigma: 3.0,
            weight_clip: None,
        }
    }
}

impl DfqConfig {
    /// Plain quantisation: fold BN, nothing else (the paper's
    /// "original model" baseline).
    pub fn baseline() -> DfqConfig {
        DfqConfig {
            replace_relu6: false,
            equalize: false,
            absorb_bias: false,
            ..DfqConfig::default()
        }
    }
}

/// A model after the FP32-preserving DFQ stages, ready to be quantised.
#[derive(Debug, Clone)]
pub struct Prepared {
    /// Transformed model (folded; post CLE/absorption; post weight
    /// clipping when configured).
    pub model: Model,
    /// The *unclipped* transformed model — the true FP32 function bias
    /// correction measures ε against (paper §5.1.2: BC repairs the
    /// biased error introduced by clipping as well as quantisation).
    /// Identical to `model` when no clipping is configured.
    pub reference: Model,
    /// Pass log for reporting.
    pub log: PrepareLog,
}

#[derive(Debug, Clone, Default)]
pub struct PrepareLog {
    pub relu6_replaced: usize,
    pub cle_pairs: usize,
    pub cle_sweeps: usize,
    pub absorbed_channels: usize,
    pub clipped_weights: usize,
}

/// Run the FP32-side DFQ stages (everything before quantisation).
pub fn quantize_data_free(model: &Model, cfg: &DfqConfig) -> Result<Prepared> {
    Ok(quantize_data_free_report(model, cfg)?.0)
}

/// [`quantize_data_free`] through the instrumented [`PassManager`],
/// also returning the per-pass [`PipelineReport`] (weight-range spread,
/// CLE convergence trace, absorbed-bias mass). The produced model is
/// bit-for-bit the one [`quantize_data_free`] always produced — each
/// pass invokes the same rewrite in the same order.
pub fn quantize_data_free_report(
    model: &Model,
    cfg: &DfqConfig,
) -> Result<(Prepared, PipelineReport)> {
    let mut m = model.clone();
    let mut cx = PassCx::default();
    let mut report =
        PassManager::fp32_pipeline(cfg).run(&mut m, &mut cx)?;
    // the unclipped reference is snapshotted between absorption and
    // clipping: bias correction measures ε against the pre-clip function
    let reference = m.clone();
    report.extend(PassManager::clip_pipeline(cfg).run(&mut m, &mut cx)?);
    let log = PrepareLog::from_report(&report);
    Ok((Prepared { model: m, reference, log }, report))
}

impl PrepareLog {
    /// Back-compat summary derived from the structured pass reports.
    fn from_report(report: &PipelineReport) -> PrepareLog {
        let changed =
            |name: &str| report.get(name).map(|p| p.changed).unwrap_or(0);
        PrepareLog {
            relu6_replaced: changed("relu6"),
            cle_pairs: report
                .get("equalize")
                .and_then(|p| p.metric("pairs"))
                .unwrap_or(0.0) as usize,
            cle_sweeps: changed("equalize"),
            absorbed_channels: changed("absorb"),
            clipped_weights: changed("clip"),
        }
    }
}

/// Everything needed to run the quantised model on any engine.
#[derive(Debug, Clone)]
pub struct QuantizedModel {
    /// Weights fake-quantised (+ bias-corrected) model.
    pub model: Model,
    /// Per-layer weight grids (one or out_ch entries per layer).
    pub weight_params: Vec<(usize, Vec<QParams>)>,
    /// Retained integer weight codes per layer (node id → signed-storage
    /// [`QTensor`]): the grids the fake-quant image was computed from,
    /// kept so the int8 engine never re-derives them. Empty when the
    /// scheme is wider than 8 bits.
    pub int_weights: Vec<(usize, QTensor)>,
    /// Activation quantisation rows for the executable / engine.
    pub act_cfg: QuantCfg,
    /// Data-free *pre-activation* grids per conv node (β ± n·γ, no ReLU
    /// clip): the integer planner requantises residual-branch convs onto
    /// these so adds/GAP/head stay on the integer path. Empty when the
    /// scheme or activations are wider than 8 bits.
    pub preact_params: Vec<(usize, QParams)>,
}

impl QuantizedModel {
    /// Compile the retained integer grids into a true-int8 execution
    /// plan ([`qengine::QModel`]): per-layer i8 weights, i64 biases
    /// pre-folded with the input zero-points, fixed-point requant
    /// multipliers, fused clamped-ReLU epilogues, requantise-add /
    /// integer-GAP / int8-head lowering, and dense value slots.
    /// Requires an 8-bit-or-narrower weight scheme and quantised
    /// activations (`act_bits` in 1..=8).
    pub fn pack_int8(&self) -> Result<qengine::QModel> {
        self.pack_int8_opts(qengine::PlanOpts::default())
    }

    /// Like [`QuantizedModel::pack_int8`] with explicit planner options
    /// — `PlanOpts { int8_only: true }` errors (rather than silently
    /// executing f32) when any fallback op survives planning.
    pub fn pack_int8_opts(
        &self,
        opts: qengine::PlanOpts,
    ) -> Result<qengine::QModel> {
        if self.int_weights.len() < self.model.layers().len() {
            anyhow::bail!(
                "pack_int8 needs retained integer weights for all {} \
                 layers, have {} (quantise with bits <= 8)",
                self.model.layers().len(),
                self.int_weights.len()
            );
        }
        let aux = qengine::AuxGrids { preact: self.preact_params.clone() };
        qengine::plan(&self.model, &self.int_weights, &self.act_cfg, &aux, opts)
    }

    /// Compile this model into an execution plan (per `opts`) and write
    /// it to `path` as a `.dfqm` *compiled artifact* — the one-time
    /// export side of the load-and-go deployment path
    /// ([`crate::nn::qengine::QModel::from_artifact`] /
    /// [`crate::serve::Registry`]). Returns the artifact metadata.
    pub fn save_artifact(
        &self,
        path: impl AsRef<std::path::Path>,
        opts: qengine::PlanOpts,
    ) -> Result<crate::artifact::ArtifactInfo> {
        crate::artifact::write_artifact(self, opts, path)
    }

    /// [`Self::save_artifact`] with the bulky sections (`wgrid.i8`,
    /// `plan`) compressed in the container (`dfq compile --compress`).
    pub fn save_artifact_compressed(
        &self,
        path: impl AsRef<std::path::Path>,
        opts: qengine::PlanOpts,
    ) -> Result<crate::artifact::ArtifactInfo> {
        crate::artifact::write_artifact_opts(self, opts, true, path)
    }
}

impl Prepared {
    /// Quantise weights per `scheme`, set data-free activation ranges at
    /// `act_bits` (0 = leave activations FP32), and apply bias
    /// correction (`calib` required for the empirical mode).
    pub fn quantize(
        &self,
        scheme: &QScheme,
        act_bits: u32,
        bc: BiasCorrMode,
        calib: Option<&crate::tensor::Tensor>,
    ) -> Result<QuantizedModel> {
        Ok(self.quantize_report(scheme, act_bits, bc, calib)?.0)
    }

    /// [`Prepared::quantize`] through the instrumented quantisation-side
    /// pass pipeline (`quantize` → `bias_correct`), also returning the
    /// per-pass [`PipelineReport`] (retained int layers, |Δb|
    /// correction magnitude). Output is bit-for-bit identical to
    /// [`Prepared::quantize`].
    pub fn quantize_report(
        &self,
        scheme: &QScheme,
        act_bits: u32,
        bc: BiasCorrMode,
        calib: Option<&crate::tensor::Tensor>,
    ) -> Result<(QuantizedModel, PipelineReport)> {
        let mut q = self.model.clone();
        let mut cx = PassCx {
            reference: Some(&self.reference),
            calib,
            ..PassCx::default()
        };
        let report =
            PassManager::quantize_pipeline(scheme, bc).run(&mut q, &mut cx)?;
        let PassCx { weight_params, int_weights, .. } = cx;
        // one stats propagation feeds both the activation-site rows and
        // the pre-activation grids (the latter only when the int8 path
        // itself is available: bits <= 8 and quantised activations)
        let n_sigma = quant::ranges::DEFAULT_N_SIGMA;
        let (act_cfg, preact_params) = if act_bits == 0 {
            (
                quant::ranges::activation_qcfg(
                    &self.model, 0, scheme.symmetric, n_sigma,
                )?,
                Vec::new(),
            )
        } else {
            let stats = crate::graph::stats::propagate(&self.model)?;
            let act_cfg = quant::ranges::activation_qcfg_with(
                &self.model, &stats, act_bits, scheme.symmetric, n_sigma,
            )?;
            let preact = if scheme.bits <= 8 && act_bits <= 8 {
                quant::ranges::preact_qparams_with(
                    &self.model, &stats, act_bits, scheme.symmetric, n_sigma,
                )
            } else {
                Vec::new()
            };
            (act_cfg, preact)
        };
        Ok((
            QuantizedModel {
                model: q,
                weight_params,
                int_weights,
                act_cfg,
                preact_params,
            },
            report,
        ))
    }

    /// Bias-correct the *unquantised* prepared model against its
    /// unclipped reference (the paper's Table-2 FP32 column for the
    /// clipping baseline: clipping alone loses 4.66%, BC recovers most).
    pub fn bias_corrected_fp32(
        &self,
        bc: BiasCorrMode,
        calib: Option<&crate::tensor::Tensor>,
    ) -> Result<Model> {
        let mut m = self.model.clone();
        match bc {
            BiasCorrMode::None => {}
            BiasCorrMode::Analytic => {
                bias_correct::analytic(&mut m, &self.reference)?;
            }
            BiasCorrMode::Empirical => {
                let calib = calib.ok_or_else(|| {
                    anyhow::anyhow!("empirical BC requires calibration data")
                })?;
                bias_correct::empirical(&mut m, &self.reference, calib)?;
            }
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::testutil::{random_input, two_layer_model};
    use crate::graph::Op;
    use crate::nn;

    #[test]
    fn full_pipeline_runs() {
        let m = two_layer_model(91, true);
        let prep = quantize_data_free(&m, &DfqConfig::default()).unwrap();
        assert!(prep.model.folded);
        assert_eq!(prep.log.cle_pairs, 1);
        let q = prep
            .quantize(&QScheme::int8_asymmetric(), 8, BiasCorrMode::Analytic,
                      None)
            .unwrap();
        assert_eq!(q.act_cfg.rows.len(), prep.model.act_sites().len());
        // quantised model still runs and is close to fp32
        let x = random_input(&m, 2, 1);
        let yq = nn::forward(&q.model, &x, &q.act_cfg).unwrap();
        let yf = nn::forward(
            &prep.model,
            &x,
            &nn::QuantCfg::fp32(&prep.model),
        )
        .unwrap();
        let rel = yq[0].max_abs_diff(&yf[0]) / yf[0].abs_max().max(1e-6);
        assert!(rel < 0.25, "INT8 output wildly off: {rel}");
    }

    #[test]
    fn dfq_beats_baseline_after_corruption() {
        // Corrupt per-channel scales, then check per-tensor INT8 error
        // shrinks dramatically with DFQ vs baseline quantisation.
        let m = two_layer_model(92, true);
        let mut folded = bn_fold::fold(&m).unwrap();
        let pair = equalize::find_pairs(&folded)[0];
        let mut rng = crate::util::rng::Rng::new(17);
        let s: Vec<f32> = (0..8).map(|_| rng.log_uniform(0.05, 20.0)).collect();
        // corrupt by inverse-equalizing (same transform CLE undoes)
        {
            let (wa, ba) = match &folded.node(pair.a).op {
                Op::Conv { w, b, .. } => (w.clone(), b.clone().unwrap()),
                _ => unreachable!(),
            };
            let w = folded.tensor_mut(&wa).unwrap();
            for (i, &si) in s.iter().enumerate() {
                w.scale_out_channel(i, si);
            }
            let b = folded.tensor_mut(&ba).unwrap();
            for (i, &si) in s.iter().enumerate() {
                b.data_mut()[i] *= si;
            }
            if let Some(st) = folded.act_stats.get_mut(&pair.a) {
                for (i, &si) in s.iter().enumerate() {
                    st.mean[i] *= si;
                    st.std[i] *= si;
                }
            }
            let wb = match &folded.node(pair.b).op {
                Op::Conv { w, .. } => w.clone(),
                _ => unreachable!(),
            };
            let w = folded.tensor_mut(&wb).unwrap();
            for (i, &si) in s.iter().enumerate() {
                w.scale_in_channel(i, 1.0 / si);
            }
        }
        let x = random_input(&m, 4, 2);
        let y_fp = nn::forward(&folded, &x, &nn::QuantCfg::fp32(&folded))
            .unwrap();

        let err = |prep: &Prepared| -> f32 {
            let q = prep
                .quantize(&QScheme::int8_asymmetric(), 0,
                          BiasCorrMode::None, None)
                .unwrap();
            let y = nn::forward(&q.model, &x, &q.act_cfg).unwrap();
            y[0].max_abs_diff(&y_fp[0])
        };
        let base = err(&Prepared {
            model: folded.clone(),
            reference: folded.clone(),
            log: PrepareLog::default(),
        });
        let dfq = err(&quantize_data_free(&folded, &DfqConfig::default())
            .unwrap());
        assert!(
            dfq < base * 0.5,
            "DFQ {dfq} not clearly better than baseline {base}"
        );
    }
}
