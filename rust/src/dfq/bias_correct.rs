//! Quantization bias correction (paper §4.2, appendices B–D).
//!
//! Weight quantisation introduces a *biased* error on layer outputs:
//! `E[ỹ] = E[y] + ε·E[x]` with `ε = W̃ − W`. Subtracting `ε·E[x]` from
//! the layer bias restores the FP32 output means.
//!
//! * **Analytic** (level 1, data-free): `E[x]` comes from the
//!   clipped-normal pushforward of the folded BatchNorm statistics
//!   (§4.2.1, App. C) via [`crate::graph::stats::propagate`].
//! * **Empirical** (level 2, App. D): `E[x]` is measured on calibration
//!   data, correcting layers in topological order on the
//!   weights-quantised / activations-FP32 network.

use std::collections::HashMap;

use anyhow::Result;

use crate::graph::{Model, Op};
use crate::nn::{self, QuantCfg};
use crate::tensor::Tensor;

/// Correct `quantized` (weights already fake-quantised) against the
/// FP32 reference `orig` using data-free statistics. Both models must be
/// the *same prepared graph* (post fold/CLE/absorption).
pub fn analytic(quantized: &mut Model, orig: &Model) -> Result<usize> {
    Ok(analytic_traced(quantized, orig)?.0)
}

/// [`analytic`] also reporting the correction *magnitude* — the summed
/// |Δb| folded into biases across all layers (the pass-diagnostics gauge
/// for how much biased error quantisation introduced).
pub fn analytic_traced(
    quantized: &mut Model,
    orig: &Model,
) -> Result<(usize, f64)> {
    let stats = crate::graph::stats::propagate(orig)?;
    let mut corrected = 0usize;
    let mut magnitude = 0f64;
    let layers: Vec<usize> =
        quantized.layers().iter().map(|n| n.id).collect();
    for id in layers {
        let input = quantized.node(id).inputs[0];
        let ex = &stats[&input].mean;
        let (n, m) = correct_layer(quantized, orig, id, ex)?;
        corrected += n;
        magnitude += m;
    }
    Ok((corrected, magnitude))
}

/// Subtract `ε·E[x]` from layer `id`'s bias. Returns 1 if a correction was
/// applied. `ex` is per input channel (paper App. B: the expected error
/// is spatially constant, so it folds into the bias).
fn correct_layer(
    quantized: &mut Model,
    orig: &Model,
    id: usize,
    ex: &[f32],
) -> Result<(usize, f64)> {
    let n = quantized.node(id);
    match &n.op {
        // ConvT shares the dense-conv weight layout [out_ch, in_ch, k, k];
        // with stride > 1 the k² taps partition across output-position
        // phases, so the full eps_sum corrects the phase-averaged mean —
        // the same spatial-constancy approximation App. B makes for
        // padded conv borders.
        Op::Conv { w, b, out_ch, .. } | Op::ConvT2d { w, b, out_ch, .. } => {
            let dw = n.op.is_depthwise();
            let (w_name, b_name, out_ch) =
                (w.clone(), b.clone().expect("folded"), *out_ch);
            let wq = quantized.tensor(&w_name)?;
            let wf = orig.tensor(&w_name)?;
            let spatial: usize = wq.shape()[2..].iter().product();
            let i_count = wq.shape()[1];
            let mut delta = vec![0f64; out_ch];
            for o in 0..out_ch {
                let q = wq.out_channel(o);
                let f = wf.out_channel(o);
                if dw {
                    let eps_sum: f32 =
                        q.iter().zip(f).map(|(a, b)| a - b).sum();
                    delta[o] = (eps_sum * ex[o]) as f64;
                } else {
                    for i in 0..i_count {
                        let mut eps_sum = 0f32;
                        for s in 0..spatial {
                            let k = i * spatial + s;
                            eps_sum += q[k] - f[k];
                        }
                        delta[o] += (eps_sum * ex[i]) as f64;
                    }
                }
            }
            let b = quantized.tensor_mut(&b_name)?;
            for o in 0..out_ch {
                b.data_mut()[o] -= delta[o] as f32;
            }
            Ok((1, delta.iter().map(|d| d.abs()).sum()))
        }
        Op::Linear { w, b, in_dim, out_dim } => {
            let (w_name, b_name, in_dim, out_dim) =
                (w.clone(), b.clone(), *in_dim, *out_dim);
            let wq = quantized.tensor(&w_name)?;
            let wf = orig.tensor(&w_name)?;
            let mut delta = vec![0f64; out_dim];
            for o in 0..out_dim {
                for i in 0..in_dim {
                    let k = o * in_dim + i;
                    delta[o] += ((wq.data()[k] - wf.data()[k]) * ex[i]) as f64;
                }
            }
            let b = quantized.tensor_mut(&b_name)?;
            for o in 0..out_dim {
                b.data_mut()[o] -= delta[o] as f32;
            }
            Ok((1, delta.iter().map(|d| d.abs()).sum()))
        }
        _ => Ok((0, 0.0)),
    }
}

/// Empirical bias correction (paper appendix D) on calibration images.
///
/// Layers are corrected in node order (all producers of a layer are
/// corrected before it); each step measures per-channel pre-activation
/// means of the FP32 network vs the weights-quantised network and folds
/// the difference into the bias.
pub fn empirical(
    quantized: &mut Model,
    orig: &Model,
    calib: &Tensor,
) -> Result<usize> {
    Ok(empirical_traced(quantized, orig, calib)?.0)
}

/// [`empirical`] also reporting the summed |Δb| correction magnitude.
pub fn empirical_traced(
    quantized: &mut Model,
    orig: &Model,
    calib: &Tensor,
) -> Result<(usize, f64)> {
    let cfg_f = QuantCfg::fp32(orig);
    let fp_means = nn::preact_channel_means(orig, calib, &cfg_f)?;
    let layers: Vec<usize> =
        quantized.layers().iter().map(|n| n.id).collect();
    let mut corrected = 0usize;
    let mut magnitude = 0f64;
    for id in layers {
        let cfg_q = QuantCfg::fp32(quantized);
        let q_means = layer_preact_means(quantized, calib, &cfg_q, id)?;
        let b_name = match &quantized.node(id).op {
            Op::Conv { b, .. } | Op::ConvT2d { b, .. } => {
                b.clone().expect("folded")
            }
            Op::Linear { b, .. } => b.clone(),
            _ => continue,
        };
        let fp = &fp_means[&id];
        let b = quantized.tensor_mut(&b_name)?;
        for (o, (&qm, &fm)) in q_means.iter().zip(fp).enumerate() {
            b.data_mut()[o] -= qm - fm;
            magnitude += (qm - fm).abs() as f64;
        }
        corrected += 1;
    }
    Ok((corrected, magnitude))
}

fn layer_preact_means(
    model: &Model,
    x: &Tensor,
    cfg: &QuantCfg,
    id: usize,
) -> Result<Vec<f32>> {
    // a full instrumented forward; fine for calibration-sized batches
    let means: HashMap<usize, Vec<f32>> =
        nn::preact_channel_means(model, x, cfg)?;
    Ok(means[&id].clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::bn_fold;
    use crate::dfq::testutil::{random_input, two_layer_model};
    use crate::quant::{quantize_weights, QScheme};

    fn quantize_model(m: &Model, bits: u32) -> Model {
        let mut q = m.clone();
        let names: Vec<String> = q
            .layers()
            .iter()
            .map(|n| match &n.op {
                Op::Conv { w, .. } | Op::Linear { w, .. } => w.clone(),
                _ => unreachable!(),
            })
            .collect();
        for w in names {
            let t = q.tensors.get_mut(&w).unwrap();
            quantize_weights(
                t,
                &QScheme { bits, symmetric: false, per_channel: false },
            );
        }
        q
    }

    /// The core claim (eq. 16/17): correction restores output means.
    #[test]
    fn empirical_restores_output_means() {
        let m = bn_fold::fold(&two_layer_model(31, true)).unwrap();
        let x = random_input(&m, 16, 5);
        let cfg = QuantCfg::fp32(&m);
        let fp = nn::preact_channel_means(&m, &x, &cfg).unwrap();

        let mut q = quantize_model(&m, 4); // coarse grid -> visible bias
        let out_id = q.layers().last().unwrap().id;
        let before = nn::preact_channel_means(&q, &x, &cfg).unwrap();
        let bias_before: f32 = before[&out_id]
            .iter()
            .zip(&fp[&out_id])
            .map(|(a, b)| (a - b).abs())
            .sum();

        empirical(&mut q, &m, &x).unwrap();
        let after = nn::preact_channel_means(&q, &x, &cfg).unwrap();
        let bias_after: f32 = after[&out_id]
            .iter()
            .zip(&fp[&out_id])
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(
            bias_after < 0.05 * bias_before.max(1e-3),
            "bias {bias_before} -> {bias_after}"
        );
    }

    /// Analytic correction moves output means toward FP32 on data whose
    /// distribution matches the Gaussian assumption.
    #[test]
    fn analytic_reduces_output_bias() {
        let m = bn_fold::fold(&two_layer_model(32, true)).unwrap();
        let x = random_input(&m, 32, 6);
        let cfg = QuantCfg::fp32(&m);
        let fp = nn::preact_channel_means(&m, &x, &cfg).unwrap();

        let mut q = quantize_model(&m, 4);
        let out_id = q.layers().last().unwrap().id;
        let before = nn::preact_channel_means(&q, &x, &cfg).unwrap();
        let bias_before: f32 = before[&out_id]
            .iter()
            .zip(&fp[&out_id])
            .map(|(a, b)| (a - b).abs())
            .sum();

        analytic(&mut q, &m).unwrap();
        let after = nn::preact_channel_means(&q, &x, &cfg).unwrap();
        let bias_after: f32 = after[&out_id]
            .iter()
            .zip(&fp[&out_id])
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(
            bias_after < bias_before,
            "analytic BC did not help: {bias_before} -> {bias_after}"
        );
    }
}
