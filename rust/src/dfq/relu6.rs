//! ReLU6 → ReLU replacement (paper §5.1.1).
//!
//! Equalization rescales channels; a per-channel cut-off would be needed
//! to keep ReLU6 exactly equivariant, so the paper replaces ReLU6 with
//! plain ReLU first ("does not significantly degrade the model
//! performance") and we do the same.

use crate::graph::{ActKind, Model, Op};

/// Replace every ReLU6 with ReLU. Returns how many were replaced.
pub fn replace_relu6(model: &mut Model) -> usize {
    let mut n = 0;
    for node in &mut model.nodes {
        if let Op::Act(kind) = &mut node.op {
            if *kind == ActKind::Relu6 {
                *kind = ActKind::Relu;
                n += 1;
            }
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::testutil::two_layer_model;

    #[test]
    fn replaces_all() {
        let mut m = two_layer_model(51, true);
        // flip the acts to relu6 first
        for node in &mut m.nodes {
            if let Op::Act(k) = &mut node.op {
                *k = ActKind::Relu6;
            }
        }
        assert_eq!(replace_relu6(&mut m), 2);
        assert_eq!(replace_relu6(&mut m), 0);
        assert!(m
            .nodes
            .iter()
            .all(|n| !matches!(n.op, Op::Act(ActKind::Relu6))));
    }
}
