//! High-bias absorption (paper §4.1.3).
//!
//! Equalization with `s_i < 1` inflates biases, which in turn inflates
//! activation quantisation ranges. For a ReLU pair, any per-channel
//! constant `c` with `r(Wx + b - c) = r(Wx + b) - c` for (almost) all x
//! can be moved into the next layer: `b1 -= c`, `b2 += W2·c`. Data-free,
//! `c = max(0, β - 3γ)` holds for 99.865% of inputs under the Gaussian
//! assumption carried by the folded BatchNorm statistics.

use anyhow::Result;

use crate::graph::{ActKind, Model, Op};

use super::equalize::{find_pairs, ClePair};

/// Absorb high biases across every ReLU-connected CLE pair.
/// Returns the number of channels absorbed.
pub fn absorb_high_biases(model: &mut Model, n_sigma: f32) -> Result<usize> {
    Ok(absorb_high_biases_traced(model, n_sigma)?.0)
}

/// [`absorb_high_biases`] also reporting the absorbed-bias *mass* — the
/// sum of the per-channel shifts `c` moved into downstream biases (the
/// pass-diagnostics gauge for how much activation range absorption won).
pub fn absorb_high_biases_traced(
    model: &mut Model,
    n_sigma: f32,
) -> Result<(usize, f64)> {
    assert!(model.folded);
    let pairs = find_pairs(model);
    let mut absorbed = 0usize;
    let mut mass = 0f64;
    for p in &pairs {
        // only plain ReLU satisfies the shift identity; ReLU6's upper
        // clip breaks it (the paper replaces ReLU6 beforehand).
        match p.act {
            Some(act_id) => match model.node(act_id).op {
                Op::Act(ActKind::Relu) => {}
                _ => continue,
            },
            None => continue,
        }
        let (n, m) = absorb_pair(model, p, n_sigma)?;
        absorbed += n;
        mass += m;
    }
    Ok((absorbed, mass))
}

fn absorb_pair(
    model: &mut Model,
    p: &ClePair,
    n_sigma: f32,
) -> Result<(usize, f64)> {
    let Some(st) = model.act_stats.get(&p.a) else {
        return Ok((0, 0.0)); // no BN statistics -> nothing data-free to absorb
    };
    let c: Vec<f32> = st
        .mean
        .iter()
        .zip(&st.std)
        .map(|(m, s)| (m - n_sigma * s).max(0.0))
        .collect();
    if c.iter().all(|&x| x == 0.0) {
        return Ok((0, 0.0));
    }

    // b1 -= c ; stats.mean -= c
    let ba = match &model.node(p.a).op {
        Op::Conv { b, .. } => b.clone().expect("folded conv has bias"),
        _ => unreachable!(),
    };
    {
        let b = model.tensor_mut(&ba)?;
        for (i, &ci) in c.iter().enumerate() {
            b.data_mut()[i] -= ci;
        }
    }
    if let Some(st) = model.act_stats.get_mut(&p.a) {
        for (i, &ci) in c.iter().enumerate() {
            st.mean[i] -= ci;
        }
    }

    // b2 += W2 · c  (sum over the kernel's spatial taps per channel)
    let nb = model.node(p.b);
    let dw = nb.op.is_depthwise();
    let (wb, bb) = match &nb.op {
        Op::Conv { w, b, .. } => {
            (w.clone(), b.clone().expect("folded conv has bias"))
        }
        _ => unreachable!(),
    };
    let w = model.tensor(&wb)?.clone();
    let b2 = model.tensor_mut(&bb)?;
    let spatial: usize = w.shape()[2..].iter().product();
    if dw {
        for (i, &ci) in c.iter().enumerate() {
            let sum: f32 = w.out_channel(i).iter().sum();
            b2.data_mut()[i] += ci * sum;
        }
    } else {
        let i_count = w.shape()[1];
        for o in 0..w.shape()[0] {
            let ch = w.out_channel(o);
            let mut acc = 0f64;
            for (i, &ci) in c.iter().enumerate() {
                let sum: f32 = ch[i * spatial..(i + 1) * spatial].iter().sum();
                acc += (ci * sum) as f64;
            }
            debug_assert_eq!(i_count, c.len());
            b2.data_mut()[o] += acc as f32;
        }
    }
    Ok((
        c.iter().filter(|&&x| x > 0.0).count(),
        c.iter().map(|&x| x as f64).sum(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::bn_fold;
    use crate::dfq::testutil::{random_input, two_layer_model};
    use crate::graph::ChannelStats;
    use crate::nn::{self, QuantCfg};

    /// Build a folded pair where channel biases are large and positive so
    /// absorption has something to move, with statistics set such that
    /// `c = β − 3γ` equals the *actual* per-channel pre-activation
    /// minimum on the probe input — the regime where absorption is exact.
    fn model_with_high_bias(x: &crate::tensor::Tensor) -> Model {
        let mut m = bn_fold::fold(&two_layer_model(21, true)).unwrap();
        let pair = find_pairs(&m)[0];
        let ba = match &m.node(pair.a).op {
            Op::Conv { b, .. } => b.clone().unwrap(),
            _ => unreachable!(),
        };
        {
            let b = m.tensor_mut(&ba).unwrap();
            for v in b.data_mut() {
                *v += 5.0;
            }
        }
        // measure actual pre-act minima of layer a on the probe input
        let vals = nn::forward_collect(&m, x, &QuantCfg::fp32(&m)).unwrap();
        let t = &vals[&pair.a];
        let s = t.shape().to_vec();
        let spatial = s[2] * s[3];
        let mut mins = vec![f32::INFINITY; s[1]];
        for img in 0..s[0] {
            for c in 0..s[1] {
                let base = (img * s[1] + c) * spatial;
                for p in 0..spatial {
                    mins[c] = mins[c].min(t.data()[base + p]);
                }
            }
        }
        let st = m.act_stats.get_mut(&pair.a).unwrap();
        for i in 0..st.mean.len() {
            st.std[i] = 0.1;
            st.mean[i] = mins[i] + 3.0 * 0.1; // c == mins[i]
        }
        m
    }

    #[test]
    fn absorbs_and_preserves_function_when_exact() {
        let x = {
            let m0 = bn_fold::fold(&two_layer_model(21, true)).unwrap();
            random_input(&m0, 3, 7)
        };
        let mut m = model_with_high_bias(&x);
        let y0 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();
        let n = absorb_high_biases(&mut m, 3.0).unwrap();
        assert!(n > 0, "nothing absorbed");
        let y1 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();
        // exact because every pre-activation stays >= c by construction
        let rel = y0[0].max_abs_diff(&y1[0]) / y0[0].abs_max().max(1e-6);
        assert!(rel < 1e-4, "absorption broke the function: {rel}");
    }

    #[test]
    fn reduces_activation_upper_range() {
        let x = {
            let m0 = bn_fold::fold(&two_layer_model(21, true)).unwrap();
            random_input(&m0, 3, 7)
        };
        let mut m = model_with_high_bias(&x);
        let pair = find_pairs(&m)[0];
        let before = m.act_stats[&pair.a]
            .mean
            .iter()
            .cloned()
            .fold(f32::MIN, f32::max);
        absorb_high_biases(&mut m, 3.0).unwrap();
        let after = m.act_stats[&pair.a]
            .mean
            .iter()
            .cloned()
            .fold(f32::MIN, f32::max);
        assert!(after < before, "{after} !< {before}");
    }

    #[test]
    fn no_stats_is_a_noop() {
        let mut m = bn_fold::fold(&two_layer_model(22, true)).unwrap();
        m.act_stats.clear();
        assert_eq!(absorb_high_biases(&mut m, 3.0).unwrap(), 0);
    }

    #[test]
    fn zero_c_is_a_noop() {
        let mut m = bn_fold::fold(&two_layer_model(23, true)).unwrap();
        let pair = find_pairs(&m)[0];
        m.act_stats.insert(
            pair.a,
            ChannelStats { mean: vec![0.0; 8], std: vec![1.0; 8] },
        );
        let before = m.clone();
        absorb_high_biases(&mut m, 3.0).unwrap();
        let ba = match &m.node(pair.a).op {
            Op::Conv { b, .. } => b.clone().unwrap(),
            _ => unreachable!(),
        };
        assert_eq!(
            m.tensor(&ba).unwrap().data(),
            before.tensor(&ba).unwrap().data()
        );
    }
}
