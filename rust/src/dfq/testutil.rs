//! Test fixtures: small randomly-initialised models built directly in
//! Rust (no artifacts needed), plus a BN-aware reference forward used to
//! validate folding. Compiled only for tests.

use std::collections::{BTreeMap, HashMap};

use crate::graph::{ActKind, Model, Node, Op, Task};
use crate::nn::{conv, ops};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub fn rand_t(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
    Tensor::new(shape, rng.normal_vec(shape.iter().product(), std))
}

/// conv(3->8, 3x3) + bn + relu + conv(8->8 depthwise or dense) + bn + relu.
/// `with_bn=false` gives plain biased convs.
pub fn two_layer_model(seed: u64, with_bn: bool) -> Model {
    let mut rng = Rng::new(seed);
    let mut tensors = BTreeMap::new();
    let mut nodes = vec![Node { id: 0, inputs: vec![], op: Op::Input }];
    let mut id = 0usize;

    let mut conv = |nodes: &mut Vec<Node>,
                    tensors: &mut BTreeMap<String, Tensor>,
                    rng: &mut Rng,
                    input: usize,
                    in_ch: usize,
                    out_ch: usize,
                    k: usize,
                    act: ActKind|
     -> usize {
        id += 1;
        let w = format!("w{id}");
        tensors.insert(w.clone(), rand_t(rng, &[out_ch, in_ch, k, k], 0.4));
        let b = if with_bn {
            None
        } else {
            let b = format!("b{id}");
            tensors.insert(b.clone(), rand_t(rng, &[out_ch], 0.2));
            Some(b)
        };
        nodes.push(Node {
            id,
            inputs: vec![input],
            op: Op::Conv {
                w,
                b,
                in_ch,
                out_ch,
                k,
                stride: 1,
                pad: k / 2,
                groups: 1,
            },
        });
        let mut last = id;
        if with_bn {
            id += 1;
            for (p, std, ofs) in [
                ("g", 0.3f32, 1.0f32),
                ("be", 0.3, 0.1),
                ("m", 0.3, 0.0),
                ("v", 0.0, 0.0),
            ] {
                let name = format!("{p}{id}");
                let mut t = rand_t(rng, &[out_ch], std);
                t.map_inplace(|x| x + ofs);
                if p == "v" {
                    // positive variances
                    t = rand_t(rng, &[out_ch], 0.3);
                    t.map_inplace(|x| x.abs() + 0.5);
                }
                tensors.insert(name, t);
            }
            nodes.push(Node {
                id,
                inputs: vec![last],
                op: Op::BatchNorm {
                    ch: out_ch,
                    gamma: format!("g{id}"),
                    beta: format!("be{id}"),
                    mean: format!("m{id}"),
                    var: format!("v{id}"),
                },
            });
            last = id;
        }
        id += 1;
        nodes.push(Node { id, inputs: vec![last], op: Op::Act(act) });
        id
    };

    let a1 = conv(&mut nodes, &mut tensors, &mut rng, 0, 3, 8, 3, ActKind::Relu);
    let a2 = conv(&mut nodes, &mut tensors, &mut rng, a1, 8, 8, 1, ActKind::Relu);

    Model {
        name: "test2l".into(),
        task: Task::Classification,
        input_shape: [3, 8, 8],
        num_classes: 8,
        nodes,
        outputs: vec![a2],
        tensors,
        meta: BTreeMap::new(),
        act_stats: HashMap::new(),
        folded: !with_bn,
    }
}

pub fn random_input(model: &Model, batch: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let [c, h, w] = model.input_shape;
    let data: Vec<f32> =
        (0..batch * c * h * w).map(|_| rng.f32()).collect();
    Tensor::new(&[batch, c, h, w], data)
}

/// Reference forward that evaluates bn nodes live (inference statistics),
/// independent of the folding code path.
pub fn forward_with_bn(model: &Model, x: &Tensor) -> Tensor {
    let mut vals: HashMap<usize, Tensor> = HashMap::new();
    vals.insert(0, x.clone());
    for n in &model.nodes {
        let y = match &n.op {
            Op::Input => continue,
            Op::Conv { w, b, stride, pad, groups, .. } => {
                let bias = b.as_ref().map(|b| model.tensor(b).unwrap().data());
                conv::conv2d(
                    &vals[&n.inputs[0]],
                    model.tensor(w).unwrap(),
                    bias,
                    *stride,
                    *pad,
                    *groups,
                )
            }
            Op::BatchNorm { ch, gamma, beta, mean, var } => {
                let g = model.tensor(gamma).unwrap().data();
                let be = model.tensor(beta).unwrap().data();
                let mu = model.tensor(mean).unwrap().data();
                let va = model.tensor(var).unwrap().data();
                let mut t = vals[&n.inputs[0]].clone();
                let s = t.shape().to_vec();
                let spatial = s[2] * s[3];
                let d = t.data_mut();
                for img in 0..s[0] {
                    for c in 0..*ch {
                        let inv = g[c] / (va[c] + super::bn_fold::BN_EPS).sqrt();
                        let base = (img * ch + c) * spatial;
                        for p in 0..spatial {
                            d[base + p] = (d[base + p] - mu[c]) * inv + be[c];
                        }
                    }
                }
                t
            }
            Op::Act(kind) => {
                let mut t = vals[&n.inputs[0]].clone();
                ops::clip_act(&mut t, kind.clip_hi());
                t
            }
            Op::Add => ops::add(&vals[&n.inputs[0]], &vals[&n.inputs[1]]),
            Op::Gap => ops::global_avg_pool(&vals[&n.inputs[0]]),
            Op::Linear { w, b, .. } => ops::linear(
                &vals[&n.inputs[0]],
                model.tensor(w).unwrap(),
                model.tensor(b).unwrap().data(),
            ),
            Op::Upsample { factor } => {
                ops::upsample_nearest(&vals[&n.inputs[0]], *factor)
            }
        };
        vals.insert(n.id, y);
    }
    vals.remove(&model.outputs[0]).unwrap()
}
