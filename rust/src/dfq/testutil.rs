//! Test fixtures: small randomly-initialised models built directly in
//! Rust (no artifacts needed), plus a BN-aware reference forward used to
//! validate folding. Compiled only for tests.

use std::collections::{BTreeMap, HashMap};

use crate::graph::{ActKind, Model, Node, Op, PoolKind, Task};
use crate::nn::{conv, ops};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub fn rand_t(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
    Tensor::new(shape, rng.normal_vec(shape.iter().product(), std))
}

/// conv(3->8, 3x3) + bn + relu + conv(8->8 depthwise or dense) + bn + relu.
/// `with_bn=false` gives plain biased convs.
pub fn two_layer_model(seed: u64, with_bn: bool) -> Model {
    let mut rng = Rng::new(seed);
    let mut tensors = BTreeMap::new();
    let mut nodes = vec![Node { id: 0, inputs: vec![], op: Op::Input }];
    let mut id = 0usize;

    let mut conv = |nodes: &mut Vec<Node>,
                    tensors: &mut BTreeMap<String, Tensor>,
                    rng: &mut Rng,
                    input: usize,
                    in_ch: usize,
                    out_ch: usize,
                    k: usize,
                    act: ActKind|
     -> usize {
        id += 1;
        let w = format!("w{id}");
        tensors.insert(w.clone(), rand_t(rng, &[out_ch, in_ch, k, k], 0.4));
        let b = if with_bn {
            None
        } else {
            let b = format!("b{id}");
            tensors.insert(b.clone(), rand_t(rng, &[out_ch], 0.2));
            Some(b)
        };
        nodes.push(Node {
            id,
            inputs: vec![input],
            op: Op::Conv {
                w,
                b,
                in_ch,
                out_ch,
                k,
                stride: 1,
                pad: k / 2,
                groups: 1,
            },
        });
        let mut last = id;
        if with_bn {
            id += 1;
            for (p, std, ofs) in [
                ("g", 0.3f32, 1.0f32),
                ("be", 0.3, 0.1),
                ("m", 0.3, 0.0),
                ("v", 0.0, 0.0),
            ] {
                let name = format!("{p}{id}");
                let mut t = rand_t(rng, &[out_ch], std);
                t.map_inplace(|x| x + ofs);
                if p == "v" {
                    // positive variances
                    t = rand_t(rng, &[out_ch], 0.3);
                    t.map_inplace(|x| x.abs() + 0.5);
                }
                tensors.insert(name, t);
            }
            nodes.push(Node {
                id,
                inputs: vec![last],
                op: Op::BatchNorm {
                    ch: out_ch,
                    gamma: format!("g{id}"),
                    beta: format!("be{id}"),
                    mean: format!("m{id}"),
                    var: format!("v{id}"),
                },
            });
            last = id;
        }
        id += 1;
        nodes.push(Node { id, inputs: vec![last], op: Op::Act(act) });
        id
    };

    let a1 = conv(&mut nodes, &mut tensors, &mut rng, 0, 3, 8, 3, ActKind::Relu);
    let a2 = conv(&mut nodes, &mut tensors, &mut rng, a1, 8, 8, 1, ActKind::Relu);

    Model {
        name: "test2l".into(),
        task: Task::Classification,
        input_shape: [3, 8, 8],
        num_classes: 8,
        nodes,
        outputs: vec![a2],
        tensors,
        meta: BTreeMap::new(),
        act_stats: HashMap::new(),
        folded: !with_bn,
    }
}

/// MobileNet-v2-style residual block + head:
///
/// ```text
/// input → conv3x3(3→8) → bn → relu ─┬→ dw3x3(8) → bn → relu
///                                   │      → pw1x1(8→8) → bn ─┐
///                                   └───────────── add ←──────┘
///                                                   ↓
///                                                  gap → linear(8→10)
/// ```
///
/// Exercises every integer op of the qengine plan: fused dense +
/// depthwise convs, a pointwise conv requantised onto its
/// pre-activation grid, requantise-add, integer GAP and the int8
/// linear head.
pub fn residual_block_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let mut tensors = BTreeMap::new();
    let mut nodes = vec![Node { id: 0, inputs: vec![], op: Op::Input }];
    let mut id = 0usize;
    let c = 8usize;

    let mut conv_bn = |nodes: &mut Vec<Node>,
                       tensors: &mut BTreeMap<String, Tensor>,
                       rng: &mut Rng,
                       input: usize,
                       in_ch: usize,
                       out_ch: usize,
                       k: usize,
                       groups: usize,
                       act: Option<ActKind>|
     -> usize {
        id += 1;
        let w = format!("w{id}");
        tensors.insert(
            w.clone(),
            rand_t(rng, &[out_ch, in_ch / groups, k, k], 0.4),
        );
        nodes.push(Node {
            id,
            inputs: vec![input],
            op: Op::Conv {
                w,
                b: None,
                in_ch,
                out_ch,
                k,
                stride: 1,
                pad: k / 2,
                groups,
            },
        });
        // bn params: gamma ~ N(1, .3), beta ~ N(.1, .3), mean ~ N(0, .3),
        // var = |N(0, .3)| + .5
        id += 1;
        for (p, std, ofs) in [
            ("g", 0.3f32, 1.0f32),
            ("be", 0.3, 0.1),
            ("m", 0.3, 0.0),
            ("v", 0.0, 0.0),
        ] {
            let name = format!("{p}{id}");
            let mut t = rand_t(rng, &[out_ch], std);
            t.map_inplace(|x| x + ofs);
            if p == "v" {
                t = rand_t(rng, &[out_ch], 0.3);
                t.map_inplace(|x| x.abs() + 0.5);
            }
            tensors.insert(name, t);
        }
        nodes.push(Node {
            id,
            inputs: vec![id - 1],
            op: Op::BatchNorm {
                ch: out_ch,
                gamma: format!("g{id}"),
                beta: format!("be{id}"),
                mean: format!("m{id}"),
                var: format!("v{id}"),
            },
        });
        if let Some(kind) = act {
            id += 1;
            nodes.push(Node {
                id,
                inputs: vec![id - 1],
                op: Op::Act(kind),
            });
        }
        id
    };

    let a1 = conv_bn(
        &mut nodes, &mut tensors, &mut rng, 0, 3, c, 3, 1,
        Some(ActKind::Relu),
    );
    let a2 = conv_bn(
        &mut nodes, &mut tensors, &mut rng, a1, c, c, 3, c,
        Some(ActKind::Relu),
    );
    // pointwise with bn but no activation: its output feeds the add
    let p3 = conv_bn(&mut nodes, &mut tensors, &mut rng, a2, c, c, 1, 1, None);

    id += 1;
    let add_id = id;
    nodes.push(Node { id: add_id, inputs: vec![a1, p3], op: Op::Add });
    id += 1;
    let gap_id = id;
    nodes.push(Node { id: gap_id, inputs: vec![add_id], op: Op::Gap });
    id += 1;
    let lin_id = id;
    let wl = format!("wl{lin_id}");
    tensors.insert(wl.clone(), rand_t(&mut rng, &[10, c], 0.4));
    let bl = format!("bl{lin_id}");
    tensors.insert(bl.clone(), rand_t(&mut rng, &[10], 0.2));
    nodes.push(Node {
        id: lin_id,
        inputs: vec![gap_id],
        op: Op::Linear { w: wl, b: bl, in_dim: c, out_dim: 10 },
    });

    Model {
        name: "test_resblock".into(),
        task: Task::Classification,
        input_shape: [3, 8, 8],
        num_classes: 10,
        nodes,
        outputs: vec![lin_id],
        tensors,
        meta: BTreeMap::new(),
        act_stats: HashMap::new(),
        folded: false,
    }
}

/// Inception-style multi-branch block + max-pool stem:
///
/// ```text
/// input → conv3x3(3→8) → bn → relu → maxpool(3, s2, p1)
///           ┌────────────────┬───────────────────┐
///   conv1x1(8→8)     conv1x1(8→4) → relu     avgpool(3, s1, p1)
///     → bn → relu      → conv3x3(4→8)            → conv1x1(8→4)
///           │           → bn → relu                → bn → relu
///           └───────→ concat (8+8+4 = 20ch) ←──────┘
///                          ↓
///                    gap → linear(20→10)
/// ```
///
/// Exercises the branchy-graph integer ops end to end: a max-pool stem,
/// an avg-pool branch, a requantise-concat merge, and a CLE pair *inside*
/// branch b (pair discovery must stop at the pool/concat boundaries).
pub fn inception_block_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let mut tensors = BTreeMap::new();
    let mut nodes = vec![Node { id: 0, inputs: vec![], op: Op::Input }];
    let mut id = 0usize;
    let c = 8usize;

    // `id` is threaded by &mut (not captured) so pool/concat nodes can
    // be appended between conv_bn calls
    let conv_bn = |nodes: &mut Vec<Node>,
                   tensors: &mut BTreeMap<String, Tensor>,
                   rng: &mut Rng,
                   id: &mut usize,
                   input: usize,
                   in_ch: usize,
                   out_ch: usize,
                   k: usize|
     -> usize {
        *id += 1;
        let w = format!("w{id}");
        tensors.insert(w.clone(), rand_t(rng, &[out_ch, in_ch, k, k], 0.4));
        nodes.push(Node {
            id: *id,
            inputs: vec![input],
            op: Op::Conv {
                w,
                b: None,
                in_ch,
                out_ch,
                k,
                stride: 1,
                pad: k / 2,
                groups: 1,
            },
        });
        *id += 1;
        for (p, std, ofs) in [
            ("g", 0.3f32, 1.0f32),
            ("be", 0.3, 0.1),
            ("m", 0.3, 0.0),
            ("v", 0.0, 0.0),
        ] {
            let name = format!("{p}{id}");
            let mut t = rand_t(rng, &[out_ch], std);
            t.map_inplace(|x| x + ofs);
            if p == "v" {
                t = rand_t(rng, &[out_ch], 0.3);
                t.map_inplace(|x| x.abs() + 0.5);
            }
            tensors.insert(name, t);
        }
        nodes.push(Node {
            id: *id,
            inputs: vec![*id - 1],
            op: Op::BatchNorm {
                ch: out_ch,
                gamma: format!("g{id}"),
                beta: format!("be{id}"),
                mean: format!("m{id}"),
                var: format!("v{id}"),
            },
        });
        *id += 1;
        nodes.push(Node {
            id: *id,
            inputs: vec![*id - 1],
            op: Op::Act(ActKind::Relu),
        });
        *id
    };

    // stem: conv + max-pool
    let stem =
        conv_bn(&mut nodes, &mut tensors, &mut rng, &mut id, 0, 3, c, 3);
    id += 1;
    let pool0 = id;
    nodes.push(Node {
        id: pool0,
        inputs: vec![stem],
        op: Op::pool2d(PoolKind::Max, 3, 2, 1),
    });

    // branch a: 1x1 conv
    let ba =
        conv_bn(&mut nodes, &mut tensors, &mut rng, &mut id, pool0, c, c, 1);
    // branch b: 1x1 squeeze -> 3x3 expand (a CLE pair inside the branch)
    let bb1 = conv_bn(
        &mut nodes, &mut tensors, &mut rng, &mut id, pool0, c, c / 2, 1,
    );
    let bb2 = conv_bn(
        &mut nodes, &mut tensors, &mut rng, &mut id, bb1, c / 2, c, 3,
    );
    // branch c: avg-pool -> 1x1 conv
    id += 1;
    let poolc = id;
    nodes.push(Node {
        id: poolc,
        inputs: vec![pool0],
        op: Op::pool2d(PoolKind::Avg, 3, 1, 1),
    });
    let bc = conv_bn(
        &mut nodes, &mut tensors, &mut rng, &mut id, poolc, c, c / 2, 1,
    );

    // merge + head
    id += 1;
    let cat = id;
    nodes.push(Node { id: cat, inputs: vec![ba, bb2, bc], op: Op::Concat });
    let c_cat = c + c + c / 2;
    id += 1;
    let gap_id = id;
    nodes.push(Node { id: gap_id, inputs: vec![cat], op: Op::Gap });
    id += 1;
    let lin_id = id;
    let wl = format!("wl{lin_id}");
    tensors.insert(wl.clone(), rand_t(&mut rng, &[10, c_cat], 0.4));
    let bl = format!("bl{lin_id}");
    tensors.insert(bl.clone(), rand_t(&mut rng, &[10], 0.2));
    nodes.push(Node {
        id: lin_id,
        inputs: vec![gap_id],
        op: Op::Linear { w: wl, b: bl, in_dim: c_cat, out_dim: 10 },
    });

    Model {
        name: "test_inception".into(),
        task: Task::Classification,
        input_shape: [3, 8, 8],
        num_classes: 10,
        nodes,
        outputs: vec![lin_id],
        tensors,
        meta: BTreeMap::new(),
        act_stats: HashMap::new(),
        folded: false,
    }
}

/// Shared conv+bn+relu builder for the branchy fixtures: threads `id`
/// by `&mut` so pool/concat/upsample nodes can be appended between
/// calls. BN params follow the inception recipe: gamma ~ N(1, .3),
/// beta ~ N(.1, .3), mean ~ N(0, .3), var = |N(0, .3)| + .5.
#[allow(clippy::too_many_arguments)]
fn conv_bn_relu(
    nodes: &mut Vec<Node>,
    tensors: &mut BTreeMap<String, Tensor>,
    rng: &mut Rng,
    id: &mut usize,
    input: usize,
    in_ch: usize,
    out_ch: usize,
    k: usize,
) -> usize {
    *id += 1;
    let w = format!("w{id}");
    tensors.insert(w.clone(), rand_t(rng, &[out_ch, in_ch, k, k], 0.4));
    nodes.push(Node {
        id: *id,
        inputs: vec![input],
        op: Op::Conv {
            w,
            b: None,
            in_ch,
            out_ch,
            k,
            stride: 1,
            pad: k / 2,
            groups: 1,
        },
    });
    push_bn_relu(nodes, tensors, rng, id, out_ch)
}

/// ConvT+bn+relu builder (decoder upsampling stage).
#[allow(clippy::too_many_arguments)]
fn convt_bn_relu(
    nodes: &mut Vec<Node>,
    tensors: &mut BTreeMap<String, Tensor>,
    rng: &mut Rng,
    id: &mut usize,
    input: usize,
    in_ch: usize,
    out_ch: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> usize {
    *id += 1;
    let w = format!("w{id}");
    tensors.insert(w.clone(), rand_t(rng, &[out_ch, in_ch, k, k], 0.4));
    nodes.push(Node {
        id: *id,
        inputs: vec![input],
        op: Op::ConvT2d { w, b: None, in_ch, out_ch, k, stride, pad },
    });
    push_bn_relu(nodes, tensors, rng, id, out_ch)
}

fn push_bn_relu(
    nodes: &mut Vec<Node>,
    tensors: &mut BTreeMap<String, Tensor>,
    rng: &mut Rng,
    id: &mut usize,
    out_ch: usize,
) -> usize {
    *id += 1;
    for (p, std, ofs) in [
        ("g", 0.3f32, 1.0f32),
        ("be", 0.3, 0.1),
        ("m", 0.3, 0.0),
        ("v", 0.0, 0.0),
    ] {
        let name = format!("{p}{id}");
        let mut t = rand_t(rng, &[out_ch], std);
        t.map_inplace(|x| x + ofs);
        if p == "v" {
            t = rand_t(rng, &[out_ch], 0.3);
            t.map_inplace(|x| x.abs() + 0.5);
        }
        tensors.insert(name, t);
    }
    nodes.push(Node {
        id: *id,
        inputs: vec![*id - 1],
        op: Op::BatchNorm {
            ch: out_ch,
            gamma: format!("g{id}"),
            beta: format!("be{id}"),
            mean: format!("m{id}"),
            var: format!("v{id}"),
        },
    });
    *id += 1;
    nodes.push(Node {
        id: *id,
        inputs: vec![*id - 1],
        op: Op::Act(ActKind::Relu),
    });
    *id
}

/// DeepLab-style segmentation head:
///
/// ```text
/// input → conv3x3(3→8) → bn → relu → maxpool(3, s2, p1)   ← through-pool
///       → conv3x3(8→8) → bn → relu                          CLE pair
///           ┌──────────────────┬──────────────────────┐
///   conv1x1(8→4)       conv3x3(8→4)        global avgpool → conv1x1(8→4)
///     → bn → relu        → bn → relu         → bn → relu → upsample(4)
///           └────────→ concat (12ch) ←─────────────────┘
///                          ↓
///        convT2d(12→8, k4, s2, p1) → bn → relu   (decoder upsample 4→8)
///                          ↓
///        conv3x3(8→8) → bn → relu → gap → linear(8→10)
/// ```
///
/// Exercises the decoder path end to end: the transposed-conv integer
/// lowering, a global pool inside a branch (ASPP image pooling), the
/// requantise-concat merge, and a CLE pair whose chain crosses the stem
/// max-pool (`through_pool`).
pub fn deeplab_head_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let mut tensors = BTreeMap::new();
    let mut nodes = vec![Node { id: 0, inputs: vec![], op: Op::Input }];
    let mut id = 0usize;
    let c = 8usize;

    // backbone: conv → pool → conv (the pool sits inside a CLE pair)
    let stem1 =
        conv_bn_relu(&mut nodes, &mut tensors, &mut rng, &mut id, 0, 3, c, 3);
    id += 1;
    let pool0 = id;
    nodes.push(Node {
        id: pool0,
        inputs: vec![stem1],
        op: Op::pool2d(PoolKind::Max, 3, 2, 1),
    });
    let stem2 = conv_bn_relu(
        &mut nodes, &mut tensors, &mut rng, &mut id, pool0, c, c, 3,
    );

    // atrous-style branches over the 4x4 feature map
    let b1 = conv_bn_relu(
        &mut nodes, &mut tensors, &mut rng, &mut id, stem2, c, c / 2, 1,
    );
    let b2 = conv_bn_relu(
        &mut nodes, &mut tensors, &mut rng, &mut id, stem2, c, c / 2, 3,
    );
    // image-pooling branch: global avg pool → 1x1 conv → upsample back
    id += 1;
    let gp = id;
    nodes.push(Node {
        id: gp,
        inputs: vec![stem2],
        op: Op::global_pool2d(PoolKind::Avg),
    });
    let b3c = conv_bn_relu(
        &mut nodes, &mut tensors, &mut rng, &mut id, gp, c, c / 2, 1,
    );
    id += 1;
    let b3 = id;
    nodes.push(Node {
        id: b3,
        inputs: vec![b3c],
        op: Op::Upsample { factor: 4 },
    });

    // merge + transposed-conv decoder
    id += 1;
    let cat = id;
    nodes.push(Node { id: cat, inputs: vec![b1, b2, b3], op: Op::Concat });
    let c_cat = 3 * (c / 2); // 12
    let dec = convt_bn_relu(
        &mut nodes, &mut tensors, &mut rng, &mut id, cat, c_cat, c, 4, 2, 1,
    );
    let head = conv_bn_relu(
        &mut nodes, &mut tensors, &mut rng, &mut id, dec, c, c, 3,
    );

    id += 1;
    let gap_id = id;
    nodes.push(Node { id: gap_id, inputs: vec![head], op: Op::Gap });
    id += 1;
    let lin_id = id;
    let wl = format!("wl{lin_id}");
    tensors.insert(wl.clone(), rand_t(&mut rng, &[10, c], 0.4));
    let bl = format!("bl{lin_id}");
    tensors.insert(bl.clone(), rand_t(&mut rng, &[10], 0.2));
    nodes.push(Node {
        id: lin_id,
        inputs: vec![gap_id],
        op: Op::Linear { w: wl, b: bl, in_dim: c, out_dim: 10 },
    });

    Model {
        name: "test_deeplab".into(),
        task: Task::Classification,
        input_shape: [3, 8, 8],
        num_classes: 10,
        nodes,
        outputs: vec![lin_id],
        tensors,
        meta: BTreeMap::new(),
        act_stats: HashMap::new(),
        folded: false,
    }
}

/// SSD-style detection head: multi-scale feature taps, a per-scale conv
/// head each, global pools onto a shared 1x1 grid, channel concat:
///
/// ```text
/// input → conv3x3(3→8) → bn → relu                          (8x8 tap)
///   ├→ conv1x1(8→4) → bn → relu → global maxpool ──┐
///   └→ maxpool k=(2,3) s=(2,1) p=(0,1)             │        (4x8 tap)
///        ├→ conv3x3(8→4) → bn → relu → global avgpool ─┤
///        └→ maxpool k=(1,3) s=(1,2) p=(0,1)            │    (4x4 tap)
///             └→ conv1x1(8→4) → bn → relu → global avgpool ─┤
///                            concat (12ch, 1x1) ←───────────┘
///                 → conv1x1(12→8) → bn → relu → gap → linear(8→10)
/// ```
///
/// Exercises rectangular windows/strides/pads on the int8 pool path,
/// global max *and* avg pooling, and the multi-branch requantise-concat.
pub fn ssd_head_model(seed: u64) -> Model {
    let mut rng = Rng::new(seed);
    let mut tensors = BTreeMap::new();
    let mut nodes = vec![Node { id: 0, inputs: vec![], op: Op::Input }];
    let mut id = 0usize;
    let c = 8usize;

    let stem =
        conv_bn_relu(&mut nodes, &mut tensors, &mut rng, &mut id, 0, 3, c, 3);

    // scale 1: head on the full-resolution tap
    let h1 = conv_bn_relu(
        &mut nodes, &mut tensors, &mut rng, &mut id, stem, c, c / 2, 1,
    );
    // scale 2: rectangular downsample (8x8 → 4x8), then a 3x3 head
    id += 1;
    let pool1 = id;
    nodes.push(Node {
        id: pool1,
        inputs: vec![stem],
        op: Op::Pool2d {
            kind: PoolKind::Max,
            k: (2, 3),
            stride: (2, 1),
            pad: (0, 1),
            global: false,
        },
    });
    let h2 = conv_bn_relu(
        &mut nodes, &mut tensors, &mut rng, &mut id, pool1, c, c / 2, 3,
    );
    // scale 3: second rectangular pool (4x8 → 4x4), then a 1x1 head
    id += 1;
    let pool2 = id;
    nodes.push(Node {
        id: pool2,
        inputs: vec![pool1],
        op: Op::Pool2d {
            kind: PoolKind::Max,
            k: (1, 3),
            stride: (1, 2),
            pad: (0, 1),
            global: false,
        },
    });
    let h3 = conv_bn_relu(
        &mut nodes, &mut tensors, &mut rng, &mut id, pool2, c, c / 2, 1,
    );

    // per-scale global pools onto the shared 1x1 grid
    let mut gpool = |input: usize, kind: PoolKind| -> usize {
        id += 1;
        nodes.push(Node {
            id,
            inputs: vec![input],
            op: Op::global_pool2d(kind),
        });
        id
    };
    let g1 = gpool(h1, PoolKind::Max);
    let g2 = gpool(h2, PoolKind::Avg);
    let g3 = gpool(h3, PoolKind::Avg);

    id += 1;
    let cat = id;
    nodes.push(Node { id: cat, inputs: vec![g1, g2, g3], op: Op::Concat });
    let c_cat = 3 * (c / 2); // 12
    let merge = conv_bn_relu(
        &mut nodes, &mut tensors, &mut rng, &mut id, cat, c_cat, c, 1,
    );

    id += 1;
    let gap_id = id;
    nodes.push(Node { id: gap_id, inputs: vec![merge], op: Op::Gap });
    id += 1;
    let lin_id = id;
    let wl = format!("wl{lin_id}");
    tensors.insert(wl.clone(), rand_t(&mut rng, &[10, c], 0.4));
    let bl = format!("bl{lin_id}");
    tensors.insert(bl.clone(), rand_t(&mut rng, &[10], 0.2));
    nodes.push(Node {
        id: lin_id,
        inputs: vec![gap_id],
        op: Op::Linear { w: wl, b: bl, in_dim: c, out_dim: 10 },
    });

    Model {
        name: "test_ssd".into(),
        task: Task::Classification,
        input_shape: [3, 8, 8],
        num_classes: 10,
        nodes,
        outputs: vec![lin_id],
        tensors,
        meta: BTreeMap::new(),
        act_stats: HashMap::new(),
        folded: false,
    }
}

pub fn random_input(model: &Model, batch: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let [c, h, w] = model.input_shape;
    let data: Vec<f32> =
        (0..batch * c * h * w).map(|_| rng.f32()).collect();
    Tensor::new(&[batch, c, h, w], data)
}

/// Reference forward that evaluates bn nodes live (inference statistics),
/// independent of the folding code path.
pub fn forward_with_bn(model: &Model, x: &Tensor) -> Tensor {
    let mut vals: HashMap<usize, Tensor> = HashMap::new();
    vals.insert(0, x.clone());
    for n in &model.nodes {
        let y = match &n.op {
            Op::Input => continue,
            Op::Conv { w, b, stride, pad, groups, .. } => {
                let bias = b.as_ref().map(|b| model.tensor(b).unwrap().data());
                conv::conv2d(
                    &vals[&n.inputs[0]],
                    model.tensor(w).unwrap(),
                    bias,
                    *stride,
                    *pad,
                    *groups,
                )
            }
            Op::BatchNorm { ch, gamma, beta, mean, var } => {
                let g = model.tensor(gamma).unwrap().data();
                let be = model.tensor(beta).unwrap().data();
                let mu = model.tensor(mean).unwrap().data();
                let va = model.tensor(var).unwrap().data();
                let mut t = vals[&n.inputs[0]].clone();
                let s = t.shape().to_vec();
                let spatial = s[2] * s[3];
                let d = t.data_mut();
                for img in 0..s[0] {
                    for c in 0..*ch {
                        let inv = g[c] / (va[c] + super::bn_fold::BN_EPS).sqrt();
                        let base = (img * ch + c) * spatial;
                        for p in 0..spatial {
                            d[base + p] = (d[base + p] - mu[c]) * inv + be[c];
                        }
                    }
                }
                t
            }
            Op::Act(kind) => {
                let mut t = vals[&n.inputs[0]].clone();
                ops::clip_act(&mut t, kind.clip_hi());
                t
            }
            Op::Add => ops::add(&vals[&n.inputs[0]], &vals[&n.inputs[1]]),
            Op::Concat => {
                let ins: Vec<&Tensor> =
                    n.inputs.iter().map(|i| &vals[i]).collect();
                ops::concat_channels(&ins)
            }
            Op::Gap => ops::global_avg_pool(&vals[&n.inputs[0]]),
            Op::Pool2d { kind, k, stride, pad, global } => {
                let x = &vals[&n.inputs[0]];
                let (k, stride, pad) = if *global {
                    let s = x.shape();
                    ((s[2], s[3]), (1, 1), (0, 0))
                } else {
                    (*k, *stride, *pad)
                };
                match kind {
                    PoolKind::Max => ops::max_pool2d_rect(x, k, stride, pad),
                    PoolKind::Avg => ops::avg_pool2d_rect(x, k, stride, pad),
                }
            }
            Op::ConvT2d { w, b, stride, pad, .. } => {
                let bias = b.as_ref().map(|b| model.tensor(b).unwrap().data());
                conv::conv_transpose2d(
                    &vals[&n.inputs[0]],
                    model.tensor(w).unwrap(),
                    bias,
                    *stride,
                    *pad,
                )
            }
            Op::Linear { w, b, .. } => ops::linear(
                &vals[&n.inputs[0]],
                model.tensor(w).unwrap(),
                model.tensor(b).unwrap().data(),
            ),
            Op::Upsample { factor } => {
                ops::upsample_nearest(&vals[&n.inputs[0]], *factor)
            }
        };
        vals.insert(n.id, y);
    }
    vals.remove(&model.outputs[0]).unwrap()
}
