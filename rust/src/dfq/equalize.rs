//! Cross-layer range equalization (paper §4.1, appendix A).
//!
//! For every pair of layers connected without input/output splits, the
//! positive-scaling equivariance of (clipped-)ReLU lets us rescale
//! channel `i` by `s_i` in layer 1 and `1/s_i` in layer 2 without
//! changing the FP32 function. The optimum of eq. 9 is attained at
//! `s_i = sqrt(r1_i / r2_i)` (eq. 11), which matches per-channel ranges
//! across the pair; iterating over all pairs to convergence equalises
//! whole chains.

use anyhow::Result;

use crate::graph::{Model, Op};

/// A CLE-eligible pair: conv `a` feeds conv `b` through a
/// single-consumer chain of act / pool nodes (folded graph), possibly
/// none.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClePair {
    pub a: usize,
    pub b: usize,
    /// The act node on the chain, if any.
    pub act: Option<usize>,
    /// True when the chain crosses a `Pool2d` node: max and avg pool
    /// commute with per-channel positive scaling (`max(s·x) = s·max(x)`
    /// for `s > 0`; avg is linear), so the pair stays CLE-eligible.
    pub through_pool: bool,
}

/// Discover CLE pairs (paper §4.1.2: "pairs of layers that are connected
/// to each other without input or output splits in between"). The chain
/// may cross act and pool nodes — both are per-channel
/// positive-scale-equivariant — but stops at concat (channel identity is
/// lost), add, and every other non-monotone boundary.
pub fn find_pairs(model: &Model) -> Vec<ClePair> {
    assert!(model.folded, "CLE runs on the folded graph");
    let mut pairs = Vec::new();
    for n in &model.nodes {
        if !matches!(n.op, Op::Conv { .. }) {
            continue;
        }
        let mut cur = n.id;
        let mut act = None;
        let mut through_pool = false;
        loop {
            let cons = model.consumers(cur);
            if cons.len() != 1 {
                break;
            }
            let next = cons[0];
            match next.op {
                Op::Act(_) => {
                    act = Some(next.id);
                    cur = next.id;
                }
                Op::Pool2d { .. } => {
                    through_pool = true;
                    cur = next.id;
                }
                Op::Conv { .. } => {
                    pairs.push(ClePair {
                        a: n.id,
                        b: next.id,
                        act,
                        through_pool,
                    });
                    break;
                }
                _ => break,
            }
        }
    }
    pairs
}

/// Per-output-channel symmetric range of a conv weight: `2·max|W_i|`.
fn out_ranges(model: &Model, id: usize) -> Result<Vec<f32>> {
    let w = match &model.node(id).op {
        Op::Conv { w, .. } => model.tensor(w)?,
        _ => unreachable!(),
    };
    Ok((0..w.shape()[0])
        .map(|o| {
            2.0 * w
                .out_channel(o)
                .iter()
                .fold(0f32, |m, &x| m.max(x.abs()))
        })
        .collect())
}

/// Per-*input*-channel symmetric range of a conv weight.
fn in_ranges(model: &Model, id: usize) -> Result<Vec<f32>> {
    let n = model.node(id);
    let (w, dw, in_ch) = match &n.op {
        Op::Conv { w, in_ch, .. } => {
            (model.tensor(w)?, n.op.is_depthwise(), *in_ch)
        }
        _ => unreachable!(),
    };
    if dw {
        // depthwise: input channel i is exactly weight channel i
        return Ok((0..in_ch)
            .map(|i| {
                2.0 * w
                    .out_channel(i)
                    .iter()
                    .fold(0f32, |m, &x| m.max(x.abs()))
            })
            .collect());
    }
    let (o_count, i_count) = (w.shape()[0], w.shape()[1]);
    let spatial: usize = w.shape()[2..].iter().product();
    let mut out = vec![0f32; i_count];
    let d = w.data();
    for o in 0..o_count {
        for i in 0..i_count {
            let base = (o * i_count + i) * spatial;
            for s in 0..spatial {
                out[i] = out[i].max(d[base + s].abs());
            }
        }
    }
    Ok(out.into_iter().map(|x| 2.0 * x).collect())
}

/// Apply scale vector `s` to a pair: layer `a` out-channels divided by
/// `s_i` (weights, bias, stats), layer `b` in-channels multiplied.
fn apply_scales(model: &mut Model, pair: &ClePair, s: &[f32]) -> Result<()> {
    // layer a
    let (wa, ba) = match &model.node(pair.a).op {
        Op::Conv { w, b, .. } => (w.clone(), b.clone()),
        _ => unreachable!(),
    };
    {
        let w = model.tensor_mut(&wa)?;
        for (i, &si) in s.iter().enumerate() {
            w.scale_out_channel(i, 1.0 / si);
        }
    }
    if let Some(ba) = ba {
        let b = model.tensor_mut(&ba)?;
        for (i, &si) in s.iter().enumerate() {
            b.data_mut()[i] /= si;
        }
    }
    if let Some(st) = model.act_stats.get_mut(&pair.a) {
        for (i, &si) in s.iter().enumerate() {
            st.mean[i] /= si;
            st.std[i] /= si;
        }
    }
    // layer b
    let nb = model.node(pair.b);
    let dw = nb.op.is_depthwise();
    let wb = match &nb.op {
        Op::Conv { w, .. } => w.clone(),
        _ => unreachable!(),
    };
    let w = model.tensor_mut(&wb)?;
    for (i, &si) in s.iter().enumerate() {
        if dw {
            w.scale_out_channel(i, si);
        } else {
            w.scale_in_channel(i, si);
        }
    }
    Ok(())
}

/// Equalize one pair; returns the max |log s| applied (convergence gauge).
pub fn equalize_pair(model: &mut Model, pair: &ClePair) -> Result<f32> {
    let r1 = out_ranges(model, pair.a)?;
    let r2 = in_ranges(model, pair.b)?;
    debug_assert_eq!(r1.len(), r2.len(), "pair channel mismatch");
    let s: Vec<f32> = r1
        .iter()
        .zip(&r2)
        .map(|(&a, &b)| {
            // dead channels (zero-range filters) and non-finite ranges
            // would give s = 0 / ∞ / NaN from r1·r2 = 0 — pin them to
            // the identity scale instead of corrupting the pair
            // (is_finite first: it also rejects NaN ranges).
            if !a.is_finite() || !b.is_finite() || a <= 0.0 || b <= 0.0 {
                1.0
            } else {
                (a / b).sqrt() // = (1/r2) * sqrt(r1*r2), eq. 11
            }
        })
        .collect();
    apply_scales(model, pair, &s)?;
    Ok(s.iter().fold(0f32, |m, &x| m.max(x.ln().abs())))
}

/// Iterate equalization over all pairs until convergence (paper §4.1.2).
/// Returns the number of sweeps performed.
pub fn equalize(model: &mut Model, max_iters: usize, tol: f32) -> Result<usize> {
    Ok(equalize_traced(model, max_iters, tol)?.len())
}

/// [`equalize`] keeping the convergence trace: one entry per sweep, the
/// worst |log s| applied across all pairs in that sweep (the gauge the
/// stop rule tests). `trace.len()` is the sweep count; the last entry is
/// `< tol` iff the iteration converged before `max_iters`.
pub fn equalize_traced(
    model: &mut Model,
    max_iters: usize,
    tol: f32,
) -> Result<Vec<f32>> {
    let pairs = find_pairs(model);
    let mut trace = Vec::new();
    for _ in 0..max_iters {
        let mut worst = 0f32;
        for p in &pairs {
            worst = worst.max(equalize_pair(model, p)?);
        }
        trace.push(worst);
        if worst < tol {
            break;
        }
    }
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::bn_fold;
    use crate::dfq::testutil::{random_input, two_layer_model};
    use crate::nn::{self, QuantCfg};
    use crate::util::rng::Rng;

    fn prepared() -> Model {
        bn_fold::fold(&two_layer_model(11, true)).unwrap()
    }

    #[test]
    fn finds_the_pair() {
        let m = prepared();
        let pairs = find_pairs(&m);
        assert_eq!(pairs.len(), 1);
        assert!(pairs[0].act.is_some());
    }

    #[test]
    fn preserves_function() {
        let mut m = prepared();
        // corrupt per-channel scales first so there is something to fix
        let mut rng = Rng::new(5);
        let pair = find_pairs(&m)[0];
        let s: Vec<f32> = (0..8).map(|_| rng.log_uniform(0.1, 10.0)).collect();
        super::apply_scales(&mut m, &pair, &s).unwrap();
        let x = random_input(&m, 2, 3);
        let y0 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();

        let sweeps = equalize(&mut m, 50, 1e-4).unwrap();
        assert!(sweeps >= 1);
        let y1 = nn::forward(&m, &x, &QuantCfg::fp32(&m)).unwrap();
        let rel = y0[0].max_abs_diff(&y1[0]) / y0[0].abs_max().max(1e-6);
        assert!(rel < 1e-3, "CLE changed FP32 function by {rel}");
    }

    #[test]
    fn matches_ranges_across_pair() {
        let mut m = prepared();
        let pair = find_pairs(&m)[0];
        equalize(&mut m, 50, 1e-5).unwrap();
        let r1 = out_ranges(&m, pair.a).unwrap();
        let r2 = in_ranges(&m, pair.b).unwrap();
        for (a, b) in r1.iter().zip(&r2) {
            assert!((a - b).abs() < 1e-3 * a.max(*b), "{a} vs {b}");
        }
    }

    #[test]
    fn dead_channel_gets_identity_scale() {
        // an all-zero output filter has r1 = 0; eq. 11 would give
        // s = sqrt(0 / r2) = 0 and 1/s = inf — the guard must pin s = 1
        // and leave every weight finite
        let mut m = prepared();
        let pair = find_pairs(&m)[0];
        let (wa, wb) = match (&m.node(pair.a).op, &m.node(pair.b).op) {
            (Op::Conv { w: a, .. }, Op::Conv { w: b, .. }) => {
                (a.clone(), b.clone())
            }
            _ => unreachable!(),
        };
        {
            let w = m.tensor_mut(&wa).unwrap();
            for x in w.out_channel_mut(0) {
                *x = 0.0;
            }
        }
        let before_b = m.tensor(&wb).unwrap().clone();
        let worst = equalize_pair(&mut m, &pair).unwrap();
        assert!(worst.is_finite(), "non-finite convergence gauge");
        let w_a = m.tensor(&wa).unwrap();
        assert!(
            w_a.out_channel(0).iter().all(|&x| x == 0.0),
            "dead channel must stay dead"
        );
        assert!(
            w_a.data().iter().all(|x| x.is_finite()),
            "layer a weights went non-finite"
        );
        let w_b = m.tensor(&wb).unwrap();
        assert!(w_b.data().iter().all(|x| x.is_finite()));
        // s == 1 for the dead channel: b's matching in-channel untouched
        let i_count = w_b.shape()[1];
        let spatial: usize = w_b.shape()[2..].iter().product();
        for o in 0..w_b.shape()[0] {
            let base = o * i_count * spatial;
            for s in 0..spatial {
                assert_eq!(
                    w_b.data()[base + s],
                    before_b.data()[base + s],
                    "in-channel 0 of layer b was rescaled"
                );
            }
        }
        // a full equalize run over the damaged model still converges
        let sweeps = equalize(&mut m, 50, 1e-4).unwrap();
        assert!(sweeps >= 1);
    }

    #[test]
    fn improves_precision_objective() {
        // eq. 9 objective must not decrease
        let mut m = prepared();
        let mut rng = Rng::new(8);
        let pair = find_pairs(&m)[0];
        let s: Vec<f32> = (0..8).map(|_| rng.log_uniform(0.05, 20.0)).collect();
        super::apply_scales(&mut m, &pair, &s).unwrap();

        let objective = |m: &Model| -> f32 {
            let wa = match &m.node(pair.a).op {
                Op::Conv { w, .. } => m.tensor(w).unwrap(),
                _ => unreachable!(),
            };
            let wb = match &m.node(pair.b).op {
                Op::Conv { w, .. } => m.tensor(w).unwrap(),
                _ => unreachable!(),
            };
            let p1 = crate::quant::channel_precision(wa);
            // in-channel precision for b
            let r2 = in_ranges(m, pair.b).unwrap();
            let total = 2.0 * wb.abs_max();
            p1.iter()
                .zip(&r2)
                .map(|(p, r)| p * (r / total))
                .sum()
        };
        let before = objective(&m);
        equalize(&mut m, 50, 1e-5).unwrap();
        let after = objective(&m);
        assert!(after >= before - 1e-4, "objective fell: {before} -> {after}");
    }
}
