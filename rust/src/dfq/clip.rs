//! Weight-clipping baseline (paper §5.1.2).
//!
//! "Weight clipping solves the problem of large differences in ranges
//! between channels by clipping large ranges to smaller ranges, but it
//! introduces a strongly biased error" — which bias correction then
//! repairs. Applied after BN folding on every conv/linear weight.

use anyhow::Result;

use crate::graph::{Model, Op};

/// Clip every conv/linear weight to `[-c, c]` in place.
/// Returns the number of clipped elements.
pub fn clip_weights(model: &mut Model, c: f32) -> Result<usize> {
    assert!(model.folded, "clip runs on the folded graph");
    let names: Vec<String> = model
        .layers()
        .iter()
        .map(|n| match &n.op {
            Op::Conv { w, .. }
            | Op::ConvT2d { w, .. }
            | Op::Linear { w, .. } => w.clone(),
            _ => unreachable!(),
        })
        .collect();
    let mut clipped = 0usize;
    for name in names {
        let t = model.tensor_mut(&name)?;
        for x in t.data_mut() {
            if x.abs() > c {
                *x = x.clamp(-c, c);
                clipped += 1;
            }
        }
    }
    Ok(clipped)
}

/// A data-driven default clip level: the q-quantile of |w| across all
/// layer weights (the paper's fixed 15 corresponds to roughly the
/// 99.9th percentile of MobileNetV2's folded weights).
pub fn quantile_clip_level(model: &Model, q: f64) -> f32 {
    let mut all: Vec<f32> = Vec::new();
    for n in model.layers() {
        let w = match &n.op {
            Op::Conv { w, .. }
            | Op::ConvT2d { w, .. }
            | Op::Linear { w, .. } => w,
            _ => unreachable!(),
        };
        all.extend(model.tensor(w).unwrap().data().iter().map(|x| x.abs()));
    }
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((all.len() - 1) as f64 * q).round() as usize;
    all[idx]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::bn_fold;
    use crate::dfq::testutil::two_layer_model;

    #[test]
    fn clips_in_place() {
        let mut m = bn_fold::fold(&two_layer_model(41, true)).unwrap();
        let c = 0.05;
        let n = clip_weights(&mut m, c).unwrap();
        assert!(n > 0);
        for node in m.layers() {
            let w = match &node.op {
                Op::Conv { w, .. } | Op::Linear { w, .. } => w,
                _ => unreachable!(),
            };
            assert!(m.tensor(w).unwrap().abs_max() <= c + 1e-7);
        }
    }

    #[test]
    fn quantile_level_monotone() {
        let m = bn_fold::fold(&two_layer_model(42, true)).unwrap();
        let c50 = quantile_clip_level(&m, 0.5);
        let c99 = quantile_clip_level(&m, 0.99);
        assert!(c99 >= c50);
        assert!(c50 > 0.0);
    }
}
