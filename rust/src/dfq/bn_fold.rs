//! BatchNorm folding (the paper's §5 pre-step: "Batch normalization is
//! folded in the adjacent layer before quantization").
//!
//! `W' = W · γ/σ`, `b' = (b − μ)·γ/σ + β` per output channel; the bn node
//! is removed and the conv inherits its consumers. Folding also seeds
//! [`crate::graph::ChannelStats`]: the folded conv's pre-activation is
//! distributed N(β, γ²) — the data-free handle every later pass uses.

use anyhow::{bail, Result};

use crate::graph::{ChannelStats, Model, Op};

pub const BN_EPS: f32 = 1e-5;

/// Fold all BatchNorm nodes into their producing convolutions.
/// Returns a new, folded model; the input is left untouched.
pub fn fold(model: &Model) -> Result<Model> {
    let mut m = model.clone();
    fold_in_place(&mut m)?;
    Ok(m)
}

/// [`fold`] operating on the model in place — the pass-manager entry
/// point, avoiding a second deep copy of the tensor table when the
/// caller already owns a working clone. No-op on a folded model.
pub fn fold_in_place(m: &mut Model) -> Result<()> {
    if m.folded {
        return Ok(());
    }
    let bn_nodes: Vec<usize> = m
        .nodes
        .iter()
        .filter(|n| matches!(n.op, Op::BatchNorm { .. }))
        .map(|n| n.id)
        .collect();

    for bn_id in bn_nodes {
        let (bn_inputs, ch, gamma, beta, mean, var) = {
            let n = m.node(bn_id);
            match &n.op {
                Op::BatchNorm { ch, gamma, beta, mean, var } => (
                    n.inputs.clone(),
                    *ch,
                    gamma.clone(),
                    beta.clone(),
                    mean.clone(),
                    var.clone(),
                ),
                _ => unreachable!(),
            }
        };
        let conv_id = bn_inputs[0];
        let (w_name, b_name, out_ch) = {
            let p = m.node(conv_id);
            match &p.op {
                Op::Conv { w, b, out_ch, .. }
                | Op::ConvT2d { w, b, out_ch, .. } => {
                    (w.clone(), b.clone(), *out_ch)
                }
                other => bail!(
                    "bn node {bn_id} follows {:?}, only conv/convT supported",
                    other.kind()
                ),
            }
        };
        if out_ch != ch {
            bail!("bn {bn_id} channel mismatch");
        }

        let g = m.tensor(&gamma)?.data().to_vec();
        let be = m.tensor(&beta)?.data().to_vec();
        let mu = m.tensor(&mean)?.data().to_vec();
        let va = m.tensor(&var)?.data().to_vec();

        // scale = gamma / sqrt(var + eps)
        let scale: Vec<f32> = g
            .iter()
            .zip(&va)
            .map(|(g, v)| g / (v + BN_EPS).sqrt())
            .collect();

        // fold into weights
        {
            let w = m.tensor_mut(&w_name)?;
            for (o, s) in scale.iter().enumerate() {
                w.scale_out_channel(o, *s);
            }
        }
        // fold into (possibly synthetic) bias — name must match the
        // python lowering: "fb{conv_id}" when the conv had none.
        let bias_name = match &b_name {
            Some(b) => b.clone(),
            None => format!("fb{conv_id}"),
        };
        let mut bias = match &b_name {
            Some(b) => m.tensor(b)?.data().to_vec(),
            None => vec![0.0; out_ch],
        };
        for o in 0..out_ch {
            bias[o] = (bias[o] - mu[o]) * scale[o] + be[o];
        }
        m.tensors
            .insert(bias_name.clone(), crate::tensor::Tensor::from_vec(bias));
        {
            let p = m.node_mut(conv_id);
            if let Op::Conv { b, .. } | Op::ConvT2d { b, .. } = &mut p.op {
                *b = Some(bias_name);
            }
        }

        // pre-activation statistics: N(beta, gamma^2)
        m.act_stats.insert(
            conv_id,
            ChannelStats {
                mean: be.clone(),
                std: g.iter().map(|x| x.abs()).collect(),
            },
        );

        // rewire consumers of the bn node to the conv, drop bn + params
        for n in &mut m.nodes {
            for i in &mut n.inputs {
                if *i == bn_id {
                    *i = conv_id;
                }
            }
        }
        for o in &mut m.outputs {
            if *o == bn_id {
                *o = conv_id;
            }
        }
        m.nodes.retain(|n| n.id != bn_id);
        for t in [gamma, beta, mean, var] {
            m.tensors.remove(&t);
        }
    }
    m.folded = true;
    m.validate()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfq::testutil::{random_input, two_layer_model};
    use crate::nn::{self, QuantCfg};

    #[test]
    fn folding_preserves_function() {
        let model = two_layer_model(77, true);
        let folded = fold(&model).unwrap();
        assert!(folded.folded);
        // same outputs on the engine (bn applied live vs folded)
        let x = random_input(&model, 3, 11);
        let y_folded =
            nn::forward(&folded, &x, &QuantCfg::fp32(&folded)).unwrap();
        // reference: evaluate unfolded via manual bn-aware path
        let y_ref = crate::dfq::testutil::forward_with_bn(&model, &x);
        assert_eq!(y_folded.len(), 1);
        let d = y_folded[0].max_abs_diff(&y_ref);
        assert!(d < 1e-4, "fold changed function by {d}");
    }

    #[test]
    fn fold_populates_stats() {
        let model = two_layer_model(78, true);
        let folded = fold(&model).unwrap();
        // first conv gained stats from its bn
        let convs: Vec<usize> = folded
            .layers()
            .iter()
            .filter(|n| matches!(n.op, Op::Conv { .. }))
            .map(|n| n.id)
            .collect();
        assert!(folded.act_stats.contains_key(&convs[0]));
        let st = &folded.act_stats[&convs[0]];
        assert!(st.std.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn fold_is_idempotent() {
        let model = two_layer_model(79, true);
        let f1 = fold(&model).unwrap();
        let f2 = fold(&f1).unwrap();
        assert_eq!(f1.nodes.len(), f2.nodes.len());
    }
}
